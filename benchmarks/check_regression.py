"""Bench regression gate: fresh results/BENCH_*.json vs committed baselines.

  PYTHONPATH=src python -m benchmarks.check_regression [--tolerance 0.2]
  PYTHONPATH=src python -m benchmarks.check_regression --partial
  PYTHONPATH=src python -m benchmarks.check_regression --update

Baselines live in benchmarks/baselines/ (committed — the bench
trajectory starts here).  Two kinds of gate:

  * baseline gates (GATES): *deterministic* metrics (byte counts, token
    counts, ratios) compared against the committed baseline within
    ``--tolerance`` (default ±20%).  Wall-clock numbers are never
    compared across machines — CI runners are too noisy.
  * directional gates (DIRECTIONAL): win-or-fail comparisons evaluated
    on the FRESH results alone.  Both sides come from the same run on
    the same machine, so these CAN gate wall-clock: the compressed
    cross-pod sync must beat the dense sync's step-time median, or the
    lane goes red.  Directional gates run even under ``--update`` — a
    losing bench cannot be baselined away.

Coverage is closed both ways: a fresh BENCH_*.json with no GATES entry
(orphan output) is a hard failure, and a committed baseline with no
fresh result (orphan baseline) is a hard failure unless ``--partial``
is passed by jobs that intentionally run a subset of the benches.
``--update`` rewrites the baselines from the fresh results (run it when
a drift is intentional and commit the diff).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

HERE = os.path.dirname(__file__)
RESULTS = os.path.join(HERE, "..", "results")
BASELINES = os.path.join(HERE, "baselines")

# file -> dotted-path prefixes of gated metrics.  A prefix selects every
# numeric leaf beneath it ("loads.*" wildcards one list level).
GATES = {
    "BENCH_serve.json": [
        "hbm.idx_bits",
        "hbm.packed_weight_bytes",
        "hbm.packed_weight_bytes_4bit_idx",
        "hbm.measured_packed_weight_bytes",
        "hbm.dense_weight_bytes",
        "hbm.hbm_saving",
        "hbm.total_hbm_bytes",
        # measured decode traffic: structural HLO bytes of one compiled
        # decode step (deterministic for a pinned jax), u4 vs u8 store
        "decode.hlo_bytes_per_step_u4",
        "decode.hlo_bytes_per_step_u8",
        "decode.idx_bytes_saved_accounted",
        # per-projection stored bytes (latency keys are wall-clock and
        # deliberately NOT gated)
        "projections.*.vals_bytes",
        "projections.*.idx_bytes",
        "projections.*.stored_bytes",
        "projections.*.dense_bytes",
        "projections.*.idx_bits",
        "loads.*.tokens",
        "loads.*.decode_steps",
        "loads.*.slot_utilization",
    ],
    "BENCH_fleet.json": [
        "workload.n_requests",
        "workload.distinct_prompts",
        # latencies are in fleet STEPS (deterministic given the seeded
        # arrivals + seeded router), not wall-clock — gateable
        "loads.*.tokens",
        "loads.*.decode_steps",
        "loads.*.prefill_steps",
        "loads.*.prefix_hits",
        "loads.*.hit_rate",
        "loads.*.latency_steps_p50",
        "loads.*.latency_steps_p95",
        "routing.prefix.prefill_steps",
        "routing.least_loaded.prefill_steps",
        "routing.random.prefill_steps",
        "routing.prefill_steps_saved",
        "routing.streams_match_across_policies",
        "disagg.streams_equal",
        "disagg.tokens",
        "disagg.handoff_lanes",
        # 0 ± 20% of 0 rejects ANY prefill on a decode engine
        "disagg.decode_prefill_steps",
        "disagg.store_leftover",
    ],
    "BENCH_spmd.json": [
        "sync.dense_bytes",
        "sync.packed_bytes",
        "sync.wire_ratio",
        "variants.dense_sync.collectives.total",
        "variants.compressed_sync.collectives.total",
        "variants.dense_sync.pod_link_bytes",
        "variants.compressed_sync.pod_link_bytes",
        "variants.dense_sync.hlo_flops",
        "variants.compressed_sync.hlo_flops",
    ],
    # mask-once invariant: one fused top_k per prunable param at WU time
    # (±20% of 1.0 still rejects any regrown selection — counts are ints);
    # moe_pregen gates the same invariant for bare-array expert stacks
    "BENCH_pregen.json": [
        "mask_ops.pregen",
        "mask_ops.pregen_packed",
        "mask_ops.prunable_params",
        "mask_ops.pregen_per_param",
        "moe_pregen.mask_ops.pregen",
        "moe_pregen.mask_ops.prunable_params",
        "moe_pregen.mask_ops.pregen_per_param",
        # unified packed-FF train consumption (SparseOperand/nm_apply):
        # the forward must stay scatter-free on both backends (0 ± 20%
        # of 0 rejects ANY regrown scatter-unpack), invoke nm_spmm per
        # packed site on pallas, and keep the packed FF HBM saving
        "packed_train.packed_sites",
        "packed_train.forward_scatter_ops.jnp",
        "packed_train.forward_scatter_ops.pallas",
        "packed_train.forward_nm_spmm_calls.pallas",
        "packed_train.ff_hbm_bytes.packed",
        "packed_train.ff_hbm_bytes.dense",
        "packed_train.ff_hbm_bytes.saving",
    ],
}


# file -> (lhs dotted path, op, rhs) win-or-fail comparisons evaluated on
# the FRESH result alone.  rhs is either another dotted path into the same
# file or a numeric literal.  Both sides of a path-vs-path gate come from
# one run on one machine, so wall-clock medians are fair game here even
# though GATES never compares them across machines.
DIRECTIONAL = {
    "BENCH_serve.json": [
        # the u4 store must SHIP what it accounts: live buffer bytes of
        # the packed tree within ±5% of the SORE 4-bit-idx footprint
        # (they are equal by construction today; 5% leaves room for
        # padding on odd compact extents without letting the accounting
        # drift back to fiction)
        ("hbm.measured_over_accounted_4bit", ">=", 0.95),
        ("hbm.measured_over_accounted_4bit", "<=", 1.05),
        # the fused u4 decode must move fewer bytes per step than the
        # byte-wide control — measured off the optimized HLO of the
        # exact compiled decode, same run, same machine
        ("decode.hlo_bytes_per_step_u4", "<=",
         "decode.hlo_bytes_per_step_u8"),
    ],
    "BENCH_fleet.json": [
        # the KV-affinity win, win-or-fail: on the shared-prefix trace
        # the prefix router must serve with STRICTLY fewer compiled
        # prefill steps than the random-routing control (same trace,
        # same run — integers, so >= 1 means strictly fewer)
        ("routing.prefill_steps_saved", ">=", 1),
        # ...and no worse tail latency at the same offered load (both
        # sides in deterministic fleet steps from one run)
        ("routing.prefix.latency_steps_p95", "<=",
         "routing.random.latency_steps_p95"),
        # routing decides WHERE work runs, never WHAT comes out
        ("routing.streams_match_across_policies", ">=", 1),
        # disaggregated prefill/decode must be bitwise invisible: the
        # handed-off streams equal the colocated engine's, measured
        ("disagg.streams_equal", ">=", 1),
        ("disagg.decode_prefill_steps", "<=", 0),
    ],
    "BENCH_spmd.json": [
        # the whole point of the compressed sync: it must WIN, not just
        # ship.  step_ms_median = measured compute + measured pod-crossing
        # bytes charged at the bench's fixed emulated inter-pod link
        # (spmd_bench.POD_LINK_GBPS) — so this passes only when the real
        # compute overhead of compressing is smaller than the wire time
        # the real byte saving buys
        ("variants.compressed_sync.step_ms_median", "<=",
         "variants.dense_sync.step_ms_median"),
        # and the measured pod-crossing traffic itself must shrink
        ("variants.compressed_sync.pod_link_bytes", "<=",
         "variants.dense_sync.pod_link_bytes"),
        # 2:8 payload (bf16 vals + uint8 idx) must stay ≤ a quarter of the
        # dense fp32 wire bytes
        ("sync.wire_ratio", "<=", 0.25),
    ],
}


def _flatten(node, prefix=""):
    out = {}
    if isinstance(node, dict):
        for k, v in node.items():
            out.update(_flatten(v, f"{prefix}{k}."))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            out.update(_flatten(v, f"{prefix}{i}."))
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        out[prefix[:-1]] = float(node)
    return out


def _match(path: str, pattern: str) -> bool:
    ps, qs = path.split("."), pattern.split(".")
    if len(ps) < len(qs):
        return False
    return all(q == "*" or p == q for p, q in zip(ps, qs))


def check_file(name: str, fresh_path: str, base_path: str,
               tol: float) -> list:
    with open(fresh_path) as f:
        fresh = _flatten(json.load(f))
    with open(base_path) as f:
        base = _flatten(json.load(f))
    failures = []
    patterns = GATES[name]
    gated = [p for p in base
             if any(_match(p, pat) for pat in patterns)]
    for path in sorted(gated):
        if "interpret" in path:
            # benches label CPU interpret-mode kernel timings with an
            # "_interpret" suffix: they measure the Pallas interpreter,
            # not the kernel, and gating one is a configuration error
            failures.append(f"{name}:{path}: interpret-mode metric is "
                            f"gated — fix the GATES pattern")
            continue
        old = base[path]
        new = fresh.get(path)
        if new is None:
            failures.append(f"{name}:{path}: metric vanished "
                            f"(baseline {old})")
            continue
        bound = tol * max(abs(old), 1e-9)
        if abs(new - old) > bound:
            failures.append(
                f"{name}:{path}: {new:g} vs baseline {old:g} "
                f"(|Δ|={abs(new - old):g} > ±{tol:.0%})")
    return failures


def check_directional(name: str, fresh_path: str) -> list:
    with open(fresh_path) as f:
        fresh = _flatten(json.load(f))
    failures = []
    for lhs, op, rhs in DIRECTIONAL.get(name, []):
        left = fresh.get(lhs)
        right = fresh.get(rhs) if isinstance(rhs, str) else float(rhs)
        if left is None or right is None:
            missing = lhs if left is None else rhs
            failures.append(f"{name}:{missing}: directional gate operand "
                            f"missing from fresh result")
            continue
        ok = left <= right if op == "<=" else left >= right
        tag = "ok" if ok else "FAIL"
        rhs_tag = f"{rhs}=" if isinstance(rhs, str) else ""
        print(f"[{tag}] {name}: {lhs}={left:g} {op} {rhs_tag}{right:g}")
        if not ok:
            failures.append(f"{name}: {lhs}={left:g} must be {op} "
                            f"{rhs}={right:g} (win-or-fail)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results", default=RESULTS)
    ap.add_argument("--baselines", default=BASELINES)
    ap.add_argument("--tolerance", type=float, default=0.20)
    ap.add_argument("--update", action="store_true",
                    help="rewrite baselines from fresh results")
    ap.add_argument("--partial", action="store_true",
                    help="job runs a subset of the benches: absent fresh "
                         "results are skips, not orphan-baseline failures")
    args = ap.parse_args(argv)

    # gate-config sanity: no gate may name an interpret-mode metric
    bad = [p for pats in GATES.values() for p in pats if "interpret" in p]
    bad += [f"{lhs} {op} {rhs}" for gates in DIRECTIONAL.values()
            for (lhs, op, rhs) in gates
            if "interpret" in lhs or "interpret" in str(rhs)]
    if bad:
        print(f"[FAIL] gate config touches interpret-mode metrics: {bad}")
        return 1

    os.makedirs(args.baselines, exist_ok=True)
    failures, checked = [], 0

    # coverage closure, fresh side: every results/BENCH_*.json must have a
    # gate entry, or the bench silently escapes regression tracking
    for path in sorted(glob.glob(os.path.join(args.results, "BENCH_*.json"))):
        name = os.path.basename(path)
        if name not in GATES:
            failures.append(f"{name}: fresh result has no GATES entry "
                            f"(orphan output — add gates or delete the bench)")
            print(f"[FAIL] {failures[-1]}")

    for name in sorted(GATES):
        fresh_path = os.path.join(args.results, name)
        base_path = os.path.join(args.baselines, name)
        if not os.path.exists(fresh_path):
            # coverage closure, baseline side: a committed baseline whose
            # bench stopped emitting would drift forever unnoticed
            if os.path.exists(base_path) and not args.partial:
                failures.append(
                    f"{name}: baseline committed but no fresh result in "
                    f"{args.results} (orphan baseline — run the bench or "
                    f"pass --partial for subset jobs)")
                print(f"[FAIL] {failures[-1]}")
            else:
                print(f"[skip] {name}: no fresh result in {args.results}")
            continue
        # directional gates run even under --update: a losing bench result
        # must never be baselined into green
        failures.extend(check_directional(name, fresh_path))
        if args.update:
            with open(fresh_path) as f:
                data = f.read()
            with open(base_path, "w") as f:
                f.write(data)
            print(f"[baseline] {name} updated")
            continue
        if not os.path.exists(base_path):
            # a gate with no reference is a silent no-op — refuse;
            # baselines are committed, bootstrap explicitly via --update
            failures.append(f"{name}: no baseline in {args.baselines} "
                            f"(run with --update and commit it)")
            print(f"[FAIL] {failures[-1]}")
            continue
        fails = check_file(name, fresh_path, base_path, args.tolerance)
        checked += 1
        if fails:
            failures.extend(fails)
            for line in fails:
                print(f"[FAIL] {line}")
        else:
            print(f"[ok] {name} within ±{args.tolerance:.0%}")
    if failures:
        print(f"\n{len(failures)} regression(s). Intentional? "
              f"re-run with --update and commit the baseline diff.")
        return 1
    print(f"\n{checked} bench file(s) checked, no regressions.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
