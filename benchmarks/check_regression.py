"""Bench regression gate: fresh results/BENCH_*.json vs committed baselines.

  PYTHONPATH=src python -m benchmarks.check_regression [--tolerance 0.2]
  PYTHONPATH=src python -m benchmarks.check_regression --update

Baselines live in benchmarks/baselines/ (committed — the bench
trajectory starts here).  Only *deterministic* metrics are gated (byte
counts, token counts, ratios); wall-clock numbers are recorded in the
JSON but never compared — CI machines are too noisy.  A gated metric
drifting more than ``--tolerance`` (default ±20%) from its baseline
exits nonzero with a per-metric report; ``--update`` rewrites the
baselines from the fresh results instead (run it when a drift is
intentional and commit the diff).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

HERE = os.path.dirname(__file__)
RESULTS = os.path.join(HERE, "..", "results")
BASELINES = os.path.join(HERE, "baselines")

# file -> dotted-path prefixes of gated metrics.  A prefix selects every
# numeric leaf beneath it ("loads.*" wildcards one list level).
GATES = {
    "BENCH_serve.json": [
        "hbm.packed_weight_bytes",
        "hbm.dense_weight_bytes",
        "hbm.hbm_saving",
        "hbm.total_hbm_bytes",
        "loads.*.tokens",
        "loads.*.decode_steps",
        "loads.*.slot_utilization",
    ],
    "BENCH_spmd.json": [
        "sync.dense_bytes",
        "sync.packed_bytes",
        "sync.wire_ratio",
        "variants.dense_sync.collectives.total",
        "variants.compressed_sync.collectives.total",
        "variants.dense_sync.hlo_flops",
        "variants.compressed_sync.hlo_flops",
    ],
    # mask-once invariant: one fused top_k per prunable param at WU time
    # (±20% of 1.0 still rejects any regrown selection — counts are ints);
    # moe_pregen gates the same invariant for bare-array expert stacks
    "BENCH_pregen.json": [
        "mask_ops.pregen",
        "mask_ops.pregen_packed",
        "mask_ops.prunable_params",
        "mask_ops.pregen_per_param",
        "moe_pregen.mask_ops.pregen",
        "moe_pregen.mask_ops.prunable_params",
        "moe_pregen.mask_ops.pregen_per_param",
        # unified packed-FF train consumption (SparseOperand/nm_apply):
        # the forward must stay scatter-free on both backends (0 ± 20%
        # of 0 rejects ANY regrown scatter-unpack), invoke nm_spmm per
        # packed site on pallas, and keep the packed FF HBM saving
        "packed_train.packed_sites",
        "packed_train.forward_scatter_ops.jnp",
        "packed_train.forward_scatter_ops.pallas",
        "packed_train.forward_nm_spmm_calls.pallas",
        "packed_train.ff_hbm_bytes.packed",
        "packed_train.ff_hbm_bytes.dense",
        "packed_train.ff_hbm_bytes.saving",
    ],
}


def _flatten(node, prefix=""):
    out = {}
    if isinstance(node, dict):
        for k, v in node.items():
            out.update(_flatten(v, f"{prefix}{k}."))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            out.update(_flatten(v, f"{prefix}{i}."))
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        out[prefix[:-1]] = float(node)
    return out


def _match(path: str, pattern: str) -> bool:
    ps, qs = path.split("."), pattern.split(".")
    if len(ps) < len(qs):
        return False
    return all(q == "*" or p == q for p, q in zip(ps, qs))


def check_file(name: str, fresh_path: str, base_path: str,
               tol: float) -> list:
    with open(fresh_path) as f:
        fresh = _flatten(json.load(f))
    with open(base_path) as f:
        base = _flatten(json.load(f))
    failures = []
    patterns = GATES[name]
    gated = [p for p in base
             if any(_match(p, pat) for pat in patterns)]
    for path in sorted(gated):
        old = base[path]
        new = fresh.get(path)
        if new is None:
            failures.append(f"{name}:{path}: metric vanished "
                            f"(baseline {old})")
            continue
        bound = tol * max(abs(old), 1e-9)
        if abs(new - old) > bound:
            failures.append(
                f"{name}:{path}: {new:g} vs baseline {old:g} "
                f"(|Δ|={abs(new - old):g} > ±{tol:.0%})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results", default=RESULTS)
    ap.add_argument("--baselines", default=BASELINES)
    ap.add_argument("--tolerance", type=float, default=0.20)
    ap.add_argument("--update", action="store_true",
                    help="rewrite baselines from fresh results")
    args = ap.parse_args(argv)

    os.makedirs(args.baselines, exist_ok=True)
    failures, checked = [], 0
    for name in sorted(GATES):
        fresh_path = os.path.join(args.results, name)
        base_path = os.path.join(args.baselines, name)
        if not os.path.exists(fresh_path):
            print(f"[skip] {name}: no fresh result in {args.results}")
            continue
        if args.update:
            with open(fresh_path) as f:
                data = f.read()
            with open(base_path, "w") as f:
                f.write(data)
            print(f"[baseline] {name} updated")
            continue
        if not os.path.exists(base_path):
            # a gate with no reference is a silent no-op — refuse;
            # baselines are committed, bootstrap explicitly via --update
            failures.append(f"{name}: no baseline in {args.baselines} "
                            f"(run with --update and commit it)")
            print(f"[FAIL] {failures[-1]}")
            continue
        fails = check_file(name, fresh_path, base_path, args.tolerance)
        checked += 1
        if fails:
            failures.extend(fails)
            for line in fails:
                print(f"[FAIL] {line}")
        else:
            print(f"[ok] {name} within ±{args.tolerance:.0%}")
    if failures:
        print(f"\n{len(failures)} regression(s). Intentional? "
              f"re-run with --update and commit the baseline diff.")
        return 1
    print(f"\n{checked} bench file(s) checked, no regressions.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
