"""Fig. 14 reproduction: FPGA resource overhead of STCE vs dense
systolic arrays (analytical LUT/FF/DSP model from satsim.arch).

Compares a 4x4 dense baseline against 4x4 STCEs at 2:4 / 2:8 / 2:16,
and each STCE against the dense array of EQUAL THROUGHPUT (4x8, 4x16,
4x32) — the paper's headline: 2:8 STCE beats the iso-throughput 4x16
dense array by ~3.4x LUT / 2.0x FF / 4.0x DSP.
"""

from __future__ import annotations

import dataclasses

from repro.satsim.arch import SATConfig, stce_resources

BASE = SATConfig(array=4)


def run() -> list:
    rows = []
    dense4 = stce_resources(BASE, dense=True)
    rows.append({"config": "4x4 dense", **{k: round(v) for k, v in dense4.items()},
                 "rel_lut": 1.0, "rel_ff": 1.0, "dsp": dense4["dsp"]})
    for n, m in ((2, 4), (2, 8), (2, 16)):
        cfg = dataclasses.replace(BASE, n=n, m=m)
        r = stce_resources(cfg)
        rows.append({
            "config": f"4x4 STCE {n}:{m}",
            **{k: round(v) for k, v in r.items()},
            "rel_lut": round(r["lut"] / dense4["lut"], 2),
            "rel_ff": round(r["ff"] / dense4["ff"], 2),
        })
        # iso-throughput dense array: m/n x the MACs/cycle -> 4 x 4*(m/n)
        iso_cols = 4 * m // n
        iso = stce_resources(BASE, dense=True)
        iso = {k: v * iso_cols / 4 for k, v in iso.items()}
        rows.append({
            "config": f"4x{iso_cols} dense (iso-throughput)",
            **{k: round(v) for k, v in iso.items()},
            "vs_stce_lut": round(iso["lut"] / r["lut"], 2),
            "vs_stce_ff": round(iso["ff"] / r["ff"], 2),
            "vs_stce_dsp": round(iso["dsp"] / r["dsp"], 2),
        })
    return rows


def main():
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))
    print("# paper Fig.14: STCE LUT x1.1/1.2/1.3, FF x1.7/2.2/3.3 vs dense;"
          " 2:8 STCE vs 4x16 dense: 3.4x LUT, 2.0x FF, 4.0x DSP cheaper")


if __name__ == "__main__":
    main()
