"""Fig. 15 reproduction: per-batch training time and speedup of
SR-STE / SDGP / BDWP (2:8, on SAT) over dense training, plus the
TTA (time-to-accuracy) speedup.

TTA = per-batch speedup x convergence factor.  The paper measures the
convergence factor empirically (lower part of Fig. 15 — BDWP needs a
few % more epochs than dense to reach the same accuracy); we carry the
paper's reported aggregate (1.82x per-batch -> 1.75x TTA, i.e. a 0.96
mean convergence factor) as the documented assumption.
"""

from __future__ import annotations

from repro.satsim.model import model_step_time
from repro.satsim.workloads import paper_model_layers

MODELS = ("resnet9", "vit", "vgg19", "resnet18", "resnet50")
CONVERGENCE_FACTOR = 1.75 / 1.82  # paper Fig. 15 aggregate


def run() -> list:
    rows = []
    for name in MODELS:
        layers = paper_model_layers(name)
        t_dense = model_step_time(layers, "dense")["total_s"]
        for method in ("srste", "sdgp", "bdwp"):
            t = model_step_time(layers, method)["total_s"]
            speed = t_dense / t
            rows.append({
                "model": name, "method": method,
                "dense_s": t_dense, "sparse_s": t,
                "batch_speedup": speed,
                "tta_speedup": speed * (CONVERGENCE_FACTOR
                                        if method != "dense" else 1.0),
            })
    return rows


def main():
    rows = run()
    print("model,method,dense_s,sparse_s,batch_speedup,tta_speedup")
    for r in rows:
        print(f"{r['model']},{r['method']},{r['dense_s']:.3f},"
              f"{r['sparse_s']:.3f},{r['batch_speedup']:.2f},"
              f"{r['tta_speedup']:.2f}")
    bd = [r for r in rows if r["method"] == "bdwp"]
    avg_b = sum(r["batch_speedup"] for r in bd) / len(bd)
    avg_t = sum(r["tta_speedup"] for r in bd) / len(bd)
    print(f"# BDWP mean: {avg_b:.2f}x/batch (paper 1.82x), "
          f"TTA {avg_t:.2f}x (paper 1.75x)")


if __name__ == "__main__":
    main()
