"""Fig. 16 reproduction: per-layer running time of 2:8 BDWP training
for each sparse conv layer of ResNet18 on Tiny ImageNet (batch 512),
non-overlapped (the paper purposely separates memory and compute here).
"""

from __future__ import annotations

from repro.satsim.model import layer_time, train_step_report
from repro.satsim.workloads import resnet18_layers


def run() -> list:
    rows = []
    for layer in resnet18_layers(batch=512):
        sts = layer_time(layer, "bdwp", pregen=True)
        rows.append({
            "layer": layer.name, "rows": layer.rows, "k": layer.k,
            "f": layer.f, "prunable": layer.prunable,
            **{f"{st.stage}_compute_ms": st.compute_s * 1e3 for st in sts},
            **{f"{st.stage}_ddr_ms": st.ddr_s * 1e3 for st in sts},
            **{f"{st.stage}_dataflow": st.dataflow for st in sts},
        })
    return rows


def main():
    rows = run()
    hdr = ("layer,ff_ms,bp_ms,wu_ms,ff_ddr,bp_ddr,wu_ddr,"
           "ff_df,bp_df,wu_df")
    print(hdr)
    ff = bp = wu = 0.0
    for r in rows:
        print(f"{r['layer']},{r['ff_compute_ms']:.2f},"
              f"{r['bp_compute_ms']:.2f},{r['wu_compute_ms']:.2f},"
              f"{r['ff_ddr_ms']:.2f},{r['bp_ddr_ms']:.2f},"
              f"{r['wu_ddr_ms']:.2f},{r['ff_dataflow']},"
              f"{r['bp_dataflow']},{r['wu_dataflow']}")
        ff += r["ff_compute_ms"]
        bp += r["bp_compute_ms"]
        wu += r["wu_compute_ms"]
    print(f"# totals ff={ff:.1f}ms bp={bp:.1f}ms wu={wu:.1f}ms; "
          f"paper: FF/BP ~1/4 of WU at 2:8 -> ratio ff/wu={ff/wu:.2f}")


if __name__ == "__main__":
    main()
