"""Fig. 17 reproduction: runtime training throughput of ResNet18 when
scaling the STCE array size x off-chip bandwidth.

Paper claims: at 409.6 GB/s and a scaled array, 2:8 BDWP reaches
3.9 TOPS runtime — above an RTX 2080 Ti's measured 3.4 TOPS on the same
workload — with peak only 26.2 TOPS sparse (vs 76 TOPS GPU peak).
"""

from __future__ import annotations

from repro.satsim.model import scale_sweep
from repro.satsim.workloads import resnet18_layers


def run() -> list:
    return scale_sweep(resnet18_layers(batch=512), "bdwp",
                       arrays=(32, 64, 128),
                       bandwidths=(25.6e9, 102.4e9, 409.6e9))


def main():
    rows = run()
    print("array,bw_gbs,runtime_tops,peak_sparse_tops")
    for r in rows:
        print(f"{r['array']},{r['bw_gbs']},{r['tops']:.2f},"
              f"{r['peak_sparse_tops']:.1f}")
    best = max(rows, key=lambda r: r["tops"])
    print(f"# best {best['tops']:.1f} TOPS at array={best['array']}, "
          f"bw={best['bw_gbs']} GB/s (paper: 3.9 TOPS @ 409.6 GB/s; "
          f"RTX 2080 Ti runtime 3.4 TOPS)")


if __name__ == "__main__":
    main()
