"""Serve-fleet benchmark: KV-aware routing + prefill/decode disaggregation.

  PYTHONPATH=src python -m benchmarks.fleet_bench [--smoke]

Drives a multi-replica ServeFleet with Poisson arrivals over a
shared-prefix workload (many requests repeating a small set of distinct
prompts — the traffic shape where KV reuse pays).  Three sections:

  * ``loads`` — offered-load sweep with the default prefix router:
    per-request latency (p50/p95, in fleet steps), fleet tok/s, tok/s
    per engine, routing hit rate and the by-depth routing histogram.
  * ``routing`` — the three policies on the IDENTICAL trace at one
    comparison load.  The prefix-aware router must serve with strictly
    fewer compiled prefill steps than the random control
    (``prefill_steps_saved`` — directionally gated >= 1) and a no-worse
    tail latency (prefix p95 <= random p95, same trace, same machine).
    All three policies must emit identical token streams — routing may
    decide WHERE work runs, never WHAT comes out.
  * ``disagg`` — the same trace through a disaggregated fleet
    (dedicated prefill engine, CacheStore lane handoff, decode engines
    that never prefill) vs one colocated engine.  ``streams_equal`` is
    MEASURED (bitwise token comparison), not assumed, and directionally
    gated; ``decode_prefill_steps`` must stay 0.

Latencies are in fleet steps (deterministic given the seeds), so they
are gateable; tok/s fields are wall-clock and recorded but never gated
across machines.  Writes results/BENCH_fleet.json.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.sparsity import SparsityConfig
from repro.models import transformer_lm as T
from repro.serve import FleetConfig, ServeConfig, ServeEngine, ServeFleet

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def build_trace(vocab: int, n_requests: int, distinct: int, max_new: int,
                seed: int = 23) -> list:
    """Shared-prefix workload: ``n_requests`` drawn from ``distinct``
    prompts (mixed lengths) — repeats are exact, so every repeat's
    prefill is reusable KV."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(4, 12, distinct)
    prompts = [rng.integers(0, vocab, int(n)).tolist() for n in lens]
    picks = rng.integers(0, distinct, n_requests)
    return [(prompts[int(i)], max_new) for i in picks]


def run_fleet(fleet: ServeFleet, trace, load: float, seed: int = 17) -> dict:
    """Drive the fleet: Poisson arrivals at ``load`` requests per fleet
    step; returns metrics + the streams (rid order = trace order)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / max(load, 1e-9), len(trace)))
    rids, submitted = [], 0
    t0 = time.perf_counter()
    while submitted < len(trace) or fleet.n_pending:
        while (submitted < len(trace)
               and arrivals[submitted] <= fleet.step_count):
            p, m = trace[submitted]
            rids.append(fleet.submit(p, max_new_tokens=m))
            submitted += 1
        fleet.step()
    dt = time.perf_counter() - t0
    reqs = fleet.finished_requests
    lats = [r.finish_step - r.submit_step for r in reqs]
    hits = sum(1 for r in reqs if r.prefix_hit)
    done = fleet.harvest()
    streams = [done[r] for r in rids]
    st = fleet.stats()
    tokens = sum(len(s) for s in streams)
    per_engine = [{
        "decoded_tokens": e["decoded_tokens"],
        "decode_steps": e["decode_steps"],
        "prefill_steps": e["prefill_steps"],
        "tok_per_s": e["decoded_tokens"] / dt if dt else 0.0,
    } for e in st["engines"]]
    return {
        "offered_load_req_per_step": load,
        "n_requests": len(trace),
        "tokens": tokens,
        "wall_s": dt,
        "tok_per_s": tokens / dt if dt else 0.0,
        "fleet_steps": st["steps"],
        "decode_steps": st["decode_steps"],
        "prefill_steps": st["prefill_steps"],
        "prefix_hits": hits,
        "hit_rate": hits / len(trace),
        "routed_by_depth": {str(k): v
                            for k, v in st["routed_by_depth"].items()},
        "latency_steps_p50": _percentile(lats, 50),
        "latency_steps_p95": _percentile(lats, 95),
        "per_engine": per_engine,
        "_streams": streams,
    }


def disagg_section(params, cfg, sp_cfg, serve_cfg, trace) -> dict:
    """Disaggregated fleet vs one colocated engine, bitwise."""
    # max_new_tokens=1 head: that request finishes on the prefill side
    # and must still match the colocated engine
    trace = [(trace[0][0], 1)] + list(trace[1:])

    eng = ServeEngine(params, cfg, sp_cfg, serve_cfg)
    rc = [eng.submit(p, max_new_tokens=m) for p, m in trace]
    t0 = time.perf_counter()
    outc = eng.run()
    colo_s = time.perf_counter() - t0
    colo = [outc[r] for r in rc]

    fleet = ServeFleet(params, cfg, sp_cfg, serve_cfg,
                       FleetConfig(n_replicas=1, router="least_loaded",
                                   disaggregate=True, n_prefill=1))
    rd = [fleet.submit(p, max_new_tokens=m) for p, m in trace]
    t0 = time.perf_counter()
    outd = fleet.run()
    disagg_s = time.perf_counter() - t0
    disagg = [outd[r] for r in rd]
    st = fleet.stats()
    return {
        "n_requests": len(trace),
        "streams_equal": int(disagg == colo),   # MEASURED, gated >= 1
        "tokens": sum(len(s) for s in disagg),
        "handoff_lanes": st["store"]["puts"],
        "store_leftover": st["store"]["size"],
        # decode engines must never run a prefill — that is the split
        "decode_prefill_steps": sum(e["prefill_steps"]
                                    for e in st["engines"]),
        "prefill_engine_steps": sum(e["prefill_steps"]
                                    for e in st["prefill_engines"]),
        "colocated_wall_s": colo_s,
        "disagg_wall_s": disagg_s,
    }


def main(smoke: bool = False, out_path: str | None = None) -> dict:
    arch = get_arch("qwen3-8b")
    cfg = arch.smoke
    sp_cfg = SparsityConfig(n=2, m=8, method="bdwp")
    params, _ = T.init(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(lambda w: w.astype(jnp.bfloat16), params)

    if smoke:
        loads, n_requests, distinct, max_new = [0.5, 3.0], 16, 6, 6
    else:
        loads, n_requests, distinct, max_new = [0.3, 1.0, 3.0], 24, 8, 8
    serve_cfg = ServeConfig(n_slots=2, max_len=24, prompt_bucket=12)
    trace = build_trace(cfg.vocab, n_requests, distinct, max_new)
    compare_load = loads[-1]

    def fresh(router):
        return ServeFleet(params, cfg, sp_cfg, serve_cfg,
                          FleetConfig(n_replicas=2, router=router,
                                      route_seed=3))

    rows = []
    for load in loads:
        row = run_fleet(fresh("prefix"), trace, load)
        row.pop("_streams")
        rows.append(row)
        print(f"load={load:5.2f} req/step: {row['tok_per_s']:8.1f} tok/s  "
              f"p95={row['latency_steps_p95']:.0f} steps  "
              f"hit_rate={row['hit_rate']:.2f}  "
              f"prefills={row['prefill_steps']}")

    routing = {}
    streams = {}
    for policy in ("prefix", "least_loaded", "random"):
        row = run_fleet(fresh(policy), trace, compare_load)
        streams[policy] = row.pop("_streams")
        routing[policy] = row
        print(f"router={policy:13s} prefills={row['prefill_steps']:3d}  "
              f"p95={row['latency_steps_p95']:.0f} steps  "
              f"hit_rate={row['hit_rate']:.2f}")
    routing["compare_load"] = compare_load
    # the KV-affinity win, win-or-fail: strictly fewer compiled
    # prefills than the random control on the identical trace
    routing["prefill_steps_saved"] = (routing["random"]["prefill_steps"]
                                      - routing["prefix"]["prefill_steps"])
    # routing must never change WHAT comes out, only WHERE it runs
    routing["streams_match_across_policies"] = int(
        streams["prefix"] == streams["least_loaded"] == streams["random"])

    disagg = disagg_section(params, cfg, sp_cfg, serve_cfg, trace[:6])
    print(f"disagg: streams_equal={disagg['streams_equal']}  "
          f"handoffs={disagg['handoff_lanes']}  "
          f"decode_prefills={disagg['decode_prefill_steps']}")

    summary = {
        "bench": "fleet_bench",
        "arch": cfg.name,
        "sparsity": {"n": sp_cfg.n, "m": sp_cfg.m, "method": sp_cfg.method},
        "serve": {"n_slots": serve_cfg.n_slots,
                  "max_len": serve_cfg.max_len,
                  "prompt_bucket": serve_cfg.prompt_bucket},
        "fleet": {"n_replicas": 2, "prefix_cache": 8},
        "workload": {"n_requests": n_requests, "distinct_prompts": distinct,
                     "max_new": max_new},
        "smoke": smoke,
        "loads": rows,
        "routing": routing,
        "disagg": disagg,
    }
    os.makedirs(RESULTS, exist_ok=True)
    out_path = out_path or os.path.join(RESULTS, "BENCH_fleet.json")
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"wrote {out_path}")
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sweep for CI")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    main(smoke=args.smoke, out_path=args.out)
