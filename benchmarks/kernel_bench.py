"""Pallas kernel microbenchmarks (CPU: oracle path wall-time + kernel
interpret-mode correctness cost; TPU target numbers are structural).

For each kernel we report:
  * oracle (pure-jnp) wall time on CPU — the apples-to-apples baseline
    the tests pin kernels against,
  * the structural VMEM working set + HBM bytes per call of the Pallas
    BlockSpec tiling (what matters on the real TPU),
  * the N:M arithmetic-intensity gain: packed weights move N/M of the
    dense bytes (the paper's bandwidth claim, transplanted).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.sparsity import SparsityConfig
from repro.kernels import ops, ref


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run() -> list:
    rows = []
    key = jax.random.PRNGKey(0)
    n, m = 2, 8
    for (b, k, f) in ((256, 1024, 1024), (512, 2048, 512)):
        w = jax.random.normal(key, (k, f), jnp.float32)
        x = jax.random.normal(key, (b, k), jnp.bfloat16)
        t_dense = _time(lambda: jnp.matmul(x.astype(jnp.float32), w))
        dense_bytes = k * f * 2
        for idx_bits in (8, 4):
            vals, idx = ops.nm_compact(w.T, n, m, use_pallas=False,
                                       idx_bits=idx_bits)
            vals, idx = vals.T, idx.T  # pack along K
            t_pack = _time(lambda ww, ib=idx_bits: ops.nm_compact(
                ww, n, m, use_pallas=False, idx_bits=ib), w.T)
            t_spmm = _time(lambda v=vals, i=idx, ib=idx_bits: ops.nm_spmm(
                x.astype(jnp.float32), v, i, n, m, use_pallas=False,
                idx_bits=ib))
            # bytes as stored: bf16-width vals + the actual index plane
            # (one byte per offset at u8, two offsets per byte at u4)
            packed_bytes = (k * f * n // m * 2
                            + k * f * n // m * idx_bits // 8)
            rows.append({
                "kernel": "nm_spmm", "shape": f"{b}x{k}x{f}",
                "nm": f"{n}:{m}", "idx_bits": idx_bits,
                "oracle_ms": t_spmm * 1e3, "dense_matmul_ms": t_dense * 1e3,
                "pack_ms": t_pack * 1e3,
                "weight_bytes_dense": dense_bytes,
                "weight_bytes_packed": packed_bytes,
                "hbm_reduction": dense_bytes / packed_bytes,
            })
        # the two index widths must be interchangeable bitwise — the u4
        # plane is a storage format, never a different computation
        v8, i8 = ops.nm_compact(w.T, n, m, use_pallas=False, idx_bits=8)
        v4, i4 = ops.nm_compact(w.T, n, m, use_pallas=False, idx_bits=4)
        y8 = ops.nm_spmm(x.astype(jnp.float32), v8.T, i8.T, n, m,
                         use_pallas=False)
        y4 = ops.nm_spmm(x.astype(jnp.float32), v4.T, i4.T, n, m,
                         use_pallas=False, idx_bits=4)
        assert (y8 == y4).all(), "u4 decode diverged from byte-wide"
    return rows


def main():
    rows = run()
    print("kernel,shape,nm,idx_bits,oracle_ms,dense_ms,pack_ms,"
          "hbm_reduction")
    for r in rows:
        print(f"{r['kernel']},{r['shape']},{r['nm']},{r['idx_bits']},"
              f"{r['oracle_ms']:.2f},"
              f"{r['dense_matmul_ms']:.2f},{r['pack_ms']:.2f},"
              f"{r['hbm_reduction']:.2f}")
    print("# packed N:M weights move ~M/(N+idx) x fewer HBM bytes — u4 "
          "indices push 2:8 bf16 from 2.67x to 3.2x (see EXPERIMENTS.md "
          "§Perf)")


if __name__ == "__main__":
    main()
