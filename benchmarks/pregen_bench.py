"""Pre-generation dataflow bench + gate (paper Fig. 11c executed).

Two claims, measured on the bdwp LM train step (qwen3 smoke config):

  1. MASK-ONCE INVARIANT (gated, deterministic): the traced pregen step
     contains exactly ONE top_k/sort selection per prunable parameter —
     the fused FF+BP mask derivation at WU time — versus the legacy
     dataflow's per-consumer re-derivation (FF forward, FF remat
     recompute, BP backward, SR-STE decay).  Counted as jaxpr
     primitives (compiler-version stable); the same census is asserted
     by tests/test_pregen.py in the blocking CI job, and this script
     exits nonzero if the invariant breaks so the smoke job flags
     mask-regen creep.
  2. STEP TIME (recorded, not gated — CI machines are noisy): median
     wall-clock of the pregen vs legacy jitted step.

Writes results/BENCH_pregen.json; benchmarks/check_regression.py gates
the deterministic counts against benchmarks/baselines/BENCH_pregen.json.

  PYTHONPATH=src python -m benchmarks.pregen_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import bdwp
from repro.core.sparsity import SparsityConfig
from repro.data import synthetic as D
from repro.launch.hlo_cost import count_mask_ops
from repro.launch.mesh import make_host_mesh
from repro.optim import sgd
from repro.train import step as ST

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _structs(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def prunable_sites(master, sp_cfg) -> list:
    names = []
    for path, w in jax.tree_util.tree_flatten_with_path(master)[0]:
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        lshape, _ = sgd._logical_shape(name, w.shape)
        if bdwp.pregen_site(name, lshape, sp_cfg):
            names.append(name)
    return names


def time_steps(bundle, state, vocab, batch, seq, steps) -> float:
    sh = None  # single-device host mesh: default placement
    stream = D.lm_stream(vocab, batch, seq, shardings=sh, seed=0)
    _, first = next(stream)
    state, _ = bundle.step_fn(state, first)  # compile + warmup
    jax.block_until_ready(state)
    times = []
    for _ in range(steps):
        _, b = next(stream)
        t0 = time.perf_counter()
        state, metrics = bundle.step_fn(state, b)
        jax.block_until_ready(metrics["loss"])
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e3)


def moe_section(smoke: bool) -> dict:
    """MoE edition of the mask-once gate: bare-array expert stacks
    (granite smoke) must pay exactly one fused selection per prunable
    param at WU time.  The census is N:M-shape-filtered (nm=(n, m)) so
    the router's top_k over the expert dim is not miscounted — 2:4
    sparsity here keeps m=4 distinguishable from the 8-expert router.
    """
    cfg = get_arch("granite-moe-1b-a400m").smoke
    mesh = make_host_mesh()
    sp_cfg = SparsityConfig(n=2, m=4, method="bdwp")
    opt_cfg = sgd.SGDConfig(lr=0.05, total_steps=100)
    batch, seq = (2, 32) if smoke else (4, 64)
    steps = 3 if smoke else 8

    state = ST.init_train_state(jax.random.PRNGKey(0), cfg, sp_cfg=sp_cfg)
    legacy_state = {k: v for k, v in state.items() if k != "compute"}
    sites = prunable_sites(state["master"], sp_cfg)
    b0 = {"tokens": jnp.zeros((batch, seq), jnp.int32),
          "labels": jnp.zeros((batch, seq), jnp.int32)}

    counts, times = {}, {}
    for mode, pregen, st in (("pregen", True, state),
                             ("legacy", False, legacy_state)):
        bundle = ST.build_lm_train(cfg, mesh, sp_cfg, opt_cfg, donate=False,
                                   pregen=pregen)
        counts[mode] = count_mask_ops(bundle.step_fn, _structs(st),
                                      _structs(b0), nm=(sp_cfg.n, sp_cfg.m))
        times[f"moe_{mode}_step_ms_median"] = time_steps(
            bundle, jax.device_put(st, bundle.state_shardings),
            cfg.vocab, batch, seq, steps)

    return {
        "config": {"arch": "granite-moe-1b-smoke", "method": sp_cfg.method,
                   "nm": f"{sp_cfg.n}:{sp_cfg.m}", "batch": batch,
                   "seq": seq},
        "mask_ops": {
            "pregen": counts["pregen"],
            "legacy": counts["legacy"],
            "prunable_params": len(sites),
            "pregen_per_param": counts["pregen"] / max(len(sites), 1),
            "legacy_per_param": counts["legacy"] / max(len(sites), 1),
        },
        "times": times,
    }


def main(smoke: bool = False) -> dict:
    cfg = get_arch("qwen3-8b").smoke
    mesh = make_host_mesh()
    sp_cfg = SparsityConfig(n=2, m=8, method="bdwp")
    opt_cfg = sgd.SGDConfig(lr=0.05, total_steps=100)
    batch, seq = (2, 32) if smoke else (4, 64)
    steps = 3 if smoke else 8

    state = ST.init_train_state(jax.random.PRNGKey(0), cfg, sp_cfg=sp_cfg)
    legacy_state = {k: v for k, v in state.items() if k != "compute"}
    sites = prunable_sites(state["master"], sp_cfg)
    b0 = {"tokens": jnp.zeros((batch, seq), jnp.int32),
          "labels": jnp.zeros((batch, seq), jnp.int32)}

    packed_state = ST.init_train_state(jax.random.PRNGKey(0), cfg,
                                       sp_cfg=sp_cfg, pregen_pack=True)
    counts, times = {}, {}
    for mode, pregen, pack, st in (("pregen", True, False, state),
                                   ("pregen_packed", True, True, packed_state),
                                   ("legacy", False, False, legacy_state)):
        bundle = ST.build_lm_train(cfg, mesh, sp_cfg, opt_cfg, donate=False,
                                   pregen=pregen, pregen_pack=pack)
        counts[mode] = count_mask_ops(bundle.step_fn, _structs(st),
                                      _structs(b0))
        times[f"{mode}_step_ms_median"] = time_steps(
            bundle, jax.device_put(st, bundle.state_shardings),
            cfg.vocab, batch, seq, steps)

    moe = moe_section(smoke)
    rec = {
        "config": {"arch": "qwen3-8b-smoke", "method": sp_cfg.method,
                   "nm": f"{sp_cfg.n}:{sp_cfg.m}", "batch": batch,
                   "seq": seq},
        "mask_ops": {
            "pregen": counts["pregen"],
            "pregen_packed": counts["pregen_packed"],
            "legacy": counts["legacy"],
            "prunable_params": len(sites),
            "pregen_per_param": counts["pregen"] / max(len(sites), 1),
            "legacy_per_param": counts["legacy"] / max(len(sites), 1),
        },
        "times": times,
        "moe_pregen": moe,
    }
    os.makedirs(RESULTS, exist_ok=True)
    out = os.path.join(RESULTS, "BENCH_pregen.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)

    mo = rec["mask_ops"]
    print(f"prunable params: {mo['prunable_params']}")
    print(f"mask top_k/sort ops per step: pregen {mo['pregen']} "
          f"({mo['pregen_per_param']:.0f}/param) vs legacy {mo['legacy']} "
          f"({mo['legacy_per_param']:.1f}/param)")
    print(f"step ms (median): pregen {times['pregen_step_ms_median']:.1f} "
          f"vs legacy {times['legacy_step_ms_median']:.1f}")
    print(f"wrote {out}")

    mm = moe["mask_ops"]
    print(f"moe (granite smoke): pregen {mm['pregen']} "
          f"({mm['pregen_per_param']:.0f}/param) vs legacy {mm['legacy']} "
          f"({mm['legacy_per_param']:.1f}/param) over "
          f"{mm['prunable_params']} prunable params")

    failed = False
    if mo["pregen_per_param"] != 1.0:
        print(f"[FAIL] mask-once invariant broken: "
              f"{mo['pregen_per_param']:.2f} selections per prunable param "
              f"(want exactly 1) — mask re-generation crept back in")
        failed = True
    if mm["pregen_per_param"] != 1.0:
        print(f"[FAIL] MoE mask-once invariant broken: "
              f"{mm['pregen_per_param']:.2f} selections per prunable param "
              f"(want exactly 1) — expert-stack mask re-generation crept "
              f"back in")
        failed = True
    if failed:
        sys.exit(1)
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    main(smoke=ap.parse_args().smoke)
