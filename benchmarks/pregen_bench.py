"""Pre-generation dataflow bench + gate (paper Fig. 11c executed).

Two claims, measured on the bdwp LM train step (qwen3 smoke config):

  1. MASK-ONCE INVARIANT (gated, deterministic): the traced pregen step
     contains exactly ONE top_k/sort selection per prunable parameter —
     the fused FF+BP mask derivation at WU time — versus the legacy
     dataflow's per-consumer re-derivation (FF forward, FF remat
     recompute, BP backward, SR-STE decay).  Counted as jaxpr
     primitives (compiler-version stable); the same census is asserted
     by tests/test_pregen.py in the blocking CI job, and this script
     exits nonzero if the invariant breaks so the smoke job flags
     mask-regen creep.
  2. STEP TIME (recorded, not gated — CI machines are noisy): median
     wall-clock of the pregen vs legacy jitted step.

Writes results/BENCH_pregen.json; benchmarks/check_regression.py gates
the deterministic counts against benchmarks/baselines/BENCH_pregen.json.

  PYTHONPATH=src python -m benchmarks.pregen_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

# census helpers live in the nmlint analysis layer — ONE implementation
# shared by this bench, tests, and tools/nmlint.py's graph audit
from repro.analysis.graph_audit import (
    _structs, mask_census, pallas_call_census, prunable_sites,
    scatter_census,
)
from repro.configs import get_arch
from repro.core.sparsity import SparsityConfig
from repro.data import synthetic as D
from repro.launch.mesh import make_host_mesh
from repro.optim import sgd
from repro.train import step as ST

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def time_steps(bundle, state, vocab, batch, seq, steps) -> float:
    sh = None  # single-device host mesh: default placement
    stream = D.lm_stream(vocab, batch, seq, shardings=sh, seed=0)
    _, first = next(stream)
    state, _ = bundle.step_fn(state, first)  # compile + warmup
    jax.block_until_ready(state)
    times = []
    for _ in range(steps):
        _, b = next(stream)
        t0 = time.perf_counter()
        state, metrics = bundle.step_fn(state, b)
        jax.block_until_ready(metrics["loss"])
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e3)


def moe_section(smoke: bool) -> dict:
    """MoE edition of the mask-once gate: bare-array expert stacks
    (granite smoke) must pay exactly one fused selection per prunable
    param at WU time.  The census is N:M-shape-filtered (nm=(n, m)) so
    the router's top_k over the expert dim is not miscounted — 2:4
    sparsity here keeps m=4 distinguishable from the 8-expert router.
    """
    cfg = get_arch("granite-moe-1b-a400m").smoke
    mesh = make_host_mesh()
    sp_cfg = SparsityConfig(n=2, m=4, method="bdwp")
    opt_cfg = sgd.SGDConfig(lr=0.05, total_steps=100)
    batch, seq = (2, 32) if smoke else (4, 64)
    steps = 3 if smoke else 8

    state = ST.init_train_state(jax.random.PRNGKey(0), cfg, sp_cfg=sp_cfg)
    legacy_state = {k: v for k, v in state.items() if k != "compute"}
    sites = prunable_sites(state["master"], sp_cfg)
    b0 = {"tokens": jnp.zeros((batch, seq), jnp.int32),
          "labels": jnp.zeros((batch, seq), jnp.int32)}

    counts, times = {}, {}
    for mode, pregen, st in (("pregen", True, state),
                             ("legacy", False, legacy_state)):
        bundle = ST.build_lm_train(cfg, mesh, sp_cfg, opt_cfg, donate=False,
                                   pregen=pregen)
        counts[mode] = mask_census(bundle.step_fn, _structs(st),
                                   _structs(b0), nm=(sp_cfg.n, sp_cfg.m))
        times[f"moe_{mode}_step_ms_median"] = time_steps(
            bundle, jax.device_put(st, bundle.state_shardings),
            cfg.vocab, batch, seq, steps)

    return {
        "config": {"arch": "granite-moe-1b-smoke", "method": sp_cfg.method,
                   "nm": f"{sp_cfg.n}:{sp_cfg.m}", "batch": batch,
                   "seq": seq},
        "mask_ops": {
            "pregen": counts["pregen"],
            "legacy": counts["legacy"],
            "prunable_params": len(sites),
            "pregen_per_param": counts["pregen"] / max(len(sites), 1),
            "legacy_per_param": counts["legacy"] / max(len(sites), 1),
        },
        "times": times,
    }


def packed_train_section(smoke: bool) -> dict:
    """Unified packed-FF consumption gate (the ROADMAP item closed by
    the SparseOperand API): with ``pregen_pack=True`` the train-step
    FORWARD consumes each packed ``(vals, idx)`` FF operand directly —
    through kernels/nm_spmm on the pallas backend, select-decompressed
    on jnp — so the traced forward contains ZERO scatter-unpacks on
    either backend, and the pallas forward invokes the kernel once per
    packed site.  Also accounts the FF-operand HBM bytes the packed
    compute tree actually stores vs its dense-layout equivalent.
    Deterministic counts are gated by check_regression; backend step
    times are recorded for the wall-clock trajectory.
    """
    from repro.core import operand as O
    from repro.models import transformer_lm as T

    cfg = get_arch("qwen3-8b").smoke
    sp_cfg = SparsityConfig(n=2, m=8, method="bdwp")
    opt_cfg = sgd.SGDConfig(lr=0.05, total_steps=100)
    batch, seq = (2, 32) if smoke else (4, 64)
    steps = 3 if smoke else 8
    mesh = make_host_mesh()

    state = ST.init_train_state(jax.random.PRNGKey(0), cfg, sp_cfg=sp_cfg,
                                pregen_pack=True)
    b0 = {"tokens": jnp.zeros((batch, seq), jnp.int32),
          "labels": jnp.zeros((batch, seq), jnp.int32)}

    # -- FF-operand HBM accounting: packed (vals + uint8 idx) vs dense --
    packed_sites = [leaf for leaf in jax.tree.leaves(
        state["compute"], is_leaf=lambda x: isinstance(x, O.PregenOp))
        if isinstance(leaf, O.PregenOp) and leaf.is_packed]
    bytes_of = lambda a: int(a.size) * jnp.dtype(a.dtype).itemsize  # noqa
    packed_bytes = sum(bytes_of(s.vals) + bytes_of(s.idx)
                       for s in packed_sites)
    dense_bytes = sum(bytes_of(s.bp) for s in packed_sites)  # dense layout

    # -- forward census per backend: scatter-free, kernel-consuming -----
    def forward_loss(backend):
        def fn(compute, b):
            with O.backend_scope(backend):
                hidden, _, aux = T.forward(compute, b["tokens"], cfg, sp_cfg)
                return T.lm_loss(compute, hidden, b["labels"], cfg) \
                    + 0.01 * aux
        return fn

    census, times = {}, {}
    for backend in ("jnp", "pallas"):
        jaxpr = jax.make_jaxpr(forward_loss(backend))(
            _structs(state["compute"]), _structs(b0))
        census[backend] = {
            "scatter_ops": scatter_census(jaxpr),
            "nm_spmm_calls": pallas_call_census(jaxpr),
        }
        bundle = ST.build_lm_train(cfg, mesh, sp_cfg, opt_cfg, donate=False,
                                   pregen_pack=True, nm_backend=backend)
        # off-TPU the pallas backend runs the kernel body op-by-op in
        # interpret mode — its wall-clock measures the INTERPRETER, not
        # the kernel, and must never be read against the compiled jnp
        # number.  Label it so (check_regression refuses to gate any
        # "interpret"-labeled metric; docs/benchmarks.md explains).
        interp = backend == "pallas" and jax.default_backend() != "tpu"
        key = (f"packed_{backend}_step_ms_median_interpret" if interp
               else f"packed_{backend}_step_ms_median")
        times[key] = time_steps(
            bundle, jax.device_put(state, bundle.state_shardings),
            cfg.vocab, batch, seq, steps)

    return {
        "config": {"arch": "qwen3-8b-smoke", "method": sp_cfg.method,
                   "nm": f"{sp_cfg.n}:{sp_cfg.m}", "batch": batch,
                   "seq": seq},
        "packed_sites": len(packed_sites),
        "forward_scatter_ops": {be: census[be]["scatter_ops"]
                                for be in census},
        "forward_nm_spmm_calls": {be: census[be]["nm_spmm_calls"]
                                  for be in census},
        "ff_hbm_bytes": {
            "packed": packed_bytes,
            "dense": dense_bytes,
            "saving": dense_bytes / max(packed_bytes, 1),
        },
        "times": times,
    }


def main(smoke: bool = False) -> dict:
    cfg = get_arch("qwen3-8b").smoke
    mesh = make_host_mesh()
    sp_cfg = SparsityConfig(n=2, m=8, method="bdwp")
    opt_cfg = sgd.SGDConfig(lr=0.05, total_steps=100)
    batch, seq = (2, 32) if smoke else (4, 64)
    steps = 3 if smoke else 8

    state = ST.init_train_state(jax.random.PRNGKey(0), cfg, sp_cfg=sp_cfg)
    legacy_state = {k: v for k, v in state.items() if k != "compute"}
    sites = prunable_sites(state["master"], sp_cfg)
    b0 = {"tokens": jnp.zeros((batch, seq), jnp.int32),
          "labels": jnp.zeros((batch, seq), jnp.int32)}

    packed_state = ST.init_train_state(jax.random.PRNGKey(0), cfg,
                                       sp_cfg=sp_cfg, pregen_pack=True)
    counts, times = {}, {}
    for mode, pregen, pack, st in (("pregen", True, False, state),
                                   ("pregen_packed", True, True, packed_state),
                                   ("legacy", False, False, legacy_state)):
        bundle = ST.build_lm_train(cfg, mesh, sp_cfg, opt_cfg, donate=False,
                                   pregen=pregen, pregen_pack=pack)
        counts[mode] = mask_census(bundle.step_fn, _structs(st),
                                   _structs(b0))
        times[f"{mode}_step_ms_median"] = time_steps(
            bundle, jax.device_put(st, bundle.state_shardings),
            cfg.vocab, batch, seq, steps)

    moe = moe_section(smoke)
    packed_train = packed_train_section(smoke)
    rec = {
        "config": {"arch": "qwen3-8b-smoke", "method": sp_cfg.method,
                   "nm": f"{sp_cfg.n}:{sp_cfg.m}", "batch": batch,
                   "seq": seq},
        "mask_ops": {
            "pregen": counts["pregen"],
            "pregen_packed": counts["pregen_packed"],
            "legacy": counts["legacy"],
            "prunable_params": len(sites),
            "pregen_per_param": counts["pregen"] / max(len(sites), 1),
            "legacy_per_param": counts["legacy"] / max(len(sites), 1),
        },
        "times": times,
        "moe_pregen": moe,
        "packed_train": packed_train,
    }
    os.makedirs(RESULTS, exist_ok=True)
    out = os.path.join(RESULTS, "BENCH_pregen.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)

    mo = rec["mask_ops"]
    print(f"prunable params: {mo['prunable_params']}")
    print(f"mask top_k/sort ops per step: pregen {mo['pregen']} "
          f"({mo['pregen_per_param']:.0f}/param) vs legacy {mo['legacy']} "
          f"({mo['legacy_per_param']:.1f}/param)")
    print(f"step ms (median): pregen {times['pregen_step_ms_median']:.1f} "
          f"vs legacy {times['legacy_step_ms_median']:.1f}")
    print(f"wrote {out}")

    mm = moe["mask_ops"]
    print(f"moe (granite smoke): pregen {mm['pregen']} "
          f"({mm['pregen_per_param']:.0f}/param) vs legacy {mm['legacy']} "
          f"({mm['legacy_per_param']:.1f}/param) over "
          f"{mm['prunable_params']} prunable params")

    pt = packed_train
    print(f"packed train fwd: scatter ops jnp {pt['forward_scatter_ops']['jnp']} "
          f"pallas {pt['forward_scatter_ops']['pallas']}; nm_spmm calls "
          f"pallas {pt['forward_nm_spmm_calls']['pallas']} over "
          f"{pt['packed_sites']} packed sites; FF HBM saving "
          f"{pt['ff_hbm_bytes']['saving']:.2f}x")

    failed = False
    if pt["forward_scatter_ops"]["jnp"] or pt["forward_scatter_ops"]["pallas"]:
        print("[FAIL] packed train forward scatters (vals, idx) back to "
              "dense — the unified nm_spmm consumption regressed")
        failed = True
    if pt["forward_nm_spmm_calls"]["pallas"] < pt["packed_sites"]:
        print("[FAIL] pallas-backend packed train forward does not invoke "
              "nm_spmm for every packed site")
        failed = True
    if mo["pregen_per_param"] != 1.0:
        print(f"[FAIL] mask-once invariant broken: "
              f"{mo['pregen_per_param']:.2f} selections per prunable param "
              f"(want exactly 1) — mask re-generation crept back in")
        failed = True
    if mm["pregen_per_param"] != 1.0:
        print(f"[FAIL] MoE mask-once invariant broken: "
              f"{mm['pregen_per_param']:.2f} selections per prunable param "
              f"(want exactly 1) — expert-stack mask re-generation crept "
              f"back in")
        failed = True
    if failed:
        sys.exit(1)
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    main(smoke=ap.parse_args().smoke)
