"""Roofline table generator: reads the dry-run JSON artifacts
(results/dryrun/*.json) and renders the 40-cell roofline table for
EXPERIMENTS.md §Roofline.

Terms are per chip (the SPMD module is per-partition):
  compute    = HLO_FLOPs / peak (197 TFLOP/s bf16)
  memory     = HLO_bytes / HBM bw (819 GB/s)
  collective = link_bytes / ICI bw (50 GB/s)
"""

from __future__ import annotations

import glob
import json
import os
import sys


def load(path_glob="results/dryrun/*.json"):
    recs = []
    for p in sorted(glob.glob(path_glob)):
        with open(p) as f:
            recs.extend(json.load(f))
    return recs


def render(recs, mesh="16x16") -> str:
    lines = [
        "| arch | shape | Tc (ms) | Tm (ms) | Tx (ms) | dominant | "
        "roofline frac | useful ratio | what moves the bottleneck |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    hints = {
        ("memory", "train"): "cut fp32 activation passes / remat stash",
        ("memory", "prefill"): "KV/layout fusion; bf16 end-to-end",
        ("memory", "decode"): "u4-idx N:M-packed weights: HBM bytes x N/M "
                              "+ half-byte idx (BENCH_serve measures it)",
        ("collective", "train"): "reduce-scatter grads; overlap TP collectives",
        ("collective", "prefill"): "sequence-parallel halves TP traffic",
        ("collective", "decode"): "TP all-reduce in bf16; fewer hops",
        ("compute", "train"): "already compute-bound: shared-N:M reduced-K",
        ("compute", "prefill"): "shared-N:M reduced-K matmuls",
        ("compute", "decode"): "batch more sequences per step",
    }
    kind_of = {"train_4k": "train", "prefill_32k": "prefill",
               "decode_32k": "decode", "long_500k": "decode"}
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip | — | — | {r['reason']} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"FAIL | — | — | {r.get('error','')[:60]} |")
            continue
        dom = r["dominant"]
        hint = hints.get((dom, kind_of.get(r["shape"], "train")), "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']*1e3:.1f} | "
            f"{r['t_memory']*1e3:.1f} | {r['t_collective']*1e3:.1f} | "
            f"{dom} | {r['roofline_frac']:.3f} | "
            f"{r['useful_ratio']:.2f} | {hint} |")
    return "\n".join(lines)


def interesting_cells(recs, mesh="16x16"):
    """The three hillclimb picks: worst roofline fraction among cells
    with non-trivial compute (decode steps have Tc ~ 0 by construction,
    so rf ~ 0 there is not "worst utilization" in a meaningful sense),
    most collective-bound, most paper-representative (decode cell with
    the largest memory term — where packed N:M weights bite hardest)."""
    ok = [r for r in recs if r.get("mesh") == mesh and r["status"] == "ok"]
    if not ok:
        return {}
    compute_cells = [r for r in ok if r["t_compute"] > 0.1] or ok
    worst = min(compute_cells, key=lambda r: r["roofline_frac"])
    coll = max(compute_cells, key=lambda r: r["t_collective"] /
               max(r["t_compute"] + r["t_memory"] + r["t_collective"], 1e-12))
    decode = [r for r in ok if "decode" in r["shape"] or "long" in r["shape"]]
    paper = max(decode or ok, key=lambda r: r["t_memory"])
    return {"worst_roofline": (worst["arch"], worst["shape"]),
            "most_collective": (coll["arch"], coll["shape"]),
            "paper_representative": (paper["arch"], paper["shape"])}


def measured_decode_footer(serve_json="results/BENCH_serve.json") -> str:
    """Close the decode-memory loop against MEASURED numbers: the table's
    Tm claim for decode cells assumes packed weights move N/M of the
    dense bytes — BENCH_serve.json carries the measured store bytes and
    the HLO-measured per-step traffic of the exact compiled decode, so
    the roofline's assumption is checkable, not folklore."""
    if not os.path.exists(serve_json):
        return (f"# measured decode bytes: {serve_json} absent — run "
                f"`python -m benchmarks.serve_bench` to close the loop")
    with open(serve_json) as f:
        s = json.load(f)
    hbm, dec = s.get("hbm", {}), s.get("decode", {})
    lines = [
        "# measured decode-path HBM (benchmarks/serve_bench.py):",
        f"#   packed store: {hbm.get('measured_packed_weight_bytes', 0)} B "
        f"live (idx_bits={hbm.get('idx_bits')}) = "
        f"{hbm.get('measured_over_accounted_4bit', 0):.3f}x the accounted "
        f"SORE 4-bit footprint; {hbm.get('hbm_saving', 0):.2f}x below "
        f"dense",
    ]
    if dec:
        lines.append(
            f"#   decode step HLO bytes: u4 "
            f"{dec.get('hlo_bytes_per_step_u4', 0)} vs u8 "
            f"{dec.get('hlo_bytes_per_step_u8', 0)} "
            f"({dec.get('idx_bytes_saved_per_step', 0)} B/step saved)")
    return "\n".join(lines)


def main():
    g = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun/*.json"
    recs = load(g)
    if not recs:
        print(f"# no dry-run records under {g} — run "
              f"`python -m repro.launch.dryrun --all --out results/dryrun`")
        print(measured_decode_footer())
        return
    print(render(recs))
    print()
    print("picks:", json.dumps(interesting_cells(recs)))
    print(measured_decode_footer())


if __name__ == "__main__":
    main()
