"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run table2     # one
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI-fast subset
"""

from __future__ import annotations

import inspect
import sys
import time

from benchmarks import (fig14_resources, fig15_speedup, fig16_layerwise,
                        fig17_scaling, fleet_bench, kernel_bench,
                        pregen_bench, roofline, serve_bench, spmd_bench,
                        table2_flops, table4_platforms, table5_accels)

SUITES = {
    "table2": table2_flops,
    "fig14": fig14_resources,
    "fig15": fig15_speedup,
    "fig16": fig16_layerwise,
    "table4": table4_platforms,
    "fig17": fig17_scaling,
    "table5": table5_accels,
    "kernels": kernel_bench,
    "roofline": roofline,
    "serve": serve_bench,
    # fleet layer above the engine: KV-aware routing + disaggregation
    "fleet": fleet_bench,
    # pre-generation dataflow gate: exactly one top_k per prunable param
    "pregen": pregen_bench,
    # needs multiple devices to be interesting; run it standalone with
    # XLA_FLAGS=--xla_force_host_platform_device_count=8 (the CI spmd
    # job does) — inside this driver it inherits the ambient backend
    "spmd": spmd_bench,
}

# cheap suites CI can afford on every push
SMOKE_SUITES = ["table2", "serve", "fleet", "pregen"]


def main() -> None:
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    names = [a for a in argv if not a.startswith("-")]
    if not names:
        names = SMOKE_SUITES if smoke else list(SUITES)
    for name in names:
        mod = SUITES[name]
        print(f"\n===== {name} ({mod.__name__}) =====")
        t0 = time.perf_counter()
        kwargs = {}
        if "smoke" in inspect.signature(mod.main).parameters:
            kwargs["smoke"] = smoke  # suites opt in by accepting smoke=
        mod.main(**kwargs)
        print(f"# {name}: {(time.perf_counter() - t0)*1e3:.0f} ms")


if __name__ == "__main__":
    main()
