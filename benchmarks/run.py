"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run table2     # one
"""

from __future__ import annotations

import sys
import time

from benchmarks import (fig14_resources, fig15_speedup, fig16_layerwise,
                        fig17_scaling, kernel_bench, roofline, table2_flops,
                        table4_platforms, table5_accels)

SUITES = {
    "table2": table2_flops,
    "fig14": fig14_resources,
    "fig15": fig15_speedup,
    "fig16": fig16_layerwise,
    "table4": table4_platforms,
    "fig17": fig17_scaling,
    "table5": table5_accels,
    "kernels": kernel_bench,
    "roofline": roofline,
}


def main() -> None:
    names = sys.argv[1:] or list(SUITES)
    for name in names:
        mod = SUITES[name]
        print(f"\n===== {name} ({mod.__name__}) =====")
        t0 = time.perf_counter()
        mod.main()
        print(f"# {name}: {(time.perf_counter() - t0)*1e3:.0f} ms")


if __name__ == "__main__":
    main()
