"""Serve-engine benchmark: throughput vs. offered load.

  PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]

Drives the continuous-batching engine with Poisson arrivals and mixed
prompt lengths at a sweep of offered loads (requests per decode step),
measuring delivered tok/s, per-request latency (in engine steps) and
slot utilization — the "serves heavy traffic" axis of the roadmap, on
the smoke config so it runs on CPU CI.

Writes a JSON summary to results/BENCH_serve.json so the bench
trajectory accumulates across PRs (CI uploads it as an artifact).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.sparsity import SparsityConfig
from repro.models import transformer_lm as T
from repro.serve import ServeConfig, ServeEngine

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def run_load(engine: ServeEngine, *, n_requests: int, load: float,
             prompt_lens, max_new: int, seed: int = 0) -> dict:
    """Offered load = Poisson arrivals at `load` requests per decode step."""
    rng = np.random.default_rng(seed)
    # exponential inter-arrival times in units of engine steps
    arrivals = np.cumsum(rng.exponential(1.0 / max(load, 1e-9), n_requests))
    plens = rng.choice(prompt_lens, n_requests)
    prompts = [rng.integers(0, engine.cfg.vocab, int(p)).tolist()
               for p in plens]
    submitted = 0
    t0 = time.perf_counter()
    while submitted < n_requests or engine.n_running or engine.n_queued:
        while submitted < n_requests and arrivals[submitted] <= engine.step_count:
            engine.submit(prompts[submitted], max_new_tokens=max_new)
            submitted += 1
        engine.step()
    dt = time.perf_counter() - t0
    lats = [r.finish_step - r.submit_step for r in engine.finished_requests]
    done = engine.harvest()
    st = engine.stats()
    tokens_out = sum(len(v) for v in done.values())
    return {
        "offered_load_req_per_step": load,
        "n_requests": n_requests,
        "tokens": tokens_out,
        "wall_s": dt,
        "tok_per_s": tokens_out / dt if dt else 0.0,
        "decode_steps": st["decode_steps"],
        "engine_steps": st["steps"],
        # first token of each request comes from its prefill, not a
        # decode step — exclude it from per-step lane accounting
        "tokens_per_decode_step": (tokens_out - n_requests)
        / max(st["decode_steps"], 1),
        "slot_utilization": (tokens_out - n_requests) / max(
            st["decode_steps"] * engine.serve_cfg.n_slots, 1),
        "latency_steps_mean": float(np.mean(lats)) if lats else 0.0,
        "latency_steps_p50": _percentile(lats, 50),
        "latency_steps_p95": _percentile(lats, 95),
    }


def main(smoke: bool = False, out_path: str | None = None) -> dict:
    arch = get_arch("qwen3-8b")
    cfg = arch.smoke
    sp_cfg = SparsityConfig(n=2, m=8, method="bdwp")
    params, _ = T.init(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(lambda w: w.astype(jnp.bfloat16), params)

    if smoke:
        loads, n_requests, max_new, slots = [0.2, 1.0], 6, 6, 2
    else:
        loads, n_requests, max_new, slots = [0.1, 0.3, 1.0, 3.0], 24, 12, 4
    serve_cfg = ServeConfig(n_slots=slots, prompt_bucket=16,
                            max_len=16 + max_new, packed=True)

    # one engine for the whole sweep: pack + compile once, reset() the
    # host-side counters between load levels
    engine = ServeEngine(params, cfg, sp_cfg, serve_cfg)
    hbm = engine.hbm_report()
    rows = []
    for load in loads:
        engine.reset()
        row = run_load(engine, n_requests=n_requests, load=load,
                       prompt_lens=(4, 8, 12, 16), max_new=max_new, seed=17)
        rows.append(row)
        print(f"load={load:5.2f} req/step: {row['tok_per_s']:8.1f} tok/s  "
              f"util={row['slot_utilization']:.2f}  "
              f"steps={row['engine_steps']}")

    summary = {
        "bench": "serve_bench",
        "arch": cfg.name,
        "sparsity": {"n": sp_cfg.n, "m": sp_cfg.m, "method": sp_cfg.method},
        "serve": {"n_slots": slots, "prompt_bucket": 16,
                  "max_len": 16 + max_new, "packed": True},
        "hbm": hbm,
        "smoke": smoke,
        "loads": rows,
    }
    os.makedirs(RESULTS, exist_ok=True)
    out_path = out_path or os.path.join(RESULTS, "BENCH_serve.json")
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"wrote {out_path}")
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    main(smoke=args.smoke, out_path=args.out)
