"""Serve-engine benchmark: throughput vs. offered load.

  PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]

Drives the continuous-batching engine with Poisson arrivals and mixed
prompt lengths at a sweep of offered loads (requests per decode step),
measuring delivered tok/s, per-request latency (in engine steps) and
slot utilization — the "serves heavy traffic" axis of the roadmap, on
the smoke config so it runs on CPU CI.

Beyond throughput, the bench MEASURES (never merely accounts) the
decode-path HBM story of the u4-packed store:

  * ``hbm``   — the store's own report: live ``.nbytes`` of every
    PackedOp leaf (``measured_packed_weight_bytes``) against the
    accounted SORE 4-bit footprint; the ratio is directionally gated
    within ±5% by check_regression.
  * ``decode`` — structural HBM bytes of ONE lowered decode step
    (``launch.hlo_cost.analyze`` over the optimized HLO), for the u4
    store and a byte-wide u8 control on the same weights: the index
    plane halving must show up in the measured per-step traffic.
  * ``projections`` — per packed projection: stored vals/idx bytes vs
    dense, plus decode-shaped oracle latency (wall-clock, recorded but
    never gated — CI machines are noisy; byte fields are gated).

Writes a JSON summary to results/BENCH_serve.json so the bench
trajectory accumulates across PRs (CI uploads it as an artifact).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.sparsity import SparsityConfig
from repro.models import transformer_lm as T
from repro.serve import ServeConfig, ServeEngine

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def run_load(engine: ServeEngine, *, n_requests: int, load: float,
             prompt_lens, max_new: int, seed: int = 0) -> dict:
    """Offered load = Poisson arrivals at `load` requests per decode step."""
    rng = np.random.default_rng(seed)
    # exponential inter-arrival times in units of engine steps
    arrivals = np.cumsum(rng.exponential(1.0 / max(load, 1e-9), n_requests))
    plens = rng.choice(prompt_lens, n_requests)
    prompts = [rng.integers(0, engine.cfg.vocab, int(p)).tolist()
               for p in plens]
    submitted = 0
    t0 = time.perf_counter()
    while submitted < n_requests or engine.n_running or engine.n_queued:
        while submitted < n_requests and arrivals[submitted] <= engine.step_count:
            engine.submit(prompts[submitted], max_new_tokens=max_new)
            submitted += 1
        engine.step()
    dt = time.perf_counter() - t0
    lats = [r.finish_step - r.submit_step for r in engine.finished_requests]
    done = engine.harvest()
    st = engine.stats()
    tokens_out = sum(len(v) for v in done.values())
    return {
        "offered_load_req_per_step": load,
        "n_requests": n_requests,
        "tokens": tokens_out,
        "wall_s": dt,
        "tok_per_s": tokens_out / dt if dt else 0.0,
        "decode_steps": st["decode_steps"],
        "engine_steps": st["steps"],
        # first token of each request comes from its prefill, not a
        # decode step — exclude it from per-step lane accounting
        "tokens_per_decode_step": (tokens_out - n_requests)
        / max(st["decode_steps"], 1),
        "slot_utilization": (tokens_out - n_requests) / max(
            st["decode_steps"] * engine.serve_cfg.n_slots, 1),
        "latency_steps_mean": float(np.mean(lats)) if lats else 0.0,
        "latency_steps_p50": _percentile(lats, 50),
        "latency_steps_p95": _percentile(lats, 95),
    }


def _decode_step_hlo(engine: ServeEngine) -> dict:
    """Structural per-step cost of the engine's compiled decode fn —
    measured off the optimized HLO of the exact jit the hot loop runs,
    not re-derived from shapes."""
    from repro.launch import hlo_cost
    b = engine.batcher
    lowered = b._decode.lower(b.params, b.kv.cache, b.tokens, b.positions)
    return hlo_cost.analyze(lowered.compile().as_text())


def projection_section(engine: ServeEngine, n_slots: int) -> dict:
    """Per-projection stored bytes + decode-shaped consumption latency.

    Walks the packed store's PackedOp leaves (one per projection; stacked
    (L, Kc, F) leaves time their layer-0 slice — the per-step decode cost
    is per layer).  Latency is the jitted oracle path (`use_pallas=False`,
    real XLA CPU timing); the Pallas kernel only runs interpreted on CPU,
    so timing it here would measure the interpreter, not the kernel —
    see docs/benchmarks.md on the interpret-mode confound.
    """
    import jax.tree_util as jtu
    from repro.core import operand as O

    out = {}
    flat, _ = jtu.tree_flatten_with_path(
        engine.store.params, is_leaf=lambda x: isinstance(x, O.PackedOp))
    for path, leaf in flat:
        if not isinstance(leaf, O.PackedOp):
            continue
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        vals, idx = leaf.vals, leaf.idx
        v2, i2 = (vals[0], idx[0]) if vals.ndim == 3 else (vals, idx)
        op = O.PackedOp(v2, i2, leaf.cfg, leaf.idx_bits)
        k_dense = v2.shape[0] * leaf.cfg.m // leaf.cfg.n
        x = jax.random.normal(jax.random.PRNGKey(0), (n_slots, k_dense),
                              jnp.bfloat16)
        apply = jax.jit(lambda o, xx: O.nm_apply(o, xx, backend="jnp"))
        jax.block_until_ready(apply(op, x))  # compile outside the timer
        t0 = time.perf_counter()
        reps = 10
        for _ in range(reps):
            jax.block_until_ready(apply(op, x))
        out[name] = {
            "layers": int(vals.shape[0]) if vals.ndim == 3 else 1,
            "idx_bits": leaf.idx_bits,
            "vals_bytes": int(vals.nbytes),
            "idx_bytes": int(idx.nbytes),
            "stored_bytes": int(vals.nbytes) + int(idx.nbytes),
            "dense_bytes": int(vals.nbytes) * leaf.cfg.m // leaf.cfg.n,
            "decode_latency_oracle_ms": (time.perf_counter() - t0)
            / reps * 1e3,
        }
    return out


def main(smoke: bool = False, out_path: str | None = None) -> dict:
    arch = get_arch("qwen3-8b")
    cfg = arch.smoke
    sp_cfg = SparsityConfig(n=2, m=8, method="bdwp")
    params, _ = T.init(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(lambda w: w.astype(jnp.bfloat16), params)

    if smoke:
        loads, n_requests, max_new, slots = [0.2, 1.0], 6, 6, 2
    else:
        loads, n_requests, max_new, slots = [0.1, 0.3, 1.0, 3.0], 24, 12, 4
    serve_cfg = ServeConfig(n_slots=slots, prompt_bucket=16,
                            max_len=16 + max_new, packed=True)

    # one engine for the whole sweep: pack + compile once, reset() the
    # host-side counters between load levels
    engine = ServeEngine(params, cfg, sp_cfg, serve_cfg)
    hbm = engine.hbm_report()

    # measured decode traffic: structural bytes of one lowered decode
    # step, u4 store vs a byte-wide u8 control over the same weights —
    # the index-plane halving must be visible in the per-step HLO bytes
    hlo_u4 = _decode_step_hlo(engine)
    eng_u8 = ServeEngine(params, cfg, sp_cfg,
                         ServeConfig(n_slots=slots, prompt_bucket=16,
                                     max_len=16 + max_new, packed=True,
                                     idx_bits=8))
    hlo_u8 = _decode_step_hlo(eng_u8)
    decode = {
        "hlo_bytes_per_step_u4": int(hlo_u4["bytes"]),
        "hlo_bytes_per_step_u8": int(hlo_u8["bytes"]),
        "hlo_flops_per_step": int(hlo_u4["flops"]),
        # what the u4 plane saves each step, measured off the HLO.  This
        # exceeds the raw plane-size delta below: the halved plane also
        # halves every fusion-boundary re-read and decompress
        # intermediate derived from it inside the scanned layer body
        "idx_bytes_saved_per_step": int(hlo_u8["bytes"] - hlo_u4["bytes"]),
        # the stored-plane delta: u8 planes minus u4 planes, off .nbytes
        "idx_bytes_saved_accounted": (
            eng_u8.store.measured_packed_bytes()
            - engine.store.measured_packed_bytes()),
    }
    del eng_u8
    projections = projection_section(engine, slots)
    rows = []
    for load in loads:
        engine.reset()
        row = run_load(engine, n_requests=n_requests, load=load,
                       prompt_lens=(4, 8, 12, 16), max_new=max_new, seed=17)
        rows.append(row)
        print(f"load={load:5.2f} req/step: {row['tok_per_s']:8.1f} tok/s  "
              f"util={row['slot_utilization']:.2f}  "
              f"steps={row['engine_steps']}")

    summary = {
        "bench": "serve_bench",
        "arch": cfg.name,
        "sparsity": {"n": sp_cfg.n, "m": sp_cfg.m, "method": sp_cfg.method},
        "serve": {"n_slots": slots, "prompt_bucket": 16,
                  "max_len": 16 + max_new, "packed": True},
        "hbm": hbm,
        "decode": decode,
        "projections": projections,
        "smoke": smoke,
        "loads": rows,
    }
    os.makedirs(RESULTS, exist_ok=True)
    out_path = out_path or os.path.join(RESULTS, "BENCH_serve.json")
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"store: idx_bits={hbm['idx_bits']} measured "
          f"{hbm['measured_packed_weight_bytes']} B = "
          f"{hbm['measured_over_accounted_4bit']:.3f}x the accounted "
          f"4-bit-idx footprint ({hbm['packed_weight_bytes_4bit_idx']} B)")
    print(f"decode step HLO bytes: u4 {decode['hlo_bytes_per_step_u4']} "
          f"vs u8 {decode['hlo_bytes_per_step_u8']} "
          f"(saves {decode['idx_bytes_saved_per_step']} B/step; "
          f"planes account {decode['idx_bytes_saved_accounted']} B)")
    print(f"wrote {out_path}")
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    main(smoke=args.smoke, out_path=args.out)
