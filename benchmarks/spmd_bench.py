"""SPMD train-step benchmark: dense vs N:M-compressed gradient sync.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m benchmarks.spmd_bench [--smoke]

Builds the real sharded train step on a ("pod","data","model") mesh
over every visible device (the module forces 8 CPU devices when it owns
the process), runs it both with dense cross-pod gradient sync and with
the N:M-compressed path (optim/compress), and records:

  * per-step wall time (median of the timed steps, compile excluded) —
    informational only, CI machines are too noisy to gate on it;
  * per-chip collective link bytes from the optimized HLO (hlo_cost's
    ring accounting) — deterministic, gated by check_regression;
  * the analytic wire-format arithmetic: fp32 grad bytes vs packed
    bf16-vals + u8-idx bytes over the compressible leaves.

Writes results/BENCH_spmd.json.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

if "jax" not in sys.modules:  # own process: force a multi-device host
    from repro.launch.spmd import force_host_devices
    force_host_devices(8)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs import get_arch
from repro.core.sparsity import SparsityConfig, nm_pack
from repro.data import synthetic as D
from repro.launch import hlo_cost
from repro.launch import spmd
from repro.models import transformer_lm as T
from repro.optim import sgd
from repro.train import step as ST

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def grad_sync_bytes(params, sp_cfg: SparsityConfig) -> dict:
    """Wire bytes of one cross-pod gradient sync, dense vs packed."""
    dense = packed = ragged = 0
    for leaf in jax.tree.leaves(params):
        nbytes = int(np.prod(leaf.shape)) * 4  # fp32 grads
        dense += nbytes
        if leaf.ndim and int(np.prod(leaf.shape)) % sp_cfg.m == 0:
            vals, idx = jax.eval_shape(
                lambda l: nm_pack(
                    jnp.zeros((int(np.prod(l.shape)) // sp_cfg.m,
                               sp_cfg.m), jnp.bfloat16),
                    sp_cfg.n, sp_cfg.m, axis=-1), leaf)
            packed += (int(np.prod(vals.shape)) * 2
                       + int(np.prod(idx.shape)) * 1)
        else:
            packed += nbytes  # rides uncompressed
            ragged += nbytes
    return {"dense_bytes": dense, "packed_bytes": packed,
            "uncompressed_ragged_bytes": ragged,
            "wire_ratio": packed / max(dense, 1)}


def bench_variant(cfg, mesh, sp_cfg, opt_cfg, *, compress: bool,
                  batch: int, seq: int, steps: int) -> dict:
    bundle = ST.build_lm_train(cfg, mesh, sp_cfg, opt_cfg, donate=False,
                               compress=compress)
    state = ST.init_train_state(jax.random.PRNGKey(0), cfg,
                                compress=compress, sp_cfg=sp_cfg)
    state = jax.device_put(state, bundle.state_shardings)
    sh = {k: NamedSharding(mesh, ps)
          for k, ps in bundle.input_pspecs.items()}
    stream = D.lm_stream(cfg.vocab, batch, seq, shardings=sh, seed=0)

    _, first = next(stream)
    lowered = bundle.step_fn.lower(state, first)
    analysis = hlo_cost.analyze(lowered.compile().as_text())

    state, _ = bundle.step_fn(state, first)  # compile + warmup
    jax.block_until_ready(state)
    times = []
    for _ in range(steps):
        _, b = next(stream)
        t0 = time.perf_counter()
        state, metrics = bundle.step_fn(state, b)
        jax.block_until_ready(metrics["loss"])
        times.append(time.perf_counter() - t0)
    return {
        "step_ms_median": float(np.median(times) * 1e3),
        "step_ms_all": [round(t * 1e3, 2) for t in times],
        "final_loss": float(metrics["loss"]),
        "collectives": analysis["collectives"],
        "hlo_flops": analysis["flops"],
    }


def main(smoke: bool = False, out_path: str | None = None) -> dict:
    arch = get_arch("qwen3-8b")
    cfg = arch.smoke
    sp_cfg = SparsityConfig(n=2, m=8, method="bdwp")
    opt_cfg = sgd.SGDConfig(lr=0.1)
    batch, seq, steps = (8, 32, 3) if smoke else (8, 64, 8)

    n_dev = jax.device_count()
    mesh = spmd.make_spmd_mesh("pod,data,model")
    print(f"devices={n_dev} mesh={dict(mesh.shape)}")

    variants = {}
    for name, compress in (("dense_sync", False), ("compressed_sync", True)):
        variants[name] = bench_variant(cfg, mesh, sp_cfg, opt_cfg,
                                       compress=compress, batch=batch,
                                       seq=seq, steps=steps)
        v = variants[name]
        print(f"{name:16s} {v['step_ms_median']:8.1f} ms/step  "
              f"coll={v['collectives']['total']:>12,} B/chip  "
              f"loss={v['final_loss']:.4f}")

    params, _ = T.init(jax.random.PRNGKey(0), cfg, abstract=True)
    sync = grad_sync_bytes(params, sp_cfg)
    print(f"grad sync wire bytes: dense={sync['dense_bytes']:,} "
          f"packed={sync['packed_bytes']:,} "
          f"(ratio {sync['wire_ratio']:.3f})")

    summary = {
        "bench": "spmd_bench",
        "arch": cfg.name,
        "devices": n_dev,
        "mesh": dict(mesh.shape),
        "sparsity": {"n": sp_cfg.n, "m": sp_cfg.m, "method": sp_cfg.method},
        "batch": batch, "seq": seq,
        "smoke": smoke,
        "sync": sync,
        "variants": variants,
    }
    os.makedirs(RESULTS, exist_ok=True)
    out_path = out_path or os.path.join(RESULTS, "BENCH_spmd.json")
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"wrote {out_path}")
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    main(smoke=args.smoke, out_path=args.out)
