"""SPMD train-step benchmark: dense vs N:M-compressed gradient sync.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m benchmarks.spmd_bench [--smoke]

Builds the real sharded train step on a ("pod","data","model") mesh
over every visible device (the module forces 8 CPU devices when it owns
the process), runs it both with dense cross-pod gradient sync and with
the N:M-compressed path (optim/compress), and records per variant:

  * compute_ms_median — measured steady-state wall time (the compile
    step AND the warmup steps are discarded).  Machine-noisy in absolute
    terms; both variants run in one process on one machine, so the
    directional comparison is fair;
  * pod_link_bytes — the per-chip ring link bytes of collectives whose
    replica groups SPAN pods, measured from the optimized HLO
    (hlo_cost.analyze(pod_block=...)).  Deterministic;
  * pod_wire_ms / step_ms_median — the emulated inter-pod link model.
    The CI hosts force 8 XLA devices onto shared memory: every
    collective is a memcpy, so raw wall time cannot see the one cost
    the compressed sync exists to remove — inter-pod wire time.  The
    bench therefore charges each variant's MEASURED pod-crossing bytes
    at a fixed POD_LINK_GBPS (1 Gb/s commodity Ethernet — the canonical
    setting of the gradient-compression literature, e.g. Deep Gradient
    Compression, arXiv 1712.01887) and reports

        step_ms_median = compute_ms_median
                       + pod_link_bytes * device_count / link_bw

    applied identically to both variants: intra-pod collectives are
    free (fast fabric), pod-crossing ones pay the modeled link.  The
    granularity is WHOLE-HOST on both terms, deliberately: the forced
    devices serialize onto the host's cores, so compute_ms_median is
    the sum over all chips' compute — and the emulated host likewise
    has ONE physical NIC shared by all its chips, so wall wire time is
    the sum over all chips' pod-crossing link bytes (per-chip ring
    bytes × device_count), not one chip's.  The win-or-fail gate in
    check_regression compares step_ms_median, so a compressed sync
    only wins when its REAL measured compute overhead is smaller than
    the wire time its REAL measured byte saving buys;
  * the analytic wire-format arithmetic (optim/compress.wire_bytes):
    fp32 grad bytes vs the bucketed packed slab's bf16-vals + u8-idx
    bytes, gated on wire_ratio.

Writes results/BENCH_spmd.json.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

if "jax" not in sys.modules:  # own process: force a multi-device host
    from repro.launch.spmd import force_host_devices
    force_host_devices(8)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs import get_arch
from repro.core.sparsity import SparsityConfig
from repro.data import synthetic as D
from repro.launch import hlo_cost
from repro.launch import spmd
from repro.models import transformer_lm as T
from repro.optim import compress as C
from repro.optim import sgd
from repro.train import step as ST

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

# Emulated inter-pod link bandwidth, Gbit/s.  1 GbE is the canonical
# gradient-compression setting (DGC, arXiv 1712.01887); the pod-crossing
# bytes it is applied to are MEASURED from the compiled HLO, never
# assumed.  Fixed — not a CLI knob — so the win-or-fail CI gate always
# compares against the same link model.
POD_LINK_GBPS = 1.0


def grad_sync_bytes(params, sp_cfg: SparsityConfig,
                    gc_cfg: "C.GradCompressConfig | None" = None) -> dict:
    """Wire bytes of one cross-pod gradient sync, dense vs packed —
    the same bucketed-slab accounting optim/compress ships (bf16 vals +
    u8 idx per M-group over the compressible slab, fp32 raggeds)."""
    gc = gc_cfg or C.GradCompressConfig.from_sparsity(sp_cfg)
    leaves = jax.tree.leaves(params)
    dense = sum(int(np.prod(leaf.shape)) * 4 for leaf in leaves)
    total = C.err_state_elems(params, gc.m)
    ragged = sum(int(np.prod(leaf.shape)) for leaf in leaves
                 if not C.compressible_shape(leaf.shape, gc.m))
    packed = C.wire_bytes(total, ragged, gc)
    return {"dense_bytes": dense, "packed_bytes": packed,
            "uncompressed_ragged_bytes": ragged * 4,
            "slab_elems": total,
            "buckets": len(C.plan_buckets(total, gc.bucket_elems, gc.m)),
            "wire_ratio": packed / max(dense, 1)}


def bench_variant(cfg, mesh, sp_cfg, opt_cfg, *, compress: bool,
                  batch: int, seq: int, steps: int,
                  warmup: int = 2) -> dict:
    bundle = ST.build_lm_train(cfg, mesh, sp_cfg, opt_cfg, donate=True,
                               compress=compress)
    state = ST.init_train_state(jax.random.PRNGKey(0), cfg,
                                compress=compress, sp_cfg=sp_cfg, mesh=mesh)
    state = jax.device_put(state, bundle.state_shardings)
    sh = {k: NamedSharding(mesh, ps)
          for k, ps in bundle.input_pspecs.items()}
    stream = D.lm_stream(cfg.vocab, batch, seq, shardings=sh, seed=0)

    _, first = next(stream)
    lowered = bundle.step_fn.lower(state, first)
    pod_block = jax.device_count() // mesh.shape.get("pod", 1)
    analysis = hlo_cost.analyze(lowered.compile().as_text(),
                                pod_block=pod_block)

    state, _ = bundle.step_fn(state, first)  # compile step (never timed)
    jax.block_until_ready(state)
    for _ in range(warmup):  # discarded: medians are steady-state only
        _, b = next(stream)
        state, metrics = bundle.step_fn(state, b)
    jax.block_until_ready(state)
    times = []
    for _ in range(steps):
        _, b = next(stream)
        t0 = time.perf_counter()
        state, metrics = bundle.step_fn(state, b)
        jax.block_until_ready(metrics["loss"])
        times.append(time.perf_counter() - t0)
    compute_ms = float(np.median(times) * 1e3)
    pod_link_bytes = analysis["collectives"]["pod_crossing"]
    # measured pod-crossing bytes charged at the fixed emulated link.
    # × device_count: compute_ms is the whole host's serialized compute,
    # so the wire term is the whole host's traffic through its one NIC
    # (per-chip ring bytes × chips), keeping both terms host-granular.
    host_bytes = pod_link_bytes * jax.device_count()
    pod_wire_ms = host_bytes * 8 / (POD_LINK_GBPS * 1e9) * 1e3
    return {
        "compute_ms_median": compute_ms,
        "compute_ms_all": [round(t * 1e3, 2) for t in times],
        "pod_link_bytes": pod_link_bytes,
        "pod_link_gbps": POD_LINK_GBPS,
        "pod_wire_ms": round(pod_wire_ms, 3),
        "step_ms_median": compute_ms + pod_wire_ms,
        "warmup_steps": warmup,
        "timed_steps": len(times),
        "final_loss": float(metrics["loss"]),
        "collectives": analysis["collectives"],
        "hlo_flops": analysis["flops"],
    }


def main(smoke: bool = False, out_path: str | None = None) -> dict:
    arch = get_arch("qwen3-8b")
    cfg = arch.smoke
    sp_cfg = SparsityConfig(n=2, m=8, method="bdwp")
    opt_cfg = sgd.SGDConfig(lr=0.1)
    # enough timed steps for a stable median (odd count → the median is
    # one real sample, robust to transient host-contention outliers):
    # the directional win gate compares the two variants' medians from
    # this one process
    batch, seq, steps = (8, 32, 11) if smoke else (8, 64, 11)

    n_dev = jax.device_count()
    mesh = spmd.make_spmd_mesh("pod,data,model")
    print(f"devices={n_dev} mesh={dict(mesh.shape)}")

    variants = {}
    for name, compress in (("dense_sync", False), ("compressed_sync", True)):
        variants[name] = bench_variant(cfg, mesh, sp_cfg, opt_cfg,
                                       compress=compress, batch=batch,
                                       seq=seq, steps=steps)
        v = variants[name]
        print(f"{name:16s} {v['step_ms_median']:8.1f} ms/step "
              f"(compute {v['compute_ms_median']:.1f} + pod wire "
              f"{v['pod_wire_ms']:.1f} @ {POD_LINK_GBPS:g}Gb/s)  "
              f"pod-crossing={v['pod_link_bytes']:>9,} B/chip  "
              f"loss={v['final_loss']:.4f}")

    params, _ = T.init(jax.random.PRNGKey(0), cfg, abstract=True)
    sync = grad_sync_bytes(params, sp_cfg)
    print(f"grad sync wire bytes: dense={sync['dense_bytes']:,} "
          f"packed={sync['packed_bytes']:,} "
          f"(ratio {sync['wire_ratio']:.3f})")

    summary = {
        "bench": "spmd_bench",
        "arch": cfg.name,
        "devices": n_dev,
        "mesh": dict(mesh.shape),
        "sparsity": {"n": sp_cfg.n, "m": sp_cfg.m, "method": sp_cfg.method},
        "batch": batch, "seq": seq,
        "smoke": smoke,
        "sync": sync,
        "variants": variants,
    }
    os.makedirs(RESULTS, exist_ok=True)
    out_path = out_path or os.path.join(RESULTS, "BENCH_spmd.json")
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"wrote {out_path}")
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    main(smoke=args.smoke, out_path=args.out)
