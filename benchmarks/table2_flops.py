"""Table II reproduction: training/inference FLOPs for the five paper
models under {dense, SR-STE, SDGP, SDWP, BDWP} x {2:4, 2:8, 2:16}.

The paper's accounting: total training FLOPs = epochs x dataset_size x
per-sample train FLOPs, where per-sample train = FF + BP + WU = 3x the
inference FLOPs for dense training; N:M methods scale the pruned stages
by N/M (first layer excluded).  Inference FLOPs = 2 x MACs of the
forward pass (pruned stages at N/M).

Paper reference values (dense): ResNet9 2.62e16 / ViT 1.45e16 /
VGG19 9.00e15 / ResNet18 4.82e16 / ResNet50 1.91e18 train FLOPs.
"""

from __future__ import annotations

from repro.configs.paper_models import PAPER_MODELS
from repro.satsim.workloads import paper_model_layers

DATASET_SIZE = {"cifar10": 50_000, "cifar100": 50_000,
                "tinyimagenet": 100_000, "imagenet": 1_281_167}

# (ff_sparse, bp_sparse) per method — WU always dense (Alg. 1)
METHODS = {
    "dense": (False, False),
    "srste": (True, False),
    "sdgp": (False, True),
    "bdwp": (True, True),
}

PAPER_TRAIN_E16 = {  # Table II "Train. FLOPS" dense baselines (x1e16)
    "resnet9": 2.62, "vit": 1.45, "vgg19": 0.90, "resnet18": 4.82,
    "resnet50": 191.0,
}


def model_flops(name: str, method: str, n: int, m: int) -> dict:
    pm = PAPER_MODELS[name]
    layers = paper_model_layers(name, batch=1)  # per-sample
    ff_sp, bp_sp = METHODS[method]
    frac = n / m
    infer = train = 0.0
    for l in layers:
        base = 2.0 * l.macs
        f_ff = frac if (ff_sp and l.prunable) else 1.0
        f_bp = frac if (bp_sp and l.prunable) else 1.0
        infer += base * f_ff
        train += base * (f_ff + f_bp + 1.0)
    samples = pm.epochs * DATASET_SIZE[pm.dataset]
    return {"model": name, "method": method, "nm": f"{n}:{m}",
            "infer_flops": infer, "train_flops": train * samples}


def run() -> list:
    rows = []
    for name in PAPER_MODELS:
        dense = model_flops(name, "dense", 2, 8)
        for (n, m) in ((2, 4), (2, 8), (2, 16)):
            for method in ("srste", "sdgp", "bdwp"):
                r = model_flops(name, method, n, m)
                r["train_reduction_vs_dense"] = round(
                    dense["train_flops"] / r["train_flops"], 3)
                r["infer_reduction_vs_dense"] = round(
                    dense["infer_flops"] / r["infer_flops"], 3)
                rows.append(r)
        dense["train_reduction_vs_dense"] = 1.0
        dense["infer_reduction_vs_dense"] = 1.0
        dense["paper_train_e16"] = PAPER_TRAIN_E16[name]
        dense["ratio_vs_paper"] = round(
            dense["train_flops"] / (PAPER_TRAIN_E16[name] * 1e16), 3)
        rows.append(dense)
    return rows


def main():
    rows = run()
    avg_red = [r["train_reduction_vs_dense"] for r in rows
               if r["method"] == "bdwp" and r["nm"] == "2:8"]
    print("model,method,nm,train_flops,infer_flops,train_red,infer_red")
    for r in rows:
        print(f"{r['model']},{r['method']},{r['nm']},"
              f"{r['train_flops']:.3e},{r['infer_flops']:.3e},"
              f"{r['train_reduction_vs_dense']},{r['infer_reduction_vs_dense']}")
    print(f"# BDWP 2:8 mean train reduction: "
          f"{sum(avg_red)/len(avg_red):.2f}x (paper: 1.93x)")


if __name__ == "__main__":
    main()
