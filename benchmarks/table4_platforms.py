"""Table IV reproduction: SAT vs CPU / GPU on ResNet18 (batch 512).

CPU/GPU columns are the paper's measured reference points (we have no
RTX 2080 Ti in this container); the SAT column is OUR cycle model, so
the table checks satsim against the paper's reported SAT row: latency
11.98 s, runtime 484.21 GFLOPS avg (280.31 dense / 702.54 sparse),
peak 409.6 / 1638.4 GOPS.
"""

from __future__ import annotations

from repro.satsim.arch import DEFAULT
from repro.satsim.model import (POWER_AVG_W, POWER_DENSE_W, POWER_SPARSE_W,
                                model_step_time, runtime_throughput)
from repro.satsim.workloads import resnet18_layers

REFERENCE = [
    # platform, latency_s, power_w, peak_gflops, runtime_gflops, eff
    ("i9-9900X (paper)", 12.91, 165.0, 2240, 423.69, 2.57),
    ("Jetson Nano (paper)", 61.28, 7.54, 472, 94.66, 12.56),
    ("RTX 2080 Ti (paper)", 1.72, 238.36, 76000, 3372.52, 14.15),
]


def run() -> dict:
    layers = resnet18_layers(batch=512)
    dense = runtime_throughput(layers, "dense")
    sparse = runtime_throughput(layers, "bdwp")
    # paper latency counts the whole-epoch per-batch averaged pipeline;
    # per-batch latency here
    avg_gops = (dense["gops"] + sparse["gops"]) / 2
    return {
        "dense_gops": dense["gops"], "sparse_gops": sparse["gops"],
        "avg_gops": avg_gops,
        "dense_latency_s": dense["total_s"],
        "sparse_latency_s": sparse["total_s"],
        "peak_dense": DEFAULT.dense_peak_ops / 1e9,
        "peak_sparse": DEFAULT.sparse_peak_ops / 1e9,
        "eff_dense": dense["gops"] / POWER_DENSE_W,
        "eff_sparse": sparse["gops"] / POWER_SPARSE_W,
        "eff_avg": avg_gops / POWER_AVG_W,
    }


def main():
    r = run()
    print("platform,latency_s,power_w,peak_gflops,runtime_gflops,gflops_per_w")
    for row in REFERENCE:
        print(",".join(str(x) for x in row))
    print(f"SAT satsim dense,{r['dense_latency_s']:.2f},{POWER_DENSE_W},"
          f"{r['peak_dense']:.1f},{r['dense_gops']:.1f},{r['eff_dense']:.2f}")
    print(f"SAT satsim 2:8,{r['sparse_latency_s']:.2f},{POWER_SPARSE_W},"
          f"{r['peak_sparse']:.1f},{r['sparse_gops']:.1f},{r['eff_sparse']:.2f}")
    print(f"# paper SAT row: 11.98s, 280.31/702.54 GFLOPS, "
          f"13.52/29.09 GFLOPS/W; avg eff here {r['eff_avg']:.2f} "
          f"(paper 21.64)")


if __name__ == "__main__":
    main()
