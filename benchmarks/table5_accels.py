"""Table V reproduction: SAT vs prior FPGA-based training accelerators.

Literature rows are fixed reference points from the paper; the SAT row
comes from satsim.  Derived: throughput / computational-efficiency /
energy-efficiency improvement ranges vs the FP16+ accelerators —
the paper's 2.97~25.22x / 1.3~39x / 1.36~3.58x claims.
"""

from __future__ import annotations

from repro.satsim.model import POWER_AVG_W, runtime_throughput
from repro.satsim.workloads import resnet18_layers

# accelerator, platform, network, precision, dsp, freq, power, gops
PRIOR = [
    ("TODAES'22", "ZCU102", "VGG-16", "FP32", 1508, 100, 7.71, 46.99),
    ("FPGA'20", "Stratix10", "AlexNet", "FP32", 1796, 253, None, 24.00),
    ("FPT'17", "ZU19EG", "LeNet-10", "FP32", 1500, 200, 14.24, 86.12),
    ("ICCAD'20", "Stratix10MX", "VGG-like", "FP16", 1046, 185, 20.00, 158.54),
    ("OJCAS'23", "ZCU104", "AlexNet", "BFP16", 1285, 200, 6.44, 102.43),
    ("AICAS'21", "XC7Z100", "FC", "INT16", 64, 150, 2.50, 19.20),
    ("FPL'19", "Stratix10GX", "VGG-like", "INT16", 1699, 240, 20.60, 163.00),
]
SAT_DSP = 1228


def run() -> dict:
    r = runtime_throughput(resnet18_layers(batch=512), "bdwp")
    dense = runtime_throughput(resnet18_layers(batch=512), "dense")
    sat_gops = (r["gops"] + dense["gops"]) / 2  # paper reports the average
    sat_eff = sat_gops / POWER_AVG_W
    sat_comp = sat_gops / SAT_DSP
    ratios_t, ratios_c, ratios_e = [], [], []
    for (_, _, _, prec, dsp, _, pw, gops) in PRIOR:
        ratios_t.append(sat_gops / gops)
        ratios_c.append(sat_comp / (gops / dsp))
        if pw:
            ratios_e.append(sat_eff / (gops / pw))
    return {"sat_gops": sat_gops, "sat_eff": sat_eff, "sat_comp": sat_comp,
            "throughput_x": (min(ratios_t), max(ratios_t)),
            "comp_eff_x": (min(ratios_c), max(ratios_c)),
            "energy_eff_x": (min(ratios_e), max(ratios_e))}


def main():
    print("accel,platform,network,precision,dsp,freq,power_w,gops")
    for row in PRIOR:
        print(",".join(str(x) for x in row))
    r = run()
    print(f"SAT (satsim),XCVU9P,ResNet-18,FP16+FP32,{SAT_DSP},200,"
          f"{POWER_AVG_W},{r['sat_gops']:.1f}")
    print(f"# improvements: throughput {r['throughput_x'][0]:.2f}~"
          f"{r['throughput_x'][1]:.2f}x (paper 2.97~25.22x), comp-eff "
          f"{r['comp_eff_x'][0]:.1f}~{r['comp_eff_x'][1]:.1f}x (paper "
          f"1.3~39x), energy-eff {r['energy_eff_x'][0]:.2f}~"
          f"{r['energy_eff_x'][1]:.2f}x (paper 1.36~3.58x)")


if __name__ == "__main__":
    main()
