"""Fig. 4 reproduction (direction): from-scratch loss curves under
dense / SR-STE / SDGP / SDWP / BDWP at the same N:M ratio.

  PYTHONPATH=src python examples/paper_loss_curves.py [--steps 120]

Real CIFAR/TinyImageNet are not available offline, so this trains the
paper's ResNet9 (width-reduced) on the synthetic class-blob task and a
small LM on the copy task, checking the paper's *ordering* claim:
BDWP's curve tracks dense/SR-STE closely while SDGP (pruned output
gradients) converges visibly worse at aggressive ratios (Fig. 4c).
"""

import argparse
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sparsity import SparsityConfig
from repro.data import synthetic as D
from repro.models import convnets as C
from repro.optim import sgd

METHODS = ("dense", "srste", "sdgp", "sdwp", "bdwp")


def train_resnet9(method: str, nm=(2, 8), steps=120, batch=64, seed=0):
    sp_cfg = SparsityConfig(n=nm[0], m=nm[1], method=method)
    icfg = D.ImageTaskConfig(image=32, num_classes=10, batch=batch, seed=seed)
    params = C.resnet9_init(jax.random.PRNGKey(seed), num_classes=10, width=32)
    state = sgd.init_state(params)
    opt = sgd.SGDConfig(lr=0.05, warmup_steps=10, total_steps=steps,
                        weight_decay=5e-4)

    @jax.jit
    def step_fn(state, x, y):
        def loss_fn(master):
            # pass fp32 master straight through: nm_conv/nm_linear score
            # their N:M masks on the weights they are given and cast to
            # the activation dtype only AFTER masking, so the FF/BP masks
            # agree with the optimizer's fp32-master SR-STE decay mask
            # (a bf16 pre-cast here made near-tie groups disagree)
            logits = C.resnet9_apply(master, x.astype(jnp.bfloat16), sp_cfg)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
            return (logz - gold).mean()

        loss, grads = jax.value_and_grad(loss_fn)(state["master"])
        new_state, _ = sgd.update(state, grads, opt, sp_cfg)
        return new_state, loss

    losses = []
    for step in range(steps):
        x, y = D.image_batch(icfg, step)
        state, loss = step_fn(state, jnp.asarray(x), jnp.asarray(y))
        losses.append(float(loss))
    return losses


def tail_mean(xs, k=20):
    return sum(xs[-k:]) / min(k, len(xs))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--nm", default="2:8")
    args = ap.parse_args()
    n, m = (int(v) for v in args.nm.split(":"))

    print(f"ResNet9(w=32) on synthetic blobs, {n}:{m}, {args.steps} steps")
    results = {}
    for method in METHODS:
        losses = train_resnet9(method, (n, m), steps=args.steps)
        results[method] = losses
        print(f"  {method:6s} final(+tail20) loss {tail_mean(losses):.4f}")

    dense = tail_mean(results["dense"])
    bdwp = tail_mean(results["bdwp"])
    sdgp = tail_mean(results["sdgp"])
    print(f"\nordering check (Fig. 4): BDWP-dense gap "
          f"{bdwp-dense:+.4f}; SDGP-dense gap {sdgp-dense:+.4f}")
    print("expected: |BDWP-dense| small; SDGP drifts highest at 2:8+"
          if sdgp >= bdwp else "note: SDGP tracked well on this task/scale")


if __name__ == "__main__":
    main()
