"""Quickstart: the paper's BDWP N:M sparse training in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

Shows the three public layers of the stack:
  1. core.sparsity — N:M masks and SORE-style packing,
  2. core.bdwp     — the bidirectional-pruning matmul (Alg. 1),
  3. train.step    — a jitted train step with resolved shardings.
"""

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import bdwp
from repro.core.sparsity import SparsityConfig, group_nonzeros, nm_pack, nm_unpack_n, sparsify
from repro.data import synthetic as D
from repro.launch.mesh import make_host_mesh
from repro.optim import sgd
from repro.train import step as ST

# --- 1. N:M sparsity primitives -------------------------------------------
cfg = SparsityConfig(n=2, m=8, method="bdwp")
w = jax.random.normal(jax.random.PRNGKey(0), (16, 32))
w_sparse = sparsify(w, cfg, axis=0)           # FF view: groups along K
nz = group_nonzeros(w_sparse, m=8, axis=0)
print(f"N:M mask: every 8-group keeps {int(nz.max())} values "
      f"(density {float((w_sparse != 0).mean()):.3f})")

vals, idx = nm_pack(w, 2, 8, axis=0)          # SORE: compact (values, idx)
w_rt = nm_unpack_n(vals, idx, 2, 8, axis=0)
assert jnp.allclose(w_rt, w_sparse), "pack/unpack must equal the mask"
print(f"packed storage: {vals.size * 2 + idx.size} bytes vs dense "
      f"{w.size * 2} (bf16)")

# --- 2. BDWP matmul: FF-sparse, BP-sparse, WU-dense ------------------------
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
y, vjp = jax.vjp(lambda xx, ww: bdwp.nm_linear(xx, ww, cfg), x, w)
dx, dw = vjp(jnp.ones_like(y))
print(f"BDWP matmul: y={y.shape}, dense dw (straight-through): "
      f"{float((dw != 0).mean()):.2f} density")

# --- 3. A real train step on the qwen3 smoke config ------------------------
arch = get_arch("qwen3-8b")
mesh = make_host_mesh()
opt = sgd.SGDConfig(lr=0.05, total_steps=20)
bundle = ST.build_lm_train(arch.smoke, mesh, cfg, opt)
state = jax.device_put(
    ST.init_train_state(jax.random.PRNGKey(0), arch.smoke, sp_cfg=cfg),
    bundle.state_shardings)
stream = D.lm_stream(arch.smoke.vocab, batch=4, seq=64)
for step, batch in stream:
    state, metrics = bundle.step_fn(state, batch)
    if step % 5 == 0:
        print(f"step {step:2d}  loss {float(metrics['loss']):.4f}")
    if step >= 15:
        break
print("quickstart OK")
