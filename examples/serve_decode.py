"""Serving example: batched prefill + KV-cache decode with N:M-packed
weights (the paper's inference-side win: weights stream at N/M of the
dense bytes).

  PYTHONPATH=src python examples/serve_decode.py [--tokens 32]

Uses the same build_lm_serve path the 32k-decode dry-run cells lower,
on the qwen3 smoke config, and reports decode throughput plus the
HBM-byte saving of SORE-packed weights.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.sparsity import SparsityConfig, nm_pack, sparsify
from repro.launch.mesh import make_host_mesh
from repro.models import transformer_lm as T
from repro.train import step as ST


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    arch = get_arch("qwen3-8b")
    cfg = arch.smoke
    sp_cfg = SparsityConfig(n=2, m=8, method="bdwp")
    mesh = make_host_mesh()

    params, _ = T.init(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(lambda w: w.astype(jnp.bfloat16), params)

    # paper Fig. 11c: serve from FF-pruned (packed) weights
    packed_bytes = dense_bytes = 0
    def pack_weights(path, w):
        nonlocal packed_bytes, dense_bytes
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        from repro.core import bdwp as B
        if w.ndim >= 2 and B.should_prune(name.split("/")[-1], w.shape[-2:], sp_cfg):
            dense_bytes += w.size * 2
            v, i = nm_pack(w, sp_cfg.n, sp_cfg.m, axis=w.ndim - 2)
            packed_bytes += v.size * 2 + i.size
            return sparsify(w, sp_cfg, axis=w.ndim - 2)  # masked = unpack(pack)
        return w
    params = jax.tree_util.tree_map_with_path(pack_weights, params)
    if dense_bytes:
        print(f"packed weights: {packed_bytes/1e6:.2f} MB vs dense "
              f"{dense_bytes/1e6:.2f} MB ({dense_bytes/packed_bytes:.2f}x HBM saving)")

    max_len = args.prompt_len + args.tokens
    tokens = jax.random.randint(jax.random.PRNGKey(1), (args.batch, args.prompt_len),
                                0, cfg.vocab)
    # prefill
    logits, cache = ST.lm_prefill_step(params, {"tokens": tokens},
                                       cfg=cfg, sp_cfg=sp_cfg)
    # the prefill cache is sized to the prompt; re-seat into a max_len cache
    full = T.init_lm_cache(cfg, args.batch, max_len)
    def seat(dst, src):
        if dst.ndim == 0 or dst.shape == src.shape:
            return src.astype(dst.dtype)
        sl = tuple(slice(0, s) for s in src.shape)
        return dst.at[sl].set(src.astype(dst.dtype))
    cache = jax.tree.map(seat, full, cache)

    decode = jax.jit(lambda p, c, t, pos: ST.lm_decode_step(
        p, c, t, pos, cfg=cfg, sp_cfg=sp_cfg), donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.tokens):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    total = args.batch * args.tokens
    print(f"decoded {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, batch={args.batch})")
    seq = jnp.concatenate(out, axis=1)
    print("sample token ids:", seq[0, :12].tolist())


if __name__ == "__main__":
    main()
