"""Serving example: the continuous-batching engine on N:M-packed weights.

  PYTHONPATH=src python examples/serve_decode.py [--tokens 24]

A thin client of ``repro.serve.ServeEngine``: three mixed-length
requests share a 2-slot engine, so the third request *joins mid-flight*
into the slot freed by the first — and every per-request token stream
is identical to decoding that request alone (the engine's per-slot
position/mask semantics make batch composition invisible to a request).

With ``--packed`` (default on) decode runs from element-mode SORE-packed
(vals, idx) weights through kernels/nm_spmm — the paper's Fig. 11c
inference win: weights stream at ~N/M of the dense HBM bytes.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.sparsity import SparsityConfig
from repro.models import transformer_lm as T
from repro.serve import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=24,
                    help="max new tokens per request")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--dense", action="store_true",
                    help="serve re-masked dense weights instead of packed")
    args = ap.parse_args()

    arch = get_arch("qwen3-8b")
    cfg = arch.smoke
    sp_cfg = SparsityConfig(n=2, m=8, method="bdwp")

    params, _ = T.init(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(lambda w: w.astype(jnp.bfloat16), params)

    serve_cfg = ServeConfig(n_slots=args.slots, prompt_bucket=16,
                            max_len=16 + args.tokens,
                            packed=not args.dense)

    key = jax.random.PRNGKey(1)
    prompts = [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                             (plen,), 0, cfg.vocab))
               for i, plen in enumerate((5, 11, 14))]

    # --- solo references: each request decoded alone (one engine, reused
    # sequentially — run() drains between submissions) ----------------------
    engine = ServeEngine(params, cfg, sp_cfg, serve_cfg)
    solo = {}
    for i, p in enumerate(prompts):
        rid = engine.submit(p, max_new_tokens=args.tokens)
        solo[i] = engine.run()[rid]

    # --- mixed workload: r2 joins mid-flight when r0's slot frees ----------
    engine.reset()
    if engine.store is not None:
        r = engine.hbm_report()
        print(f"packed weights: {r['packed_weight_bytes']/1e6:.2f} MB vs "
              f"dense {r['dense_weight_bytes']/1e6:.2f} MB "
              f"({r['hbm_saving']:.2f}x HBM saving, "
              f"{r['n_packed']} tensors packed)")
    r0 = engine.submit(prompts[0], max_new_tokens=args.tokens // 2)
    r1 = engine.submit(prompts[1], max_new_tokens=args.tokens)
    r2 = None
    t0 = time.perf_counter()
    while engine.n_running or engine.n_queued or r2 is None:
        events = engine.step()
        if r2 is None and r0 in events["finished"]:
            # slot freed this step -> the next step admits r2 mid-flight
            r2 = engine.submit(prompts[2], max_new_tokens=args.tokens)
    dt = time.perf_counter() - t0
    out = engine.harvest()

    ok = (out[r0] == solo[0][:len(out[r0])]
          and out[r1] == solo[1] and out[r2] == solo[2])
    for rid, sref in ((r0, solo[0]), (r1, solo[1]), (r2, solo[2])):
        print(f"req {rid}: {len(out[rid])} tokens, first 8 = "
              f"{out[rid][:8]}")
    st = engine.stats()
    print(f"decoded {st['decoded_tokens']} tokens in {dt:.2f}s "
          f"({st['decoded_tokens']/dt:.1f} tok/s, {st['decode_steps']} "
          f"decode steps, {args.slots} slots)")
    print("continuous-batching streams identical to solo decode:", ok)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
