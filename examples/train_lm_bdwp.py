"""End-to-end driver: train a ~100M-param LM with BDWP 2:8 for a few
hundred steps on synthetic data, with checkpointing + fault tolerance.

  PYTHONPATH=src python examples/train_lm_bdwp.py [--steps 300]

This is deliverable (b)'s "train ~100M model" example: the same stack
the production launcher uses (StepBundle -> trainer.fit), at a scale a
CPU container completes.  Compare --method dense vs bdwp to see the
loss curves track (Fig. 4's claim) while BDWP executes ~48% fewer
matmul MACs (printed from the RWG schedule).
"""

import argparse

import jax

from repro.core import schedule as SCHED
from repro.core.sparsity import SparsityConfig
from repro.data import synthetic as D
from repro.launch.mesh import make_host_mesh
from repro.models import transformer_lm as T
from repro.optim import sgd
from repro.train import step as ST
from repro.train import trainer as TR

LM_100M = T.LMConfig(
    name="lm-100m", vocab=32768, d_model=640, n_layers=10, n_heads=10,
    n_kv=5, head_dim=64, d_ff=2560, tie_embed=True, remat=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--method", default="bdwp")
    ap.add_argument("--nm", default="2:8")
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_bdwp")
    args = ap.parse_args()

    n, m = (int(v) for v in args.nm.split(":"))
    sp_cfg = SparsityConfig(n=n, m=m, method=args.method)
    params, _ = T.init(jax.random.PRNGKey(0), LM_100M, abstract=True)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params | {args.method} {n}:{m}")

    # RWG offline schedule: predicted MAC reduction for this model
    shapes = {"/".join(str(getattr(k, 'key', k)) for k in path): v.shape
              for path, v in jax.tree_util.tree_flatten_with_path(params)[0]}
    plans = SCHED.plan_model(shapes, tokens=args.batch * args.seq, cfg=sp_cfg)
    summ = SCHED.schedule_summary(plans)
    print(f"RWG schedule: {summ['n_layers']} matmuls, MAC reduction "
          f"{summ['reduction']:.2f}x vs dense, mean predicted utilization "
          f"{summ['mean_utilization']:.2f}")

    mesh = make_host_mesh()
    opt = sgd.SGDConfig(lr=0.02, warmup_steps=20, total_steps=args.steps)
    bundle = ST.build_lm_train(LM_100M, mesh, sp_cfg, opt)
    state = jax.device_put(
        ST.init_train_state(jax.random.PRNGKey(0), LM_100M, sp_cfg=sp_cfg),
        bundle.state_shardings)
    stream = D.lm_stream(LM_100M.vocab, args.batch, args.seq)
    tcfg = TR.TrainerConfig(total_steps=args.steps, ckpt_every=100,
                            log_every=20, ckpt_dir=args.ckpt_dir,
                            heartbeat_path=f"{args.ckpt_dir}/heartbeat.json")
    state, history = TR.fit(bundle, state, stream, tcfg)
    print(f"final loss {history[-1]['loss']:.4f} over {len(history)} steps "
          f"({sum(h['sec'] for h in history):.0f}s)")


if __name__ == "__main__":
    main()
