import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys

from repro.configs import get_arch, SHAPES
from repro.core.sparsity import SparsityConfig
from repro.launch import dryrun as DR, hlo_cost, mesh as M

sp = SparsityConfig(n=2, m=8, method="bdwp")
mesh = M.make_production_mesh()
jobs = [("hymba-1.5b", "train_4k", dict(seq_parallel=True)),
        ("deepseek-v2-lite-16b", "train_4k", dict())]
for arch_id, shape_id, kw in jobs:
    comp = DR.lower_cell(get_arch(arch_id), SHAPES[shape_id], mesh, sp,
                         **kw).compile()
    bd = hlo_cost.breakdown(comp.as_text(), top=8)
    print(f"==== {arch_id} {shape_id} {kw} ====")
    print(f"totals: flops={bd['total_flops']:.3e} "
          f"bytes={bd['total_bytes']:.3e} coll={bd['total_coll']:.3e}")
    print("-- top coll --")
    for r in bd["top_coll"][:7]:
        print(f"{r['coll']:.2e} w={r['weight']:g} {r['kind']:14s} "
              f"{r['line'][:115]}")
    print("-- top bytes --")
    for r in bd["top_bytes"][:5]:
        print(f"{r['bytes']:.2e} w={r['weight']:g} {r['kind']:14s} "
              f"{r['line'][:115]}")
    sys.stdout.flush()
