"""repro.analysis — nmlint: the repo-wide N:M invariant auditor.

One blocking static-analysis layer instead of scattered runtime
asserts: AST rules over src/repro/ (ast_pass + the NM402/NM404 buffer
rules), jaxpr/HLO rules over the representative config matrix
(graph_audit) in three families — graph structure (NM2xx), dtype
provenance (NM3xx, dtype_flow), buffer/dispatch lifecycle (NM4xx,
buffer_audit) — a waiver file with expiry (findings), a deterministic
machine-readable report (report), and a self-test that seeds one
violation per rule (selftest).  CLI: tools/nmlint.py; rule narrative:
docs/analysis.md.
"""

from repro.analysis.ast_pass import run_ast_pass, scanned_file_count
from repro.analysis.buffer_audit import (
    check_dispatch_stable, check_donation_aliased, check_tree_buffers,
    count_output_aliases, expected_donation_matches, run_async_sync_pass,
)
from repro.analysis.dtype_flow import (
    audit_kernels, check_accum_dtype, check_master_mask_source,
    check_no_double_round, check_wire_narrow, propagate_tags, tag_inputs,
)
from repro.analysis.findings import (
    RULES, RULES_BY_ID, WAIVER_FILE, Finding, apply_waivers, load_waivers,
)
from repro.analysis.graph_audit import (
    ALL_FAMILIES, callback_census, check_callback_free,
    check_group_integrity, check_mask_once, check_no_dense_entry_params,
    check_recompile_stable, check_scatter_free, mask_census,
    pallas_call_census, packed_dense_shapes, prunable_sites,
    run_graph_audit, scatter_census, trace_once,
)
from repro.analysis.report import SCHEMA_VERSION, build_report, write_report
from repro.analysis.selftest import run_selftest

__all__ = [
    "RULES", "RULES_BY_ID", "WAIVER_FILE", "Finding", "apply_waivers",
    "load_waivers", "run_ast_pass", "scanned_file_count",
    "check_dispatch_stable", "check_donation_aliased",
    "check_tree_buffers", "count_output_aliases",
    "expected_donation_matches", "run_async_sync_pass",
    "audit_kernels", "check_accum_dtype", "check_master_mask_source",
    "check_no_double_round", "check_wire_narrow", "propagate_tags",
    "tag_inputs",
    "ALL_FAMILIES", "callback_census", "check_callback_free",
    "check_group_integrity", "check_mask_once",
    "check_no_dense_entry_params", "check_recompile_stable",
    "check_scatter_free", "mask_census", "pallas_call_census",
    "packed_dense_shapes", "prunable_sites", "run_graph_audit",
    "scatter_census", "trace_once", "SCHEMA_VERSION", "build_report",
    "write_report", "run_selftest",
]
