"""nmlint AST rules (NM101–NM104): source-level N:M invariants.

Scans every ``*.py`` under ``src/repro/`` (no execution, pure
``ast.parse``) for the four source-shape invariants the paper's
dataflow depends on.  See repro/analysis/findings.RULES for the rule
table and docs/analysis.md for the narrative.

Scope conventions:
  * module allowlists are repo-relative paths under src/repro/ — e.g.
    the SORE *producers* (kernels/, core/sparsity.py, optim/sgd.py)
    may scatter/unpack (vals, idx) because packing and WU-time
    unpacking is their job; every consumer must go through nm_apply.
  * tests/ and benchmarks/ are deliberately NOT scanned: exercising a
    deprecated shim or hand-unpacking in an A/B reference closure is
    what tests are for.
"""

from __future__ import annotations

import ast
import os
from typing import List, Optional, Sequence

from repro.analysis.findings import Finding

# NM101 — legacy entry points and the module that may define/call them
DEPRECATED_SHIMS = frozenset({
    "nm_linear", "nm_linear_pregen", "nm_conv", "nm_conv_pregen",
    "nm_linear_packed", "packed_shared_apply",
})
SHIM_HOME = "core/bdwp.py"

# NM102 — sanctioned (vals, idx) producers/definers; everyone else must
# consume packed operands through operand.nm_apply -> kernels/nm_spmm.
# optim/compress.py is the grad-sync wire codec: packing gradients for
# the pod link and unpacking on receive is its whole job.
UNPACK_ALLOWED = ("kernels/", "core/sparsity.py", "optim/sgd.py",
                  "optim/compress.py")
UNPACK_FNS = frozenset({"nm_unpack_n"})

# NM103 — predicates that return traced arrays under jit
TRACED_PREDS = frozenset({
    "any", "all", "isnan", "isfinite", "isinf", "allclose",
    "array_equal", "logical_and", "logical_or",
})
TRACED_BASES = frozenset({"jnp", "lax"})

# modules never scanned: the selftest intentionally embeds one violating
# example per rule — scanning the seeds would make the pass fail itself
SCAN_EXCLUDE = ("analysis/selftest.py",)


def _call_name(node: ast.Call) -> str:
    """Trailing identifier of the call target: f(...) -> 'f',
    mod.sub.f(...) -> 'f'."""
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return ""


def _base_name(node: ast.expr) -> str:
    """Leftmost identifier of an attribute chain ('jnp.any' -> 'jnp')."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else ""


def _is_traced_pred(node: ast.expr) -> Optional[ast.Call]:
    """First jnp/lax array-predicate call inside an if/while test."""
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Call)
                and isinstance(sub.func, ast.Attribute)
                and sub.func.attr in TRACED_PREDS
                and _base_name(sub.func) in TRACED_BASES):
            return sub
    return None


def _is_scatter_style(node: ast.Call) -> bool:
    """x.at[...].set(...) / .add(...), jnp.put_along_axis, lax.scatter*."""
    fn = node.func
    if isinstance(fn, ast.Attribute):
        if fn.attr == "put_along_axis":
            return True
        if fn.attr.startswith("scatter") and _base_name(fn) == "lax":
            return True
        if fn.attr in ("set", "add") and isinstance(fn.value, ast.Subscript):
            tgt = fn.value.value
            if isinstance(tgt, ast.Attribute) and tgt.attr == "at":
                return True
    return False


def _is_where(node: ast.Call) -> bool:
    fn = node.func
    return (isinstance(fn, ast.Attribute) and fn.attr == "where"
            and _base_name(fn) == "jnp")


def _scopes(tree: ast.Module):
    """(scope_node, body_statements) for the module and every function."""
    yield tree, tree.body
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node, node.body


def check_source(rel_path: str, source: str) -> List[Finding]:
    """All AST findings for one module (``rel_path`` is relative to the
    scan root, posix-style — e.g. ``core/operand.py``)."""
    try:
        tree = ast.parse(source, filename=rel_path)
    except SyntaxError as e:
        return [Finding("NM101", rel_path, e.lineno or 0,
                        f"unparseable module: {e.msg}")]
    findings: List[Finding] = []
    in_shim_home = rel_path == SHIM_HOME
    unpack_ok = rel_path.startswith(UNPACK_ALLOWED)

    # --- NM101 / NM104 / NM103: single walk over all nodes ---------------
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = _call_name(node)
            if name in DEPRECATED_SHIMS and not in_shim_home:
                findings.append(Finding(
                    "NM101", rel_path, node.lineno,
                    f"internal call to deprecated shim bdwp.{name}() — "
                    f"use operand.nm_apply"))
            if name in UNPACK_FNS and not unpack_ok:
                findings.append(Finding(
                    "NM102", rel_path, node.lineno,
                    f"{name}() scatter-unpacks a packed operand outside "
                    f"the sanctioned producers "
                    f"({', '.join(UNPACK_ALLOWED)})"))
            if name == "PackedOp":
                kwargs = {k.arg for k in node.keywords}
                if len(node.args) < 4 and "idx_bits" not in kwargs:
                    findings.append(Finding(
                        "NM104", rel_path, node.lineno,
                        "PackedOp(...) without explicit idx_bits — the "
                        "index plane width must be plumbed, not defaulted"))
            if name == "PregenOp":
                kwargs = {k.arg for k in node.keywords}
                if "vals" in kwargs and "idx_bits" not in kwargs:
                    findings.append(Finding(
                        "NM104", rel_path, node.lineno,
                        "packed PregenOp(vals=...) without explicit "
                        "idx_bits — the index plane width must be "
                        "plumbed, not defaulted"))
        elif isinstance(node, (ast.If, ast.While)):
            call = _is_traced_pred(node.test)
            if call is not None:
                findings.append(Finding(
                    "NM103", rel_path, node.lineno,
                    f"Python {type(node).__name__.lower()} branches on "
                    f"traced predicate "
                    f"{_base_name(call.func)}.{call.func.attr}(...) — "
                    f"device-unsafe under jit (use lax.cond / jnp.where)"))

    # --- NM402: donate + in_shardings without pinned out_shardings -------
    # (lives in buffer_audit with its NM4xx siblings; rides this walk so
    # the rule is on by default and --changed-only sees it)
    from repro.analysis.buffer_audit import check_tree_buffers
    findings.extend(check_tree_buffers(rel_path, tree))

    # --- NM102: scatter-style ops in scopes that bind both vals & idx ----
    if not unpack_ok:
        for scope, body in _scopes(tree):
            names = {n.id for stmt in body for n in ast.walk(stmt)
                     if isinstance(n, ast.Name)}
            if not {"vals", "idx"} <= names:
                continue
            for stmt in body:
                for sub in ast.walk(stmt):
                    if isinstance(sub, ast.Call) and (
                            _is_scatter_style(sub) or _is_where(sub)):
                        kind = ("jnp.where recombination"
                                if _is_where(sub) else "scatter-style op")
                        findings.append(Finding(
                            "NM102", rel_path, sub.lineno,
                            f"{kind} in a scope holding packed (vals, "
                            f"idx) — raw unpacking belongs to "
                            f"{', '.join(UNPACK_ALLOWED)}"))
    # the module scope's name-set contains every function's names, so a
    # function-level hit is seen twice — dedup by location
    seen, unique = set(), []
    for f in findings:
        key = (f.rule, f.path, f.line, f.message)
        if key not in seen:
            seen.add(key)
            unique.append(f)
    return unique


def run_ast_pass(root: Optional[str] = None,
                 files: Optional[Sequence[str]] = None) -> List[Finding]:
    """Scan ``root`` (default: the src/repro/ this module lives in) or an
    explicit file list.  Returns raw findings; the caller applies
    waivers."""
    if root is None:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    findings: List[Finding] = []
    if files is None:
        files = []
        for dirpath, _, names in sorted(os.walk(root)):
            for name in sorted(names):
                if name.endswith(".py"):
                    files.append(os.path.join(dirpath, name))
    for path in files:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        if rel in SCAN_EXCLUDE:
            continue
        with open(path) as f:
            findings.extend(check_source(rel, f.read()))
    return findings


def scanned_file_count(root: Optional[str] = None) -> int:
    if root is None:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    total = 0
    for dirpath, _, names in os.walk(root):
        for name in names:
            if not name.endswith(".py"):
                continue
            rel = os.path.relpath(os.path.join(dirpath, name),
                                  root).replace(os.sep, "/")
            total += rel not in SCAN_EXCLUDE
    return total
