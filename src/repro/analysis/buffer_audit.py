"""nmlint buffer/dispatch rules (NM401–NM404).

PR 9's crash class — a donated output whose sharding XLA chose freely,
then aliased against a differently-sharded donated input — was silent
until runtime.  This module makes the whole buffer-lifecycle family
static:

  NM401 ``check_donation_aliased`` — every donated input leaf that has
        a same-dtype/shape output to alias against must actually appear
        in the compiled executable's ``input_output_alias`` header.  A
        donation jax dropped (sharding/layout mismatch) silently
        doubles HBM for that buffer.
  NM402 ``check_tree_buffers`` — AST: ``jax.jit`` (or
        ``functools.partial(jax.jit, ...)``) called with
        ``donate_argnums`` AND ``in_shardings`` but NO
        ``out_shardings``.  On a multi-device mesh XLA then picks the
        output shardings freely and the donation alias can pair
        buffers of different per-device sizes — the exact PR 9 batcher
        crash, now a named rule.  Single-device jits (no in_shardings)
        are exempt: the batcher's solo ``_seat``/``_decode`` legitimately
        omit shardings.
  NM403 ``check_dispatch_stable`` — after a short REAL workload, every
        per-step-loop jit must hold ≤1 compile-cache entry
        (``_cache_size``).  NM206 covers the train step; this covers
        the serve dispatch loop (prefill/seat/decode) where a python
        scalar or static-arg churn retraces per request.
  NM404 ``run_async_sync_pass`` — AST call-graph over ``serve/``:
        host-sync points (``jax.device_get``, ``np.asarray``/``np.array``,
        ``.block_until_ready()``, ``.item()``) reachable from
        ``serve/fleet.py``'s async driver functions.  The engine must
        sync exactly once per step to route/finish, so the sanctioned
        harvest sites (``batcher.step``/``batcher.prefill``) are
        allowlisted; anything else stalls the event loop.
"""

from __future__ import annotations

import ast
import os
import re
from collections import Counter
from typing import Dict, List, Optional, Tuple

from repro.analysis.findings import Finding

# ---------------------------------------------------------------------------
# NM401 — donated buffers must alias
# ---------------------------------------------------------------------------

_ALIAS_MARK_RE = re.compile(r"(?:may|must)-alias")
_ENTRY_RESULT_RE = re.compile(r"^ENTRY[^\n]*->\s*(.*?)\s*\{\s*$", re.M)

_NP_TO_HLO = {
    "float64": "f64", "float32": "f32", "float16": "f16",
    "bfloat16": "bf16", "float8_e4m3fn": "f8e4m3fn",
    "float8_e5m2": "f8e5m2", "int64": "s64", "int32": "s32",
    "int16": "s16", "int8": "s8", "uint64": "u64", "uint32": "u32",
    "uint16": "u16", "uint8": "u8", "bool": "pred",
}


def count_output_aliases(hlo_text: str) -> int:
    """Entries in the module header's ``input_output_alias={...}`` —
    the donations jax successfully matched to outputs at lowering."""
    for line in hlo_text.splitlines():
        if "input_output_alias=" in line:
            return len(_ALIAS_MARK_RE.findall(line))
    return 0


def _hlo_leaves(tree) -> List[Tuple[str, tuple]]:
    import jax
    import numpy as np
    out = []
    for leaf in jax.tree.leaves(tree):
        dt = _NP_TO_HLO.get(np.dtype(leaf.dtype).name)
        if dt is not None:
            out.append((dt, tuple(leaf.shape)))
    return out


def expected_donation_matches(donated_tree, hlo_text: str) -> int:
    """How many donated leaves have a same-dtype/shape output leaf to
    alias against (multiset matching against the ENTRY result type).

    On a solo compile this is exact.  On an SPMD-partitioned module the
    ENTRY carries per-device local shapes while the donated tree is
    global, so this undercounts — a best-effort lower bound, which
    keeps the NM401 comparison (aliased >= expected) conservative."""
    from repro.launch.hlo_cost import _parse_shapes

    m = _ENTRY_RESULT_RE.search(hlo_text)
    if m is None:
        return 0
    outs = Counter(_parse_shapes(m.group(1)))
    matched = 0
    for leaf in _hlo_leaves(donated_tree):
        if outs[leaf] > 0:
            outs[leaf] -= 1
            matched += 1
    return matched


def check_donation_aliased(hlo_text: str, donated_tree, case: str,
                           label: str = "") -> Tuple[List[Finding], dict]:
    """NM401 as a finding-producer.  Returns (findings, {expected,
    aliased})."""
    expected = expected_donation_matches(donated_tree, hlo_text)
    actual = count_output_aliases(hlo_text)
    stats = {"donation_expected": expected, "donation_aliased": actual}
    if actual < expected:
        return [Finding(
            "NM401", case, 0,
            f"{label or 'compiled executable'}: only {actual} of "
            f"{expected} matchable donated buffers appear in "
            f"input_output_alias — the unmatched donations silently "
            f"double their HBM (sharding/layout mismatch at lowering)")], \
            stats
    return [], stats


# ---------------------------------------------------------------------------
# NM402 — donate + in_shardings requires pinned out_shardings (AST)
# ---------------------------------------------------------------------------


def _trailing_name(node: ast.expr) -> str:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _jit_kwargs(call: ast.Call) -> Optional[set]:
    """Keyword names of a ``jax.jit(...)`` or
    ``functools.partial(jax.jit, ...)`` call, else None."""
    name = _trailing_name(call.func)
    if name == "jit":
        return {k.arg for k in call.keywords if k.arg}
    if name == "partial" and call.args \
            and _trailing_name(call.args[0]) == "jit":
        return {k.arg for k in call.keywords if k.arg}
    return None


def check_tree_buffers(rel_path: str, tree: ast.Module) -> List[Finding]:
    """NM402 over one parsed module (called by ast_pass.check_source so
    the rule rides the ordinary AST scan and --changed-only)."""
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        kw = _jit_kwargs(node)
        if kw is None:
            continue
        donates = kw & {"donate_argnums", "donate_argnames"}
        if donates and "in_shardings" in kw and "out_shardings" not in kw:
            findings.append(Finding(
                "NM402", rel_path, node.lineno,
                "jit with donate_argnums and in_shardings but no "
                "out_shardings — XLA picks output shardings freely and "
                "the donation alias can pair differently-sharded "
                "buffers (PR 9 batcher crash class); pin out_shardings"))
    return findings


# ---------------------------------------------------------------------------
# NM403 — per-step-loop jits must not retrace
# ---------------------------------------------------------------------------


def check_dispatch_stable(named_jits: Dict[str, object], case: str,
                          run_fn=None) -> Tuple[List[Finding], dict]:
    """NM403: after ``run_fn`` drives a short real workload, every
    named per-step jit holds ≤ 1 compile-cache entry.  Returns
    (findings, {label: cache_size}); -1 entries when the jax build has
    no ``_cache_size`` (skipped, never failed)."""
    if run_fn is not None:
        run_fn()
    findings: List[Finding] = []
    sizes: Dict[str, int] = {}
    for label, jitted in named_jits.items():
        if not hasattr(jitted, "_cache_size"):
            sizes[label] = -1
            continue
        size = int(jitted._cache_size())
        sizes[label] = size
        if size > 1:
            findings.append(Finding(
                "NM403", case, 0,
                f"per-step-loop jit '{label}' holds {size} compile-cache "
                f"entries after a steady workload — something in its "
                f"call signature (python scalars, static args, weak "
                f"types, shapes) retraces inside the serving loop"))
    return findings, sizes


# ---------------------------------------------------------------------------
# NM404 — host syncs reachable from the async fleet driver (AST)
# ---------------------------------------------------------------------------

ASYNC_ROOT_FILE = "serve/fleet.py"
# sanctioned sync sites: the engine must harvest tokens to route/finish
# (np.asarray(nxt) in batcher.step is THE once-per-step sync point) and
# prefill ingests the host-side prompt list
SYNC_OK = frozenset({
    ("serve/batcher.py", "step"),
    ("serve/batcher.py", "prefill"),
})
_NP_BASES = frozenset({"np", "numpy", "onp"})


def _base_name(node: ast.expr) -> str:
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else ""


def _host_sync_kind(call: ast.Call) -> Optional[str]:
    fn = call.func
    name = _trailing_name(fn)
    if name == "device_get" and _base_name(fn) == "jax":
        return "jax.device_get"
    if name == "block_until_ready":
        return ".block_until_ready()"
    if name in ("asarray", "array") and _base_name(fn) in _NP_BASES:
        return f"np.{name}"
    if name == "item" and isinstance(fn, ast.Attribute) and not call.args:
        return ".item()"
    return None


def _serve_sources(root: Optional[str] = None) -> Dict[str, str]:
    if root is None:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    serve_dir = os.path.join(root, "serve")
    sources: Dict[str, str] = {}
    if not os.path.isdir(serve_dir):
        return sources
    for name in sorted(os.listdir(serve_dir)):
        if name.endswith(".py"):
            with open(os.path.join(serve_dir, name)) as f:
                sources[f"serve/{name}"] = f.read()
    return sources


def run_async_sync_pass(sources: Optional[Dict[str, str]] = None,
                        root: Optional[str] = None) -> List[Finding]:
    """NM404 over the serve package (or injected ``sources`` for the
    selftest): BFS the name-resolved call graph from serve/fleet.py's
    async defs; flag host-sync calls in any reachable, non-sanctioned
    function."""
    if sources is None:
        sources = _serve_sources(root)
    defs: Dict[str, List[tuple]] = {}
    roots: List[tuple] = []
    for rel, src in sources.items():
        try:
            tree = ast.parse(src, filename=rel)
        except SyntaxError:
            continue
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, []).append((rel, node))
                if rel == ASYNC_ROOT_FILE \
                        and isinstance(node, ast.AsyncFunctionDef):
                    roots.append((rel, node))

    queue, seen = list(roots), {id(n) for _, n in roots}
    reachable: List[tuple] = []
    while queue:
        rel, node = queue.pop()
        reachable.append((rel, node))
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            for target in defs.get(_trailing_name(sub.func), ()):
                if id(target[1]) not in seen:
                    seen.add(id(target[1]))
                    queue.append(target)

    findings: List[Finding] = []
    located = set()
    for rel, node in reachable:
        if (rel, node.name) in SYNC_OK:
            continue
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            kind = _host_sync_kind(sub)
            if kind is None or (rel, sub.lineno) in located:
                continue
            located.add((rel, sub.lineno))
            findings.append(Finding(
                "NM404", rel, sub.lineno,
                f"host sync {kind} in {node.name}(), reachable from the "
                f"async fleet driver — stalls the event loop outside "
                f"the sanctioned harvest sites "
                f"({', '.join(sorted(f'{p}:{n}' for p, n in SYNC_OK))})"))
    return findings
