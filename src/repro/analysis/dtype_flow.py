"""nmlint numerics rules (NM301–NM304): dtype-provenance dataflow.

The paper's pre-generation dataflow (Fig. 11c) is numerically correct
only if every N:M selection *scores the fp32 master* while compute runs
bf16 — SR-STE (arXiv 2102.04010) and the MVUE estimator (arXiv
2203.10991) are both statements about which precision the selection
sees.  That invariant has been violated and hot-fixed twice (PR 3 conv
masks scored a bf16 copy; PR 6 the EF residual saw wire-rounded
values), so this module makes it static: tag every input leaf of a
traced program with a provenance set and push a small lattice through
the jaxpr equations.

Input tags (``tag_inputs``):

  fp32_master   f32/f64 float leaf — master weights, momentum
  ef_state      f32 leaf whose path names the error-feedback residual
                ("err"): master-precision, but NOT master lineage — it
                exists to absorb wire rounding, so it must not taint
                the values it joins with ROUNDED
  bf16_compute  sub-32-bit float leaf — the compute tree / activations
  wire_u16      u16 leaf — the bitcast compressed-sync payload
  idx_plane     integer leaf whose tree path names an index plane

Derived tags (``propagate_tags``):

  rounded        a MASTER-lineage value passed through an f32→sub-f32
                 convert (plain forward intermediates rounding to bf16
                 is routine mixed precision and stays untagged)
  double_rounded a ``rounded`` value widened back to ≥ f32 — the
                 double-rounding fingerprint

Checks:

  NM301 ``check_master_mask_source`` — an N:M selection (top_k/sort,
        ``nm_selection_pred``-filtered so router top_k is exempt) whose
        operand is sub-f32 or ``rounded`` while an fp32 master input
        exists.  The selection must score the master, not a rounded
        shadow of it.
  NM302 ``check_no_double_round`` — an f32 master/momentum/EF *output*
        leaf carrying ``double_rounded`` provenance.  Structurally
        exempt on the gradsync cases: the compressed sync's EF residual
        intentionally absorbs the bf16 wire rounding
        (``err = g - decode(encode(g))`` IS the PR 6 fix, not the bug).
  NM303 ``check_accum_dtype`` / ``audit_kernels`` — dot_general
        accumulation below f32 on the kernel surfaces (nm_spmm,
        nm_spmm_shared, fused_update, grad_compress,
        grad_decompress_mean; both backends, pallas sub-jaxprs
        included).
  NM304 ``check_wire_narrow`` — a widening convert feeding a
        (pod-crossing) collective in optimized HLO: the XLA hoist that
        doubled wire bytes until PR 6 bitcast the payload to u16.
        With ``pod_block`` only pod-crossing collectives are audited —
        intra-pod f32 all-reduces ride the fast fabric and are
        legitimate.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding
from repro.launch.hlo_cost import MASK_PRIMS, _subjaxprs, nm_selection_pred

FP32_MASTER = "fp32_master"
EF_STATE = "ef_state"
BF16_COMPUTE = "bf16_compute"
WIRE_U16 = "wire_u16"
IDX_PLANE = "idx_plane"
ROUNDED = "rounded"
DOUBLE_ROUNDED = "double_rounded"

_EMPTY: frozenset = frozenset()
_FIXPOINT_ITERS = 8  # loop-carried tags converge fast (lattice is tiny)


# ---------------------------------------------------------------------------
# Input tagging
# ---------------------------------------------------------------------------


def _is_sub32_float(dtype) -> bool:
    import jax.numpy as jnp
    import numpy as np
    dt = np.dtype(dtype)
    return bool(jnp.issubdtype(dt, jnp.floating)) and dt.itemsize < 4


def _is_f32_plus(dtype) -> bool:
    import jax.numpy as jnp
    import numpy as np
    dt = np.dtype(dtype)
    return bool(jnp.issubdtype(dt, jnp.floating)) and dt.itemsize >= 4


def tag_inputs(*args) -> List[frozenset]:
    """Provenance tags for every flattened leaf of ``args``, in the
    order ``jax.make_jaxpr(fn)(*args)`` binds them as invars.

    Leaves may be arrays or ShapeDtypeStructs.  The rule is dtype-led —
    in the pregen dataflow every ≥f32 float input *is* master-lineage
    state (master/momentum/EF), while the compute tree is sub-f32 by
    construction — with the tree path consulted only to spot index
    planes.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    tags: List[frozenset] = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(args)[0]:
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        dt = np.dtype(leaf.dtype)
        t = set()
        if _is_f32_plus(dt):
            # the error-feedback residual is f32 *by design around* wire
            # rounding: it exists to absorb the encode/decode round-trip,
            # so it must not lend master lineage to the values it joins
            # (g + err before encode) or every compressed sync would
            # carry a false ROUNDED taint into the update
            t.add(EF_STATE if "err" in name else FP32_MASTER)
        elif _is_sub32_float(dt):
            t.add(BF16_COMPUTE)
        elif dt == np.dtype(np.uint16):
            t.add(WIRE_U16)
        elif jnp.issubdtype(dt, jnp.integer) and "idx" in name:
            t.add(IDX_PLANE)
        tags.append(frozenset(t))
    return tags


# ---------------------------------------------------------------------------
# Lattice propagation
# ---------------------------------------------------------------------------


def _n_invars(sub) -> int:
    return len(getattr(sub, "jaxpr", sub).invars)


def propagate_tags(jaxpr, in_tags: Sequence[frozenset],
                   visit: Optional[Callable] = None) -> List[frozenset]:
    """Push input tags through a (Closed)Jaxpr -> per-outvar tag sets.

    ``visit(eqn, in_tag_sets)`` is called for every equation, including
    ones inside sub-jaxprs (pjit/scan/while/cond/custom-vjp/pallas).
    Loop carries (scan/while) run to a fixpoint before the visited
    pass.  Sub-jaxprs whose invar count does not line up with the
    equation (pallas refs, custom-vjp consts) get the conservative
    union of all operand tags — over-approximate, never silent.
    """
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    env: Dict = {}

    def read(v) -> frozenset:
        if hasattr(v, "val"):  # Literal
            return _EMPTY
        return env.get(v, _EMPTY)

    for v, t in zip(inner.invars, in_tags):
        env[v] = frozenset(t)
    for v in inner.constvars:
        env[v] = _EMPTY

    for eqn in inner.eqns:
        in_sets = [read(v) for v in eqn.invars]
        base = frozenset().union(*in_sets) if in_sets else _EMPTY
        name = eqn.primitive.name

        if name == "convert_element_type" and eqn.invars:
            src = getattr(eqn.invars[0].aval, "dtype", None)
            dst = getattr(eqn.outvars[0].aval, "dtype", None)
            if src is not None and dst is not None:
                # rounding only taints MASTER-lineage values: a forward
                # f32 intermediate (RoPE tables, norm internals) cast to
                # bf16 is routine mixed precision, and tainting it would
                # smear ROUNDED through every cotangent via residuals
                if _is_f32_plus(src) and _is_sub32_float(dst) \
                        and FP32_MASTER in base:
                    base = base | {ROUNDED}
                elif _is_sub32_float(src) and _is_f32_plus(dst) \
                        and ROUNDED in base:
                    base = base | {DOUBLE_ROUNDED}

        if visit is not None:
            visit(eqn, in_sets)

        out_tags: List[frozenset]
        if name == "scan":
            sub = eqn.params["jaxpr"]
            nc = eqn.params.get("num_consts", 0)
            nk = eqn.params.get("num_carry", 0)
            cur = list(in_sets)
            for _ in range(_FIXPOINT_ITERS):
                outs = propagate_tags(sub, cur)
                new_carry = [cur[nc + i] | outs[i] for i in range(nk)]
                if new_carry == cur[nc:nc + nk]:
                    break
                cur[nc:nc + nk] = new_carry
            outs = propagate_tags(sub, cur, visit)
            out_tags = outs
        elif name == "while":
            cn = eqn.params.get("cond_nconsts", 0)
            bn = eqn.params.get("body_nconsts", 0)
            cond_j, body_j = eqn.params["cond_jaxpr"], eqn.params["body_jaxpr"]
            carry = list(in_sets[cn + bn:])
            for _ in range(_FIXPOINT_ITERS):
                outs = propagate_tags(body_j, in_sets[cn:cn + bn] + carry)
                new = [carry[i] | outs[i] for i in range(len(carry))]
                if new == carry:
                    break
                carry = new
            propagate_tags(cond_j, in_sets[:cn] + carry, visit)
            propagate_tags(body_j, in_sets[cn:cn + bn] + carry, visit)
            out_tags = carry
        elif name == "cond" and "branches" in eqn.params:
            branch_outs = [propagate_tags(b, in_sets[1:], visit)
                           for b in eqn.params["branches"]]
            out_tags = [frozenset().union(*(bo[i] for bo in branch_outs))
                        for i in range(len(eqn.outvars))] \
                if branch_outs else [base] * len(eqn.outvars)
        else:
            subs = [s for val in eqn.params.values() for s in _subjaxprs(val)]
            if subs:
                sub = subs[0]
                sub_in = (list(in_sets) if _n_invars(sub) == len(in_sets)
                          else [base] * _n_invars(sub))
                outs = propagate_tags(sub, sub_in, visit)
                for extra in subs[1:]:
                    propagate_tags(extra, [base] * _n_invars(extra), visit)
                if len(outs) == len(eqn.outvars):
                    out_tags = outs
                else:
                    spill = base | (frozenset().union(*outs) if outs
                                    else _EMPTY)
                    out_tags = [spill] * len(eqn.outvars)
            else:
                out_tags = [base] * len(eqn.outvars)

        for v, t in zip(eqn.outvars, out_tags):
            env[v] = t

    return [read(v) for v in inner.outvars]


def _trace(fn_or_jaxpr, args):
    import jax
    if hasattr(fn_or_jaxpr, "eqns") or hasattr(fn_or_jaxpr, "jaxpr"):
        return fn_or_jaxpr
    return jax.make_jaxpr(fn_or_jaxpr)(*args)


# ---------------------------------------------------------------------------
# NM301 — selection must score the fp32 master
# ---------------------------------------------------------------------------


def check_master_mask_source(fn_or_jaxpr, in_tags: Sequence[frozenset],
                             nm: Optional[Tuple[int, int]], case: str,
                             label: str = "",
                             args: tuple = ()) -> Tuple[List[Finding], int]:
    """NM301: no N:M selection may consume a sub-f32 or ``rounded``
    value while an fp32 master input exists.  Returns
    (findings, selections_inspected)."""
    jaxpr = _trace(fn_or_jaxpr, args)
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    if len(in_tags) != len(inner.invars):
        raise ValueError(
            f"{case}/{label}: {len(in_tags)} input tags for "
            f"{len(inner.invars)} jaxpr invars — tag_inputs must see the "
            f"same arg tree the trace saw")
    has_master = any(FP32_MASTER in t for t in in_tags)
    pred = nm_selection_pred(*nm) if nm is not None else None
    findings: List[Finding] = []
    seen = set()
    inspected = [0]

    def visit(eqn, in_sets):
        if eqn.primitive.name not in MASK_PRIMS:
            return
        if pred is not None and not pred(eqn):
            return
        inspected[0] += 1
        if not has_master or not in_sets:
            return
        dt = getattr(eqn.invars[0].aval, "dtype", None)
        tags = in_sets[0]
        if dt is not None and (_is_sub32_float(dt) or ROUNDED in tags):
            why = (f"a {dt} operand" if _is_sub32_float(dt)
                   else "an operand that passed through an f32→bf16 "
                        "rounding")
            msg = (f"{label or 'traced program'}: N:M selection "
                   f"({eqn.primitive.name}) scores {why} while an fp32 "
                   f"master input exists — SR-STE/MVUE selections must "
                   f"score the master (PR 3 conv-mask incident class)")
            if msg not in seen:
                seen.add(msg)
                findings.append(Finding("NM301", case, 0, msg))

    propagate_tags(jaxpr, in_tags, visit)
    return findings, inspected[0]


# ---------------------------------------------------------------------------
# NM302 — no double rounding into f32 state outputs
# ---------------------------------------------------------------------------

_STATE_OUT_MARKS = ("master", "momentum", "err")


def check_no_double_round(fn_or_jaxpr, in_tags: Sequence[frozenset],
                          out_paths: Sequence[str], case: str,
                          label: str = "",
                          args: tuple = ()) -> List[Finding]:
    """NM302: no f32 master/momentum/EF output leaf may carry
    ``double_rounded`` provenance (a value that went f32→bf16→f32 on
    its way into the optimizer update or EF residual).

    Callers must NOT run this on compressed-gradsync programs: the EF
    residual there intentionally absorbs the bf16 wire rounding — the
    double round-trip IS the PR 6 fix (``audit_gradsync_mesh8`` skips
    this check structurally and documents why).
    """
    jaxpr = _trace(fn_or_jaxpr, args)
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    if len(in_tags) != len(inner.invars):
        raise ValueError(
            f"{case}/{label}: {len(in_tags)} input tags for "
            f"{len(inner.invars)} jaxpr invars")
    if len(out_paths) != len(inner.outvars):
        raise ValueError(
            f"{case}/{label}: {len(out_paths)} output paths for "
            f"{len(inner.outvars)} jaxpr outvars")
    out_tags = propagate_tags(jaxpr, in_tags)
    findings: List[Finding] = []
    for path, var, tags in zip(out_paths, inner.outvars, out_tags):
        dt = getattr(var.aval, "dtype", None)
        if dt is None or not _is_f32_plus(dt):
            continue
        if not any(mark in path for mark in _STATE_OUT_MARKS):
            continue
        if DOUBLE_ROUNDED in tags:
            findings.append(Finding(
                "NM302", case, 0,
                f"{label or 'traced program'}: f32 state output "
                f"'{path}' carries double-rounded (f32→bf16→f32) "
                f"provenance — the update/EF path quantized a master "
                f"lineage value (PR 6 wire-rounding incident class)"))
    return findings


# ---------------------------------------------------------------------------
# NM303 — kernel accumulation dtype
# ---------------------------------------------------------------------------


def check_accum_dtype(fn_or_jaxpr, case: str, label: str = "",
                      args: tuple = ()) -> Tuple[List[Finding], int]:
    """NM303: every dot_general on a sub-f32 float operand must
    accumulate in ≥f32 (``preferred_element_type``), i.e. its output
    aval is ≥f32.  Descends into pallas_call sub-jaxprs.  Returns
    (findings, dot_sites_inspected)."""
    jaxpr = _trace(fn_or_jaxpr, args)
    findings: List[Finding] = []
    seen = set()
    inspected = [0]

    def walk(j):
        inner = getattr(j, "jaxpr", j)
        for eqn in inner.eqns:
            if eqn.primitive.name in ("dot_general", "dot"):
                inspected[0] += 1
                in_dts = [getattr(v.aval, "dtype", None) for v in eqn.invars]
                out_dt = getattr(eqn.outvars[0].aval, "dtype", None)
                if any(d is not None and _is_sub32_float(d)
                       for d in in_dts) \
                        and out_dt is not None \
                        and _is_sub32_float(out_dt):
                    msg = (f"{label or 'traced kernel'}: dot_general "
                           f"accumulates {in_dts[0]}×{in_dts[-1]} into "
                           f"{out_dt} — below-f32 accumulation on a "
                           f"kernel surface (set "
                           f"preferred_element_type=jnp.float32)")
                    if msg not in seen:
                        seen.add(msg)
                        findings.append(Finding("NM303", case, 0, msg))
            for val in eqn.params.values():
                for sub in _subjaxprs(val):
                    walk(sub)

    walk(jaxpr)
    return findings, inspected[0]


def audit_kernels(families=("numerics",)) -> Optional[Tuple[dict, list]]:
    """The ``kernels`` matrix case: NM303 over every packed-math kernel
    surface (nm_spmm, nm_spmm_shared, fused_update, grad_compress,
    grad_decompress_mean) on both backends.  Small traces only — no
    compilation, no execution beyond one tiny pack."""
    if "numerics" not in set(families):
        return None
    import jax
    import jax.numpy as jnp
    from functools import partial
    from repro.kernels import ops

    n, m = 2, 8
    act = jnp.ones((4, 16), jnp.bfloat16)
    vals = jnp.ones((4, 8), jnp.bfloat16)          # kc = 16//8*2 = 4
    idx = jnp.zeros((4, 8), jnp.uint8)
    w = jnp.ones((16, 8), jnp.bfloat16)
    g = jnp.ones((8, 16), jnp.float32)
    err = jnp.zeros((8, 16), jnp.float32)
    sh_vals, sh_rows = ops.pack_shared(w, n, m, tile=8)
    cv, ci, _ = jax.eval_shape(
        partial(ops.grad_compress, n=n, m=m, use_pallas=False), g, err)
    cva = jnp.zeros(cv.shape, cv.dtype)
    cia = jnp.zeros(ci.shape, ci.dtype)

    surfaces = []
    for pallas in (False, True):
        tag = "pallas" if pallas else "jnp"
        surfaces += [
            (f"nm_spmm[{tag}]",
             partial(ops.nm_spmm, n=n, m=m, use_pallas=pallas),
             (act, vals, idx)),
            (f"nm_spmm_shared[{tag}]",
             partial(ops.nm_spmm_shared, use_pallas=pallas),
             (act, sh_vals, sh_rows)),
            (f"fused_update[{tag}]",
             partial(ops.fused_update, n=n, m=m, use_pallas=pallas),
             (w.astype(jnp.float32).T, g, err, 0.1, 0.9, 0.0, 1e-4)),
            (f"grad_compress[{tag}]",
             partial(ops.grad_compress, n=n, m=m, use_pallas=pallas),
             (g, err)),
            (f"grad_decompress_mean[{tag}]",
             partial(ops.grad_decompress_mean, n=n, m=m,
                     use_pallas=pallas),
             (cva, cia)),
        ]

    findings: List[Finding] = []
    dots = {}
    for label, fn, fargs in surfaces:
        fs, n_dots = check_accum_dtype(fn, "kernels", label, args=fargs)
        findings.extend(fs)
        dots[label] = n_dots
    metrics = {"nm": f"{n}:{m}",
               "numerics": {"dot_sites": dots,
                            "subf32_accum_findings": len(findings)}}
    return metrics, findings


# ---------------------------------------------------------------------------
# NM304 — no widening convert feeding a (pod-crossing) collective
# ---------------------------------------------------------------------------

_WRAPPER_KINDS = ("bitcast", "copy", "reshape", "transpose")


def check_wire_narrow(hlo_text: str, case: str, label: str = "",
                      pod_block: Optional[int] = None
                      ) -> Tuple[List[Finding], int]:
    """NM304: in optimized HLO, no collective may consume the result of
    a *widening* convert (XLA hoisting the f32 upcast above the
    collective doubles the wire bytes — the hazard PR 6 closed by
    u16-bitcasting the payload).  With ``pod_block`` only pod-crossing
    collectives are audited: intra-pod f32 reductions are legitimate.
    Returns (findings, collectives_inspected)."""
    from repro.launch.hlo_cost import (
        _COLLECTIVES, _DTYPE_BYTES, _crosses_pod, parse_module,
    )

    comps = parse_module(hlo_text)
    findings: List[Finding] = []
    seen = set()
    inspected = 0

    def resolve(comp, name, depth=0):
        """Follow single-operand layout wrappers and fusion roots to the
        op that actually produced this value."""
        op = next((o for o in comp.ops if o.name == name), None)
        if op is None or depth > 4:
            return comp, op
        if op.kind in _WRAPPER_KINDS and op.operands:
            return resolve(comp, op.operands[0], depth + 1)
        if op.kind == "fusion":
            import re as _re
            mt = _re.search(r"calls=%?([\w.\-]+)", op.line)
            fused = comps.get(mt.group(1)) if mt else None
            root = fused.root_op() if fused else None
            if root is not None:
                return resolve(fused, root.name, depth + 1)
        return comp, op

    def widths(comp, op):
        from repro.launch.hlo_cost import _parse_shapes
        res = _parse_shapes(op.type_text)
        src = _parse_shapes(comp.table.get(op.operands[0], "")) \
            if op.operands else []
        return res, src

    for comp in comps.values():
        for op in comp.ops:
            base = op.kind.replace("-start", "")
            if base not in _COLLECTIVES:
                continue
            inspected += 1
            if pod_block and not _crosses_pod(op.line, pod_block):
                continue
            for operand in op.operands:
                src_comp, src = resolve(comp, operand)
                if src is None or src.kind != "convert":
                    continue
                res, srcs = widths(src_comp, src)
                if not res or not srcs:
                    continue
                (rd, rs), (sd, ss) = res[0], srcs[0]
                if _DTYPE_BYTES.get(rd, 0) > _DTYPE_BYTES.get(sd, 0):
                    msg = (f"{label or 'compiled module'}: {op.kind} "
                           f"consumes a widening convert {sd}→{rd} "
                           f"(shape {list(rs)}) — the upcast rode onto "
                           f"the wire; compress/bitcast before the "
                           f"collective (PR 6 wire-doubling hazard)")
                    if msg not in seen:
                        seen.add(msg)
                        findings.append(Finding("NM304", case, 0, msg))
    return findings, inspected
