"""nmlint rule registry, findings, and the waiver mechanism.

One Rule per N:M structural invariant the repo must keep.  AST rules
(NM1xx) fire on source text in src/repro/; graph rules (NM2xx) fire on
traced jaxprs / compiled optimized HLO of the representative config
matrix (repro/analysis/graph_audit); NM001 is the meta-rule for the
waiver file itself.  docs/analysis.md carries the human version of
this table (ID, invariant, paper section, how to waive) and is kept in
sync by tests/test_nmlint.py.
"""

from __future__ import annotations

import dataclasses
import datetime
import fnmatch
import json
import os
from typing import Dict, List, Optional

WAIVER_FILE = os.path.join("tools", "nmlint_waivers.json")


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    title: str
    kind: str        # "ast" | "graph" | "meta"
    invariant: str   # one sentence: what must hold
    paper: str       # paper section the invariant protects


RULES: List[Rule] = [
    Rule("NM001", "expired-waiver", "meta",
         "Every waiver in tools/nmlint_waivers.json carries an unexpired "
         "`expires` date; an expired waiver is itself a finding.",
         "—"),
    Rule("NM101", "deprecated-shim-call", "ast",
         "No module under src/repro/ calls a legacy bdwp entry-point shim "
         "(nm_linear, nm_linear_pregen, nm_conv, nm_conv_pregen, "
         "nm_linear_packed, packed_shared_apply) outside core/bdwp.py — "
         "all consumption goes through operand.nm_apply.",
         "Sec. V (unified sparse dataflow)"),
    Rule("NM102", "raw-vals-idx-unpack", "ast",
         "No scatter-style decompression of packed (vals, idx) operands "
         "— .at[].set/.add, jnp.put_along_axis, lax.scatter*, jnp.where "
         "recombination, or sparsity.nm_unpack_n — outside the sanctioned "
         "producers (kernels/, core/sparsity.py, optim/sgd.py, "
         "optim/compress.py).",
         "Sec. IV-B (SORE packed consumption)"),
    Rule("NM103", "traced-python-branch", "ast",
         "No Python `if`/`while` branches on a traced predicate "
         "(jnp.any/all/isnan/…): device-unsafe under jit, silently "
         "concretizes under eager.",
         "Sec. V (compiled dataflow)"),
    Rule("NM104", "idx-bits-unplumbed", "ast",
         "Every PackedOp(...) construction and every packed PregenOp "
         "(vals=...) construction states idx_bits explicitly — the u4 "
         "index plane (PR 7) must be an end-to-end decision, never an "
         "accidental default.",
         "Sec. IV-B (index plane width)"),
    Rule("NM201", "scatter-in-packed-path", "graph",
         "The traced packed train forward and the packed serve decode "
         "contain ZERO scatter primitives on every backend: packed "
         "(vals, idx) is consumed directly, never scattered to dense.",
         "Sec. IV-B / Fig. 11c"),
    Rule("NM202", "mask-census-drift", "graph",
         "The traced pregen train step performs exactly ONE N:M mask "
         "selection (top_k/sort) per prunable parameter — the fused "
         "FF+BP derivation at WU time.",
         "Fig. 11c (pre-generation dataflow)"),
    Rule("NM203", "dense-weight-in-packed-decode", "graph",
         "The compiled packed decode step's ENTRY parameters carry no "
         "dense-shaped weight matching a packed site's dense equivalent "
         "— the store must ship compact planes, not pre-decompressed "
         "weights.",
         "Sec. VI (serving HBM claim)"),
    Rule("NM204", "nm-group-split-sharding", "graph",
         "Every resolved NamedSharding keeps M-groups whole on grouped "
         "axes and keeps u4 index bytes (N/2-byte runs) whole on packed "
         "planes (sharding/rules.assert_nm_unsplit).",
         "Sec. III (BDWP group structure)"),
    Rule("NM205", "host-callback-in-step", "graph",
         "No host callbacks (pure_callback/io_callback/debug_callback) "
         "inside a traced train/decode step: a host round-trip in the "
         "hot path voids every dataflow timing claim.",
         "Sec. V (accelerator-resident training)"),
    Rule("NM206", "unstable-compile-cache", "graph",
         "Running the jitted train step over same-shaped batches adds no "
         "compilation cache entries after the first (recompile "
         "detector): the compiled-once contract behind all step-time "
         "claims.",
         "Sec. V (one compiled step)"),
    Rule("NM301", "selection-off-master", "graph",
         "No N:M selection (top_k/sort, nm-shape-filtered) in a traced "
         "train program consumes a sub-f32 or f32→bf16-rounded value "
         "while an fp32 master input exists — SR-STE and MVUE are "
         "statements about the precision the selection sees (the PR 3 "
         "conv-mask incident, now static).",
         "Sec. III (SR-STE scoring) / arXiv 2102.04010"),
    Rule("NM302", "double-rounded-state", "graph",
         "No f32 master/momentum/EF output leaf of a traced train step "
         "carries f32→bf16→f32 double-rounding provenance; the "
         "compressed-sync EF residual is the one sanctioned exception "
         "(the PR 6 wire-rounding incident, now static).",
         "Sec. V (fp32 master state) / arXiv 2203.10991"),
    Rule("NM303", "sub-f32-kernel-accum", "graph",
         "Every dot_general on the packed-math kernel surfaces "
         "(nm_spmm, nm_spmm_shared, fused_update, grad_compress, "
         "grad_decompress_mean; both backends, pallas sub-jaxprs "
         "included) with a sub-f32 operand accumulates in ≥f32 "
         "(preferred_element_type).",
         "Sec. IV (MXU accumulation)"),
    Rule("NM304", "widening-convert-on-wire", "graph",
         "No pod-crossing collective in optimized HLO consumes the "
         "result of a widening convert — XLA hoisting the f32 upcast "
         "above the collective doubles wire bytes (the hazard PR 6 "
         "closed by u16-bitcasting the compressed payload).",
         "Sec. VI (cross-pod wire bytes)"),
    Rule("NM401", "donation-not-aliased", "graph",
         "Every donated input leaf with a same-dtype/shape output to "
         "alias against appears in the compiled executable's "
         "input_output_alias — a donation jax silently dropped doubles "
         "that buffer's HBM.",
         "Sec. VI (HBM footprint)"),
    Rule("NM402", "donation-unpinned-out-shardings", "ast",
         "No jax.jit call combines donate_argnums with in_shardings "
         "unless out_shardings is also pinned — otherwise XLA picks "
         "output shardings freely and the donation alias can pair "
         "differently-sharded buffers (the PR 9 batcher crash class).",
         "Sec. VI (sharded serving)"),
    Rule("NM403", "retrace-in-serve-loop", "graph",
         "After a steady serve workload, every per-step-loop jit "
         "(prefill/seat/decode) holds at most one compile-cache entry — "
         "python-scalar or static-arg churn inside the dispatch loop "
         "retraces per request.",
         "Sec. V (one compiled step)"),
    Rule("NM404", "host-sync-in-async-driver", "ast",
         "No host-sync call (jax.device_get, np.asarray/np.array, "
         ".block_until_ready(), .item()) is reachable from "
         "serve/fleet.py's async driver functions outside the "
         "sanctioned once-per-step harvest sites in serve/batcher.py.",
         "Sec. VI (async serving throughput)"),
]

RULES_BY_ID: Dict[str, Rule] = {r.id: r for r in RULES}


@dataclasses.dataclass
class Finding:
    rule: str
    path: str        # repo-relative file, or graph-audit case name
    line: int        # 1-based source line; 0 for graph findings
    message: str
    waived: bool = False
    waiver_reason: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        tag = " (waived)" if self.waived else ""
        return f"[{self.rule}] {loc}: {self.message}{tag}"


# ---------------------------------------------------------------------------
# Waivers
# ---------------------------------------------------------------------------


def load_waivers(path: str, today: Optional[datetime.date] = None):
    """Read the waiver file -> (active_waivers, expired_findings).

    Schema: {"waivers": [{"rule": "NM102", "path": "src/repro/x.py",
    "reason": "...", "expires": "YYYY-MM-DD"}, ...]} — ``path`` is an
    fnmatch glob against the finding's repo-relative path.  A waiver
    whose ``expires`` has passed stops waiving AND files an NM001
    finding: waivers are temporary by construction.
    """
    today = today or datetime.date.today()
    if not os.path.exists(path):
        return [], []
    with open(path) as f:
        data = json.load(f)
    active, expired = [], []
    for w in data.get("waivers", []):
        try:
            expires = datetime.date.fromisoformat(w["expires"])
        except (KeyError, ValueError):
            expired.append(Finding(
                "NM001", os.path.relpath(path), 0,
                f"waiver for {w.get('rule')}:{w.get('path')} has a "
                f"missing/malformed `expires` date"))
            continue
        if expires < today:
            expired.append(Finding(
                "NM001", os.path.relpath(path), 0,
                f"waiver for {w.get('rule')}:{w.get('path')} expired "
                f"{w['expires']} ({w.get('reason', 'no reason')})"))
            continue
        active.append(w)
    return active, expired


def apply_waivers(findings: List[Finding], waivers: list) -> List[Finding]:
    """Mark findings matched by an active waiver (rule + path glob)."""
    for f in findings:
        for w in waivers:
            if w.get("rule") == f.rule and fnmatch.fnmatch(
                    f.path, w.get("path", "")):
                f.waived = True
                f.waiver_reason = w.get("reason", "")
                break
    return findings
