"""nmlint graph rules (NM2xx/NM3xx/NM4xx): jaxpr/HLO invariants of the
compiled programs, audited over a representative config matrix.

The matrix (one case per workload family the repo trains/serves):

  dense_lm        qwen3-8b smoke, 2:8 bdwp, pregen_pack=True — packed
                  train forward on both backends + recompile detector
  moe             granite-moe-1b smoke, 2:4 bdwp — bare-array expert
                  stacks, N:M-shape-filtered mask census
  conv            ResNet9, 2:8 bdwp pregen — conv mask derivation +
                  selection-free forward
  serve_u4        qwen3-8b smoke ServeEngine, element-packed u4 store —
                  compiled decode HLO entry params + scatter census +
                  donation aliasing + dispatch-cache stability
  kernels         the packed-math kernel surfaces (nm_spmm, fused
                  update, grad compress/decompress) on both backends —
                  accumulation-dtype audit (numerics family only)
  gradsync_mesh8  qwen3-8b smoke on the (pod, data, model) 8-device
                  mesh with N:M-compressed cross-pod sync (mesh8 only)

Rules are grouped into *families* — ``graph`` (NM2xx structure),
``numerics`` (NM3xx dtype provenance, repro/analysis/dtype_flow), and
``buffers`` (NM401/NM403 donation + dispatch, repro/analysis/
buffer_audit).  Each case traces its program ONCE (``trace_once``) and
compiles at most ONE executable, then shares those artifacts across
every family's checks, so wall-clock does not scale with rule count.
A case asked for no family it covers returns ``None`` and is skipped.

Every census helper here is THE implementation — benchmarks
(pregen_bench) and tests call these instead of keeping private copies,
so an invariant has exactly one definition.  HLO structure comes from
``launch/hlo_cost.parse_module``/``entry_param_shapes`` — extended,
not duplicated.
"""

from __future__ import annotations

from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding

SCATTER_PRIMS = ("scatter", "scatter-add")
CALLBACK_PRIMS = ("pure_callback", "io_callback", "debug_callback",
                  "callback")

GRAPH = "graph"
NUMERICS = "numerics"
BUFFERS = "buffers"
ALL_FAMILIES = (GRAPH, NUMERICS, BUFFERS)


# ---------------------------------------------------------------------------
# Shared-artifact helpers
# ---------------------------------------------------------------------------


def _structs(tree):
    import jax
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)


def trace_once(fn, *args):
    """Trace ``fn`` exactly once -> (ClosedJaxpr, output tree paths).

    The jaxpr feeds every census/provenance check for the case; the
    paths (one per flattened outvar, '/'-joined tree keys) let NM302
    name which state leaf an output is without a second trace.
    """
    import jax

    box = {}

    def wrapper(*a):
        out = fn(*a)
        box["treedef"] = jax.tree_util.tree_structure(out)
        return out

    jaxpr = jax.make_jaxpr(wrapper)(*args)
    n_out = len(jaxpr.jaxpr.outvars)
    skeleton = jax.tree_util.tree_unflatten(box["treedef"],
                                            list(range(n_out)))
    paths = [""] * n_out
    for path, leaf in jax.tree_util.tree_flatten_with_path(skeleton)[0]:
        paths[leaf] = "/".join(str(getattr(k, "key", k)) for k in path)
    return jaxpr, paths


# ---------------------------------------------------------------------------
# Census helpers — single source of truth (benchmarks import these)
# ---------------------------------------------------------------------------


def _is_jaxpr(fn) -> bool:
    return hasattr(fn, "eqns") or hasattr(fn, "jaxpr")


def mask_census(fn, *args, nm=None) -> int:
    """N:M mask selections (top_k/sort) in ``fn`` — a function to trace
    or an already-traced jaxpr (nm=(n, m) filters router top_k)."""
    from repro.launch.hlo_cost import (MASK_PRIMS, count_jaxpr_prims,
                                       count_mask_ops, nm_selection_pred)
    if _is_jaxpr(fn):
        pred = nm_selection_pred(*nm) if nm is not None else None
        return count_jaxpr_prims(fn, names=MASK_PRIMS, pred=pred)
    return count_mask_ops(fn, *args, nm=nm)


def scatter_census(fn, *args) -> int:
    """Scatter primitives in the traced ``fn`` (0 == packed operands are
    consumed directly, never decompressed)."""
    import jax
    from repro.launch.hlo_cost import count_jaxpr_prims
    jaxpr = fn if _is_jaxpr(fn) else jax.make_jaxpr(fn)(*args)
    return count_jaxpr_prims(jaxpr, names=SCATTER_PRIMS)


def callback_census(fn, *args) -> int:
    """Host callbacks in the traced ``fn`` (0 == hot path never leaves
    the device)."""
    import jax
    from repro.launch.hlo_cost import count_jaxpr_prims
    jaxpr = fn if _is_jaxpr(fn) else jax.make_jaxpr(fn)(*args)
    return count_jaxpr_prims(jaxpr, names=CALLBACK_PRIMS)


def pallas_call_census(fn, *args) -> int:
    """pallas_call invocations in the traced ``fn`` (== packed sites on
    the pallas backend)."""
    import jax
    from repro.launch.hlo_cost import count_jaxpr_prims
    jaxpr = fn if _is_jaxpr(fn) else jax.make_jaxpr(fn)(*args)
    return count_jaxpr_prims(jaxpr, names=("pallas_call",))


def prunable_sites(master, sp_cfg) -> List[str]:
    """Tree paths of every prunable parameter (``bdwp.pregen_site`` on
    the logical shape) — the denominator of the mask-once invariant."""
    import jax
    from repro.core import bdwp
    from repro.optim import sgd

    names = []
    for path, w in jax.tree_util.tree_flatten_with_path(master)[0]:
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        lshape, _ = sgd._logical_shape(name, w.shape)
        if bdwp.pregen_site(name, lshape, sp_cfg):
            names.append(name)
    return names


def packed_dense_shapes(params_tree) -> set:
    """Dense-equivalent shapes of every PackedOp leaf in a tree — what a
    packed decode must NOT materialize as an entry parameter."""
    import jax
    from repro.core import operand as O

    shapes = set()
    for leaf in jax.tree.leaves(
            params_tree, is_leaf=lambda x: isinstance(x, O.PackedOp)):
        if isinstance(leaf, O.PackedOp):
            v = leaf.vals.shape
            cfg = leaf.cfg
            shapes.add(v[:-2] + (v[-2] * cfg.m // cfg.n,) + v[-1:])
    return shapes


def check_scatter_free(fn, args, case: str, label: str = "",
                       allowed: int = 0) -> Tuple[List[Finding], int]:
    """NM201 as a finding-producer: the traced fn must contain no more
    than ``allowed`` scatter primitives (``allowed`` > 0 when the same
    program carries legitimate non-weight scatters, e.g. per-slot
    KV-cache writes — pass the dense-control census).  Returns
    (findings, census)."""
    n = scatter_census(fn, *args)
    if n > allowed:
        return [Finding(
            "NM201", case, 0,
            f"{label or 'traced packed path'} contains {n} scatter "
            f"op(s) (baseline {allowed}) — (vals, idx) is being "
            f"decompressed to dense")], n
    return [], n


def check_mask_once(fn, args, expected: int, nm, case: str,
                    label: str = "") -> Tuple[List[Finding], int]:
    """NM202 as a finding-producer: the traced fn must derive exactly
    ``expected`` N:M mask selections.  Returns (findings, census)."""
    n = mask_census(fn, *args, nm=nm)
    if n != expected:
        return [Finding(
            "NM202", case, 0,
            f"{label or 'traced step'} derives {n} N:M masks, expected "
            f"{expected} (one per prunable param)")], n
    return [], n


def check_callback_free(fn, args, case: str,
                        label: str = "") -> Tuple[List[Finding], int]:
    """NM205 as a finding-producer: zero host callbacks in the traced
    fn.  Returns (findings, census)."""
    n = callback_census(fn, *args)
    if n:
        return [Finding(
            "NM205", case, 0,
            f"{label or 'traced step'} traces {n} host callback(s) — "
            f"the hot path leaves the device")], n
    return [], n


def check_no_dense_entry_params(hlo_text: str, dense_shapes: set,
                                case: str) -> List[Finding]:
    """NM203: the compiled program's ENTRY parameters must not carry a
    weight-dtype array shaped like a packed site's dense equivalent."""
    from repro.launch.hlo_cost import entry_param_shapes

    weight_dtypes = {"bf16", "f16", "f32"}
    findings = []
    for pname, dtype, shape in entry_param_shapes(hlo_text):
        if dtype in weight_dtypes and tuple(shape) in dense_shapes:
            findings.append(Finding(
                "NM203", case, 0,
                f"entry parameter {pname} is a dense {dtype}{list(shape)}"
                f" weight matching a packed site's dense equivalent — "
                f"the store pre-decompressed outside the step"))
    return findings


def check_group_integrity(pspecs_tree, params_tree, mesh, sp_cfg,
                          case: str) -> List[Finding]:
    """NM204 as a finding-producer around rules.assert_nm_unsplit."""
    from repro.sharding import rules as R
    try:
        R.assert_nm_unsplit(pspecs_tree, params_tree, mesh, sp_cfg)
    except AssertionError as e:
        return [Finding("NM204", case, 0, str(e))]
    return []


def check_recompile_stable(jitted, case: str, runs: int = 2,
                           run_fn=None) -> Tuple[List[Finding], int]:
    """NM206: after ``runs`` same-shaped invocations (performed by
    ``run_fn``), the jit cache must hold exactly one entry.  Returns
    (findings, cache_size); cache_size -1 when the jax build exposes no
    ``_cache_size`` (check skipped, never failed)."""
    if not hasattr(jitted, "_cache_size"):
        return [], -1
    if run_fn is not None:
        run_fn()
    size = int(jitted._cache_size())
    if size > 1:
        return [Finding(
            "NM206", case, 0,
            f"compiled step cache holds {size} entries after {runs} "
            f"same-shaped steps — something in the step signature "
            f"(weak types, python scalars, donation) retriggers "
            f"compilation")], size
    return [], size


def _numerics_step_checks(step_jaxpr, step_args, out_paths, nm, case: str,
                          label: str, check_302: bool = True
                          ) -> Tuple[List[Finding], dict]:
    """NM301 (+ optionally NM302) over one already-traced train step —
    the shared numerics pass every train case runs on its cached
    jaxpr."""
    from repro.analysis import dtype_flow as DF

    in_tags = DF.tag_inputs(*step_args)
    findings, selections = DF.check_master_mask_source(
        step_jaxpr, in_tags, nm, case, label)
    stats = {"selections_inspected": selections,
             "double_round_checked": bool(check_302)}
    if check_302:
        findings.extend(DF.check_no_double_round(
            step_jaxpr, in_tags, out_paths, case, label))
    return findings, stats


# ---------------------------------------------------------------------------
# Config-matrix cases
# ---------------------------------------------------------------------------


def _lm_batch(batch, seq):
    import jax.numpy as jnp
    return {"tokens": jnp.zeros((batch, seq), jnp.int32),
            "labels": jnp.zeros((batch, seq), jnp.int32)}


def audit_dense_lm(families: Sequence[str] = (GRAPH,)
                   ) -> Optional[Tuple[dict, List[Finding]]]:
    """Dense-architecture LM (qwen3 smoke), 2:8 bdwp, packed pregen:
    mask-once, scatter-free packed forward (both backends), no host
    callbacks, stable compile cache over real steps; numerics: the
    selections score the fp32 master and no state output double-rounds.
    One step trace serves every family."""
    fam = set(families)
    if not fam & {GRAPH, NUMERICS}:
        return None
    import jax
    from repro.configs import get_arch
    from repro.core import operand as O
    from repro.core.sparsity import SparsityConfig
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer_lm as T
    from repro.optim import sgd
    from repro.train import step as ST

    cfg = get_arch("qwen3-8b").smoke
    sp = SparsityConfig(n=2, m=8, method="bdwp")
    opt = sgd.SGDConfig(lr=0.05, total_steps=100)
    mesh = make_host_mesh()
    # batch divides the data axis even when --mesh8 forced 8 devices
    batch, seq = max(2, int(dict(mesh.shape).get("data", 1))), 32

    state = ST.init_train_state(jax.random.PRNGKey(0), cfg, sp_cfg=sp,
                                pregen_pack=True)
    sites = prunable_sites(state["master"], sp)
    b0 = _lm_batch(batch, seq)
    bundle = ST.build_lm_train(cfg, mesh, sp, opt, donate=False,
                               pregen_pack=True)

    findings: List[Finding] = []
    step_args = (_structs(state), _structs(b0))
    step_jaxpr, out_paths = trace_once(bundle.step_fn, *step_args)
    metrics = {"arch": "qwen3-8b-smoke", "nm": f"{sp.n}:{sp.m}",
               "prunable_params": len(sites)}

    if GRAPH in fam:
        fs, masks = check_mask_once(step_jaxpr, (), len(sites),
                                    (sp.n, sp.m), "dense_lm",
                                    "pregen train step")
        findings.extend(fs)

        def forward_loss(backend):
            def fn(compute, b):
                with O.backend_scope(backend):
                    hidden, _, aux = T.forward(compute, b["tokens"], cfg,
                                               sp)
                    return T.lm_loss(compute, hidden, b["labels"], cfg) \
                        + 0.01 * aux
            return fn

        scatters = {}
        for backend in ("jnp", "pallas"):
            fwd_args = (_structs(state["compute"]), _structs(b0))
            fs, scatters[backend] = check_scatter_free(
                forward_loss(backend), fwd_args, "dense_lm",
                f"{backend}-backend packed train forward")
            findings.extend(fs)

        fs, callbacks = check_callback_free(step_jaxpr, (), "dense_lm",
                                            "train step")
        findings.extend(fs)

        # recompile detector: two REAL same-shaped steps, one cache entry
        state = jax.device_put(state, bundle.state_shardings)

        def run_two():
            nonlocal state
            for _ in range(2):
                state, metrics_ = bundle.step_fn(state, b0)
            jax.block_until_ready(metrics_["loss"])

        rc_findings, cache_size = check_recompile_stable(
            bundle.step_fn, "dense_lm", run_fn=run_two)
        findings.extend(rc_findings)
        metrics.update(mask_ops=masks, forward_scatter_ops=scatters,
                       host_callbacks=callbacks,
                       compile_cache_entries=cache_size)

    if NUMERICS in fam:
        fs, stats = _numerics_step_checks(
            step_jaxpr, step_args, out_paths, (sp.n, sp.m), "dense_lm",
            "pregen train step")
        findings.extend(fs)
        metrics["numerics"] = stats

    return metrics, findings


def audit_moe(families: Sequence[str] = (GRAPH,)
              ) -> Optional[Tuple[dict, List[Finding]]]:
    """MoE LM (granite smoke), 2:4 bdwp: mask-once over bare-array
    expert stacks with the N:M-shape-filtered census (the 8-expert
    router top_k must not be miscounted), no host callbacks; numerics:
    master-scored selections (router top_k exempt via the nm-shape
    filter) and no double-rounded state."""
    fam = set(families)
    if not fam & {GRAPH, NUMERICS}:
        return None
    import jax
    from repro.configs import get_arch
    from repro.core.sparsity import SparsityConfig
    from repro.launch.mesh import make_host_mesh
    from repro.optim import sgd
    from repro.train import step as ST

    cfg = get_arch("granite-moe-1b-a400m").smoke
    sp = SparsityConfig(n=2, m=4, method="bdwp")
    opt = sgd.SGDConfig(lr=0.05, total_steps=100)
    mesh = make_host_mesh()

    state = ST.init_train_state(jax.random.PRNGKey(0), cfg, sp_cfg=sp)
    sites = prunable_sites(state["master"], sp)
    b0 = _lm_batch(max(2, int(dict(mesh.shape).get("data", 1))), 32)
    bundle = ST.build_lm_train(cfg, mesh, sp, opt, donate=False,
                               pregen=True)

    findings: List[Finding] = []
    step_args = (_structs(state), _structs(b0))
    step_jaxpr, out_paths = trace_once(bundle.step_fn, *step_args)
    metrics = {"arch": "granite-moe-1b-smoke", "nm": f"{sp.n}:{sp.m}",
               "prunable_params": len(sites)}

    if GRAPH in fam:
        fs, masks = check_mask_once(step_jaxpr, (), len(sites),
                                    (sp.n, sp.m), "moe", "MoE pregen step")
        findings.extend(fs)
        fs, callbacks = check_callback_free(step_jaxpr, (), "moe",
                                            "MoE train step")
        findings.extend(fs)
        metrics.update(mask_ops=masks, host_callbacks=callbacks)

    if NUMERICS in fam:
        fs, stats = _numerics_step_checks(
            step_jaxpr, step_args, out_paths, (sp.n, sp.m), "moe",
            "MoE pregen step")
        findings.extend(fs)
        metrics["numerics"] = stats

    return metrics, findings


def audit_conv(families: Sequence[str] = (GRAPH,)
               ) -> Optional[Tuple[dict, List[Finding]]]:
    """Convnet (ResNet9), 2:8 bdwp pregen: the mask derivation pays one
    selection per prunable conv param, and the forward over the
    pre-generated tree re-derives none; numerics: the derivation scores
    the fp32 master (the PR 3 conv-mask incident surface)."""
    fam = set(families)
    if not fam & {GRAPH, NUMERICS}:
        return None
    import jax
    import jax.numpy as jnp
    from repro.core.sparsity import SparsityConfig
    from repro.models import convnets as C
    from repro.optim import sgd

    sp = SparsityConfig(n=2, m=8, method="bdwp")
    params = C.resnet9_init(jax.random.PRNGKey(0), num_classes=10,
                            width=32)
    sites = prunable_sites(params, sp)

    findings: List[Finding] = []
    derive = partial(sgd.pregen_tree, sp_cfg=sp)
    derive_args = (_structs(params),)
    derive_jaxpr, _ = trace_once(derive, *derive_args)

    compute = sgd.pregen_tree(params, sp)
    x = jax.ShapeDtypeStruct((2, 32, 32, 3), jnp.bfloat16)

    def fwd(tree, xx):
        return C.resnet9_apply(tree, xx, sp)

    fwd_args = (_structs(compute), x)
    fwd_jaxpr, _ = trace_once(fwd, *fwd_args)
    metrics = {"arch": "resnet9", "nm": f"{sp.n}:{sp.m}",
               "prunable_params": len(sites)}

    if GRAPH in fam:
        fs, masks = check_mask_once(derive_jaxpr, (), len(sites),
                                    (sp.n, sp.m), "conv",
                                    "conv pregen derivation")
        findings.extend(fs)
        fs, fwd_masks = check_mask_once(
            fwd_jaxpr, (), 0, (sp.n, sp.m), "conv",
            "conv forward over the pre-generated tree")
        findings.extend(fs)
        fs, callbacks = check_callback_free(fwd_jaxpr, (), "conv",
                                            "conv forward")
        findings.extend(fs)
        metrics.update(mask_ops=masks, forward_mask_ops=fwd_masks,
                       host_callbacks=callbacks)

    if NUMERICS in fam:
        from repro.analysis import dtype_flow as DF
        fs, selections = DF.check_master_mask_source(
            derive_jaxpr, DF.tag_inputs(*derive_args), (sp.n, sp.m),
            "conv", "conv pregen derivation")
        findings.extend(fs)
        metrics["numerics"] = {"selections_inspected": selections,
                               "double_round_checked": False}

    return metrics, findings


def audit_serve_u4(families: Sequence[str] = (GRAPH,)
                   ) -> Optional[Tuple[dict, List[Finding]]]:
    """Element-packed u4 serve decode (qwen3 smoke ServeEngine): zero
    scatters in the decode jaxpr beyond the dense control, no
    dense-shaped packed weight among the compiled step's ENTRY
    parameters, no host callbacks; buffers: the donated KV cache really
    aliases (NM401) and the prefill/seat/decode jits hold one cache
    entry after a real workload (NM403).  One decode trace + one
    compile serve every family."""
    fam = set(families)
    if not fam & {GRAPH, NUMERICS, BUFFERS}:
        return None
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.core.sparsity import SparsityConfig
    from repro.models import transformer_lm as T
    from repro.serve import ServeConfig, ServeEngine

    cfg = get_arch("qwen3-8b").smoke
    sp = SparsityConfig(n=2, m=8, method="bdwp")
    params, _ = T.init(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(lambda w: w.astype(jnp.bfloat16), params)
    geom = dict(n_slots=2, prompt_bucket=8, max_len=16)
    engine = ServeEngine(params, cfg, sp, ServeConfig(packed=True, **geom))

    findings: List[Finding] = []
    b = engine.batcher
    args = (b.params, b.kv.cache, b.tokens, b.positions)
    decode_jaxpr, _ = trace_once(b._decode, *args)
    metrics = {"arch": "qwen3-8b-smoke", "nm": f"{sp.n}:{sp.m}",
               "idx_bits": engine.store.idx_bits,
               "packed_sites": engine.store.n_packed}

    hlo = None
    if fam & {GRAPH, BUFFERS}:
        hlo = b._decode.lower(*args).compile().as_text()

    if GRAPH in fam:
        # dense-store control on the same geometry: the per-slot KV-cache
        # writes scatter legitimately, so "scatter-free packed path" means
        # packing adds ZERO scatters over the dense decode, not zero total
        dense = ServeEngine(params, cfg, sp,
                            ServeConfig(packed=False, **geom))
        db = dense.batcher
        dense_scatters = scatter_census(
            db._decode, db.params, db.kv.cache, db.tokens, db.positions)
        fs, scatters = check_scatter_free(
            decode_jaxpr, (), "serve_u4", "packed u4 decode step",
            allowed=dense_scatters)
        findings.extend(fs)
        fs, callbacks = check_callback_free(decode_jaxpr, (), "serve_u4",
                                            "decode step")
        findings.extend(fs)
        dense_shapes = packed_dense_shapes(engine.store.params)
        findings.extend(check_no_dense_entry_params(hlo, dense_shapes,
                                                    "serve_u4"))
        metrics.update(decode_scatter_ops=scatters,
                       decode_scatter_ops_dense_control=dense_scatters,
                       host_callbacks=callbacks,
                       dense_equiv_shapes_checked=len(dense_shapes))

    if NUMERICS in fam:
        # no fp32 master exists at serve time, so NM301 runs as a
        # structural negative: the pass must find nothing to flag
        from repro.analysis import dtype_flow as DF
        fs, selections = DF.check_master_mask_source(
            decode_jaxpr, DF.tag_inputs(*args), (sp.n, sp.m), "serve_u4",
            "packed u4 decode step")
        findings.extend(fs)
        metrics["numerics"] = {"selections_inspected": selections,
                               "double_round_checked": False}

    if BUFFERS in fam:
        from repro.analysis import buffer_audit as BA
        # the solo decode donates the KV cache (argnums=(1,)) — it must
        # really alias or decode HBM silently doubles
        fs, donation = BA.check_donation_aliased(
            hlo, b.kv.cache, "serve_u4", "packed u4 decode step")
        findings.extend(fs)

        def workload():
            engine.submit([1, 2, 3], max_new_tokens=3)
            engine.submit([4, 5, 6, 7], max_new_tokens=3)
            engine.run(max_steps=12)

        fs, cache_sizes = BA.check_dispatch_stable(
            {"prefill": b._prefill, "seat": b._seat, "decode": b._decode},
            "serve_u4", run_fn=workload)
        findings.extend(fs)
        metrics["buffers"] = dict(donation, dispatch_cache=cache_sizes)

    return metrics, findings


def audit_kernels(families: Sequence[str] = (GRAPH,)
                  ) -> Optional[Tuple[dict, List[Finding]]]:
    """The kernels case: NM303 accumulation-dtype audit over every
    packed-math kernel surface (see dtype_flow.audit_kernels)."""
    from repro.analysis import dtype_flow as DF
    return DF.audit_kernels(families)


def audit_gradsync_mesh8(families: Sequence[str] = (GRAPH,)
                         ) -> Optional[Tuple[dict, List[Finding]]]:
    """Compressed cross-pod gradient sync on the (pod, data, model)
    8-device mesh: group-safe shardings for the train state AND the
    element-packed u4 serve tree, scatter-free + callback-free
    compressed-sync step, mask-once under shard_map; numerics: NM301 on
    the step trace and NM304 on the compiled donated step (pod-crossing
    collectives only); buffers: NM401 on the same compiled step.

    NM302 is structurally EXEMPT here: the compressed sync's error-
    feedback residual ``err = g - decode(encode(g))`` intentionally
    round-trips f32→bf16→f32 — that double round IS the PR 6 fix, so
    running the double-round rule on this case would flag the cure as
    the disease.
    """
    fam = set(families)
    if not fam & {GRAPH, NUMERICS, BUFFERS}:
        return None
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.core.sparsity import SparsityConfig
    from repro.launch import spmd
    from repro.models import transformer_lm as T
    from repro.optim import sgd
    from repro.serve.packed_params import pack_tree_element
    from repro.sharding import rules as R
    from repro.train import step as ST

    if jax.device_count() < 8:
        raise RuntimeError(
            "gradsync_mesh8 needs 8 devices — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 or let "
            "tools/nmlint.py --mesh8 force them before backend init")

    cfg = get_arch("qwen3-8b").smoke
    sp = SparsityConfig(n=2, m=8, method="bdwp")
    opt = sgd.SGDConfig(lr=0.05, total_steps=100)
    mesh = spmd.make_spmd_mesh("pod,data,model")

    findings: List[Finding] = []
    # NM204 on the train state: build_lm_train runs assert_nm_unsplit
    # internally — surface a violation as a finding, not a crash.  The
    # bundle donates the state so the SAME compiled artifact serves the
    # NM304 wire audit and the NM401 donation audit.
    try:
        bundle = ST.build_lm_train(cfg, mesh, sp, opt, donate=True,
                                   compress=True)
    except AssertionError as e:
        return ({"arch": "qwen3-8b-smoke", "nm": f"{sp.n}:{sp.m}"},
                [Finding("NM204", "gradsync_mesh8", 0,
                         f"train-state sharding refused: {e}")])

    state = ST.init_train_state(jax.random.PRNGKey(0), cfg, compress=True,
                                sp_cfg=sp, mesh=mesh)
    b0 = _lm_batch(8, 32)
    sites = prunable_sites(state["master"], sp)
    step_args = (_structs(state), _structs(b0))
    step_jaxpr, _ = trace_once(bundle.step_fn, *step_args)
    metrics = {"arch": "qwen3-8b-smoke", "nm": f"{sp.n}:{sp.m}",
               "mesh": {k: int(v) for k, v in dict(mesh.shape).items()},
               "prunable_params": len(sites)}

    hlo = None
    if fam & {NUMERICS, BUFFERS}:
        hlo = bundle.step_fn.lower(*step_args).compile().as_text()

    if GRAPH in fam:
        fs, masks = check_mask_once(step_jaxpr, (), len(sites),
                                    (sp.n, sp.m), "gradsync_mesh8",
                                    "compressed-sync step")
        findings.extend(fs)
        fs, callbacks = check_callback_free(step_jaxpr, (),
                                            "gradsync_mesh8",
                                            "compressed-sync step")
        findings.extend(fs)

        # NM204 on the element-packed u4 serve tree, resolved on this mesh
        aparams, specs = T.init(jax.random.PRNGKey(0), cfg, abstract=True)
        p_pspecs = R.nm_params_pspecs(specs, R.SERVE_BATCH_RULES, aparams,
                                      mesh, sp)
        findings.extend(check_group_integrity(p_pspecs, aparams, mesh, sp,
                                              "gradsync_mesh8"))
        params, _ = T.init(jax.random.PRNGKey(0), cfg)
        params = jax.tree.map(lambda w: w.astype(jnp.bfloat16), params)
        packed, _, packed_pspecs = pack_tree_element(params, sp,
                                                     pspecs=p_pspecs,
                                                     idx_bits=4)
        findings.extend(check_group_integrity(packed_pspecs, packed, mesh,
                                              sp, "gradsync_mesh8"))
        metrics.update(mask_ops=masks, host_callbacks=callbacks)

    if NUMERICS in fam:
        from repro.analysis import dtype_flow as DF
        fs, selections = DF.check_master_mask_source(
            step_jaxpr, DF.tag_inputs(*step_args), (sp.n, sp.m),
            "gradsync_mesh8", "compressed-sync step")
        findings.extend(fs)
        # NM302 skipped: EF residual double-round is the PR 6 fix (see
        # docstring); NM304 audits only pod-crossing collectives —
        # intra-pod f32 reductions ride the fast fabric legitimately
        pod_block = int(jax.device_count()
                        // int(dict(mesh.shape).get("pod", 1)))
        fs, collectives = DF.check_wire_narrow(
            hlo, "gradsync_mesh8", "compiled compressed-sync step",
            pod_block=pod_block)
        findings.extend(fs)
        metrics["numerics"] = {"selections_inspected": selections,
                               "double_round_checked": False,
                               "collectives_inspected": collectives}

    if BUFFERS in fam:
        from repro.analysis import buffer_audit as BA
        fs, donation = BA.check_donation_aliased(
            hlo, _structs(state), "gradsync_mesh8",
            "donated compressed-sync step")
        findings.extend(fs)
        metrics["buffers"] = donation

    return metrics, findings


CASES = {
    "dense_lm": audit_dense_lm,
    "moe": audit_moe,
    "conv": audit_conv,
    "serve_u4": audit_serve_u4,
    "kernels": audit_kernels,
}
MESH8_CASES = {
    "gradsync_mesh8": audit_gradsync_mesh8,
}


def run_graph_audit(mesh8: bool = False,
                    cases: Optional[Dict] = None,
                    families: Sequence[str] = (GRAPH,)
                    ) -> Tuple[List[Finding], Dict[str, dict]]:
    """Run the config matrix -> (findings, per-case metrics).

    ``families`` selects which rule families each case runs (graph /
    numerics / buffers); a case that covers none of them returns None
    and is skipped entirely."""
    todo = dict(cases) if cases is not None else dict(CASES)
    if cases is None and mesh8:
        todo.update(MESH8_CASES)
    findings: List[Finding] = []
    metrics: Dict[str, dict] = {}
    for name, fn in todo.items():
        res = fn(families=families)
        if res is None:
            continue
        m, fs = res
        metrics[name] = m
        findings.extend(fs)
    return findings, metrics
