"""NMLINT.json report writer — machine-readable, schema-stable.

The committed ``results/NMLINT.json`` is deterministic by construction
(rule metadata, findings, and graph-audit *counts* only — no
wall-clock, no timestamps), so a regenerated report diffs empty when
the repo's invariants are intact.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.analysis.findings import RULES, Finding

# v2: adds ``families_run`` (which rule families the graph matrix
# executed: graph / numerics / buffers) and the NM3xx/NM4xx rules
SCHEMA_VERSION = 2


def build_report(findings: List[Finding],
                 graph_metrics: Optional[Dict[str, dict]] = None,
                 cases_run: Optional[List[str]] = None,
                 scanned_files: int = 0,
                 families_run: Optional[List[str]] = None) -> dict:
    by_rule = {r.id: 0 for r in RULES}
    waived = 0
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
        waived += f.waived
    return {
        "schema_version": SCHEMA_VERSION,
        "rules": {r.id: {"title": r.title, "kind": r.kind,
                         "invariant": r.invariant, "paper": r.paper}
                  for r in RULES},
        "findings": [f.to_json() for f in findings],
        "counts": {
            "total": len(findings),
            "unwaived": len(findings) - waived,
            "waived": waived,
            "by_rule": by_rule,
        },
        "scanned_files": scanned_files,
        "cases_run": sorted(cases_run or []),
        "families_run": sorted(families_run or []),
        "graph": graph_metrics or {},
    }


def write_report(report: dict, path: str) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=False)
        f.write("\n")
    return path
