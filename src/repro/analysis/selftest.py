"""nmlint self-test: seed one violation per rule, assert each fires.

The checkers are only trustworthy if a planted violation of every rule
actually produces a finding — a static-analysis pass that silently
stops matching is worse than none (green CI, rotten invariants).  Each
seed below routes through the SAME code path the real pass uses
(check_source for AST rules, the check_* producers for graph rules,
load_waivers for NM001), so a refactor that breaks detection breaks
this test.

Run via ``python tools/nmlint.py --selftest`` (wired into tier-1 by
tests/test_nmlint.py): exit 0 iff every rule fires on its seed.
"""

from __future__ import annotations

import datetime
import json
import os
import tempfile
from typing import Dict, List, Tuple

from repro.analysis import ast_pass
from repro.analysis.findings import Finding, load_waivers

# --- AST seeds: one minimal violating module per NM1xx rule --------------

_AST_SEEDS = {
    "NM101": (
        "models/seeded.py",
        "from repro.core import bdwp\n"
        "def f(x, w, cfg):\n"
        "    return bdwp.nm_linear(x, w, cfg)\n",
    ),
    "NM102": (
        "models/seeded.py",
        "import jax.numpy as jnp\n"
        "def unpack(vals, idx, k, f):\n"
        "    dense = jnp.zeros((k, f), vals.dtype)\n"
        "    return dense.at[idx].set(vals)\n",
    ),
    "NM103": (
        "train/seeded.py",
        "import jax.numpy as jnp\n"
        "def step(x):\n"
        "    if jnp.any(jnp.isnan(x)):\n"
        "        return x * 0\n"
        "    return x\n",
    ),
    "NM104": (
        "serve/seeded.py",
        "from repro.core import operand as O\n"
        "def make(vals, idx, cfg):\n"
        "    return O.PackedOp(vals, idx, cfg)\n",
    ),
    # the PR 9 batcher crash pattern: donated + input-sharded jit with
    # the output shardings left for XLA to choose
    "NM402": (
        "serve/seeded.py",
        "import jax\n"
        "def build(step, sh):\n"
        "    return jax.jit(step, in_shardings=(sh,),\n"
        "                   donate_argnums=(0,))\n",
    ),
}


def _seed_ast(rule: str) -> List[Finding]:
    rel, src = _AST_SEEDS[rule]
    return [f for f in ast_pass.check_source(rel, src) if f.rule == rule]


# --- graph seeds: violating programs through the real check_* producers --


def _seed_nm201() -> List[Finding]:
    import jax.numpy as jnp
    from repro.analysis.graph_audit import check_scatter_free

    def bad_unpack(vals, idx):
        dense = jnp.zeros((8, 4), vals.dtype)
        return dense.at[idx].set(vals)

    vals = jnp.ones((2, 4), jnp.bfloat16)
    idx = jnp.zeros((2,), jnp.int32)
    findings, _ = check_scatter_free(bad_unpack, (vals, idx), "selftest",
                                     "seeded scatter unpack")
    return findings


def _seed_nm202() -> List[Finding]:
    import jax.numpy as jnp
    from repro.analysis.graph_audit import check_mask_once
    from repro.core import sparsity as S

    def double_derive(w):
        m1 = S.nm_mask(w, 2, 8, axis=1)
        m2 = S.nm_mask(w * 2.0, 2, 8, axis=1)
        return jnp.where(m1 & m2, w, 0.0)

    w = jnp.ones((4, 16), jnp.float32)
    findings, _ = check_mask_once(double_derive, (w,), 1, (2, 8),
                                  "selftest", "seeded double derivation")
    return findings


def _seed_nm203() -> List[Finding]:
    from repro.analysis.graph_audit import check_no_dense_entry_params

    hlo = """HloModule seeded

ENTRY %main (p0: bf16[64,32], p1: u8[8,32]) -> bf16[64,32] {
  %p0 = bf16[64,32] parameter(0)
  %p1 = u8[8,32] parameter(1)
  ROOT %r = bf16[64,32] copy(%p0)
}
"""
    return check_no_dense_entry_params(hlo, {(64, 32)}, "selftest")


def _seed_nm204() -> List[Finding]:
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.analysis.graph_audit import check_group_integrity
    from repro.core.sparsity import SparsityConfig
    from repro.launch.mesh import make_host_mesh

    # a packed plane whose compact axis (6) is not a multiple of N (4):
    # its N-runs cannot be kept whole by ANY sharding — assert_nm_unsplit
    # must refuse it even on one device
    sp = SparsityConfig(n=4, m=8, method="bdwp")
    p_node = {"proj": {"vals": np.zeros((6, 8), np.float32),
                       "idx": np.zeros((6, 8), np.uint8)}}
    pspecs = {"proj": {"vals": P(None, None), "idx": P(None, None)}}
    return check_group_integrity(pspecs, p_node, make_host_mesh(), sp,
                                 "selftest")


def _seed_nm205() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from repro.analysis.graph_audit import check_callback_free

    def bad_step(x):
        y = jax.pure_callback(
            lambda a: a, jax.ShapeDtypeStruct(x.shape, x.dtype), x)
        return y * 2

    findings, _ = check_callback_free(bad_step, (jnp.ones((4,)),),
                                      "selftest", "seeded callback step")
    return findings


def _seed_nm206() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from repro.analysis.graph_audit import check_recompile_stable

    jitted = jax.jit(lambda x: x * 2)
    if not hasattr(jitted, "_cache_size"):
        # jax build without cache introspection: the real audit skips
        # the rule too, so the selftest cannot assert it — treat as fired
        return [Finding("NM206", "selftest", 0,
                        "skipped: no _cache_size on this jax build")]

    def churn():
        jitted(jnp.ones((4,)))
        jitted(jnp.ones((8,)))  # new shape -> second cache entry

    findings, _ = check_recompile_stable(jitted, "selftest", run_fn=churn)
    return findings


def _seed_nm001() -> List[Finding]:
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "waivers.json")
        with open(path, "w") as f:
            json.dump({"waivers": [{
                "rule": "NM102", "path": "src/repro/x.py",
                "reason": "seeded", "expires": "2020-01-01"}]}, f)
        _, expired = load_waivers(path, today=datetime.date(2026, 1, 1))
    return expired


def _seed_nm301() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from repro.analysis.dtype_flow import check_master_mask_source, tag_inputs

    def bad_select(w):
        # selection scores a bf16 shadow of the fp32 master
        _, i = jax.lax.top_k(w.astype(jnp.bfloat16), 2)
        return i

    w = jnp.ones((4, 8), jnp.float32)
    findings, _ = check_master_mask_source(
        bad_select, tag_inputs(w), (2, 8), "selftest",
        "seeded bf16-scored selection", args=(w,))
    return findings


def _seed_nm302() -> List[Finding]:
    import jax.numpy as jnp
    from repro.analysis.dtype_flow import check_no_double_round, tag_inputs

    def bad_update(w, g):
        # master-lineage gradient quantized f32->bf16->f32 on its way
        # into the master update
        return {"master": {
            "w": w - 0.1 * g.astype(jnp.bfloat16).astype(jnp.float32)}}

    w = jnp.ones((4, 8), jnp.float32)
    g = jnp.ones((4, 8), jnp.float32)
    return check_no_double_round(bad_update, tag_inputs(w, g),
                                 ["master/w"], "selftest",
                                 "seeded double-rounded update",
                                 args=(w, g))


def _seed_nm303() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from repro.analysis.dtype_flow import check_accum_dtype

    def bad_mm(a, b):
        return jax.lax.dot(a, b)  # no preferred_element_type: bf16 accum

    a = jnp.ones((4, 8), jnp.bfloat16)
    b = jnp.ones((8, 4), jnp.bfloat16)
    findings, _ = check_accum_dtype(bad_mm, "selftest",
                                    "seeded bf16-accum matmul",
                                    args=(a, b))
    return findings


def _seed_nm304() -> List[Finding]:
    from repro.analysis.dtype_flow import check_wire_narrow

    # widening convert feeding a POD-CROSSING all-reduce (groups pair
    # device i with i+4 across the pod boundary at pod_block=4)
    hlo = """HloModule seeded

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main (p0: bf16[64,32]) -> f32[64,32] {
  %p0 = bf16[64,32] parameter(0)
  %cvt = f32[64,32] convert(bf16[64,32] %p0)
  ROOT %ar = f32[64,32] all-reduce(f32[64,32] %cvt), replica_groups={{0,4},{1,5},{2,6},{3,7}}, to_apply=%add
}
"""
    findings, _ = check_wire_narrow(hlo, "selftest",
                                    "seeded hoisted upcast", pod_block=4)
    return findings


def _seed_nm401() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from repro.analysis.buffer_audit import check_donation_aliased

    # a REAL donated compile, with its input_output_alias header
    # stripped — exactly what a sharding/layout mismatch leaves behind
    x = jnp.ones((8, 8), jnp.float32)
    jitted = jax.jit(lambda a: a * 2.0, donate_argnums=(0,))
    hlo = jitted.lower(x).compile().as_text()
    stripped = "\n".join(line for line in hlo.splitlines()
                         if "input_output_alias" not in line)
    findings, _ = check_donation_aliased(stripped, x, "selftest",
                                         "seeded dropped donation")
    return findings


def _seed_nm403() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from repro.analysis.buffer_audit import check_dispatch_stable

    # static-arg churn: two values of a static python scalar = two cache
    # entries (plain float args are weak-typed and share one — that
    # shape-churn variant is NM206's seed)
    jitted = jax.jit(lambda a, s: a * s, static_argnums=(1,))
    if not hasattr(jitted, "_cache_size"):
        return [Finding("NM403", "selftest", 0,
                        "skipped: no _cache_size on this jax build")]
    x = jnp.ones((4,))

    def churn():
        jitted(x, 2)
        jitted(x, 3)

    findings, _ = check_dispatch_stable({"decode": jitted}, "selftest",
                                        run_fn=churn)
    return findings


def _seed_nm404() -> List[Finding]:
    from repro.analysis.buffer_audit import run_async_sync_pass

    # a sync two hops from the async driver, in a non-sanctioned helper
    sources = {
        "serve/fleet.py": ("async def _drive(self):\n"
                           "    self._emit()\n"),
        "serve/emit.py": ("import numpy as np\n"
                          "def _emit(self):\n"
                          "    return np.asarray(self.buf)\n"),
    }
    return run_async_sync_pass(sources=sources)


_GRAPH_SEEDS = {
    "NM201": _seed_nm201,
    "NM202": _seed_nm202,
    "NM203": _seed_nm203,
    "NM204": _seed_nm204,
    "NM205": _seed_nm205,
    "NM206": _seed_nm206,
    "NM301": _seed_nm301,
    "NM302": _seed_nm302,
    "NM303": _seed_nm303,
    "NM304": _seed_nm304,
    "NM401": _seed_nm401,
    "NM403": _seed_nm403,
    "NM404": _seed_nm404,
    "NM001": _seed_nm001,
}


def run_selftest() -> Tuple[bool, Dict[str, bool]]:
    """Seed every rule -> {rule: fired}; ok iff all fired."""
    fired: Dict[str, bool] = {}
    for rule in _AST_SEEDS:
        fired[rule] = bool(_seed_ast(rule))
    for rule, seed in _GRAPH_SEEDS.items():
        fired[rule] = any(f.rule == rule for f in seed())
    return all(fired.values()), fired
