"""Architecture registry: ``--arch <id>`` -> ArchSpec."""

from repro.configs import (
    deepseek_v2_lite,
    gemma3_12b,
    glm4_9b,
    granite_moe_1b,
    hymba_1_5b,
    internvl2_26b,
    mamba2_370m,
    qwen2_5_32b,
    qwen3_8b,
    whisper_large_v3,
)
from repro.configs.base import SHAPES, ArchSpec, Shape, lm_input_specs

ARCHS = {
    a.ARCH.arch_id: a.ARCH
    for a in (
        qwen3_8b, qwen2_5_32b, glm4_9b, gemma3_12b, whisper_large_v3,
        granite_moe_1b, deepseek_v2_lite, mamba2_370m, hymba_1_5b,
        internvl2_26b,
    )
}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def all_cells():
    """Every (arch, shape) pair — 40 cells; skips annotated, not dropped."""
    for arch_id, arch in ARCHS.items():
        for shape_id, shape in SHAPES.items():
            yield arch, shape


__all__ = ["ARCHS", "SHAPES", "ArchSpec", "Shape", "get_arch",
           "lm_input_specs", "all_cells"]
