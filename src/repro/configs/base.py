"""Config registry: architectures x input shapes (the 40 dry-run cells).

Each assigned architecture gets its own module exporting ``ARCH``; this
module defines the shared dataclasses, the shape table and the
``input_specs`` builders (ShapeDtypeStruct stand-ins — shardable, weak-
type-correct, zero allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.models.encdec import EncDecConfig
from repro.models.transformer_lm import LMConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    shape_id: str
    seq: int
    batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                  # "lm" | "encdec"
    kind: str                    # dense | moe | ssm | hybrid | vlm | audio
    full: Union[LMConfig, EncDecConfig]
    smoke: Union[LMConfig, EncDecConfig]
    source: str                  # provenance tag from the assignment
    sub_quadratic: bool = False  # may run long_500k
    prefix_len: int = 0          # stub-frontend prefix tokens (vlm/audio enc)

    def supports(self, shape_id: str) -> bool:
        if shape_id == "long_500k" and not self.sub_quadratic:
            return False  # pure full-attention arch: noted skip (DESIGN.md)
        return True

    def skip_reason(self, shape_id: str) -> str:
        if shape_id == "long_500k" and not self.sub_quadratic:
            return "pure full-attention arch; 500k decode requires sub-quadratic attention"
        return ""


def lm_input_specs(arch: ArchSpec, shape: Shape, smoke: bool = False):
    """ShapeDtypeStruct inputs for one (arch, shape) cell."""
    cfg = arch.smoke if smoke else arch.full
    b, s = shape.batch, shape.seq
    if smoke:
        b, s = 2, min(s, 64)
    i32 = jnp.int32
    if arch.family == "encdec":
        enc_t = 128 if smoke else cfg.max_source
        d = cfg.d_model
        if shape.kind == "train":
            return {
                "frames": jax.ShapeDtypeStruct((b, enc_t, d), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        if shape.kind == "prefill":
            return {
                "frames": jax.ShapeDtypeStruct((b, enc_t, d), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
            }
        # decode: enc output + dec cache + one token
        from repro.models import encdec as E

        cache = jax.eval_shape(lambda: E.init_cache(cfg, b, s))
        return {
            "enc_out": jax.ShapeDtypeStruct((b, enc_t, d), jnp.bfloat16),
            "cache": cache,
            "token": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
        }

    # LM family
    prefix = arch.prefix_len if not smoke else (8 if arch.prefix_len else 0)
    s_txt = s - prefix
    specs = {}
    if shape.kind == "train":
        if prefix:
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, prefix, cfg.d_model), jnp.bfloat16)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s_txt), i32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s_txt), i32)
        return specs
    if shape.kind == "prefill":
        if prefix:
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, prefix, cfg.d_model), jnp.bfloat16)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s_txt), i32)
        return specs
    # decode
    from repro.models import transformer_lm as T

    cache = jax.eval_shape(lambda: T.init_lm_cache(cfg, b, s))
    return {
        "cache": cache,
        "token": jax.ShapeDtypeStruct((b, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }
