"""deepseek-v2-lite-16b [moe]: 27L d=2048 16H (MLA kv_lora=512)
vocab=102400, MoE 64 routed experts (d_expert=1408) top-6 + 2 shared,
dense first layer (d_ff=10944).  [arXiv:2405.04434; hf]

Assignment note: the line reads "MoE 64e top-6" and "2 shared+160
routed"; the 160-routed figure belongs to full DeepSeek-V2 — V2-Lite is
64 routed + 2 shared (paper Table 1), which we use (DESIGN.md §5).
long_500k skipped: MLA is still full quadratic attention.
"""

from repro.configs.base import ArchSpec
from repro.models.moe import MoEConfig
from repro.models.transformer_lm import LMConfig

FULL = LMConfig(
    name="deepseek-v2-lite-16b", vocab=102400, d_model=2048, n_layers=27,
    n_heads=16, n_kv=16, head_dim=128, d_ff=0,
    kv_lora=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    first_dense_ff=10944,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
    tie_embed=True,
)

SMOKE = LMConfig(
    name="deepseek-v2-lite-smoke", vocab=512, d_model=64, n_layers=3,
    n_heads=4, n_kv=4, head_dim=16, d_ff=0,
    kv_lora=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    first_dense_ff=128,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=2),
    tie_embed=True,
)

ARCH = ArchSpec(
    arch_id="deepseek-v2-lite-16b", family="lm", kind="moe",
    full=FULL, smoke=SMOKE, source="arXiv:2405.04434; hf",
    sub_quadratic=False,
)
