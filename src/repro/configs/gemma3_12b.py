"""gemma3-12b [dense]: 48L d=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.

5:1 local:global sliding-window pattern, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

long_500k runs: 5/6 of layers are sliding-window (true O(S*W) banded
attention); the 1-in-6 global layers use distributed flash-decoding over
the sequence-sharded cache (DESIGN.md §4).
"""

from repro.configs.base import ArchSpec
from repro.models.transformer_lm import LMConfig

FULL = LMConfig(
    name="gemma3-12b", vocab=262144, d_model=3840, n_layers=48,
    n_heads=16, n_kv=8, head_dim=256, d_ff=15360,
    rope_theta=1e6, qk_norm=True,
    pattern=("swa", "swa", "swa", "swa", "swa", "attn"), window=1024,
    tie_embed=True,
)

SMOKE = LMConfig(
    name="gemma3-12b-smoke", vocab=512, d_model=64, n_layers=6,
    n_heads=4, n_kv=2, head_dim=16, d_ff=128, qk_norm=True,
    pattern=("swa", "swa", "swa", "swa", "swa", "attn"), window=16,
    tie_embed=True,
)

ARCH = ArchSpec(
    arch_id="gemma3-12b", family="lm", kind="dense", full=FULL, smoke=SMOKE,
    source="hf:google/gemma-3-1b-pt; unverified", sub_quadratic=True,
)
