"""glm4-9b [dense]: 40L d=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.

RoPE, GQA.  [hf:THUDM/glm-4-9b; hf]
"""

from repro.configs.base import ArchSpec
from repro.models.transformer_lm import LMConfig

FULL = LMConfig(
    name="glm4-9b", vocab=151552, d_model=4096, n_layers=40,
    n_heads=32, n_kv=2, head_dim=128, d_ff=13696,
    rope_theta=1e4, tie_embed=False,
)

SMOKE = LMConfig(
    name="glm4-9b-smoke", vocab=512, d_model=64, n_layers=2,
    n_heads=4, n_kv=1, head_dim=16, d_ff=128, tie_embed=False,
)

ARCH = ArchSpec(
    arch_id="glm4-9b", family="lm", kind="dense", full=FULL, smoke=SMOKE,
    source="hf:THUDM/glm-4-9b; hf", sub_quadratic=False,
)
