"""granite-moe-1b-a400m [moe]: 24L d=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""

from repro.configs.base import ArchSpec
from repro.models.moe import MoEConfig
from repro.models.transformer_lm import LMConfig

FULL = LMConfig(
    name="granite-moe-1b-a400m", vocab=49155, d_model=1024, n_layers=24,
    n_heads=16, n_kv=8, head_dim=64, d_ff=0,
    moe=MoEConfig(n_experts=32, top_k=8, d_expert=512),
    tie_embed=True,
)

SMOKE = LMConfig(
    name="granite-moe-1b-smoke", vocab=512, d_model=64, n_layers=2,
    n_heads=4, n_kv=2, head_dim=16, d_ff=0,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32),
    tie_embed=True,
)

ARCH = ArchSpec(
    arch_id="granite-moe-1b-a400m", family="lm", kind="moe",
    full=FULL, smoke=SMOKE,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    sub_quadratic=False,
)
