"""hymba-1.5b [hybrid]: 32L d=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16 — parallel attn+mamba heads.  [arXiv:2411.13676; hf]

Each layer runs attention and an SSM head in parallel on the same input
and mean-combines (models/transformer_lm kind="hybrid").  Attention is
sliding-window (Hymba uses SWA for all but 3 layers; we use SWA
everywhere + the SSM path provides global context) -> sub-quadratic,
long_500k runs.
"""

from repro.configs.base import ArchSpec
from repro.models.transformer_lm import LMConfig

FULL = LMConfig(
    name="hymba-1.5b", vocab=32001, d_model=1600, n_layers=32,
    n_heads=25, n_kv=5, head_dim=64, d_ff=5504,
    pattern=("hybrid",), window=1024,
    ssm_state=16, ssm_head_dim=64, ssm_chunk=128,
    tie_embed=True,
)

SMOKE = LMConfig(
    name="hymba-1.5b-smoke", vocab=512, d_model=64, n_layers=2,
    n_heads=4, n_kv=2, head_dim=16, d_ff=128,
    pattern=("hybrid",), window=16,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
    tie_embed=True,
)

ARCH = ArchSpec(
    arch_id="hymba-1.5b", family="lm", kind="hybrid", full=FULL, smoke=SMOKE,
    source="arXiv:2411.13676; hf", sub_quadratic=True,
)
