"""internvl2-26b [vlm]: 48L d=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.

InternViT + InternLM2 — per assignment the vision frontend is a STUB:
``input_specs()`` provides precomputed patch embeddings (B, 1024, d)
consumed as a prefix by the LM backbone.  [arXiv:2404.16821; hf]
long_500k skipped: full-attention backbone.
"""

from repro.configs.base import ArchSpec
from repro.models.transformer_lm import LMConfig

FULL = LMConfig(
    name="internvl2-26b", vocab=92553, d_model=6144, n_layers=48,
    n_heads=48, n_kv=8, head_dim=128, d_ff=16384,
    rope_theta=1e6, tie_embed=False,
)

SMOKE = LMConfig(
    name="internvl2-26b-smoke", vocab=512, d_model=64, n_layers=2,
    n_heads=4, n_kv=2, head_dim=16, d_ff=128, tie_embed=False,
)

ARCH = ArchSpec(
    arch_id="internvl2-26b", family="lm", kind="vlm", full=FULL, smoke=SMOKE,
    source="arXiv:2404.16821; hf", sub_quadratic=False, prefix_len=1024,
)
