"""mamba2-370m [ssm]: 48L d=1024 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

BDWP applies to in_proj/out_proj (~90% of FLOPs); the SSD scan itself has
no prunable weight contraction (DESIGN.md §5).  long_500k runs: O(1)
state decode.
"""

from repro.configs.base import ArchSpec
from repro.models.transformer_lm import LMConfig

FULL = LMConfig(
    name="mamba2-370m", vocab=50280, d_model=1024, n_layers=48,
    pattern=("mamba",), ssm_state=128, ssm_head_dim=64, ssm_chunk=128,
    tie_embed=True,
)

SMOKE = LMConfig(
    name="mamba2-370m-smoke", vocab=512, d_model=64, n_layers=2,
    pattern=("mamba",), ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
    tie_embed=True,
)

ARCH = ArchSpec(
    arch_id="mamba2-370m", family="lm", kind="ssm", full=FULL, smoke=SMOKE,
    source="arXiv:2405.21060; unverified", sub_quadratic=True,
)
