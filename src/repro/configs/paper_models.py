"""The paper's own five benchmark models (Table I) for the faithful
reproduction track: ResNet9, ViT, VGG19, ResNet18, ResNet50.

These drive the Table II FLOP accounting, the Fig. 4 loss-curve study
and the SAT cycle-model benchmarks (Fig. 15/16).  They are not part of
the 40 assigned dry-run cells.
"""

from __future__ import annotations

import dataclasses

from repro.models.convnets import ViTConfig


@dataclasses.dataclass(frozen=True)
class PaperModel:
    name: str
    dataset: str
    image: int
    num_classes: int
    epochs: int
    batch: int
    lr: float
    wd: float
    # Table II training/inference FLOPs for the dense baseline (x1e9 fwd)
    table2_infer_gflops_dense: float = 0.0


PAPER_MODELS = {
    "resnet9": PaperModel("resnet9", "cifar10", 32, 10, 150, 512, 0.5, 5e-4, 1.16),
    "vit": PaperModel("vit", "cifar100", 32, 100, 150, 512, 0.1, 5e-4, 0.643),
    "vgg19": PaperModel("vgg19", "cifar100", 32, 100, 150, 512, 0.1, 5e-4, 0.4),
    "resnet18": PaperModel("resnet18", "tinyimagenet", 64, 200, 88, 512, 0.05, 5e-3, 1.83),
    "resnet50": PaperModel("resnet50", "imagenet", 224, 1000, 120, 256, 0.1, 5e-5, 4.14),
}

VIT_PAPER = ViTConfig(image=32, patch=4, d_model=384, n_layers=7, n_heads=6,
                      d_ff=1536, num_classes=100)
