"""qwen2.5-32b [dense]: 64L d=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.

GQA, QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]
"""

from repro.configs.base import ArchSpec
from repro.models.transformer_lm import LMConfig

FULL = LMConfig(
    name="qwen2.5-32b", vocab=152064, d_model=5120, n_layers=64,
    n_heads=40, n_kv=8, head_dim=128, d_ff=27648,
    rope_theta=1e6, qkv_bias=True, tie_embed=False,
)

SMOKE = LMConfig(
    name="qwen2.5-32b-smoke", vocab=512, d_model=64, n_layers=2,
    n_heads=4, n_kv=2, head_dim=16, d_ff=160,
    rope_theta=1e6, qkv_bias=True, tie_embed=False,
)

ARCH = ArchSpec(
    arch_id="qwen2.5-32b", family="lm", kind="dense", full=FULL, smoke=SMOKE,
    source="hf:Qwen/Qwen2.5-0.5B; hf", sub_quadratic=False,
)
