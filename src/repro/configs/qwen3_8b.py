"""qwen3-8b [dense]: 36L d=4096 32H (GQA kv=8) d_ff=12288 vocab=151936.

qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]
"""

from repro.configs.base import ArchSpec
from repro.models.transformer_lm import LMConfig

FULL = LMConfig(
    name="qwen3-8b", vocab=151936, d_model=4096, n_layers=36,
    n_heads=32, n_kv=8, head_dim=128, d_ff=12288,
    rope_theta=1e6, qk_norm=True, tie_embed=False,
)

SMOKE = LMConfig(
    name="qwen3-8b-smoke", vocab=512, d_model=64, n_layers=2,
    n_heads=4, n_kv=2, head_dim=16, d_ff=128,
    rope_theta=1e6, qk_norm=True, tie_embed=False,
)

ARCH = ArchSpec(
    arch_id="qwen3-8b", family="lm", kind="dense", full=FULL, smoke=SMOKE,
    source="hf:Qwen/Qwen3-8B; hf", sub_quadratic=False,
)
