"""whisper-large-v3 [audio]: 32L d=1280 20H (kv=20) d_ff=5120 vocab=51866.

Encoder-decoder; conv/mel frontend is a STUB per assignment —
``input_specs()`` provides precomputed frame embeddings (B, 1500, d).
[arXiv:2212.04356; unverified]

long_500k skipped: full (self+cross) attention decoder.  Shapes are
applied to the decoder backbone (max_target stretched to the assigned
sequence lengths; the real model caps at 448 — noted in DESIGN.md).
"""

from repro.configs.base import ArchSpec
from repro.models.encdec import EncDecConfig

FULL = EncDecConfig(
    name="whisper-large-v3", vocab=51866, d_model=1280,
    n_layers=32, n_enc_layers=32, n_heads=20, n_kv=20, head_dim=64,
    d_ff=5120, max_source=1500, max_target=32768,
)

SMOKE = EncDecConfig(
    name="whisper-large-v3-smoke", vocab=512, d_model=64,
    n_layers=2, n_enc_layers=2, n_heads=4, n_kv=4, head_dim=16,
    d_ff=128, max_source=128, max_target=64,
)

ARCH = ArchSpec(
    arch_id="whisper-large-v3", family="encdec", kind="audio",
    full=FULL, smoke=SMOKE, source="arXiv:2212.04356; unverified",
    sub_quadratic=False,
)
