"""BDWP — Bidirectional Weight Pruning for N:M sparse training (Alg. 1).

The paper's training flow:

  FF : y  = x @ sparsify_{N:M}(W, axis=in)      # srste | bdwp
  BP : dx = g @ sparsify_{N:M}(W, axis=out)^T   # sdwp  | bdwp
       dx = sparsify_{N:M}(g, axis=out) @ W^T   # sdgp
  WU : dW = x^T @ g                             # always dense (paper)

Gradients reach the *dense master weights* by straight-through estimation;
SR-STE's sparse-refined decay term lam*(1-mask)*W is applied in the
optimizer (``optim/``; fused kernel in ``kernels/fused_update.py``).

The consumption semantics — in-op masking, pre-generated FF/BP operands
(Fig. 11c), packed ``(vals, idx)`` — live in ``core/operand.py`` as the
``SparseOperand`` algebra behind the single ``nm_apply`` entry point;
this module keeps the *policy* layer (per-parameter pruning eligibility,
decay/pre-generation site classification, shared-mode serving pack,
training-FLOP accounting) plus thin deprecation shims for the old
per-path entry points (``nm_linear``/``nm_conv``/``nm_linear_pregen``/
``nm_conv_pregen``/``nm_linear_packed``).
"""

from __future__ import annotations

import re
import warnings

import jax
import jax.numpy as jnp

from repro.core import operand as O
from repro.core.sparsity import SparsityConfig

# ---------------------------------------------------------------------------
# Deprecation shims — the pre-operand per-path entry points
# ---------------------------------------------------------------------------
#
# Every consumer now routes through operand.nm_apply; these wrappers keep
# external callers and the A/B reference closures in the test-suite
# working (same custom-VJP cores, so outputs and gradients are bitwise
# what they always were) while flagging the migration.


# warn-once ledger: a training loop calling a shim per-step must not
# spam one warning per call; tests reset via reset_deprecation_warnings
_warned: set = set()


def _deprecated(old: str, new: str) -> None:
    if old in _warned:
        return
    _warned.add(old)
    warnings.warn(f"bdwp.{old} is deprecated; use core.operand.{new}",
                  DeprecationWarning, stacklevel=3)


def reset_deprecation_warnings() -> None:
    """Forget which shims already warned (test isolation hook)."""
    _warned.clear()


def nm_linear(x: jax.Array, w: jax.Array, cfg: SparsityConfig) -> jax.Array:
    """DEPRECATED: ``nm_apply(MaskedOp(w, cfg), x)``."""
    _deprecated("nm_linear", "nm_apply(MaskedOp(w, cfg), x)")
    return O.nm_apply(O.MaskedOp(w, cfg), x)


def nm_linear_pregen(x: jax.Array, ff: jax.Array, bp: jax.Array) -> jax.Array:
    """DEPRECATED: ``nm_apply(PregenOp(ff=ff, bp=bp), x)``."""
    _deprecated("nm_linear_pregen", "nm_apply(PregenOp(ff=ff, bp=bp), x)")
    return O.pregen_linear(x, ff, bp)


def nm_conv(x, w, cfg: SparsityConfig, stride: int = 1,
            padding: str = "SAME"):
    """DEPRECATED: ``nm_apply(MaskedOp(w, cfg), x, stride=, padding=)``."""
    _deprecated("nm_conv", "nm_apply(MaskedOp(w, cfg), x, ...)")
    return O.masked_conv(x, w, cfg, stride, padding)


def nm_conv_pregen(x, ff, bp, stride: int = 1, padding: str = "SAME"):
    """DEPRECATED: ``nm_apply(PregenOp(ff=ff, bp=bp), x, stride=, ...)``."""
    _deprecated("nm_conv_pregen", "nm_apply(PregenOp(ff=ff, bp=bp), x, ...)")
    return O.pregen_conv(x, ff, bp, stride, padding)


def nm_linear_packed(x, vals, idx, cfg: SparsityConfig,
                     use_pallas: bool = False):
    """DEPRECATED: ``nm_apply(PackedOp(vals, idx, cfg), x, backend=)``."""
    _deprecated("nm_linear_packed", "nm_apply(PackedOp(vals, idx, cfg), x)")
    return O.nm_apply(O.PackedOp(vals, idx, cfg, idx_bits=8), x,
                      backend="pallas" if use_pallas else "jnp")


def is_pregen(leaf) -> bool:
    """True for a WU-time pre-generated operand — an ``operand.PregenOp``
    leaf (what optim/sgd emits) or the dict form older checkpoints /
    callers used."""
    if isinstance(leaf, O.PregenOp):
        return True
    return isinstance(leaf, dict) and "bp" in leaf and \
        ("ff" in leaf or "vals" in leaf)


def pregen_ff_operand(pg, cfg: SparsityConfig) -> jax.Array:
    """Resolve the dense-layout FF operand of a pre-generated leaf
    (PregenOp or legacy dict).  Packed leaves decompress with the shared
    select-based helper (kernels.decompress_nm) — exact (pack keeps
    values verbatim), scatter-free, and outside the custom VJP so the
    uint8 indices never need a cotangent.  The pallas backend of
    ``nm_apply`` skips this entirely and consumes the pair in VMEM."""
    from repro.kernels.nm_spmm_shared import decompress_nm

    if "vals" in pg:
        return decompress_nm(pg["vals"], pg["idx"], cfg.n, cfg.m, axis=-2)
    if "ff" not in pg:  # transposable: the one stored operand serves both
        return pg["bp"]
    return pg["ff"]


# ---------------------------------------------------------------------------
# Shared-mode packed serving (beyond-paper, MXU-native reduced-K)
# ---------------------------------------------------------------------------
#
# For serving, the FF weights are N:M sparse anyway (BDWP-trained).  In
# "shared" granularity one pattern covers every output column, so the
# contraction axis can be *pre-gathered* offline: w (K, F) becomes
# vals (K*N/M, F) + row indices (K*N/M,), and the forward is a dense
# matmul over the shortened K — M/N x fewer MXU FLOPs AND M/N x fewer
# weight bytes, both visible in lowered HLO (unlike element-mode, whose
# win lives inside the Pallas kernel's VMEM decompression).


def shared_ff_pack(w: jax.Array, cfg: SparsityConfig):
    """w (K, F) -> (vals (Kc, F), idx (Kc,)); pattern shared across F."""
    k = w.shape[0]
    score = jnp.abs(w).astype(jnp.float32).sum(1).reshape(k // cfg.m, cfg.m)
    _, top = jax.lax.top_k(score, cfg.n)
    top = jnp.sort(top, axis=-1)
    idx = (jnp.arange(k // cfg.m)[:, None] * cfg.m + top).reshape(-1)
    return jnp.take(w, idx, axis=0), idx.astype(jnp.int32)


def packed_shared_apply(p: dict, x: jax.Array) -> jax.Array:
    """y = gather(x, idx) @ vals  — the reduced-K serving matmul.

    DEPRECATED entry point: routes through
    ``nm_apply(SharedOp(vals, idx), x)`` (bias added here)."""
    _deprecated("packed_shared_apply", "nm_apply(SharedOp(vals, idx), x)")
    y = O.nm_apply(O.SharedOp(p["vals"], p["idx"]), x)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def serve_packable(name: str, lshape, cfg: SparsityConfig) -> bool:
    """FF-direction packing eligibility (serving reads only w_FF).

    lm_head is excluded to match training: the logits projection never
    routes through nm_linear (vocab head kept dense, like the paper's
    first-layer rule at the other end of the net)."""
    if cfg.is_dense or len(lshape) != 2:
        return False
    # k_up/v_up are consumed directly by the absorbed-matrix MLA decode
    for frag in (*cfg.excluded, "lm_head", "k_up", "v_up"):
        if re.search(frag, name):
            return False
    k = lshape[0]
    return k % cfg.m == 0 and k >= 2 * cfg.m


def pack_tree_shared(params, cfg: SparsityConfig, pspecs=None):
    """Transform a param tree for packed serving: every eligible
    {"w": (…, K, F)} leaf-dict becomes {"w": operand.SharedOp(vals,
    idx)(, "b")} — the bias and leaf-dict shape survive, only the
    weight leaf changes type (mirroring serve/packed_params'
    element-mode PackedOp).  Stacked (L, K, F) weights pack per layer
    (vmapped pattern selection).

    With ``pspecs`` given (a matching tree of PartitionSpecs), returns
    (packed_params, packed_pspecs) transformed consistently: vals keep
    w's spec, idx drops the feature axis.
    """
    from jax.sharding import PartitionSpec as P

    def name_of(path):
        return "/".join(str(getattr(k, "key", k)) for k in path)

    def walk(node, spec_node, path):
        if isinstance(node, dict) and "w" in node:
            w = node["w"]
            name = name_of(path)
            lshape = tuple(w.shape[-2:])
            if serve_packable(name, lshape, cfg):
                pack = lambda ww: shared_ff_pack(ww, cfg)  # noqa: E731
                for _ in range(w.ndim - 2):
                    pack = jax.vmap(pack)
                if isinstance(w, jax.ShapeDtypeStruct):
                    vals, idx = jax.eval_shape(pack, w)  # abstract tree
                else:
                    vals, idx = pack(w)
                new = {"w": O.SharedOp(vals, idx)}
                if "b" in node:
                    new["b"] = node["b"]
                if spec_node is None:
                    return new, None
                w_spec = spec_node["w"]
                idx_spec = P(*w_spec[:-1]) if len(w_spec) else P()
                new_spec = {"w": O.SharedOp(w_spec, idx_spec)}
                if "b" in node:
                    new_spec["b"] = spec_node["b"]
                return new, new_spec
            return node, spec_node
        if isinstance(node, dict):
            out_p, out_s = {}, {}
            for key, sub in node.items():
                sp = spec_node[key] if spec_node is not None else None
                out_p[key], s = walk(sub, sp, path + (key,))
                if spec_node is not None:
                    out_s[key] = s
            return out_p, (out_s if spec_node is not None else None)
        return node, spec_node

    packed, packed_specs = walk(params, pspecs, ())
    return (packed, packed_specs) if pspecs is not None else packed


# ---------------------------------------------------------------------------
# Pruning eligibility — the paper's layer-exclusion policy
# ---------------------------------------------------------------------------


def ff_group_axis(shape) -> int:
    """FF-pass N:M group axis (input features) for a weight of this rank.

    (K, F) -> 0; conv HWIO (H, W, I, O) -> 2; stacked-layer (L, K, F) and
    MoE (L, E, K, F) -> rank-2 (the contraction axis in both cases).
    """
    if len(shape) == 2:
        return 0
    if len(shape) == 3:
        return 1
    return len(shape) - 2


def bp_group_axis(shape) -> int:
    """BP-pass group axis (output features): always the last axis."""
    return len(shape) - 1


def should_prune(name: str, shape, cfg: SparsityConfig) -> bool:
    """Paper policy: prune all conv/linear weights except the first conv
    layer (accuracy-sensitive, few input channels); here extended with
    excluded-name fragments (embeddings, routers, norms, frontends) and a
    divisibility check on every axis the method groups along (BDWP needs
    both the FF/input and BP/output axes to tile into M-groups)."""
    if cfg.is_dense:
        return False
    if len(shape) < 2:
        return False
    for frag in cfg.excluded:
        if re.search(frag, name):
            return False
    axes = []
    if cfg.prunes_ff_weights():
        axes.append(ff_group_axis(shape))
    if cfg.prunes_bp_weights() or cfg.prunes_bp_grads():
        axes.append(bp_group_axis(shape))  # SDGP groups grads along F
    if not axes:
        axes.append(ff_group_axis(shape))
    return all(shape[a] % cfg.m == 0 and shape[a] >= 2 * cfg.m
               for a in axes)


def pick_cfg(name: str, shape, cfg: SparsityConfig) -> SparsityConfig:
    """Per-parameter effective config (dense fallback when excluded)."""
    from repro.core.sparsity import DENSE

    return cfg if should_prune(name, shape, cfg) else DENSE


# Weights that satisfy ``should_prune`` by name/shape but are consumed
# *directly* (never through nm_linear/nm_conv): the logits head is a raw
# transposed matmul in logits_from_hidden.  They must not be replaced by
# pre-generated operand dicts, and SR-STE must not decay them — decay
# targets weights the forward actually prunes.
_DIRECT_CONSUMED = ("lm_head",)

# Bare-array prunable leaves: weights stored directly as arrays rather
# than ``{"w": ...}`` leaf-dicts — the MoE expert stacks (E, K, F) and
# shared-expert mats of models/moe.  A basename may only be listed here
# if its forward consumer dispatches on ``is_pregen`` (moe._expert_ffn
# and the shared-expert path do, mirroring layers.dense_apply): the
# pregen traversal replaces exactly these leaves with operand dicts, so
# an unlisted bare weight can never be swapped out from under a consumer
# that still expects an array.  Note the FFN leaves of the same names
# are dict sites ("…/w_gate/w") and take the "/w" route instead.
_BARE_NM_BASENAMES = ("w_gate", "w_up", "w_down")


def bare_nm_leaf(name: str) -> bool:
    """Is this the tree name of a bare-array N:M-consumed weight leaf?"""
    return name.rsplit("/", 1)[-1] in _BARE_NM_BASENAMES


def decays(name: str, lshape, cfg: SparsityConfig) -> bool:
    """Should SR-STE's sparse-refined decay apply to this parameter?

    ``should_prune`` minus the directly-consumed weights: decaying a
    weight toward zero is only meaningful when FF/BP really mask it."""
    if any(re.search(frag, name) for frag in _DIRECT_CONSUMED):
        return False
    return should_prune(name, lshape, cfg)


def pregen_site(name: str, lshape, cfg: SparsityConfig, *,
                bare: bool = True) -> bool:
    """Is this master leaf replaced by a pre-generated operand dict?

    True for ``{"w": ...}`` leaf-dict weights (tree names end in "/w" —
    the models/layers convention routed through dense_apply / nm_conv)
    and for bare-array expert-stack leaves (``bare_nm_leaf`` — MoE
    w_gate/w_up/w_down, consumed through moe's is_pregen dispatch) that
    the method weight-prunes.  ``bare=False`` reproduces the earlier
    dict-sites-only structure in which bare leaves stayed legacy;
    train/step.restore_with_pregen uses it to recognize checkpoints
    written before MoE pre-generation.
    """
    if not (name.endswith("/w") or (bare and bare_nm_leaf(name))):
        return False
    if cfg.is_dense or not (cfg.prunes_ff_weights() or cfg.prunes_bp_weights()):
        return False
    return decays(name, lshape, cfg)


# ---------------------------------------------------------------------------
# Training-FLOP accounting (Table II's Train FLOPS column)
# ---------------------------------------------------------------------------


def train_macs_per_matmul(b: int, k: int, f: int, cfg: SparsityConfig) -> dict:
    """MACs of the three training matmuls for one (B,K)x(K,F) layer."""
    dense = b * k * f
    frac = cfg.keep_fraction if not cfg.is_dense else 1.0
    ff = dense * (frac if cfg.prunes_ff_weights() else 1.0)
    bp = dense * (frac if (cfg.prunes_bp_weights() or cfg.prunes_bp_grads()) else 1.0)
    wu = dense  # always dense in all five methods
    return {"ff": ff, "bp": bp, "wu": wu, "total": ff + bp + wu,
            "dense_total": 3 * dense}
