"""BDWP — Bidirectional Weight Pruning for N:M sparse training (Alg. 1).

The paper's training flow, as composable JAX ops with custom VJPs:

  FF : y  = x @ sparsify_{N:M}(W, axis=in)      # srste | bdwp
  BP : dx = g @ sparsify_{N:M}(W, axis=out)^T   # sdwp  | bdwp
       dx = sparsify_{N:M}(g, axis=out) @ W^T   # sdgp
  WU : dW = x^T @ g                             # always dense (paper)

Gradients reach the *dense master weights* by straight-through estimation;
SR-STE's sparse-refined decay term lam*(1-mask)*W is applied in the
optimizer (``optim/``; fused kernel in ``kernels/fused_update.py``).

Two consumption modes:
  * ``nm_linear`` / ``nm_conv`` — self-contained: each call re-derives
    its N:M mask from the weights it is given (score in fp32 of the
    GIVEN values; cast to the activation dtype only after masking, so
    callers holding fp32 master get fp32-scored masks).  The conv
    backward reuses XLA's conv transposes through ``jax.vjp`` closures,
    so dgrad runs with the BP-pruned weights and wgrad with dense
    weights — exactly Alg. 1.
  * ``nm_linear_pregen`` / ``nm_conv_pregen`` — the pre-generation
    dataflow (paper Fig. 11c): FF/BP consume the bf16 operands the
    optimizer wrote at WU time (optim/sgd.pregen_tree — masks derived
    ONCE per parameter per step from fp32 master, one fused top_k via
    sparsity.nm_mask_pair), with the dense straight-through WU gradient
    riding on the BP operand's cotangent.  The train-step builders use
    this mode by default.
"""

from __future__ import annotations

import re
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sparsity import SparsityConfig, sparsify

# ---------------------------------------------------------------------------
# Matmul view: x (..., K) @ w (K, F) -> (..., F)
# ---------------------------------------------------------------------------


def _ff_weights(w: jax.Array, cfg: SparsityConfig) -> jax.Array:
    """FF-pruned weights: N:M groups along the input (contraction) axis."""
    if cfg.prunes_ff_weights():
        return sparsify(w, cfg, axis=0, share_axis=1)
    return w


def _bp_weights(w: jax.Array, cfg: SparsityConfig) -> jax.Array:
    """BP-pruned weights: N:M groups along the output axis (dgrad contraction)."""
    if cfg.prunes_bp_weights():
        return sparsify(w, cfg, axis=1, share_axis=0)
    return w


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def nm_linear(x: jax.Array, w: jax.Array, cfg: SparsityConfig) -> jax.Array:
    """y = x @ w with the cfg.method's N:M sparse training semantics."""
    return jnp.matmul(x, _ff_weights(w, cfg).astype(x.dtype))


def _nm_linear_fwd(x, w, cfg):
    y = jnp.matmul(x, _ff_weights(w, cfg).astype(x.dtype))
    return y, (x, w)


def _nm_linear_bwd(cfg, res, g):
    x, w = res
    # AMP dataflow (paper Fig. 11): BP/WU arithmetic runs in the compute
    # dtype (bf16 here, FP16 on SAT); only the weight-gradient *result*
    # accumulates in fp32 for WUVE.  Casting the cotangent down — rather
    # than the weights up — keeps backward activations, remat recompute
    # and the TP collectives in 16-bit (2x traffic saving, and faithful).
    gc = g.astype(x.dtype)
    # BP: activation gradient with the backward-pruned operand
    if cfg.prunes_bp_grads():  # SDGP: prune the *output gradients* N:M
        g_bp = sparsify(gc, cfg, axis=-1)
        dx = jnp.matmul(g_bp, w.T.astype(gc.dtype))
    else:
        w_bp = _bp_weights(w, cfg)
        dx = jnp.matmul(gc, w_bp.T.astype(gc.dtype))
    # WU: weight gradient — dense (paper Alg. 1 line 9), straight-through;
    # fp32 accumulation via preferred_element_type (MXU-native)
    x2 = x.reshape(-1, x.shape[-1])
    g2 = gc.reshape(-1, gc.shape[-1])
    dw = jnp.matmul(x2.T, g2, preferred_element_type=jnp.float32)
    return dx.reshape(x.shape).astype(x.dtype), dw.astype(w.dtype)


nm_linear.defvjp(_nm_linear_fwd, _nm_linear_bwd)


# ---------------------------------------------------------------------------
# Pre-generation mode (Fig. 11c executed): FF/BP consume WU-time operands
# ---------------------------------------------------------------------------
#
# ``nm_linear`` re-derives the N:M masks with lax.top_k on every call —
# once in FF, once in BP, plus once more in the optimizer's SR-STE decay:
# three selections per prunable parameter per step, and the FF/BP ones
# are scored on *bf16-rounded* weights while the decay is scored on fp32
# master.  The pre-generation dataflow moves all of that to WU time: the
# optimizer computes the FF and BP masks ONCE from fp32 master (one fused
# top_k — core/sparsity.nm_mask_pair), prunes, casts and (where eligible)
# SORE-packs the bf16 operands, and the next step's FF/BP load them from
# the train state without any selection op.  ``nm_linear_pregen`` /
# ``nm_conv_pregen`` are those consumers; the dense WU gradient
# (straight-through, Alg. 1 line 9) rides on the BP operand's cotangent —
# always dense-shaped, even when the FF operand is packed.


@jax.custom_vjp
def nm_linear_pregen(x: jax.Array, ff: jax.Array, bp: jax.Array) -> jax.Array:
    """y = x @ ff with BP running against ``bp`` and a dense WU gradient.

    ff: FF operand written at WU time (N:M-pruned bf16 for srste/bdwp,
        dense bf16 for sdwp).
    bp: BP operand (pruned for sdwp/bdwp, dense for srste).  Its
        cotangent carries the dense straight-through weight gradient.
    """
    return jnp.matmul(x, ff.astype(x.dtype))


def _nm_linear_pregen_fwd(x, ff, bp):
    return jnp.matmul(x, ff.astype(x.dtype)), (x, ff, bp)


def _nm_linear_pregen_bwd(res, g):
    x, ff, bp = res
    # identical arithmetic to _nm_linear_bwd: bf16 cotangent, bf16 BP
    # matmul, fp32-accumulated dense WU gradient
    gc = g.astype(x.dtype)
    dx = jnp.matmul(gc, bp.T.astype(gc.dtype))
    x2 = x.reshape(-1, x.shape[-1])
    g2 = gc.reshape(-1, gc.shape[-1])
    dw = jnp.matmul(x2.T, g2, preferred_element_type=jnp.float32)
    return (dx.reshape(x.shape).astype(x.dtype), jnp.zeros_like(ff),
            dw.astype(bp.dtype))


nm_linear_pregen.defvjp(_nm_linear_pregen_fwd, _nm_linear_pregen_bwd)


def is_pregen(leaf) -> bool:
    """True for a WU-time pre-generated operand dict (optim/sgd emits
    these in place of a prunable weight array inside the compute tree)."""
    return isinstance(leaf, dict) and "bp" in leaf and \
        ("ff" in leaf or "vals" in leaf)


def pregen_ff_operand(pg: dict, cfg: SparsityConfig) -> jax.Array:
    """Resolve the dense-layout FF operand of a pre-generated leaf.

    Packed leaves ((vals, idx) along the contraction axis, ndim-2) are
    scattered back with ``nm_unpack_n`` — exact (pack keeps values
    verbatim), sort-free, and outside the custom VJP so the uint8
    indices never need a cotangent.  On TPU the Pallas serving kernel
    (kernels/nm_spmm) would consume the pair in VMEM instead.
    """
    from repro.core.sparsity import nm_unpack_n

    if "vals" in pg:
        return nm_unpack_n(pg["vals"], pg["idx"], cfg.n, cfg.m, axis=-2)
    return pg["ff"]


# ---------------------------------------------------------------------------
# Conv view (NHWC x HWIO -> NHWC) — the paper's CNN benchmarks
# ---------------------------------------------------------------------------

_CONV_IN_AXIS = 2   # HWIO: input-channel axis (FF grouping, Fig. 5a)
_CONV_OUT_AXIS = 3  # HWIO: output-channel axis (BP grouping, Fig. 5b)


def _conv(x, w, stride, padding):
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def nm_conv(x, w, cfg: SparsityConfig, stride: int = 1, padding: str = "SAME"):
    w_ff = sparsify(w, cfg, axis=_CONV_IN_AXIS, share_axis=_CONV_OUT_AXIS) \
        if cfg.prunes_ff_weights() else w
    return _conv(x, w_ff, stride, padding)


def _nm_conv_fwd(x, w, cfg, stride, padding):
    w_ff = sparsify(w, cfg, axis=_CONV_IN_AXIS, share_axis=_CONV_OUT_AXIS) \
        if cfg.prunes_ff_weights() else w
    return _conv(x, w_ff, stride, padding), (x, w)


def _nm_conv_bwd(cfg, stride, padding, res, g):
    x, w = res
    if cfg.prunes_bp_grads():
        g_eff = sparsify(g, cfg, axis=-1)  # N:M across output channels
        w_bp = w
    else:
        g_eff = g
        w_bp = sparsify(w, cfg, axis=_CONV_OUT_AXIS, share_axis=_CONV_IN_AXIS) \
            if cfg.prunes_bp_weights() else w
    # dgrad through a closure over the BP weights
    _, dgrad = jax.vjp(lambda xx: _conv(xx, w_bp, stride, padding), x)
    (dx,) = dgrad(g_eff.astype(x.dtype))
    # wgrad dense (straight-through to master weights)
    _, wgrad = jax.vjp(lambda ww: _conv(x, ww, stride, padding), w)
    (dw,) = wgrad(g.astype(x.dtype))
    return dx, dw.astype(w.dtype)


nm_conv.defvjp(_nm_conv_fwd, _nm_conv_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def nm_conv_pregen(x, ff, bp, stride: int = 1, padding: str = "SAME"):
    """Conv view of ``nm_linear_pregen``: FF convolves the WU-time FF
    operand, dgrad convolves ``bp``, wgrad is dense straight-through on
    the BP operand's cotangent."""
    return _conv(x, ff, stride, padding)


def _nm_conv_pregen_fwd(x, ff, bp, stride, padding):
    return _conv(x, ff, stride, padding), (x, ff, bp)


def _nm_conv_pregen_bwd(stride, padding, res, g):
    x, ff, bp = res
    _, dgrad = jax.vjp(lambda xx: _conv(xx, bp, stride, padding), x)
    (dx,) = dgrad(g.astype(x.dtype))
    _, wgrad = jax.vjp(lambda ww: _conv(x, ww, stride, padding), bp)
    (dw,) = wgrad(g.astype(x.dtype))
    return dx, jnp.zeros_like(ff), dw.astype(bp.dtype)


nm_conv_pregen.defvjp(_nm_conv_pregen_fwd, _nm_conv_pregen_bwd)


# ---------------------------------------------------------------------------
# Packed-forward (inference / pre-generated weights, Fig. 11c)
# ---------------------------------------------------------------------------


def nm_linear_packed(x, vals, idx, cfg: SparsityConfig, use_pallas: bool = False):
    """Forward-only matmul consuming SORE-packed weights.

    Used by the serving path: weights live in HBM in compact N:M form
    (N/M of dense bytes + indices); the Pallas kernel (kernels/nm_spmm)
    decompresses tile-by-tile in VMEM.  Routes through kernels/ops so
    TPU runs the kernel; the default oracle path keeps the lowered HLO
    clean for roofline accounting and is dry-run friendly.
    """
    from repro.kernels import ops  # local import to avoid cycles

    x2 = x.reshape(-1, x.shape[-1])
    y = ops.nm_spmm(x2, vals, idx, cfg.n, cfg.m, use_pallas=use_pallas)
    return y.reshape(*x.shape[:-1], vals.shape[-1]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Shared-mode packed serving (beyond-paper, MXU-native reduced-K)
# ---------------------------------------------------------------------------
#
# For serving, the FF weights are N:M sparse anyway (BDWP-trained).  In
# "shared" granularity one pattern covers every output column, so the
# contraction axis can be *pre-gathered* offline: w (K, F) becomes
# vals (K*N/M, F) + row indices (K*N/M,), and the forward is a dense
# matmul over the shortened K — M/N x fewer MXU FLOPs AND M/N x fewer
# weight bytes, both visible in lowered HLO (unlike element-mode, whose
# win lives inside the Pallas kernel's VMEM decompression).


def shared_ff_pack(w: jax.Array, cfg: SparsityConfig):
    """w (K, F) -> (vals (Kc, F), idx (Kc,)); pattern shared across F."""
    k = w.shape[0]
    score = jnp.abs(w).astype(jnp.float32).sum(1).reshape(k // cfg.m, cfg.m)
    _, top = jax.lax.top_k(score, cfg.n)
    top = jnp.sort(top, axis=-1)
    idx = (jnp.arange(k // cfg.m)[:, None] * cfg.m + top).reshape(-1)
    return jnp.take(w, idx, axis=0), idx.astype(jnp.int32)


def packed_shared_apply(p: dict, x: jax.Array) -> jax.Array:
    """y = gather(x, idx) @ vals  — the reduced-K serving matmul."""
    xg = jnp.take(x, p["idx"], axis=-1)
    y = jnp.matmul(xg, p["vals"].astype(xg.dtype))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def serve_packable(name: str, lshape, cfg: SparsityConfig) -> bool:
    """FF-direction packing eligibility (serving reads only w_FF).

    lm_head is excluded to match training: the logits projection never
    routes through nm_linear (vocab head kept dense, like the paper's
    first-layer rule at the other end of the net)."""
    if cfg.is_dense or len(lshape) != 2:
        return False
    # k_up/v_up are consumed directly by the absorbed-matrix MLA decode
    for frag in (*cfg.excluded, "lm_head", "k_up", "v_up"):
        if re.search(frag, name):
            return False
    k = lshape[0]
    return k % cfg.m == 0 and k >= 2 * cfg.m


def pack_tree_shared(params, cfg: SparsityConfig, pspecs=None):
    """Transform a param tree for packed serving: every eligible
    {"w": (…, K, F)} leaf-dict becomes {"vals", "idx"(, "b")}.  Stacked
    (L, K, F) weights pack per layer (vmapped pattern selection).

    With ``pspecs`` given (a matching tree of PartitionSpecs), returns
    (packed_params, packed_pspecs) transformed consistently: vals keep
    w's spec, idx drops the feature axis.
    """
    from jax.sharding import PartitionSpec as P

    def name_of(path):
        return "/".join(str(getattr(k, "key", k)) for k in path)

    def walk(node, spec_node, path):
        if isinstance(node, dict) and "w" in node:
            w = node["w"]
            name = name_of(path)
            lshape = tuple(w.shape[-2:])
            if serve_packable(name, lshape, cfg):
                pack = lambda ww: shared_ff_pack(ww, cfg)  # noqa: E731
                for _ in range(w.ndim - 2):
                    pack = jax.vmap(pack)
                if isinstance(w, jax.ShapeDtypeStruct):
                    vals, idx = jax.eval_shape(pack, w)  # abstract tree
                else:
                    vals, idx = pack(w)
                new = {"vals": vals, "idx": idx}
                if "b" in node:
                    new["b"] = node["b"]
                if spec_node is None:
                    return new, None
                w_spec = spec_node["w"]
                idx_spec = P(*w_spec[:-1]) if len(w_spec) else P()
                new_spec = {"vals": w_spec, "idx": idx_spec}
                if "b" in node:
                    new_spec["b"] = spec_node["b"]
                return new, new_spec
            return node, spec_node
        if isinstance(node, dict):
            out_p, out_s = {}, {}
            for key, sub in node.items():
                sp = spec_node[key] if spec_node is not None else None
                out_p[key], s = walk(sub, sp, path + (key,))
                if spec_node is not None:
                    out_s[key] = s
            return out_p, (out_s if spec_node is not None else None)
        return node, spec_node

    packed, packed_specs = walk(params, pspecs, ())
    return (packed, packed_specs) if pspecs is not None else packed


# ---------------------------------------------------------------------------
# Pruning eligibility — the paper's layer-exclusion policy
# ---------------------------------------------------------------------------


def ff_group_axis(shape) -> int:
    """FF-pass N:M group axis (input features) for a weight of this rank.

    (K, F) -> 0; conv HWIO (H, W, I, O) -> 2; stacked-layer (L, K, F) and
    MoE (L, E, K, F) -> rank-2 (the contraction axis in both cases).
    """
    if len(shape) == 2:
        return 0
    if len(shape) == 3:
        return 1
    return len(shape) - 2


def bp_group_axis(shape) -> int:
    """BP-pass group axis (output features): always the last axis."""
    return len(shape) - 1


def should_prune(name: str, shape, cfg: SparsityConfig) -> bool:
    """Paper policy: prune all conv/linear weights except the first conv
    layer (accuracy-sensitive, few input channels); here extended with
    excluded-name fragments (embeddings, routers, norms, frontends) and a
    divisibility check on every axis the method groups along (BDWP needs
    both the FF/input and BP/output axes to tile into M-groups)."""
    if cfg.is_dense:
        return False
    if len(shape) < 2:
        return False
    for frag in cfg.excluded:
        if re.search(frag, name):
            return False
    axes = []
    if cfg.prunes_ff_weights():
        axes.append(ff_group_axis(shape))
    if cfg.prunes_bp_weights() or cfg.prunes_bp_grads():
        axes.append(bp_group_axis(shape))  # SDGP groups grads along F
    if not axes:
        axes.append(ff_group_axis(shape))
    return all(shape[a] % cfg.m == 0 and shape[a] >= 2 * cfg.m
               for a in axes)


def pick_cfg(name: str, shape, cfg: SparsityConfig) -> SparsityConfig:
    """Per-parameter effective config (dense fallback when excluded)."""
    from repro.core.sparsity import DENSE

    return cfg if should_prune(name, shape, cfg) else DENSE


# Weights that satisfy ``should_prune`` by name/shape but are consumed
# *directly* (never through nm_linear/nm_conv): the logits head is a raw
# transposed matmul in logits_from_hidden.  They must not be replaced by
# pre-generated operand dicts, and SR-STE must not decay them — decay
# targets weights the forward actually prunes.
_DIRECT_CONSUMED = ("lm_head",)

# Bare-array prunable leaves: weights stored directly as arrays rather
# than ``{"w": ...}`` leaf-dicts — the MoE expert stacks (E, K, F) and
# shared-expert mats of models/moe.  A basename may only be listed here
# if its forward consumer dispatches on ``is_pregen`` (moe._expert_ffn
# and the shared-expert path do, mirroring layers.dense_apply): the
# pregen traversal replaces exactly these leaves with operand dicts, so
# an unlisted bare weight can never be swapped out from under a consumer
# that still expects an array.  Note the FFN leaves of the same names
# are dict sites ("…/w_gate/w") and take the "/w" route instead.
_BARE_NM_BASENAMES = ("w_gate", "w_up", "w_down")


def bare_nm_leaf(name: str) -> bool:
    """Is this the tree name of a bare-array N:M-consumed weight leaf?"""
    return name.rsplit("/", 1)[-1] in _BARE_NM_BASENAMES


def decays(name: str, lshape, cfg: SparsityConfig) -> bool:
    """Should SR-STE's sparse-refined decay apply to this parameter?

    ``should_prune`` minus the directly-consumed weights: decaying a
    weight toward zero is only meaningful when FF/BP really mask it."""
    if any(re.search(frag, name) for frag in _DIRECT_CONSUMED):
        return False
    return should_prune(name, lshape, cfg)


def pregen_site(name: str, lshape, cfg: SparsityConfig, *,
                bare: bool = True) -> bool:
    """Is this master leaf replaced by a pre-generated operand dict?

    True for ``{"w": ...}`` leaf-dict weights (tree names end in "/w" —
    the models/layers convention routed through dense_apply / nm_conv)
    and for bare-array expert-stack leaves (``bare_nm_leaf`` — MoE
    w_gate/w_up/w_down, consumed through moe's is_pregen dispatch) that
    the method weight-prunes.  ``bare=False`` reproduces the earlier
    dict-sites-only structure in which bare leaves stayed legacy;
    train/step.restore_with_pregen uses it to recognize checkpoints
    written before MoE pre-generation.
    """
    if not (name.endswith("/w") or (bare and bare_nm_leaf(name))):
        return False
    if cfg.is_dense or not (cfg.prunes_ff_weights() or cfg.prunes_bp_weights()):
        return False
    return decays(name, lshape, cfg)


# ---------------------------------------------------------------------------
# Training-FLOP accounting (Table II's Train FLOPS column)
# ---------------------------------------------------------------------------


def train_macs_per_matmul(b: int, k: int, f: int, cfg: SparsityConfig) -> dict:
    """MACs of the three training matmuls for one (B,K)x(K,F) layer."""
    dense = b * k * f
    frac = cfg.keep_fraction if not cfg.is_dense else 1.0
    ff = dense * (frac if cfg.prunes_ff_weights() else 1.0)
    bp = dense * (frac if (cfg.prunes_bp_weights() or cfg.prunes_bp_grads()) else 1.0)
    wu = dense  # always dense in all five methods
    return {"ff": ff, "bp": bp, "wu": wu, "total": ff + bp + wu,
            "dense_total": 3 * dense}
