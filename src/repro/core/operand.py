"""SparseOperand — the unified N:M weight-consumption algebra.

The paper's SAT accelerator wins because ONE datapath serves both dense
and N:M sparse operations (PAPER.md §IV); this module is that datapath's
software twin.  Every way the system consumes a (possibly N:M-sparse)
weight is one operand type, and every consumer calls one entry point:

    y = nm_apply(op, x, backend=...)

Operand variants (all registered pytrees — they live inside train-state
/ param trees, shard leaf-by-leaf, scan/vmap transparently, and
checkpoint as ordinary leaves):

  DenseOp(w)            dense weight — plain matmul/conv, AMP backward.
  MaskedOp(w, cfg)      legacy in-op masking: FF/BP N:M masks re-derived
                        from ``w`` on every call (bdwp Alg. 1; all five
                        methods incl. sdgp gradient pruning).
  PregenOp(ff|vals+idx, pre-generated WU-time operands (paper Fig. 11c,
           bp, mask,    written by optim/sgd): FF forward on the stored
           cfg,         sparse operand — packed ``(vals, idx)`` consumed
           idx_bits)    straight through ``kernels/nm_spmm`` on the
                        pallas backend, decompressed (select-based, no
                        scatter) on the jnp backend — BP backward on the
                        ``bp`` operand, and the dense straight-through
                        WU gradient riding the ``bp`` cotangent.
  PackedOp(vals, idx,   forward-only element-packed serving weight
           cfg,         (serve/packed_params): ``kernels/nm_spmm``
           idx_bits)    consumes the pair at ~N/M of dense HBM bytes.
  SharedOp(vals, idx)   shared-pattern reduced-K serving weight
                        (bdwp.pack_tree_shared): gather + short matmul.

``idx_bits`` (4 or 8, default 8) names the stored index-plane width on
the two packed operands: 8 = one uint8 in-group offset per kept value,
4 = two offsets per byte (``sparsity.pack_idx_u4`` layout, M <= 16 —
the serving default, worth an extra ~17% off packed HBM bytes at 2:8).
It rides the pytree *aux* (not a leaf), so jit caches key on the index
format and a u4 tree can never be silently consumed as u8.  Both widths
are bitwise interchangeable end-to-end — same matmul, same grads.

Backends: ``backend="auto"`` resolves through the ambient
``backend_scope`` (set by the train-step builders) and then the device —
"pallas" on TPU, "jnp" elsewhere.  The two backends are numerically
interchangeable (the CPU kernel path runs interpret-mode; the tests pin
them bitwise on the suite shapes); the pallas backend is where the
packed HBM saving lands in training wall-clock, because the packed FF
operand never materializes densely outside VMEM.

The custom-VJP rules (FF forward on the sparse operand, BP backward on
the bp operand, dense straight-through WU cotangent) were previously
re-implemented per consumption path in ``core/bdwp.py``; they live here
now, once.  ``bdwp.nm_linear`` / ``nm_conv`` / ``nm_linear_pregen`` /
``nm_conv_pregen`` / ``nm_linear_packed`` remain as deprecation shims.
"""

from __future__ import annotations

import contextlib
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsity import SparsityConfig, sparsify

__all__ = [
    "SparseOperand", "DenseOp", "MaskedOp", "PregenOp", "PackedOp",
    "SharedOp", "as_operand", "nm_apply", "backend_scope",
    "resolve_backend",
]


# ---------------------------------------------------------------------------
# Operand pytrees
# ---------------------------------------------------------------------------
#
# Children are registered in a FIXED alphabetical field order.  This is
# load-bearing for checkpoint forward-compatibility: the PR-3/PR-4-era
# compute trees stored pre-generated operands as plain dicts, which jax
# flattens in sorted-key order — a PregenOp flattens to the same leaf
# sequence, so dict-leaf checkpoints restore leaf-for-leaf (bitwise)
# into operand-typed state with no conversion pass.


class SparseOperand:
    """Base class: field storage + dict-like access (migration aid).

    The dict accessors (``op["bp"]``, ``"vals" in op``, ``op.get``,
    iteration over field names) exist so code and tests written against
    the operand-dict era keep working verbatim; new code should use the
    attributes."""

    _FIELDS: tuple = ()          # class-level ordered field names
    fields: tuple = ()           # instance-level present fields

    # -- dict-like migration accessors -----------------------------------
    def __getitem__(self, key):
        if key in self.fields:
            return getattr(self, key)
        raise KeyError(key)

    def __contains__(self, key) -> bool:
        return key in self.fields

    def __iter__(self):
        return iter(self.fields)

    def keys(self):
        return self.fields

    def get(self, key, default=None):
        return getattr(self, key) if key in self.fields else default

    def __repr__(self):
        body = ", ".join(f"{f}={getattr(self, f)!r}" for f in self.fields)
        return f"{type(self).__name__}({body})"

    # -- pytree plumbing --------------------------------------------------
    def map_children(self, fn):
        """Same operand structure with ``fn`` applied to every child —
        used to build matching PartitionSpec / sharding trees."""
        new = object.__new__(type(self))
        new.__dict__.update(self.__dict__)
        for f in self.fields:
            setattr(new, f, fn(getattr(self, f)))
        return new

    def _aux(self):
        # idx_bits rides the aux so jit caches key on the index format;
        # the 2-tuple form is still accepted by _unflatten (pre-u4
        # pickled treedefs and any external callers keep working)
        return (self.fields, getattr(self, "cfg", None),
                getattr(self, "idx_bits", 8))

    def _children(self):
        return tuple(getattr(self, f) for f in self.fields)

    @classmethod
    def _unflatten(cls, aux, children):
        new = object.__new__(cls)
        new.fields, new.cfg = aux[0], aux[1]
        new.idx_bits = aux[2] if len(aux) > 2 else 8
        for f in cls._FIELDS:
            setattr(new, f, None)
        for f, c in zip(new.fields, children):
            setattr(new, f, c)
        return new


def _register(cls):
    from jax.tree_util import DictKey, register_pytree_with_keys

    register_pytree_with_keys(
        cls,
        lambda op: (tuple((DictKey(f), getattr(op, f)) for f in op.fields),
                    op._aux()),
        cls._unflatten,
        flatten_func=lambda op: (op._children(), op._aux()),
    )
    return cls


@_register
class DenseOp(SparseOperand):
    """A dense weight: no sparsity semantics, AMP forward/backward."""

    _FIELDS = ("w",)

    def __init__(self, w):
        self.fields = ("w",)
        self.w = w
        self.cfg = None


@_register
class MaskedOp(SparseOperand):
    """Legacy in-op masking: masks re-derived from ``w`` per call."""

    _FIELDS = ("w",)

    def __init__(self, w, cfg: SparsityConfig):
        self.fields = ("w",)
        self.w = w
        self.cfg = cfg


@_register
class PregenOp(SparseOperand):
    """Pre-generated WU-time operands (optim/sgd, paper Fig. 11c).

    At most one of ``ff`` (dense-layout bf16 FF operand) or
    ``vals``+``idx`` (SORE-packed FF operand along the contraction axis)
    is present; ``bp`` always is (its cotangent carries the dense
    straight-through WU gradient); ``mask`` is the stored SR-STE decay
    mask (optional).

    With a *transposable* cfg (arXiv 2102.08124: one mask N:M in both
    orientations) a bare ``bp`` operand is also valid — the same stored
    array serves FF and BP, so no separate ``ff`` leaf exists and the
    pregen weight state halves."""

    _FIELDS = ("bp", "ff", "idx", "mask", "vals")  # alphabetical — see above

    def __init__(self, *, bp, ff=None, vals=None, idx=None, mask=None,
                 cfg: SparsityConfig | None = None, idx_bits: int = 8):
        transposable = cfg is not None and getattr(cfg, "transposable", False)
        if ff is not None and vals is not None:
            raise ValueError("PregenOp needs at most one of ff | (vals, idx)")
        if ff is None and vals is None and not transposable:
            raise ValueError("PregenOp needs exactly one of ff | (vals, idx)"
                             " (bp-only operands require a transposable cfg)")
        if (vals is None) != (idx is None):
            raise ValueError("PregenOp packed form needs both vals and idx")
        if idx_bits not in (4, 8):
            raise ValueError(f"idx_bits must be 4 or 8, got {idx_bits}")
        present = {"bp": bp, "ff": ff, "idx": idx, "mask": mask, "vals": vals}
        self.fields = tuple(f for f in self._FIELDS
                            if present[f] is not None)
        for f in self._FIELDS:
            setattr(self, f, present[f])
        self.cfg = cfg
        self.idx_bits = idx_bits

    @property
    def is_packed(self) -> bool:
        return "vals" in self.fields

    @property
    def is_transposable(self) -> bool:
        return self.cfg is not None and getattr(self.cfg, "transposable",
                                                False)


@_register
class PackedOp(SparseOperand):
    """Forward-only element-packed serving weight (serve/packed_params).

    vals (…, K·N/M, F) surviving values; idx the uint8 in-group offset
    plane — same shape as vals with ``idx_bits=8``, or the u4-packed
    plane (…, ceil(K·N/M / 2), F) with ``idx_bits=4`` (two offsets per
    byte, ``core.sparsity.pack_idx_u4`` layout — half the index HBM
    traffic).  Consumed through ``kernels/nm_spmm``; ``idx_bits`` rides
    the pytree aux, so both formats dispatch through ``nm_apply``
    unchanged."""

    _FIELDS = ("idx", "vals")  # alphabetical

    def __init__(self, vals, idx, cfg: SparsityConfig, idx_bits: int = 8):
        if idx_bits not in (4, 8):
            raise ValueError(f"idx_bits must be 4 or 8, got {idx_bits}")
        self.fields = ("idx", "vals")
        self.vals = vals
        self.idx = idx
        self.cfg = cfg
        self.idx_bits = idx_bits

    @property
    def shape(self) -> tuple:
        """Dense-equivalent weight shape the pair decompresses to."""
        kc = self.vals.shape[-2]
        return (*self.vals.shape[:-2],
                kc * self.cfg.m // self.cfg.n, self.vals.shape[-1])


@_register
class SharedOp(SparseOperand):
    """Shared-pattern reduced-K serving weight (bdwp.pack_tree_shared):
    vals (…, Kc, F) pre-gathered rows, idx (…, Kc) absolute K indices —
    the forward is a gather + an M/N×-shorter matmul."""

    _FIELDS = ("idx", "vals")

    def __init__(self, vals, idx):
        self.fields = ("idx", "vals")
        self.vals = vals
        self.idx = idx
        self.cfg = None


def is_operand(leaf) -> bool:
    return isinstance(leaf, SparseOperand)


def as_operand(leaf, name: str, cfg: SparsityConfig) -> SparseOperand:
    """Coerce any consumption-path leaf format into a SparseOperand.

    Accepts operands (returned as-is), plain weight arrays (→ MaskedOp
    with per-param eligibility via ``bdwp.pick_cfg``), and the legacy
    dict formats: pre-generated operand dicts (→ PregenOp), element-
    packed serve dicts (idx rank == vals rank → PackedOp) and shared-
    packed dicts (per-row idx → SharedOp)."""
    if isinstance(leaf, SparseOperand):
        return leaf
    if isinstance(leaf, dict):
        if "bp" in leaf and ("ff" in leaf or "vals" in leaf):
            # legacy dicts predate the u4 plane: always byte-wide indices
            return PregenOp(bp=leaf["bp"], ff=leaf.get("ff"),
                            vals=leaf.get("vals"), idx=leaf.get("idx"),
                            mask=leaf.get("mask"), cfg=cfg, idx_bits=8)
        if "vals" in leaf and "idx" in leaf:
            if leaf["idx"].ndim == leaf["vals"].ndim:
                return PackedOp(leaf["vals"], leaf["idx"], cfg, idx_bits=8)
            return SharedOp(leaf["vals"], leaf["idx"])
        raise TypeError(f"unrecognized operand dict for {name}: "
                        f"{sorted(leaf)}")
    from repro.core import bdwp  # runtime import: bdwp imports this module

    return MaskedOp(leaf, bdwp.pick_cfg(name, leaf.shape, cfg))


# ---------------------------------------------------------------------------
# Backend selection
# ---------------------------------------------------------------------------

_BACKENDS = ("auto", "jnp", "pallas")
_SCOPE = {"backend": None}


@contextlib.contextmanager
def backend_scope(backend: str):
    """Ambient backend for ``nm_apply(backend="auto")`` calls — the step
    builders enter this around model tracing so one flag switches every
    packed consumption site in the forward.

    The scope is consulted at TRACE time only: a function jitted while
    one scope was ambient keeps that backend in its compiled cache —
    re-entering a different scope does not retrace it.  To switch
    backends, build a fresh jitted function per backend (what the step
    builders' ``nm_backend=`` flag does) or pass ``backend=``
    explicitly."""
    if backend not in _BACKENDS:
        raise ValueError(f"unknown nm_apply backend {backend!r}")
    old = _SCOPE["backend"]
    _SCOPE["backend"] = backend
    try:
        yield
    finally:
        _SCOPE["backend"] = old


def resolve_backend(backend: str = "auto") -> str:
    if backend not in _BACKENDS:
        raise ValueError(f"unknown nm_apply backend {backend!r}")
    if backend == "auto" and _SCOPE["backend"] not in (None, "auto"):
        backend = _SCOPE["backend"]
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return backend


# ---------------------------------------------------------------------------
# Custom-VJP cores — matmul view: x (..., K) @ w (K, F) -> (..., F)
# ---------------------------------------------------------------------------
#
# These carry the paper's training semantics (Alg. 1 / Fig. 11c), moved
# verbatim from core/bdwp.py so every operand type shares one set of
# rules:
#   FF : y  = x @ w_FF          (sparse operand)
#   BP : dx = g @ w_BP^T        (bp operand / re-derived BP mask)
#   WU : dW = x^T @ g           (always dense, straight-through)


def _ff_weights(w: jax.Array, cfg: SparsityConfig) -> jax.Array:
    """FF-pruned weights: N:M groups along the input (contraction) axis."""
    if cfg.prunes_ff_weights():
        return sparsify(w, cfg, axis=0, share_axis=1)
    return w


def _bp_weights(w: jax.Array, cfg: SparsityConfig) -> jax.Array:
    """BP-pruned weights: N:M groups along the output axis (dgrad)."""
    if cfg.prunes_bp_weights():
        return sparsify(w, cfg, axis=1, share_axis=0)
    return w


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def masked_linear(x: jax.Array, w: jax.Array, cfg: SparsityConfig):
    """y = x @ w with cfg.method's N:M sparse training semantics."""
    return jnp.matmul(x, _ff_weights(w, cfg).astype(x.dtype))


def _masked_linear_fwd(x, w, cfg):
    y = jnp.matmul(x, _ff_weights(w, cfg).astype(x.dtype))
    return y, (x, w)


def _masked_linear_bwd(cfg, res, g):
    x, w = res
    # AMP dataflow (paper Fig. 11): BP/WU arithmetic runs in the compute
    # dtype (bf16 here, FP16 on SAT); only the weight-gradient *result*
    # accumulates in fp32 for WUVE.  Casting the cotangent down — rather
    # than the weights up — keeps backward activations, remat recompute
    # and the TP collectives in 16-bit (2x traffic saving, and faithful).
    gc = g.astype(x.dtype)
    if cfg.prunes_bp_grads():  # SDGP: prune the *output gradients* N:M
        g_bp = sparsify(gc, cfg, axis=-1)
        dx = jnp.matmul(g_bp, w.T.astype(gc.dtype))
    else:
        w_bp = _bp_weights(w, cfg)
        dx = jnp.matmul(gc, w_bp.T.astype(gc.dtype))
    # WU: dense (paper Alg. 1 line 9), straight-through; fp32 accumulation
    x2 = x.reshape(-1, x.shape[-1])
    g2 = gc.reshape(-1, gc.shape[-1])
    dw = jnp.matmul(x2.T, g2, preferred_element_type=jnp.float32)
    return dx.reshape(x.shape).astype(x.dtype), dw.astype(w.dtype)


masked_linear.defvjp(_masked_linear_fwd, _masked_linear_bwd)


@jax.custom_vjp
def pregen_linear(x: jax.Array, ff: jax.Array, bp: jax.Array) -> jax.Array:
    """y = x @ ff with BP on ``bp`` and the dense WU gradient riding the
    ``bp`` cotangent (always dense-shaped)."""
    return jnp.matmul(x, ff.astype(x.dtype))


def _pregen_linear_fwd(x, ff, bp):
    return jnp.matmul(x, ff.astype(x.dtype)), (x, ff, bp)


def _pregen_linear_bwd(res, g):
    x, ff, bp = res
    gc = g.astype(x.dtype)
    dx = jnp.matmul(gc, bp.T.astype(gc.dtype))
    x2 = x.reshape(-1, x.shape[-1])
    g2 = gc.reshape(-1, gc.shape[-1])
    dw = jnp.matmul(x2.T, g2, preferred_element_type=jnp.float32)
    return (dx.reshape(x.shape).astype(x.dtype), jnp.zeros_like(ff),
            dw.astype(bp.dtype))


pregen_linear.defvjp(_pregen_linear_fwd, _pregen_linear_bwd)


def _spmm_stacked(x2, vals, idx, n: int, m: int, use_pallas: bool,
                  idx_bits: int = 8):
    """kernels/nm_spmm over optionally-stacked packed weights.

    x2 (*stack, T, K), vals/idx (*stack, Kc, F) — vmaps the kernel over
    the leading stack axes (MoE expert stacks ride the same kernel).
    ``idx_bits=4`` hands the kernel the u4 index plane unchanged."""
    from repro.kernels import ops  # local import to avoid cycles

    if vals.ndim == 2:
        return ops.nm_spmm(x2, vals, idx, n, m, use_pallas=use_pallas,
                           idx_bits=idx_bits)
    return jax.vmap(
        lambda xe, ve, ie: _spmm_stacked(xe, ve, ie, n, m, use_pallas,
                                         idx_bits)
    )(x2, vals, idx)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def packed_pregen_linear(x, vals, idx, bp, n: int, m: int,
                         use_pallas: bool = True, idx_bits: int = 8):
    """Packed-FF pre-generated matmul: the forward consumes the SORE
    pair ``(vals, idx)`` directly through ``kernels/nm_spmm`` — the
    dense FF layout never materializes in HBM — while BP/WU follow the
    pregen rules (BP on ``bp``, dense straight-through WU cotangent on
    ``bp``; the uint8 indices get a float0 cotangent).

    Shapes: x (*stack, ..., K), vals/idx (*stack, Kc, F), bp
    (*stack, K, F); token dims between stack and K are flattened for the
    kernel and restored after.  ``idx_bits=4``: idx is the u4 plane
    (*stack, ceil(Kc/2), F).
    """
    y, _ = _packed_pregen_fwd(x, vals, idx, bp, n, m, use_pallas, idx_bits)
    return y


def _packed_pregen_fwd(x, vals, idx, bp, n, m, use_pallas, idx_bits=8):
    stack = vals.ndim - 2
    x2 = x.reshape(*x.shape[:stack], -1, x.shape[-1])
    y = _spmm_stacked(x2, vals, idx, n, m, use_pallas, idx_bits)
    y = y.reshape(*x.shape[:-1], vals.shape[-1]).astype(x.dtype)
    return y, (x, vals, idx, bp)


def _packed_pregen_bwd(n, m, use_pallas, idx_bits, res, g):
    x, vals, idx, bp = res
    stack = bp.ndim - 2
    gc = g.astype(x.dtype)
    # BP: batched over the stack axes — identical arithmetic to the
    # (vmapped) pregen_linear backward
    g2 = gc.reshape(*gc.shape[:stack], -1, gc.shape[-1])
    x2 = x.reshape(*x.shape[:stack], -1, x.shape[-1])
    bp_t = jnp.swapaxes(bp, -1, -2).astype(gc.dtype)
    dx = jnp.matmul(g2, bp_t).reshape(x.shape).astype(x.dtype)
    # WU: dense straight-through, fp32-accumulated, on the bp cotangent
    dw = jnp.matmul(jnp.swapaxes(x2, -1, -2), g2,
                    preferred_element_type=jnp.float32)
    didx = np.zeros(idx.shape, dtype=jax.dtypes.float0)
    return dx, jnp.zeros_like(vals), didx, dw.astype(bp.dtype)


packed_pregen_linear.defvjp(_packed_pregen_fwd, _packed_pregen_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def packed_pregen_linear_t(x, vals, idx, bp, n: int, m: int,
                           use_pallas: bool = True, idx_bits: int = 8):
    """Transposable-mask packed matmul (arXiv 2102.08124): the ONE
    stored mask is N:M along both the contraction and the output axis,
    so the packed ``(vals, idx)`` pair serves FF *and* BP.  The forward
    is ``packed_pregen_linear``'s (nm_spmm on the pair); dgrad
    decompresses the pair (select-based, exact — decompressed == the
    dense ``bp`` copy bitwise, same mask, same bf16 values) and
    contracts g @ w^T instead of reading ``bp``.  ``bp`` therefore only
    carries the dense straight-through WU gradient on its cotangent —
    no op ever reads the array, so the lowered step loads one weight
    operand per layer instead of two."""
    y, _ = _packed_pregen_fwd(x, vals, idx, bp, n, m, use_pallas, idx_bits)
    return y


def _packed_pregen_t_bwd(n, m, use_pallas, idx_bits, res, g):
    x, vals, idx, bp = res
    from repro.kernels.nm_spmm_shared import decompress_nm

    stack = bp.ndim - 2
    gc = g.astype(x.dtype)
    g2 = gc.reshape(*gc.shape[:stack], -1, gc.shape[-1])
    x2 = x.reshape(*x.shape[:stack], -1, x.shape[-1])
    w_bp = decompress_nm(vals, idx, n, m, axis=-2, idx_bits=idx_bits)
    dx = jnp.matmul(g2, jnp.swapaxes(w_bp, -1, -2).astype(gc.dtype))
    dx = dx.reshape(x.shape).astype(x.dtype)
    dw = jnp.matmul(jnp.swapaxes(x2, -1, -2), g2,
                    preferred_element_type=jnp.float32)
    didx = np.zeros(idx.shape, dtype=jax.dtypes.float0)
    return dx, jnp.zeros_like(vals), didx, dw.astype(bp.dtype)


packed_pregen_linear_t.defvjp(_packed_pregen_fwd, _packed_pregen_t_bwd)


# ---------------------------------------------------------------------------
# Custom-VJP cores — conv view (NHWC x HWIO -> NHWC)
# ---------------------------------------------------------------------------

_CONV_IN_AXIS = 2   # HWIO: input-channel axis (FF grouping, Fig. 5a)
_CONV_OUT_AXIS = 3  # HWIO: output-channel axis (BP grouping, Fig. 5b)


def _conv(x, w, stride, padding):
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def masked_conv(x, w, cfg: SparsityConfig, stride: int = 1,
                padding: str = "SAME"):
    w_ff = sparsify(w, cfg, axis=_CONV_IN_AXIS, share_axis=_CONV_OUT_AXIS) \
        if cfg.prunes_ff_weights() else w
    return _conv(x, w_ff, stride, padding)


def _masked_conv_fwd(x, w, cfg, stride, padding):
    w_ff = sparsify(w, cfg, axis=_CONV_IN_AXIS, share_axis=_CONV_OUT_AXIS) \
        if cfg.prunes_ff_weights() else w
    return _conv(x, w_ff, stride, padding), (x, w)


def _masked_conv_bwd(cfg, stride, padding, res, g):
    x, w = res
    if cfg.prunes_bp_grads():
        g_eff = sparsify(g, cfg, axis=-1)  # N:M across output channels
        w_bp = w
    else:
        g_eff = g
        w_bp = sparsify(w, cfg, axis=_CONV_OUT_AXIS, share_axis=_CONV_IN_AXIS) \
            if cfg.prunes_bp_weights() else w
    # dgrad through a closure over the BP weights
    _, dgrad = jax.vjp(lambda xx: _conv(xx, w_bp, stride, padding), x)
    (dx,) = dgrad(g_eff.astype(x.dtype))
    # wgrad dense (straight-through to master weights)
    _, wgrad = jax.vjp(lambda ww: _conv(x, ww, stride, padding), w)
    (dw,) = wgrad(g.astype(x.dtype))
    return dx, dw.astype(w.dtype)


masked_conv.defvjp(_masked_conv_fwd, _masked_conv_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def pregen_conv(x, ff, bp, stride: int = 1, padding: str = "SAME"):
    """Conv view of ``pregen_linear``: FF convolves the WU-time FF
    operand, dgrad convolves ``bp``, wgrad is dense straight-through on
    the BP operand's cotangent."""
    return _conv(x, ff, stride, padding)


def _pregen_conv_fwd(x, ff, bp, stride, padding):
    return _conv(x, ff, stride, padding), (x, ff, bp)


def _pregen_conv_bwd(stride, padding, res, g):
    x, ff, bp = res
    _, dgrad = jax.vjp(lambda xx: _conv(xx, bp, stride, padding), x)
    (dx,) = dgrad(g.astype(x.dtype))
    _, wgrad = jax.vjp(lambda ww: _conv(x, ww, stride, padding), bp)
    (dw,) = wgrad(g.astype(x.dtype))
    return dx, jnp.zeros_like(ff), dw.astype(bp.dtype)


pregen_conv.defvjp(_pregen_conv_fwd, _pregen_conv_bwd)


# ---------------------------------------------------------------------------
# Forward-only serving consumption
# ---------------------------------------------------------------------------


def _packed_serve(x, op: PackedOp, backend: str):
    """Element-packed serving matmul through kernels/nm_spmm.

    Leading stack axes on the pair (layer-stacked leaves consumed
    outside the scan) vmap through the kernel, same as the packed
    training path."""
    stack = op.vals.ndim - 2
    x2 = x.reshape(*x.shape[:stack], -1, x.shape[-1])
    y = _spmm_stacked(x2, op.vals, op.idx, op.cfg.n, op.cfg.m,
                      backend == "pallas", op.idx_bits)
    return y.reshape(*x.shape[:-1], op.vals.shape[-1]).astype(x.dtype)


def _shared_serve(x, op: SharedOp):
    """Shared-pattern reduced-K matmul: gather survivors, contract Kc."""
    xg = jnp.take(x, op.idx, axis=-1)
    return jnp.matmul(xg, op.vals.astype(xg.dtype))


# ---------------------------------------------------------------------------
# The dispatch
# ---------------------------------------------------------------------------


def _pregen_ff_dense(op: PregenOp) -> jax.Array:
    """Dense-layout FF operand of a PregenOp (decompressing packed
    leaves with the shared select-based helper — exact, scatter-free).
    Transposable bp-only operands FF on ``bp`` itself: the one mask is
    N:M in both orientations, so the same array is the FF operand."""
    if op.ff is not None:
        return op.ff
    if op.is_packed:
        from repro.kernels.nm_spmm_shared import decompress_nm

        cfg = op.cfg
        return decompress_nm(op.vals, op.idx, cfg.n, cfg.m, axis=-2,
                             idx_bits=op.idx_bits)
    return op.bp


def nm_apply(op, x: jax.Array, *, backend: str = "auto",
             stacked: bool = False, stride: int = 1,
             padding: str = "SAME") -> jax.Array:
    """Apply one operand to activations — THE N:M consumption seam.

    Dispatch:
      * matmul view for rank-2 weights (rank-3 with ``stacked=True``:
        the leading axis is a vmapped expert/stack axis — N:M groups
        stay within one expert);
      * conv view (NHWC x HWIO) for rank-4 weights, with ``stride`` /
        ``padding``;
      * ``backend`` picks how packed ``(vals, idx)`` pairs are consumed:
        "pallas" streams them through ``kernels/nm_spmm`` (interpret
        mode off-TPU), "jnp" decompresses in-register (select-based, no
        scatter) and runs the dense-layout matmul; "auto" defers to the
        ambient ``backend_scope`` then the device;
      * the operand's ``idx_bits`` flows through unchanged — a u4 index
        plane is expanded inside the kernel tile (pallas) or unpacked
        nibble-first before the in-register decompress (jnp); the two
        widths are bitwise interchangeable.

    Gradient semantics ride the operand type: MaskedOp re-derives masks
    per cfg.method; PregenOp backs through ``bp`` with the dense
    straight-through WU cotangent; PackedOp/SharedOp are forward-only
    serving paths.
    """
    backend = resolve_backend(backend)

    if isinstance(op, DenseOp):
        from repro.core.sparsity import DENSE

        op = MaskedOp(op.w, DENSE)

    if isinstance(op, MaskedOp):
        w, cfg = op.w, op.cfg
        if w.ndim == 4 and not stacked:
            return masked_conv(x, w, cfg, stride, padding)
        if stacked:
            return jax.vmap(lambda xe, we: masked_linear(xe, we, cfg))(x, w)
        return masked_linear(x, w, cfg)

    if isinstance(op, PregenOp):
        if op.bp.ndim == 4 and not stacked:  # conv: HWIO operands
            return pregen_conv(x, _pregen_ff_dense(op), op.bp,
                               stride, padding)
        if op.is_packed and backend == "pallas":
            cfg = op.cfg
            fn = packed_pregen_linear_t if op.is_transposable \
                else packed_pregen_linear
            return fn(x, op.vals, op.idx, op.bp, cfg.n, cfg.m, True,
                      op.idx_bits)
        ff = _pregen_ff_dense(op)
        if stacked:
            return jax.vmap(pregen_linear)(x, ff, op.bp)
        return pregen_linear(x, ff, op.bp)

    if isinstance(op, PackedOp):
        return _packed_serve(x, op, backend)

    if isinstance(op, SharedOp):
        return _shared_serve(x, op)

    raise TypeError(f"nm_apply: not a SparseOperand: {type(op).__name__}")
