"""Offline dataflow scheduling — the RWG (reconfiguration word generator)
analogue (paper Fig. 12).

The paper's RWG walks the model once, ahead of time, and emits per-layer
"configuration words": for each of the three training stages (FF/BP/WU)
it decides (a) sparse vs dense execution, (b) where the N:M packing runs
(pre-generated in WU vs inline in FF/BP), and (c) the WS-vs-OS systolic
dataflow, chosen by predicted utilization of the 32x32 PE array.

On TPU the same decisions exist, relocated:
  (a) sparse-vs-dense per stage   -> resolved at trace time from the
      SparsityConfig + the per-parameter exclusion policy (core/bdwp);
  (b) packing site                -> the fused optimizer kernel
      (pre-generation, Fig. 11c) vs inline sparsify in the matmul vjp;
  (c) WS-vs-OS                    -> which operand a Pallas matmul keeps
      resident in VMEM across grid steps (the "stationary" operand) and
      the grid iteration order.  The utilization model below is the
      MXU-tile analogue of the paper's PE-array occupancy predictor.

Everything here is *static*: a ``plan_model`` call returns a plain-python
list of LayerPlans, consumed at trace time — zero runtime branching, the
exact property that lets the FPGA version stream configuration words.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core import bdwp
from repro.core.sparsity import SparsityConfig

# MXU-tile geometry used by the utilization predictor (v5e-class).
TILE = 128          # systolic tile edge (rows == cols on the MXU)
PIPE_FILL = 128     # cycles to fill/drain the array (paper: array edge)


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """One stage (ff | bp | wu) of one matmul layer."""

    stage: str            # "ff" | "bp" | "wu"
    sparse: bool          # N:M sparse execution?
    pack_site: str        # "pregen" | "inline" | "-" (dense)
    dataflow: str         # "WS" | "OS" (stationary operand choice)
    utilization: float    # predicted PE/MXU occupancy in [0, 1]
    macs: int             # MACs executed (after sparsity skipping)


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    name: str             # parameter name (matmul id)
    b: int                # rows of the activation operand (B*S or B*H*W)
    k: int                # contraction length
    f: int                # output features
    ff: StagePlan
    bp: StagePlan
    wu: StagePlan

    @property
    def total_macs(self) -> int:
        return self.ff.macs + self.bp.macs + self.wu.macs

    def config_word(self) -> dict:
        """The RWG output: one serializable word per layer."""
        return {
            "layer": self.name,
            "dims": (self.b, self.k, self.f),
            "ff": (self.ff.dataflow, "sparse" if self.ff.sparse else "dense",
                   self.ff.pack_site),
            "bp": (self.bp.dataflow, "sparse" if self.bp.sparse else "dense",
                   self.bp.pack_site),
            "wu": (self.wu.dataflow, "sparse" if self.wu.sparse else "dense",
                   self.wu.pack_site),
        }


# ---------------------------------------------------------------------------
# WS / OS utilization prediction (the paper's RWG occupancy model, MXU tiles)
# ---------------------------------------------------------------------------


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def ws_cycles(b: int, k: int, f: int) -> int:
    """Weight-stationary: the (K,F) operand is preloaded tile-by-tile and
    the B rows stream through.  Cost per (K,F) tile: preload (TILE) +
    stream (b) + drain (PIPE_FILL)."""
    tiles = _ceil_div(k, TILE) * _ceil_div(f, TILE)
    return tiles * (TILE + b + PIPE_FILL)


def os_cycles(b: int, k: int, f: int) -> int:
    """Output-stationary: each (B,F) tile accumulates over K in place;
    operands stream in.  Cost per (B,F) tile: k + fill/drain."""
    tiles = _ceil_div(b, TILE) * _ceil_div(f, TILE)
    return tiles * (k + PIPE_FILL)


def _utilization(macs: int, cycles: int) -> float:
    peak = TILE * TILE  # MACs per cycle at full occupancy
    return min(1.0, macs / (cycles * peak)) if cycles else 0.0


def pick_dataflow(b: int, k: int, f: int) -> tuple:
    """Choose the dataflow with the fewer predicted cycles (paper Fig. 12:
    'RWG calculates the hardware utilization of OS and WS ... and based on
    predicted results' assigns the dataflow)."""
    ws, os_ = ws_cycles(b, k, f), os_cycles(b, k, f)
    macs = b * k * f
    if ws <= os_:
        return "WS", _utilization(macs, ws)
    return "OS", _utilization(macs, os_)


# ---------------------------------------------------------------------------
# Per-layer stage planning
# ---------------------------------------------------------------------------


def plan_layer(name: str, b: int, k: int, f: int,
               cfg: SparsityConfig) -> LayerPlan:
    """Plan FF/BP/WU for one matmul  act(B,K) @ W(K,F).

    Stage shapes (im2col'd — Fig. 1c-e):
      FF : (B,K)  @ (K,F)      contraction K   (sparse if FF-pruned: K·N/M)
      BP : (B,F)  @ (F,K)      contraction F   (sparse if BP-pruned: F·N/M)
      WU : (K,B)  @ (B,F)      contraction B   (always dense — Alg. 1)
    """
    prune = bdwp.should_prune(name, (k, f), cfg)
    frac = cfg.keep_fraction
    ff_sparse = prune and cfg.prunes_ff_weights()
    bp_sparse = prune and (cfg.prunes_bp_weights() or cfg.prunes_bp_grads())

    # pre-generation (Fig. 11c) applies when the *weights* are what gets
    # pruned — the optimizer already owns the fresh values at WU time.
    # SDGP prunes gradients, which only exist inside BP -> inline.
    pregen_ok = cfg.method in ("srste", "sdwp", "bdwp")
    pack = "pregen" if pregen_ok else "inline"

    k_ff = int(k * frac) if ff_sparse else k
    df_ff, u_ff = pick_dataflow(b, k_ff, f)
    ff = StagePlan("ff", ff_sparse, pack if ff_sparse else "-",
                   df_ff, u_ff, b * k_ff * f)

    f_bp = int(f * frac) if bp_sparse else f
    df_bp, u_bp = pick_dataflow(b, f_bp, k)
    bp = StagePlan("bp", bp_sparse, pack if bp_sparse else "-",
                   df_bp, u_bp, b * f_bp * k)

    df_wu, u_wu = pick_dataflow(k, b, f)
    wu = StagePlan("wu", False, "-", df_wu, u_wu, b * k * f)

    return LayerPlan(name, b, k, f, ff, bp, wu)


# ---------------------------------------------------------------------------
# Whole-model planning from a spec tree
# ---------------------------------------------------------------------------


def matmul_dims_of(name: str, shape: tuple, tokens: int) -> Optional[tuple]:
    """(b, k, f) of the training matmul a parameter participates in, or
    None for non-matmul params (norms, biases, scalars).

    tokens = B*S for LMs / B*H*W for conv features (im2col rows).
    Stacked-layer params (L, K, F) contribute L independent matmuls — the
    caller multiplies; conv HWIO (H, W, I, O) -> k = H*W*I (im2col).
    """
    if len(shape) < 2:
        return None
    if len(shape) == 2:
        return (tokens, shape[0], shape[1])
    if len(shape) == 4 and name.endswith("conv"):
        h, w, i, o = shape
        return (tokens, h * w * i, o)
    # stacked (L, K, F) or (L, E, K, F): per-layer matmul dims
    return (tokens, shape[-2], shape[-1])


def plan_model(named_shapes: dict, tokens: int,
               cfg: SparsityConfig) -> list:
    """RWG over a whole model: {param_name: shape} -> [LayerPlan].

    ``named_shapes`` comes from the spec tree the models expose
    (flattened names with '/' separators, same names the optimizer's
    exclusion policy sees).
    """
    plans = []
    for name, shape in sorted(named_shapes.items()):
        dims = matmul_dims_of(name, tuple(shape), tokens)
        if dims is None:
            continue
        b, k, f = dims
        layers = 1
        if len(shape) >= 3:  # stacked scan params: L leading
            layers = int(shape[0]) if not name.endswith("conv") else 1
        plan = plan_layer(name, b, k, f, cfg)
        for rep in range(layers):
            plans.append(plan if layers == 1 else dataclasses.replace(
                plan, name=f"{name}[{rep}]"))
    return plans


def schedule_summary(plans: list) -> dict:
    """Aggregate the plan the way the paper reports it: total MACs per
    stage, dense-equivalent MACs, realized reduction, mean utilization."""
    tot = {"ff": 0, "bp": 0, "wu": 0}
    dense = 0
    util_num = util_den = 0.0
    for p in plans:
        tot["ff"] += p.ff.macs
        tot["bp"] += p.bp.macs
        tot["wu"] += p.wu.macs
        dense += 3 * p.b * p.k * p.f
        for s in (p.ff, p.bp, p.wu):
            util_num += s.utilization * s.macs
            util_den += s.macs
    total = sum(tot.values())
    return {
        "macs_ff": tot["ff"], "macs_bp": tot["bp"], "macs_wu": tot["wu"],
        "macs_total": total, "macs_dense": dense,
        "reduction": dense / total if total else 1.0,
        "mean_utilization": util_num / util_den if util_den else 0.0,
        "n_layers": len(plans),
    }
