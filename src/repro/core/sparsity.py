"""N:M fine-grained structured sparsity primitives (pure jnp).

This is the algorithmic substrate of the paper: group the elements of a
tensor along one axis into consecutive groups of M, keep the N
largest-magnitude elements per group, zero (or pack away) the rest.

Two granularities:
  * ``element`` — the paper-faithful pattern: every M-group of every
    "output column" chooses its own survivors.  On TPU this yields a
    memory/bandwidth win (compact storage) but no MXU FLOP win.
  * ``shared``  — beyond-paper, MXU-native: the survivor pattern is shared
    across a tile of ``tile`` entries of a sibling axis, so the contraction
    axis can be *gathered and shortened* K -> K*N/M, giving a true FLOP
    reduction on a rigid systolic array.

All functions are shape-polymorphic, jit-safe and differentiable where it
makes sense (masking is piecewise constant; gradients flow through the
kept values only — the straight-through estimator lives in core/bdwp.py).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """Static description of an N:M sparsity scheme.

    Attributes:
      n: survivors per group (0 < n <= m).  n == m means dense.
      m: group size along the grouped axis.
      method: one of 'dense' | 'srste' | 'sdgp' | 'sdwp' | 'bdwp'.
        srste: N:M weights in FF only            (Zhou et al., ICLR'21)
        sdgp : N:M output gradients in BP only   (McDanel et al., ICPR'22)
        sdwp : N:M weights in BP only            (paper ablation, Fig. 4)
        bdwp : N:M weights in FF and BP          (the paper's contribution)
      granularity: 'element' | 'shared' (see module docstring).
      tile: pattern-sharing tile width for 'shared' granularity.
      lam: SR-STE sparse-refined regularization strength (lambda_w).
      excluded: regex fragments of param names excluded from pruning
        (paper: first conv layer; here also routers/embeddings/norms).
      transposable: one mask serves W and Wᵀ (Hubara et al., NeurIPS'21,
        arXiv 2102.08124): survivors satisfy N:M along BOTH the FF
        (contraction) and BP (output) axes of every m x m tile, so the
        pre-generated FF and BP operands collapse into one stored
        operand + one mask.  bdwp + element granularity only.
    """

    n: int = 2
    m: int = 8
    method: str = "bdwp"
    granularity: str = "element"
    tile: int = 128
    lam: float = 2e-4
    excluded: tuple = ("embed", "router", "norm", "frontend", "bias", "head0")
    transposable: bool = False

    def __post_init__(self):
        if not (0 < self.n <= self.m):
            raise ValueError(f"need 0 < n <= m, got {self.n}:{self.m}")
        if self.method not in ("dense", "srste", "sdgp", "sdwp", "bdwp"):
            raise ValueError(f"unknown method {self.method!r}")
        if self.granularity not in ("element", "shared"):
            raise ValueError(f"unknown granularity {self.granularity!r}")
        if self.transposable and (self.method != "bdwp"
                                  or self.granularity != "element"):
            raise ValueError(
                "transposable masks need method='bdwp' and element "
                f"granularity, got {self.method!r}/{self.granularity!r}")

    @property
    def is_dense(self) -> bool:
        return self.method == "dense" or self.n == self.m

    @property
    def keep_fraction(self) -> float:
        return self.n / self.m

    def prunes_ff_weights(self) -> bool:
        return self.method in ("srste", "bdwp") and not self.is_dense

    def prunes_bp_weights(self) -> bool:
        return self.method in ("sdwp", "bdwp") and not self.is_dense

    def prunes_bp_grads(self) -> bool:
        return self.method == "sdgp" and not self.is_dense


DENSE = SparsityConfig(method="dense")


def _move_axis_last(x: jax.Array, axis: int):
    axis = axis % x.ndim
    perm = [i for i in range(x.ndim) if i != axis] + [axis]
    inv = [perm.index(i) for i in range(x.ndim)]
    return jnp.transpose(x, perm), inv


def _topn_group_mask(score: jax.Array, n: int) -> jax.Array:
    """Survivor mask over (..., M) score groups: N largest, earliest-index
    tie-break (what a greater-than-only hardware sorter does).  The single
    shared selection core — ``nm_mask`` and ``nm_mask_pair`` both call it,
    so every mask in the system breaks ties identically."""
    # kth-largest value per group = the survival threshold
    top = jax.lax.top_k(score, n)[0]
    thresh = top[..., n - 1 : n]
    # exact tie-break, no epsilon games: keep everything strictly above the
    # threshold, then fill the remaining quota with the *earliest* entries
    # that exactly equal it.
    greater = score > thresh
    tie = score == thresh
    quota = n - greater.sum(axis=-1, keepdims=True)
    tie_rank = jnp.cumsum(tie.astype(jnp.int32), axis=-1)
    return greater | (tie & (tie_rank <= quota))


def nm_mask(x: jax.Array, n: int, m: int, axis: int = -1) -> jax.Array:
    """Boolean mask keeping the N largest-|x| of each consecutive M along axis.

    Deterministic tie-break: earlier index wins (matches a hardware top-K
    sorter that only replaces on strict greater-than, like SORE).
    """
    if n == m:
        return jnp.ones_like(x, dtype=bool)
    xt, inv = _move_axis_last(x, axis)
    k = xt.shape[-1]
    if k % m != 0:
        raise ValueError(f"axis length {k} not divisible by group size {m}")
    g = xt.reshape(*xt.shape[:-1], k // m, m)
    score = jnp.abs(g).astype(jnp.float32)
    mask = _topn_group_mask(score, n)
    mask = mask.reshape(*xt.shape[:-1], k)
    return jnp.transpose(mask, inv)


def nm_mask_pair(x: jax.Array, n: int, m: int, ff_axis: int, bp_axis: int):
    """(FF mask, BP mask) of one tensor with a SINGLE fused top_k.

    The FF groups (along ``ff_axis``) and BP groups (along ``bp_axis``)
    are independent M-groups, so their |x| scores can be flattened into
    one (G_ff + G_bp, M) batch and selected in one ``lax.top_k`` call —
    the pre-generation dataflow's "masks computed once at WU time"
    becomes literally one selection op per parameter in the lowered HLO
    (down from one per consumer).  Bitwise-identical to two ``nm_mask``
    calls.  Shape-polymorphic over leading axes: a stacked MoE expert
    leaf (L?, E, K, F) with ff_axis=ndim-2, bp_axis=ndim-1 yields
    per-expert masks — equal to vmapping ``nm_mask`` over the stack —
    while still lowering to ONE selection for the whole parameter
    (tests/test_sparsity.py pins both properties).
    """
    if n == m:
        ones = jnp.ones_like(x, dtype=bool)
        return ones, ones
    views = []
    for axis in (ff_axis, bp_axis):
        xt, inv = _move_axis_last(x, axis)
        k = xt.shape[-1]
        if k % m != 0:
            raise ValueError(f"axis length {k} not divisible by {m}")
        score = jnp.abs(xt).astype(jnp.float32).reshape(-1, m)
        views.append((xt.shape, inv, score))
    mask_flat = _topn_group_mask(
        jnp.concatenate([v[2] for v in views], axis=0), n)
    out, offset = [], 0
    for shape, inv, score in views:
        rows = score.shape[0]
        mask = mask_flat[offset : offset + rows].reshape(shape)
        out.append(jnp.transpose(mask, inv))
        offset += rows
    return tuple(out)


def nm_mask_transposable(x: jax.Array, n: int, m: int) -> jax.Array:
    """One mask serving W and Wᵀ: N:M along rows AND columns of every
    m x m tile of the last two axes (Hubara et al., arXiv 2102.08124).

    Three phases, all vectorized over tiles and deterministic:
      1. greedy — accept cells largest-|x|-first while the cell's row
         and column quotas are both open (ties to the earliest row-major
         cell, the same greater-than-only convention as ``nm_mask``);
         greedy can strand a few quotas (a deficit row's open columns
         may all sit in saturated rows' shadows);
      2. repair — while any quota is open, apply the best augmenting
         swap: add (r, c2) and (r', c), drop (r', c2), for the selected
         cell (r', c2) maximizing the score gain; each swap closes one
         row and one column deficit and never overfills a quota;
      3. fallback — any tile the bounded repair loop leaves deficient
         (not observed in practice; the loop runs n*m swaps and each
         valid swap closes a deficit) gets the top-n cyclic-diagonal
         mask, which is transposable by construction.

    Leading axes batch through (a stacked MoE leaf gets per-expert
    tiles).  Both trailing dims must be divisible by m.
    """
    if n == m:
        return jnp.ones_like(x, dtype=bool)
    *lead, rdim, cdim = x.shape
    if rdim % m or cdim % m:
        raise ValueError(f"dims ({rdim}, {cdim}) not divisible by m={m}")
    rt, ct = rdim // m, cdim // m
    tiles = x.reshape(*lead, rt, m, ct, m)
    tiles = jnp.moveaxis(tiles, -3, -2)          # (*lead, rt, ct, m, m)
    score = jnp.abs(tiles).astype(jnp.float32).reshape(-1, m * m)
    order = jnp.argsort(-score, axis=-1)         # stable: ties earliest-first
    t = score.shape[0]
    cell_ids = jnp.arange(m * m, dtype=jnp.int32)
    slot_ids = jnp.arange(m, dtype=jnp.int32)

    def greedy(k, carry):
        mask, rows, cols = carry                 # (T, m*m), (T, m), (T, m)
        cell = order[:, k]
        r, c = cell // m, cell % m
        r_hot = slot_ids[None, :] == r[:, None]  # (T, m)
        c_hot = slot_ids[None, :] == c[:, None]
        ok = (jnp.sum(jnp.where(r_hot, rows, 0), axis=-1) < n) \
            & (jnp.sum(jnp.where(c_hot, cols, 0), axis=-1) < n)
        mask = mask | ((cell_ids[None, :] == cell[:, None]) & ok[:, None])
        rows = rows + jnp.where(r_hot & ok[:, None], 1, 0)
        cols = cols + jnp.where(c_hot & ok[:, None], 1, 0)
        return mask, rows, cols

    init = (jnp.zeros((t, m * m), bool),
            jnp.zeros((t, m), jnp.int32), jnp.zeros((t, m), jnp.int32))
    mask, _, _ = jax.lax.fori_loop(0, m * m, greedy, init)
    mask = mask.reshape(t, m, m)
    sc = score.reshape(t, m, m)

    def repair(_, mask):
        rows = mask.sum(-1)                      # (T, m)
        cols = mask.sum(-2)
        need = (rows < n).any(-1)                # (T,)
        r = jnp.argmax(rows < n, axis=-1)        # first deficit row
        c = jnp.argmax(cols < n, axis=-1)        # first deficit column
        row_r = jnp.take_along_axis(mask, r[:, None, None], axis=1)[:, 0]
        col_c = jnp.take_along_axis(mask, c[:, None, None], axis=2)[:, :, 0]
        s_row = jnp.take_along_axis(sc, r[:, None, None], axis=1)[:, 0]
        s_col = jnp.take_along_axis(sc, c[:, None, None], axis=2)[:, :, 0]
        # swap candidates (r', c2): drop selected (r', c2), add (r, c2)
        # and (r', c); c2 == c / r' == r are self-excluded by the masks
        valid = mask & ~row_r[:, None, :] & ~col_c[:, :, None] \
            & need[:, None, None]
        gain = s_row[:, None, :] + s_col[:, :, None] - sc
        flat = jnp.where(valid, gain, -jnp.inf).reshape(t, m * m)
        best = jnp.argmax(flat, axis=-1)
        rp, c2 = best // m, best % m
        apply = (need & valid.reshape(t, m * m).any(-1))[:, None, None]
        oh = lambda i: slot_ids[None, :] == i[:, None]
        add = (oh(r)[:, :, None] & oh(c2)[:, None, :]) \
            | (oh(rp)[:, :, None] & oh(c)[:, None, :])
        rem = oh(rp)[:, :, None] & oh(c2)[:, None, :]
        return (mask | (add & apply)) & ~(rem & apply)

    mask = jax.lax.fori_loop(0, n * m, repair, mask)

    # guaranteed-valid fallback: top-n cyclic diagonals by summed |x|
    rolled = jax.vmap(lambda s: jnp.stack(
        [jnp.diagonal(jnp.roll(s, -d, axis=1), axis1=0, axis2=1).sum()
         for d in range(m)]))(sc)                # (T, m) diagonal scores
    dsel = _topn_group_mask(rolled, n)           # (T, m) chosen offsets
    i_ = slot_ids[None, :, None]
    j_ = slot_ids[None, None, :]
    fallback = jnp.take_along_axis(
        jnp.broadcast_to(dsel[:, None, :], (t, m, m)),
        jnp.broadcast_to((j_ - i_) % m, (t, m, m)), axis=2)
    ok_tile = (mask.sum(-1) == n).all(-1) & (mask.sum(-2) == n).all(-1)
    mask = jnp.where(ok_tile[:, None, None], mask, fallback)

    mask = mask.reshape(*lead, rt, ct, m, m)
    mask = jnp.moveaxis(mask, -3, -2)
    return mask.reshape(*lead, rdim, cdim)


def nm_mask_shared(
    x: jax.Array, n: int, m: int, axis: int, share_axis: int, tile: int
) -> jax.Array:
    """Mask with the N:M pattern shared across tiles of ``share_axis``.

    The group score is the summed |x| over each tile, so all ``tile``
    columns of an output tile agree on which K-slots survive — allowing a
    reduced-K gathered matmul on the MXU (true FLOP saving).
    """
    if n == m:
        return jnp.ones_like(x, dtype=bool)
    axis = axis % x.ndim
    share_axis = share_axis % x.ndim
    if share_axis == axis:
        raise ValueError("share_axis must differ from group axis")
    s = x.shape[share_axis]
    pad = (-s) % tile
    absx = jnp.abs(x).astype(jnp.float32)
    if pad:
        pw = [(0, 0)] * x.ndim
        pw[share_axis] = (0, pad)
        absx = jnp.pad(absx, pw)
    # sum |x| within each tile of share_axis
    st = absx.shape[share_axis] // tile
    new_shape = list(absx.shape)
    new_shape[share_axis : share_axis + 1] = [st, tile]
    scores = absx.reshape(new_shape).sum(axis=share_axis + 1)
    # scores now has share_axis replaced by the tile index; group axis may
    # have shifted if it was after share_axis... it was reshape in place, so
    # axes after share_axis keep their relative order; compute mask on scores
    g_axis = axis if axis < share_axis else axis  # same position (tile kept)
    tile_mask = nm_mask(scores, n, m, axis=g_axis)
    # broadcast back over the tile
    tile_mask = jnp.repeat(tile_mask, tile, axis=share_axis)
    slicer = [slice(None)] * x.ndim
    slicer[share_axis] = slice(0, s)
    return tile_mask[tuple(slicer)]


def sparsify(
    x: jax.Array,
    cfg: SparsityConfig,
    axis: int = -1,
    share_axis: Optional[int] = None,
) -> jax.Array:
    """x * mask with cfg's N:M pattern along ``axis``."""
    if cfg.is_dense:
        return x
    if cfg.granularity == "shared":
        if share_axis is None:
            share_axis = (axis % x.ndim) - 1 if (axis % x.ndim) else x.ndim - 1
        mask = nm_mask_shared(x, cfg.n, cfg.m, axis, share_axis, cfg.tile)
    else:
        mask = nm_mask(x, cfg.n, cfg.m, axis)
    return jnp.where(mask, x, jnp.zeros_like(x))


# ---------------------------------------------------------------------------
# Compact packed format — the SORE output: (values, indices)
# ---------------------------------------------------------------------------
#
# For a tensor with grouped axis length K (divisible by M), packing keeps the
# N survivors of each group *in ascending index order* (hardware-friendly,
# deterministic) producing:
#     values : same shape but grouped axis length K*N/M
#     indices: uint8, same shape as values, the within-group offsets (0..M-1)
# Memory: values N/M of dense + indices ceil(log2 M) bits (stored as uint8
# here; the Pallas kernels treat them as 4-bit-packable).


def nm_pack(x: jax.Array, n: int, m: int, axis: int = -1):
    """Pack x into N:M compact (values, indices) along ``axis``."""
    xt, inv = _move_axis_last(x, axis)
    k = xt.shape[-1]
    if k % m != 0:
        raise ValueError(f"axis length {k} not divisible by {m}")
    g = xt.reshape(*xt.shape[:-1], k // m, m)
    score = jnp.abs(g).astype(jnp.float32)
    # lax.top_k is stable: on ties the lower index wins, matching nm_mask
    _, idx = jax.lax.top_k(score, n)  # (..., G, N) indices into the group
    idx = jnp.sort(idx, axis=-1)  # ascending order inside the group
    vals = jnp.take_along_axis(g, idx, axis=-1)
    vals = vals.reshape(*xt.shape[:-1], (k // m) * n)
    idx = idx.reshape(*xt.shape[:-1], (k // m) * n).astype(jnp.uint8)
    # inverse-permute back so the packed axis sits where `axis` was
    vals = jnp.transpose(vals, inv)
    idx = jnp.transpose(idx, inv)
    return vals, idx


def nm_pack_from_mask(x: jax.Array, mask: jax.Array, n: int, m: int,
                      axis: int = -1):
    """Pack x into N:M compact (values, indices) given its survivor mask.

    Sort-free alternative to ``nm_pack`` for when the mask already exists
    (the pre-generation WU path): survivors are compacted in ascending
    group offset by a cumsum rank + scatter, so packing adds zero
    top_k/sort ops to the lowered step.  Bitwise-identical output to
    ``nm_pack(x, n, m, axis)`` whenever ``mask == nm_mask(x, n, m, axis)``.
    Leading axes (layer stacks, MoE expert stacks) batch through: only
    the packed ``axis`` shrinks to k*n/m, and ``nm_unpack_n`` inverts it
    exactly (pack keeps values verbatim).
    """
    xt, inv = _move_axis_last(x, axis)
    mt, _ = _move_axis_last(mask, axis)
    k = xt.shape[-1]
    if k % m != 0:
        raise ValueError(f"axis length {k} not divisible by {m}")
    g = xt.reshape(*xt.shape[:-1], k // m, m)
    gm = mt.reshape(*mt.shape[:-1], k // m, m)
    rank = jnp.cumsum(gm.astype(jnp.int32), axis=-1) - 1
    slot = jnp.where(gm, rank, n)  # pruned entries land in an overflow slot
    pos = jax.lax.broadcasted_iota(jnp.int32, g.shape, g.ndim - 1)
    vals = jnp.put_along_axis(
        jnp.zeros((*g.shape[:-1], n + 1), g.dtype), slot, g,
        axis=-1, inplace=False)[..., :n]
    idx = jnp.put_along_axis(
        jnp.zeros((*g.shape[:-1], n + 1), jnp.int32), slot, pos,
        axis=-1, inplace=False)[..., :n]
    vals = vals.reshape(*xt.shape[:-1], (k // m) * n)
    idx = idx.reshape(*xt.shape[:-1], (k // m) * n).astype(jnp.uint8)
    return jnp.transpose(vals, inv), jnp.transpose(idx, inv)


def nm_unpack_n(values: jax.Array, indices: jax.Array, n: int, m: int, axis: int = -1):
    """Scatter compact (values, indices) back to dense; axis length *m/n."""
    vt, inv_perm_src = _move_axis_last(values, axis)
    it, _ = _move_axis_last(indices, axis)
    kn = vt.shape[-1]
    if kn % n != 0:
        raise ValueError(f"packed axis {kn} not divisible by n={n}")
    groups = kn // n
    k = groups * m
    gv = vt.reshape(*vt.shape[:-1], groups, n)
    gi = it.reshape(*it.shape[:-1], groups, n).astype(jnp.int32)
    dense_g = jnp.zeros((*vt.shape[:-1], groups, m), dtype=vt.dtype)
    dense_g = jnp.put_along_axis(dense_g, gi, gv, axis=-1, inplace=False)
    dense = dense_g.reshape(*vt.shape[:-1], k)
    return jnp.transpose(dense, inv_perm_src)


# ---------------------------------------------------------------------------
# 4-bit index packing — two in-group offsets per byte
# ---------------------------------------------------------------------------
#
# An N:M in-group offset needs ceil(log2 M) bits; for every M <= 16 that is
# at most 4, so two consecutive offsets along the compact axis share one
# uint8: entry 2i in the low nibble, entry 2i+1 in the high nibble.  An odd
# compact-axis length zero-pads the final high nibble (the unpacked length
# is an explicit argument of ``unpack_idx_u4``, so the pad never leaks).
# This is the storage format arXiv 2102.04010 argues makes N:M
# hardware-friendly: index HBM traffic halves on a bytes-bound decode.


def pack_idx_u4(idx: jax.Array, axis: int = -1) -> jax.Array:
    """Pack uint8 in-group offsets (< 16) to two-per-byte along ``axis``.

    Output axis length is ``ceil(len/2)``; all other axes are unchanged.
    Bitwise inverse of ``unpack_idx_u4`` for any values < 16 (the N:M
    compact formats guarantee offsets in [0, M) with M <= 16).
    """
    it, inv = _move_axis_last(idx, axis)
    kc = it.shape[-1]
    pad = kc % 2
    if pad:
        it = jnp.pad(it, [(0, 0)] * (it.ndim - 1) + [(0, 1)])
    pairs = it.reshape(*it.shape[:-1], (kc + pad) // 2, 2).astype(jnp.uint8)
    packed = pairs[..., 0] | (pairs[..., 1] << 4)
    return jnp.transpose(packed, inv)


def unpack_idx_u4(packed: jax.Array, kc: int, axis: int = -1) -> jax.Array:
    """Unpack two-per-byte nibbles back to ``kc`` uint8 offsets along ``axis``."""
    pt, inv = _move_axis_last(packed, axis)
    if pt.shape[-1] != (kc + 1) // 2:
        raise ValueError(
            f"packed axis {pt.shape[-1]} does not hold kc={kc} nibbles")
    lo = pt & jnp.uint8(0x0F)
    hi = pt >> 4
    idx = jnp.stack([lo, hi], axis=-1).reshape(*pt.shape[:-1], -1)[..., :kc]
    return jnp.transpose(idx, inv)


# ---------------------------------------------------------------------------
# SR-STE regularized straight-through update term
# ---------------------------------------------------------------------------


def srste_decay(w: jax.Array, mask: jax.Array, lam: float) -> jax.Array:
    """SR-STE's sparse-refined term: decay *pruned* weights toward zero.

    The update becomes  g <- g + lam * (1 - mask) * w , pulling dormant
    weights down so the pattern can still flip when a pruned weight's
    gradient signal is strong (Zhou et al., ICLR'21 eq. 6).
    """
    return jnp.where(mask, jnp.zeros_like(w), w) * lam


# ---------------------------------------------------------------------------
# Introspection helpers used by tests & benchmarks
# ---------------------------------------------------------------------------


def group_nonzeros(x: jax.Array, m: int, axis: int = -1) -> jax.Array:
    """Number of nonzeros per M-group (for property tests)."""
    xt, _ = _move_axis_last(x, axis)
    g = xt.reshape(*xt.shape[:-1], xt.shape[-1] // m, m)
    return (g != 0).sum(axis=-1)


def density(x: jax.Array) -> jax.Array:
    return (x != 0).mean()


def nm_flops_fraction(cfg: SparsityConfig) -> float:
    """Fraction of dense MACs kept by one N:M-sparsified matmul."""
    return 1.0 if cfg.is_dense else cfg.n / cfg.m
