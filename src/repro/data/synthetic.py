"""Deterministic synthetic data pipeline (offline container — no real
corpora).  Seeded per (run, step, host-shard) so restarts resume the
exact stream; batches are placed directly under the step's input
shardings (no host-side gather).

Two generators:
  * token streams with Zipfian unigram structure + a copy-task signal so
    LMs have something learnable (loss curves order meaningfully —
    what the Fig. 4 study needs);
  * CIFAR-like image batches (class-conditional Gaussian blobs) for the
    paper's CNN track.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenTaskConfig:
    vocab: int
    seq: int
    batch: int
    copy_period: int = 16  # every k-th token repeats (learnable structure)
    zipf_a: float = 1.2
    seed: int = 0


def token_batch(cfg: TokenTaskConfig, step: int):
    """(tokens, labels) — labels are next-token targets."""
    rng = np.random.default_rng(np.random.PCG64([cfg.seed, step]))
    ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
    probs = ranks ** -cfg.zipf_a
    probs /= probs.sum()
    toks = rng.choice(cfg.vocab, size=(cfg.batch, cfg.seq + 1), p=probs)
    # inject copy structure: position i repeats position i - copy_period
    for i in range(cfg.copy_period, cfg.seq + 1, cfg.copy_period):
        toks[:, i] = toks[:, i - cfg.copy_period]
    toks = toks.astype(np.int32)
    return toks[:, :-1], toks[:, 1:]


def token_stream(cfg: TokenTaskConfig, start_step: int = 0, shardings=None):
    step = start_step
    while True:
        tokens, labels = token_batch(cfg, step)
        batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        if shardings is not None:
            batch = {k: jax.device_put(v, shardings[k])
                     for k, v in batch.items()}
        yield step, batch
        step += 1


def lm_stream(vocab: int, batch: int, seq: int, *, shardings=None,
              seed: int = 0, start: int = 0, prefix: int = 0,
              d_model: int = 0):
    """Workload-shaped LM stream: (step, {tokens, labels[,prefix_embeds]}).

    prefix > 0 adds stub-frontend embeddings (vlm/audio prefix tokens).
    """
    cfg = TokenTaskConfig(vocab=vocab, seq=seq, batch=batch, seed=seed)
    step = start
    while True:
        tokens, labels = token_batch(cfg, step)
        batch_d = {"tokens": jnp.asarray(tokens),
                   "labels": jnp.asarray(labels)}
        if prefix:
            rng = np.random.default_rng(np.random.PCG64([seed + 7, step]))
            batch_d["prefix_embeds"] = jnp.asarray(
                rng.normal(size=(batch, prefix, d_model)).astype(np.float32),
                dtype=jnp.bfloat16)
        if shardings is not None:
            batch_d = {k: jax.device_put(v, shardings[k])
                       for k, v in batch_d.items() if k in shardings}
        yield step, batch_d
        step += 1


def encdec_stream(vocab: int, batch: int, seq: int, d_model: int, *,
                  enc_frames: int = 128, shardings=None, seed: int = 0,
                  start: int = 0):
    """Whisper-style stream: stub frame embeddings + target tokens."""
    cfg = TokenTaskConfig(vocab=vocab, seq=seq, batch=batch, seed=seed)
    step = start
    while True:
        tokens, labels = token_batch(cfg, step)
        rng = np.random.default_rng(np.random.PCG64([seed + 11, step]))
        frames = rng.normal(size=(batch, enc_frames, d_model))
        batch_d = {"frames": jnp.asarray(frames.astype(np.float32),
                                         dtype=jnp.bfloat16),
                   "tokens": jnp.asarray(tokens),
                   "labels": jnp.asarray(labels)}
        if shardings is not None:
            batch_d = {k: jax.device_put(v, shardings[k])
                       for k, v in batch_d.items() if k in shardings}
        yield step, batch_d
        step += 1


@dataclasses.dataclass(frozen=True)
class ImageTaskConfig:
    image: int = 32
    num_classes: int = 10
    batch: int = 128
    noise: float = 0.6
    seed: int = 0


def image_batch(cfg: ImageTaskConfig, step: int):
    """Class-conditional blobs: learnable but non-trivial."""
    rng = np.random.default_rng(np.random.PCG64([cfg.seed + 1, step]))
    labels = rng.integers(0, cfg.num_classes, size=(cfg.batch,))
    proto_rng = np.random.default_rng(np.random.PCG64([cfg.seed + 2]))
    protos = proto_rng.normal(size=(cfg.num_classes, cfg.image, cfg.image, 3))
    x = protos[labels] + cfg.noise * rng.normal(
        size=(cfg.batch, cfg.image, cfg.image, 3))
    return x.astype(np.float32), labels.astype(np.int32)
