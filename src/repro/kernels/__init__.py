"""Custom-kernel package: Pallas TPU kernels + jnp oracles.

This is the public kernel surface — consumers (core/operand, serve,
benchmarks) import from here instead of deep-importing the private
modules:

  nm_compact(w, n, m, *, idx_bits=8)
      SORE compact packing: dense -> (vals, idx) along the second-to-
      last axis.  ``idx_bits=4`` emits the half-width index plane (two
      in-group offsets per byte, low nibble first, final high nibble
      zero-padded on odd compact extents; requires M <= 16).
  nm_spmm(x, vals, idx, n, m, *, idx_bits=8)
      fused decompress-matmul: the dense weight tile exists only in
      VMEM.  ``idx_bits=4`` expands nibbles inside the kernel tile, so
      the index plane crosses HBM at half width.  The pallas path falls
      back to the bitwise-equal jnp oracle when a u4 tile cannot split
      cleanly (odd compact rows per block); callers never see the
      difference — the two widths are bitwise interchangeable by
      construction and pinned so in tests/test_operand.py.
  nm_spmm_shared / fused_update
      reduced-K shared-pattern matmul; fused SGD + re-sparsify weight
      update (emits u8 or u4 planes to match the operand).
  nm_compact_pallas / nm_spmm_pallas / nm_spmm_shared_pallas /
  fused_update_pallas
      the raw pallas_call wrappers (explicit block sizes) behind the
      jit'd dispatchers above — Pallas on TPU, interpret mode on CPU,
      oracle with ``use_pallas=False``.
  decompress_nm(vals, idx, n, m, *, idx_bits=8)
      the one shared (vals, idx) -> dense N:M expansion (select-based,
      scatter-free) used by the kernel, the oracle and the operand
      fallback alike; unpacks u4 nibbles first when ``idx_bits=4``.
  pack_shared / packed_bytes
      host-side shared-mode packer + HBM byte accounting.
"""

from repro.kernels.fused_update import fused_update_pallas
from repro.kernels.nm_compact import nm_compact_pallas
from repro.kernels.nm_spmm import nm_spmm_pallas
from repro.kernels.nm_spmm_shared import decompress_nm, nm_spmm_shared_pallas
from repro.kernels.ops import (fused_update, nm_compact, nm_spmm,
                               nm_spmm_shared, pack_shared, packed_bytes)

__all__ = [
    "nm_compact", "nm_spmm", "nm_spmm_shared", "fused_update",
    "nm_compact_pallas", "nm_spmm_pallas", "nm_spmm_shared_pallas",
    "fused_update_pallas", "decompress_nm", "pack_shared", "packed_bytes",
]
