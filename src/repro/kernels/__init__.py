"""Custom-kernel package: Pallas TPU kernels + jnp oracles.

This is the public kernel surface — consumers (core/operand, serve,
benchmarks) import from here instead of deep-importing the private
modules:

  nm_compact / nm_spmm / nm_spmm_shared / fused_update
      jit'd dispatchers (kernels.ops): Pallas on TPU, interpret mode on
      CPU, oracle with ``use_pallas=False``.
  nm_spmm_pallas / nm_spmm_shared_pallas / nm_compact_pallas /
  fused_update_pallas
      the raw pallas_call wrappers (explicit block sizes).
  decompress_nm
      the one shared (vals, idx) -> dense N:M expansion (select-based,
      scatter-free) used by the kernel, the oracle and the operand
      fallback alike.
  pack_shared / packed_bytes
      host-side shared-mode packer + HBM byte accounting.
"""

from repro.kernels.fused_update import fused_update_pallas
from repro.kernels.nm_compact import nm_compact_pallas
from repro.kernels.nm_spmm import nm_spmm_pallas
from repro.kernels.nm_spmm_shared import decompress_nm, nm_spmm_shared_pallas
from repro.kernels.ops import (fused_update, nm_compact, nm_spmm,
                               nm_spmm_shared, pack_shared, packed_bytes)

__all__ = [
    "nm_compact", "nm_spmm", "nm_spmm_shared", "fused_update",
    "nm_compact_pallas", "nm_spmm_pallas", "nm_spmm_shared_pallas",
    "fused_update_pallas", "decompress_nm", "pack_shared", "packed_bytes",
]
