"""Fused WUVE + SORE pre-generation — Pallas TPU kernel.

The paper's pre-generation dataflow (Fig. 11c): the optimizer's weight
update is fused with the N:M compaction so the FF/BP stages of the next
iteration load only compact sparse weights — saving external-memory
bandwidth and storage whenever sparsity > 50%.

One grid step performs, on a (TR, TK) fp32 master-weight tile:

  mask  = N:M survivor mask of w (SR-STE's sparse-refined target)
  g_eff = g + wd*w + lam*(1-mask)*w        # SR-STE regularized gradient
  v'    = mu*v + g_eff                     # momentum (fp32, WUVE lane)
  w'    = w - lr*v'
  (vals, idx) = pack_{N:M}(w')             # SORE, fused — bf16 + uint8

lr/mu/wd/lam stream in as (1,1) fp32 scalars so schedules don't retrace.

Wired into training via ``optim/sgd.update(use_pallas=True)``: the
caller moves the FF contraction axis last, the kernel's in-VMEM decay
mask is bitwise-identical to the stored previous-WU mask (both score the
same fp32 master with the same earlier-index tie-break), and its packed
output becomes the pre-generated FF operand of the next step
(tests/test_pregen.py pins jnp-vs-kernel trajectories bitwise).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu

from repro.kernels.nm_compact import _select_topn


def _fused_update_kernel(
    lr_ref, mu_ref, wd_ref, lam_ref,
    w_ref, g_ref, v_ref,
    w_out, v_out, vals_out, idx_out,
    *, n: int, m: int,
):
    tr, tk = w_ref.shape
    w = w_ref[...]
    grp = w.reshape(tr, tk // m, m)
    # survivor mask of the *current* weights (pre-update), per SR-STE
    _, keep_idx = _select_topn(grp, n, m)  # (TR, G, N) ascending
    pos = jax.lax.broadcasted_iota(jnp.int32, grp.shape, 2)
    mask = jnp.zeros(grp.shape, jnp.bool_)
    for j in range(n):
        mask = mask | (pos == keep_idx[..., j][..., None])
    mask = mask.reshape(tr, tk)

    lr = lr_ref[0, 0]
    mu = mu_ref[0, 0]
    wd = wd_ref[0, 0]
    lam = lam_ref[0, 0]

    g_eff = g_ref[...] + wd * w + lam * jnp.where(mask, 0.0, w)
    v_new = mu * v_ref[...] + g_eff
    w_new = w - lr * v_new

    v_out[...] = v_new
    w_out[...] = w_new

    # SORE: pack the updated weights along the last axis
    pv, pi = _select_topn(w_new.reshape(tr, tk // m, m), n, m)
    vals_out[...] = pv.reshape(tr, tk // m * n).astype(vals_out.dtype)
    idx_out[...] = pi.reshape(tr, tk // m * n).astype(jnp.uint8)


def fused_update_pallas(
    w: jax.Array,
    g: jax.Array,
    v: jax.Array,
    lr: jax.Array,
    mu: jax.Array,
    wd: jax.Array,
    lam: jax.Array,
    n: int,
    m: int,
    *,
    block_r: int = 256,
    block_k: int = 512,
    interpret: bool = False,
):
    r, k = w.shape
    block_r = min(block_r, r)
    block_k = min(block_k, k)
    assert r % block_r == 0 and k % block_k == 0 and block_k % m == 0
    kc_blk = block_k // m * n
    grid = (r // block_r, k // block_k)
    scal = lambda: pl.BlockSpec(  # noqa: E731
        (1, 1), lambda i, j: (0, 0), memory_space=pltpu.MemorySpace.SMEM
    )
    blk = lambda bk: pl.BlockSpec(  # noqa: E731
        (block_r, bk), lambda i, j: (i, j), memory_space=pltpu.MemorySpace.VMEM
    )
    as2d = lambda s: jnp.asarray(s, jnp.float32).reshape(1, 1)  # noqa: E731
    return pl.pallas_call(
        functools.partial(_fused_update_kernel, n=n, m=m),
        grid=grid,
        in_specs=[scal(), scal(), scal(), scal(), blk(block_k), blk(block_k), blk(block_k)],
        out_specs=(blk(block_k), blk(block_k), blk(kc_blk), blk(kc_blk)),
        out_shape=(
            jax.ShapeDtypeStruct((r, k), jnp.float32),
            jax.ShapeDtypeStruct((r, k), jnp.float32),
            jax.ShapeDtypeStruct((r, k // m * n), jnp.bfloat16),
            jax.ShapeDtypeStruct((r, k // m * n), jnp.uint8),
        ),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=(
                pltpu.GridDimensionSemantics.PARALLEL,
                pltpu.GridDimensionSemantics.PARALLEL,
            )
        ),
        interpret=interpret,
        name=f"fused_update_{n}_{m}",
    )(as2d(lr), as2d(mu), as2d(wd), as2d(lam), w, g, v)
