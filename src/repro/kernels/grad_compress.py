"""Fused error-feedback gradient compression as Pallas TPU kernels.

The cross-pod sync path ships gradients as N:M packed ``(bf16 vals,
uint8 idx)`` payloads.  Done naively that costs three dense HBM round
trips per bucket (add residual, pack, recompute residual); these two
kernels fuse each side into a single VMEM-resident pass so compression
stays off the critical path (the paper's pre-generation argument,
Fig. 11c, applied to gradients per arXiv 2203.10991):

``grad_compress_pallas``
    (g, err) -> (vals bf16, idx uint8, new_err f32) per tile:
    t = g + err; select top-n |t| per consecutive-m group (same
    greater-than-only tie-break as SORE / ``nm_compact``); the wire
    payload is t rounded to bf16, and the *rounded* value is what the
    new residual subtracts — so error feedback telescopes exactly in
    f32 arithmetic: decoded + new_err == g + err bitwise.

``grad_decompress_mean_pallas``
    All-gathered payloads (P, Kc) -> dense mean (1, K) without ever
    materializing the P dense gradients: each grid step scatters its
    packed tile into registers via m-way selects and reduces over the
    pod axis in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu
from repro.kernels.nm_compact import _select_topn


def _scatter_groups(vals_f32: jax.Array, idx: jax.Array, n: int, m: int):
    """(..., G, n) packed -> (..., G, m) dense, select-based (Mosaic-safe)."""
    shape = vals_f32.shape[:-1] + (m,)
    pos = jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) - 1)
    out = jnp.zeros(shape, jnp.float32)
    for s in range(n):
        sel = pos == idx[..., s : s + 1].astype(jnp.int32)
        out = out + jnp.where(sel, vals_f32[..., s : s + 1], 0.0)
    return out


def _compress_kernel(g_ref, e_ref, vals_ref, idx_ref, err_ref, *, n: int, m: int):
    tr, tk = g_ref.shape
    t = g_ref[...].astype(jnp.float32) + e_ref[...].astype(jnp.float32)
    tg = t.reshape(tr, tk // m, m)
    v, i = _select_topn(tg, n, m)
    sent = v.astype(jnp.bfloat16)
    # the residual must see the *wire* (bf16-rounded) values, so the
    # rounding error is carried forward rather than silently dropped
    dec = _scatter_groups(sent.astype(jnp.float32), i, n, m)
    vals_ref[...] = sent.reshape(tr, (tk // m) * n)
    idx_ref[...] = i.reshape(tr, (tk // m) * n).astype(jnp.uint8)
    err_ref[...] = (tg - dec).reshape(tr, tk)


def grad_compress_pallas(
    g: jax.Array,
    e: jax.Array,
    n: int,
    m: int,
    *,
    block_r: int = 8,
    block_k: int = 1024,
    interpret: bool = False,
):
    """(R, K) grads + residual -> bf16 vals, uint8 idx (R, K*n/m), err (R, K)."""
    r, k = g.shape
    block_r = min(block_r, r)
    block_k = min(block_k, k)
    assert k % m == 0 and block_k % m == 0, (k, block_k, m)
    assert r % block_r == 0 and k % block_k == 0, (r, k, block_r, block_k)
    kc_blk = block_k // m * n
    grid = (r // block_r, k // block_k)
    vmem = pltpu.MemorySpace.VMEM
    out_shape = (
        jax.ShapeDtypeStruct((r, k // m * n), jnp.bfloat16),
        jax.ShapeDtypeStruct((r, k // m * n), jnp.uint8),
        jax.ShapeDtypeStruct((r, k), jnp.float32),
    )
    return pl.pallas_call(
        functools.partial(_compress_kernel, n=n, m=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_k), lambda i, j: (i, j), memory_space=vmem),
            pl.BlockSpec((block_r, block_k), lambda i, j: (i, j), memory_space=vmem),
        ],
        out_specs=(
            pl.BlockSpec((block_r, kc_blk), lambda i, j: (i, j), memory_space=vmem),
            pl.BlockSpec((block_r, kc_blk), lambda i, j: (i, j), memory_space=vmem),
            pl.BlockSpec((block_r, block_k), lambda i, j: (i, j), memory_space=vmem),
        ),
        out_shape=out_shape,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=(
                pltpu.GridDimensionSemantics.PARALLEL,
                pltpu.GridDimensionSemantics.PARALLEL,
            )
        ),
        interpret=interpret,
        name=f"grad_compress_{n}_{m}",
    )(g, e)


def _decompress_mean_kernel(vals_ref, idx_ref, out_ref, *, n: int, m: int):
    p, ck = vals_ref.shape
    v = vals_ref[...].astype(jnp.float32).reshape(p, ck // n, n)
    i = idx_ref[...].reshape(p, ck // n, n)
    dec = _scatter_groups(v, i, n, m)  # (P, G, m)
    out_ref[...] = (dec.sum(axis=0) / p).reshape(1, (ck // n) * m)


def grad_decompress_mean_pallas(
    vals: jax.Array,
    idx: jax.Array,
    n: int,
    m: int,
    *,
    block_c: int = 1024,
    interpret: bool = False,
):
    """All-gathered packed payloads (P, Kc) -> pod-mean dense (1, K) f32."""
    p, kc = vals.shape
    block_c = min(block_c, kc)
    assert kc % n == 0 and block_c % n == 0, (kc, block_c, n)
    assert kc % block_c == 0, (kc, block_c)
    k = kc // n * m
    k_blk = block_c // n * m
    grid = (kc // block_c,)
    vmem = pltpu.MemorySpace.VMEM
    return pl.pallas_call(
        functools.partial(_decompress_mean_kernel, n=n, m=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, block_c), lambda j: (0, j), memory_space=vmem),
            pl.BlockSpec((p, block_c), lambda j: (0, j), memory_space=vmem),
        ],
        out_specs=pl.BlockSpec((1, k_blk), lambda j: (0, j), memory_space=vmem),
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=(pltpu.GridDimensionSemantics.PARALLEL,)
        ),
        interpret=interpret,
        name=f"grad_decompress_mean_{n}_{m}",
    )(vals, idx)
