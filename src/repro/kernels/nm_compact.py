"""SORE — N:M sparse online reduction engine, as a Pallas TPU kernel.

The paper's SORE is a 32-lane array of top-K sorters that turns a dense
M-group stream into (top-N values, within-group indices) in M cycles.
The TPU-native analogue is a VMEM-tiled vector kernel: each grid step
loads a (TR, TK) tile, selects the N largest-|x| per consecutive-M group
with a strictly-earlier-index tie-break (exactly what a greater-than-only
hardware sorter does), and writes the packed (TR, TK*N/M) values and
uint8 offsets.

Selection is done with N rounds of masked max (no argsort — Mosaic-safe),
then an N-element index sorting network so survivors appear in ascending
group offset, matching the ``ref.py``/`nm_pack` layout and the compact
format of Mishra et al. (the paper's [21]).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu

_NEG = -jnp.inf


def _select_topn(g: jax.Array, n: int, m: int):
    """g: (..., G, M) -> (vals (..., G, N), idx (..., G, N)) sorted by idx."""
    f32 = g.astype(jnp.float32)
    pos = jax.lax.broadcasted_iota(jnp.int32, g.shape, g.ndim - 1)
    # ties broken exactly: each round takes the *first* position attaining
    # the max (j = min position where score == max), so earlier index wins.
    score = jnp.abs(f32)
    vals, idxs = [], []
    remaining = score
    for _ in range(n):
        mx = jnp.max(remaining, axis=-1, keepdims=True)
        hit = remaining == mx
        # first position attaining the max
        j = jnp.min(jnp.where(hit, pos, m), axis=-1, keepdims=True)
        sel = pos == j
        vals.append(jnp.sum(jnp.where(sel, g, 0), axis=-1))
        idxs.append(j[..., 0])
        remaining = jnp.where(sel, _NEG, remaining)
    # sort the n (val, idx) pairs ascending by idx — O(n^2) network, n tiny
    for a in range(n):
        for b in range(a + 1, n):
            swap = idxs[a] > idxs[b]
            ia, ib = idxs[a], idxs[b]
            va, vb = vals[a], vals[b]
            idxs[a] = jnp.where(swap, ib, ia)
            idxs[b] = jnp.where(swap, ia, ib)
            vals[a] = jnp.where(swap, vb, va)
            vals[b] = jnp.where(swap, va, vb)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def _compact_kernel(x_ref, vals_ref, idx_ref, *, n: int, m: int,
                    idx_bits: int = 8):
    tr, tk = x_ref.shape
    g = x_ref[...].reshape(tr, tk // m, m)
    v, i = _select_topn(g, n, m)
    kc = (tk // m) * n
    vals_ref[...] = v.reshape(tr, kc).astype(vals_ref.dtype)
    if idx_bits == 4:
        # two offsets per byte, low nibble first — the SORE output in the
        # ceil(log2 M)-bit storage format (arXiv 2102.04010); the byte-wide
        # index never exists outside this tile
        pair = i.reshape(tr, kc // 2, 2).astype(jnp.uint8)
        idx_ref[...] = pair[..., 0] | (pair[..., 1] << 4)
    else:
        idx_ref[...] = i.reshape(tr, kc).astype(jnp.uint8)


def nm_compact_pallas(
    x: jax.Array,
    n: int,
    m: int,
    *,
    block_r: int = 256,
    block_k: int = 512,
    idx_bits: int = 8,
    interpret: bool = False,
):
    """Pack (R, K) -> values (R, K*n/m), idx uint8 along the last axis.

    ``idx_bits=4`` emits the u4 index plane (R, K*n/m/2) straight from
    the selection tile — two in-group offsets per byte, low nibble first
    (``core.sparsity.pack_idx_u4`` layout).  Needs an even per-tile
    compact length, which every even ``n`` guarantees.
    """
    r, k = x.shape
    block_r = min(block_r, r)
    block_k = min(block_k, k)
    assert k % m == 0 and block_k % m == 0, (k, block_k, m)
    assert r % block_r == 0 and k % block_k == 0, (r, k, block_r, block_k)
    kc_blk = block_k // m * n
    if idx_bits == 4:
        assert kc_blk % 2 == 0, (
            f"u4 compact tiles must be even, got block_kc={kc_blk}")
    idx_blk = kc_blk // 2 if idx_bits == 4 else kc_blk
    grid = (r // block_r, k // block_k)
    kc = k // m * n
    out_shape = (
        jax.ShapeDtypeStruct((r, kc), x.dtype),
        jax.ShapeDtypeStruct((r, kc // 2 if idx_bits == 4 else kc),
                             jnp.uint8),
    )
    return pl.pallas_call(
        functools.partial(_compact_kernel, n=n, m=m, idx_bits=idx_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (block_r, block_k),
                lambda i, j: (i, j),
                memory_space=pltpu.MemorySpace.VMEM,
            )
        ],
        out_specs=(
            pl.BlockSpec(
                (block_r, kc_blk),
                lambda i, j: (i, j),
                memory_space=pltpu.MemorySpace.VMEM,
            ),
            pl.BlockSpec(
                (block_r, idx_blk),
                lambda i, j: (i, j),
                memory_space=pltpu.MemorySpace.VMEM,
            ),
        ),
        out_shape=out_shape,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=(
                pltpu.GridDimensionSemantics.PARALLEL,
                pltpu.GridDimensionSemantics.PARALLEL,
            )
        ),
        interpret=interpret,
        name=f"nm_compact_{n}_{m}" + ("_u4" if idx_bits == 4 else ""),
    )(x)
