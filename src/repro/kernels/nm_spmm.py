"""Element-mode N:M sparse x dense matmul — Pallas TPU kernel.

Semantics: ``out = act @ unpack(vals, idx)`` where the weight matrix is
stored in the compact N:M format (values K*N/M of dense, uint8 group
offsets), pattern chosen independently per output column — the paper's
faithful sparsity granularity.

TPU adaptation (see DESIGN.md §2): the MXU cannot skip individual MACs,
so the win here is *memory*: HBM->VMEM weight traffic is N/M of dense
(+1 byte/val of index), which is the dominant term in decode/serving and
in the BP pass of training.  Each grid step:

  1. streams a compact (TKc, TF) value tile + its offsets into VMEM,
  2. decompresses to a dense (TK, TF) tile entirely in VMEM
     (M-way select against the offset plane — no gather needed),
  3. feeds the MXU a dense (TB, TK) x (TK, TF) partial matmul,
  4. accumulates over the K grid axis in an fp32 VMEM tile.

The decompression is O(TK*TF) vector work vs O(TB*TK*TF) MXU work, so it
pipelines away for TB >= 8 (one sublane quantum).

WS/OS note: this grid order keeps the *output* tile stationary in VMEM
across the contraction axis (OS dataflow); the weight tile is re-streamed
— the right choice when weights are compact (small) and outputs are fp32
(large).  The paper's WS mode corresponds to swapping the grid so the
decompressed weight tile persists; XLA's emitted loop structure makes OS
the profitable one on TPU, which we record as a dataflow adaptation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu


def _decompress(vals, idx, n: int, m: int, idx_bits: int = 8):
    """(TKc, TF) packed -> (TK, TF) dense, TK = TKc*m/n.

    Delegates to the package-wide select-based helper (one decompress
    implementation for the kernel, the oracle and the operand fallback).
    With ``idx_bits=4`` the index tile is the u4 plane (TKc//2, TF) and
    the nibble expansion happens here, inside the tile — the byte-wide
    index never exists in HBM and the dense weight never leaves VMEM.
    """
    from repro.kernels.nm_spmm_shared import decompress_nm

    return decompress_nm(vals, idx, n, m, axis=0, idx_bits=idx_bits)


def _spmm_kernel(act_ref, vals_ref, idx_ref, out_ref, *, n: int, m: int,
                 nk: int, idx_bits: int = 8):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w_dense = _decompress(vals_ref[...], idx_ref[...], n, m, idx_bits)
    acc = jnp.dot(
        act_ref[...],
        w_dense.astype(act_ref.dtype),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] += acc


def nm_spmm_pallas(
    act: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    n: int,
    m: int,
    *,
    block_b: int = 128,
    block_f: int = 128,
    block_k: int = 512,
    idx_bits: int = 8,
    interpret: bool = False,
):
    """act (B, K) @ packed weights (Kc=K*n/m, F) -> (B, F) fp32.

    ``idx_bits=4`` consumes the u4-packed index plane (Kc//2, F): the
    index BlockSpec streams half the bytes per tile and the nibble
    expansion is fused into the tile decompress, so decode moves
    ``Kc*F`` value bytes + ``Kc*F/2`` index bytes and nothing dense.
    Requires an even per-tile compact length (any even ``n`` satisfies
    it); ``kernels.ops.nm_spmm`` falls back to jnp otherwise.
    """
    b, k = act.shape
    kc, f = vals.shape
    assert kc * m == k * n, (k, kc, n, m)
    block_b = min(block_b, b)
    block_f = min(block_f, f)
    block_k = min(block_k, k)
    assert b % block_b == 0 and f % block_f == 0 and k % block_k == 0
    assert block_k % m == 0
    block_kc = block_k // m * n
    if idx_bits == 4:
        assert kc % 2 == 0 and block_kc % 2 == 0, (
            f"u4 pallas path needs even compact tiles, got Kc={kc}, "
            f"block_kc={block_kc}")
        assert idx.shape == (kc // 2, f), (idx.shape, kc, f)
        block_kci = block_kc // 2
    else:
        assert idx.shape == vals.shape
        block_kci = block_kc
    nk = k // block_k
    grid = (b // block_b, f // block_f, nk)
    return pl.pallas_call(
        functools.partial(_spmm_kernel, n=n, m=m, nk=nk, idx_bits=idx_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (block_b, block_k),
                lambda i, j, kk: (i, kk),
                memory_space=pltpu.MemorySpace.VMEM,
            ),
            pl.BlockSpec(
                (block_kc, block_f),
                lambda i, j, kk: (kk, j),
                memory_space=pltpu.MemorySpace.VMEM,
            ),
            pl.BlockSpec(
                (block_kci, block_f),
                lambda i, j, kk: (kk, j),
                memory_space=pltpu.MemorySpace.VMEM,
            ),
        ],
        out_specs=pl.BlockSpec(
            (block_b, block_f),
            lambda i, j, kk: (i, j),
            memory_space=pltpu.MemorySpace.VMEM,
        ),
        out_shape=jax.ShapeDtypeStruct((b, f), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=(
                pltpu.GridDimensionSemantics.PARALLEL,
                pltpu.GridDimensionSemantics.PARALLEL,
                pltpu.GridDimensionSemantics.ARBITRARY,
            )
        ),
        interpret=interpret,
        name=f"nm_spmm_{n}_{m}" + ("_u4" if idx_bits == 4 else ""),
    )(act, vals, idx)
