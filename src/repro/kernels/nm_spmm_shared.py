"""Shared-pattern N:M reduced-K matmul — the MXU-native FLOP-saving mode.

Beyond-paper TPU adaptation (DESIGN.md §2): when the N:M survivor pattern
is shared across a 128-wide tile of output columns, the contraction axis
itself can be *gathered and shortened*: instead of decompressing weights
to dense K, we gather the N/M surviving activation columns once per
output tile and contract over Kc = K*N/M.  The MXU then executes N/M of
the dense FLOPs — this recovers on a rigid systolic array the compute
saving that the paper's value-serial USPE achieves per-element on FPGA.

Layout:
  act : (B, K) dense
  vals: (nf, Kc, TF)  per-output-tile packed weights
  rows: (nf, Kc) int32 absolute K indices of the survivors (ascending)
  out : (B, nf*TF) fp32

Grid is (B tiles, F tiles); the full K row-panel of activations for a B
tile is held in VMEM (bounded by ops.py; falls back to the oracle when it
would not fit) and the gather is a one-shot ``jnp.take`` along lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu


# ---------------------------------------------------------------------------
# Shared decompress helper — THE (vals, idx) -> dense expansion
# ---------------------------------------------------------------------------
#
# One implementation of the element-mode N:M decompression, used by the
# nm_spmm Pallas kernel (per VMEM tile), the ref.py oracle and the
# core/operand jnp fallback.  Select-based (an M-way select against the
# offset plane), so it lowers scatter-free — O(K*F) vector work that
# pipelines away against the MXU matmul.  Exact: packed values are kept
# verbatim and every in-group offset hits exactly one slot, so the
# result is bitwise-identical to the scatter formulation
# (core/sparsity.nm_unpack_n).


def unpack_idx_nibbles(idx: jax.Array, kc: int, axis: int) -> jax.Array:
    """Two-per-byte nibble expansion along ``axis`` (low nibble first).

    Kernel-safe inline of ``core.sparsity.unpack_idx_u4`` — interleaves
    ``idx & 0xF`` and ``idx >> 4`` and trims to ``kc`` entries.  Lives
    here so the Pallas tile decompress never imports the core layer.
    """
    axis = axis % idx.ndim
    lo = idx & jnp.uint8(0x0F)
    hi = idx >> 4
    pair = jnp.stack([lo, hi], axis=axis + 1)
    shape = idx.shape[:axis] + (2 * idx.shape[axis],) + idx.shape[axis + 1:]
    return jax.lax.slice_in_dim(pair.reshape(shape), 0, kc, axis=axis)


def decompress_nm(vals: jax.Array, idx: jax.Array, n: int, m: int,
                  axis: int = -1, idx_bits: int = 8) -> jax.Array:
    """(…, Kc, …) packed -> (…, K, …) dense along ``axis``, K = Kc*m/n.

    dense[g*m + s] = sum_j vals[g*n + j] * (idx[g*n + j] == s), unrolled
    over the m slot positions — all ops are selects/adds, no scatter.

    ``idx_bits=4`` accepts the u4-packed index plane (two in-group
    offsets per byte along ``axis``, ceil(Kc/2) bytes); it is expanded
    with :func:`unpack_idx_nibbles` first, so the result is bitwise
    identical to the byte-wide path on the same offsets.
    """
    axis = axis % vals.ndim
    kc = vals.shape[axis]
    if kc % n:
        raise ValueError(f"packed axis {kc} not divisible by n={n}")
    if idx_bits == 4:
        idx = unpack_idx_nibbles(idx, kc, axis)
    elif idx_bits != 8:
        raise ValueError(f"idx_bits must be 4 or 8, got {idx_bits}")
    shape = vals.shape
    g = kc // n
    gshape = shape[:axis] + (g, n) + shape[axis + 1:]
    v = vals.reshape(gshape)
    i = idx.reshape(gshape)
    slots = []
    for s in range(m):
        hit = (i == s)
        slots.append(jnp.sum(jnp.where(hit, v, 0), axis=axis + 1))
    dense = jnp.stack(slots, axis=axis + 1)  # (…, G, M, …)
    return dense.reshape(shape[:axis] + (g * m,) + shape[axis + 1:])


def _spmm_shared_kernel(act_ref, vals_ref, rows_ref, out_ref):
    rows = rows_ref[0, :]  # (Kc,) int32, ascending within each M-group
    act_g = jnp.take(act_ref[...], rows, axis=1)  # (TB, Kc)
    out_ref[...] = jnp.dot(
        act_g,
        vals_ref[0].astype(act_ref.dtype),
        preferred_element_type=jnp.float32,
    )


def nm_spmm_shared_pallas(
    act: jax.Array,
    vals: jax.Array,
    rows: jax.Array,
    *,
    block_b: int = 128,
    interpret: bool = False,
):
    b, k = act.shape
    nf, kc, tf = vals.shape
    assert rows.shape == (nf, kc)
    block_b = min(block_b, b)
    assert b % block_b == 0
    grid = (b // block_b, nf)
    return pl.pallas_call(
        _spmm_shared_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (block_b, k),
                lambda i, j: (i, 0),
                memory_space=pltpu.MemorySpace.VMEM,
            ),
            pl.BlockSpec(
                (1, kc, tf),
                lambda i, j: (j, 0, 0),
                memory_space=pltpu.MemorySpace.VMEM,
            ),
            pl.BlockSpec(
                (1, kc),
                lambda i, j: (j, 0),
                memory_space=pltpu.MemorySpace.VMEM,
            ),
        ],
        out_specs=pl.BlockSpec(
            (block_b, tf),
            lambda i, j: (i, j),
            memory_space=pltpu.MemorySpace.VMEM,
        ),
        out_shape=jax.ShapeDtypeStruct((b, nf * tf), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=(
                pltpu.GridDimensionSemantics.PARALLEL,
                pltpu.GridDimensionSemantics.PARALLEL,
            )
        ),
        interpret=interpret,
        name="nm_spmm_shared",
    )(act, vals, rows)
