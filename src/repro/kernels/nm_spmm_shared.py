"""Shared-pattern N:M reduced-K matmul — the MXU-native FLOP-saving mode.

Beyond-paper TPU adaptation (DESIGN.md §2): when the N:M survivor pattern
is shared across a 128-wide tile of output columns, the contraction axis
itself can be *gathered and shortened*: instead of decompressing weights
to dense K, we gather the N/M surviving activation columns once per
output tile and contract over Kc = K*N/M.  The MXU then executes N/M of
the dense FLOPs — this recovers on a rigid systolic array the compute
saving that the paper's value-serial USPE achieves per-element on FPGA.

Layout:
  act : (B, K) dense
  vals: (nf, Kc, TF)  per-output-tile packed weights
  rows: (nf, Kc) int32 absolute K indices of the survivors (ascending)
  out : (B, nf*TF) fp32

Grid is (B tiles, F tiles); the full K row-panel of activations for a B
tile is held in VMEM (bounded by ops.py; falls back to the oracle when it
would not fit) and the gather is a one-shot ``jnp.take`` along lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu


def _spmm_shared_kernel(act_ref, vals_ref, rows_ref, out_ref):
    rows = rows_ref[0, :]  # (Kc,) int32, ascending within each M-group
    act_g = jnp.take(act_ref[...], rows, axis=1)  # (TB, Kc)
    out_ref[...] = jnp.dot(
        act_g,
        vals_ref[0].astype(act_ref.dtype),
        preferred_element_type=jnp.float32,
    )


def nm_spmm_shared_pallas(
    act: jax.Array,
    vals: jax.Array,
    rows: jax.Array,
    *,
    block_b: int = 128,
    interpret: bool = False,
):
    b, k = act.shape
    nf, kc, tf = vals.shape
    assert rows.shape == (nf, kc)
    block_b = min(block_b, b)
    assert b % block_b == 0
    grid = (b // block_b, nf)
    return pl.pallas_call(
        _spmm_shared_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (block_b, k),
                lambda i, j: (i, 0),
                memory_space=pltpu.MemorySpace.VMEM,
            ),
            pl.BlockSpec(
                (1, kc, tf),
                lambda i, j: (j, 0, 0),
                memory_space=pltpu.MemorySpace.VMEM,
            ),
            pl.BlockSpec(
                (1, kc),
                lambda i, j: (j, 0),
                memory_space=pltpu.MemorySpace.VMEM,
            ),
        ],
        out_specs=pl.BlockSpec(
            (block_b, tf),
            lambda i, j: (i, j),
            memory_space=pltpu.MemorySpace.VMEM,
        ),
        out_shape=jax.ShapeDtypeStruct((b, nf * tf), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=(
                pltpu.GridDimensionSemantics.PARALLEL,
                pltpu.GridDimensionSemantics.PARALLEL,
            )
        ),
        interpret=interpret,
        name="nm_spmm_shared",
    )(act, vals, rows)
