"""Public jit'd wrappers around the Pallas kernels.

On TPU the kernels run compiled; on CPU (this container) they run in
``interpret=True`` mode, which executes the kernel body op-by-op and is
what the test-suite validates against the ``ref.py`` oracles.

``use_pallas=False`` (the default for model code, the dry-run and the
benchmarks) routes to the oracle implementations — XLA fuses them well
and keeps the lowered HLO clean for roofline accounting.  The kernels are
the TPU deployment path; both paths share the exact same semantics, which
the per-kernel allclose sweeps in tests/test_kernels.py enforce.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import sparsity as S
from repro.kernels import ref
from repro.kernels.fused_update import fused_update_pallas
from repro.kernels.grad_compress import (
    grad_compress_pallas,
    grad_decompress_mean_pallas,
)
from repro.kernels.nm_compact import nm_compact_pallas
from repro.kernels.nm_spmm import nm_spmm_pallas
from repro.kernels.nm_spmm_shared import nm_spmm_shared_pallas

# VMEM budget used by the shared-mode act-panel residency check (bytes).
_VMEM_BUDGET = 12 * 1024 * 1024


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit,
                   static_argnames=("n", "m", "use_pallas", "idx_bits"))
def nm_compact(x: jax.Array, n: int, m: int, use_pallas: bool = True,
               idx_bits: int = 8):
    """SORE: pack along the last axis -> (values, uint8 indices).

    ``idx_bits=4`` returns the u4 index plane (two offsets per byte,
    compact axis length ceil(Kc/2)); the Pallas path emits it straight
    from the selection tile, the fallback packs the oracle's bytes.
    """
    if idx_bits not in (4, 8):
        raise ValueError(f"idx_bits must be 4 or 8, got {idx_bits}")
    shape = x.shape
    kc = shape[-1] // m * n
    bk_ok = True
    if use_pallas:
        x2 = x.reshape(-1, shape[-1])
        r, k = x2.shape
        br = _pick_block(r, (256, 128, 64, 32, 16, 8, 4, 2, 1))
        bk = _pick_block(k, (512, 256, 128, 64, 32, 16, 8), multiple_of=m)
        bk_ok = idx_bits == 8 or (bk // m * n) % 2 == 0
    if not use_pallas or not bk_ok:
        v, i = ref.ref_nm_compact(x, n, m)
        if idx_bits == 4:
            i = S.pack_idx_u4(i, axis=-1)
        return v, i
    v, i = nm_compact_pallas(x2, n, m, block_r=br, block_k=bk,
                             idx_bits=idx_bits, interpret=_interpret())
    kci = (kc + 1) // 2 if idx_bits == 4 else kc
    return v.reshape(*shape[:-1], kc), i.reshape(*shape[:-1], kci)


@functools.partial(jax.jit,
                   static_argnames=("n", "m", "use_pallas", "idx_bits"))
def nm_spmm(act, vals, idx, n: int, m: int, use_pallas: bool = True,
            idx_bits: int = 8):
    """Element-mode sparse matmul: (B,K) @ packed(Kc,F) -> (B,F) fp32.

    ``idx_bits=4`` consumes the u4 index plane (ceil(Kc/2), F) — two
    in-group offsets per byte, low nibble first (see
    ``core.sparsity.pack_idx_u4``).  The Pallas path fuses the nibble
    expansion into the tile decompress (half the index HBM traffic, no
    dense weight outside VMEM); shapes the tiled kernel cannot split
    evenly (odd compact tiles — impossible for even n) fall back to the
    oracle.  Both paths are bitwise-identical to ``idx_bits=8`` on the
    same offsets.
    """
    if not use_pallas:
        return ref.ref_nm_spmm(act, vals, idx, n, m, idx_bits=idx_bits)
    b, k = act.shape
    kc, f = vals.shape
    bb = _pick_block(b, (128, 64, 32, 16, 8, 4, 2, 1))
    bf = _pick_block(f, (128, 64, 32, 16, 8))
    bk = _pick_block(k, (512, 256, 128, 64, 32, 16, 8), multiple_of=m)
    if idx_bits == 4 and (kc % 2 or (bk // m * n) % 2):
        # the tiled kernel streams whole bytes of the u4 plane; an odd
        # compact tile would straddle one — route to the fused-free oracle
        return ref.ref_nm_spmm(act, vals, idx, n, m, idx_bits=idx_bits)
    return nm_spmm_pallas(
        act, vals, idx, n, m, block_b=bb, block_f=bf, block_k=bk,
        idx_bits=idx_bits, interpret=_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def nm_spmm_shared(act, vals, rows, use_pallas: bool = True):
    """Shared-pattern reduced-K matmul: true N/M FLOP saving on the MXU."""
    b, k = act.shape
    bb = _pick_block(b, (128, 64, 32, 16, 8, 4, 2, 1))
    panel_bytes = bb * k * act.dtype.itemsize
    if not use_pallas or panel_bytes > _VMEM_BUDGET:
        return ref.ref_nm_spmm_shared(act, vals, rows)
    return nm_spmm_shared_pallas(act, vals, rows, block_b=bb, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("n", "m", "use_pallas"))
def fused_update(w, g, v, lr, mu, wd, lam, n: int, m: int, use_pallas: bool = True):
    """Momentum-SGD + SR-STE decay + N:M pre-generation, fused."""
    if not use_pallas:
        return ref.ref_fused_update(w, g, v, lr=lr, mu=mu, wd=wd, lam=lam, n=n, m=m)
    shape = w.shape
    w2 = w.reshape(-1, shape[-1])
    g2 = g.reshape(-1, shape[-1]).astype(jnp.float32)
    v2 = v.reshape(-1, shape[-1])
    r, k = w2.shape
    br = _pick_block(r, (256, 128, 64, 32, 16, 8, 4, 2, 1))
    bk = _pick_block(k, (512, 256, 128, 64, 32, 16, 8), multiple_of=m)
    nw, nv, vals, idx = fused_update_pallas(
        w2, g2, v2, lr, mu, wd, lam, n, m, block_r=br, block_k=bk,
        interpret=_interpret(),
    )
    kc = k // m * n
    return (
        nw.reshape(shape),
        nv.reshape(shape),
        vals.reshape(*shape[:-1], kc),
        idx.reshape(*shape[:-1], kc),
    )


def _jnp_grad_compress(g, err, n: int, m: int):
    """Vectorized jnp EF compress, bitwise-identical to ``ref_grad_compress``.

    The oracle spells the semantics with ``nm_pack``/``nm_unpack_n``
    (top_k + sort + scatter) — readable, but those lower to per-group
    variadic sorts and scatters that dominate the sync step on XLA CPU.
    This path gets the same bits from branchless elementwise ops only:

      * selection: n rounds of masked argmax.  ``jnp.argmax`` keeps the
        *first* occurrence on ties, which is exactly ``lax.top_k``'s
        stable lower-index-wins rule, so the survivor sets and packed
        order (ascending offset after the n-element sort) agree with the
        oracle on every tie pattern.
      * ordering: the n selected offsets are distinct, so an exchange
        (bubble) network of ``minimum``/``maximum`` pairs yields the same
        ascending order as ``jnp.sort`` — without the variadic per-group
        sort XLA CPU would otherwise emit (~10x slower at slab sizes).
      * residual: no decode/scatter at all.  The decoded payload equals
        ``bf16(t)`` at survivor lanes and 0 elsewhere, so
        ``t - decode(payload)`` is just ``where(survivor, t - bf16(t), t)``
        — elementwise, and bitwise the same f32 subtraction the oracle
        performs.

    tests/test_grad_compress.py pins the bitwise equality property.
    """
    t = g.astype(jnp.float32) + err.astype(jnp.float32)
    k = t.shape[-1]
    gg = t.reshape(*t.shape[:-1], k // m, m)
    score = jnp.abs(gg)
    offs = jnp.arange(m, dtype=jnp.int32)
    masked = score
    sel = []
    for _ in range(n):
        i = jnp.argmax(masked, axis=-1).astype(jnp.int32)
        sel.append(i)
        masked = jnp.where(offs == i[..., None], -jnp.inf, masked)
    for a in range(n - 1):
        for b in range(n - 1 - a):
            lo = jnp.minimum(sel[b], sel[b + 1])
            hi = jnp.maximum(sel[b], sel[b + 1])
            sel[b], sel[b + 1] = lo, hi
    idx = jnp.stack(sel, axis=-1)
    vals = jnp.take_along_axis(gg, idx, axis=-1)
    survivor = jnp.zeros(gg.shape, bool)
    for i in sel:
        survivor = survivor | (offs == i[..., None])
    rounded = gg.astype(jnp.bfloat16).astype(jnp.float32)
    new_err = jnp.where(survivor, gg - rounded, gg).reshape(t.shape)
    kc = k // m * n
    return (vals.astype(jnp.bfloat16).reshape(*t.shape[:-1], kc),
            idx.reshape(*t.shape[:-1], kc).astype(jnp.uint8),
            new_err)


def _jnp_grad_decompress_mean(vals, idx, n: int, m: int):
    """Vectorized pod-mean decompress, bitwise == ``ref_grad_decompress_mean``.

    One-hot multiply-accumulate instead of the oracle's scatter: XLA CPU
    lowers ``put_along_axis`` to a serial per-group scatter loop, while
    the (P, G, n, m) one-hot contraction stays a fused elementwise kernel
    (~5x faster at sync-slab sizes).
    """
    p, kc = vals.shape
    gv = vals.astype(jnp.float32).reshape(p, kc // n, n)
    gi = idx.reshape(p, kc // n, n).astype(jnp.int32)
    offs = jnp.arange(m, dtype=jnp.int32)
    dense = jnp.sum(gv[..., None] * (gi[..., None] == offs), axis=-2)
    return dense.reshape(p, kc // n * m).mean(axis=0)


@functools.partial(jax.jit, static_argnames=("n", "m", "use_pallas"))
def grad_compress(g, err, n: int, m: int, use_pallas: bool = True):
    """Fused EF compress: (g+err) -> (bf16 vals, uint8 idx, new residual).

    Accepts any shape whose last axis is divisible by m (the sync path
    passes (n_pods, bucket) slabs).  Telescoping is exact: the decoded
    payload plus the returned residual equals g + err bitwise in f32.
    """
    if not use_pallas:
        return _jnp_grad_compress(g, err, n, m)
    shape = g.shape
    g2 = g.reshape(-1, shape[-1]).astype(jnp.float32)
    e2 = err.reshape(-1, shape[-1]).astype(jnp.float32)
    r, k = g2.shape
    br = _pick_block(r, (8, 4, 2, 1))
    bk = _pick_block(k, (2048, 1024, 512, 256, 128, 64, 32, 16, 8),
                     multiple_of=m)
    vals, idx, new_err = grad_compress_pallas(
        g2, e2, n, m, block_r=br, block_k=bk, interpret=_interpret()
    )
    kc = k // m * n
    return (
        vals.reshape(*shape[:-1], kc),
        idx.reshape(*shape[:-1], kc),
        new_err.reshape(shape),
    )


@functools.partial(jax.jit, static_argnames=("n", "m", "use_pallas"))
def grad_decompress_mean(vals, idx, n: int, m: int, use_pallas: bool = True):
    """All-gathered payloads (P, Kc) -> pod-mean dense gradient (K,) f32."""
    if not use_pallas:
        return _jnp_grad_decompress_mean(vals, idx, n, m)
    p, kc = vals.shape
    bc = _pick_block(kc, (2048, 1024, 512, 256, 128, 64, 32, 16, 8),
                     multiple_of=n)
    out = grad_decompress_mean_pallas(
        vals, idx, n, m, block_c=bc, interpret=_interpret()
    )
    return out.reshape(kc // n * m)


def pack_shared(w: jax.Array, n: int, m: int, tile: int = 128):
    """Host-side packer for the shared mode: (K,F) -> (nf, Kc, TF), rows.

    Pattern is chosen per F-tile by summed |w| over the tile (the same
    scoring the shared-granularity mask in core/sparsity uses), so the
    kernel and ``sparsify(granularity='shared')`` agree exactly.
    """
    k, f = w.shape
    assert f % tile == 0 and k % m == 0
    nf = f // tile
    wt = w.reshape(k, nf, tile)
    score = jnp.abs(wt).astype(jnp.float32).sum(-1)  # (K, nf)
    gsc = score.reshape(k // m, m, nf)
    mask = S.nm_mask(gsc.transpose(2, 0, 1).reshape(nf, -1), n, m, axis=-1)
    mask = mask.reshape(nf, k // m, m)
    # rows: absolute K index of each survivor, ascending
    _, gidx = jax.lax.top_k(
        jnp.where(mask, 1.0, 0.0)
        - jnp.arange(m, dtype=jnp.float32)[None, None, :] * 1e-3,
        n,
    )
    gidx = jnp.sort(gidx, axis=-1)  # (nf, K/m, n)
    base = (jnp.arange(k // m) * m)[None, :, None]
    rows = (gidx + base).reshape(nf, -1).astype(jnp.int32)  # (nf, Kc)
    vals = jax.vmap(lambda r, wti: jnp.take(wti, r, axis=0), in_axes=(0, 1))(
        rows, wt
    )  # (nf, Kc, tile)
    return vals, rows


def packed_bytes(k: int, f: int, n: int, m: int, dtype_bytes: int = 2,
                 idx_bits: int = 8) -> int:
    """HBM footprint of an element-mode packed (K,F) weight."""
    kc = k // m * n
    return kc * f * dtype_bytes + kc * f * idx_bits // 8


def _pick_block(dim: int, candidates, multiple_of: int = 1) -> int:
    for c in candidates:
        if c % multiple_of == 0 and dim % c == 0 and c <= dim:
            return c
    return dim
