"""Version-compat facade over ``jax.experimental.pallas.tpu``.

The Pallas TPU API renamed several symbols across JAX releases:

  new (>= 0.5.x)              old (0.4.x, this container)
  ------------------------    ---------------------------------
  MemorySpace                 TPUMemorySpace
  CompilerParams              TPUCompilerParams
  GridDimensionSemantics.X    the strings "parallel"/"arbitrary"

Kernels import this module *as* ``pltpu`` and write against the new
spelling; on older JAX the aliases below resolve to the old names, and
every other attribute falls through to the real module.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

MemorySpace = getattr(_pltpu, "MemorySpace", None) \
    or getattr(_pltpu, "TPUMemorySpace")
CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or getattr(_pltpu, "TPUCompilerParams")

if hasattr(_pltpu, "GridDimensionSemantics"):
    GridDimensionSemantics = _pltpu.GridDimensionSemantics
else:
    class GridDimensionSemantics:
        """Old API: dimension_semantics takes plain strings."""
        PARALLEL = "parallel"
        ARBITRARY = "arbitrary"


def __getattr__(name):
    return getattr(_pltpu, name)
