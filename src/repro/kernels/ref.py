"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` function defines the exact semantics the corresponding
kernel must match (tests assert allclose across shape/dtype sweeps).
These are also the implementations used on non-TPU backends and inside
the multi-pod dry-run (XLA fuses them well, and they keep the lowered
HLO clean for roofline accounting).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sparsity as S


def ref_nm_compact(x: jax.Array, n: int, m: int):
    """SORE oracle: pack x N:M along the last axis -> (values, indices)."""
    return S.nm_pack(x, n, m, axis=-1)


def ref_nm_spmm(act: jax.Array, vals: jax.Array, idx: jax.Array, n: int, m: int,
                idx_bits: int = 8):
    """Element-mode N:M sparse matmul oracle.

    act:  (B, K) dense activations
    vals: (Kc, F) packed weight values, Kc = K*n/m, pattern along K per column
    idx:  (Kc, F) uint8 within-group offsets — or the u4 plane
          (ceil(Kc/2), F) with ``idx_bits=4``, two offsets per byte
    out:  (B, F) fp32
    """
    from repro.kernels.nm_spmm_shared import decompress_nm

    w = decompress_nm(vals, idx, n, m, axis=0, idx_bits=idx_bits)
    return jnp.dot(act, w.astype(act.dtype), preferred_element_type=jnp.float32)


def ref_nm_spmm_shared(act: jax.Array, vals: jax.Array, rows: jax.Array):
    """Shared-pattern reduced-K matmul oracle.

    act:  (B, K)
    vals: (nf_tiles, Kc, TF) per-output-tile packed weights
    rows: (nf_tiles, Kc) int32 absolute K-row of each packed slot
    out:  (B, nf_tiles*TF) fp32
    """
    def per_tile(v, r):
        a = jnp.take(act, r, axis=1)  # (B, Kc)
        return jnp.dot(a, v.astype(act.dtype), preferred_element_type=jnp.float32)

    outs = jax.vmap(per_tile, in_axes=(0, 0), out_axes=1)(vals, rows)
    return outs.reshape(act.shape[0], -1)


def ref_grad_compress(g: jax.Array, err: jax.Array, n: int, m: int):
    """EF compress oracle: (g, err) -> (bf16 vals, uint8 idx, new_err f32).

    t = g + err; top-n |t| per consecutive-m group along the last axis;
    the wire payload is bf16, and the residual subtracts the *rounded*
    values so error feedback telescopes exactly: decoded + new_err ==
    g + err bitwise in f32.
    """
    t = (g.astype(jnp.float32) + err.astype(jnp.float32))
    vals, idx = S.nm_pack(t, n, m, axis=-1)
    sent = vals.astype(jnp.bfloat16)
    dec = S.nm_unpack_n(sent.astype(jnp.float32), idx, n, m, axis=-1)
    return sent, idx, t - dec


def ref_grad_decompress_mean(vals: jax.Array, idx: jax.Array, n: int, m: int):
    """Pod-mean decompress oracle: (P, Kc) payloads -> (K,) dense f32."""
    dec = S.nm_unpack_n(vals.astype(jnp.float32), idx, n, m, axis=-1)
    return dec.mean(axis=0)


def ref_fused_update(
    w: jax.Array,
    g: jax.Array,
    v: jax.Array,
    *,
    lr: float,
    mu: float,
    wd: float,
    lam: float,
    n: int,
    m: int,
):
    """WUVE + SORE pre-generation oracle (momentum SGD, fp32 master).

    Returns (new_w fp32, new_v fp32, wff_vals bf16, wff_idx uint8) where the
    packed pair is the N:M compaction of the *updated* weights along the
    last axis (the FF contraction axis) — the paper's pre-generation
    dataflow: FF never reloads dense weights.
    """
    mask = S.nm_mask(w, n, m, axis=-1)
    g_eff = g + wd * w + lam * jnp.where(mask, 0.0, w)
    new_v = mu * v + g_eff
    new_w = w - lr * new_v
    vals, idx = S.nm_pack(new_w, n, m, axis=-1)
    return new_w, new_v, vals.astype(jnp.bfloat16), idx
