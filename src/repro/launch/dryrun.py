import os

from repro.launch.spmd import force_host_devices

force_host_devices(512)  # before any backend touch; preserves XLA_FLAGS

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell, build the real train/serve
step with its resolved shardings, ``.lower().compile()`` it against the
production mesh — single-pod (16x16 = 256 chips) and multi-pod
(2x16x16 = 512 chips) — with ShapeDtypeStruct stand-ins (zero device
allocation), then extract:

  * ``compiled.memory_analysis()``  — proves the cell fits per-chip HBM,
  * ``compiled.cost_analysis()``    — HLO FLOPs / bytes for the roofline,
  * the optimized HLO's collective ops (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute) — summed into
    per-chip link-byte traffic for the collective roofline term.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
  python -m repro.launch.dryrun --all --out results/dryrun
Failures (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system — the process exits nonzero.
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_arch, lm_input_specs
from repro.core.sparsity import SparsityConfig
from repro.launch import hlo_cost
from repro.launch import mesh as M
from repro.optim import sgd

# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------


def roofline_terms(cost: dict, coll: dict) -> dict:
    """Three terms in seconds (per chip: SPMD cost_analysis is the
    per-device partitioned program)."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    t_c = flops / M.PEAK_FLOPS
    t_m = byts / M.HBM_BW
    t_x = coll["total"] / M.ICI_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    bound = max(t_c, t_m, t_x)
    return {"t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
            "dominant": dom,
            "roofline_frac": (t_c / bound if bound else 0.0),
            "hlo_flops": flops, "hlo_bytes": byts}


def model_flops(arch, shape, chips: int) -> float:
    """Useful-work FLOPs per chip per step: 6·N_active·D (train) or
    2·N_active·D (serve fwd), D = tokens processed this step."""
    cfg = arch.full
    n_act = (cfg.n_active_params() if hasattr(cfg, "n_active_params")
             else cfg.n_params())
    if shape.kind == "train":
        d = shape.batch * shape.seq
        mult = 6.0
    elif shape.kind == "prefill":
        d = shape.batch * shape.seq
        mult = 2.0
    else:  # decode: one new token per sequence
        d = shape.batch
        mult = 2.0
    return mult * n_act * d / chips


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------


def lower_cell(arch, shape, mesh, sp_cfg: SparsityConfig, *,
               seq_parallel: bool = False, packed_serve: bool = False,
               compress: bool = False):
    """Build + lower one cell; returns the Lowered object.

    seq_parallel: sequence-parallel activations (train cells).
    packed_serve: shared-mode reduced-K packed weights (serve cells).
    """
    from repro.models import encdec as E
    from repro.models import transformer_lm as T
    from repro.train import step as ST

    cfg = arch.full
    opt_cfg = sgd.SGDConfig()
    specs = lm_input_specs(arch, shape)

    def f32s(tree):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), tree)

    def bf16s(tree):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16), tree)

    if shape.kind == "train":
        if arch.family == "encdec":
            bundle = ST.build_encdec_train(cfg, mesh, sp_cfg, opt_cfg,
                                           donate=False)
            params, _ = E.init(jax.random.PRNGKey(0), cfg, abstract=True)
        else:
            use_c = compress and "pod" in mesh.axis_names
            bundle = ST.build_lm_train(cfg, mesh, sp_cfg, opt_cfg,
                                       donate=False,
                                       seq_parallel=seq_parallel,
                                       compress=use_c)
            params, _ = T.init(jax.random.PRNGKey(0), cfg, abstract=True)
        state = {"master": f32s(params), "momentum": f32s(params),
                 "step": jax.ShapeDtypeStruct((), jnp.int32)}
        if shape.kind == "train" and arch.family != "encdec" and \
                compress and "pod" in mesh.axis_names:
            state["err"] = f32s(params)
        # the pre-generated compute tree (abstract, zero allocation)
        state["compute"] = ST.abstract_compute_tree(f32s(params), sp_cfg)
        return bundle.step_fn.lower(state, specs)

    long_ctx = shape.shape_id == "long_500k"
    if arch.family == "encdec":
        params, _ = E.init(jax.random.PRNGKey(0), cfg, abstract=True)
        params = bf16s(params)
        if shape.kind == "prefill":
            bundle = ST.build_encdec_serve(cfg, mesh, sp_cfg, specs,
                                           prefill=True)
            return bundle.step_fn.lower(params, specs)
        bundle = ST.build_encdec_serve(cfg, mesh, sp_cfg, specs)
        return bundle.step_fn.lower(params, specs["cache"],
                                    specs["enc_out"], specs["token"],
                                    specs["pos"])
    from repro.core import bdwp as B

    params, _ = T.init(jax.random.PRNGKey(0), cfg, abstract=True)
    params = bf16s(params)
    if packed_serve:
        params = B.pack_tree_shared(params, sp_cfg)
    if shape.kind == "prefill":
        bundle = ST.build_lm_serve(cfg, mesh, sp_cfg, specs,
                                   long_context=long_ctx, prefill=True,
                                   packed=packed_serve)
        return bundle.step_fn.lower(params, specs)
    bundle = ST.build_lm_serve(cfg, mesh, sp_cfg, specs,
                               long_context=long_ctx, packed=packed_serve)
    return bundle.step_fn.lower(params, specs["cache"], specs["token"],
                                specs["pos"])


def run_cell(arch_id: str, shape_id: str, *, multi_pod: bool,
             sp_cfg: SparsityConfig, verbose: bool = True,
             seq_parallel: bool = False, packed_serve: bool = False,
             compress: bool = False) -> dict:
    arch = get_arch(arch_id)
    shape = SHAPES[shape_id]
    rec = {"arch": arch_id, "shape": shape_id,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "method": sp_cfg.method, "nm": f"{sp_cfg.n}:{sp_cfg.m}",
           "granularity": sp_cfg.granularity}
    if not arch.supports(shape_id):
        rec.update(status="skip", reason=arch.skip_reason(shape_id))
        return rec
    mesh = M.make_production_mesh(multi_pod=multi_pod)
    chips = M.mesh_chips(mesh)
    t0 = time.perf_counter()
    lowered = lower_cell(arch, shape, mesh, sp_cfg,
                         seq_parallel=seq_parallel,
                         packed_serve=packed_serve, compress=compress)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()

    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):
        xla_cost = xla_cost[0]
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception:  # CPU backend may not implement it
        mem_rec = {}
    # structural analysis with while-body trip expansion (hlo_cost.py) —
    # XLA's cost_analysis counts scan bodies once and would be ~n_layers off
    analysis = hlo_cost.analyze(compiled.as_text())
    coll = analysis["collectives"]
    terms = roofline_terms({"flops": analysis["flops"],
                            "bytes accessed": analysis["bytes"]}, coll)
    mf = model_flops(arch, shape, chips)
    rec.update(
        status="ok", chips=chips,
        lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
        memory=mem_rec, collectives=coll, **terms,
        model_flops=mf,
        useful_ratio=(mf / terms["hlo_flops"] if terms["hlo_flops"] else 0.0),
        xla_cost={"flops": xla_cost.get("flops"),
                  "bytes": xla_cost.get("bytes accessed")},
    )
    if verbose:
        print(f"[ok] {arch_id:22s} {shape_id:12s} {rec['mesh']:8s} "
              f"Tc={terms['t_compute']*1e3:9.3f}ms "
              f"Tm={terms['t_memory']*1e3:9.3f}ms "
              f"Tx={terms['t_collective']*1e3:9.3f}ms "
              f"dom={terms['dominant']:10s} "
              f"useful={rec['useful_ratio']:.2f} "
              f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")
    return rec


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) cell")
    ap.add_argument("--method", default="bdwp",
                    choices=["dense", "srste", "sdgp", "sdwp", "bdwp"])
    ap.add_argument("--nm", default="2:8")
    ap.add_argument("--granularity", default="element",
                    choices=["element", "shared"])
    ap.add_argument("--out", default=None, help="JSON output directory")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="sequence-parallel activations (train cells)")
    ap.add_argument("--packed-serve", action="store_true",
                    help="shared-mode reduced-K packed weights (serve)")
    ap.add_argument("--compress", action="store_true",
                    help="N:M cross-pod gradient compression (multi-pod)")
    args = ap.parse_args(argv)

    n, m = (int(v) for v in args.nm.split(":"))
    sp_cfg = SparsityConfig(n=n, m=m, method=args.method,
                            granularity=args.granularity)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        cells = [(a, s) for a in sorted(ARCHS) for s in SHAPES]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    records, failures = [], []
    for arch_id, shape_id in cells:
        for mp in meshes:
            try:
                rec = run_cell(arch_id, shape_id, multi_pod=mp,
                               sp_cfg=sp_cfg,
                               seq_parallel=args.seq_parallel,
                               packed_serve=args.packed_serve,
                               compress=args.compress)
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": arch_id, "shape": shape_id,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "fail", "error": f"{type(e).__name__}: {e}"}
                failures.append(rec)
            records.append(rec)
            if rec["status"] == "skip":
                print(f"[skip] {arch_id:22s} {shape_id:12s} "
                      f"{rec['mesh']:8s} {rec['reason']}")

    if args.out:
        os.makedirs(args.out, exist_ok=True)
        variant = ("_sp" if args.seq_parallel else "") + \
            ("_packed" if args.packed_serve else "")
        suffix = (f"{args.method}_{n}x{m}_{args.granularity}_"
                  f"{args.mesh}{variant}")
        path = os.path.join(args.out, f"dryrun_{suffix}.json")
        with open(path, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {path} ({len(records)} records)")

    ok = sum(r["status"] == "ok" for r in records)
    sk = sum(r["status"] == "skip" for r in records)
    print(f"\n{ok} ok, {sk} skip, {len(failures)} fail")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
