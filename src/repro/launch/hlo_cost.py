"""Structural HLO cost model with while-loop trip-count expansion.

XLA's built-in ``compiled.cost_analysis()`` counts a ``while`` body ONCE.
Scan-over-layers models (every LM here) would under-count FLOPs, memory
traffic and collective bytes by ~n_layers, so the roofline table would be
garbage.  This module parses the optimized HLO text into computations,
walks the entry computation and multiplies each ``while`` body/cond by
its ``known_trip_count`` backend_config (annotated by XLA's
WhileLoopTripCountAnnotator), recursing through nested loops, calls,
fusions and conditionals (max over branches).

Per-op accounting (per-device, since SPMD modules are per-partition):
  flops:
    dot          2 * numel(result) * prod(contracting dims)
    convolution  2 * numel(result) * prod(kernel spatial) * C_in/groups
    elementwise  numel(result)   (cheap; dots dominate)
  memory bytes (HBM traffic — reads = operand bytes, writes = result):
    counted for top-level "real" ops; free ops (bitcast, tuple, GTE,
    parameter) cost nothing; fusions count boundary traffic only (their
    internals live in registers/cache — the XLA fusion contract);
    dynamic-slice / dynamic-update-slice count slice-sized traffic.
  collective link bytes (per chip, ring accounting):
    all-reduce 2·s·(g-1)/g | all-gather s·(g-1)/g | reduce-scatter
    s·(g-1)   | all-to-all s·(g-1)/g | collective-permute s

Pod-crossing attribution: with ``pod_block`` (devices per pod; the pod
axis is the mesh's outermost, so pod(id) = id // pod_block), each
collective's replica_groups / source_target_pairs are parsed and its
link bytes are additionally booked as *pod-crossing* when any group or
pair spans two pods.  This is what benchmarks/spmd_bench.py feeds its
emulated inter-pod link model: intra-pod collectives ride the fast
fabric, pod-crossing ones are charged at the modeled link bandwidth.
"""

from __future__ import annotations

import dataclasses
import math
import re
from functools import lru_cache
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_CALL_ATTR_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_FULL_RE = re.compile(r"replica_groups=\{\{(.*?)\}\}")
_GROUPS_IOTA_FULL_RE = re.compile(
    r"replica_groups=\[([\d,]+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{\{(.*?)\}\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")
_WINDOW_RE = re.compile(r"window=\{size=([\dx]+)")

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "opt-barrier",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """All dtype[shape] occurrences in a type string (handles tuples)."""
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _numel(shape) -> int:
    return math.prod(shape) if shape else 1


def _bytes_of(type_text: str) -> int:
    return sum(_numel(s) * _DTYPE_BYTES[d] for d, s in _parse_shapes(type_text))


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    type_text: str       # result type(s)
    operands: List[str]  # operand op names
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]          # param name -> type text
    ops: List[Op]
    table: Dict[str, str]           # op name -> result type text
    root: Optional[str] = None      # ROOT op name
    is_entry: bool = False          # the module's ENTRY computation

    def root_op(self) -> Optional[Op]:
        for op in self.ops:
            if op.name == self.root:
                return op
        return self.ops[-1] if self.ops else None


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Optional[dict] = None
    coll_count: int = 0
    cross: Optional[dict] = None   # pod-crossing subset of coll

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in _COLLECTIVES}
        if self.cross is None:
            self.cross = {k: 0.0 for k in _COLLECTIVES}

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        self.coll_count += int(other.coll_count * times)
        for k in _COLLECTIVES:
            self.coll[k] += other.coll[k] * times
            self.cross[k] += other.cross[k] * times

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())

    @property
    def cross_bytes(self) -> float:
        return sum(self.cross.values())


# ---------------------------------------------------------------------------
# Parsing
# ---------------------------------------------------------------------------


def _split_top_args(argstr: str) -> List[str]:
    """Split 'a, b, c' at depth 0 (parens/braces/brackets nested)."""
    parts, depth, cur = [], 0, []
    for ch in argstr:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return parts


_OP_RE = re.compile(
    r"^(\(?[a-z0-9\[\],{}\/ *#:]+?\)?)\s+([\w\-]+)\((.*)$")


def _balanced(text: str, start: int) -> int:
    """Index just past the paren that matches text[start] == '('."""
    depth = 0
    for i in range(start, len(text)):
        if text[i] == "(":
            depth += 1
        elif text[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(text)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    comment_re = re.compile(r"/\*.*?\*/")
    for raw in text.splitlines():
        line = comment_re.sub("", raw).rstrip()
        if not line or line.startswith(("HloModule", "//", "#")):
            continue
        # computation header: [ENTRY] %name (params...) -> type {
        if line.endswith("{") and "->" in line and "=" not in line.split("->")[0]:
            hdr = _COMP_NAME_RE.match(line.strip())
            if hdr:
                popen = line.index("(", hdr.start(1))
                pclose = _balanced(line, popen)
                param_text = line[popen + 1: pclose - 1]
                params = {}
                for part in _split_top_args(param_text):
                    if ":" in part:
                        pname, ptype = part.split(":", 1)
                        params[pname.strip().lstrip("%")] = ptype.strip()
                cur = Computation(hdr.group(1), params, [], dict(params),
                                  is_entry=line.lstrip().startswith("ENTRY"))
                comps[cur.name] = cur
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        d = _DEF_RE.match(line)
        if not d:
            continue
        name, rest = d.group(1), d.group(2)
        is_root = line.lstrip().startswith("ROOT ")
        m = _OP_RE.match(rest)
        if not m:
            continue
        type_text, kind, tail = m.groups()
        if is_root:
            cur.root = name
        # operand list = everything until the matching close paren
        depth, i = 1, 0
        while i < len(tail) and depth:
            if tail[i] in "([{":
                depth += 1
            elif tail[i] in ")]}":
                depth -= 1
            i += 1
        arg_text = tail[: i - 1] if depth == 0 else tail
        # Operand parts are either bare names ("%p0") or, in newer XLA
        # dumps, inline-typed ("f32[32,48]{1,0} %Arg_0.1") — take the
        # trailing %name of each top-level part either way.
        operands = []
        for part in _split_top_args(arg_text):
            names = _OPERAND_NAME_RE.findall(part)
            if names:
                operands.append(names[-1])
        op = Op(name, kind, type_text.strip(), operands, line)
        cur.ops.append(op)
        cur.table[name] = op.type_text
    return comps


def entry_computation(comps: Dict[str, Computation]) -> Optional[Computation]:
    """The module's ENTRY computation (falls back to the one named
    ``main``-ish, then the last parsed — older dumps drop the keyword)."""
    for comp in comps.values():
        if comp.is_entry:
            return comp
    for comp in comps.values():
        if comp.name.startswith("main"):
            return comp
    return next(reversed(comps.values()), None) if comps else None


def entry_param_shapes(text: str) -> List[Tuple[str, str, Tuple[int, ...]]]:
    """(param_name, dtype, shape) for every leaf of the ENTRY
    computation's parameter list, nested tuple types flattened.

    This is what a compiled program *materializes as inputs*: the
    nmlint dense-weight audit (repro/analysis) checks that a packed
    decode step's entry never carries a dense-shaped weight that the
    packed store was supposed to replace."""
    comp = entry_computation(parse_module(text))
    if comp is None:
        return []
    out = []
    for pname, ptype in comp.params.items():
        for dtype, shape in _parse_shapes(ptype):
            out.append((pname, dtype, shape))
    return out


def count_hlo_ops(text: str, kinds: Tuple[str, ...],
                  entry_only: bool = False) -> int:
    """Census of op *kinds* (``scatter``, ``custom-call``, …) over the
    parsed module — every computation by default, so ops inside while
    bodies and fusions are seen exactly once (structural presence, not
    trip-weighted)."""
    comps = parse_module(text)
    total = 0
    for comp in comps.values():
        if entry_only and not comp.is_entry:
            continue
        total += sum(1 for op in comp.ops if op.kind in kinds)
    return total


# ---------------------------------------------------------------------------
# Cost walk
# ---------------------------------------------------------------------------


def _crosses_pod(line: str, pod_block: int) -> bool:
    """Does this collective's device grouping span two pods?

    pod(id) = id // pod_block (the pod axis is the mesh's outermost).
    Handles explicit replica_groups={{0,4},{1,5}}, the iota form
    replica_groups=[G,S]<=[dims](T(perm)), and collective-permute's
    source_target_pairs.  A collective with no visible grouping spans
    the world — conservatively counted as crossing.
    """
    def spans(ids) -> bool:
        return len({int(i) // pod_block for i in ids}) > 1

    m = _PAIRS_RE.search(line)
    if m:
        for pair in m.group(1).split("},{"):
            if spans(x for x in pair.split(",") if x.strip()):
                return True
        return False
    m = _GROUPS_FULL_RE.search(line)
    if m:
        for grp in m.group(1).split("},{"):
            if spans(x for x in grp.split(",") if x.strip()):
                return True
        return False
    m = _GROUPS_IOTA_FULL_RE.search(line)
    if m:
        dims = [int(x) for x in m.group(1).split(",")]
        space = [int(x) for x in m.group(2).split(",")]
        perm = ([int(x) for x in m.group(3).split(",")] if m.group(3)
                else list(range(len(space))))
        n = math.prod(space)
        ids = list(range(n))
        # arange(n).reshape(space).transpose(perm).reshape(G, S)
        strides = [0] * len(space)
        acc = 1
        for i in reversed(range(len(space))):
            strides[i] = acc
            acc *= space[i]
        pspace = [space[p] for p in perm]
        pstrides = [strides[p] for p in perm]
        flat = []
        idx = [0] * len(pspace)
        for _ in range(n):
            flat.append(sum(i * s for i, s in zip(idx, pstrides)))
            for d in reversed(range(len(pspace))):
                idx[d] += 1
                if idx[d] < pspace[d]:
                    break
                idx[d] = 0
        gsize = n // max(dims[0], 1) if dims else n
        for g in range(0, n, max(gsize, 1)):
            if spans(flat[g:g + gsize]):
                return True
        return False
    return True


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def _operand_type(comp: Computation, name: str) -> str:
    return comp.table.get(name, "")


def _dot_flops(comp: Computation, op: Op) -> float:
    res = _parse_shapes(op.type_text)
    if not res:
        return 0.0
    n_out = _numel(res[0][1])
    m = _CONTRACT_RE.search(op.line)
    k = 1
    if m and op.operands:
        lhs_shapes = _parse_shapes(_operand_type(comp, op.operands[0]))
        if lhs_shapes:
            lshape = lhs_shapes[0][1]
            dims = [int(x) for x in m.group(1).split(",") if x]
            for d in dims:
                if d < len(lshape):
                    k *= lshape[d]
    return 2.0 * n_out * k


def _conv_flops(comp: Computation, op: Op) -> float:
    res = _parse_shapes(op.type_text)
    if not res or len(op.operands) < 2:
        return 0.0
    n_out = _numel(res[0][1])
    ker = _parse_shapes(_operand_type(comp, op.operands[1]))
    if not ker:
        return 0.0
    # HWIO kernel: all dims except the last (O) contribute per-output MACs
    kshape = ker[0][1]
    per_out = _numel(kshape[:-1]) if len(kshape) > 1 else 1
    return 2.0 * n_out * per_out


class HloCostModel:
    def __init__(self, text: str, pod_block: Optional[int] = None):
        self.comps = parse_module(text)
        self.pod_block = pod_block
        self._memo: Dict[str, Cost] = {}
        entry = None
        for name in self.comps:
            if re.search(r"^main\b|\bentry\b", name) or name.startswith("main"):
                entry = name
        if entry is None:  # fall back: the computation never called by others
            called = set()
            for c in self.comps.values():
                for op in c.ops:
                    called.update(_CALL_ATTR_RE.findall(op.line))
                    b = _BRANCH_RE.search(op.line)
                    if b:
                        called.update(x.strip().lstrip("%")
                                      for x in b.group(1).split(","))
            candidates = [n for n in self.comps if n not in called]
            entry = candidates[-1] if candidates else next(iter(self.comps))
        self.entry = entry

    def cost(self, comp_name: Optional[str] = None) -> Cost:
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        total = Cost()
        self._memo[comp_name] = total  # cycle guard (shouldn't happen)
        if comp is None:
            return total
        for op in comp.ops:
            self._op_cost(comp, op, total)
        return total

    def _op_cost(self, comp: Computation, op: Op, total: Cost):
        kind = op.kind
        if kind in _FREE_OPS:
            return
        result_bytes = _bytes_of(op.type_text)
        operand_bytes = sum(_bytes_of(_operand_type(comp, o))
                            for o in op.operands)

        if kind == "while":
            body = cond = None
            mb = re.search(r"body=%?([\w.\-]+)", op.line)
            mc = re.search(r"condition=%?([\w.\-]+)", op.line)
            body = mb.group(1) if mb else None
            cond = mc.group(1) if mc else None
            mt = _TRIP_RE.search(op.line)
            trips = int(mt.group(1)) if mt else 1
            if body:
                total.add(self.cost(body), trips)
            if cond:
                total.add(self.cost(cond), trips + 1)
            return
        if kind == "conditional":
            b = _BRANCH_RE.search(op.line)
            names = ([x.strip().lstrip("%") for x in b.group(1).split(",")]
                     if b else _CALL_ATTR_RE.findall(op.line))
            if names:
                branch_costs = [self.cost(n) for n in names]
                worst = max(branch_costs, key=lambda c: (c.flops + c.bytes))
                total.add(worst)
            return
        if kind == "call":
            for target in _CALL_ATTR_RE.findall(op.line):
                total.add(self.cost(target))
            return
        if kind == "fusion":
            # boundary traffic + any dots hiding inside the fused comp.
            # In-place slice fusions (root = dynamic-update-slice /
            # dynamic-slice) alias the big buffer: traffic is the slice,
            # not the buffer — XLA's buffer-assignment contract.
            targets = _CALL_ATTR_RE.findall(op.line)
            fused = self.comps.get(targets[0]) if targets else None
            root = fused.root_op() if fused else None
            root_kind = root.kind if root else ""
            # unwrap elementwise/layout wrappers to find an aliasing root
            _WRAPPERS = {"bitcast", "convert", "copy", "reshape",
                         "transpose"}
            seen_wrap = 0
            while (root is not None and root_kind in _WRAPPERS
                   and root.operands and seen_wrap < 8):
                nxt = None
                for o2 in fused.ops:
                    if o2.name == root.operands[0]:
                        nxt = o2
                        break
                if nxt is None:
                    break
                root, root_kind = nxt, nxt.kind
                seen_wrap += 1
            if root_kind == "dynamic-update-slice" and root and \
                    len(root.operands) >= 2:
                upd = _bytes_of(fused.table.get(root.operands[1], ""))
                small = sum(b for b in
                            (_bytes_of(_operand_type(comp, o))
                             for o in op.operands)
                            if b < result_bytes)
                total.bytes += 2 * upd + small
            elif root_kind == "dynamic-slice":
                total.bytes += 2 * result_bytes
            else:
                total.bytes += result_bytes + operand_bytes
            for target in targets:
                inner = self.cost(target)
                total.flops += inner.flops
            return
        if kind == "dot":
            total.flops += _dot_flops(comp, op)
            total.bytes += result_bytes + operand_bytes
            return
        if kind == "convolution":
            total.flops += _conv_flops(comp, op)
            total.bytes += result_bytes + operand_bytes
            return
        base = kind.replace("-start", "")
        if base in _COLLECTIVES:
            g = _group_size(op.line)
            size = max(result_bytes, operand_bytes)
            if g > 1 or base == "collective-permute":
                frac = (g - 1) / g
                if base == "all-reduce":
                    link = 2 * operand_bytes * frac
                elif base == "all-gather":
                    link = result_bytes * frac
                elif base == "reduce-scatter":
                    link = result_bytes * (g - 1)
                elif base == "all-to-all":
                    link = size * frac
                else:
                    link = size
                total.coll[base] += link
                total.coll_count += 1
                if self.pod_block and _crosses_pod(op.line, self.pod_block):
                    total.cross[base] += link
            total.bytes += result_bytes + operand_bytes
            return
        if kind.endswith("-done"):
            return
        if kind == "dynamic-slice":
            total.bytes += 2 * result_bytes  # read slice + write slice
            return
        if kind == "dynamic-update-slice":
            if len(op.operands) >= 2:
                upd = _bytes_of(_operand_type(comp, op.operands[1]))
                total.bytes += 2 * upd
            return
        if kind in ("copy", "copy-start", "transpose", "reshape",
                    "broadcast", "iota", "reverse", "slice", "pad",
                    "concatenate", "gather", "scatter", "reduce",
                    "reduce-window", "select-and-scatter", "sort", "rng",
                    "convert", "compare", "select", "clamp", "map",
                    "custom-call"):
            total.bytes += result_bytes + operand_bytes
            if kind in ("reduce", "map", "sort"):
                total.flops += _numel(_parse_shapes(op.type_text)[0][1]) \
                    if _parse_shapes(op.type_text) else 0
            return
        # generic elementwise (add, multiply, tanh, exponential, ...)
        total.bytes += result_bytes + operand_bytes
        shapes = _parse_shapes(op.type_text)
        if shapes:
            total.flops += _numel(shapes[0][1])


def analyze(hlo_text: str, pod_block: Optional[int] = None) -> dict:
    """Entry point: optimized HLO text -> per-device cost dict.

    With ``pod_block`` (devices per pod) the collectives dict also
    carries ``pod_crossing``: the ring link bytes of collectives whose
    groups span pods — the traffic that rides the slow inter-pod links.
    """
    model = HloCostModel(hlo_text, pod_block=pod_block)
    c = model.cost()
    out = {
        "flops": c.flops,
        "bytes": c.bytes,
        "collectives": {**{k: int(v) for k, v in c.coll.items()},
                        "count": c.coll_count,
                        "total": int(c.coll_bytes)},
    }
    if pod_block:
        out["collectives"]["pod_crossing"] = int(c.cross_bytes)
    return out


# ---------------------------------------------------------------------------
# Mask-op census (pre-generation dataflow gate)
# ---------------------------------------------------------------------------
#
# The pre-generation invariant: a lowered train step derives each
# prunable parameter's N:M masks exactly ONCE (at WU time), so the traced
# step contains exactly one top_k/sort selection per prunable parameter —
# and none inside the scanned model body.  Counting jaxpr primitives is
# compiler-version-stable (optimized HLO spelling of top_k varies across
# XLA releases); benchmarks/pregen_bench.py and tests/test_pregen.py both
# gate on this census.

MASK_PRIMS = ("top_k", "sort", "approx_top_k")


def count_jaxpr_prims(jaxpr, names=MASK_PRIMS, pred=None) -> int:
    """Recursively count primitive occurrences in a (Closed)Jaxpr,
    descending through scan/while/cond/pjit/remat/custom-vjp sub-jaxprs.
    ``pred(eqn)`` optionally filters the name-matched equations."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)  # ClosedJaxpr -> Jaxpr
    total = 0
    for eqn in inner.eqns:
        if eqn.primitive.name in names and (pred is None or pred(eqn)):
            total += 1
        for val in eqn.params.values():
            for sub in _subjaxprs(val):
                total += count_jaxpr_prims(sub, names, pred)
    return total


def _subjaxprs(val):
    if hasattr(val, "jaxpr") or type(val).__name__ == "Jaxpr":
        yield val
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _subjaxprs(v)


def nm_selection_pred(n: int, m: int):
    """Equation predicate matching only *N:M mask* selections.

    Every mask derivation in the system scores ``(..., M)`` groups with
    a ``top_k`` of k=N (sparsity._topn_group_mask; legacy packing also
    sorts M-wide groups), so a selection whose trailing operand dim is
    not M — e.g. the MoE router's top_k over the expert dim — is routing
    compute, not a mask derivation, and must not trip the mask-once
    census.  A stacked (E, …, M) expert leaf batches all experts into
    ONE such equation: the census counts stacked leaves as one
    derivation per parameter.  Caveat: when a model's expert count
    equals M and its routing top-k equals N the shapes are
    indistinguishable — census tests/benches pick (n, m) apart from the
    router dims.
    """
    def pred(eqn) -> bool:
        if not eqn.invars or not getattr(eqn.invars[0], "aval", None):
            return False
        shape = eqn.invars[0].aval.shape
        if not shape or shape[-1] != m:
            return False
        if eqn.primitive.name == "top_k":
            return eqn.params.get("k") == n
        return True
    return pred


def count_mask_ops(fn, *args, nm=None) -> int:
    """top_k/sort census of ``fn`` traced on ``args`` (arrays or
    ShapeDtypeStructs).  ``nm=(n, m)`` restricts the count to
    N:M-mask-shaped selections (``nm_selection_pred``) — required for
    MoE models, whose router top_k would otherwise be counted."""
    import jax

    pred = nm_selection_pred(*nm) if nm is not None else None
    return count_jaxpr_prims(jax.make_jaxpr(fn)(*args), pred=pred)


# ---------------------------------------------------------------------------
# Diagnostics: where do the bytes/flops/collective terms come from?
# ---------------------------------------------------------------------------


def breakdown(hlo_text: str, top: int = 25) -> dict:
    """Attribute cost to individual top-level ops (weighted by the trip
    counts of enclosing loops).  The perf-iteration loop reads this to
    find the dominant contributors (redundant all-gathers, fat copies,
    remat recompute)."""
    model = HloCostModel(hlo_text)
    rows = []

    def walk(comp_name: str, weight: float, ctx: str):
        comp = model.comps.get(comp_name)
        if comp is None:
            return
        for op in comp.ops:
            kind = op.kind
            if kind == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.line)
                mt = _TRIP_RE.search(op.line)
                trips = int(mt.group(1)) if mt else 1
                if mb:
                    walk(mb.group(1), weight * trips,
                         f"{ctx}>while[{trips}]")
                continue
            if kind == "conditional":
                b = _BRANCH_RE.search(op.line)
                if b:
                    names = [x.strip().lstrip("%")
                             for x in b.group(1).split(",")]
                    costs = [(n, model.cost(n)) for n in names]
                    worst = max(costs, key=lambda nc: nc[1].flops + nc[1].bytes)
                    walk(worst[0], weight, f"{ctx}>cond")
                continue
            if kind == "call":
                for target in _CALL_ATTR_RE.findall(op.line):
                    walk(target, weight, f"{ctx}>call")
                continue
            one = Cost()
            model._op_cost(comp, op, one)
            if one.flops or one.bytes or one.coll_bytes:
                rows.append({
                    "op": f"{comp_name}/{op.name}", "kind": kind,
                    "ctx": ctx, "weight": weight,
                    "flops": one.flops * weight,
                    "bytes": one.bytes * weight,
                    "coll": one.coll_bytes * weight,
                    "line": op.line.strip()[:200],
                })

    walk(model.entry, 1.0, "entry")
    out = {"total_flops": sum(r["flops"] for r in rows),
           "total_bytes": sum(r["bytes"] for r in rows),
           "total_coll": sum(r["coll"] for r in rows)}
    for key in ("flops", "bytes", "coll"):
        rows.sort(key=lambda r, k=key: -r[k])
        out[f"top_{key}"] = [dict(r) for r in rows[:top]]
    by_kind = {}
    for r in rows:
        d = by_kind.setdefault(r["kind"], {"flops": 0.0, "bytes": 0.0,
                                           "coll": 0.0, "n": 0})
        d["flops"] += r["flops"]
        d["bytes"] += r["bytes"]
        d["coll"] += r["coll"]
        d["n"] += 1
    out["by_kind"] = by_kind
    return out
