"""Production mesh builders.

Functions, not module-level constants — importing this module never
touches jax device state, so smoke tests keep seeing 1 device while the
dry-run (which sets XLA_FLAGS before any jax import) sees 512.

Topology (TPU v5e-class):
  single pod : (data=16, model=16)          = 256 chips
  multi-pod  : (pod=2, data=16, model=16)   = 512 chips
The "model" axis carries TP/EP collectives (fast intra-pod ICI rings);
"data" carries FSDP/DP; "pod" is the slow inter-pod hop — only the
once-per-step gradient reduction (optionally N:M-compressed) rides it.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1, pods: int = 1):
    """Whatever this host actually has — smoke tests / examples / CI.

    pods > 1 adds the hierarchical "pod" axis (cross-pod gradient sync /
    compression paths) — real on a forced-device host
    (XLA_FLAGS=--xla_force_host_platform_device_count=N).
    """
    n = jax.device_count()
    if n % (model * pods):
        model = pods = 1
    if pods > 1:
        return jax.make_mesh((pods, n // (model * pods), model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_chips(mesh) -> int:
    return mesh.devices.size


# Hardware constants for the roofline terms (TPU v5e, per chip).
PEAK_FLOPS = 197e12      # bf16 FLOP/s
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link (~per-chip usable axis bandwidth)
VMEM_BYTES = 128 * 2**20
HBM_BYTES = 16 * 2**30
