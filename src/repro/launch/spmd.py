"""Real SPMD execution of the sharding rule tables.

The rule tables (sharding/rules.py) were born in the dry-run planner —
this module is where they execute: a ``Mesh`` over ("pod","data",
"model") is built from *actual* devices and the train / serve steps
compile against it with ``jax.jit`` + ``NamedSharding`` (the cross-pod
gradient compression rides ``shard_map`` inside the train step).

On CPU containers XLA can fake a multi-chip host:

    XLA_FLAGS=--xla_force_host_platform_device_count=8

``force_host_devices`` sets that flag programmatically; it only works
before the first backend touch (any ``jax.devices()`` / array op), so
call it at the very top of an entry point — the dry-run, the SPMD
benchmark and the distributed tests all do.

Mesh specs (the ``--mesh`` CLI grammar):

    pod,data,model            axis names; device count auto-factored,
                              inner axes ("model") get factors first
    pod=2,data=2,model=2      explicit sizes (product must divide the
                              device count; at most one axis unsized)
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding import rules as R


def force_host_devices(n: int = 8) -> int:
    """Ask the CPU backend for ``n`` devices (replaces any earlier
    forced count, preserves every other XLA_FLAGS entry).  Must run
    before jax initializes a backend; the returned count is what the
    process actually sees — callers that got in too late observe fewer
    and can skip/degrade."""
    flags = [f for f in os.environ.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    flags.append(f"--xla_force_host_platform_device_count={n}")
    os.environ["XLA_FLAGS"] = " ".join(flags)
    return jax.device_count()


# ---------------------------------------------------------------------------
# Mesh construction
# ---------------------------------------------------------------------------


def parse_mesh_spec(spec: str, n_devices: int) -> dict:
    """``--mesh`` string -> ordered {axis: size} covering n_devices.

    Unsized axes split the remaining factor; prime factors are dealt to
    the *innermost* unsized axes first so "model" (fast collectives)
    grows before "data" before "pod" — e.g. 8 devices over
    "pod,data,model" -> {pod: 2, data: 2, model: 2}, 4 devices ->
    {pod: 1, data: 2, model: 2}.
    """
    axes: dict = {}
    unsized = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" in part:
            name, size = part.split("=")
            axes[name.strip()] = int(size)
        else:
            axes[part] = None
            unsized.append(part)
    sized = 1
    for v in axes.values():
        sized *= v or 1
    if n_devices % sized:
        raise ValueError(f"mesh sizes {spec!r} (product {sized}) do not "
                         f"divide device count {n_devices}")
    rest = n_devices // sized
    for name in unsized:
        axes[name] = 1
    # deal prime factors of the remainder, innermost unsized axis first
    factors = []
    x, p = rest, 2
    while x > 1:
        while x % p == 0:
            factors.append(p)
            x //= p
        p += 1
    for i, f in enumerate(sorted(factors, reverse=True)):
        if not unsized:
            raise ValueError(f"{spec!r} under-covers {n_devices} devices "
                             f"({rest}x unassigned, no unsized axis)")
        axes[unsized[-1 - (i % len(unsized))]] *= f
    return axes


def make_spmd_mesh(spec: str = "pod,data,model", *,
                   devices=None) -> Mesh:
    """Build a Mesh from actual devices per a ``--mesh`` spec string."""
    devices = list(devices if devices is not None else jax.devices())
    axes = parse_mesh_spec(spec, len(devices))
    import numpy as np
    arr = np.asarray(devices).reshape(tuple(axes.values()))
    return Mesh(arr, tuple(axes))


def single_device_mesh(axis_names=("data", "model")) -> Mesh:
    """A 1-chip mesh with the same axis names — the parity reference."""
    import numpy as np
    arr = np.asarray(jax.devices()[:1]).reshape((1,) * len(axis_names))
    return Mesh(arr, tuple(axis_names))


def replica_device_groups(n_replicas: int, *, devices=None) -> list:
    """Partition the device pool into ``n_replicas`` disjoint contiguous
    groups (serve-fleet replicas never share a chip: each replica owns
    its weights copy + KV residents, and lanes cross replicas through
    the host-side CacheStore, not a collective)."""
    devices = list(devices if devices is not None else jax.devices())
    if n_replicas < 1:
        raise ValueError("n_replicas must be >= 1")
    if len(devices) % n_replicas:
        raise ValueError(f"{len(devices)} devices do not split into "
                         f"{n_replicas} equal replica groups")
    per = len(devices) // n_replicas
    return [devices[i * per:(i + 1) * per] for i in range(n_replicas)]


def fleet_meshes(n_replicas: int, spec: str = "data,model", *,
                 devices=None) -> list:
    """Per-replica serve meshes for a ServeFleet: one ``--mesh``-grammar
    Mesh per disjoint device group.  Each replica then resolves its own
    SERVE_BATCH shardings (``serve_shardings``) against its mesh — the
    fleet-level router stays host-side and mesh-agnostic."""
    return [make_spmd_mesh(spec, devices=group)
            for group in replica_device_groups(n_replicas,
                                               devices=devices)]


# ---------------------------------------------------------------------------
# Serve-side sharding resolution (SERVE_BATCH rules, slot-paged cache)
# ---------------------------------------------------------------------------


def _sanitize_pspec(ps: P, shape, mesh: Mesh) -> P:
    """Drop spec entries whose mesh-axis product doesn't divide the dim
    (odd slot counts, batch-1 prefill) — GSPMD would pad; we replicate."""
    fixed = []
    for i, entry in enumerate(ps):
        if entry is None:
            fixed.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape.get(a, 1)
        fixed.append(entry if shape[i] % size == 0 else None)
    return P(*fixed)


def sanitize_pspecs(pspecs, tree, mesh: Mesh):
    """Tree-wide ``_sanitize_pspec`` (pspecs is a prefix-matching tree of
    PartitionSpecs over ``tree`` of arrays/ShapeDtypeStructs)."""
    return jax.tree.map(
        lambda ps, x: _sanitize_pspec(ps, tuple(x.shape), mesh),
        pspecs, tree, is_leaf=lambda x: isinstance(x, P))


def serve_shardings(cfg, mesh: Mesh, sp_cfg, *, n_slots: int, max_len: int,
                    packed: bool = False, idx_bits=None,
                    cache_dtype=jnp.bfloat16) -> dict:
    """Resolve SERVE_BATCH NamedShardings for a continuous-batching
    engine: params (TP over "model", N:M groups unsplit), the slot-paged
    KV cache (slot axis over the DP axes), per-slot tokens/positions.

    ``idx_bits`` must match the engine's packed store (None resolves the
    same ``default_idx_bits`` auto choice, so the default agrees).

    Returns {"params", "cache", "token", "pos"} of NamedSharding trees
    plus the raw "pspecs" for introspection/tests.  The resolved specs
    are asserted group-safe (``rules.assert_nm_unsplit``) before use.
    """
    from repro.models import transformer_lm as T
    from repro.serve.packed_params import pack_tree_element

    aparams, specs = T.init(jax.random.PRNGKey(0), cfg, abstract=True)
    p_pspecs = R.nm_params_pspecs(specs, R.SERVE_BATCH_RULES, aparams,
                                  mesh, sp_cfg)
    check_tree = aparams
    if packed:
        check_tree, _, p_pspecs = pack_tree_element(aparams, sp_cfg,
                                                    pspecs=p_pspecs,
                                                    idx_bits=idx_bits)
    R.assert_nm_unsplit(p_pspecs, check_tree, mesh, sp_cfg)

    cache = jax.eval_shape(
        lambda: T.init_lm_cache(cfg, n_slots, max_len, cache_dtype))
    in_specs = {"cache": cache,
                "token": jax.ShapeDtypeStruct((n_slots, 1), jnp.int32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    in_pspecs = R.serve_input_pspecs(in_specs, mesh, long_context=False)
    dp = R.batch_axes(mesh)
    # continuous batching: per-slot position vector, not a shared cursor
    in_pspecs["pos"] = P(dp)
    cache_ps = sanitize_pspecs(in_pspecs["cache"], cache, mesh)
    token_ps = _sanitize_pspec(in_pspecs["token"], (n_slots, 1), mesh)
    pos_ps = _sanitize_pspec(in_pspecs["pos"], (n_slots,), mesh)

    def named(ps_tree):
        return jax.tree.map(lambda ps: NamedSharding(mesh, ps), ps_tree,
                            is_leaf=lambda x: isinstance(x, P))

    return {
        "params": named(p_pspecs),
        "cache": named(cache_ps),
        "token": NamedSharding(mesh, token_ps),
        "pos": NamedSharding(mesh, pos_ps),
        "pspecs": {"params": p_pspecs, "cache": cache_ps,
                   "token": token_ps, "pos": pos_ps},
    }
