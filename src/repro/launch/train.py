"""Training launcher CLI.

  PYTHONPATH=src python -m repro.launch.train \\
      --arch qwen3-8b --smoke --steps 50 --method bdwp --nm 2:8 \\
      --ckpt-dir /tmp/run1 [--resume] [--watchdog]

Drives the full stack: config -> mesh -> StepBundle (resolved shardings)
-> synthetic data stream -> trainer loop (checkpoints, heartbeat,
straggler monitor).  ``--smoke`` selects the reduced config (CPU-sized);
the full configs are exercised via the dry-run (launch/dryrun.py).

``--watchdog`` wraps the run in a supervisor: if the heartbeat file goes
stale (crash / hang / SIGKILL'd host), the training process is restarted
and auto-resumes from the newest checkpoint — the single-host analogue
of the cluster controller's evict-and-restart path.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

import jax
import numpy as np


def build_parser():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (default on CPU containers)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--method", default="bdwp",
                    choices=["dense", "srste", "sdgp", "sdwp", "bdwp"])
    ap.add_argument("--nm", default="2:8")
    ap.add_argument("--granularity", default="element",
                    choices=["element", "shared"])
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="N:M cross-pod gradient compression (needs a "
                         "mesh with a 'pod' axis, e.g. --mesh "
                         "pod,data,model)")
    ap.add_argument("--grad-estimator", default="topk",
                    choices=["topk", "mvue"],
                    help="gradient sparsifier for --compress: topk with "
                         "error feedback, or the unbiased MVUE sampler "
                         "(arXiv 2203.10991)")
    ap.add_argument("--bucket-elems", type=int, default=1 << 16,
                    help="compressed-sync bucket size in elements "
                         "(must be a multiple of M)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--mesh", default=None,
                    help="mesh spec over the visible devices, e.g. "
                         "'pod,data,model' (auto-factored) or "
                         "'pod=2,data=2,model=2'; with "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=8 this runs real SPMD on a CPU host. "
                         "Default: host mesh (data x model-parallel)")
    ap.add_argument("--watchdog", action="store_true")
    ap.add_argument("--heartbeat-timeout", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def run_training(args) -> int:
    from jax.sharding import NamedSharding

    from repro.configs import get_arch
    from repro.core.sparsity import SparsityConfig
    from repro.data import synthetic as D
    from repro.launch.mesh import make_host_mesh
    from repro.optim import sgd
    from repro.train import step as ST
    from repro.train import trainer as TR
    from repro.train.checkpoint import CheckpointManager
    from repro.train.fault import recover_or_init

    arch = get_arch(args.arch)
    cfg = arch.smoke if args.smoke else arch.full
    n, m = (int(v) for v in args.nm.split(":"))
    sp_cfg = SparsityConfig(n=n, m=m, method=args.method,
                            granularity=args.granularity)
    opt_cfg = sgd.SGDConfig(lr=args.lr, total_steps=args.steps)
    if args.mesh:
        from repro.launch.spmd import make_spmd_mesh
        if args.model_parallel != 1:
            print("[warn] --model-parallel ignored: --mesh controls the "
                  "axis sizes (use e.g. --mesh pod,data,model="
                  f"{args.model_parallel})")
        mesh = make_spmd_mesh(args.mesh)
    else:
        mesh = make_host_mesh(model=args.model_parallel)
    # compression is the cross-pod hop; without a pod axis the state
    # must not carry an error-feedback buffer the bundle doesn't shard
    compress = args.compress and "pod" in mesh.axis_names
    if args.compress and not compress:
        print("[warn] --compress ignored: mesh has no 'pod' axis "
              "(use --mesh pod,data,model)")
    print(f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} | "
          f"{args.arch} ({'smoke' if args.smoke else 'full'}) | "
          f"{args.method} {n}:{m} {args.granularity}"
          + (" | compressed pod sync" if compress else ""))

    if arch.family == "encdec":
        bundle = ST.build_encdec_train(cfg, mesh, sp_cfg, opt_cfg)
    else:
        from repro.optim.compress import GradCompressConfig
        grad_sync = GradCompressConfig(
            n=n, m=m, estimator=args.grad_estimator,
            bucket_elems=args.bucket_elems) if compress else None
        bundle = ST.build_lm_train(cfg, mesh, sp_cfg, opt_cfg,
                                   compress=compress, grad_sync=grad_sync)

    def fresh():
        key = jax.random.PRNGKey(args.seed)
        state = ST.init_train_state(key, cfg, family=arch.family,
                                    compress=compress, sp_cfg=sp_cfg,
                                    mesh=mesh)
        return jax.device_put(state, bundle.state_shardings)

    if args.resume and args.ckpt_dir:
        from functools import partial

        mgr = CheckpointManager(args.ckpt_dir)
        # restore_with_pregen upgrades pre-pregen checkpoints (no
        # "compute" leaf) by regenerating the operands from master
        state, _ = recover_or_init(
            mgr, fresh, shardings=bundle.state_shardings,
            restore_fn=partial(ST.restore_with_pregen, mgr, sp_cfg=sp_cfg))
    else:
        state = fresh()

    batch_sh = {k: NamedSharding(mesh, ps)
                for k, ps in bundle.input_pspecs.items()}
    if arch.family == "encdec":
        stream = D.encdec_stream(cfg.vocab, args.batch, args.seq,
                                 cfg.d_model, shardings=batch_sh,
                                 seed=args.seed, start=int(state["step"]))
    else:
        prefix = 8 if arch.prefix_len else 0
        stream = D.lm_stream(cfg.vocab, args.batch, args.seq,
                             shardings=batch_sh, seed=args.seed,
                             start=int(state["step"]), prefix=prefix,
                             d_model=cfg.d_model)

    tcfg = TR.TrainerConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        log_every=args.log_every, ckpt_dir=args.ckpt_dir,
        heartbeat_path=(os.path.join(args.ckpt_dir, "heartbeat.json")
                        if args.ckpt_dir else None))
    state, history = TR.fit(bundle, state, stream, tcfg)
    final = history[-1]["loss"] if history else float("nan")
    print(f"done: {len(history)} steps, final loss {final:.4f}")
    return 0


def run_watchdog(args, argv) -> int:
    """Supervise: restart-on-stale-heartbeat until steps complete."""
    assert args.ckpt_dir, "--watchdog requires --ckpt-dir"
    hb_path = os.path.join(args.ckpt_dir, "heartbeat.json")
    child_argv = [a for a in argv if a != "--watchdog"] + ["--resume"]
    attempts = 0
    while attempts < 10:
        attempts += 1
        proc = subprocess.Popen([sys.executable, "-m", "repro.launch.train",
                                 *child_argv],
                                env=dict(os.environ))
        while proc.poll() is None:
            time.sleep(2.0)
            try:
                age = time.time() - os.path.getmtime(hb_path)
            except OSError:
                continue
            if age > args.heartbeat_timeout:
                print(f"[watchdog] heartbeat stale ({age:.0f}s) — "
                      f"restarting from latest checkpoint")
                proc.kill()
                proc.wait()
                break
        if proc.returncode == 0:
            return 0
    return 1


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    args = build_parser().parse_args(argv)
    if args.watchdog:
        sys.exit(run_watchdog(args, argv))
    sys.exit(run_training(args))


if __name__ == "__main__":
    main()
