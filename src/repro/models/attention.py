"""Attention: GQA + RoPE + qk-norm + sliding-window + MLA, train & decode.

Memory-sane by construction: training/prefill attention is chunked with
an online-softmax accumulator (flash-attention recurrence in pure JAX),
so lowering 32k-token prefill never materializes an S x S tensor.
Sliding-window attention is *banded* — a scan over query chunks that
dynamic-slices only the in-window KV span — so SWA costs O(S*W) FLOPs in
the compiled HLO, not O(S^2) (this is what makes gemma3/hymba long_500k
honest).

All projections route through BDWP (core/bdwp) so N:M sparse training
applies to attention weights exactly as the paper does for ViT.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sparsity import SparsityConfig
from repro.models import layers as L
from repro.sharding.rules import BATCH, act

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    rope_theta: float = 10000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    window: Optional[int] = None          # sliding-window width (gemma3 local)
    # MLA (deepseek-v2): when kv_lora is set, the layer uses compressed KV.
    kv_lora: Optional[int] = None
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: Optional[int] = None
    chunk_q: int = 1024
    chunk_kv: int = 1024


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def attn_init(key, cfg: AttnConfig):
    ks = jax.random.split(key, 8)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    p, s = {}, {}
    if cfg.kv_lora is None:
        for i, (name, dout) in enumerate(
            [("q_proj", h * hd), ("k_proj", kv * hd), ("v_proj", kv * hd)]
        ):
            pp, ss = L.dense_init(ks[i], d, dout, axes=("embed", "heads" if name == "q_proj" else "kv"),
                                  bias=cfg.qkv_bias)
            p[name], s[name] = pp, ss
        pp, ss = L.dense_init(ks[3], h * hd, d, axes=("heads", "embed"))
        p["o_proj"], s["o_proj"] = pp, ss
        if cfg.qk_norm:
            p["q_norm"] = {"norm_scale": jnp.ones((hd,), jnp.float32)}
            p["k_norm"] = {"norm_scale": jnp.ones((hd,), jnp.float32)}
            s["q_norm"] = {"norm_scale": (None,)}
            s["k_norm"] = {"norm_scale": (None,)}
    else:
        dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
        dv = cfg.v_head_dim or dn
        pp, ss = L.dense_init(ks[0], d, h * (dn + dr), axes=("embed", "heads"))
        p["q_proj"], s["q_proj"] = pp, ss
        pp, ss = L.dense_init(ks[1], d, cfg.kv_lora + dr, axes=("embed", None))
        p["kv_down"], s["kv_down"] = pp, ss
        pp, ss = L.dense_init(ks[2], cfg.kv_lora, h * dn, axes=(None, "heads"))
        p["k_up"], s["k_up"] = pp, ss
        pp, ss = L.dense_init(ks[3], cfg.kv_lora, h * dv, axes=(None, "heads"))
        p["v_up"], s["v_up"] = pp, ss
        pp, ss = L.dense_init(ks[4], h * dv, d, axes=("heads", "embed"))
        p["o_proj"], s["o_proj"] = pp, ss
        p["ckv_norm"], sn = L.rmsnorm_init(cfg.kv_lora)
        s["ckv_norm"] = {"norm_scale": (None,)}
    return p, s


# ---------------------------------------------------------------------------
# Core chunked attention (online softmax over KV blocks)
# ---------------------------------------------------------------------------


def _largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (static chunk sizing)."""
    cap = min(cap, n)
    for c in range(cap, 0, -1):
        if n % c == 0:
            return c
    return 1


def _gqa_logits(q, k):
    """q: (B,Sq,Hkv,G,D), k: (B,Ck,Hkv,D) -> (B,Hkv,G,Sq,Ck)"""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)


def chunked_attention(q, k, v, *, causal: bool, q_offset, chunk_kv: int = 1024,
                      kv_len_mask: Optional[int] = None):
    """Online-softmax attention, scanning KV chunks.

    q: (B, Sq, H, D); k, v: (B, Skv, Hkv, D); q_offset: scalar — absolute
    position of q[0] (for causal masking of prefill continuations).
    """
    b, sq, h, d = q.shape
    dv = v.shape[-1]
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    chunk_kv = _largest_divisor(skv, chunk_kv)
    nk = skv // chunk_kv
    qg = q.reshape(b, sq, hkv, g, d)
    scale = d ** -0.5
    kc = k.reshape(b, nk, chunk_kv, hkv, d)
    vc = v.reshape(b, nk, chunk_kv, hkv, dv)
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp
        logits = _gqa_logits(qg, kj) * scale  # (B,Hkv,G,Sq,Ck)
        k_pos = j * chunk_kv + jnp.arange(chunk_kv)
        mask = jnp.ones((sq, chunk_kv), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if kv_len_mask is not None:
            mask &= k_pos[None, :] < kv_len_mask
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(nk), kc.swapaxes(0, 1), vc.swapaxes(0, 1))
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


def banded_attention(q, k, v, *, window: int, chunk_q: int = 1024):
    """Sliding-window causal attention with true O(S*W) FLOPs.

    Scans query chunks; each step dynamic-slices the static-size KV band
    [chunk_start - W_pad, chunk_start + Cq) and masks to the exact window.
    """
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    chunk_q = _largest_divisor(s, chunk_q)
    nq = s // chunk_q
    w_pad = ((window + chunk_q - 1) // chunk_q) * chunk_q  # static band padding
    span = w_pad + chunk_q
    scale = d ** -0.5
    # pad kv at the front so every band slice is in-bounds
    kp = jnp.pad(k, ((0, 0), (w_pad, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (w_pad, 0), (0, 0), (0, 0)))

    def step(_, i):
        q0 = i * chunk_q
        qi = jax.lax.dynamic_slice_in_dim(q, q0, chunk_q, axis=1)
        ki = jax.lax.dynamic_slice_in_dim(kp, q0, span, axis=1)  # [q0-wpad, q0+Cq)
        vi = jax.lax.dynamic_slice_in_dim(vp, q0, span, axis=1)
        qg = qi.reshape(b, chunk_q, hkv, g, d)
        logits = _gqa_logits(qg, ki) * scale  # (B,Hkv,G,Cq,span)
        q_pos = q0 + jnp.arange(chunk_q)
        k_pos = q0 - w_pad + jnp.arange(span)  # absolute (pre-pad coords)
        mask = (q_pos[:, None] >= k_pos[None, :]) \
            & (q_pos[:, None] - k_pos[None, :] < window) \
            & (k_pos[None, :] >= 0)
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        out = jnp.einsum(
            "bhgqk,bkhd->bqhgd",
            jax.nn.softmax(logits, axis=-1).astype(vi.dtype), vi,
            preferred_element_type=jnp.float32,
        )
        return None, out.reshape(b, chunk_q, h, d)

    _, outs = jax.lax.scan(step, None, jnp.arange(nq))
    out = outs.swapaxes(0, 1).reshape(b, s, h, d)
    return out.astype(q.dtype)


def decode_attention(q1, k_cache, v_cache, cur_pos, *, window: Optional[int] = None):
    """Single-step decode: q1 (B,1,H,D) vs cache (B,Smax,Hkv,D).

    ``cur_pos`` is either a scalar (whole batch at one position — the
    classic synchronized-decode path) or a (B,) vector of per-request
    positions (continuous batching: every slot is at its own depth).

    For SWA layers with a scalar position only the last `window`
    positions are sliced (static size), so FLOPs/bytes are O(W) not
    O(Smax); with per-slot positions the slice start would differ per
    row, so the window is enforced by masking instead.  For global
    layers the full cache participates; under a sequence-sharded cache
    GSPMD turns the softmax/PV reductions into the distributed
    flash-decoding pattern (partial max/sum + all-reduce).
    """
    b, _, h, d = q1.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = d ** -0.5
    cur_pos = jnp.asarray(cur_pos)
    per_slot = cur_pos.ndim > 0
    cur_b = cur_pos if per_slot else jnp.broadcast_to(cur_pos, (b,))  # (B,)
    if window is not None and window < smax and not per_slot:
        start = jnp.clip(cur_pos + 1 - window, 0, smax - window)
        kc = jax.lax.dynamic_slice_in_dim(k_cache, start, window, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v_cache, start, window, axis=1)
        k_pos = start + jnp.arange(window)
    else:
        kc, vc = k_cache, v_cache
        k_pos = jnp.arange(smax)
    qg = q1.reshape(b, 1, hkv, g, d)
    logits = _gqa_logits(qg, kc) * scale  # (B,Hkv,G,1,S)
    mask = k_pos[None, :] <= cur_b[:, None]  # (B,S)
    if window is not None and per_slot:
        mask &= (cur_b[:, None] - k_pos[None, :]) < window
    logits = jnp.where(mask[:, None, None, None, :], logits, NEG_INF)
    attn = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", attn.astype(vc.dtype), vc,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q1.dtype)


# ---------------------------------------------------------------------------
# Full attention layer (projections + cache plumbing)
# ---------------------------------------------------------------------------


def _split_heads(x, n, d):
    return x.reshape(*x.shape[:-1], n, d)


def attn_apply(p, x, cfg: AttnConfig, sp_cfg: SparsityConfig, *,
               positions, cache=None, layer_window: Optional[int] = None,
               decode: bool = False, per_slot: bool = False):
    """Returns (out, new_cache).  cache: dict(k, v) or dict(ckv, kpe) for MLA.

    per_slot=True (decode only): cache reads/writes are indexed by the
    per-row `positions` instead of the shared `cache["pos"]` cursor, so
    each batch row is an independent request slot (continuous batching).
    """
    if cfg.kv_lora is not None:
        return _mla_apply(p, x, cfg, sp_cfg, positions=positions, cache=cache,
                          decode=decode, per_slot=per_slot)
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = _split_heads(L.dense_apply(p["q_proj"], x, "attn/q_proj", sp_cfg), h, hd)
    k = _split_heads(L.dense_apply(p["k_proj"], x, "attn/k_proj", sp_cfg), kv, hd)
    v = _split_heads(L.dense_apply(p["v_proj"], x, "attn/v_proj", sp_cfg), kv, hd)
    if cfg.qk_norm:
        q = L.rmsnorm_apply(p["q_norm"], q)
        k = L.rmsnorm_apply(p["k_norm"], k)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    # TP anchor AFTER rope: rope's position broadcast is unsharded, and
    # anchoring before it lets GSPMD replicate the batch through the
    # rope elementwise chain (observed: full-batch fp32 q/k all-gathers)
    q = act(q, BATCH, None, "model", None)
    k = act(k, BATCH, None, "model", None)
    v = act(v, BATCH, None, "model", None)
    window = layer_window

    if decode:
        assert cache is not None
        if per_slot:
            # slot-indexed cache write: every request (batch row) sits at
            # its own position — `positions` (B,1) is the absolute
            # position the incoming token is written to (continuous
            # batching: rows join/leave the batch independently)
            b = x.shape[0]
            wpos = jnp.clip(positions[:, -1].astype(jnp.int32), 0,
                            cache["k"].shape[1] - 1)
            b_idx = jnp.arange(b)
            k_cache = cache["k"].at[b_idx, wpos].set(
                k[:, 0].astype(cache["k"].dtype))
            v_cache = cache["v"].at[b_idx, wpos].set(
                v[:, 0].astype(cache["v"].dtype))
            cur = positions[:, -1]
        else:
            cur = cache["pos"]
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cur, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cur, axis=1)
        # anchor: batch-sharded cache, heads over TP only when divisible —
        # without this GSPMD reshards heads over a subgroup and re-gathers
        # the whole stacked cache at the loop boundary
        k_cache = act(k_cache, BATCH, None, "model", None)
        v_cache = act(v_cache, BATCH, None, "model", None)
        out = decode_attention(q, k_cache, v_cache, cur, window=window)
        new_cache = {"k": k_cache, "v": v_cache, "pos": cache["pos"] + 1}
    else:
        if window is not None:
            out = banded_attention(q, k, v, window=window, chunk_q=cfg.chunk_q)
        else:
            out = chunked_attention(q, k, v, causal=True, q_offset=0,
                                    chunk_kv=cfg.chunk_kv)
        new_cache = None
        if cache is not None:  # prefill: fill the cache
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
            new_cache = {"k": k_cache, "v": v_cache, "pos": jnp.asarray(k.shape[1], jnp.int32)}
    out = out.reshape(*x.shape[:-1], h * hd)
    return L.dense_apply(p["o_proj"], out, "attn/o_proj", sp_cfg), new_cache


def _mla_apply(p, x, cfg: AttnConfig, sp_cfg, *, positions, cache, decode,
               per_slot: bool = False):
    """DeepSeek-V2 multi-head latent attention (compressed KV cache)."""
    h = cfg.n_heads
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    dv = cfg.v_head_dim or dn
    lora = cfg.kv_lora
    b = x.shape[0]

    qall = L.dense_apply(p["q_proj"], x, "attn/q_proj", sp_cfg)
    qall = qall.reshape(*x.shape[:-1], h, dn + dr)
    qall = act(qall, BATCH, None, "model", None)  # heads over TP
    q_nope, q_pe = qall[..., :dn], qall[..., dn:]
    q_pe = L.apply_rope(q_pe, positions, cfg.rope_theta)

    down = L.dense_apply(p["kv_down"], x, "attn/kv_down", sp_cfg)
    ckv, k_pe = down[..., :lora], down[..., lora:]
    ckv = L.rmsnorm_apply(p["ckv_norm"], ckv)
    k_pe = L.apply_rope(k_pe[..., None, :], positions, cfg.rope_theta)[..., 0, :]

    if decode:
        assert cache is not None
        if per_slot:
            wpos = jnp.clip(positions[:, -1].astype(jnp.int32), 0,
                            cache["ckv"].shape[1] - 1)
            b_idx = jnp.arange(b)
            ckv_c = cache["ckv"].at[b_idx, wpos].set(
                ckv[:, 0].astype(cache["ckv"].dtype))
            kpe_c = cache["kpe"].at[b_idx, wpos].set(
                k_pe[:, 0].astype(cache["kpe"].dtype))
            cur = positions[:, -1]
        else:
            cur = cache["pos"]
            ckv_c = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv.astype(cache["ckv"].dtype), cur, axis=1)
            kpe_c = jax.lax.dynamic_update_slice_in_dim(cache["kpe"], k_pe.astype(cache["kpe"].dtype), cur, axis=1)
        ckv_c = act(ckv_c, BATCH, None, None)
        kpe_c = act(kpe_c, BATCH, None, None)
        # absorbed-matrix decode: attention entirely in the lora space
        wk = p["k_up"]["w"].reshape(lora, h, dn)
        q_abs = jnp.einsum("bqhd,lhd->bqhl", q_nope.astype(jnp.float32),
                           wk.astype(jnp.float32))
        scores = jnp.einsum("bqhl,bsl->bhqs", q_abs, ckv_c.astype(jnp.float32))
        scores += jnp.einsum("bqhd,bsd->bhqs", q_pe.astype(jnp.float32),
                             kpe_c.astype(jnp.float32))
        scores *= (dn + dr) ** -0.5
        smax = ckv_c.shape[1]
        cur_b = jnp.broadcast_to(jnp.asarray(cur), (b,))  # (B,) per-row
        mask = jnp.arange(smax)[None, :] <= cur_b[:, None]
        scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
        attn = jax.nn.softmax(scores, axis=-1)
        ctx_c = jnp.einsum("bhqs,bsl->bqhl", attn, ckv_c.astype(jnp.float32))
        wv = p["v_up"]["w"].reshape(lora, h, dv)
        ctx = jnp.einsum("bqhl,lhv->bqhv", ctx_c, wv.astype(jnp.float32))
        new_cache = {"ckv": ckv_c, "kpe": kpe_c, "pos": cache["pos"] + 1}
    else:
        k_nope = L.dense_apply(p["k_up"], ckv, "attn/k_up", sp_cfg)
        k_nope = k_nope.reshape(*x.shape[:-1], h, dn)
        val = L.dense_apply(p["v_up"], ckv, "attn/v_up", sp_cfg)
        val = val.reshape(*x.shape[:-1], h, dv)
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe[..., None, :],
                                                      (*k_pe.shape[:-1], h, dr))], axis=-1)
        out5 = chunked_attention(q, k, val, causal=True, q_offset=0,
                                 chunk_kv=cfg.chunk_kv)
        ctx = out5
        new_cache = None
        if cache is not None:
            ckv_c = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv.astype(cache["ckv"].dtype), 0, axis=1)
            kpe_c = jax.lax.dynamic_update_slice_in_dim(cache["kpe"], k_pe.astype(cache["kpe"].dtype), 0, axis=1)
            new_cache = {"ckv": ckv_c, "kpe": kpe_c,
                         "pos": jnp.asarray(x.shape[1], jnp.int32)}
    ctx = ctx.reshape(*x.shape[:-1], h * dv).astype(x.dtype)
    return L.dense_apply(p["o_proj"], ctx, "attn/o_proj", sp_cfg), new_cache


def init_cache(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    if cfg.kv_lora is not None:
        return {
            "ckv": jnp.zeros((batch, max_len, cfg.kv_lora), dtype),
            "kpe": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv, cfg.head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
