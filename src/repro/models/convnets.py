"""The paper's own benchmark models: ResNet9/18/50, VGG19, ViT.

These are the five DNNs of Table I/II, built on ``core/operand.nm_apply``
(MaskedOp / PregenOp conv + linear views) so BDWP applies exactly as in
the paper: every conv layer except the first (named ``head0`` — excluded
by the default SparsityConfig), plus all linear layers of the ViT
blocks.  NHWC / HWIO.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import operand as O
from repro.core.sparsity import DENSE, SparsityConfig
from repro.models import layers as L


def _conv_init(key, kh, kw, cin, cout):
    scale = (kh * kw * cin) ** -0.5
    return {"w": jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * scale}


def _bn_init(c):
    return {"norm_scale": jnp.ones((c,), jnp.float32),
            "norm_bias": jnp.zeros((c,), jnp.float32)}


def _bn_apply(p, x):
    """Inference-style norm (per-batch statistics; the paper trains with
    BN — batch statistics are equivalent for our loss-curve studies)."""
    xf = x.astype(jnp.float32)
    mu = xf.mean((0, 1, 2), keepdims=True)
    var = xf.var((0, 1, 2), keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * p["norm_scale"] + p["norm_bias"]
    return out.astype(x.dtype)


def _conv_bn_relu(p, x, sp_cfg, name, stride=1):
    y = _nm_conv_auto(p["conv"], x, sp_cfg, name, stride)
    return jax.nn.relu(_bn_apply(p["bn"], y))


def _nm_conv_auto(leaf, x, sp_cfg, name, stride=1, padding="SAME"):
    """Conv through ``operand.nm_apply``, dispatching on the leaf format.

    A pre-generated leaf (leaf["w"] is the WU-time PregenOp from
    optim/sgd.pregen_tree) consumes the stored FF/BP operands — masks
    were derived once from fp32 master at WU time.  A plain array takes
    the in-op-masking MaskedOp route; pass the fp32 master here (NOT a
    bf16 compute cast): the masked conv scores its masks on the weights
    it is given and casts to the activation dtype only after masking, so
    fp32-master masks come for free.
    """
    op = O.as_operand(leaf["w"], name, sp_cfg)
    return O.nm_apply(op, x, stride=stride, padding=padding)


# ---------------------------------------------------------------------------
# ResNet9 (DAWNBench-style, CIFAR)
# ---------------------------------------------------------------------------


def resnet9_init(key, num_classes=10, width=64):
    ks = jax.random.split(key, 12)
    w = width

    def cb(k, cin, cout):
        return {"conv": _conv_init(k, 3, 3, cin, cout), "bn": _bn_init(cout)}

    return {
        "head0": cb(ks[0], 3, w),
        "conv1": cb(ks[1], w, 2 * w),
        "res1a": cb(ks[2], 2 * w, 2 * w),
        "res1b": cb(ks[3], 2 * w, 2 * w),
        "conv2": cb(ks[4], 2 * w, 4 * w),
        "conv3": cb(ks[5], 4 * w, 8 * w),
        "res2a": cb(ks[6], 8 * w, 8 * w),
        "res2b": cb(ks[7], 8 * w, 8 * w),
        "fc": {"w": jax.random.normal(ks[8], (8 * w, num_classes), jnp.float32)
               * (8 * w) ** -0.5},
    }


def resnet9_apply(p, x, sp_cfg: SparsityConfig = DENSE):
    x = _conv_bn_relu(p["head0"], x, sp_cfg, "head0")
    x = _conv_bn_relu(p["conv1"], x, sp_cfg, "conv1")
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    r = _conv_bn_relu(p["res1a"], x, sp_cfg, "res1a")
    r = _conv_bn_relu(p["res1b"], r, sp_cfg, "res1b")
    x = x + r
    x = _conv_bn_relu(p["conv2"], x, sp_cfg, "conv2")
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    x = _conv_bn_relu(p["conv3"], x, sp_cfg, "conv3")
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    r = _conv_bn_relu(p["res2a"], x, sp_cfg, "res2a")
    r = _conv_bn_relu(p["res2b"], r, sp_cfg, "res2b")
    x = x + r
    x = x.max((1, 2))  # global max pool
    return jnp.matmul(x, p["fc"]["w"].astype(x.dtype),
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# ResNet18 / ResNet50 (standard He et al.)
# ---------------------------------------------------------------------------

_RESNET_STAGES = {
    18: ([2, 2, 2, 2], "basic"),
    50: ([3, 4, 6, 3], "bottleneck"),
}


def resnet_init(key, depth: int, num_classes=1000, width=64):
    stages, kind = _RESNET_STAGES[depth]
    ks = iter(jax.random.split(key, 256))
    p = {"head0": {"conv": _conv_init(next(ks), 7, 7, 3, width),
                   "bn": _bn_init(width)}}
    cin = width
    for si, n_blocks in enumerate(stages):
        cout = width * (2 ** si)
        cexp = cout * (4 if kind == "bottleneck" else 1)
        for bi in range(n_blocks):
            name = f"s{si}b{bi}"
            blk = {}
            if kind == "basic":
                blk["c1"] = {"conv": _conv_init(next(ks), 3, 3, cin, cout),
                             "bn": _bn_init(cout)}
                blk["c2"] = {"conv": _conv_init(next(ks), 3, 3, cout, cout),
                             "bn": _bn_init(cout)}
            else:
                blk["c1"] = {"conv": _conv_init(next(ks), 1, 1, cin, cout),
                             "bn": _bn_init(cout)}
                blk["c2"] = {"conv": _conv_init(next(ks), 3, 3, cout, cout),
                             "bn": _bn_init(cout)}
                blk["c3"] = {"conv": _conv_init(next(ks), 1, 1, cout, cexp),
                             "bn": _bn_init(cexp)}
            if bi == 0 and cin != cexp:
                blk["proj"] = {"conv": _conv_init(next(ks), 1, 1, cin, cexp),
                               "bn": _bn_init(cexp)}
            p[name] = blk
            cin = cexp
    p["fc"] = {"w": jax.random.normal(next(ks), (cin, num_classes), jnp.float32)
               * cin ** -0.5}
    p["_meta"] = jnp.asarray([depth], jnp.int32)
    return p


def resnet_apply(p, x, depth: int, sp_cfg: SparsityConfig = DENSE, width=64):
    stages, kind = _RESNET_STAGES[depth]
    x = _conv_bn_relu(p["head0"], x, sp_cfg, "head0", stride=2)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    for si, n_blocks in enumerate(stages):
        for bi in range(n_blocks):
            blk = p[f"s{si}b{bi}"]
            stride = 2 if (bi == 0 and si > 0) else 1
            sc = x
            if "proj" in blk:
                sc = _nm_conv_auto(blk["proj"]["conv"], x, sp_cfg,
                                   f"s{si}b{bi}/proj", stride)
                sc = _bn_apply(blk["proj"]["bn"], sc)
            if kind == "basic":
                y = _conv_bn_relu(blk["c1"], x, sp_cfg, f"s{si}b{bi}/c1", stride)
                y = _nm_conv_auto(blk["c2"]["conv"], y, sp_cfg,
                                  f"s{si}b{bi}/c2", 1)
                y = _bn_apply(blk["c2"]["bn"], y)
            else:
                y = _conv_bn_relu(blk["c1"], x, sp_cfg, f"s{si}b{bi}/c1", 1)
                y = _conv_bn_relu(blk["c2"], y, sp_cfg, f"s{si}b{bi}/c2", stride)
                y = _nm_conv_auto(blk["c3"]["conv"], y, sp_cfg,
                                  f"s{si}b{bi}/c3", 1)
                y = _bn_apply(blk["c3"]["bn"], y)
            x = jax.nn.relu(sc + y)
    x = x.mean((1, 2))
    return jnp.matmul(x, p["fc"]["w"].astype(x.dtype),
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# VGG19
# ---------------------------------------------------------------------------

_VGG19 = [64, 64, "M", 128, 128, "M", 256, 256, 256, 256, "M",
          512, 512, 512, 512, "M", 512, 512, 512, 512, "M"]


def vgg19_init(key, num_classes=100):
    ks = iter(jax.random.split(key, 64))
    p = {}
    cin = 3
    for i, v in enumerate(_VGG19):
        if v == "M":
            continue
        name = "head0" if cin == 3 else f"conv{i}"
        p[name] = {"conv": _conv_init(next(ks), 3, 3, cin, v), "bn": _bn_init(v)}
        cin = v
    p["fc"] = {"w": jax.random.normal(next(ks), (512, num_classes), jnp.float32)
               * 512 ** -0.5}
    return p


def vgg19_apply(p, x, sp_cfg: SparsityConfig = DENSE):
    cin = 3
    for i, v in enumerate(_VGG19):
        if v == "M":
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                      (1, 2, 2, 1), "VALID")
            continue
        name = "head0" if cin == 3 else f"conv{i}"
        x = _conv_bn_relu(p[name], x, sp_cfg, name)
        cin = v
    x = x.mean((1, 2))
    return jnp.matmul(x, p["fc"]["w"].astype(x.dtype),
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# ViT (CIFAR-scale, the paper's transformer benchmark)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    image: int = 32
    patch: int = 4
    d_model: int = 384
    n_layers: int = 7
    n_heads: int = 6
    d_ff: int = 1536
    num_classes: int = 100


def vit_init(key, cfg: ViTConfig):
    ks = iter(jax.random.split(key, 8 + 8 * cfg.n_layers))
    n_patch = (cfg.image // cfg.patch) ** 2
    pdim = cfg.patch * cfg.patch * 3
    p = {
        "patch_frontend": {"w": jax.random.normal(next(ks), (pdim, cfg.d_model),
                                                  jnp.float32) * pdim ** -0.5},
        "pos_embed": jax.random.normal(next(ks), (n_patch + 1, cfg.d_model),
                                       jnp.float32) * 0.02,
        "cls_embed": jnp.zeros((cfg.d_model,), jnp.float32),
        "head": {"w": jax.random.normal(next(ks), (cfg.d_model, cfg.num_classes),
                                        jnp.float32) * cfg.d_model ** -0.5},
    }
    hd = cfg.d_model // cfg.n_heads
    for i in range(cfg.n_layers):
        blk = {}
        blk["ln1"], _ = L.layernorm_init(cfg.d_model)
        blk["ln2"], _ = L.layernorm_init(cfg.d_model)
        for nm in ("q_proj", "k_proj", "v_proj", "o_proj"):
            din = cfg.d_model
            blk[nm] = {"w": jax.random.normal(next(ks), (din, cfg.d_model),
                                              jnp.float32) * din ** -0.5}
        blk["w_in"] = {"w": jax.random.normal(next(ks), (cfg.d_model, cfg.d_ff),
                                              jnp.float32) * cfg.d_model ** -0.5}
        blk["w_out"] = {"w": jax.random.normal(next(ks), (cfg.d_ff, cfg.d_model),
                                               jnp.float32) * cfg.d_ff ** -0.5}
        p[f"block{i}"] = blk
    return p


def _nm_lin(leaf, x, name, sp_cfg):
    """ViT linear through operand.nm_apply (array or PregenOp leaf)."""
    return O.nm_apply(O.as_operand(leaf["w"], name, sp_cfg), x)


def vit_apply(p, x, cfg: ViTConfig, sp_cfg: SparsityConfig = DENSE):
    b = x.shape[0]
    s = cfg.image // cfg.patch
    x = x.reshape(b, s, cfg.patch, s, cfg.patch, 3).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(b, s * s, -1).astype(jnp.bfloat16)
    # patch embedding = the "first layer" -> excluded from pruning by name
    x = _nm_lin(p["patch_frontend"], x, "patch_frontend", sp_cfg)
    cls = jnp.broadcast_to(p["cls_embed"].astype(x.dtype), (b, 1, cfg.d_model))
    x = jnp.concatenate([cls, x], axis=1)
    x = x + p["pos_embed"].astype(x.dtype)
    hd = cfg.d_model // cfg.n_heads
    for i in range(cfg.n_layers):
        blk = p[f"block{i}"]
        h = L.layernorm_apply(blk["ln1"], x)
        q = _nm_lin(blk["q_proj"], h, "attn/q_proj", sp_cfg)
        k = _nm_lin(blk["k_proj"], h, "attn/k_proj", sp_cfg)
        v = _nm_lin(blk["v_proj"], h, "attn/v_proj", sp_cfg)
        q = q.reshape(b, -1, cfg.n_heads, hd)
        k = k.reshape(b, -1, cfg.n_heads, hd)
        v = v.reshape(b, -1, cfg.n_heads, hd)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                            preferred_element_type=jnp.float32) * hd ** -0.5
        attn = jax.nn.softmax(logits, -1).astype(v.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", attn, v).reshape(b, -1, cfg.d_model)
        o = _nm_lin(blk["o_proj"], o, "attn/o_proj", sp_cfg)
        x = x + o
        h2 = L.layernorm_apply(blk["ln2"], x)
        f = jax.nn.gelu(_nm_lin(blk["w_in"], h2, "mlp/w_in", sp_cfg))
        x = x + _nm_lin(blk["w_out"], f.astype(x.dtype), "mlp/w_out", sp_cfg)
    cls_out = x[:, 0]
    return jnp.matmul(cls_out, p["head"]["w"].astype(cls_out.dtype),
                      preferred_element_type=jnp.float32)
