"""Encoder-decoder transformer (Whisper-family backbone).

Per the assignment the conv/mel frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, T_enc, d) — the encoder is the
bidirectional transformer stack over those frames, the decoder is a
causal stack with cross-attention.  GELU MLP + LayerNorm (Whisper uses
pre-LN GELU blocks, learned positions, no RoPE).

BDWP applies to every projection (the paper prunes all ViT linear
layers; Whisper's are the same shape class).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sparsity import DENSE, SparsityConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.sharding.rules import BATCH, act


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int          # decoder layers
    n_enc_layers: int      # encoder layers
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    max_source: int = 1500
    max_target: int = 448
    remat: bool = True
    pad_vocab_to: int = 256  # vocab-parallel padding (see LMConfig)

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab // self.pad_vocab_to) * self.pad_vocab_to

    def n_params(self) -> int:
        import math

        p, _ = init(jax.random.PRNGKey(0), self, abstract=True)
        return sum(math.prod(x.shape) for x in jax.tree.leaves(p))

    def n_active_params(self) -> int:
        return self.n_params()

    def attn_cfg(self) -> A.AttnConfig:
        return A.AttnConfig(d_model=self.d_model, n_heads=self.n_heads,
                            n_kv=self.n_kv, head_dim=self.head_dim)


def _gelu_ffn_init(key, d, d_ff):
    k1, k2 = jax.random.split(key)
    p, s = {}, {}
    p["w_in"], s["w_in"] = L.dense_init(k1, d, d_ff, axes=("embed", "mlp"), bias=True)
    p["w_out"], s["w_out"] = L.dense_init(k2, d_ff, d, axes=("mlp", "embed"), bias=True)
    return p, s


def _gelu_ffn_apply(p, x, sp_cfg):
    h = jax.nn.gelu(L.dense_apply(p["w_in"], x, "mlp/w_in", sp_cfg))
    h = act(h, BATCH, None, "model")
    return L.dense_apply(p["w_out"], h.astype(x.dtype), "mlp/w_out", sp_cfg)


def _xattn_init(key, cfg: EncDecConfig):
    """Cross-attention: q from decoder, k/v from encoder output."""
    ks = jax.random.split(key, 4)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    p, s = {}, {}
    p["q_proj"], s["q_proj"] = L.dense_init(ks[0], d, h * hd, axes=("embed", "heads"))
    p["k_proj"], s["k_proj"] = L.dense_init(ks[1], d, kv * hd, axes=("embed", "kv"))
    p["v_proj"], s["v_proj"] = L.dense_init(ks[2], d, kv * hd, axes=("embed", "kv"))
    p["o_proj"], s["o_proj"] = L.dense_init(ks[3], h * hd, d, axes=("heads", "embed"))
    return p, s


def _xattn_apply(p, x, enc_kv, cfg: EncDecConfig, sp_cfg):
    """enc_kv: precomputed (k, v) from encoder output (cached for decode)."""
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = L.dense_apply(p["q_proj"], x, "xattn/q_proj", sp_cfg)
    q = q.reshape(*x.shape[:-1], h, hd)
    k, v = enc_kv
    out = A.chunked_attention(q, k, v, causal=False, q_offset=0, chunk_kv=512)
    out = out.reshape(*x.shape[:-1], h * hd)
    return L.dense_apply(p["o_proj"], out, "xattn/o_proj", sp_cfg)


def _enc_block_init(key, cfg: EncDecConfig):
    k1, k2 = jax.random.split(key)
    p, s = {}, {}
    p["ln1"], s["ln1"] = L.layernorm_init(cfg.d_model)
    p["ln2"], s["ln2"] = L.layernorm_init(cfg.d_model)
    p["attn"], s["attn"] = A.attn_init(k1, cfg.attn_cfg())
    p["ffn"], s["ffn"] = _gelu_ffn_init(k2, cfg.d_model, cfg.d_ff)
    return p, s


def _dec_block_init(key, cfg: EncDecConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    p, s = {}, {}
    p["ln1"], s["ln1"] = L.layernorm_init(cfg.d_model)
    p["ln2"], s["ln2"] = L.layernorm_init(cfg.d_model)
    p["ln3"], s["ln3"] = L.layernorm_init(cfg.d_model)
    p["attn"], s["attn"] = A.attn_init(k1, cfg.attn_cfg())
    p["xattn"], s["xattn"] = _xattn_init(k2, cfg)
    p["ffn"], s["ffn"] = _gelu_ffn_init(k3, cfg.d_model, cfg.d_ff)
    return p, s


def init(key, cfg: EncDecConfig, abstract: bool = False):
    box = {}

    def build(key):
        ks = jax.random.split(key, 6)
        p, s = {}, {}
        p["embed"], s["embed"] = L.embed_init(ks[0], cfg.padded_vocab, cfg.d_model)
        p["pos_embed_dec"] = jax.random.normal(
            ks[1], (cfg.max_target, cfg.d_model), jnp.float32) * 0.01
        s["pos_embed_dec"] = (None, "embed")
        p["pos_embed_enc"] = jax.random.normal(
            ks[2], (cfg.max_source, cfg.d_model), jnp.float32) * 0.01
        s["pos_embed_enc"] = (None, "embed")
        ekeys = jax.random.split(ks[3], cfg.n_enc_layers)
        p["enc_blocks"] = jax.vmap(lambda k: _enc_block_init(k, cfg)[0])(ekeys)
        s["enc_blocks"] = _stack_spec(_spec_of(partial(_enc_block_init, cfg=cfg)))
        dkeys = jax.random.split(ks[4], cfg.n_layers)
        p["dec_blocks"] = jax.vmap(lambda k: _dec_block_init(k, cfg)[0])(dkeys)
        s["dec_blocks"] = _stack_spec(_spec_of(partial(_dec_block_init, cfg=cfg)))
        p["enc_norm"], s["enc_norm"] = L.layernorm_init(cfg.d_model)
        p["dec_norm"], s["dec_norm"] = L.layernorm_init(cfg.d_model)
        box["specs"] = s
        return p

    if abstract:
        return jax.eval_shape(build, key), box["specs"]
    return build(key), box["specs"]


def _spec_of(init_fn):
    box = {}

    def f(k):
        p, s = init_fn(k)
        box["s"] = s
        return p

    jax.eval_shape(f, jax.random.PRNGKey(0))
    return box["s"]


def _is_axes(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def _stack_spec(spec):
    return jax.tree.map(lambda ax: ("layer",) + tuple(ax), spec, is_leaf=_is_axes)


def encode(params, frames, cfg: EncDecConfig, sp_cfg: SparsityConfig = DENSE):
    """frames: (B, T_enc, d) stub-frontend embeddings -> (B, T_enc, d)."""
    x = frames.astype(jnp.bfloat16)
    t = x.shape[1]
    x = x + params["pos_embed_enc"][:t].astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(t), x.shape[:2])

    def body(xh, bp):
        xh = act(xh, BATCH, None, None)
        h = L.layernorm_apply(bp["ln1"], xh)
        acfg = cfg.attn_cfg()
        hseq = h
        q = L.dense_apply(bp["attn"]["q_proj"], hseq, "attn/q_proj", sp_cfg)
        k = L.dense_apply(bp["attn"]["k_proj"], hseq, "attn/k_proj", sp_cfg)
        v = L.dense_apply(bp["attn"]["v_proj"], hseq, "attn/v_proj", sp_cfg)
        q = q.reshape(*hseq.shape[:-1], acfg.n_heads, acfg.head_dim)
        k = k.reshape(*hseq.shape[:-1], acfg.n_kv, acfg.head_dim)
        v = v.reshape(*hseq.shape[:-1], acfg.n_kv, acfg.head_dim)
        attn = A.chunked_attention(q, k, v, causal=False, q_offset=0, chunk_kv=512)
        attn = attn.reshape(*hseq.shape[:-1], acfg.n_heads * acfg.head_dim)
        xh = xh + L.dense_apply(bp["attn"]["o_proj"], attn, "attn/o_proj", sp_cfg)
        xh = xh + _gelu_ffn_apply(bp["ffn"], L.layernorm_apply(bp["ln2"], xh), sp_cfg)
        return xh, None

    fn = body
    if cfg.remat:
        fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(fn, x, params["enc_blocks"])
    return L.layernorm_apply(params["enc_norm"], x)


def _enc_kv(bp, enc_out, cfg: EncDecConfig, sp_cfg):
    acfg = cfg.attn_cfg()
    k = L.dense_apply(bp["xattn"]["k_proj"], enc_out, "xattn/k_proj", sp_cfg)
    v = L.dense_apply(bp["xattn"]["v_proj"], enc_out, "xattn/v_proj", sp_cfg)
    k = k.reshape(*enc_out.shape[:-1], acfg.n_kv, acfg.head_dim)
    v = v.reshape(*enc_out.shape[:-1], acfg.n_kv, acfg.head_dim)
    return k, v


def decode(params, tokens, enc_out, cfg: EncDecConfig,
           sp_cfg: SparsityConfig = DENSE, *, cache=None, decode_step=False,
           positions=None):
    """Decoder trunk.  Returns (hidden, new_cache)."""
    x = L.embed_apply(params["embed"], tokens)
    b, s = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = x + jnp.take(params["pos_embed_dec"], positions, axis=0).astype(x.dtype)
    acfg = cfg.attn_cfg()

    def body(carry, xs):
        xh = carry
        bp, layer_cache = xs
        xh = act(xh, BATCH, None, None)
        h = L.layernorm_apply(bp["ln1"], xh)
        mix, nc = A.attn_apply(bp["attn"], h, acfg, sp_cfg, positions=positions,
                               cache=layer_cache, decode=decode_step)
        xh = xh + mix
        h2 = L.layernorm_apply(bp["ln2"], xh)
        kv = _enc_kv(bp, enc_out, cfg, sp_cfg)
        xh = xh + _xattn_apply(bp["xattn"], h2, kv, cfg, sp_cfg)
        xh = xh + _gelu_ffn_apply(bp["ffn"], L.layernorm_apply(bp["ln3"], xh), sp_cfg)
        return xh, nc

    fn = body
    if cfg.remat and not decode_step:
        fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    layer_caches = cache["layers"] if cache is not None else None
    if layer_caches is None:
        x, _ = jax.lax.scan(lambda c, bp: (fn(c, (bp, None))[0], None),
                            x, params["dec_blocks"])
        new_cache = None
    else:
        x, new_layers = jax.lax.scan(fn, x, (params["dec_blocks"], layer_caches))
        new_cache = {"layers": new_layers}
    x = L.layernorm_apply(params["dec_norm"], x)
    return x, new_cache


def logits_from_hidden(params, hidden, cfg: Optional[EncDecConfig] = None):
    logits = jnp.matmul(hidden, params["embed"]["embed_table"].T.astype(hidden.dtype),
                        preferred_element_type=jnp.float32)
    if cfg is not None and cfg.padded_vocab != cfg.vocab:
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(valid, logits, -1e30)
    return logits


def init_cache(cfg: EncDecConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    per = [A.init_cache(cfg.attn_cfg(), batch, max_len, dtype)
           for _ in range(cfg.n_layers)]
    return {"layers": jax.tree.map(lambda *xs: jnp.stack(xs), *per)}
