"""Shared layer primitives: functional params + logical-axis specs.

Parameters live in plain nested dicts; every ``*_init`` returns
``(params, specs)`` where ``specs`` mirrors the tree with tuples of
*logical axis names*.  ``sharding/rules.py`` maps logical axes to mesh
axes per workload (MaxText-style), so one model definition serves every
(shape x mesh) cell of the dry-run.

Every weight matmul routes through ``core/operand.nm_apply`` so the
paper's N:M sparse training semantics apply uniformly; per-parameter
eligibility is decided by name via ``bdwp.pick_cfg`` (embeddings,
routers, norms and frontends stay dense — the paper's first-layer
exclusion, generalized).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import operand as O
from repro.core.sparsity import SparsityConfig

# Logical axis vocabulary (see sharding/rules.py):
#   "embed"   – model width (FSDP-shardable)
#   "mlp"     – FFN hidden (tensor-parallel)
#   "heads"   – flattened attention heads*head_dim (tensor-parallel)
#   "kv"      – kv heads*head_dim
#   "vocab"   – vocabulary (tensor-parallel)
#   "expert"  – MoE expert (expert-parallel)
#   "layer"   – stacked scan-over-layers axis (never sharded)
#   None      – replicated


def dense_init(key, d_in: int, d_out: int, *, axes, bias: bool = False,
               scale: Optional[float] = None, dtype=jnp.float32):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    s = {"w": axes}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
        s["b"] = (axes[-1],)
    return p, s


def dense_apply(p, x, name: str, cfg: SparsityConfig, compute_dtype=jnp.bfloat16):
    """x @ w via the SparseOperand algebra (core/operand.nm_apply).

    The leaf under ``p["w"]`` may be any operand variant — a plain array
    (legacy in-op masking with per-param eligibility), a PregenOp (the
    pre-generated training dataflow, Fig. 11c), a PackedOp (element-
    packed serving, consumed through kernels/nm_spmm) — or, for trees
    written by older packers, the equivalent dicts, including the flat
    shared-packed ``{"vals", "idx"}`` layout of bdwp.pack_tree_shared;
    ``as_operand`` normalizes every format and ``nm_apply`` carries the
    consumption + custom-VJP semantics."""
    leaf = p["w"] if "w" in p else p
    op = O.as_operand(leaf, name, cfg)
    y = O.nm_apply(op, x.astype(compute_dtype))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def rmsnorm_init(d: int):
    return {"norm_scale": jnp.ones((d,), jnp.float32)}, {"norm_scale": ("embed",)}


def rmsnorm_apply(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["norm_scale"]
    return out.astype(x.dtype)


def layernorm_init(d: int):
    return (
        {"norm_scale": jnp.ones((d,), jnp.float32),
         "norm_bias": jnp.zeros((d,), jnp.float32)},
        {"norm_scale": ("embed",), "norm_bias": ("embed",)},
    )


def layernorm_apply(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["norm_scale"] + p["norm_bias"]
    return out.astype(x.dtype)


def embed_init(key, vocab: int, d: int, scale: float = 1.0):
    p = {"embed_table": jax.random.normal(key, (vocab, d), jnp.float32) * scale * d ** -0.5}
    return p, {"embed_table": ("vocab", "embed")}


def embed_apply(p, tokens, compute_dtype=jnp.bfloat16):
    return jnp.take(p["embed_table"], tokens, axis=0).astype(compute_dtype)


def unembed_apply(p, x, name="lm_head_embed", tied_table=None):
    """Logits projection (never pruned — 'embed' is in the exclusion list)."""
    table = tied_table if tied_table is not None else p["embed_table"]
    return jnp.matmul(x, table.T.astype(x.dtype), preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0):
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(gate: jax.Array, up: jax.Array):
    return jax.nn.silu(gate) * up


@dataclasses.dataclass(frozen=True)
class Policy:
    """Numerics policy (the WUVE/AMP analogue at the model level)."""

    compute_dtype: jnp.dtype = jnp.bfloat16
    param_dtype: jnp.dtype = jnp.float32
    logits_dtype: jnp.dtype = jnp.float32
