"""Mixture-of-Experts FFN: top-k routing, capacity dispatch, shared experts.

GShard/Switch-style dense dispatch (one-hot einsums) — the formulation
GSPMD turns into all-to-alls under expert-parallel sharding of the
``expert`` logical axis.  Expert FFN weights route through BDWP (the
paper's N:M sparsity applies per-expert along the contraction axes);
the router stays dense (excluded by name — accuracy-critical and tiny,
the spirit of the paper's first-layer exclusion).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import bdwp
from repro.core import operand as O
from repro.core.sparsity import SparsityConfig
from repro.models import layers as L
from repro.sharding.rules import BATCH, act


def _slot_gather(src, idx):
    """out[g, a, b, :] = src[g, idx[g, a, b], :]; OOB indices read 0.

    Plain take_along_axis.  (A custom-VJP variant with a manual bf16
    scatter-add was tried to keep the backward in 16-bit; under GSPMD
    the explicit scatter replicated the expert-sharded source and
    *tripled* collective traffic — refuted, see EXPERIMENTS.md §Perf.)

    mode="fill" stands in for the zero row a concat-pad would provide:
    gathering from a concat-padded source (sg+1 rows) is miscompiled by
    the SPMD partitioner when the token axis is sharded unevenly (small
    decode batches put the DP axes on sg) — the fill-mode gather from
    the evenly-sharded source is bitwise-identical and partitions
    correctly (tests/test_spmd.py drives this on a forced mesh).
    """
    return jnp.take_along_axis(src[:, None], idx[..., None], axis=2,
                               mode="fill", fill_value=0)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int          # per-expert FFN hidden size
    n_shared: int = 0      # always-on shared experts (deepseek-v2 style)
    capacity_factor: float = 1.25
    group_size: int = 512  # routing group (GShard): capacity is per-group


def moe_init(key, d_model: int, cfg: MoEConfig):
    ks = jax.random.split(key, 8)
    e, dff = cfg.n_experts, cfg.d_expert
    scale = d_model ** -0.5
    p = {
        "router": {"w": jax.random.normal(ks[0], (d_model, e), jnp.float32) * scale},
        "w_gate": jax.random.normal(ks[1], (e, d_model, dff), jnp.float32) * scale,
        "w_up": jax.random.normal(ks[2], (e, d_model, dff), jnp.float32) * scale,
        "w_down": jax.random.normal(ks[3], (e, dff, d_model), jnp.float32) * (dff ** -0.5),
    }
    s = {
        "router": {"w": ("embed", None)},
        "w_gate": ("expert", "embed", "mlp"),
        "w_up": ("expert", "embed", "mlp"),
        "w_down": ("expert", "mlp", "embed"),
    }
    if cfg.n_shared:
        sh = cfg.n_shared * dff
        p["shared"] = {
            "w_gate": jax.random.normal(ks[4], (d_model, sh), jnp.float32) * scale,
            "w_up": jax.random.normal(ks[5], (d_model, sh), jnp.float32) * scale,
            "w_down": jax.random.normal(ks[6], (sh, d_model), jnp.float32) * (sh ** -0.5),
        }
        s["shared"] = {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
                       "w_down": ("mlp", "embed")}
    return p, s


def _nm_mm(leaf, x, name: str, sp_cfg: SparsityConfig, *,
           stacked: bool = False):
    """One bare-leaf matmul through ``operand.nm_apply``.

    Pre-generated operand leaves (the training dataflow — optim/sgd
    wrote the bf16 FF/BP copies at WU time, masks scored once on fp32
    master) consume as PregenOp: the MoE forward/backward derive zero
    masks, packed ``(vals, idx)`` stacks stream through kernels/nm_spmm
    on the pallas backend, and the dense straight-through WU gradient
    rides the BP operand's cotangent — exactly like layers.dense_apply.
    Bare arrays keep the legacy self-masking semantics (MaskedOp:
    serving from raw bf16 weights, dense methods, the pregen=False A/B
    path).  With ``stacked=True`` the leaf carries a leading expert axis
    and the matmul is vmapped per expert — N:M groups stay within one
    expert.
    """
    if isinstance(leaf, O.SparseOperand) or bdwp.is_pregen(leaf):
        op = O.as_operand(leaf, name, sp_cfg)
    else:
        lshape = leaf.shape[1:] if stacked else leaf.shape
        op = O.MaskedOp(leaf, bdwp.pick_cfg(name, lshape, sp_cfg))
    return O.nm_apply(op, x, stacked=stacked)


def _expert_ffn(w_gate, w_up, w_down, x, sp_cfg: SparsityConfig):
    """x: (E, C, d) -> (E, C, d); vmapped BDWP matmuls per expert."""
    h = L.swiglu(_nm_mm(w_gate, x, "moe/expert/w_gate", sp_cfg, stacked=True),
                 _nm_mm(w_up, x, "moe/expert/w_up", sp_cfg, stacked=True))
    return _nm_mm(w_down, h.astype(x.dtype), "moe/expert/w_down", sp_cfg,
                  stacked=True)


def moe_apply(p, x, cfg: MoEConfig, sp_cfg: SparsityConfig):
    """x: (B, S, d) -> (B, S, d) plus aux load-balancing loss.

    GShard-style *grouped* routing with gather/scatter dispatch: tokens
    are split into groups of ``group_size`` and capacity is per-group,
    so no tensor ever scales with (global_tokens x experts x capacity).
    Dispatch/combine are index gathers (memory ops, fully differentiable
    through the value path), not dense one-hot matmuls — at the 1M-token
    train_4k shapes the one-hot formulation would cost more FLOPs than
    the experts themselves.  Expert-parallel sharding over "model" turns
    the (G, E, C, d) regroup into the canonical all-to-all.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    sg = min(cfg.group_size, t)
    while t % sg:  # static: largest divisor fallback
        sg -= 1
    g = t // sg
    xt = x.reshape(g, sg, d)

    logits = jnp.matmul(xt, p["router"]["w"].astype(xt.dtype),
                        preferred_element_type=jnp.float32)  # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (G, S, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = int(max(cfg.top_k, round(sg * cfg.capacity_factor * k / e)))
    cap = min(cap, sg)

    # slot assignment inside each (group, expert) queue
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)  # (G, S, K, E)
    flat = onehot.reshape(g, sg * k, e)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat              # (G, S*K, E)
    pos = (pos_in_e * flat).sum(-1).reshape(g, sg, k)       # (G, S, K)
    keep = pos < cap
    gate_vals = gate_vals * keep

    # scatter: slot_token[g, e, c] = index of the token filling that slot
    gi = jnp.broadcast_to(jnp.arange(g)[:, None, None], gate_idx.shape)
    si = jnp.broadcast_to(jnp.arange(sg)[None, :, None], gate_idx.shape)
    pos_c = jnp.where(keep, pos, cap)  # dropped -> sentinel column
    slot_token = jnp.full((g, e, cap + 1), sg, jnp.int32)  # sg = zero row
    slot_token = slot_token.at[gi, gate_idx, pos_c].set(si, mode="drop")
    slot_token = slot_token[..., :cap]                      # (G, E, C)

    # gather dispatched tokens (sentinel index sg is OOB -> reads zero)
    x_e = _slot_gather(xt, slot_token)                      # (G, E, C, d)
    x_e = act(x_e, BATCH, "model", None, None)  # EP: experts over "model"
    xe2 = x_e.transpose(1, 0, 2, 3).reshape(e, g * cap, d)  # the all-to-all
    y_e = _expert_ffn(p["w_gate"], p["w_up"], p["w_down"], xe2, sp_cfg)
    y_e = y_e.reshape(e, g, cap, d).transpose(1, 0, 2, 3)   # (G, E, C, d)
    y_e = act(y_e, BATCH, "model", None, None)
    # reshard expert-sharded outputs back to token shards BEFORE the
    # combine gather — one (G,E,C,d)-sized hop (a2a-class traffic);
    # gathering from an expert-sharded tensor instead would all-gather
    # the full dispatched tensor onto every chip (~16x the bytes)
    y_e = act(y_e, BATCH, None, None, None)

    # combine: token side gathers its K slots back, weighted by gates
    y_flat = y_e.reshape(g, e * cap, d)
    slot_of = gate_idx * cap + jnp.where(keep, pos, 0)      # (G, S, K)
    y_k = _slot_gather(y_flat, slot_of)                     # (G, S, K, d)
    yt = (y_k * gate_vals[..., None].astype(y_k.dtype)).sum(2)  # (G, S, d)
    yt = act(yt, BATCH, None, None)
    yt = yt.reshape(t, d)

    if "shared" in p:
        sh = p["shared"]
        xt2 = xt.reshape(t, d)
        h = L.swiglu(_nm_mm(sh["w_gate"], xt2, "moe/shared/w_gate", sp_cfg),
                     _nm_mm(sh["w_up"], xt2, "moe/shared/w_up", sp_cfg))
        yt = yt + _nm_mm(sh["w_down"], h.astype(xt2.dtype),
                         "moe/shared/w_down", sp_cfg)

    # Switch-style load-balance aux loss (counts from kept assignments)
    me = probs.mean((0, 1))                                 # (E,)
    counts = (onehot * keep[..., None]).sum((0, 1, 2)).astype(jnp.float32)
    ce = counts / jnp.maximum(counts.sum(), 1.0)
    aux = e * jnp.sum(me * ce)
    return yt.reshape(b, s, d).astype(x.dtype), aux
