"""Mamba-2 (SSD — state-space duality) block, train + single-step decode.

Chunked SSD algorithm (Dao & Gu, 2024, arXiv:2405.21060): within-chunk
quadratic "attention-like" term + across-chunk recurrent state passing.
All big projections (in_proj / out_proj) route through BDWP — the SSD
scan itself has no prunable weight contraction (noted in DESIGN.md
§Arch-applicability), but the projections are ~90% of block FLOPs.

Shapes follow the minimal mamba2: heads H = d_inner / head_dim P,
scalar A per head, grouped B/C (n_groups=1), short depthwise causal
conv on (x, B, C).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.sparsity import SparsityConfig
from repro.models import layers as L
from repro.sharding.rules import BATCH as _BATCH, act as _act


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.d_state


def ssm_init(key, cfg: SSMConfig):
    ks = jax.random.split(key, 6)
    d, di, st, nh = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    d_in_proj = 2 * di + 2 * st + nh  # z, x, B, C, dt
    scale = d ** -0.5
    p = {
        "in_proj": {"w": jax.random.normal(ks[0], (d, d_in_proj), jnp.float32) * scale},
        "out_proj": {"w": jax.random.normal(ks[1], (di, d), jnp.float32) * (di ** -0.5)},
        "conv_w": jax.random.normal(ks[2], (cfg.d_conv, cfg.conv_dim), jnp.float32) * 0.3,
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 0.01, jnp.float32))),
        "ssm_norm": {"norm_scale": jnp.ones((di,), jnp.float32)},
    }
    s = {
        "in_proj": {"w": ("embed", "mlp")},
        "out_proj": {"w": ("mlp", "embed")},
        "conv_w": (None, "mlp"),
        "A_log": (None,), "D": (None,), "dt_bias": (None,),
        "ssm_norm": {"norm_scale": ("mlp",)},
    }
    return p, s


def _causal_conv(xbc, conv_w, conv_state=None):
    """Depthwise causal conv1d. xbc: (B, S, C); conv_w: (K, C)."""
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i : i + xbc.shape[1]] * conv_w[i].astype(xbc.dtype)
              for i in range(k))
    new_state = xp[:, -(k - 1):]
    return jax.nn.silu(out), new_state


def _ssd_chunked(x, dt, A, Bmat, Cmat, D, chunk: int):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H); A: (H,) negative decay rates;
    Bmat/Cmat: (B, S, N); D: (H,).  Returns y: (B, S, H, P).
    """
    b, s, h, pdim = x.shape
    n = Bmat.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, pdim)
    dtc = dt.reshape(b, nc, chunk, h)
    bc = Bmat.reshape(b, nc, chunk, n)
    cc = Cmat.reshape(b, nc, chunk, n)

    dA = dtc * A  # (B,nc,L,H) negative
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative log-decay

    # ---- intra-chunk (quadratic in chunk length) ----
    # decay(t, s) = exp(cum_t - cum_s) for t >= s.  The (B,nc,L,L,H)
    # attention-like factors are bounded in [0,1] -> bf16 is safe and
    # halves the dominant memory term; accumulation stays fp32 via
    # preferred_element_type (the state recurrence below stays fp32).
    li = cum[:, :, :, None, :]   # (B,nc,L,1,H) query t
    lj = cum[:, :, None, :, :]   # (B,nc,1,L,H) key s
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.exp(jnp.where(mask[None, None, :, :, None], li - lj, -jnp.inf))
    cb = jnp.einsum("bzln,bzmn->bzlm", cc.astype(jnp.bfloat16),
                    bc.astype(jnp.bfloat16),
                    preferred_element_type=jnp.float32)  # (B,nc,L,L)
    att = (cb[..., None] * decay).astype(jnp.bfloat16)  # (B,nc,L,L,H)
    dtx = (dtc[..., None] * xc).astype(jnp.bfloat16)    # (B,nc,L,H,P)
    y_intra = jnp.einsum("bzlmh,bzmhp->bzlhp", att, dtx,
                         preferred_element_type=jnp.float32)

    # ---- chunk states and inter-chunk recurrence ----
    # state contribution of chunk z: sum_s exp(cum_L - cum_s) dt_s B_s x_s
    tail = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,L,H)
    states = jnp.einsum("bzlh,bzln,bzlhp->bzhnp",
                        tail.astype(jnp.bfloat16), bc.astype(jnp.bfloat16),
                        dtx, preferred_element_type=jnp.float32)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H) total decay of a chunk

    def scan_fn(h_prev, inp):
        st, dec = inp  # (B,H,N,P), (B,H)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev  # emit state *entering* the chunk

    h0 = jnp.zeros((b, h, n, pdim), x.dtype)
    h_last, h_in = jax.lax.scan(
        scan_fn, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    h_in = h_in.swapaxes(0, 1)  # (B,nc,H,N,P) state entering each chunk

    # inter-chunk output: y_t += C_t · exp(cum_t) h_in
    inter_decay = jnp.exp(cum)  # (B,nc,L,H)
    y_inter = jnp.einsum("bzln,bzlh,bzhnp->bzlhp",
                         cc.astype(jnp.bfloat16),
                         inter_decay.astype(jnp.bfloat16),
                         h_in.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).reshape(b, s, h, pdim)
    y = y + x * D[None, None, :, None]
    return y, h_last


def ssm_apply(p, x, cfg: SSMConfig, sp_cfg: SparsityConfig, *, cache=None,
              decode: bool = False):
    """x: (B, S, d) -> (B, S, d).  cache: {'state': (B,H,N,P), 'conv': (B,K-1,C)}"""
    b, s, d = x.shape
    di, st, nh, pdim = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    proj = L.dense_apply(p["in_proj"], x, "ssm/in_proj", sp_cfg)
    proj = _act(proj, _BATCH, None, None)  # batch stays data-parallel
    z, xin, bmat, cmat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + st, 2 * di + 2 * st], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,) negative

    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv_state = cache.get("conv") if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], conv_state if decode else None)
    xin, bmat, cmat = jnp.split(conv_out, [di, di + st], axis=-1)
    xh = xin.reshape(b, s, nh, pdim).astype(jnp.float32)
    bmat = bmat.astype(jnp.float32)
    cmat = cmat.astype(jnp.float32)

    if decode:
        assert cache is not None and s == 1
        h_prev = cache["state"].astype(jnp.float32)  # (B,H,N,P)
        dt1 = dt[:, 0]  # (B,H)
        da = jnp.exp(dt1 * A[None, :])  # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhnp", dt1, bmat[:, 0], xh[:, 0])
        h_new = h_prev * da[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", cmat[:, 0], h_new)
        y = y + xh[:, 0] * p["D"][None, :, None]
        y = y.reshape(b, 1, di)
        new_cache = {"state": h_new.astype(cache["state"].dtype),
                     "conv": new_conv.astype(cache["conv"].dtype)}
    else:
        y4, h_last = _ssd_chunked(xh, dt, A, bmat, cmat, p["D"], cfg.chunk)
        y = y4.reshape(b, s, di)
        new_cache = None
        if cache is not None:
            new_cache = {"state": h_last.astype(cache["state"].dtype),
                         "conv": new_conv.astype(cache["conv"].dtype)}

    y = y.astype(x.dtype) * jax.nn.silu(z)
    y = L.rmsnorm_apply(p["ssm_norm"], y)
    return L.dense_apply(p["out_proj"], y, "ssm/out_proj", sp_cfg), new_cache


def init_ssm_cache(cfg: SSMConfig, batch: int, dtype=jnp.float32):
    return {
        "state": jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.head_dim), dtype),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_dim), dtype),
    }
