"""Unified decoder-LM: dense / GQA / MLA / SWA / MoE / SSM / hybrid.

One scan-over-layers model definition covers qwen3, qwen2.5, glm4,
gemma3 (5:1 local:global), granite-moe, deepseek-v2-lite (MLA + MoE +
dense first layer), mamba2 (attention-free), hymba (parallel attn+SSM
heads) and internvl2 (LM backbone + stubbed vision prefix).

Params are stacked along a leading "layer" axis and scanned, so compile
time is O(1) in depth; heterogeneous layer patterns (gemma3's local vs
global) are handled with per-layer flags + lax.cond inside the scan.
Every projection routes through BDWP (core/bdwp).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import bdwp
from repro.core.sparsity import DENSE, SparsityConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.sharding.rules import BATCH, SEQ, act


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int = 0
    n_kv: int = 0
    head_dim: int = 128
    d_ff: int = 0
    rope_theta: float = 1e4
    qk_norm: bool = False
    qkv_bias: bool = False
    # layer pattern, cycled over depth: "attn" | "swa" | "mamba" | "hybrid"
    pattern: tuple = ("attn",)
    window: Optional[int] = None
    # MoE
    moe: Optional[M.MoEConfig] = None
    first_dense_ff: Optional[int] = None  # deepseek: dense FFN in layer 0
    # MLA
    kv_lora: Optional[int] = None
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: Optional[int] = None
    # SSM
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    tie_embed: bool = True
    remat: bool = True
    # vocab-parallel embedding/LM-head tables are padded up to a multiple
    # of this (Megatron/MaxText convention) so the "vocab" axis divides the
    # TP mesh axis evenly; padded logit columns are masked to -inf.
    pad_vocab_to: int = 256

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab // self.pad_vocab_to) * self.pad_vocab_to

    def attn_cfg(self) -> A.AttnConfig:
        return A.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads, n_kv=self.n_kv,
            head_dim=self.head_dim, rope_theta=self.rope_theta,
            qk_norm=self.qk_norm, qkv_bias=self.qkv_bias, window=self.window,
            kv_lora=self.kv_lora, qk_nope_dim=self.qk_nope_dim,
            qk_rope_dim=self.qk_rope_dim, v_head_dim=self.v_head_dim,
        )

    def ssm_cfg(self) -> S.SSMConfig:
        return S.SSMConfig(d_model=self.d_model, d_state=self.ssm_state,
                           head_dim=self.ssm_head_dim, chunk=self.ssm_chunk)

    def layer_kinds(self):
        pat = list(self.pattern)
        kinds = [pat[i % len(pat)] for i in range(self.n_layers)]
        return kinds

    @property
    def has_attn(self) -> bool:
        return any(k in ("attn", "swa", "hybrid") for k in self.layer_kinds())

    @property
    def has_ssm(self) -> bool:
        return any(k in ("mamba", "hybrid") for k in self.layer_kinds())

    @property
    def uses_scan_prelude(self) -> bool:
        return self.first_dense_ff is not None

    def n_params(self) -> int:
        """Total parameter count (for MODEL_FLOPS accounting)."""
        import math

        p, _ = init(jax.random.PRNGKey(0), self, abstract=True)
        return sum(math.prod(x.shape) for x in jax.tree.leaves(p))

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of routed experts)."""
        total = self.n_params()
        if self.moe is None:
            return total
        e, k = self.moe.n_experts, self.moe.top_k
        expert_p = 3 * self.d_model * self.moe.d_expert
        n_moe_layers = self.n_layers - (1 if self.uses_scan_prelude else 0)
        inactive = n_moe_layers * (e - k) * expert_p
        return total - inactive


# ---------------------------------------------------------------------------
# FFN (dense SwiGLU)
# ---------------------------------------------------------------------------


def ffn_init(key, d: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    p, s = {}, {}
    p["w_gate"], s["w_gate"] = L.dense_init(k1, d, d_ff, axes=("embed", "mlp"))
    p["w_up"], s["w_up"] = L.dense_init(k2, d, d_ff, axes=("embed", "mlp"))
    p["w_down"], s["w_down"] = L.dense_init(k3, d_ff, d, axes=("mlp", "embed"))
    return p, s


def ffn_apply(p, x, sp_cfg):
    h = L.swiglu(L.dense_apply(p["w_gate"], x, "mlp/w_gate", sp_cfg),
                 L.dense_apply(p["w_up"], x, "mlp/w_up", sp_cfg))
    h = act(h, BATCH, None, "model")  # TP: FFN hidden sharded over model
    return L.dense_apply(p["w_down"], h.astype(x.dtype), "mlp/w_down", sp_cfg)


# ---------------------------------------------------------------------------
# One transformer block (scanned)
# ---------------------------------------------------------------------------


def _block_init(key, cfg: LMConfig):
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["ln1"], s["ln1"] = L.rmsnorm_init(cfg.d_model)
    p["ln2"], s["ln2"] = L.rmsnorm_init(cfg.d_model)
    kinds = set(cfg.layer_kinds())
    if kinds & {"attn", "swa", "hybrid"}:
        p["attn"], s["attn"] = A.attn_init(ks[0], cfg.attn_cfg())
    if kinds & {"mamba", "hybrid"}:
        p["ssm"], s["ssm"] = S.ssm_init(ks[1], cfg.ssm_cfg())
    if cfg.moe is not None:
        p["moe"], s["moe"] = M.moe_init(ks[2], cfg.d_model, cfg.moe)
    elif cfg.d_ff:
        p["ffn"], s["ffn"] = ffn_init(ks[3], cfg.d_model, cfg.d_ff)
    return p, s


def _block_apply(p, x, cfg: LMConfig, sp_cfg, *, positions, is_global,
                 cache=None, decode=False, per_slot=False):
    """Returns (x, new_cache, aux_loss)."""
    kinds = cfg.layer_kinds()
    kind0 = kinds[0] if len(set(kinds)) == 1 else None
    x = act(x, BATCH, SEQ, None)  # anchor: DP batch (+ seq-parallel)
    h = L.rmsnorm_apply(p["ln1"], x)
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    acfg = cfg.attn_cfg()

    if kind0 == "mamba":
        mix, nc = S.ssm_apply(p["ssm"], h, cfg.ssm_cfg(), sp_cfg,
                              cache=cache, decode=decode)
        if nc is not None:
            new_cache = nc
    elif kind0 == "hybrid":
        a_cache = {k: v for k, v in cache.items() if k in ("k", "v", "pos")} \
            if cache is not None else None
        s_cache = {k: v for k, v in cache.items() if k in ("state", "conv")} \
            if cache is not None else None
        a_out, a_nc = A.attn_apply(p["attn"], h, acfg, sp_cfg,
                                   positions=positions, cache=a_cache,
                                   layer_window=cfg.window, decode=decode,
                                   per_slot=per_slot)
        s_out, s_nc = S.ssm_apply(p["ssm"], h, cfg.ssm_cfg(), sp_cfg,
                                  cache=s_cache, decode=decode)
        mix = 0.5 * (a_out + s_out)  # hymba: parallel heads, mean-combined
        if a_nc is not None:
            new_cache.update(a_nc)
        if s_nc is not None:
            new_cache.update(s_nc)
    else:
        # attn / swa (possibly mixed per-layer, e.g. gemma3 5:1)
        if "swa" in kinds and "attn" in kinds:
            def global_branch(h_):
                return A.attn_apply(p["attn"], h_, acfg, sp_cfg,
                                    positions=positions, cache=cache,
                                    layer_window=None, decode=decode,
                                    per_slot=per_slot)

            def local_branch(h_):
                return A.attn_apply(p["attn"], h_, acfg, sp_cfg,
                                    positions=positions, cache=cache,
                                    layer_window=cfg.window, decode=decode,
                                    per_slot=per_slot)

            mix, nc = jax.lax.cond(is_global, global_branch, local_branch, h)
        else:
            window = cfg.window if kinds[0] == "swa" else None
            mix, nc = A.attn_apply(p["attn"], h, acfg, sp_cfg,
                                   positions=positions, cache=cache,
                                   layer_window=window, decode=decode,
                                   per_slot=per_slot)
        if nc is not None:
            new_cache = nc
    x = x + mix

    h2 = L.rmsnorm_apply(p["ln2"], x)
    if cfg.moe is not None:
        y, aux = M.moe_apply(p["moe"], h2, cfg.moe, sp_cfg)
    elif "ffn" in p:
        y = ffn_apply(p["ffn"], h2, sp_cfg)
    else:
        y = jnp.zeros_like(h2)
    x = x + y
    return x, (new_cache if new_cache else None), aux


# ---------------------------------------------------------------------------
# Full model: init / apply / prefill / decode
# ---------------------------------------------------------------------------


def _is_axes(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def init(key, cfg: LMConfig, abstract: bool = False):
    """Returns (params, specs).  abstract=True gives ShapeDtypeStruct leaves
    with zero device allocation (used by the dry-run)."""
    spec_box = {}

    def build(key):
        k_embed, k_blocks, k_pre, k_out = jax.random.split(key, 4)
        params, specs = {}, {}
        params["embed"], specs["embed"] = L.embed_init(
            k_embed, cfg.padded_vocab, cfg.d_model)
        n_scan = cfg.n_layers - (1 if cfg.uses_scan_prelude else 0)
        bkeys = jax.random.split(k_blocks, n_scan)
        params["blocks"] = jax.vmap(lambda k: _block_init(k, cfg)[0])(bkeys)
        bspec = _block_spec_of(cfg)
        specs["blocks"] = jax.tree.map(
            lambda ax: ("layer",) + tuple(ax), bspec, is_leaf=_is_axes)
        if cfg.uses_scan_prelude:
            pre_p, pre_s = {}, {}
            pre_p["ln1"], pre_s["ln1"] = L.rmsnorm_init(cfg.d_model)
            pre_p["ln2"], pre_s["ln2"] = L.rmsnorm_init(cfg.d_model)
            pre_p["attn"], pre_s["attn"] = A.attn_init(k_pre, cfg.attn_cfg())
            pre_p["ffn"], pre_s["ffn"] = ffn_init(k_out, cfg.d_model,
                                                  cfg.first_dense_ff)
            params["prelude"], specs["prelude"] = pre_p, pre_s
        params["final_norm"], specs["final_norm"] = L.rmsnorm_init(cfg.d_model)
        if not cfg.tie_embed:
            params["lm_head"], specs["lm_head"] = L.dense_init(
                k_out, cfg.d_model, cfg.padded_vocab, axes=("embed", "vocab"))
        spec_box["specs"] = specs
        return params

    if abstract:
        shapes = jax.eval_shape(build, key)
        return shapes, spec_box["specs"]
    params = build(key)
    return params, spec_box["specs"]


def _block_spec_of(cfg: LMConfig):
    """Spec tree of one block, computed without allocation (eval_shape +
    side-channel; specs are plain python tuples independent of key)."""
    box = {}

    def f(k):
        p, s = _block_init(k, cfg)
        box["s"] = s
        return p

    jax.eval_shape(f, jax.random.PRNGKey(0))
    return box["s"]


def _layer_flags(cfg: LMConfig):
    kinds = cfg.layer_kinds()
    if cfg.uses_scan_prelude:
        kinds = kinds[1:]
    return jnp.asarray([k == "attn" for k in kinds], jnp.bool_)


def forward(params, tokens, cfg: LMConfig, sp_cfg: SparsityConfig = DENSE, *,
            prefix_embeds=None, cache=None, decode=False, positions=None,
            per_slot=False):
    """Shared trunk: returns (hidden (B,S,d), new_cache, aux_loss).

    prefix_embeds: (B, S_img, d) stub-frontend embeddings prepended to the
    token embeddings (internvl2 / whisper-style modality prefix).

    per_slot (decode only): treat every batch row as an independent
    request slot — cache writes/masks are indexed by the per-row
    ``positions`` instead of the shared ``cache["pos"]`` cursor (the
    serve engine's continuous-batching mode).
    """
    x = L.embed_apply(params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = act(x, BATCH, SEQ, None)
    b, s_tot = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s_tot), (b, s_tot))
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.uses_scan_prelude:
        pre = params["prelude"]
        pc = cache["prelude"] if cache is not None else None
        h = L.rmsnorm_apply(pre["ln1"], x)
        mix, pre_nc = A.attn_apply(pre["attn"], h, cfg.attn_cfg(), sp_cfg,
                                   positions=positions, cache=pc, decode=decode,
                                   per_slot=per_slot)
        x = x + mix
        x = x + ffn_apply(pre["ffn"], L.rmsnorm_apply(pre["ln2"], x), sp_cfg)
    else:
        pre_nc = None

    flags = _layer_flags(cfg)

    def body(carry, xs):
        xh, aux = carry
        bp, flag, layer_cache = xs
        fn = partial(_block_apply, cfg=cfg, sp_cfg=sp_cfg, positions=positions,
                     decode=decode, per_slot=per_slot)
        if cfg.remat and not decode:
            fn = jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable,
                static_argnums=())
        xh, nc, a = fn(bp, xh, is_global=flag, cache=layer_cache)
        return (xh, aux + a), nc

    layer_caches = cache["layers"] if cache is not None else None
    if layer_caches is None:
        (x, aux_total), _ = jax.lax.scan(
            lambda c, xs: _strip_cache(body(c, (*xs, None))),
            (x, aux_total), (params["blocks"], flags))
        new_cache = None
    else:
        (x, aux_total), new_layer_caches = jax.lax.scan(
            body, (x, aux_total), (params["blocks"], flags, layer_caches))
        new_cache = {"layers": new_layer_caches}
        if pre_nc is not None:
            new_cache["prelude"] = pre_nc

    x = act(x, BATCH, SEQ, None)
    x = L.rmsnorm_apply(params["final_norm"], x)
    return x, new_cache, aux_total


def _strip_cache(res):
    carry, _ = res
    return carry, None


def logits_from_hidden(params, hidden, cfg: LMConfig):
    table = params["embed"]["embed_table"] if cfg.tie_embed else params["lm_head"]["w"].T
    logits = jnp.matmul(hidden, table.T.astype(hidden.dtype),
                        preferred_element_type=jnp.float32)
    if cfg.padded_vocab != cfg.vocab:  # mask padded columns (static)
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(valid, logits, -1e30)
    return logits


def lm_loss(params, hidden, labels, cfg: LMConfig, *, chunk: int = 1024,
            mask=None):
    """Chunked cross-entropy: never materializes (B, S, V) at once."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    hs = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(b, nc, chunk).swapaxes(0, 1)
    if mask is None:
        ms = jnp.ones((nc, b, chunk), jnp.float32)
    else:
        ms = mask.reshape(b, nc, chunk).swapaxes(0, 1).astype(jnp.float32)

    def step(acc, xs):
        h, l, mk = xs
        logits = logits_from_hidden(params, h, cfg)  # (B, c, V) fp32
        logits = act(logits, BATCH, None, "model")  # vocab-TP logits
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mk
        return (acc[0] + nll.sum(), acc[1] + mk.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())), (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Cache init (stacked across scanned layers)
# ---------------------------------------------------------------------------


def init_lm_cache(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    kinds = cfg.layer_kinds()
    n_scan = cfg.n_layers - (1 if cfg.uses_scan_prelude else 0)
    scan_kinds = kinds[1:] if cfg.uses_scan_prelude else kinds

    def one_layer(kind):
        c = {}
        if kind in ("attn", "swa", "hybrid"):
            c.update(A.init_cache(cfg.attn_cfg(), batch, max_len, dtype))
        if kind in ("mamba", "hybrid"):
            c.update(S.init_ssm_cache(cfg.ssm_cfg(), batch))
        return c

    per_layer = [one_layer(k) for k in scan_kinds]
    # all scanned layers share a structure -> stack
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
    cache = {"layers": stacked}
    if cfg.uses_scan_prelude:
        cache["prelude"] = A.init_cache(cfg.attn_cfg(), batch, max_len, dtype)
    return cache
