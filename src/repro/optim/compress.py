"""Cross-pod gradient compression: the paper's N:M top-k, turned into a
collective-bandwidth optimization with error feedback.

On a multi-pod mesh the "pod" axis rides the slow inter-pod links.  We
apply the paper's own primitive — keep the N largest-|g| of every
M-group — to the *gradients* before the cross-pod all-reduce, carrying
the pruned residual in an error-feedback buffer (Karimireddy et al.,
2019) so the compression is unbiased over time.  At 2:8 this cuts
inter-pod gradient bytes ~4x (values) — the same arithmetic as the
paper's storage claim, applied to the network instead of DRAM.

Implementation note: under pjit/GSPMD the DP mean is implicit in the
loss, so to compress *only* the pod hop we split the mean: the train
step computes per-pod-mean gradients (psum over "data" via the loss),
then this module sparsifies and psums over "pod" inside shard_map.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.sparsity import SparsityConfig, nm_mask


def compress_leaf(g, err, n: int, m: int, wire_dtype=jnp.bfloat16):
    """N:M-sparsify g+err along the last axis; returns (sparse, new_err).

    The returned sparse tensor holds what the wire ACTUALLY carries —
    the kept values rounded to ``wire_dtype`` (the packed all-gather in
    ``cross_pod_mean`` transmits bf16) — and the residual absorbs both
    the pruned values AND that rounding error.  Computing the residual
    against the unrounded kept values (the old behavior) silently
    dropped the bf16 quantization term every step, biasing the
    compressed sync; with it folded in, sum(sent) + err telescopes to
    sum(g) exactly in fp32 (pinned by tests/test_spmd.py).
    """
    size = g.size
    if size % m != 0 or g.ndim == 0:
        return g, err  # tiny/ragged leaves ride uncompressed
    flat = (g + err).reshape(-1, m)
    mask = nm_mask(flat, n, m, axis=-1)
    kept = jnp.where(mask, flat, 0.0)
    sent = kept.astype(wire_dtype).astype(jnp.float32)
    new_err = (flat - sent).reshape(g.shape)
    return sent.reshape(g.shape), new_err


def cross_pod_mean(grads, err_state, mesh: Mesh, grad_pspecs,
                   sp_cfg: SparsityConfig):
    """All-reduce gradients across the 'pod' axis with N:M compression.

    The sparse tensors are transmitted in PACKED form — bf16 values
    (N/M of dense) + uint8 within-group indices — via an all-gather
    over 'pod', then unpacked and averaged locally.  A psum of the
    masked-dense tensor would move the zeros too and save nothing;
    packing is where the paper's N:M arithmetic becomes link bytes:
    2:8 on fp32 grads -> (2/8)*2B + 1B idx per 8*4B group = 0.156x the
    all-reduce's ring traffic.  Error feedback keeps it unbiased.
    """
    if "pod" not in mesh.axis_names:
        return grads, err_state

    from repro.core.sparsity import nm_pack, nm_unpack_n

    n, m = sp_cfg.n, sp_cfg.m
    n_pods = mesh.shape["pod"]

    def body(g_tree, e_tree):
        out_g, out_e = [], []
        flat_g, tdef = jax.tree_util.tree_flatten(g_tree)
        flat_e = jax.tree_util.tree_flatten(e_tree)[0]
        for g, e in zip(flat_g, flat_e):
            if g.size % m or g.ndim == 0:
                out_g.append(jax.lax.pmean(g, "pod"))
                out_e.append(e)
                continue
            kept, new_e = compress_leaf(g, e, n, m)
            # pack: bf16 values + u8 indices, gather over the pod links
            vals, idx = nm_pack(kept.reshape(-1, m).astype(jnp.bfloat16),
                                n, m, axis=-1)
            vals_all = jax.lax.all_gather(vals, "pod")   # (P, G, n)
            idx_all = jax.lax.all_gather(idx, "pod")
            dense = jax.vmap(
                lambda v, i: nm_unpack_n(v, i, n, m, axis=-1))(
                    vals_all, idx_all)
            mean = dense.astype(jnp.float32).mean(0).reshape(g.shape)
            out_g.append(mean)
            out_e.append(new_e)
        return (jax.tree_util.tree_unflatten(tdef, out_g),
                jax.tree_util.tree_unflatten(tdef, out_e))

    specs = jax.tree.map(lambda ps: ps, grad_pspecs,
                         is_leaf=lambda x: isinstance(x, P))
    fn = shard_map(body, mesh=mesh, in_specs=(specs, specs),
                   out_specs=(specs, specs), check_rep=False)
    return fn(grads, err_state)
