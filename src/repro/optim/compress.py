"""Cross-pod gradient sync with natively-N:M payloads, off the critical
path.

On a multi-pod mesh the "pod" axis rides the slow inter-pod links.  We
apply the paper's own primitive — keep N of every M-group — to the
*gradients* crossing that axis, shipping packed (bf16 vals, uint8 idx)
instead of dense fp32.  Two estimators:

  * ``topk``  — largest-|g| per group with an error-feedback residual
    (Karimireddy et al., 2019); the fused kernel folds the bf16 wire
    rounding into the residual, so sum(decoded) + err telescopes to
    sum(g) exactly in fp32.
  * ``mvue``  — the minimum-variance unbiased estimator of arXiv
    2203.10991: water-filled inclusion probabilities p = min(1, |g|/τ)
    with Στ p = n per group, systematic sampling (exactly n draws), and
    1/p rescaling.  Unbiased per step — no residual state — and exact
    whenever a group has ≤ n nonzeros.

Dataflow (the paper's pre-generation argument, Fig. 11c, applied to the
network): the train step computes per-pod mean gradients by vmapping
value_and_grad over a pod-stacked parameter copy, so GSPMD's implicit
gradient all-reduce stays *inside* a pod ("data" groups only).  This
module then flattens each device's LOCAL blocks of the compressible
leaves into one device-local slab (no pre-gather: a device compresses
only the T_loc elements it already holds) and walks it in m-aligned
buckets inside a MANUAL shard_map — the compress math (fused
kernels/grad_compress, no dense intermediates) is purely local so the
GSPMD partitioner can never reshard inside it, and each bucket ends in
one explicit packed (vals, idx) collective over "pod": the only traffic
that crosses pods.  The payload ships vals bitcast to uint16 — XLA
would otherwise hoist the decoder's bf16→f32 convert above the
collective and double the wire bytes.  For the topk estimator on a
two-pod mesh the hop is a ppermute *exchange* rather than an
all_gather: error feedback already gives each pod its own decoded
payload for free (decode(own) == (g+err) - new_err bit-for-bit, the
bf16 rounding being Sterbenz-exact in f32), so only the peer's row pays
the one-hot decode.  Buckets are independent ops with no barrier
between them, so XLA's scheduler is free to overlap one bucket's
collective with the next bucket's compression (and with trailing
backward work under jit).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.sparsity import (
    SparsityConfig,
    _topn_group_mask,
    nm_mask,
    nm_pack_from_mask,
)
from repro.kernels import ops
from repro.sharding import rules as R


def compress_leaf(g, err, n: int, m: int, wire_dtype=jnp.bfloat16):
    """N:M-sparsify g+err along the last axis; returns (sparse, new_err).

    The returned sparse tensor holds what the wire ACTUALLY carries —
    the kept values rounded to ``wire_dtype`` (the packed all-gather
    transmits bf16) — and the residual absorbs both the pruned values
    AND that rounding error, so sum(sent) + err telescopes to sum(g)
    exactly in fp32 (pinned by tests/test_spmd.py).  This is the
    single-leaf reference semantics; the bucketed sync path below uses
    the fused kernel equivalent (kernels/grad_compress).
    """
    size = g.size
    if size % m != 0 or g.ndim == 0:
        return g, err  # tiny/ragged leaves ride uncompressed
    flat = (g + err).reshape(-1, m)
    mask = nm_mask(flat, n, m, axis=-1)
    kept = jnp.where(mask, flat, 0.0)
    sent = kept.astype(wire_dtype).astype(jnp.float32)
    new_err = (flat - sent).reshape(g.shape)
    return sent.reshape(g.shape), new_err


# ---------------------------------------------------------------------------
# Config + bucket planning
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GradCompressConfig:
    """Knobs for the bucketed cross-pod sync.

    bucket_elems must be a multiple of m: a bucket boundary inside an
    M-group would split the group's top-N selection across two buckets
    (and two collectives), silently changing the estimator — refused at
    construction, and again by ``plan_buckets`` for ad-hoc splits.
    """

    n: int = 2
    m: int = 8
    estimator: str = "topk"       # "topk" (EF) | "mvue" (unbiased, no EF)
    bucket_elems: int = 1 << 16
    use_pallas: bool = False

    def __post_init__(self):
        if self.estimator not in ("topk", "mvue"):
            raise ValueError(f"unknown gradient estimator {self.estimator!r}")
        if self.bucket_elems <= 0 or self.bucket_elems % self.m:
            raise ValueError(
                f"bucket_elems={self.bucket_elems} would split an M-group "
                f"(m={self.m}): bucket boundaries must be M-aligned")

    @classmethod
    def from_sparsity(cls, sp_cfg: SparsityConfig, **kw):
        return cls(n=sp_cfg.n, m=sp_cfg.m, **kw)


def compressible_shape(shape, m: int) -> bool:
    """Leaves whose flat size is a whole number of M-groups ride packed;
    scalars and ragged leaves (e.g. a (3,) bias) ride dense."""
    size = math.prod(shape)
    return len(shape) > 0 and size > 0 and size % m == 0


def slab_shards(mesh: Mesh) -> int:
    """S — how many distinct local slabs exist per pod (the intra-pod
    device count): each device compresses only the leaf blocks it
    already holds instead of redoing the whole slab's top-k selection."""
    return int(math.prod(s for a, s in mesh.shape.items() if a != "pod"))


def local_block_shape(shape, spec, mesh: Mesh):
    """A leaf's per-device block shape under its PartitionSpec."""
    entries = tuple(spec) if spec is not None else ()
    out = []
    for i, d in enumerate(shape):
        e = entries[i] if i < len(entries) else None
        split = 1
        if e is not None:
            for ax in (e if isinstance(e, tuple) else (e,)):
                split *= mesh.shape[ax]
        if d % split:
            raise ValueError(f"dim {d} of {shape} not divisible by its "
                             f"{split}-way shard ({spec})")
        out.append(d // split)
    return tuple(out)


def _slab_layout(shapes, specs, mesh: Mesh, m: int):
    """(per-compressible-leaf local sizes, T_loc, T_loc padded to m).

    The sync slab is DEVICE-LOCAL: each device flattens the leaf blocks
    it already holds, in tree order.  SPMD keeps block shapes uniform
    across devices, so T_loc is one number; leaves replicated along some
    intra-pod axis appear in several devices' slabs (benign duplicate
    compute, consistent results — the compressor is deterministic).
    """
    loc = []
    for shape, spec in zip(shapes, specs):
        if not compressible_shape(shape, m):
            continue
        if mesh is None:
            loc.append(math.prod(shape))
        else:
            loc.append(math.prod(local_block_shape(shape, spec, mesh)))
    t_loc = sum(loc)
    return loc, t_loc, (t_loc + m - 1) // m * m


def err_state_elems(params, m: int, mesh: Mesh = None,
                    grad_pspecs=None) -> int:
    """Width of the (n_pods, ·) error-feedback slab.

    Each device carries its own EF residual over its local slab (the
    leaf blocks it holds, padded to whole M-groups), so the global state
    is T_loc_pad * S wide — S local slabs per pod laid out along the
    intra-pod axes.  Without a mesh (or specs) everything is one
    device's slab: the plain padded compressible total.  Padding is
    benign: a zero group compresses to zero payload and zero residual.
    """
    leaves = jax.tree_util.tree_leaves(params)
    shapes = [p.shape for p in leaves]
    if mesh is None or grad_pspecs is None:
        _, _, t_pad = _slab_layout(shapes, [None] * len(shapes), None, m)
        return t_pad
    specs = jax.tree_util.tree_flatten(
        grad_pspecs, is_leaf=lambda x: isinstance(x, P))[0]
    _, _, t_pad = _slab_layout(shapes, specs, mesh, m)
    return t_pad * slab_shards(mesh)


def plan_buckets(total: int, bucket_elems: int, m: int):
    """Static (start, stop) schedule over the flat slab.

    Every boundary is M-aligned (bucket_elems % m == 0, and total is a
    sum of M-divisible leaf sizes); a split that would cross a group is
    refused rather than rounded.
    """
    if bucket_elems <= 0 or bucket_elems % m:
        raise ValueError(
            f"bucket_elems={bucket_elems} would split an M-group (m={m})")
    if total % m:
        raise ValueError(f"slab of {total} elems is not M-divisible (m={m})")
    return [(s, min(s + bucket_elems, total))
            for s in range(0, total, bucket_elems)]


# ---------------------------------------------------------------------------
# MVUE estimator (arXiv 2203.10991), jnp path
# ---------------------------------------------------------------------------


def mvue_probs(a: jax.Array, n: int) -> jax.Array:
    """Water-filled inclusion probabilities per group.

    a: (..., m) nonnegative scores.  Returns p = min(1, a/τ) with τ
    chosen so Σ p = n (when the group has ≥ n nonzeros; fewer nonzeros
    get p = 1 each — the estimator is exact there).  The fixed point is
    reached in ≤ n rounds: each round at most (n - |saturated|) entries
    can newly saturate, and τ is non-increasing.
    """
    sat = jnp.zeros(a.shape, bool)
    tau = jnp.sum(a, -1, keepdims=True) / n
    for _ in range(n):
        denom = n - jnp.sum(sat, -1, keepdims=True)
        rest = jnp.where(sat, 0.0, a).sum(-1, keepdims=True)
        ok = denom > 0
        tau = jnp.where(ok, rest / jnp.maximum(denom, 1), tau)
        sat = jnp.where(ok, a >= tau, sat)
    p = jnp.where(sat, 1.0,
                  jnp.where(tau > 0, a / jnp.maximum(tau, 1e-38), 0.0))
    return jnp.where(a > 0, jnp.clip(p, 0.0, 1.0), 0.0)


def _systematic_sample(p: jax.Array, key) -> jax.Array:
    """Exactly-⌊Σp⌋-ish draws per group via one shared uniform offset:
    position i is selected iff ⌊c_i - u⌋ > ⌊c_{i-1} - u⌋ on the cumsum
    c.  Every p=1 entry is always selected; total draws ≤ n when Σp ≤ n.
    """
    c = jnp.cumsum(p, axis=-1)
    u = jax.random.uniform(key, c.shape[:-1] + (1,), dtype=c.dtype)
    f = jnp.floor(c - u)
    prev = jnp.concatenate(
        [jnp.broadcast_to(jnp.floor(-u), f[..., :1].shape), f[..., :-1]],
        axis=-1)
    return f > prev


def mvue_compress(t: jax.Array, n: int, m: int, key):
    """(..., L) -> packed (bf16 vals, uint8 idx) along the last axis.

    Selected values are rescaled by 1/p (unbiased before the bf16 wire
    rounding).  Groups short of n draws are padded with earliest-index
    zero-probability slots (value 0 — the estimate is unchanged) so the
    payload always holds exactly n slots per group.
    """
    g = t.reshape(*t.shape[:-1], t.shape[-1] // m, m).astype(jnp.float32)
    p = mvue_probs(jnp.abs(g), n)
    sel = _systematic_sample(p, key)
    mask = _topn_group_mask(jnp.where(sel, 1.0, 0.0), n)
    est = jnp.where(sel, g / jnp.maximum(p, 1e-38), 0.0)
    vals, idx = nm_pack_from_mask(est.reshape(t.shape),
                                  mask.reshape(t.shape), n, m, axis=-1)
    return vals.astype(jnp.bfloat16), idx


# ---------------------------------------------------------------------------
# The bucketed cross-pod sync
# ---------------------------------------------------------------------------


def cross_pod_sync(grads, err, mesh: Mesh, grad_pspecs,
                   cfg: GradCompressConfig, key=None):
    """Pod-mean of pod-stacked gradients with packed N:M payload.

    grads: master-structured tree of pod-stacked leaves (n_pods, *shape)
    — each pod's own data-mean gradient (the vmapped train step keeps
    GSPMD's gradient all-reduce intra-pod).  err: the fp32 EF residual
    slab (``err_state_elems`` wide).  Returns (master-shaped mean grads,
    new err).

    The whole walk runs inside one manual shard_map over DEVICE-LOCAL
    slabs: each device flattens the leaf blocks it already holds under
    the master shardings into a (1, T_loc) slab, compresses it bucket by
    bucket, and the ONLY pod-crossing traffic is each bucket's packed
    (bf16 vals bitcast to u16, u8 idx) payload — a tiled all_gather in
    general, a ppermute exchange on the two-pod topk fast path (the own
    pod's decode comes free from the EF identity).  Because the pod
    axis is the mesh's outermost, corresponding devices across pods hold
    blocks of the SAME leaf slices, so the gathered payloads decode into
    that device's own shard of the pod-mean gradient — there is no
    global slab to assemble, no leaf re-replication before compressing,
    and no redistribution collective afterwards.  Ragged leaves ride a
    dense fp32 pmean over "pod".  Buckets are independent ops with no
    barrier, so the scheduler can overlap one bucket's gather with the
    next bucket's compression (and with trailing backward work).
    """
    from jax.experimental.shard_map import shard_map

    n_pods = mesh.shape["pod"]
    n, m = cfg.n, cfg.m
    shards = slab_shards(mesh)
    err_spec = R.grad_sync_pspecs(mesh)["err"]

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_s = jax.tree_util.tree_flatten(
        grad_pspecs, is_leaf=lambda x: isinstance(x, P))[0]
    comp = [compressible_shape(g.shape[1:], m) for g in flat_g]
    _, t_loc, t_loc_pad = _slab_layout(
        [g.shape[1:] for g in flat_g], flat_s, mesh, m)
    if err.shape != (n_pods, t_loc_pad * shards):
        raise ValueError(
            f"EF residual shape {err.shape} != "
            f"(n_pods={n_pods}, {t_loc_pad * shards}) — init the train "
            "state against the same master tree/specs/mesh")
    if key is None:
        key = jax.random.PRNGKey(0)
    buckets = plan_buckets(t_loc_pad, cfg.bucket_elems, m)

    def sync_shard(*args):
        flat_loc, eb, k = args[:-2], args[-2], args[-1]
        if cfg.estimator == "mvue":
            # decorrelate the stochastic draws across pods; intra-pod
            # devices share the key so replicated leaf blocks sample
            # identically (their decoded means must agree bitwise)
            k = jax.random.fold_in(k, jax.lax.axis_index("pod"))
        blocks = [x.reshape(1, -1).astype(jnp.float32)
                  for x, c in zip(flat_loc, comp) if c]
        outs, errs = [], []
        if buckets:
            loc = jnp.concatenate(blocks, axis=1)
            if t_loc_pad != t_loc:  # zero pad: zero payload + zero err
                loc = jnp.pad(loc, ((0, 0), (0, t_loc_pad - t_loc)))
            for b, (s, e) in enumerate(buckets):
                gb, ebk = loc[:, s:e], eb[:, s:e]
                if cfg.estimator == "mvue":
                    vals, idx = mvue_compress(gb, n, m,
                                              jax.random.fold_in(k, b))
                    new_eb = ebk  # unbiased estimator: no residual
                else:
                    vals, idx, new_eb = ops.grad_compress(
                        gb, ebk, n, m, use_pallas=cfg.use_pallas)
                # ship vals bitcast to u16: XLA otherwise hoists the
                # decoder's bf16->f32 convert above the collective and
                # doubles the wire bytes of the hop
                wire = jax.lax.bitcast_convert_type(vals, jnp.uint16)
                if cfg.estimator == "topk" and n_pods == 2:
                    # EF telescoping gives the own pod's decoded payload
                    # for free — decode(own) == t - new_err bitwise (the
                    # bf16 rounding error is Sterbenz-exact in f32) — so
                    # the pod hop is a payload *exchange* (ppermute) and
                    # only the peer's row pays the one-hot decode.
                    swap = [(0, 1), (1, 0)]
                    ov = jax.lax.bitcast_convert_type(
                        jax.lax.ppermute(wire, "pod", swap), jnp.bfloat16)
                    oi = jax.lax.ppermute(idx, "pod", swap)
                    own = (gb + ebk - new_eb)[0]
                    other = ops.grad_decompress_mean(
                        ov, oi, n, m, use_pallas=cfg.use_pallas)
                    outs.append((own + other) * 0.5)
                else:
                    # the pod hop: bf16 vals + u8 idx, N/M of dense bytes
                    vals = jax.lax.bitcast_convert_type(
                        jax.lax.all_gather(wire, "pod", axis=0,
                                           tiled=True), jnp.bfloat16)
                    idx = jax.lax.all_gather(
                        idx, "pod", axis=0, tiled=True)
                    outs.append(ops.grad_decompress_mean(
                        vals, idx, n, m, use_pallas=cfg.use_pallas))
                errs.append(new_eb)
        dense_loc = (jnp.concatenate(outs) if outs
                     else jnp.zeros((0,), jnp.float32))
        new_eb = jnp.concatenate(errs, axis=1) if errs else eb
        out, off = [], 0
        for x, c in zip(flat_loc, comp):
            if c:  # unconcat straight back into this device's block
                leaf = dense_loc[off:off + x.size].reshape(x.shape[1:])
                off += x.size
            else:  # dense fp32 pod mean for ragged leaves
                leaf = jax.lax.pmean(x.astype(jnp.float32), "pod")[0]
            out.append(leaf.astype(x.dtype))
        return (*out, new_eb)

    res = shard_map(
        sync_shard, mesh=mesh,
        in_specs=(*(P("pod", *s) for s in flat_s), err_spec, P()),
        out_specs=(*(P(*s) for s in flat_s), err_spec),
        check_rep=False)(*flat_g, err, key)
    return jax.tree_util.tree_unflatten(tdef, list(res[:-1])), res[-1]


def wire_bytes(total: int, ragged: int, cfg: GradCompressConfig) -> int:
    """Per-pod bytes crossing the pod links per step: packed payload
    (bf16 vals + uint8 idx, n per m-group) plus dense fp32 raggeds."""
    groups = total // cfg.m
    return groups * cfg.n * (2 + 1) + ragged * 4
