"""WUVE analogue: mixed-precision momentum SGD with SR-STE decay and
N:M sparse weight *pre-generation* (paper Fig. 11c) — executed for real.

State per parameter:
  master   fp32  (sharded like the param)
  momentum fp32
plus the *pre-generated compute tree* emitted by every update — the
dataflow the paper fuses into WUVE+SORE: at WU time the optimizer
computes each prunable weight's FF and BP N:M masks ONCE from fp32
master (a single fused ``lax.top_k`` per parameter — nm_mask_pair),
applies SR-STE's sparse-refined decay from the *same* masks (the copy
stored at the previous WU), and writes the bf16 FF/BP operands — pruned
copies, or SORE-packed ``(vals, idx)`` where eligible — that the next
iteration's FF and BP load directly (core/operand.nm_apply over
PregenOp leaves).
Forward passes never touch fp32 and never re-derive a mask: the lowered
train step carries exactly one top_k/sort selection per prunable
parameter (down from one per consumer — FF forward, FF remat recompute,
BP backward and SR-STE decay each re-derived it: 4x measured in
benchmarks/pregen_bench.py), and the FF/BP/decay masks can no longer
disagree at bf16-rounding near-ties.

The fused Pallas kernel (kernels/fused_update.py) implements the FF lane
of the same math per VMEM tile for the TPU deployment path and is wired
in via ``use_pallas=True`` (srste/bdwp, element granularity); this
module's jnp formulation lowers cleanly in the dry-run with identical
semantics — tests/test_pregen.py pins the two paths together bitwise.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import bdwp
from repro.core import operand as O
from repro.core.sparsity import (SparsityConfig, _move_axis_last, nm_mask,
                                 nm_mask_pair, nm_mask_shared,
                                 nm_mask_transposable, nm_pack_from_mask,
                                 nm_unpack_n)


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: float = 0.1
    momentum: float = 0.9
    weight_decay: float = 5e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.01


def lr_schedule(cfg: SGDConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac * cfg.lr + (1 - cfg.min_lr_frac) * cfg.lr \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params):
    return {
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "momentum": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Pre-generation: master fp32 -> the bf16 compute tree FF/BP consume
# ---------------------------------------------------------------------------


def _pregen_masks(w, sp_cfg: SparsityConfig):
    """(ff_mask, bp_mask, decay_mask) of one fp32 weight — the masks the
    next step's FF/BP and this step's successor-decay all share.  Element
    granularity fuses the FF+BP selections into ONE top_k (nm_mask_pair);
    unused directions return None."""
    n, m = sp_cfg.n, sp_cfg.m
    ff_ax, bp_ax = w.ndim - 2, w.ndim - 1
    if sp_cfg.transposable:
        # ONE mask, N:M along both the contraction and the output axis
        # (arXiv 2102.08124) — serves FF, BP and the SR-STE decay, so
        # the per-param mask state halves
        tm = nm_mask_transposable(w, n, m)
        return tm, tm, tm
    shared = sp_cfg.granularity == "shared"
    ff_mask = bp_mask = None
    if sp_cfg.prunes_ff_weights() and sp_cfg.prunes_bp_weights():
        if shared:
            ff_mask = nm_mask_shared(w, n, m, ff_ax, bp_ax, sp_cfg.tile)
            bp_mask = nm_mask_shared(w, n, m, bp_ax, ff_ax, sp_cfg.tile)
        else:
            ff_mask, bp_mask = nm_mask_pair(w, n, m, ff_ax, bp_ax)
    elif sp_cfg.prunes_ff_weights():
        ff_mask = nm_mask_shared(w, n, m, ff_ax, bp_ax, sp_cfg.tile) \
            if shared else nm_mask(w, n, m, axis=ff_ax)
    elif sp_cfg.prunes_bp_weights():
        bp_mask = nm_mask_shared(w, n, m, bp_ax, ff_ax, sp_cfg.tile) \
            if shared else nm_mask(w, n, m, axis=bp_ax)
    decay_mask = bp_mask if sp_cfg.method == "sdwp" else ff_mask
    return ff_mask, bp_mask, decay_mask


def _pregen_leaf(w, sp_cfg: SparsityConfig, pack: bool) -> O.PregenOp:
    """fp32 weight -> PregenOp{ff | (vals, idx), bp, mask} operand leaf.

    Masking commutes with the bf16 cast (cast(0) == 0), so the pruned
    bf16 operands equal what masking the bf16 copy would give — but the
    *selection* is scored on fp32 master, fixing the bf16/fp32 mask-source
    split between FF/BP and SR-STE decay.
    """
    ff_mask, bp_mask, decay_mask = _pregen_masks(w, sp_cfg)
    bp = jnp.where(bp_mask, w, 0.0) if bp_mask is not None else w
    if sp_cfg.transposable:
        # the one transposable-masked operand serves FF and BP — no
        # separate ff leaf (bf16 weight state halves); pack rides the
        # same mask along the contraction axis
        bp16 = bp.astype(jnp.bfloat16)
        if pack:
            vals, idx = nm_pack_from_mask(bp16, ff_mask, sp_cfg.n, sp_cfg.m,
                                          axis=w.ndim - 2)
            return O.PregenOp(bp=bp16, vals=vals, idx=idx, mask=decay_mask,
                              cfg=sp_cfg, idx_bits=8)
        return O.PregenOp(bp=bp16, mask=decay_mask, cfg=sp_cfg)
    ff = jnp.where(ff_mask, w, 0.0) if ff_mask is not None else w
    ff16 = ff.astype(jnp.bfloat16)
    if pack and ff_mask is not None and sp_cfg.granularity == "element":
        # SORE packing along the contraction axis, sort-free from the mask
        vals, idx = nm_pack_from_mask(ff16, ff_mask, sp_cfg.n, sp_cfg.m,
                                      axis=w.ndim - 2)
        return O.PregenOp(bp=bp.astype(jnp.bfloat16), vals=vals, idx=idx,
                          mask=decay_mask, cfg=sp_cfg, idx_bits=8)
    return O.PregenOp(bp=bp.astype(jnp.bfloat16), ff=ff16, mask=decay_mask,
                      cfg=sp_cfg)


def pregen_tree(master, sp_cfg: Optional[SparsityConfig], *,
                pack: bool = False, bare_sites: bool = True):
    """Build the full pre-generated compute tree from fp32 master.

    Prunable weights (bdwp.pregen_site) become PregenOp leaves — both the
    ``{"w": ...}`` leaf-dict sites and the bare-array MoE expert stacks
    (masks per expert along the last-two contraction/output axes, one
    fused ``nm_mask_pair`` over the whole stacked leaf); every other
    leaf becomes its plain bf16 compute copy.  Used to bootstrap
    ``init_train_state``, to upgrade pre-pregen checkpoints, and
    abstractly (under eval_shape) by the step builders and dry-run.
    ``bare_sites=False`` reproduces the pre-MoE structure (dict sites
    only) so restore_with_pregen can recognize older checkpoints.
    """
    from repro.core.sparsity import DENSE

    sp = sp_cfg if sp_cfg is not None else DENSE

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        name = "/".join(path)
        lshape, _ = _logical_shape(name, node.shape)
        if bdwp.pregen_site(name, lshape, sp, bare=bare_sites):
            return _pregen_leaf(node.astype(jnp.float32), sp, pack)
        if jnp.issubdtype(node.dtype, jnp.floating):
            return node.astype(jnp.bfloat16)
        return node

    return walk(master, ())


def pregen_grads(grads_compute):
    """Cotangents of the compute tree -> master-shaped gradient tree.

    The pregen custom VJPs put the dense straight-through WU gradient on
    the BP operand (always dense-shaped); everything else maps through.
    """
    def walk(node):
        if bdwp.is_pregen(node):  # PregenOp or legacy operand dict
            return node["bp"]
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(grads_compute)


def stored_decay_masks(compute) -> dict:
    """{master leaf name: decay mask} from a pre-generated compute tree."""
    out = {}

    def walk(node, path):
        if not isinstance(node, dict):
            return
        for k, v in node.items():
            if bdwp.is_pregen(v):  # PregenOp or legacy operand dict
                if v.get("mask") is not None:
                    out["/".join(path + (k,))] = v["mask"]
            elif isinstance(v, dict):
                walk(v, path + (k,))

    walk(compute, ())
    return out


# ---------------------------------------------------------------------------
# The update
# ---------------------------------------------------------------------------


def update(state, grads, opt_cfg: SGDConfig, sp_cfg: SparsityConfig,
           param_names=None, *, prev_compute=None, pregen: bool = False,
           pack: bool = False, use_pallas: bool = False):
    """One optimizer step. Returns (new_state, compute_tree).

    pregen=False (legacy / standalone callers): the SR-STE decay mask is
    re-derived from fp32 master and the returned compute tree is the
    plain bf16 cast of the new master.

    pregen=True (the train-step dataflow): the decay mask is the one
    STORED at the previous WU (``prev_compute`` — same mask FF/BP just
    consumed), and the returned compute tree is the next step's
    pre-generated operands — each prunable param pays exactly one fused
    top_k, in this function, and nowhere else in the step.

    use_pallas=True routes eligible leaves (srste/bdwp weight updates,
    element granularity) through the fused WUVE+SORE Pallas kernel
    (kernels/fused_update): in-VMEM decay mask + momentum update + FF
    pack in one pass; the BP operand is derived jnp-side.  Bitwise
    identical to the jnp path.
    """
    lr = lr_schedule(opt_cfg, state["step"])
    names = param_names or _names_of(state["master"])
    prev_masks = stored_decay_masks(prev_compute) if (
        pregen and prev_compute is not None) else {}

    def jnp_upd(name, w, g, v, lshape, off, site):
        g = g.astype(jnp.float32)
        g = g + opt_cfg.weight_decay * w
        if (not sp_cfg.is_dense and sp_cfg.lam > 0.0
                and bdwp.decays(name, lshape, sp_cfg)
                and sp_cfg.method in ("srste", "bdwp", "sdwp")):
            mask = prev_masks.get(name)
            if mask is None:  # legacy / non-pregen leaf: re-derive from master
                axis = (bdwp.bp_group_axis(lshape) if sp_cfg.method == "sdwp"
                        else bdwp.ff_group_axis(lshape)) + off
                mask = nm_mask(w, sp_cfg.n, sp_cfg.m, axis=axis)
            g = g + sp_cfg.lam * jnp.where(mask, 0.0, w)
        v_new = opt_cfg.momentum * v + g
        w_new = w - lr * v_new
        if pregen and site:
            comp = _pregen_leaf(w_new, sp_cfg, pack)
        else:
            comp = w_new.astype(jnp.bfloat16)
        return w_new, v_new, comp

    def pallas_upd(name, w, g, v):
        """Fused WUVE+SORE kernel on the FF lane: move the contraction
        axis last, one kernel pass updates w/v (decay mask re-derived
        in-VMEM from fp32 master — identical to the stored mask) and
        emits the packed FF operand; BP operand derived jnp-side."""
        from repro.kernels import ops

        ff_ax = w.ndim - 2
        w_t, inv = _move_axis_last(w, ff_ax)
        g_t, _ = _move_axis_last(g.astype(jnp.float32), ff_ax)
        v_t, _ = _move_axis_last(v, ff_ax)
        shp = w_t.shape
        nw, nv, pv, pi = ops.fused_update(
            w_t.reshape(-1, shp[-1]), g_t.reshape(-1, shp[-1]),
            v_t.reshape(-1, shp[-1]), lr, opt_cfg.momentum,
            opt_cfg.weight_decay, sp_cfg.lam, sp_cfg.n, sp_cfg.m)
        kc = shp[-1] // sp_cfg.m * sp_cfg.n
        w_new = jnp.transpose(nw.reshape(shp), inv)
        v_new = jnp.transpose(nv.reshape(shp), inv)
        vals = jnp.transpose(pv.reshape(*shp[:-1], kc), inv)
        idx = jnp.transpose(pi.reshape(*shp[:-1], kc), inv)
        ff_mask = nm_unpack_n(jnp.ones_like(vals, dtype=bool), idx,
                              sp_cfg.n, sp_cfg.m, axis=ff_ax)
        if sp_cfg.prunes_bp_weights():  # bdwp: BP operand jnp-side
            bp_mask = nm_mask(w_new, sp_cfg.n, sp_cfg.m, axis=w.ndim - 1)
            bp_op = jnp.where(bp_mask, w_new, 0.0).astype(jnp.bfloat16)
        else:  # srste: BP runs dense
            bp_op = w_new.astype(jnp.bfloat16)
        if pack and sp_cfg.granularity == "element":
            leaf = O.PregenOp(bp=bp_op, vals=vals, idx=idx, mask=ff_mask,
                              cfg=sp_cfg, idx_bits=8)
        else:
            leaf = O.PregenOp(bp=bp_op, mask=ff_mask, cfg=sp_cfg,
                              ff=nm_unpack_n(vals, idx, sp_cfg.n, sp_cfg.m,
                                             axis=ff_ax))
        return w_new, v_new, leaf

    def upd(name, w, g, v):
        lshape, off = _logical_shape(name, w.shape)
        site = pregen and bdwp.pregen_site(name, lshape, sp_cfg)
        if (site and use_pallas and sp_cfg.granularity == "element"
                and sp_cfg.method in ("srste", "bdwp")
                and not sp_cfg.transposable):
            # fused_update derives a one-sided FF mask in-VMEM — wrong
            # for transposable operands, which stay on the jnp path
            return pallas_upd(name, w, g, v)
        return jnp_upd(name, w, g, v, lshape, off, site)

    flat_w, tdef = jax.tree_util.tree_flatten(state["master"])
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_v = jax.tree_util.tree_flatten(state["momentum"])[0]
    outs = [upd(n, w, g, v) for n, w, g, v in zip(names, flat_w, flat_g, flat_v)]
    new_master = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_mom = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    # pre-generation: the compute operands written at WU time (Fig. 11c);
    # PregenOp "leaves" ride through unflatten as opaque pytree subtrees
    compute = jax.tree_util.tree_unflatten(tdef, [o[2] for o in outs])
    new_state = {"master": new_master, "momentum": new_mom,
                 "step": state["step"] + 1}
    return new_state, compute


_STACKED_PREFIXES = ("blocks/", "enc_blocks/", "dec_blocks/")


def _logical_shape(name: str, shape):
    """Per-layer shape as the model sees it: scanned param trees carry a
    leading 'layer' axis that must not count as a contraction axis."""
    if any(name.startswith(p) or f"/{p}" in name for p in _STACKED_PREFIXES):
        return tuple(shape[1:]), 1
    return tuple(shape), 0


def _names_of(tree):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return ["/".join(str(getattr(k, "key", k)) for k in path)
            for path, _ in paths]
