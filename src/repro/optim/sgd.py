"""WUVE analogue: mixed-precision momentum SGD with SR-STE decay and
N:M sparse weight *pre-generation* (paper Fig. 11c).

State per parameter:
  master   fp32  (sharded like the param)
  momentum fp32
plus a bf16 *compute copy* emitted by every update — the AMP dataflow:
the optimizer is the only consumer of fp32; FF/BP load the bf16 (and,
on TPU, N:M-packed) weights written at WU time, so forward passes never
touch fp32 and FSDP all-gathers move half the bytes.

The fused Pallas kernel (kernels/fused_update.py) implements the same
math per tile for the TPU deployment path; this module is the jnp
formulation that lowers cleanly in the dry-run (identical semantics —
tests/test_kernels.py pins them together via ref_fused_update).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import bdwp
from repro.core.sparsity import SparsityConfig, nm_mask


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: float = 0.1
    momentum: float = 0.9
    weight_decay: float = 5e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.01


def lr_schedule(cfg: SGDConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac * cfg.lr + (1 - cfg.min_lr_frac) * cfg.lr \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params):
    return {
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "momentum": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def update(state, grads, opt_cfg: SGDConfig, sp_cfg: SparsityConfig,
           param_names=None):
    """One optimizer step. Returns (new_state, compute_params_bf16)."""
    lr = lr_schedule(opt_cfg, state["step"])
    names = param_names or _names_of(state["master"])

    def upd(name, w, g, v):
        g = g.astype(jnp.float32)
        g = g + opt_cfg.weight_decay * w
        lshape, off = _logical_shape(name, w.shape)
        if (not sp_cfg.is_dense and sp_cfg.lam > 0.0
                and bdwp.should_prune(name, lshape, sp_cfg)
                and sp_cfg.method in ("srste", "bdwp", "sdwp")):
            axis = (bdwp.bp_group_axis(lshape) if sp_cfg.method == "sdwp"
                    else bdwp.ff_group_axis(lshape)) + off
            mask = nm_mask(w, sp_cfg.n, sp_cfg.m, axis=axis)
            g = g + sp_cfg.lam * jnp.where(mask, 0.0, w)
        v_new = opt_cfg.momentum * v + g
        w_new = w - lr * v_new
        return w_new, v_new

    flat_w, tdef = jax.tree_util.tree_flatten(state["master"])
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_v = jax.tree_util.tree_flatten(state["momentum"])[0]
    outs = [upd(n, w, g, v) for n, w, g, v in zip(names, flat_w, flat_g, flat_v)]
    new_master = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_mom = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    # pre-generation: the bf16 compute copy written at WU time (Fig. 11c)
    compute = jax.tree.map(lambda w: w.astype(jnp.bfloat16), new_master)
    new_state = {"master": new_master, "momentum": new_mom,
                 "step": state["step"] + 1}
    return new_state, compute


_STACKED_PREFIXES = ("blocks/", "enc_blocks/", "dec_blocks/")


def _logical_shape(name: str, shape):
    """Per-layer shape as the model sees it: scanned param trees carry a
    leading 'layer' axis that must not count as a contraction axis."""
    if any(name.startswith(p) or f"/{p}" in name for p in _STACKED_PREFIXES):
        return tuple(shape[1:]), 1
    return tuple(shape), 0


def _names_of(tree):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return ["/".join(str(getattr(k, "key", k)) for k in path)
            for path, _ in paths]
