"""satsim — cycle-accurate performance/resource model of the paper's SAT
accelerator (STCE + WUVE + SORE on a Xilinx VCU1525 @ 200 MHz).

This re-implements the paper's own evaluation methodology ("a
cycle-accurate performance model cross-validated with RTL simulation",
Sec. VI-A) so the FPGA-side results — Fig. 14/15/16/17, Tables IV/V —
reproduce on CPU.  The TPU port (kernels/, launch/) is the deployment
path; satsim is the paper-fidelity path.
"""

from repro.satsim.arch import SATConfig, STCE, WUVE, SORE
from repro.satsim.model import (layer_time, model_step_time,
                                runtime_throughput, scale_sweep,
                                train_step_report)
from repro.satsim.workloads import paper_model_layers

__all__ = ["SATConfig", "STCE", "WUVE", "SORE", "layer_time",
           "model_step_time", "runtime_throughput", "scale_sweep",
           "train_step_report", "paper_model_layers"]
