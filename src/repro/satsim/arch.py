"""SAT hardware architecture model: geometry, clocks, engines, resources.

Numbers are taken from the paper:
  * STCE: 32x32 USPE systolic array, FP16 mul + FP32 acc, both pipelined
    3 stages; value-serial N:M groups (N cycles per group); dense MatMul
    decomposed into 2:2 groups (2 cycles each).  (Sec. IV-B, Fig. 7)
  * WS / OS dataflows via the flexible interconnect.  (Sec. IV-C, Fig. 8)
  * Interleave mapping: 3 independent dot products fill the 3-stage
    accumulation loop -> 3x OS throughput.  (Sec. V-A, Fig. 10)
  * WUVE: 32 lanes of mixed-precision momentum SGD.  (Sec. IV-E)
  * SORE: 32 lanes, top-K sorter, M cycles per M-group.  (Sec. IV-F)
  * 200 MHz on XCVU9P; DDR4 off-chip at 25.6 GB/s.  (Table IV)
  * Peak: dense 409.6 GOPS, 2:8 sparse 1638.4 GOPS.  (Table IV)
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class SATConfig:
    array: int = 32              # STCE is array x array USPEs
    freq_hz: float = 200e6
    pipe_stages: int = 3         # multiplier and adder pipeline depth
    interleave: bool = True      # Fig. 10(c) mapping
    n: int = 2                   # N:M sparse mode of the built bitstream
    m: int = 8
    ddr_bw: float = 25.6e9       # bytes/s
    wuve_lanes: int = 32
    sore_lanes: int = 32
    double_buffer: bool = True   # overlap DDR transfer with compute
    weight_bytes: int = 2        # FP16 compute weights
    act_bytes: int = 2
    master_bytes: int = 4        # FP32 master copy (WUVE traffic)
    idx_bits: int = 4            # per kept element (ceil(log2 M) <= 4)

    @property
    def pes(self) -> int:
        return self.array * self.array

    @property
    def dense_peak_ops(self) -> float:
        """GOPS peak for dense ops: each USPE does a 2:2 group (2 MACs)
        in 2 cycles -> 1 MAC/cycle/PE -> 2 OPs/cycle/PE."""
        return self.pes * 2.0 * self.freq_hz

    @property
    def sparse_peak_ops(self) -> float:
        """Effective OPS counting skipped zeros: an N:M group (M MACs of
        dense-equivalent work) completes in N cycles -> M/N x dense."""
        return self.dense_peak_ops * self.m / self.n


DEFAULT = SATConfig()


# ---------------------------------------------------------------------------
# Engines
# ---------------------------------------------------------------------------


class STCE:
    """N:M sparse tensor computing engine: cycle counts for one MatMul."""

    def __init__(self, cfg: SATConfig = DEFAULT):
        self.cfg = cfg

    def ws_cycles(self, b: int, k: int, f: int, *, sparse: bool) -> int:
        """Weight-stationary: compact weight groups preloaded per (K,F)
        tile; B activation rows stream through (Fig. 8a/c).

        A tile covers ``array`` groups of the contraction dim x ``array``
        output columns.  Sparse: group spans M logical weights, N cycles
        per row.  Dense: 2:2 groups, 2 cycles per row.
        """
        c = self.cfg
        g_len = c.m if sparse else 2              # logical K per group
        cpg = c.n if sparse else 2                # cycles per group-row
        k_tiles = math.ceil(k / (g_len * c.array))
        f_tiles = math.ceil(f / c.array)
        preload = c.array                         # pipelined preload
        drain = 2 * c.array + c.pipe_stages       # array skew + pipes
        per_tile = preload + b * cpg + drain
        return k_tiles * f_tiles * per_tile

    def os_cycles(self, b: int, k: int, f: int, *, sparse: bool) -> int:
        """Output-stationary: each (B,F) tile accumulates over K in place
        (Fig. 8b/d).  Without interleave mapping the 3-stage accumulation
        loop stalls the PE to 1 op per ``pipe_stages`` cycles (Fig. 10b);
        interleaving 3 independent dot products recovers full rate."""
        c = self.cfg
        g_len = c.m if sparse else 2
        cpg = c.n if sparse else 2
        groups = math.ceil(k / g_len)
        stall = 1 if c.interleave else c.pipe_stages
        b_tiles = math.ceil(b / c.array)
        f_tiles = math.ceil(f / c.array)
        fill_drain = 2 * c.array + c.pipe_stages
        per_tile = groups * cpg * stall + fill_drain
        return b_tiles * f_tiles * per_tile

    def best_cycles(self, b: int, k: int, f: int, *, sparse: bool):
        """(dataflow, cycles) with the RWG per-layer selection (Fig. 12)."""
        ws = self.ws_cycles(b, k, f, sparse=sparse)
        os_ = self.os_cycles(b, k, f, sparse=sparse)
        return ("WS", ws) if ws <= os_ else ("OS", os_)


class WUVE:
    """Weight-update vector engine: 32 lanes, 1 param/cycle/lane."""

    def __init__(self, cfg: SATConfig = DEFAULT):
        self.cfg = cfg

    def cycles(self, n_params: int) -> int:
        return math.ceil(n_params / self.cfg.wuve_lanes)

    def ddr_bytes(self, n_params: int) -> int:
        """Read FP32 master+momentum + FP16 grads; write FP32 back."""
        c = self.cfg
        return n_params * (2 * c.master_bytes + 2 + 2 * c.master_bytes)


class SORE:
    """Sparse online reduction engine: top-K sorter per lane, streaming
    one element per cycle -> a group of M costs M cycles per lane."""

    def __init__(self, cfg: SATConfig = DEFAULT):
        self.cfg = cfg

    def cycles(self, n_params: int) -> int:
        return math.ceil(n_params / self.cfg.sore_lanes)

    def packed_bytes(self, n_params: int) -> int:
        """Compact (values + indexes) output size."""
        c = self.cfg
        kept = n_params * c.n // c.m
        return kept * c.weight_bytes + math.ceil(kept * c.idx_bits / 8)


# ---------------------------------------------------------------------------
# FPGA resource model (Fig. 14 reproduction)
# ---------------------------------------------------------------------------

# Per-USPE base costs calibrated against Table III: STCE (32x32 = 1024
# USPEs) = 389K LUT, 589K FF, 1024 DSP at 2:8.
_USPE_BASE_LUT = 280.0       # dense PE: mul+add control
_USPE_BASE_FF = 260.0        # dense PE pipeline registers
_USPE_DSP = 1.0


def uspe_resources(n: int, m: int, dense: bool = False) -> dict:
    """LUT/FF/DSP of one USPE supporting N:M (or a dense-only PE).

    The paper reports (Fig. 14, relative to a 4x4 dense array):
      LUT x1.1 / x1.2 / x1.3   at 2:4 / 2:8 / 2:16
      FF  x1.7 / x2.2 / x3.3
    The FF growth is the M-deep west-input register file + index regs;
    LUT growth is the sparse index decode mux.
    """
    if dense:
        return {"lut": _USPE_BASE_LUT, "ff": _USPE_BASE_FF, "dsp": _USPE_DSP}
    idx_bits = max(1, math.ceil(math.log2(m)))
    lut = _USPE_BASE_LUT * (1.0 + 0.05 * idx_bits)        # decode mux
    ff = _USPE_BASE_FF * (1.0 + 0.30 * (m / 2) * (2 / max(n, 1)) * 0.5) \
        + 16.0 * m + 8.0 * idx_bits * n                   # group regs
    return {"lut": lut, "ff": ff, "dsp": _USPE_DSP}


def stce_resources(cfg: SATConfig, dense: bool = False,
                   array: int | None = None) -> dict:
    a = array or cfg.array
    per = uspe_resources(cfg.n, cfg.m, dense=dense)
    return {k: v * a * a for k, v in per.items()}
