"""SAT training-time model: layers x stages x engines -> seconds.

Reproduces the paper's evaluation pipeline:
  Fig. 15 — per-batch and TTA speedup of {SR-STE, SDGP, BDWP} vs dense,
  Fig. 16 — ResNet18 layer-wise runtime breakdown,
  Fig. 17 — throughput scaling vs array size x DDR bandwidth,
  Table IV — runtime/peak throughput + energy efficiency vs CPU/GPU.

Method semantics per stage (Fig. 3):
  dense : FF dense,    BP dense,   WU dense
  srste : FF sparse,   BP dense,   WU dense   (weights pruned along C_i)
  sdgp  : FF dense,    BP sparse,  WU dense   (output grads pruned)
  sdwp  : FF dense,    BP sparse,  WU dense   (weights pruned along C_o)
  bdwp  : FF sparse,   BP sparse,  WU dense   (the paper's contribution)

DDR traffic per stage (double-buffered: stage time = max(compute, DDR)
per Sec. IV-A; Fig. 16's non-overlapped variant adds them instead).
Pre-generation (Fig. 11c) moves SORE into the WU stage pipeline and
makes FF/BP load *packed* weights; without it FF/BP load dense weights
and pay SORE latency inline (Fig. 11b).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List

from repro.satsim.arch import DEFAULT, SATConfig, SORE, STCE, WUVE
from repro.satsim.workloads import MatMulLayer, model_params

_METHOD_STAGES = {
    "dense": (False, False),
    "srste": (True, False),
    "sdgp": (False, True),
    "sdwp": (False, True),
    "bdwp": (True, True),
}


@dataclasses.dataclass
class StageTime:
    stage: str
    dataflow: str
    compute_s: float
    ddr_s: float
    sore_s: float = 0.0

    @property
    def overlapped(self) -> float:
        return max(self.compute_s + self.sore_s, self.ddr_s)

    @property
    def serial(self) -> float:
        return self.compute_s + self.sore_s + self.ddr_s


def _packed_weight_bytes(cfg: SATConfig, n_w: int) -> int:
    kept = n_w * cfg.n // cfg.m
    return kept * cfg.weight_bytes + math.ceil(kept * cfg.idx_bits / 8)


def layer_time(layer: MatMulLayer, method: str = "bdwp",
               cfg: SATConfig = DEFAULT, *, pregen: bool = True
               ) -> List[StageTime]:
    """Cycle/DDR model for one layer's FF, BP, WU."""
    stce, sore = STCE(cfg), SORE(cfg)
    ff_sp, bp_sp = _METHOD_STAGES[method]
    ff_sp &= layer.prunable
    bp_sp &= layer.prunable
    rows, k, f = layer.rows, layer.k, layer.f
    n_w = k * f
    hz = cfg.freq_hz
    out: List[StageTime] = []

    # pre-generation only applies when *weights* are pruned (srste/sdwp/
    # bdwp); SDGP prunes gradients that exist only inside BP.
    can_pregen = pregen and method in ("srste", "sdwp", "bdwp")

    # ---- FF: (rows,K) @ (K,F) ----
    df, cyc = stce.best_cycles(rows, k, f, sparse=ff_sp)
    w_bytes = (_packed_weight_bytes(cfg, n_w) if (ff_sp and can_pregen)
               else n_w * cfg.weight_bytes)
    ddr = (rows * k * cfg.act_bytes + w_bytes + rows * f * cfg.act_bytes)
    sore_s = 0.0 if (not ff_sp or can_pregen) else sore.cycles(n_w) / hz
    out.append(StageTime("ff", df, cyc / hz, ddr / cfg.ddr_bw, sore_s))

    # ---- BP: (rows,F) @ (F,K) ----
    df, cyc = stce.best_cycles(rows, f, k, sparse=bp_sp)
    w_bytes = (_packed_weight_bytes(cfg, n_w) if (bp_sp and can_pregen)
               else n_w * cfg.weight_bytes)
    ddr = (rows * f * cfg.act_bytes + w_bytes + rows * k * cfg.act_bytes)
    sore_s = 0.0 if (not bp_sp or can_pregen) else sore.cycles(n_w) / hz
    out.append(StageTime("bp", df, cyc / hz, ddr / cfg.ddr_bw, sore_s))

    # ---- WU: (K,rows) @ (rows,F) — always dense (Alg. 1 line 9) ----
    df, cyc = stce.best_cycles(k, rows, f, sparse=False)
    ddr = (rows * k * cfg.act_bytes + rows * f * cfg.act_bytes
           + n_w * cfg.weight_bytes)
    # pre-generation: SORE packs the fresh weights inside the WU/optimizer
    # pipeline (fine-grained overlap -> no added latency, Fig. 11c), and
    # the packed copies are what FF/BP will stream next iteration.
    out.append(StageTime("wu", df, cyc / hz, ddr / cfg.ddr_bw, 0.0))
    return out


def model_step_time(layers: List[MatMulLayer], method: str = "bdwp",
                    cfg: SATConfig = DEFAULT, *, pregen: bool = True,
                    overlap: bool = True) -> dict:
    """One training step (single batch) end to end, incl. WUVE."""
    wuve = WUVE(cfg)
    total = 0.0
    per_stage = {"ff": 0.0, "bp": 0.0, "wu": 0.0}
    for layer in layers:
        for st in layer_time(layer, method, cfg, pregen=pregen):
            t = st.overlapped if overlap else st.serial
            total += t
            per_stage[st.stage] += t
    n_params = model_params(layers)
    wuve_s = max(wuve.cycles(n_params) / cfg.freq_hz,
                 wuve.ddr_bytes(n_params) / cfg.ddr_bw)
    total += wuve_s
    macs = {
        "dense": 3 * sum(l.macs for l in layers),
        method: sum(
            l.macs * ((cfg.n / cfg.m if (_METHOD_STAGES[method][0] and l.prunable) else 1.0)
                      + (cfg.n / cfg.m if (_METHOD_STAGES[method][1] and l.prunable) else 1.0)
                      + 1.0)
            for l in layers),
    }
    return {"total_s": total, "per_stage": per_stage, "wuve_s": wuve_s,
            "macs": macs, "n_params": n_params}


def train_step_report(layers: List[MatMulLayer], method: str,
                      cfg: SATConfig = DEFAULT, *, pregen: bool = True
                      ) -> List[dict]:
    """Per-layer breakdown (Fig. 16): stage times + engine attribution."""
    rows = []
    sore = SORE(cfg)
    for layer in layers:
        sts = layer_time(layer, method, cfg, pregen=pregen)
        rows.append({
            "layer": layer.name,
            "dims": (layer.rows, layer.k, layer.f),
            "prunable": layer.prunable,
            **{f"{st.stage}_s": st.overlapped for st in sts},
            **{f"{st.stage}_df": st.dataflow for st in sts},
            "sore_s": sore.cycles(layer.k * layer.f) / cfg.freq_hz,
            "total_s": sum(st.overlapped for st in sts),
        })
    return rows


# ---------------------------------------------------------------------------
# Throughput / energy (Table IV, Fig. 17)
# ---------------------------------------------------------------------------

# Measured average power from the paper (Table IV), used to report
# energy efficiency of modelled runtimes.
POWER_DENSE_W = 20.73
POWER_SPARSE_W = 24.15
POWER_AVG_W = 22.38


def runtime_throughput(layers: List[MatMulLayer], method: str,
                       cfg: SATConfig = DEFAULT) -> dict:
    """Dense-equivalent OPs per second the accelerator sustains on this
    workload (the paper counts dense-equivalent work for sparse runs —
    'Runtime Throughput' in Table IV)."""
    rep = model_step_time(layers, method, cfg)
    dense_ops = 2.0 * rep["macs"]["dense"]
    gops = dense_ops / rep["total_s"]
    power = POWER_SPARSE_W if method != "dense" else POWER_DENSE_W
    return {"gops": gops / 1e9, "total_s": rep["total_s"],
            "gops_per_w": gops / 1e9 / power,
            "peak_dense_gops": cfg.dense_peak_ops / 1e9,
            "peak_sparse_gops": cfg.sparse_peak_ops / 1e9}


def scale_sweep(layers: List[MatMulLayer], method: str,
                arrays=(16, 32, 64, 128),
                bandwidths=(25.6e9, 102.4e9, 409.6e9)) -> List[dict]:
    """Fig. 17: runtime throughput when scaling USPE count x DDR BW."""
    out = []
    for bw in bandwidths:
        for a in arrays:
            cfg = dataclasses.replace(DEFAULT, array=a, ddr_bw=bw)
            r = runtime_throughput(layers, method, cfg)
            out.append({"array": a, "bw_gbs": bw / 1e9,
                        "tops": r["gops"] / 1e3,
                        "peak_sparse_tops": cfg.sparse_peak_ops / 1e12})
    return out
