"""Layer shape tables (im2col'd MatMul dims) for the paper's five
benchmark models — Table I — used by the SAT cycle model.

Each layer is (name, rows, k, f, prunable):
  rows = B * H_out * W_out (conv, im2col) or B * seq (ViT)
  k    = kh*kw*C_in (conv) or F_in (linear)
  f    = C_out / F_out
The first conv / patch-embed layer is excluded from N:M pruning
(Sec. VI-A), matching ``core/bdwp.should_prune``'s ``head0`` rule.
"""

from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass(frozen=True)
class MatMulLayer:
    name: str
    rows: int
    k: int
    f: int
    prunable: bool = True

    @property
    def macs(self) -> int:
        return self.rows * self.k * self.f


def _conv(name, batch, hw, kh, cin, cout, stride=1, prunable=True):
    out_hw = hw // stride
    return MatMulLayer(name, batch * out_hw * out_hw, kh * kh * cin, cout,
                       prunable)


def resnet9_layers(batch=512) -> List[MatMulLayer]:
    L = [
        _conv("head0", batch, 32, 3, 3, 64, prunable=False),
        _conv("conv1", batch, 32, 3, 64, 128),
        # pool -> 16
        _conv("res1a", batch, 16, 3, 128, 128),
        _conv("res1b", batch, 16, 3, 128, 128),
        _conv("conv2", batch, 16, 3, 128, 256),
        # pool -> 8
        _conv("conv3", batch, 8, 3, 256, 512),
        # pool -> 4
        _conv("res2a", batch, 4, 3, 512, 512),
        _conv("res2b", batch, 4, 3, 512, 512),
        MatMulLayer("fc", batch, 512, 10, prunable=False),
    ]
    return L


def vgg19_layers(batch=512, num_classes=100) -> List[MatMulLayer]:
    spec = [(32, 3, 64, False), (32, 64, 64, True),
            (16, 64, 128, True), (16, 128, 128, True),
            (8, 128, 256, True)] + [(8, 256, 256, True)] * 3 + \
           [(4, 256, 512, True)] + [(4, 512, 512, True)] * 3 + \
           [(2, 512, 512, True)] * 4
    out = []
    for i, (hw, cin, cout, prunable) in enumerate(spec):
        name = "head0" if not prunable else f"conv{i}"
        out.append(_conv(name, batch, hw, 3, cin, cout, prunable=prunable))
    out.append(MatMulLayer("fc", batch, 512, num_classes, prunable=False))
    return out


def resnet18_layers(batch=512, image=64, num_classes=200) -> List[MatMulLayer]:
    L = [_conv("head0", batch, image, 7, 3, 64, stride=2, prunable=False)]
    hw = image // 4  # stride-2 head + maxpool
    cin = 64
    for si, cout in enumerate((64, 128, 256, 512)):
        for bi in range(2):
            stride = 2 if (bi == 0 and si > 0) else 1
            L.append(_conv(f"s{si}b{bi}/c1", batch, hw, 3, cin, cout, stride))
            hw_out = hw // stride
            L.append(_conv(f"s{si}b{bi}/c2", batch, hw_out, 3, cout, cout))
            if cin != cout:
                L.append(_conv(f"s{si}b{bi}/proj", batch, hw, 1, cin, cout,
                               stride))
            cin, hw = cout, hw_out
    L.append(MatMulLayer("fc", batch, 512, num_classes, prunable=False))
    return L


def resnet50_layers(batch=256, image=224, num_classes=1000) -> List[MatMulLayer]:
    L = [_conv("head0", batch, image, 7, 3, 64, stride=2, prunable=False)]
    hw = image // 4
    cin = 64
    blocks = ((3, 64), (4, 128), (6, 256), (3, 512))
    for si, (n_blocks, cout) in enumerate(blocks):
        cexp = cout * 4
        for bi in range(n_blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            L.append(_conv(f"s{si}b{bi}/c1", batch, hw, 1, cin, cout))
            L.append(_conv(f"s{si}b{bi}/c2", batch, hw, 3, cout, cout, stride))
            hw_out = hw // stride
            L.append(_conv(f"s{si}b{bi}/c3", batch, hw_out, 1, cout, cexp))
            if cin != cexp:
                L.append(_conv(f"s{si}b{bi}/proj", batch, hw, 1, cin, cexp,
                               stride))
            cin, hw = cexp, hw_out
    L.append(MatMulLayer("fc", batch, 2048, num_classes, prunable=False))
    return L


def vit_layers(batch=512, d=384, d_ff=1536, n_layers=7, seq=65,
               num_classes=100) -> List[MatMulLayer]:
    rows = batch * seq
    L = [MatMulLayer("patch_frontend", rows, 48, d, prunable=False)]
    for i in range(n_layers):
        for nm in ("q_proj", "k_proj", "v_proj", "o_proj"):
            L.append(MatMulLayer(f"block{i}/{nm}", rows, d, d))
        L.append(MatMulLayer(f"block{i}/w_in", rows, d, d_ff))
        L.append(MatMulLayer(f"block{i}/w_out", rows, d_ff, d))
    L.append(MatMulLayer("head", batch, d, num_classes, prunable=False))
    return L


def paper_model_layers(name: str, batch: int | None = None):
    table = {
        "resnet9": (resnet9_layers, 512),
        "vit": (vit_layers, 512),
        "vgg19": (vgg19_layers, 512),
        "resnet18": (resnet18_layers, 512),
        "resnet50": (resnet50_layers, 256),
    }
    fn, default_b = table[name]
    return fn(batch or default_b)


def model_params(layers: List[MatMulLayer]) -> int:
    return sum(l.k * l.f for l in layers)
