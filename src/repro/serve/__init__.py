"""Serving subsystem: continuous-batching decode from N:M-packed weights.

Layering (each importable on its own):
  packed_params — element-mode (SORE) packed parameter store: eligible
                  weights live in HBM as compact (vals, idx) tensors and
                  decode consumes them through kernels/nm_spmm, with
                  actual-byte accounting (the paper's Fig. 11c win).
  batcher       — fixed-capacity slot-paged KV cache + the single
                  compiled decode step; requests join mid-flight into
                  free slots and evict without recompiling.
  engine        — request lifecycle (submit/step/harvest): admission,
                  slot allocation, per-request stop conditions.
"""

from repro.serve.batcher import ContinuousBatcher, SlotKVCache, seat_cache
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.serve.packed_params import PackedParamStore, pack_tree_element

__all__ = [
    "ContinuousBatcher", "SlotKVCache", "seat_cache",
    "Request", "ServeConfig", "ServeEngine",
    "PackedParamStore", "pack_tree_element",
]
