"""Serving subsystem: continuous-batching decode from N:M-packed weights.

Layering (each importable on its own):
  packed_params — element-mode (SORE) packed parameter store: eligible
                  weights live in HBM as compact (vals, idx) tensors and
                  decode consumes them through kernels/nm_spmm, with
                  actual-byte accounting (the paper's Fig. 11c win).
  batcher       — fixed-capacity slot-paged KV cache + the single
                  compiled decode step; requests join mid-flight into
                  free slots and evict without recompiling.
  cache_store   — host-side pool of seatable batch-1 KV lanes: the
                  prefix-reuse pool and the prefill→decode handoff
                  buffer share one abstraction.
  engine        — request lifecycle (submit/step/harvest): admission,
                  slot allocation, per-request stop conditions, lane
                  export/import hooks for the fleet.
  fleet         — multi-replica frontend: one admission queue, a
                  KV-affinity + live-utilization router, disaggregated
                  prefill/decode engine pools, asyncio frontend.
"""

from repro.serve.batcher import (ContinuousBatcher, SlotKVCache,
                                 extract_lane_cache, seat_cache)
from repro.serve.cache_store import CacheStore, Lane, prefix_chain
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.serve.fleet import (AsyncFrontend, FleetConfig, FleetRequest,
                               Router, ServeFleet)
from repro.serve.packed_params import PackedParamStore, pack_tree_element

__all__ = [
    "ContinuousBatcher", "SlotKVCache", "seat_cache", "extract_lane_cache",
    "CacheStore", "Lane", "prefix_chain",
    "Request", "ServeConfig", "ServeEngine",
    "AsyncFrontend", "FleetConfig", "FleetRequest", "Router", "ServeFleet",
    "PackedParamStore", "pack_tree_element",
]
