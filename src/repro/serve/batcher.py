"""Continuous batching over a fixed-capacity slot-paged KV cache.

The cache is one device-resident pytree with a leading *slot* axis
(``n_slots`` lanes, each ``max_len`` deep).  Requests join mid-flight
into free slots and finished requests evict without touching the
device: eviction is a host-side bitmap flip, and the next admission
overwrites the slot's lanes.  Because every step runs at the same
static shape — (n_slots, 1) tokens, (n_slots,) positions — there is
exactly ONE compiled decode step for the engine's whole lifetime,
regardless of join/evict order (the per-slot position/mask semantics
live in models/attention's ``per_slot`` decode path).

Prompts are right-padded to one static bucket (``prompt_bucket``) so
prefill also compiles once; the padded lanes hold garbage KV but stay
masked (``k_pos <= pos``) until the decode cursor overwrites them, so
they are never attended to.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsity import DENSE, SparsityConfig
from repro.models import transformer_lm as T
from repro.serve.cache_store import Lane
from repro.train import step as ST


def _seat_leaf(dst, src, slot, batch_axis: int):
    """Write a single-request cache leaf into lane ``slot`` of the
    engine cache.  Leaves without a slot axis at ``batch_axis`` (the
    per-layer ``pos`` cursors — meaningless under per-slot decode) are
    left untouched."""
    if dst.ndim <= batch_axis or src.ndim != dst.ndim \
            or src.shape[batch_axis] != 1:
        return dst
    starts = [jnp.zeros((), jnp.int32)] * dst.ndim
    starts[batch_axis] = jnp.asarray(slot, jnp.int32)
    return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), starts)


def seat_cache(cache, pre_cache, slot):
    """Seat a batch-1 prefill cache into lane ``slot`` of the slot-paged
    engine cache (jit-safe; ``slot`` may be traced).

    Layout contract (models/transformer_lm.init_lm_cache): scanned-layer
    leaves are stacked as (L, B, ...) — slot axis 1; the optional
    ``prelude`` subtree is unstacked (B, ...) — slot axis 0.
    """
    out = dict(cache)
    out["layers"] = jax.tree.map(
        partial(_seat_leaf, slot=slot, batch_axis=1),
        cache["layers"], pre_cache["layers"])
    if "prelude" in cache:
        out["prelude"] = jax.tree.map(
            partial(_seat_leaf, slot=slot, batch_axis=0),
            cache["prelude"], pre_cache["prelude"])
    return out


def _extract_leaf(src, slot, batch_axis: int, n_slots: int):
    """Inverse of ``_seat_leaf``: slice lane ``slot`` out of an engine
    cache leaf as a batch-1 leaf.  Leaves without a slot axis at
    ``batch_axis`` (the per-layer ``pos`` cursors) pass through."""
    if src.ndim <= batch_axis or src.shape[batch_axis] != n_slots:
        return src
    starts = [jnp.zeros((), jnp.int32)] * src.ndim
    starts[batch_axis] = jnp.asarray(slot, jnp.int32)
    sizes = list(src.shape)
    sizes[batch_axis] = 1
    return jax.lax.dynamic_slice(src, starts, sizes)


def extract_lane_cache(cache, slot, n_slots: int):
    """Slice lane ``slot`` of a slot-paged engine cache into a batch-1
    cache pytree (jit-safe; ``slot`` may be traced) — the cache half of
    exporting a lane for a CacheStore handoff.  Layout contract matches
    ``seat_cache``: scanned-layer leaves (L, B, ...) — slot axis 1; the
    optional ``prelude`` subtree (B, ...) — slot axis 0.  The round trip
    ``seat_cache(cache, extract_lane_cache(cache, s), s)`` is bitwise
    exact (dynamic_slice of what dynamic_update_slice wrote)."""
    out = {"layers": jax.tree.map(
        partial(_extract_leaf, slot=slot, batch_axis=1, n_slots=n_slots),
        cache["layers"])}
    if "prelude" in cache:
        out["prelude"] = jax.tree.map(
            partial(_extract_leaf, slot=slot, batch_axis=0,
                    n_slots=n_slots),
            cache["prelude"])
    return out


class SlotKVCache:
    """Device cache with a host-side free-slot bitmap."""

    def __init__(self, cfg, n_slots: int, max_len: int, dtype=jnp.bfloat16):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = T.init_lm_cache(cfg, n_slots, max_len, dtype)
        self._free = list(range(n_slots))

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    def alloc(self) -> Optional[int]:
        """Claim the lowest free slot (deterministic reuse order)."""
        if not self._free:
            return None
        self._free.sort()
        return self._free.pop(0)

    def free(self, slot: int) -> None:
        if slot in self._free:
            raise ValueError(f"slot {slot} already free")
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range")
        self._free.append(slot)


class ContinuousBatcher:
    """One-compile prefill/seat/decode over a SlotKVCache.

    Host state: per-slot next input token (n_slots, 1) and per-slot
    absolute write position (n_slots,).  Free slots keep decoding
    garbage lanes (their writes are clipped in-bounds and their outputs
    ignored); correctness for reused slots follows from the position
    mask — a lane is only attendable once the cursor has passed it,
    i.e. after this request wrote it.
    """

    def __init__(self, params, cfg, sp_cfg: SparsityConfig = DENSE, *,
                 n_slots: int, max_len: int, prompt_bucket: int,
                 cache_dtype=jnp.bfloat16, mesh=None, shardings=None):
        if prompt_bucket > max_len:
            raise ValueError("prompt_bucket must be <= max_len")
        self.params = params
        self.cfg = cfg
        self.sp_cfg = sp_cfg
        self.prompt_bucket = prompt_bucket
        self.kv = SlotKVCache(cfg, n_slots, max_len, cache_dtype)
        self.tokens = jnp.zeros((n_slots, 1), jnp.int32)
        self.positions = jnp.zeros((n_slots,), jnp.int32)
        self.shardings = shardings
        if shardings is not None:
            # SPMD serving: commit every resident to its SERVE_BATCH
            # placement (launch/spmd.serve_shardings) — weights TP over
            # "model", slot lanes over the DP axes.  The prefill/seat
            # jits follow the committed placements; the decode hot path
            # is pinned end-to-end below.
            self.params = jax.device_put(params, shardings["params"])
            self.kv.cache = jax.device_put(self.kv.cache,
                                           shardings["cache"])
            self.tokens = jax.device_put(self.tokens, shardings["token"])
            self.positions = jax.device_put(self.positions,
                                            shardings["pos"])
        vocab = cfg.vocab

        def prefill_fn(p, toks, last_index):
            logits, cache = ST.lm_prefill_step(
                p, {"tokens": toks}, cfg=cfg, sp_cfg=sp_cfg, mesh=mesh,
                last_index=last_index)
            first = jnp.argmax(logits[:, -1, :vocab], axis=-1)
            return first.astype(jnp.int32), cache

        def decode_fn(p, cache, toks, pos):
            logits, cache = ST.lm_decode_step(
                p, cache, toks, pos, cfg=cfg, sp_cfg=sp_cfg, mesh=mesh,
                per_slot=True)
            nxt = jnp.argmax(logits[:, -1, :vocab], axis=-1)
            return nxt.astype(jnp.int32), cache

        self._prefill = jax.jit(prefill_fn)
        self._seat = jax.jit(seat_cache, donate_argnums=(0,))
        self._extract = jax.jit(partial(extract_lane_cache,
                                        n_slots=n_slots))
        self.prefill_calls = 0   # compiled-prefill invocations (a reuse
        #                          hit seats a pooled lane and skips one)
        if shardings is None:
            self._decode = jax.jit(decode_fn, donate_argnums=(1,))
        else:
            self._decode = jax.jit(
                decode_fn,
                in_shardings=(shardings["params"], shardings["cache"],
                              shardings["token"], shardings["pos"]),
                # nxt is (n_slots,) like positions — pin it too: left to
                # the compiler it may pick a layout that XLA then tries
                # to alias against a donated cache leaf of another
                # sharding (Expected aliased input/output ... same size)
                out_shardings=(shardings["pos"], shardings["cache"]),
                donate_argnums=(1,))

    # -- admission ----------------------------------------------------------

    def prefill(self, prompt, key=()) -> Lane:
        """Run the compiled prefill over ``prompt`` (len <=
        prompt_bucket) WITHOUT touching a slot; returns the batch-1
        Lane a later ``seat_lane`` (here or on another engine — the
        disaggregation handoff) can seat."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        plen = prompt.shape[0]
        if not 0 < plen <= self.prompt_bucket:
            raise ValueError(
                f"prompt length {plen} not in (0, {self.prompt_bucket}]")
        padded = np.zeros((1, self.prompt_bucket), np.int32)
        padded[0, :plen] = prompt
        first, pre_cache = self._prefill(
            self.params, jnp.asarray(padded), jnp.asarray([plen - 1]))
        self.prefill_calls += 1
        return Lane(key=tuple(key), cache=pre_cache,
                    next_token=int(first[0]), pos=int(plen))

    def seat_lane(self, lane: Lane) -> int:
        """Seat a batch-1 lane (fresh prefill, pooled reuse hit, or an
        imported handoff) into a free slot.  Raises if none is free —
        the engine checks ``kv.n_free`` first."""
        slot = self.kv.alloc()
        if slot is None:
            raise RuntimeError("no free slot")
        self.kv.cache = self._seat(self.kv.cache, lane.cache,
                                   jnp.asarray(slot, jnp.int32))
        self.tokens = self.tokens.at[slot, 0].set(lane.next_token)
        self.positions = self.positions.at[slot].set(lane.pos)
        if self.shardings is not None:
            # the seat jit infers its own output layouts; re-pin to the
            # declared placements so the decode step's donated cache
            # aliasing sees exactly its committed in_shardings
            self.kv.cache = jax.device_put(self.kv.cache,
                                           self.shardings["cache"])
            self.tokens = jax.device_put(self.tokens,
                                         self.shardings["token"])
            self.positions = jax.device_put(self.positions,
                                            self.shardings["pos"])
        return slot

    def export_lane(self, slot: int, key=()) -> Lane:
        """Slice the live state of lane ``slot`` (cache + next token +
        position) into a batch-1 Lane another engine can seat and
        continue bitwise-identically — per-slot decode math never mixes
        lanes, so a migrated request cannot tell it moved."""
        if not 0 <= slot < self.kv.n_slots:
            raise ValueError(f"slot {slot} out of range")
        cache1 = self._extract(self.kv.cache, jnp.asarray(slot, jnp.int32))
        return Lane(key=tuple(key), cache=cache1,
                    next_token=int(self.tokens[slot, 0]),
                    pos=int(self.positions[slot]))

    def admit(self, prompt) -> tuple[int, int]:
        """Prefill ``prompt`` into a free slot: ``prefill`` +
        ``seat_lane``.  Returns (slot, first generated token)."""
        lane = self.prefill(prompt)
        return self.seat_lane(lane), lane.next_token

    def evict(self, slot: int) -> None:
        """Release a slot — host-side only; no device work, no recompile."""
        self.kv.free(slot)

    # -- decode -------------------------------------------------------------

    def step(self) -> np.ndarray:
        """One decode step for all n_slots lanes; returns (n_slots,)
        next-token ids (garbage on free lanes — callers index by their
        active slots)."""
        nxt, self.kv.cache = self._decode(
            self.params, self.kv.cache, self.tokens, self.positions)
        self.tokens = nxt[:, None]
        self.positions = self.positions + 1
        if self.shardings is not None:
            # keep next-step inputs pinned to their declared shardings —
            # the decode output's compiler-chosen layout must not leak
            # into the next call's committed in_shardings
            self.tokens = jax.device_put(self.tokens,
                                         self.shardings["token"])
            self.positions = jax.device_put(self.positions,
                                            self.shardings["pos"])
        return np.asarray(nxt)
