"""CacheStore: host-side pool of batch-1 KV lanes keyed by prefix hash.

A *lane* is everything the continuous batcher needs to seat a request
into a free slot without running prefill again: the batch-1 cache
pytree a prefill produced (or a slot-slice exported from a live
engine), the next input token, and the absolute decode position.  Two
consumers ride the same abstraction:

  * **prefix reuse** — an engine pools the prefill lane of every prompt
    it serves, keyed by the prompt's block-hash chain; a later request
    with the same chain seats the pooled lane instead of prefilling
    (the KvCacheManager pattern: the router asks each replica for its
    ``match_depth`` and prefers the replica already holding the longest
    matching prefix);
  * **prefill/decode disaggregation** — a dedicated prefill engine
    publishes finished lanes here and a decode engine pops them at
    admission time (the handoff buffer between the two engine pools).

Hashing granularity: prompts are chunked at the engine's
``prompt_bucket`` (the prefill compiles one bucket, so a bucket is the
unit of KV a replica can actually reuse).  ``prefix_chain`` emits one
cumulative digest per chunk; today's engine validates prompts to a
single bucket so chains have length 1, but the chain/match-depth
machinery is written for multi-bucket prompts.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Any, Optional, Tuple


def prefix_chain(prompt, block: int) -> Tuple[str, ...]:
    """Cumulative block-hash chain of ``prompt`` at ``block`` tokens per
    chunk.  chain[k] digests tokens[0 : (k+1)*block] (the last chunk may
    be partial — its digest covers its true length, so two prompts get
    equal chains iff the token sequences are identical)."""
    if block < 1:
        raise ValueError(f"block must be >= 1, got {block}")
    toks = [int(t) for t in prompt]
    chain = []
    h = hashlib.blake2b(digest_size=16)
    for start in range(0, len(toks), block):
        chunk = toks[start:start + block]
        h.update(len(chunk).to_bytes(4, "little"))
        for t in chunk:
            h.update(int(t).to_bytes(8, "little", signed=True))
        chain.append(h.hexdigest())
    return tuple(chain)


def match_depth(stored: Tuple[str, ...], query: Tuple[str, ...]) -> int:
    """Length of the common leading-block prefix of two chains."""
    d = 0
    for a, b in zip(stored, query):
        if a != b:
            break
        d += 1
    return d


@dataclasses.dataclass
class Lane:
    """One seatable KV lane (batch-1)."""

    key: Tuple[str, ...]       # prefix chain (reuse) or handoff key
    cache: Any                 # batch-1 cache pytree (bucket- or max_len-deep)
    next_token: int            # next decode input for this lane
    pos: int                   # absolute write position (== prompt len
    #                            right after prefill)


class CacheStore:
    """Bounded LRU of lanes with prefix-chain lookup + hit accounting."""

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._lanes: "OrderedDict[Tuple[str, ...], Lane]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._lanes)

    def __contains__(self, key) -> bool:
        return tuple(key) in self._lanes

    def put(self, lane: Lane) -> None:
        key = tuple(lane.key)
        if key not in self._lanes and len(self._lanes) >= self.capacity:
            self._lanes.popitem(last=False)
            self.evictions += 1
        self._lanes[key] = lane
        self._lanes.move_to_end(key)
        self.puts += 1

    def get(self, key) -> Optional[Lane]:
        """Exact-chain lookup; hit refreshes LRU recency, lane stays."""
        lane = self._lanes.get(tuple(key))
        if lane is None:
            self.misses += 1
            return None
        self._lanes.move_to_end(tuple(lane.key))
        self.hits += 1
        return lane

    def pop(self, key) -> Optional[Lane]:
        """Remove-and-return (the disaggregation handoff: a lane is
        consumed by exactly one decode engine)."""
        return self._lanes.pop(tuple(key), None)

    def match_depth(self, chain) -> int:
        """Longest common leading-block prefix between ``chain`` and any
        stored lane's key — the router's KV-affinity signal."""
        chain = tuple(chain)
        best = 0
        for key in self._lanes:
            best = max(best, match_depth(key, chain))
        return best

    def stats(self) -> dict:
        return {"size": len(self._lanes), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "puts": self.puts, "evictions": self.evictions}
