"""Request lifecycle manager: submit / step / harvest.

The engine fronts a ContinuousBatcher with a FIFO admission queue and
per-request stop conditions (max_new_tokens, optional EOS token, KV
capacity).  One ``step()`` = admit as many queued requests as there are
free slots (each costs one fixed-shape prefill + seat), then one
batched decode step for every lane; finished requests evict their slot
immediately, so a queued request can join on the very next step —
continuous batching, not static batching.

With ``packed=True`` the engine serves from an element-mode
PackedParamStore: decode matmuls consume compact (vals, idx) tensors
through kernels/nm_spmm at ~N/M of the dense weight HBM bytes
(``engine.hbm_report()`` gives the actual numbers).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

from repro.core.sparsity import DENSE, SparsityConfig
from repro.serve.batcher import ContinuousBatcher
from repro.serve.packed_params import PackedParamStore


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static engine shape — fixes the one-and-only compiled step."""

    n_slots: int = 4          # concurrent requests (KV lanes)
    max_len: int = 96         # per-slot KV depth (prompt + generation)
    prompt_bucket: int = 32   # prompts right-padded to this length
    eos_token: Optional[int] = None  # engine-wide default stop token
    packed: bool = False      # serve from element-packed N:M weights
    idx_bits: Optional[int] = None   # stored index width for the packed
    # store: 4 (u4, two offsets/byte), 8 (byte-wide), or None to pick
    # automatically (u4 whenever M <= 16 — packed_params.default_idx_bits)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    eos: Optional[int]
    state: str = "queued"             # queued | running | done
    slot: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    submit_step: int = 0
    finish_step: int = 0

    @property
    def finish_reason(self) -> str:
        if self.eos is not None and self.tokens and self.tokens[-1] == self.eos:
            return "eos"
        return "length"


class ServeEngine:
    """Continuous-batching greedy-decode engine over N:M-sparse weights."""

    def __init__(self, params, cfg, sp_cfg: SparsityConfig = DENSE,
                 serve_cfg: ServeConfig = ServeConfig(), *, mesh=None,
                 cache_dtype=None):
        import jax.numpy as jnp

        self.cfg = cfg
        self.sp_cfg = sp_cfg
        self.serve_cfg = serve_cfg
        self.mesh = mesh
        self.store: Optional[PackedParamStore] = None
        if serve_cfg.packed:
            self.store = PackedParamStore.pack(params, sp_cfg,
                                               idx_bits=serve_cfg.idx_bits)
            params = self.store.params
        shardings = None
        if mesh is not None and mesh.devices.size > 1:
            # SPMD serving: resolve SERVE_BATCH-rule shardings (weights
            # TP over "model" with N:M groups unsplit, slot lanes over
            # the DP axes) and pin the engine's residents to them.
            from repro.launch import spmd
            shardings = spmd.serve_shardings(
                cfg, mesh, sp_cfg, n_slots=serve_cfg.n_slots,
                max_len=serve_cfg.max_len, packed=serve_cfg.packed,
                idx_bits=serve_cfg.idx_bits,
                cache_dtype=cache_dtype or jnp.bfloat16)
        self.batcher = ContinuousBatcher(
            params, cfg, sp_cfg,
            n_slots=serve_cfg.n_slots, max_len=serve_cfg.max_len,
            prompt_bucket=serve_cfg.prompt_bucket,
            cache_dtype=cache_dtype or jnp.bfloat16, mesh=mesh,
            shardings=shardings)
        self._queue: deque[Request] = deque()
        self._running: Dict[int, Request] = {}   # slot -> request
        self._done: Dict[int, Request] = {}      # rid -> request
        self._next_rid = 0
        self.step_count = 0
        self.decode_steps = 0
        self.decoded_tokens = 0   # harvested from active lanes only

    # -- lifecycle ----------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int = 16,
               eos: Optional[int] = None) -> int:
        """Queue a request; returns its rid.  Admission happens in step().

        Validates against the static engine shape: the prompt must fit
        the prefill bucket and prompt+generation must fit a KV lane.
        """
        prompt = [int(t) for t in prompt]
        sc = self.serve_cfg
        if not 0 < len(prompt) <= sc.prompt_bucket:
            raise ValueError(f"prompt length {len(prompt)} not in "
                             f"(0, {sc.prompt_bucket}]")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if len(prompt) + max_new_tokens > sc.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds per-slot KV capacity {sc.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, max_new_tokens=max_new_tokens,
                      eos=eos if eos is not None else sc.eos_token,
                      submit_step=self.step_count)
        self._queue.append(req)
        return rid

    def _should_stop(self, req: Request) -> bool:
        if len(req.tokens) >= req.max_new_tokens:
            return True
        if req.eos is not None and req.tokens and req.tokens[-1] == req.eos:
            return True
        return False

    def _finish(self, req: Request) -> None:
        req.state = "done"
        req.finish_step = self.step_count
        self.batcher.evict(req.slot)
        del self._running[req.slot]
        self._done[req.rid] = req

    def step(self) -> dict:
        """Admit from the queue, decode one token for every active slot.

        Returns an event dict: {"admitted": [rid], "finished": [rid],
        "active": n_running_after}.
        """
        events = {"admitted": [], "finished": [], "active": 0}
        # 1. admission: queued requests join mid-flight into free slots
        while self._queue and self.batcher.kv.n_free > 0:
            req = self._queue.popleft()
            slot, first_tok = self.batcher.admit(req.prompt)
            req.slot, req.state = slot, "running"
            req.tokens.append(first_tok)
            self._running[slot] = req
            self.decoded_tokens += 1
            events["admitted"].append(req.rid)
            if self._should_stop(req):   # e.g. max_new_tokens == 1
                self._finish(req)
                events["finished"].append(req.rid)
        # 2. one batched decode step (all lanes; free lanes are garbage)
        if self._running:
            nxt = self.batcher.step()
            self.decode_steps += 1
            for slot, req in list(self._running.items()):
                tok = int(nxt[slot])
                req.tokens.append(tok)
                self.decoded_tokens += 1
                if self._should_stop(req):
                    self._finish(req)
                    events["finished"].append(req.rid)
        events["active"] = len(self._running)
        self.step_count += 1
        return events

    def reset(self) -> None:
        """Clear host-side counters/results between workloads while
        keeping the expensive state (packed store, compiled prefill/
        seat/decode, device cache) — stale KV lanes are harmless by the
        slot-reuse invariant.  Refuses with work in flight."""
        if self._queue or self._running:
            raise RuntimeError("reset() with requests queued or running")
        self._done = {}
        self.step_count = 0
        self.decode_steps = 0
        self.decoded_tokens = 0

    def run(self, max_steps: int = 100_000) -> Dict[int, List[int]]:
        """Drive step() until queue and slots drain; returns harvest()."""
        steps = 0
        while (self._queue or self._running) and steps < max_steps:
            self.step()
            steps += 1
        if self._queue or self._running:
            raise RuntimeError(f"engine did not drain in {max_steps} steps")
        return self.harvest()

    @property
    def finished_requests(self) -> List[Request]:
        """Finished Request objects (submit/finish step stamps intact);
        does not pop — harvest() does."""
        return list(self._done.values())

    def harvest(self) -> Dict[int, List[int]]:
        """Pop finished requests: {rid: generated token ids}."""
        out = {rid: req.tokens for rid, req in self._done.items()}
        self._done = {}
        return out

    # -- introspection ------------------------------------------------------

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    @property
    def n_running(self) -> int:
        return len(self._running)

    def hbm_report(self) -> Optional[dict]:
        """Actual packed-weight HBM bytes (None when serving dense)."""
        return self.store.report() if self.store is not None else None

    def stats(self) -> dict:
        return {
            "steps": self.step_count,
            "decode_steps": self.decode_steps,
            "decoded_tokens": self.decoded_tokens,
            "n_slots": self.serve_cfg.n_slots,
            "queued": self.n_queued,
            "running": self.n_running,
        }
