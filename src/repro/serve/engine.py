"""Request lifecycle manager: submit / step / harvest.

The engine fronts a ContinuousBatcher with a FIFO admission queue and
per-request stop conditions (max_new_tokens, optional EOS token, KV
capacity).  One ``step()`` = admit as many queued requests as there are
free slots (each costs one fixed-shape prefill + seat), then one
batched decode step for every lane; finished requests evict their slot
immediately, so a queued request can join on the very next step —
continuous batching, not static batching.

With ``packed=True`` the engine serves from an element-mode
PackedParamStore: decode matmuls consume compact (vals, idx) tensors
through kernels/nm_spmm at ~N/M of the dense weight HBM bytes
(``engine.hbm_report()`` gives the actual numbers).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

from repro.core.sparsity import DENSE, SparsityConfig
from repro.serve.batcher import ContinuousBatcher
from repro.serve.cache_store import CacheStore, Lane, prefix_chain
from repro.serve.packed_params import PackedParamStore


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static engine shape — fixes the one-and-only compiled step."""

    n_slots: int = 4          # concurrent requests (KV lanes)
    max_len: int = 96         # per-slot KV depth (prompt + generation)
    prompt_bucket: int = 32   # prompts right-padded to this length
    eos_token: Optional[int] = None  # engine-wide default stop token
    packed: bool = False      # serve from element-packed N:M weights
    idx_bits: Optional[int] = None   # stored index width for the packed
    # store: 4 (u4, two offsets/byte), 8 (byte-wide), or None to pick
    # automatically (u4 whenever M <= 16 — packed_params.default_idx_bits)
    prefix_cache: int = 0     # lanes pooled for prefix/KV reuse (0 = off):
    # an admission whose prompt-bucket hash chain matches a pooled lane
    # seats that lane instead of prefilling (serve/cache_store.py)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    eos: Optional[int]
    state: str = "queued"             # queued | running | done
    slot: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    submit_step: int = 0
    finish_step: int = 0

    @property
    def finish_reason(self) -> str:
        if self.eos is not None and self.tokens and self.tokens[-1] == self.eos:
            return "eos"
        return "length"


class ServeEngine:
    """Continuous-batching greedy-decode engine over N:M-sparse weights."""

    def __init__(self, params, cfg, sp_cfg: SparsityConfig = DENSE,
                 serve_cfg: Optional[ServeConfig] = None, *, mesh=None,
                 cache_dtype=None):
        import jax.numpy as jnp

        serve_cfg = serve_cfg if serve_cfg is not None else ServeConfig()
        self.cfg = cfg
        self.sp_cfg = sp_cfg
        self.serve_cfg = serve_cfg
        self.mesh = mesh
        self.store: Optional[PackedParamStore] = None
        if serve_cfg.packed:
            self.store = PackedParamStore.pack(params, sp_cfg,
                                               idx_bits=serve_cfg.idx_bits)
            params = self.store.params
        shardings = None
        if mesh is not None and mesh.devices.size > 1:
            # SPMD serving: resolve SERVE_BATCH-rule shardings (weights
            # TP over "model" with N:M groups unsplit, slot lanes over
            # the DP axes) and pin the engine's residents to them.
            from repro.launch import spmd
            shardings = spmd.serve_shardings(
                cfg, mesh, sp_cfg, n_slots=serve_cfg.n_slots,
                max_len=serve_cfg.max_len, packed=serve_cfg.packed,
                idx_bits=serve_cfg.idx_bits,
                cache_dtype=cache_dtype or jnp.bfloat16)
        self.batcher = ContinuousBatcher(
            params, cfg, sp_cfg,
            n_slots=serve_cfg.n_slots, max_len=serve_cfg.max_len,
            prompt_bucket=serve_cfg.prompt_bucket,
            cache_dtype=cache_dtype or jnp.bfloat16, mesh=mesh,
            shardings=shardings)
        self._queue: deque[Request] = deque()
        self._lane_queue: deque = deque()        # (Request, Lane) handoffs
        self._running: Dict[int, Request] = {}   # slot -> request
        self._done: Dict[int, Request] = {}      # rid -> request
        self._next_rid = 0
        self.step_count = 0
        self.decode_steps = 0
        self.decoded_tokens = 0   # harvested from active lanes only
        self.prefix_pool: Optional[CacheStore] = (
            CacheStore(serve_cfg.prefix_cache)
            if serve_cfg.prefix_cache > 0 else None)

    # -- lifecycle ----------------------------------------------------------

    def validate(self, prompt, max_new_tokens: int) -> List[int]:
        """Check a request against the static engine shape: the prompt
        must fit the prefill bucket and prompt+generation must fit a KV
        lane.  Returns the normalized prompt (fleet frontends call this
        at their own submit time so a bad request fails at the caller,
        not inside a later fleet step)."""
        prompt = [int(t) for t in prompt]
        sc = self.serve_cfg
        if not 0 < len(prompt) <= sc.prompt_bucket:
            raise ValueError(f"prompt length {len(prompt)} not in "
                             f"(0, {sc.prompt_bucket}]")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if len(prompt) + max_new_tokens > sc.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds per-slot KV capacity {sc.max_len}")
        return prompt

    def submit(self, prompt, max_new_tokens: int = 16,
               eos: Optional[int] = None) -> int:
        """Queue a request; returns its rid.  Admission happens in step()."""
        prompt = self.validate(prompt, max_new_tokens)
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, max_new_tokens=max_new_tokens,
                      eos=eos if eos is not None else self.serve_cfg.eos_token,
                      submit_step=self.step_count)
        self._queue.append(req)
        return rid

    def submit_lane(self, lane: Lane, max_new_tokens: int = 16,
                    eos: Optional[int] = None, *, prompt=(),
                    tokens=None) -> int:
        """Queue an already-prefilled lane (the decode half of
        prefill/decode disaggregation): the lane's KV is seated into a
        free slot at the next step() — no prefill here, ever.

        ``tokens`` are the tokens already generated for this request
        upstream (at least the prefill's first token); they count
        against ``max_new_tokens``.
        """
        tokens = [int(t) for t in (tokens if tokens is not None
                                   else [lane.next_token])]
        if not tokens:
            raise ValueError("a handed-off lane carries >= 1 token")
        if max_new_tokens < len(tokens):
            raise ValueError(f"lane already holds {len(tokens)} tokens, "
                             f"max_new_tokens={max_new_tokens}")
        if lane.pos + (max_new_tokens - len(tokens)) + 1 > self.serve_cfg.max_len:
            raise ValueError(
                f"lane pos ({lane.pos}) + remaining tokens exceeds "
                f"per-slot KV capacity {self.serve_cfg.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=[int(t) for t in prompt],
                      max_new_tokens=max_new_tokens,
                      eos=eos if eos is not None else self.serve_cfg.eos_token,
                      submit_step=self.step_count, tokens=tokens)
        self._lane_queue.append((req, lane))
        return rid

    def _should_stop(self, req: Request) -> bool:
        if len(req.tokens) >= req.max_new_tokens:
            return True
        if req.eos is not None and req.tokens and req.tokens[-1] == req.eos:
            return True
        return False

    def _finish(self, req: Request) -> None:
        req.state = "done"
        req.finish_step = self.step_count
        self.batcher.evict(req.slot)
        del self._running[req.slot]
        self._done[req.rid] = req

    def step(self) -> dict:
        """Admit from the queue, decode one token for every active slot.

        Returns an event dict: {"admitted": [rid], "finished": [rid],
        "active": n_running_after}.
        """
        events = {"admitted": [], "finished": [], "active": 0}
        # 1a. lane admission first: handed-off lanes already paid their
        # prefill upstream — seat them before spending prefills here
        while self._lane_queue and self.batcher.kv.n_free > 0:
            req, lane = self._lane_queue.popleft()
            req.slot = self.batcher.seat_lane(lane)
            req.state = "running"
            self._running[req.slot] = req
            events["admitted"].append(req.rid)
            if self._should_stop(req):
                self._finish(req)
                events["finished"].append(req.rid)
        # 1b. admission: queued requests join mid-flight into free slots
        # (a prefix-pool hit seats the pooled lane and skips the prefill)
        while self._queue and self.batcher.kv.n_free > 0:
            req = self._queue.popleft()
            lane = None
            if self.prefix_pool is not None:
                chain = prefix_chain(req.prompt,
                                     self.serve_cfg.prompt_bucket)
                lane = self.prefix_pool.get(chain)
                if lane is None:
                    lane = self.batcher.prefill(req.prompt, key=chain)
                    self.prefix_pool.put(lane)
            else:
                lane = self.batcher.prefill(req.prompt)
            req.slot = self.batcher.seat_lane(lane)
            req.state = "running"
            req.tokens.append(lane.next_token)
            self._running[req.slot] = req
            self.decoded_tokens += 1
            events["admitted"].append(req.rid)
            if self._should_stop(req):   # e.g. max_new_tokens == 1
                self._finish(req)
                events["finished"].append(req.rid)
        # 2. one batched decode step (all lanes; free lanes are garbage)
        if self._running:
            nxt = self.batcher.step()
            self.decode_steps += 1
            for slot, req in list(self._running.items()):
                tok = int(nxt[slot])
                req.tokens.append(tok)
                self.decoded_tokens += 1
                if self._should_stop(req):
                    self._finish(req)
                    events["finished"].append(req.rid)
        events["active"] = len(self._running)
        self.step_count += 1
        return events

    def reset(self) -> None:
        """Clear host-side counters/results between workloads while
        keeping the expensive state (packed store, compiled prefill/
        seat/decode, device cache, prefix pool) — stale KV lanes are
        harmless by the slot-reuse invariant.  Refuses with work in
        flight."""
        if self._queue or self._lane_queue or self._running:
            raise RuntimeError("reset() with requests queued or running")
        self._done = {}
        self.step_count = 0
        self.decode_steps = 0
        self.decoded_tokens = 0
        self.batcher.prefill_calls = 0

    def run(self, max_steps: int = 100_000) -> Dict[int, List[int]]:
        """Drive step() until queue and slots drain; returns harvest()."""
        steps = 0
        while ((self._queue or self._lane_queue or self._running)
               and steps < max_steps):
            self.step()
            steps += 1
        if self._queue or self._lane_queue or self._running:
            raise RuntimeError(f"engine did not drain in {max_steps} steps")
        return self.harvest()

    @property
    def finished_requests(self) -> List[Request]:
        """Finished Request objects (submit/finish step stamps intact);
        does not pop — harvest() does."""
        return list(self._done.values())

    def harvest(self) -> Dict[int, List[int]]:
        """Pop finished requests: {rid: generated token ids}."""
        out = {rid: req.tokens for rid, req in self._done.items()}
        self._done = {}
        return out

    # -- fleet hooks --------------------------------------------------------

    def prefill_to_lane(self, prompt, max_new_tokens: int = 16) -> Lane:
        """Dedicated-prefill-engine entry point: run prefill (or hit the
        prefix pool) and return the seatable Lane WITHOUT occupying one
        of this engine's slots — the fleet hands the lane to a decode
        engine through a CacheStore."""
        prompt = self.validate(prompt, max_new_tokens)
        chain = prefix_chain(prompt, self.serve_cfg.prompt_bucket)
        if self.prefix_pool is not None:
            lane = self.prefix_pool.get(chain)
            if lane is not None:
                return lane
        lane = self.batcher.prefill(prompt, key=chain)
        if self.prefix_pool is not None:
            self.prefix_pool.put(lane)
        return lane

    def export_lane(self, rid: int) -> Lane:
        """Freeze a RUNNING request's live KV lane into a batch-1 Lane
        (cache slice + next token + position) and release its slot; the
        request is detached from this engine.  Seating the lane on
        another engine (``submit_lane``) continues the token stream
        bitwise-identically."""
        req = next((r for r in self._running.values() if r.rid == rid),
                   None)
        if req is None:
            raise KeyError(f"rid {rid} is not running on this engine")
        lane = self.batcher.export_lane(req.slot)
        self.batcher.evict(req.slot)
        del self._running[req.slot]
        req.slot, req.state = None, "exported"
        return lane

    def prefix_match_depth(self, chain) -> int:
        """How many leading prompt blocks of ``chain`` this engine's
        prefix pool already holds — the router's KV-affinity signal."""
        return (self.prefix_pool.match_depth(chain)
                if self.prefix_pool is not None else 0)

    def utilization(self) -> dict:
        """Live occupancy snapshot the fleet scheduler routes on."""
        n = self.serve_cfg.n_slots
        queued = len(self._queue) + len(self._lane_queue)
        return {"n_slots": n, "running": len(self._running),
                "queued": queued, "free_slots": self.batcher.kv.n_free,
                "load": (len(self._running) + queued) / n}

    # -- introspection ------------------------------------------------------

    @property
    def n_queued(self) -> int:
        return len(self._queue) + len(self._lane_queue)

    @property
    def n_running(self) -> int:
        return len(self._running)

    @property
    def prefill_steps(self) -> int:
        """Compiled-prefill invocations since construction/reset —
        prefix-pool hits make this smaller than the admission count."""
        return self.batcher.prefill_calls

    def hbm_report(self) -> Optional[dict]:
        """Actual packed-weight HBM bytes (None when serving dense)."""
        return self.store.report() if self.store is not None else None

    def stats(self) -> dict:
        out = {
            "steps": self.step_count,
            "decode_steps": self.decode_steps,
            "decoded_tokens": self.decoded_tokens,
            "prefill_steps": self.prefill_steps,
            "n_slots": self.serve_cfg.n_slots,
            "queued": self.n_queued,
            "running": self.n_running,
        }
        if self.prefix_pool is not None:
            out["prefix_pool"] = self.prefix_pool.stats()
        return out
