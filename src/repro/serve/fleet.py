"""Serve fleet: one frontend, N engine replicas, KV-aware routing.

One ``ServeEngine`` on one mesh is not "millions of users".  This layer
puts a single admission queue in front of N engine replicas and routes
each request with the scheduler the paper's dataflow argument implies:
compute savings only become wall-clock savings when the *scheduler*
places work where the state already is (arXiv 2309.13015 §Dataflow —
interleave mapping + offline scheduling; here, the state is KV).

Routing policy (``FleetConfig.router``):

  * ``"prefix"`` (default) — the KvCacheManager pattern: hash the
    prompt's prefix blocks at ``prompt_bucket`` granularity
    (serve/cache_store.prefix_chain) and prefer the replica whose
    prefix pool holds the longest matching chain — its admission seats
    the pooled lane and skips the prefill entirely.  Ties (and depth 0)
    fall back to least-loaded; a holder whose backlog exceeds the
    fleet's ``balance_slack`` is overruled by load (cache affinity must
    not starve the rest of the fleet).
  * ``"least_loaded"`` — pure live-utilization routing.
  * ``"random"`` — seeded uniform routing; the bench's control arm.

Disaggregated mode (``FleetConfig.disaggregate=True``) splits the two
phases onto dedicated engine pools: prefill engines run prefill (+ the
prefix pool) and publish finished KV lanes into a ``CacheStore``; the
frontend then routes each lane to a least-loaded decode engine, which
seats it (``submit_lane``) and decodes.  The handoff is bitwise
invisible: a disaggregated request's token stream equals the colocated
single-engine stream (pinned by tests/test_fleet.py and measured by
benchmarks/fleet_bench.py).

``AsyncFrontend`` wraps the fleet in an asyncio event loop: concurrent
``generate()`` coroutines share the queue and a single driver task
steps the fleet until their futures resolve.
"""

from __future__ import annotations

import asyncio
import dataclasses
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.core.sparsity import DENSE, SparsityConfig
from repro.serve.cache_store import CacheStore, prefix_chain
from repro.serve.engine import ServeConfig, ServeEngine

ROUTERS = ("prefix", "least_loaded", "random")


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    n_replicas: int = 2       # decode-capable engine replicas
    router: str = "prefix"    # "prefix" | "least_loaded" | "random"
    route_seed: int = 0       # rng seed for the "random" control arm
    prefix_cache: int = 8     # per-engine lane pool capacity (0 = off;
    #                           "prefix" routing needs it > 0)
    balance_slack: int = 0    # extra backlog (beyond the least-loaded
    # replica's, in requests) a prefix holder may carry before load
    # overrules affinity; 0 = overrule as soon as the holder is busier
    # by a full slot-count than the emptiest replica
    disaggregate: bool = False
    n_prefill: int = 1        # dedicated prefill engines (disagg mode)

    def __post_init__(self):
        if self.n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        if self.router not in ROUTERS:
            raise ValueError(f"router {self.router!r} not in {ROUTERS}")
        if self.disaggregate and self.n_prefill < 1:
            raise ValueError("disaggregate mode needs n_prefill >= 1")


@dataclasses.dataclass
class FleetRequest:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    eos: Optional[int]
    state: str = "queued"          # queued | prefilling | running | done
    replica: Optional[int] = None  # decode engine index
    engine_rid: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    submit_step: int = 0
    finish_step: int = 0
    prefix_hit: bool = False       # admission reused a pooled lane


class Router:
    """Pick a replica for (chain, live utilization) under one policy."""

    def __init__(self, policy: str, seed: int = 0, balance_slack: int = 0):
        if policy not in ROUTERS:
            raise ValueError(f"router {policy!r} not in {ROUTERS}")
        self.policy = policy
        self.balance_slack = balance_slack
        self._rng = np.random.default_rng(seed)
        self.by_depth: Dict[int, int] = {}   # match depth -> decisions

    def choose(self, engines: List[ServeEngine], chain) -> int:
        loads = [e.utilization() for e in engines]
        # backlog in requests (running + queued) — comparable across
        # replicas of equal slot count, robust when counts differ
        backlog = [u["running"] + u["queued"] for u in loads]
        if self.policy == "random":
            pick = int(self._rng.integers(len(engines)))
            self.by_depth[0] = self.by_depth.get(0, 0) + 1
            return pick
        least = min(range(len(engines)), key=lambda i: (backlog[i], i))
        if self.policy == "least_loaded":
            self.by_depth[0] = self.by_depth.get(0, 0) + 1
            return least
        depths = [e.prefix_match_depth(chain) for e in engines]
        best = max(depths)
        pick = least
        if best > 0:
            # deepest match, least-loaded among equals
            pick = min((i for i in range(len(engines))
                        if depths[i] == best),
                       key=lambda i: (backlog[i], i))
            # affinity yields to load once the holder's backlog exceeds
            # the emptiest replica's by a slot-count (+ slack): a hit
            # saves one prefill, not a queue's worth of decode steps
            limit = (backlog[least] + loads[pick]["n_slots"]
                     + self.balance_slack)
            if backlog[pick] > limit:
                pick, best = least, 0
        self.by_depth[best] = self.by_depth.get(best, 0) + 1
        return pick


class ServeFleet:
    """Single-queue frontend over N continuous-batching replicas."""

    def __init__(self, params, cfg, sp_cfg: SparsityConfig = DENSE,
                 serve_cfg: Optional[ServeConfig] = None,
                 fleet_cfg: Optional[FleetConfig] = None, *,
                 meshes=None, cache_dtype=None):
        serve_cfg = serve_cfg if serve_cfg is not None else ServeConfig()
        fleet_cfg = fleet_cfg if fleet_cfg is not None else FleetConfig()
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self.fleet_cfg = fleet_cfg
        scfg = dataclasses.replace(serve_cfg,
                                   prefix_cache=fleet_cfg.prefix_cache)
        if meshes is not None and len(meshes) != fleet_cfg.n_replicas:
            raise ValueError(f"{len(meshes)} meshes for "
                             f"{fleet_cfg.n_replicas} replicas")

        def mesh_for(i):
            return meshes[i] if meshes is not None else None

        # decode-capable replicas.  In disaggregated mode their prefix
        # pools are idle (lanes arrive seated); the pools live on the
        # prefill engines instead, so pass prefix_cache=0 to the
        # decode side to keep its admission path prefill-free.
        decode_cfg = (dataclasses.replace(scfg, prefix_cache=0)
                      if fleet_cfg.disaggregate else scfg)
        self.engines = [ServeEngine(params, cfg, sp_cfg, decode_cfg,
                                    mesh=mesh_for(i),
                                    cache_dtype=cache_dtype)
                        for i in range(fleet_cfg.n_replicas)]
        self.prefill_engines: List[ServeEngine] = []
        if fleet_cfg.disaggregate:
            self.prefill_engines = [
                ServeEngine(params, cfg, sp_cfg, scfg,
                            cache_dtype=cache_dtype)
                for _ in range(fleet_cfg.n_prefill)]
        self.router = Router(fleet_cfg.router, fleet_cfg.route_seed,
                             fleet_cfg.balance_slack)
        # prefill engines are routed by prefix affinity too; decode
        # placement of a handed-off lane is pure load balancing
        self.prefill_router = Router(
            "prefix" if fleet_cfg.router == "prefix" else fleet_cfg.router,
            fleet_cfg.route_seed, fleet_cfg.balance_slack)
        self.store = CacheStore(capacity=max(
            8, fleet_cfg.n_replicas * serve_cfg.n_slots * 2))
        self._queue: deque[FleetRequest] = deque()
        self._handoff: deque[FleetRequest] = deque()  # lanes in the store
        self._inflight: Dict[tuple, FleetRequest] = {}  # (replica, erid)
        self._done: Dict[int, FleetRequest] = {}
        self._next_rid = 0
        self.step_count = 0

    # -- lifecycle ----------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int = 16,
               eos: Optional[int] = None) -> int:
        """Queue a request on the fleet-wide admission queue."""
        probe = (self.prefill_engines or self.engines)[0]
        prompt = probe.validate(prompt, max_new_tokens)
        rid = self._next_rid
        self._next_rid += 1
        req = FleetRequest(rid=rid, prompt=prompt,
                           max_new_tokens=max_new_tokens,
                           eos=(eos if eos is not None
                                else self.serve_cfg.eos_token),
                           submit_step=self.step_count)
        self._queue.append(req)
        return rid

    def _finish(self, req: FleetRequest, tokens: List[int]) -> None:
        req.tokens = list(tokens)
        req.state = "done"
        req.finish_step = self.step_count
        self._done[req.rid] = req

    @staticmethod
    def _has_room(engines: List[ServeEngine]) -> bool:
        """Some replica could seat new work within a step or two.  The
        frontend holds the rest of the queue back: routing a request the
        moment a slot frees lets the decision see every prefix pool and
        utilization update from the steps in between — dispatching the
        whole queue up front would route against stale (empty) state."""
        return any(e.n_running + e.n_queued < e.serve_cfg.n_slots
                   for e in engines)

    def _dispatch_colocated(self) -> None:
        while self._queue and self._has_room(self.engines):
            req = self._queue.popleft()
            chain = prefix_chain(req.prompt, self.serve_cfg.prompt_bucket)
            i = self.router.choose(self.engines, chain)
            eng = self.engines[i]
            req.prefix_hit = eng.prefix_match_depth(chain) >= len(chain)
            req.replica = i
            req.engine_rid = eng.submit(req.prompt, req.max_new_tokens,
                                        eos=req.eos)
            req.state = "running"
            self._inflight[(i, req.engine_rid)] = req

    def _dispatch_disaggregated(self) -> None:
        # phase 1: prefill — each prefill engine runs at most n_slots
        # prefills per fleet step (its own admission-loop width), then
        # publishes the lane into the CacheStore
        budget = {j: self.prefill_engines[j].serve_cfg.n_slots
                  for j in range(len(self.prefill_engines))}
        # never outrun the handoff store: an LRU-evicted handoff lane
        # would be lost, so prefill stalls at store capacity instead
        while (self._queue and any(budget.values())
               and len(self.store) < self.store.capacity):
            req = self._queue.popleft()
            chain = prefix_chain(req.prompt, self.serve_cfg.prompt_bucket)
            j = self.prefill_router.choose(
                [self.prefill_engines[k] for k in budget if budget[k]],
                chain)
            j = [k for k in budget if budget[k]][j]
            peng = self.prefill_engines[j]
            req.prefix_hit = peng.prefix_match_depth(chain) >= len(chain)
            budget[j] -= 1
            lane = peng.prefill_to_lane(req.prompt, req.max_new_tokens)
            first = lane.next_token
            req.tokens = [first]
            if (req.max_new_tokens == 1
                    or (req.eos is not None and first == req.eos)):
                self._finish(req, req.tokens)   # never reaches decode
                continue
            # republish under the request id: the handoff key must be
            # unique per request even when prompts (and chains) repeat
            lane = dataclasses.replace(lane, key=("rid", req.rid))
            self.store.put(lane)
            req.state = "prefilling"
            self._handoff.append(req)
        # phase 2: route finished lanes to decode engines (pure load).
        # With every decode replica saturated the lanes stay parked in
        # the store — prefill keeps running ahead; that buffering IS the
        # point of disaggregating the two phases
        while self._handoff and self._has_room(self.engines):
            req = self._handoff.popleft()
            lane = self.store.pop(("rid", req.rid))
            if lane is None:
                raise RuntimeError(f"lane for rid {req.rid} lost from "
                                   f"the cache store")
            i = self.router.choose(self.engines, ())
            eng = self.engines[i]
            req.replica = i
            req.engine_rid = eng.submit_lane(
                lane, req.max_new_tokens, eos=req.eos,
                prompt=req.prompt, tokens=req.tokens)
            req.state = "running"
            self._inflight[(i, req.engine_rid)] = req

    def step(self) -> dict:
        """Route everything queued, then step every decode replica once.

        Returns {"dispatched": n, "finished": [fleet rids], "active": n}.
        """
        events = {"dispatched": 0, "finished": [], "active": 0}
        n_q = len(self._queue)
        if self.fleet_cfg.disaggregate:
            self._dispatch_disaggregated()
        else:
            self._dispatch_colocated()
        events["dispatched"] = n_q - len(self._queue)
        for i, eng in enumerate(self.engines):
            if eng.n_running or eng.n_queued:
                eng.step()
            for erid, toks in eng.harvest().items():
                req = self._inflight.pop((i, erid))
                self._finish(req, toks)
                events["finished"].append(req.rid)
        events["active"] = sum(e.n_running + e.n_queued
                               for e in self.engines) + len(self._queue)
        self.step_count += 1
        return events

    def run(self, max_steps: int = 100_000) -> Dict[int, List[int]]:
        """Drive step() until every submitted request finished."""
        steps = 0
        while (self._queue or self._handoff or self._inflight) \
                and steps < max_steps:
            self.step()
            steps += 1
        if self._queue or self._handoff or self._inflight:
            raise RuntimeError(f"fleet did not drain in {max_steps} steps")
        return self.harvest()

    @property
    def finished_requests(self) -> List[FleetRequest]:
        return list(self._done.values())

    def harvest(self) -> Dict[int, List[int]]:
        out = {rid: req.tokens for rid, req in self._done.items()}
        self._done = {}
        return out

    # -- introspection ------------------------------------------------------

    @property
    def n_pending(self) -> int:
        return len(self._queue) + len(self._handoff) + len(self._inflight)

    def stats(self) -> dict:
        return {
            "steps": self.step_count,
            "router": self.fleet_cfg.router,
            "routed_by_depth": dict(self.router.by_depth),
            "prefill_steps": sum(
                e.prefill_steps
                for e in self.engines + self.prefill_engines),
            "decode_steps": sum(e.decode_steps for e in self.engines),
            "engines": [e.stats() for e in self.engines],
            "prefill_engines": [e.stats() for e in self.prefill_engines],
            "store": self.store.stats(),
        }


class AsyncFrontend:
    """Asyncio face of the fleet: concurrent ``generate()`` coroutines
    feed the shared queue; one lazily-started driver task steps the
    fleet while anything is pending and resolves per-request futures."""

    def __init__(self, fleet: ServeFleet):
        self.fleet = fleet
        self._pending: Dict[int, asyncio.Future] = {}
        self._driver: Optional[asyncio.Task] = None

    async def generate(self, prompt, max_new_tokens: int = 16,
                       eos: Optional[int] = None) -> List[int]:
        rid = self.fleet.submit(prompt, max_new_tokens, eos=eos)
        fut = asyncio.get_running_loop().create_future()
        self._pending[rid] = fut
        if self._driver is None or self._driver.done():
            self._driver = asyncio.get_running_loop().create_task(
                self._drive())
        return await fut

    async def _drive(self) -> None:
        while self._pending:
            self.fleet.step()
            for rid, toks in self.fleet.harvest().items():
                fut = self._pending.pop(rid, None)
                if fut is not None and not fut.done():
                    fut.set_result(toks)
            # yield so freshly-submitted generate() calls join the queue
            # between fleet steps
            await asyncio.sleep(0)
