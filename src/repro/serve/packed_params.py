"""Element-mode packed parameter store — serve from compact (vals, idx).

The paper's inference-side dataflow (Fig. 11c): after BDWP training the
FF weights are N:M sparse, so serving never needs the dense tensors.
Each eligible weight ``w (…, K, F)`` is SORE-packed along the FF
contraction axis into

    vals (…, K·N/M, F)   — surviving values, weight dtype
    idx  (…, K·N/M, F)   — uint8 within-group offsets (0..M-1)

and each eligible leaf becomes an ``operand.PackedOp`` — the decode
matmuls consume the pair directly through ``nm_apply`` -> ``kernels/
nm_spmm`` (Pallas on TPU, oracle elsewhere): weights stream from HBM at
~N/M of the dense bytes instead of being re-masked dense.

Element mode keeps the paper-faithful per-column patterns (exactly the
mask BDWP trained with), unlike ``bdwp.pack_tree_shared`` whose shared
patterns change values.  ``PackedParamStore`` also reports the *actual*
HBM bytes of the packed tree vs. its dense equivalent.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import bdwp
from repro.core import operand as O
from repro.core.sparsity import SparsityConfig, nm_pack


def _leaf_bytes(x) -> int:
    return int(x.size) * jnp.dtype(x.dtype).itemsize


def pack_tree_element(params, cfg: SparsityConfig, pspecs=None):
    """Transform a param tree for element-mode packed serving.

    Every eligible ``{"w": (…, K, F)}`` leaf-dict (same FF-direction
    eligibility as shared packing: ``bdwp.serve_packable``) becomes
    ``{"w": operand.PackedOp(vals, idx)(, "b")}`` — the bias and the
    leaf-dict shape survive, only the weight leaf changes type; stacked
    (L, K, F) weights pack per layer.  Returns ``(packed_tree, stats)``
    where stats counts actual bytes.

    With ``pspecs`` (matching tree of resolved PartitionSpecs) given,
    returns ``(packed_tree, stats, packed_pspecs)``: vals and idx are
    rank-preserving (both (…, K·N/M, F)) so they inherit w's spec.  The
    N:M group invariant transfers: a K shard that is a multiple of M
    packs to a compact shard that is a multiple of N, so specs resolved
    through ``rules.nm_params_pspecs`` stay group-safe after packing
    (``rules.assert_nm_unsplit`` re-checks the packed tree).
    """
    stats = {"n_packed": 0, "n_dense": 0,
             "packed_bytes": 0,      # vals + uint8 idx as stored
             "packed_bytes_4bit": 0,  # vals + ceil(log2 M)-bit idx (SORE)
             "dense_bytes": 0,       # dense bytes of the packed leaves
             "other_bytes": 0}       # leaves kept dense
    idx_bits = max(1, math.ceil(math.log2(cfg.m)))

    def pack_ok(name, w) -> bool:
        # Parity with the masked forward is the invariant: pack a weight
        # only if training/masked decode FF-sparsifies it too — i.e. the
        # method prunes FF weights at all, dense_apply's pick_cfg selects
        # this weight (should_prune: name exclusions AND divisibility of
        # every grouped axis, K and F for bdwp), and it is FF-servable
        # (serve_packable: 2-D tail, no lm_head/k_up/v_up).  A weight
        # that trains dense must serve dense.
        return (cfg.prunes_ff_weights()
                and bdwp.should_prune(name, tuple(w.shape[-2:]), cfg)
                and bdwp.serve_packable(name, tuple(w.shape[-2:]), cfg))

    def walk(node, spec_node, path):
        if isinstance(node, dict) and "w" in node:
            w = node["w"]
            name = "/".join(str(k) for k in path)
            if pack_ok(name, w):
                if isinstance(w, jax.ShapeDtypeStruct):
                    vals, idx = jax.eval_shape(
                        lambda ww: nm_pack(ww, cfg.n, cfg.m,
                                           axis=ww.ndim - 2), w)
                else:
                    vals, idx = nm_pack(w, cfg.n, cfg.m, axis=w.ndim - 2)
                new = {"w": O.PackedOp(vals, idx, cfg)}
                stats["n_packed"] += 1
                stats["dense_bytes"] += _leaf_bytes(w)
                stats["packed_bytes"] += _leaf_bytes(vals) + _leaf_bytes(idx)
                stats["packed_bytes_4bit"] += (
                    _leaf_bytes(vals) + int(idx.size) * idx_bits // 8)
                new_spec = None
                if spec_node is not None:
                    # vals and idx are rank-preserving: both keep w's spec
                    new_spec = {"w": O.PackedOp(spec_node["w"],
                                                spec_node["w"], cfg)}
                if "b" in node:
                    new["b"] = node["b"]
                    stats["other_bytes"] += _leaf_bytes(node["b"])
                    if new_spec is not None:
                        new_spec["b"] = spec_node["b"]
                return new, new_spec
            stats["n_dense"] += 1
            stats["other_bytes"] += sum(_leaf_bytes(x)
                                        for x in jax.tree.leaves(node))
            return node, spec_node
        if isinstance(node, dict):
            out_p, out_s = {}, {}
            for k, v in node.items():
                sp = spec_node[k] if spec_node is not None else None
                out_p[k], s = walk(v, sp, path + (k,))
                if spec_node is not None:
                    out_s[k] = s
            return out_p, (out_s if spec_node is not None else None)
        stats["other_bytes"] += _leaf_bytes(node)
        return node, spec_node

    packed, packed_specs = walk(params, pspecs, ())
    if pspecs is not None:
        return packed, stats, packed_specs
    return packed, stats


@dataclasses.dataclass
class PackedParamStore:
    """Packed weights + byte accounting; ``.params`` plugs into forward().

    ``models.layers.dense_apply`` consumes the ``operand.PackedOp``
    leaves through ``nm_apply`` -> the nm_spmm kernel, so the whole
    model runs from the compact representation without any model-code
    changes.
    """

    params: dict
    sp_cfg: SparsityConfig
    n_packed: int
    n_dense: int
    packed_bytes: int        # stored bytes of packed leaves (uint8 idx)
    packed_bytes_4bit: int   # with ceil(log2 M)-bit indices (SORE format)
    dense_bytes: int         # dense-equivalent bytes of the packed leaves
    other_bytes: int         # leaves served dense (embeds, norms, head)

    @classmethod
    def pack(cls, params, sp_cfg: SparsityConfig) -> "PackedParamStore":
        packed, st = pack_tree_element(params, sp_cfg)
        return cls(params=packed, sp_cfg=sp_cfg,
                   n_packed=st["n_packed"], n_dense=st["n_dense"],
                   packed_bytes=st["packed_bytes"],
                   packed_bytes_4bit=st["packed_bytes_4bit"],
                   dense_bytes=st["dense_bytes"],
                   other_bytes=st["other_bytes"])

    @property
    def hbm_saving(self) -> float:
        """Dense/packed byte ratio over the packable weights."""
        return self.dense_bytes / max(self.packed_bytes, 1)

    @property
    def total_bytes(self) -> int:
        return self.packed_bytes + self.other_bytes

    def report(self) -> dict:
        return {
            "n_packed": self.n_packed,
            "n_dense": self.n_dense,
            "n": self.sp_cfg.n, "m": self.sp_cfg.m,
            "packed_weight_bytes": self.packed_bytes,
            "packed_weight_bytes_4bit_idx": self.packed_bytes_4bit,
            "dense_weight_bytes": self.dense_bytes,
            "other_param_bytes": self.other_bytes,
            "hbm_saving": self.hbm_saving,
            "total_hbm_bytes": self.total_bytes,
            "total_hbm_bytes_dense": self.dense_bytes + self.other_bytes,
        }
