"""Element-mode packed parameter store — serve from compact (vals, idx).

The paper's inference-side dataflow (Fig. 11c): after BDWP training the
FF weights are N:M sparse, so serving never needs the dense tensors.
Each eligible weight ``w (…, K, F)`` is SORE-packed along the FF
contraction axis into

    vals (…, K·N/M, F)   — surviving values, weight dtype
    idx  (…, K·N/M, F)   — uint8 within-group offsets (0..M-1)

and each eligible leaf becomes an ``operand.PackedOp`` — the decode
matmuls consume the pair directly through ``nm_apply`` -> ``kernels/
nm_spmm`` (Pallas on TPU, oracle elsewhere): weights stream from HBM at
~N/M of the dense bytes instead of being re-masked dense.

Element mode keeps the paper-faithful per-column patterns (exactly the
mask BDWP trained with), unlike ``bdwp.pack_tree_shared`` whose shared
patterns change values.  ``PackedParamStore`` also reports the *actual*
HBM bytes of the packed tree vs. its dense equivalent.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import bdwp
from repro.core import operand as O
from repro.core.sparsity import SparsityConfig, nm_pack, pack_idx_u4


def _leaf_bytes(x) -> int:
    return int(x.size) * jnp.dtype(x.dtype).itemsize


def default_idx_bits(cfg: SparsityConfig) -> int:
    """Stored index width for a config: 4 whenever the in-group offset
    fits a nibble (M <= 16 — every paper config), else byte-wide."""
    return 4 if cfg.m <= 16 else 8


def pack_tree_element(params, cfg: SparsityConfig, pspecs=None,
                      idx_bits: Optional[int] = None):
    """Transform a param tree for element-mode packed serving.

    Every eligible ``{"w": (…, K, F)}`` leaf-dict (same FF-direction
    eligibility as shared packing: ``bdwp.serve_packable``) becomes
    ``{"w": operand.PackedOp(vals, idx)(, "b")}`` — the bias and the
    leaf-dict shape survive, only the weight leaf changes type; stacked
    (L, K, F) weights pack per layer.  Returns ``(packed_tree, stats)``
    where stats counts actual bytes.

    ``idx_bits`` picks the stored index width: 8 stores byte-wide
    offsets, 4 stores the u4 plane (two offsets per byte along the
    compact axis — ``core.sparsity.pack_idx_u4``), and ``None`` (the
    default) resolves via :func:`default_idx_bits` — u4 whenever
    M <= 16.  ``stats["packed_bytes"]`` counts the bytes actually
    stored, so with u4 it matches the previously merely *accounted*
    ``packed_bytes_4bit`` figure.

    With ``pspecs`` (matching tree of resolved PartitionSpecs) given,
    returns ``(packed_tree, stats, packed_pspecs)``: vals and idx are
    rank-preserving (the u4 plane only shortens the compact axis) so
    they inherit w's spec.  The N:M group invariant transfers: a K
    shard that is a multiple of M packs to a compact shard that is a
    multiple of N (N/2 bytes of u4 plane), so specs resolved through
    ``rules.nm_params_pspecs`` stay group-safe after packing
    (``rules.assert_nm_unsplit`` re-checks the packed tree).
    """
    if idx_bits is None:
        idx_bits = default_idx_bits(cfg)
    if idx_bits not in (4, 8):
        raise ValueError(f"idx_bits must be 4 or 8, got {idx_bits}")
    stats = {"n_packed": 0, "n_dense": 0,
             "idx_bits": idx_bits,    # stored index width
             "packed_bytes": 0,      # vals + idx bytes as actually stored
             "packed_bytes_4bit": 0,  # vals + nibble-wide idx (SORE)
             "dense_bytes": 0,       # dense bytes of the packed leaves
             "other_bytes": 0}       # leaves kept dense
    # accounted index width: ceil(log2 M) bits rounded up to the nibble a
    # byte-addressable store can actually ship (m=8 needs 3 bits, stored
    # in 4 — the old accounting multiplied by the raw 3 and undercounted
    # the realizable footprint by 2304 B on the bench model)
    acct_bits = 4 if cfg.m <= 16 else 8

    def pack_ok(name, w) -> bool:
        # Parity with the masked forward is the invariant: pack a weight
        # only if training/masked decode FF-sparsifies it too — i.e. the
        # method prunes FF weights at all, dense_apply's pick_cfg selects
        # this weight (should_prune: name exclusions AND divisibility of
        # every grouped axis, K and F for bdwp), and it is FF-servable
        # (serve_packable: 2-D tail, no lm_head/k_up/v_up).  A weight
        # that trains dense must serve dense.
        return (cfg.prunes_ff_weights()
                and bdwp.should_prune(name, tuple(w.shape[-2:]), cfg)
                and bdwp.serve_packable(name, tuple(w.shape[-2:]), cfg))

    def walk(node, spec_node, path):
        if isinstance(node, dict) and "w" in node:
            w = node["w"]
            name = "/".join(str(k) for k in path)
            if pack_ok(name, w):
                def pack_one(ww):
                    vals, idx = nm_pack(ww, cfg.n, cfg.m, axis=ww.ndim - 2)
                    if idx_bits == 4:
                        idx = pack_idx_u4(idx, axis=ww.ndim - 2)
                    return vals, idx
                if isinstance(w, jax.ShapeDtypeStruct):
                    vals, idx = jax.eval_shape(pack_one, w)
                else:
                    vals, idx = pack_one(w)
                new = {"w": O.PackedOp(vals, idx, cfg, idx_bits)}
                stats["n_packed"] += 1
                stats["dense_bytes"] += _leaf_bytes(w)
                stats["packed_bytes"] += _leaf_bytes(vals) + _leaf_bytes(idx)
                # accounted SORE footprint: one ceil(log2 M)-bit offset
                # per surviving value, independent of the stored width
                stats["packed_bytes_4bit"] += (
                    _leaf_bytes(vals) + int(vals.size) * acct_bits // 8)
                new_spec = None
                if spec_node is not None:
                    # vals and idx are rank-preserving: both keep w's spec
                    new_spec = {"w": O.PackedOp(spec_node["w"],
                                                spec_node["w"], cfg,
                                                idx_bits)}
                if "b" in node:
                    new["b"] = node["b"]
                    stats["other_bytes"] += _leaf_bytes(node["b"])
                    if new_spec is not None:
                        new_spec["b"] = spec_node["b"]
                return new, new_spec
            stats["n_dense"] += 1
            stats["other_bytes"] += sum(_leaf_bytes(x)
                                        for x in jax.tree.leaves(node))
            return node, spec_node
        if isinstance(node, dict):
            out_p, out_s = {}, {}
            for k, v in node.items():
                sp = spec_node[k] if spec_node is not None else None
                out_p[k], s = walk(v, sp, path + (k,))
                if spec_node is not None:
                    out_s[k] = s
            return out_p, (out_s if spec_node is not None else None)
        stats["other_bytes"] += _leaf_bytes(node)
        return node, spec_node

    packed, packed_specs = walk(params, pspecs, ())
    if pspecs is not None:
        return packed, stats, packed_specs
    return packed, stats


@dataclasses.dataclass
class PackedParamStore:
    """Packed weights + byte accounting; ``.params`` plugs into forward().

    ``models.layers.dense_apply`` consumes the ``operand.PackedOp``
    leaves through ``nm_apply`` -> the nm_spmm kernel, so the whole
    model runs from the compact representation without any model-code
    changes.
    """

    params: dict
    sp_cfg: SparsityConfig
    n_packed: int
    n_dense: int
    idx_bits: int            # stored index width (4 = two offsets/byte)
    packed_bytes: int        # stored bytes of packed leaves (vals + idx)
    packed_bytes_4bit: int   # with ceil(log2 M)-bit indices (SORE format)
    dense_bytes: int         # dense-equivalent bytes of the packed leaves
    other_bytes: int         # leaves served dense (embeds, norms, head)

    @classmethod
    def pack(cls, params, sp_cfg: SparsityConfig,
             idx_bits: Optional[int] = None) -> "PackedParamStore":
        packed, st = pack_tree_element(params, sp_cfg, idx_bits=idx_bits)
        return cls(params=packed, sp_cfg=sp_cfg,
                   n_packed=st["n_packed"], n_dense=st["n_dense"],
                   idx_bits=st["idx_bits"],
                   packed_bytes=st["packed_bytes"],
                   packed_bytes_4bit=st["packed_bytes_4bit"],
                   dense_bytes=st["dense_bytes"],
                   other_bytes=st["other_bytes"])

    @property
    def hbm_saving(self) -> float:
        """Dense/packed byte ratio over the packable weights."""
        return self.dense_bytes / max(self.packed_bytes, 1)

    @property
    def total_bytes(self) -> int:
        return self.packed_bytes + self.other_bytes

    def measured_packed_bytes(self) -> int:
        """Sum of the live buffer sizes of every PackedOp leaf — what the
        stored pair actually occupies, measured off the arrays rather
        than re-derived from shapes (serve_bench gates the ratio of this
        against the accounted SORE footprint)."""
        total = 0
        for leaf in jax.tree.leaves(
                self.params, is_leaf=lambda x: isinstance(x, O.PackedOp)):
            if isinstance(leaf, O.PackedOp):
                total += int(leaf.vals.nbytes) + int(leaf.idx.nbytes)
        return total

    def report(self) -> dict:
        measured = self.measured_packed_bytes()
        return {
            "n_packed": self.n_packed,
            "n_dense": self.n_dense,
            "n": self.sp_cfg.n, "m": self.sp_cfg.m,
            "idx_bits": self.idx_bits,
            "packed_weight_bytes": self.packed_bytes,
            "packed_weight_bytes_4bit_idx": self.packed_bytes_4bit,
            "measured_packed_weight_bytes": measured,
            "measured_over_accounted_4bit": (
                measured / max(self.packed_bytes_4bit, 1)),
            "dense_weight_bytes": self.dense_bytes,
            "other_param_bytes": self.other_bytes,
            "hbm_saving": self.hbm_saving,
            "total_hbm_bytes": self.total_bytes,
            "total_hbm_bytes_dense": self.dense_bytes + self.other_bytes,
        }
