"""Logical-axis -> mesh-axis rule tables (MaxText-style GSPMD planning).

One model definition + one spec tree serve every (shape x mesh) cell:
the rule table chosen per workload maps each logical axis to mesh axes.

Workloads:
  TRAIN       — FSDP("data") x TP("model"); pure DP across "pod"
                (hierarchical: params replicated across pods, weight
                all-gathers stay intra-pod, grad sync crosses pods once).
  SERVE_BATCH — prefill/decode with real batch: TP("model") weights
                (replicated over "data" — no per-step FSDP gathers),
                batch over ("pod","data"), KV cache sequence over "model"?
                no — cache follows batch; attention stays local.
  SERVE_LONG  — batch=1, 500k context: weights TP("model"), the KV/global
                cache sequence-sharded over "data" => distributed
                flash-decoding (partial softmax + small all-reduces).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple, or None=replicated)
TRAIN_RULES = {
    "embed": "data",      # FSDP: shard the width axis of every weight
    "mlp": "model",       # Megatron TP
    "heads": "model",
    "kv": "model",
    "vocab": "model",
    "expert": "model",    # expert parallelism
    "layer": None,
}

SERVE_BATCH_RULES = {
    "embed": None,
    "mlp": "model",
    "heads": "model",
    "kv": "model",
    "vocab": "model",
    "expert": "model",
    "layer": None,
}

SERVE_LONG_RULES = dict(SERVE_BATCH_RULES)


def rules_for(shape_kind: str):
    if shape_kind == "train":
        return TRAIN_RULES
    return SERVE_BATCH_RULES


def _is_axes(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def spec_to_pspec(axes: tuple, rules: dict, shape=None,
                  mesh: Optional[Mesh] = None) -> P:
    """Logical axes -> PartitionSpec with two production guards:

    * dedupe — a mesh axis may appear once per spec (stacked MoE weights
      map both "expert" and "mlp" to "model": first occurrence wins,
      later ones fall back to replicated);
    * divisibility — with ``shape`` + ``mesh`` given, any dim the mesh
      axis doesn't divide evenly is replicated instead (e.g. hymba's
      fused ssm in_proj output of 6482).
    """
    entries, used = [], set()
    for i, ax in enumerate(axes):
        target = rules.get(ax) if ax is not None else None
        if target is not None:
            tgt_axes = target if isinstance(target, tuple) else (target,)
            if any(t in used for t in tgt_axes):
                target = None
            elif shape is not None and mesh is not None:
                size = 1
                for t in tgt_axes:
                    size *= mesh.shape.get(t, 1)
                if shape[i] % size:
                    target = None
            if target is not None:
                used.update(tgt_axes)
        entries.append(target)
    return P(*entries)


def params_pspecs(specs_tree, rules: dict, params=None,
                  mesh: Optional[Mesh] = None):
    """Map a logical-axis spec tree to a PartitionSpec tree.

    params (optional): matching tree of arrays/ShapeDtypeStructs enabling
    the divisibility fallback; mesh required alongside."""
    if params is None:
        return jax.tree.map(lambda ax: spec_to_pspec(ax, rules), specs_tree,
                            is_leaf=_is_axes)
    flat_s, tdef = jax.tree_util.tree_flatten(specs_tree, is_leaf=_is_axes)
    flat_p = jax.tree_util.tree_flatten(params)[0]
    out = [spec_to_pspec(ax, rules, shape=tuple(p.shape), mesh=mesh)
           for ax, p in zip(flat_s, flat_p)]
    return jax.tree_util.tree_unflatten(tdef, out)


def params_shardings(specs_tree, mesh: Mesh, rules: dict, params=None):
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps),
                        params_pspecs(specs_tree, rules, params, mesh),
                        is_leaf=lambda x: isinstance(x, P))


def batch_axes(mesh: Mesh):
    """DP axes for the activation batch dimension on this mesh."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


# ---------------------------------------------------------------------------
# Input / cache PartitionSpecs per workload
# ---------------------------------------------------------------------------


def train_input_pspecs(input_specs: dict, mesh: Mesh):
    dp = batch_axes(mesh)
    out = {}
    for name, leaf in input_specs.items():
        if name in ("tokens", "labels"):
            out[name] = P(dp, None)
        elif name in ("frames", "prefix_embeds"):
            out[name] = P(dp, None, None)
        else:
            out[name] = P()
    return out


def serve_input_pspecs(input_specs: dict, mesh: Mesh, *, long_context: bool):
    """decode/prefill inputs; caches handled leaf-by-leaf by rank/name."""
    dp = batch_axes(mesh)
    bp = None if long_context else dp

    tp = mesh.shape.get("model", 1)

    def cache_spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        rank = len(leaf.shape)
        # rank discriminates stacked (leading scan-layer dim) vs the
        # unstacked prelude cache (deepseek's dense first layer)
        if name in ("k", "v"):  # (L, B, S, Hkv, D) or (B, S, Hkv, D)
            seq_ax = "data" if long_context else None
            head_ax = "model" if leaf.shape[rank - 2] % tp == 0 else None
            tail = (bp, seq_ax, head_ax, None)
            return P(*(((None,) + tail) if rank == 5 else tail))
        if name in ("ckv", "kpe"):  # (L, B, S, dim) or (B, S, dim)
            seq_ax = "data" if long_context else None
            tail = (bp, seq_ax, None)
            return P(*(((None,) + tail) if rank == 4 else tail))
        if name == "state":  # (L, B, H, N, Pd) or (B, H, N, Pd)
            head_ax = "model" if leaf.shape[rank - 3] % tp == 0 else None
            tail = (bp, head_ax, None, None)
            return P(*(((None,) + tail) if rank == 5 else tail))
        if name == "conv":  # (L, B, K-1, C) or (B, K-1, C)
            ch_ax = "model" if leaf.shape[rank - 1] % tp == 0 else None
            tail = (bp, None, ch_ax)
            return P(*(((None,) + tail) if rank == 4 else tail))
        if name == "pos":
            return P() if rank == 0 else P(None)
        return P(*([None] * rank))

    out = {}
    for name, leaf in input_specs.items():
        if name == "cache":
            out[name] = jax.tree_util.tree_map_with_path(cache_spec, leaf)
        elif name == "token":
            out[name] = P(bp, None)
        elif name == "tokens":
            out[name] = P(bp, None)
        elif name in ("frames", "prefix_embeds", "enc_out"):
            out[name] = P(bp, None, None)
        elif name == "pos":
            out[name] = P()
        else:
            out[name] = P()
    return out


def constrain(x, mesh: Mesh, *axes):
    """with_sharding_constraint helper tolerant of absent mesh axes."""
    fixed = []
    for ax in axes:
        if ax is None:
            fixed.append(None)
        elif isinstance(ax, tuple):
            sub = tuple(a for a in ax if a in mesh.axis_names)
            fixed.append(sub if sub else None)
        else:
            fixed.append(ax if ax in mesh.axis_names else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))


# ---------------------------------------------------------------------------
# Activation sharding context
# ---------------------------------------------------------------------------
#
# Without explicit activation constraints GSPMD's propagation is free to
# replicate the token batch and shard hidden dims over "data" instead —
# which it *does* for these models (full-batch activation all-reduces,
# ~TB-scale per-chip traffic).  The step builders enter this context at
# trace time; model code calls ``act()`` at block boundaries and on TP
# internals (FFN hidden, attention heads, MoE expert dim).  ``BATCH``
# resolves to the workload's data-parallel axes; when no context is
# active (unit tests, single-device examples) everything is a no-op.

import contextlib

BATCH = "__batch__"  # sentinel: the workload's DP axes tuple
SEQ = "__seq__"      # sentinel: sequence dim — "model" under sequence
#                      parallelism (halves TP traffic: AR -> RS+AG and
#                      norms/residuals run seq-sharded), else replicated

_ACT_CTX = {"mesh": None, "dp": None, "sp": False}


@contextlib.contextmanager
def activation_sharding(mesh: Optional[Mesh], dp, sp: bool = False):
    """dp: tuple of mesh axes carrying the batch dim (or None).
    sp: enable sequence parallelism over the "model" axis."""
    old = dict(_ACT_CTX)
    _ACT_CTX.update(mesh=mesh, dp=dp, sp=sp)
    try:
        yield
    finally:
        _ACT_CTX.update(old)


def act(x, *axes):
    """Constrain an activation under the ambient context.

    ``axes`` uses logical names: BATCH -> context dp axes, "model"/"data"
    -> mesh axes, None -> replicated.  No-op without an active context or
    when a named dim doesn't divide evenly (constraint would be invalid).
    """
    mesh = _ACT_CTX["mesh"]
    if mesh is None:
        return x
    dp = _ACT_CTX["dp"]
    fixed = []
    for i, ax in enumerate(axes):
        if ax is SEQ:
            ax = "model" if _ACT_CTX["sp"] else None
        ax = dp if ax is BATCH else ax
        if ax is None:
            fixed.append(None)
            continue
        sub = tuple(a for a in (ax if isinstance(ax, tuple) else (ax,))
                    if a in mesh.axis_names)
        size = 1
        for a in sub:
            size *= mesh.shape[a]
        if not sub or x.shape[i] % size:
            fixed.append(None)
        else:
            fixed.append(sub if len(sub) > 1 else sub[0])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))
