"""Logical-axis -> mesh-axis rule tables (MaxText-style GSPMD planning).

One model definition + one spec tree serve every (shape x mesh) cell:
the rule table chosen per workload maps each logical axis to mesh axes.

Workloads:
  TRAIN       — FSDP("data") x TP("model"); pure DP across "pod"
                (hierarchical: params replicated across pods, weight
                all-gathers stay intra-pod, grad sync crosses pods once).
  SERVE_BATCH — prefill/decode with real batch: TP("model") weights
                (replicated over "data" — no per-step FSDP gathers),
                batch over ("pod","data"), KV cache sequence over "model"?
                no — cache follows batch; attention stays local.
  SERVE_LONG  — batch=1, 500k context: weights TP("model"), the KV/global
                cache sequence-sharded over "data" => distributed
                flash-decoding (partial softmax + small all-reduces).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple, or None=replicated)
TRAIN_RULES = {
    "embed": "data",      # FSDP: shard the width axis of every weight
    "mlp": "model",       # Megatron TP
    "heads": "model",
    "kv": "model",
    "vocab": "model",
    "expert": "model",    # expert parallelism
    "layer": None,
}

SERVE_BATCH_RULES = {
    "embed": None,
    "mlp": "model",
    "heads": "model",
    "kv": "model",
    "vocab": "model",
    "expert": "model",
    "layer": None,
}

SERVE_LONG_RULES = dict(SERVE_BATCH_RULES)


def rules_for(shape_kind: str):
    if shape_kind == "train":
        return TRAIN_RULES
    return SERVE_BATCH_RULES


def _is_axes(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def spec_to_pspec(axes: tuple, rules: dict, shape=None,
                  mesh: Optional[Mesh] = None,
                  group_multiples: Optional[dict] = None) -> P:
    """Logical axes -> PartitionSpec with three production guards:

    * dedupe — a mesh axis may appear once per spec (stacked MoE weights
      map both "expert" and "mlp" to "model": first occurrence wins,
      later ones fall back to replicated);
    * divisibility — with ``shape`` + ``mesh`` given, any dim the mesh
      axis doesn't divide evenly is replicated instead (e.g. hymba's
      fused ssm in_proj output of 6482);
    * group integrity — ``group_multiples[i]`` (dim index -> int) demands
      the *per-shard* size of dim ``i`` stay a multiple of that value;
      a mesh axis that would cut a group is dropped (replicated).  This
      is how N:M structure is expressed to the partitioner: groups of
      size M along a grouped weight axis — or runs of N along a packed
      compact axis — must never straddle a "model" shard boundary.
    """
    entries, used = [], set()
    for i, ax in enumerate(axes):
        target = rules.get(ax) if ax is not None else None
        if target is not None:
            tgt_axes = target if isinstance(target, tuple) else (target,)
            if any(t in used for t in tgt_axes):
                target = None
            elif shape is not None and mesh is not None:
                size = 1
                for t in tgt_axes:
                    size *= mesh.shape.get(t, 1)
                mult = (group_multiples or {}).get(i, 1)
                if shape[i] % size or (shape[i] // size) % mult:
                    target = None
            if target is not None:
                used.update(tgt_axes)
        entries.append(target)
    return P(*entries)


def params_pspecs(specs_tree, rules: dict, params=None,
                  mesh: Optional[Mesh] = None):
    """Map a logical-axis spec tree to a PartitionSpec tree.

    params (optional): matching tree of arrays/ShapeDtypeStructs enabling
    the divisibility fallback; mesh required alongside."""
    if params is None:
        return jax.tree.map(lambda ax: spec_to_pspec(ax, rules), specs_tree,
                            is_leaf=_is_axes)
    flat_s, tdef = jax.tree_util.tree_flatten(specs_tree, is_leaf=_is_axes)
    flat_p = jax.tree_util.tree_flatten(params)[0]
    out = [spec_to_pspec(ax, rules, shape=tuple(p.shape), mesh=mesh)
           for ax, p in zip(flat_s, flat_p)]
    return jax.tree_util.tree_unflatten(tdef, out)


def params_shardings(specs_tree, mesh: Mesh, rules: dict, params=None):
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps),
                        params_pspecs(specs_tree, rules, params, mesh),
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# N:M group integrity
# ---------------------------------------------------------------------------
#
# BDWP prunes in groups of M along a weight's contraction axis (axis
# ndim-2 of every ``{"w": ...}`` leaf-dict), and the packed serving
# format stores the N survivors of each group contiguously along the
# compact axis.  A shard boundary inside a group would make the group's
# top-N selection (training) or its (vals, idx) run (serving) straddle
# two devices — the rules must never emit such a spec, and the resolved
# shardings are asserted against it.


def _shard_count(entry, mesh: Mesh) -> int:
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for a in axes:
        size *= mesh.shape.get(a, 1)
    return size


def nm_group_multiples(name: str, shape, sp_cfg) -> Optional[dict]:
    """Per-dim per-shard multiples an N:M-prunable weight demands.

    BDWP tiles M-groups along the FF/contraction axis (ndim-2) AND the
    BP/output axis (ndim-1); one-directional methods constrain only
    their own axis.  None for dense / non-prunable leaves.
    """
    if sp_cfg is None or getattr(sp_cfg, "is_dense", True):
        return None
    from repro.core import bdwp
    if len(shape) < 2 or not bdwp.should_prune(name, tuple(shape[-2:]),
                                               sp_cfg):
        return None
    gm = {}
    if sp_cfg.prunes_ff_weights():
        gm[len(shape) - 2] = sp_cfg.m
    if sp_cfg.prunes_bp_weights() or sp_cfg.prunes_bp_grads():
        gm[len(shape) - 1] = sp_cfg.m
    return gm or {len(shape) - 2: sp_cfg.m}


def nm_params_pspecs(specs_tree, rules: dict, params, mesh: Mesh,
                     sp_cfg=None):
    """``params_pspecs`` plus the N:M group guard.

    Every prunable leaf — a ``{"w": ...}`` leaf-dict (``bdwp.
    should_prune`` on its tree path) or a bare-array expert stack
    (``bdwp.bare_nm_leaf``: MoE w_gate/w_up/w_down, groups along the
    last two axes *within* each expert) — carries ``nm_group_multiples``
    into ``spec_to_pspec`` so a mesh axis that would split an M-group
    falls back to replicated; expert-parallel sharding of the leading
    expert axis is untouched (a whole expert per shard never cuts a
    group).  With ``sp_cfg`` None or dense this degenerates to
    ``params_pspecs``.
    """
    if sp_cfg is None or getattr(sp_cfg, "is_dense", True):
        return params_pspecs(specs_tree, rules, params, mesh)
    from repro.core import bdwp

    def walk(spec_node, p_node, path):
        if isinstance(spec_node, dict):
            if "w" in spec_node and _is_axes(spec_node["w"]):
                name = "/".join(str(k) for k in path)
                out = {}
                for key, ax in spec_node.items():
                    shape = tuple(p_node[key].shape)
                    gm = (nm_group_multiples(name, shape, sp_cfg)
                          if key == "w" else None)
                    out[key] = spec_to_pspec(ax, rules, shape=shape,
                                             mesh=mesh, group_multiples=gm)
                return out
            return {k: walk(v, p_node[k], path + (k,))
                    for k, v in spec_node.items()}
        name = "/".join(str(k) for k in path)
        shape = tuple(p_node.shape)
        gm = nm_group_multiples(name, shape, sp_cfg) \
            if bdwp.bare_nm_leaf(name) else None
        return spec_to_pspec(spec_node, rules, shape=shape, mesh=mesh,
                             group_multiples=gm)

    return walk(specs_tree, params, ())


def pregen_pspecs(compute_tree, master_pspecs):
    """PartitionSpecs for a pre-generated compute tree (optim/sgd).

    The compute tree mirrors master except that prunable weights —
    ``{"w": ...}`` dict sites and bare-array MoE expert stacks alike —
    became ``operand.PregenOp`` leaves ({ff | (vals, idx), bp, mask}).
    Every operand child inherits the master weight's spec: ff/bp/mask
    are dense-shaped (expert-parallel sharding of a stacked leaf carries
    straight over), and the packed vals/idx only shrink the contraction
    dim (ndim-2) by n/m — a mesh axis the group guard admitted for w
    (per-shard multiple of M along K) divides Kc with per-shard runs
    whole multiples of N, so the same spec keeps packed runs group-whole
    under SPMD (``assert_nm_unsplit`` re-checks).
    """
    from repro.core import bdwp, operand as O

    def walk(c, s):
        if isinstance(c, O.SparseOperand):
            return c.map_children(lambda _: s)
        if bdwp.is_pregen(c):  # legacy operand dicts
            return {k: s for k in c}
        if isinstance(c, dict):
            return {k: walk(v, s[k]) for k, v in c.items()}
        return s

    return walk(compute_tree, master_pspecs)


def assert_nm_unsplit(pspecs_tree, params_tree, mesh: Mesh, sp_cfg) -> None:
    """Assert no resolved sharding splits an N:M group.

    Dense prunable ``w`` leaves must keep per-shard size a multiple of M
    along every grouped axis (``nm_group_multiples``); element-packed
    ``vals``/``idx`` leaves a multiple of N along the compact axis
    (ndim-2).  Operand nodes (``operand.PregenOp`` compute leaves,
    ``operand.PackedOp`` serving leaves) are recognized by type; the
    equivalent legacy dict layouts keep working.  Raises AssertionError
    naming the offending leaf.  The pspec tree may hold PartitionSpecs
    or NamedShardings.
    """
    if sp_cfg is None or getattr(sp_cfg, "is_dense", True):
        return
    from repro.core import operand as O

    def as_spec(x) -> P:
        return x.spec if isinstance(x, NamedSharding) else x

    def check(name, key, spec, shape, multiples: dict):
        for axis, multiple in multiples.items():
            entry = spec[axis] if axis < len(spec) else None
            shards = _shard_count(entry, mesh)
            if shape[axis] % shards or (shape[axis] // shards) % multiple:
                raise AssertionError(
                    f"N:M group split: {name}/{key} dim {axis} (size "
                    f"{shape[axis]}) sharded {shards}-way over {entry!r} — "
                    f"per-shard size must be a multiple of {multiple}")

    def is_spec(x):
        return isinstance(x, (P, NamedSharding))

    def idx_multiple(spec_node, key) -> int:
        """Per-shard multiple for a compact-axis index plane.  Byte-wide
        idx shards like vals (whole N-runs).  A u4 plane holds two
        offsets per byte: even N needs N/2 bytes per group; odd N's
        group boundaries fall mid-byte, so shards must cover whole
        byte-aligned group pairs (N bytes = 2 groups)."""
        if key == "idx" and getattr(spec_node, "idx_bits", 8) == 4:
            return sp_cfg.n // 2 if sp_cfg.n % 2 == 0 else sp_cfg.n
        return sp_cfg.n

    def check_pregen(name, spec_node, p_node):
        """PregenOp (or legacy operand-dict) site: pruned operands carry
        M-groups on their own axis; packed vals/idx carry N-runs on the
        compact axis (ndim-2)."""
        if sp_cfg.prunes_ff_weights():
            if "ff" in spec_node and is_spec(spec_node["ff"]):
                shape = tuple(p_node["ff"].shape)
                check(name, "ff", as_spec(spec_node["ff"]), shape,
                      {len(shape) - 2: sp_cfg.m})
            for key in ("vals", "idx"):
                if key in spec_node and is_spec(spec_node[key]):
                    shape = tuple(p_node[key].shape)
                    check(name, key, as_spec(spec_node[key]), shape,
                          {len(shape) - 2: idx_multiple(spec_node, key)})
        if sp_cfg.prunes_bp_weights() and is_spec(spec_node["bp"]):
            shape = tuple(p_node["bp"].shape)
            check(name, "bp", as_spec(spec_node["bp"]), shape,
                  {len(shape) - 1: sp_cfg.m})

    def walk(spec_node, p_node, path):
        if isinstance(spec_node, O.PregenOp):
            check_pregen("/".join(str(k) for k in path), spec_node, p_node)
            return
        if isinstance(spec_node, O.PackedOp):
            # element-packed serving operand: N-runs on the compact axis
            # (N/2-byte runs on a u4 index plane)
            name = "/".join(str(k) for k in path)
            for key in ("vals", "idx"):
                if is_spec(spec_node[key]):
                    shape = tuple(p_node[key].shape)
                    check(name, key, as_spec(spec_node[key]), shape,
                          {len(shape) - 2: idx_multiple(spec_node, key)})
            return
        if isinstance(spec_node, O.SharedOp):
            # shared-mode: vals carry the compact axis; per-row idx has
            # no N-run constraint
            name = "/".join(str(k) for k in path)
            if is_spec(spec_node["vals"]):
                shape = tuple(p_node["vals"].shape)
                if len(shape) >= 2:
                    check(name, "vals", as_spec(spec_node["vals"]), shape,
                          {len(shape) - 2: sp_cfg.n})
            return
        if is_spec(spec_node):
            # bare-array leaf (MoE expert stack / shared-expert mat):
            # M-groups on the last two axes within each expert, and the
            # leading expert/layer axes must shard evenly — an expert's
            # matrix never straddles devices
            from repro.core import bdwp
            name = "/".join(str(k) for k in path)
            gm = nm_group_multiples(name, tuple(p_node.shape), sp_cfg) \
                if bdwp.bare_nm_leaf(name) else None
            if gm:
                shape = tuple(p_node.shape)
                for i in range(len(shape) - 2):
                    gm.setdefault(i, 1)
                check(name, "leaf", as_spec(spec_node), shape, gm)
            return
        if isinstance(spec_node, dict):
            name = "/".join(str(k) for k in path)
            if "bp" in spec_node and ("ff" in spec_node
                                      or "vals" in spec_node):
                # legacy pre-generated operand dict (pre-operand era)
                check_pregen(name, spec_node, p_node)
                return
            if "w" in spec_node and is_spec(spec_node["w"]):
                shape = tuple(p_node["w"].shape)
                gm = nm_group_multiples(name, shape, sp_cfg)
                if gm:
                    check(name, "w", as_spec(spec_node["w"]), shape, gm)
                return
            if "vals" in spec_node and is_spec(spec_node["vals"]):
                v_rank = len(p_node["vals"].shape)
                for key in ("vals", "idx"):
                    # shared-mode idx (rank vals-1) has no compact axis
                    if key in spec_node and is_spec(spec_node[key]) \
                            and len(p_node[key].shape) == v_rank >= 2:
                        shape = tuple(p_node[key].shape)
                        check(name, key, as_spec(spec_node[key]),
                              shape, {len(shape) - 2: sp_cfg.n})
                return
            for k, v in spec_node.items():
                walk(v, p_node[k], path + (k,))

    walk(pspecs_tree, params_tree, ())


def grad_sync_pspecs(mesh: Mesh) -> dict:
    """PartitionSpecs for the bucketed compressed gradient sync.

    err: the persistent error-feedback residual, (n_pods, T_loc*S) —
    row p lives on pod p's devices and the width axis is laid out as S
    device-local slabs along the intra-pod axes, so each device's EF
    state covers exactly the leaf blocks it compresses
    (optim/compress._slab_layout) and never moves between steps.  On a
    pod-less mesh the spec degenerates to replicated (the sync path is
    a no-op there).
    """
    pod = "pod" if "pod" in mesh.axis_names else None
    intra = tuple(a for a in mesh.axis_names if a != "pod")
    slab = P(pod, intra) if intra else P(pod, None)
    return {"err": slab}


def batch_axes(mesh: Mesh):
    """DP axes for the activation batch dimension on this mesh."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


# ---------------------------------------------------------------------------
# Input / cache PartitionSpecs per workload
# ---------------------------------------------------------------------------


def train_input_pspecs(input_specs: dict, mesh: Mesh):
    dp = batch_axes(mesh)
    out = {}
    for name, leaf in input_specs.items():
        if name in ("tokens", "labels"):
            out[name] = P(dp, None)
        elif name in ("frames", "prefix_embeds"):
            out[name] = P(dp, None, None)
        else:
            out[name] = P()
    return out


def serve_input_pspecs(input_specs: dict, mesh: Mesh, *, long_context: bool):
    """decode/prefill inputs; caches handled leaf-by-leaf by rank/name."""
    dp = batch_axes(mesh)
    bp = None if long_context else dp

    tp = mesh.shape.get("model", 1)

    def cache_spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        rank = len(leaf.shape)
        # rank discriminates stacked (leading scan-layer dim) vs the
        # unstacked prelude cache (deepseek's dense first layer)
        if name in ("k", "v"):  # (L, B, S, Hkv, D) or (B, S, Hkv, D)
            seq_ax = "data" if long_context else None
            head_ax = "model" if leaf.shape[rank - 2] % tp == 0 else None
            tail = (bp, seq_ax, head_ax, None)
            return P(*(((None,) + tail) if rank == 5 else tail))
        if name in ("ckv", "kpe"):  # (L, B, S, dim) or (B, S, dim)
            seq_ax = "data" if long_context else None
            tail = (bp, seq_ax, None)
            return P(*(((None,) + tail) if rank == 4 else tail))
        if name == "state":  # (L, B, H, N, Pd) or (B, H, N, Pd)
            head_ax = "model" if leaf.shape[rank - 3] % tp == 0 else None
            tail = (bp, head_ax, None, None)
            return P(*(((None,) + tail) if rank == 5 else tail))
        if name == "conv":  # (L, B, K-1, C) or (B, K-1, C)
            ch_ax = "model" if leaf.shape[rank - 1] % tp == 0 else None
            tail = (bp, None, ch_ax)
            return P(*(((None,) + tail) if rank == 4 else tail))
        if name == "pos":
            return P() if rank == 0 else P(None)
        return P(*([None] * rank))

    out = {}
    for name, leaf in input_specs.items():
        if name == "cache":
            out[name] = jax.tree_util.tree_map_with_path(cache_spec, leaf)
        elif name == "token":
            out[name] = P(bp, None)
        elif name == "tokens":
            out[name] = P(bp, None)
        elif name in ("frames", "prefix_embeds", "enc_out"):
            out[name] = P(bp, None, None)
        elif name == "pos":
            out[name] = P()
        else:
            out[name] = P()
    return out


def constrain(x, mesh: Mesh, *axes):
    """with_sharding_constraint helper tolerant of absent mesh axes."""
    fixed = []
    for ax in axes:
        if ax is None:
            fixed.append(None)
        elif isinstance(ax, tuple):
            sub = tuple(a for a in ax if a in mesh.axis_names)
            fixed.append(sub if sub else None)
        else:
            fixed.append(ax if ax in mesh.axis_names else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))


# ---------------------------------------------------------------------------
# Activation sharding context
# ---------------------------------------------------------------------------
#
# Without explicit activation constraints GSPMD's propagation is free to
# replicate the token batch and shard hidden dims over "data" instead —
# which it *does* for these models (full-batch activation all-reduces,
# ~TB-scale per-chip traffic).  The step builders enter this context at
# trace time; model code calls ``act()`` at block boundaries and on TP
# internals (FFN hidden, attention heads, MoE expert dim).  ``BATCH``
# resolves to the workload's data-parallel axes; when no context is
# active (unit tests, single-device examples) everything is a no-op.

import contextlib

BATCH = "__batch__"  # sentinel: the workload's DP axes tuple
SEQ = "__seq__"      # sentinel: sequence dim — "model" under sequence
#                      parallelism (halves TP traffic: AR -> RS+AG and
#                      norms/residuals run seq-sharded), else replicated

_ACT_CTX = {"mesh": None, "dp": None, "sp": False}


@contextlib.contextmanager
def activation_sharding(mesh: Optional[Mesh], dp, sp: bool = False):
    """dp: tuple of mesh axes carrying the batch dim (or None).
    sp: enable sequence parallelism over the "model" axis."""
    old = dict(_ACT_CTX)
    _ACT_CTX.update(mesh=mesh, dp=dp, sp=sp)
    try:
        yield
    finally:
        _ACT_CTX.update(old)


def act(x, *axes):
    """Constrain an activation under the ambient context.

    ``axes`` uses logical names: BATCH -> context dp axes, "model"/"data"
    -> mesh axes, None -> replicated.  No-op without an active context or
    when a named dim doesn't divide evenly (constraint would be invalid).
    """
    mesh = _ACT_CTX["mesh"]
    if mesh is None:
        return x
    dp = _ACT_CTX["dp"]
    fixed = []
    for i, ax in enumerate(axes):
        if ax is SEQ:
            ax = "model" if _ACT_CTX["sp"] else None
        ax = dp if ax is BATCH else ax
        if ax is None:
            fixed.append(None)
            continue
        sub = tuple(a for a in (ax if isinstance(ax, tuple) else (ax,))
                    if a in mesh.axis_names)
        size = 1
        for a in sub:
            size *= mesh.shape[a]
        if not sub or x.shape[i] % size:
            fixed.append(None)
        else:
            fixed.append(sub if len(sub) > 1 else sub[0])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))
