"""Fault-tolerant checkpointing: async, atomic, sharded, elastic.

Design (1000+-node posture):
  * each host writes only its addressable shards (per-leaf .npy chunks);
    on this single-process container that degenerates to full leaves,
    but the layout and manifest carry the *logical* metadata (tree
    structure, shapes, dtypes, step) — restore is mesh-agnostic;
  * writes go to ``step_XXXX.tmp`` then ``os.replace`` to commit
    (a torn write can never be mistaken for a checkpoint);
  * saves run on a background thread (training is never blocked by I/O);
  * ``restore(..., shardings=...)`` re-device_puts every leaf under the
    *new* mesh's NamedShardings — elastic resharding: a checkpoint taken
    on 512 chips restores onto 256 (or 8) without conversion;
  * retention: keep the newest ``keep`` checkpoints, delete older.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# numpy round-trips extended dtypes (bfloat16, fp8) as raw void bytes
# ('|V2'): the manifest records the true dtype and restore views it back
_EXTENDED_DTYPES = {"bfloat16": jnp.bfloat16}


def _rehydrate(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(arr.dtype) == dtype_str:
        return arr
    want = np.dtype(_EXTENDED_DTYPES.get(dtype_str, dtype_str))
    if arr.dtype.kind == "V" and arr.dtype.itemsize == want.itemsize:
        return arr.view(want)
    return arr.astype(want)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state, blocking: bool = False):
        """Snapshot to host memory synchronously, write asynchronously."""
        flat, treedef = jax.tree_util.tree_flatten(state)
        host = [np.asarray(x) for x in flat]  # device->host copy now
        tdef_str = str(treedef)
        if self._thread is not None:
            self._thread.join()  # one in-flight save at a time

        def write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "n_leaves": len(host),
                        "treedef": tdef_str,
                        "leaves": [{"shape": list(a.shape),
                                    "dtype": str(a.dtype)} for a in host],
                        "time": time.time()}
            for i, a in enumerate(host):
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), a)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)  # atomic commit
            self._gc()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self._thread.join()

    def wait(self):
        if self._thread is not None:
            self._thread.join()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like_state, step: Optional[int] = None, shardings=None):
        """Restore into the structure of ``like_state``.

        shardings: optional matching tree of NamedShardings for the *new*
        mesh (elastic restore).  Leaves are device_put under them.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat, treedef = jax.tree_util.tree_flatten(like_state)
        if manifest["n_leaves"] != len(flat):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, "
                f"state has {len(flat)} — structure mismatch")
        loaded = [_rehydrate(np.load(os.path.join(path, f"leaf_{i:05d}.npy")),
                             manifest["leaves"][i]["dtype"])
                  for i in range(len(flat))]
        for a, ref in zip(loaded, flat):
            if tuple(a.shape) != tuple(ref.shape):
                raise ValueError(f"shape mismatch {a.shape} vs {ref.shape}")
        if shardings is not None:
            sh_flat = jax.tree_util.tree_flatten(shardings)[0]
            arrs = [jax.device_put(a, s) for a, s in zip(loaded, sh_flat)]
        else:
            arrs = [jax.device_put(a) for a in loaded]
        return jax.tree_util.tree_unflatten(treedef, arrs)
