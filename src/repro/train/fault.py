"""Fault tolerance & straggler mitigation for the training loop.

At 1000+-node scale three things kill runs: crashed hosts, slow hosts
and lost work.  The pieces here:

  * StragglerMonitor — per-step wall-time EWMA + deviation tracking;
    flags steps slower than ``threshold x`` the running mean.  On a real
    cluster the flag feeds the scheduler (evict + restart from the last
    checkpoint); here it drives the trainer's logging and tests.
  * Heartbeat — a JSON liveness file written every step; an external
    watchdog (launch/train.py --watchdog) restarts the process from the
    latest checkpoint when the heartbeat goes stale.
  * recover_or_init — the restart path: restore the newest checkpoint
    under the *current* mesh (elastic: the checkpoint may come from a
    different device count) or fall back to fresh init.
"""

from __future__ import annotations

import json
import os
import time
import uuid
from typing import Optional


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, ewma: float = 0.9,
                 warmup: int = 3):
        self.threshold = threshold
        self.alpha = ewma
        self.warmup = warmup
        self.mean: Optional[float] = None
        self.count = 0
        self.flagged = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler.

        The first ``warmup`` samples never seed or update the mean: step
        0 carries jit compilation (often 100x a steady step), and an
        EWMA seeded from it masks every early real straggler — nothing
        exceeds ``threshold x`` the poisoned mean until it decays.  The
        mean seeds from the first post-warmup sample instead.
        """
        self.count += 1
        if self.count <= self.warmup:
            return False   # compile/warmup samples are discarded
        if self.mean is None:
            self.mean = seconds
            return False
        is_straggler = seconds > self.threshold * self.mean
        if is_straggler:
            self.flagged.append((step, seconds, self.mean))
        else:
            # stragglers don't poison the running mean
            self.mean = self.alpha * self.mean + (1 - self.alpha) * seconds
        return is_straggler


class Heartbeat:
    def __init__(self, path: str):
        self.path = path
        # the scratch name must be unique PER WRITER: during a watchdog
        # restart the old and new process briefly overlap, and with a
        # shared "path + .tmp" their write/replace pairs interleave —
        # one publishes the other's half-written payload and the loser's
        # replace() finds its tmp already gone.  pid + a per-instance
        # nonce keeps every writer on its own scratch file; the final
        # os.replace onto ``path`` stays the single atomic commit point.
        self._tmp = (f"{path}.{os.getpid()}."
                     f"{uuid.uuid4().hex[:8]}.tmp")

    def beat(self, step: int, **info):
        payload = {"step": step, "time": time.time(), **info}
        with open(self._tmp, "w") as f:
            json.dump(payload, f)
        os.replace(self._tmp, self.path)

    def age(self) -> Optional[float]:
        try:
            with open(self.path) as f:
                return time.time() - json.load(f)["time"]
        except (FileNotFoundError, json.JSONDecodeError, KeyError):
            return None

    def is_stale(self, timeout: float) -> bool:
        age = self.age()
        return age is None or age > timeout


def recover_or_init(ckpt_mgr, init_fn, like_state=None, shardings=None,
                    restore_fn=None):
    """Restart path: newest checkpoint (elastic resharding) or fresh init.

    restore_fn: optional override with the CheckpointManager.restore
    signature ``(like, step=, shardings=)`` — the launcher passes
    train/step.restore_with_pregen so pre-pregen checkpoints (no
    ``compute`` leaf) upgrade in place instead of failing the restore.
    """
    step = ckpt_mgr.latest_step()
    if step is None:
        return init_fn(), 0
    like = like_state if like_state is not None else init_fn()
    restore = restore_fn if restore_fn is not None else ckpt_mgr.restore
    state = restore(like, step=step, shardings=shardings)
    return state, step
