"""Train / serve step builders: model cfg + mesh + rules -> jitted fns.

This is the piece the launcher, the dry-run, the trainer and the
examples all share.  A step builder resolves:
  * parameter shardings from the logical-axis spec tree (sharding/rules),
  * input shardings per workload,
  * the pre-generation dataflow (paper Fig. 11c): FF/BP consume the bf16
    N:M operands the optimizer wrote at the previous WU (state leaf
    ``compute``) instead of re-casting/re-masking fp32 master per step,
  * the BDWP sparse-training semantics (via core/bdwp inside the model),
  * optional cross-pod N:M gradient compression (optim/compress).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import operand as O
from repro.core.sparsity import SparsityConfig
from repro.models import encdec as E
from repro.models import transformer_lm as T
from repro.optim import compress as C
from repro.optim import sgd
from repro.sharding import rules as R

AUX_COEF = 0.01


# ---------------------------------------------------------------------------
# Pre-generation plumbing: the compute tree is the differentiation root
# ---------------------------------------------------------------------------
#
# The compute tree written at WU time mixes float operands (bf16 weights,
# pruned FF/BP copies, packed vals) with non-float companions (uint8 pack
# indices, bool decay masks).  jax.grad roots must be inexact, so the
# step splits the tree by dtype: the float leaves form the grad root, the
# rest is re-merged inside the loss closure.  The cotangent tree (merged
# back into compute structure) maps to master-shaped grads via
# sgd.pregen_grads — the dense WU gradient rides on each BP operand.


def split_compute(tree):
    flat, tdef = jax.tree_util.tree_flatten(tree)
    which = [jnp.issubdtype(x.dtype, jnp.inexact) for x in flat]
    diff = [x for x, d in zip(flat, which) if d]
    aux = [x for x, d in zip(flat, which) if not d]
    return diff, (tdef, which, aux)


def merge_compute(diff, meta):
    tdef, which, aux = meta
    it_d, it_a = iter(diff), iter(aux)
    flat = [next(it_d) if d else next(it_a) for d in which]
    return jax.tree_util.tree_unflatten(tdef, flat)


# ---------------------------------------------------------------------------
# Pod-stacked split mean: compressed cross-pod sync off the critical path
# ---------------------------------------------------------------------------
#
# With compression on, the loss must NOT take the global batch mean —
# GSPMD would all-reduce every gradient over ("pod","data") densely and
# the packed sync would be pure overhead (this was the old behavior:
# 125ms compressed vs 81ms dense).  Instead the step broadcasts the grad
# root to a pod-stacked copy (n_pods, *shape), splits the batch
# (n_pods, B/P, ...), and vmaps value_and_grad over the pod dim: each
# pod-replica's gradient contraction only crosses "data", and the pod
# hop is the bucketed packed payload in optim/compress.cross_pod_sync.


def _pod_split_batch(x, mesh, n_pods):
    if x.shape[0] % n_pods:
        raise ValueError(
            f"global batch {x.shape[0]} not divisible by n_pods={n_pods}")
    xs = x.reshape(n_pods, x.shape[0] // n_pods, *x.shape[1:])
    return jax.lax.with_sharding_constraint(
        xs, NamedSharding(mesh, P("pod", ("data",),
                                  *([None] * (x.ndim - 1)))))


def _pod_stack(x, mesh, n_pods, spec):
    xs = jnp.broadcast_to(x[None], (n_pods,) + x.shape)
    return jax.lax.with_sharding_constraint(
        xs, NamedSharding(mesh, P("pod", *spec)))


def _diff_pspecs(compute_tree, master_pspecs):
    """Flat pspec list aligned with ``split_compute``'s diff leaves."""
    c_pspecs = R.pregen_pspecs(compute_tree, master_pspecs)
    flat_c = jax.tree_util.tree_flatten(compute_tree)[0]
    flat_s = jax.tree_util.tree_flatten(
        c_pspecs, is_leaf=lambda x: isinstance(x, P))[0]
    return [s for x, s in zip(flat_c, flat_s)
            if jnp.issubdtype(x.dtype, jnp.inexact)]


# ---------------------------------------------------------------------------
# LM-family
# ---------------------------------------------------------------------------


def lm_train_step(state, batch, *, cfg, sp_cfg, opt_cfg, mesh, names,
                  compress=False, grad_pspecs=None, seq_parallel=False,
                  pregen=True, pregen_pack=False, use_pallas=False,
                  nm_backend="auto", grad_sync=None):
    def run_model(compute, b):
        hidden, _, aux = T.forward(compute, b["tokens"], cfg, sp_cfg,
                                   prefix_embeds=b.get("prefix_embeds"))
        labels = b["labels"]
        if "prefix_embeds" in b:
            hidden = hidden[:, b["prefix_embeds"].shape[1]:]
        loss = T.lm_loss(compute, hidden, labels, cfg)
        return loss + AUX_COEF * aux, (loss, aux)

    compress_on = compress and "pod" in mesh.axis_names
    dp = ("data",) if compress_on else R.batch_axes(mesh)
    with R.activation_sharding(mesh, dp, sp=seq_parallel), \
            O.backend_scope(nm_backend):
        if pregen:
            # FF/BP load the operands written at the previous WU — no
            # per-step master cast, no in-model mask derivation; packed
            # (vals, idx) FF operands stream through kernels/nm_spmm on
            # the pallas backend (nm_backend)
            diff, meta = split_compute(state["compute"])
            loss_fn = lambda d, b: run_model(merge_compute(d, meta), b)
            root = diff
            root_specs = _diff_pspecs(state["compute"], grad_pspecs) \
                if compress_on else None
        else:  # legacy dataflow: cast master, re-derive masks in FF/BP
            loss_fn = lambda mt, b: run_model(jax.tree.map(
                lambda w: w.astype(jnp.bfloat16), mt), b)
            root = state["master"]
            root_specs = grad_pspecs if compress_on else None
        if compress_on:
            n_pods = mesh.shape["pod"]
            sbatch = jax.tree.map(
                lambda x: _pod_split_batch(x, mesh, n_pods), batch)
            sroot = jax.tree.map(
                lambda x, s: _pod_stack(x, mesh, n_pods, s),
                root, root_specs)
            (total, (loss, aux)), groot = jax.vmap(
                jax.value_and_grad(loss_fn, has_aux=True))(sroot, sbatch)
            total, loss, aux = total.mean(), loss.mean(), aux.mean()
        else:
            (total, (loss, aux)), groot = jax.value_and_grad(
                loss_fn, has_aux=True)(root, batch)
        grads = sgd.pregen_grads(merge_compute(groot, meta)) if pregen \
            else groot
    if compress_on:
        gc_cfg = grad_sync or C.GradCompressConfig.from_sparsity(sp_cfg)
        key = jax.random.fold_in(jax.random.PRNGKey(0x5EED),
                                 state["step"])
        grads, new_err = C.cross_pod_sync(grads, state["err"], mesh,
                                          grad_pspecs, gc_cfg, key)
        state = dict(state, err=new_err)
    new_state, compute = sgd.update(
        state_core(state), grads, opt_cfg, sp_cfg, param_names=names,
        prev_compute=state.get("compute") if pregen else None,
        pregen=pregen, pack=pregen_pack, use_pallas=use_pallas)
    new_state = dict(state, **new_state)
    if pregen:
        new_state["compute"] = compute
    metrics = {"loss": loss, "aux": aux, "total": total,
               "lr": sgd.lr_schedule(opt_cfg, state["step"])}
    return new_state, metrics


def state_core(state):
    return {k: state[k] for k in ("master", "momentum", "step")}


def init_train_state(key, cfg, family="lm", compress=False, sp_cfg=None,
                     pregen=True, pregen_pack=False, mesh=None):
    """Real (allocating) state init for the trainer/examples.

    pregen=True bootstraps the pre-generated compute tree from master
    with ``sp_cfg``'s masks — pass the SAME sp_cfg the step builder got,
    or the state structure won't match the bundle's shardings.

    compress=True allocates the flat (n_pods, T_loc*S) error-feedback
    residual slab (optim/compress) — pass the mesh so n_pods and the
    per-device slab layout resolve (the width depends on the resolved
    master shardings); without one (or without a "pod" axis) a
    single-row slab is created.
    """
    if family == "encdec":
        params, specs = E.init(key, cfg)
    else:
        params, specs = T.init(key, cfg)
    state = sgd.init_state(params)
    if compress:
        n_pods = mesh.shape.get("pod", 1) if mesh is not None else 1
        m = sp_cfg.m if sp_cfg is not None else 8
        p_pspecs = None
        if mesh is not None:
            # the same N:M-aware resolution build_lm_train does: the EF
            # width is a function of the per-device leaf blocks
            p_pspecs = R.nm_params_pspecs(specs, R.TRAIN_RULES,
                                          state["master"], mesh, sp_cfg)
        state["err"] = jnp.zeros(
            (n_pods, C.err_state_elems(state["master"], m, mesh, p_pspecs)),
            jnp.float32)
    if pregen:
        state["compute"] = sgd.pregen_tree(state["master"], sp_cfg,
                                           pack=pregen_pack)
    return state


def encdec_train_step(state, batch, *, cfg, sp_cfg, opt_cfg, mesh, names,
                      pregen=True, pregen_pack=False, use_pallas=False,
                      nm_backend="auto"):
    def run_model(compute):
        enc = E.encode(compute, batch["frames"], cfg, sp_cfg)
        hidden, _ = E.decode(compute, batch["tokens"], enc, cfg, sp_cfg)
        logits = E.logits_from_hidden(compute, hidden, cfg)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][..., None],
                                   axis=-1)[..., 0]
        loss = (logz - gold).mean()
        return loss, loss

    with R.activation_sharding(mesh, R.batch_axes(mesh)), \
            O.backend_scope(nm_backend):
        if pregen:
            diff, meta = split_compute(state["compute"])
            (_, loss), gdiff = jax.value_and_grad(
                lambda d: run_model(merge_compute(d, meta)),
                has_aux=True)(diff)
            grads = sgd.pregen_grads(merge_compute(gdiff, meta))
        else:
            (_, loss), grads = jax.value_and_grad(
                lambda m: run_model(jax.tree.map(
                    lambda w: w.astype(jnp.bfloat16), m)),
                has_aux=True)(state["master"])
    new_state, compute = sgd.update(
        state_core(state), grads, opt_cfg, sp_cfg, param_names=names,
        prev_compute=state.get("compute") if pregen else None,
        pregen=pregen, pack=pregen_pack, use_pallas=use_pallas)
    new_state = dict(state, **new_state)
    if pregen:
        new_state["compute"] = compute
    return new_state, {"loss": loss, "lr": sgd.lr_schedule(opt_cfg, state["step"])}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def _serve_dp(mesh, long_context):
    """Batch axes for serving activations (None: 500k batch=1 decode)."""
    return None if (mesh is None or long_context) else R.batch_axes(mesh)


def lm_prefill_step(params, batch, *, cfg, sp_cfg, mesh=None,
                    long_context=False, last_index=None):
    """Prefill: build the KV cache and return next-token logits.

    last_index: optional (B,) int array of per-request *last real token*
    indices.  With right-padded prompts (the serve engine pads every
    prompt to one static bucket so prefill compiles once), logits must be
    read at each request's own final position, not at s-1.
    """
    b, s = batch["tokens"].shape
    prefix = batch.get("prefix_embeds")
    s_tot = s + (prefix.shape[1] if prefix is not None else 0)
    with R.activation_sharding(mesh, _serve_dp(mesh, long_context)):
        cache = T.init_lm_cache(cfg, b, s_tot)
        hidden, cache, _ = T.forward(params, batch["tokens"], cfg, sp_cfg,
                                     prefix_embeds=prefix, cache=cache)
        if last_index is None:
            h_last = hidden[:, -1:]
        else:
            idx = jnp.asarray(last_index, jnp.int32).reshape(b, 1, 1)
            h_last = jnp.take_along_axis(
                hidden, jnp.broadcast_to(idx, (b, 1, hidden.shape[-1])),
                axis=1)
        logits = T.logits_from_hidden(params, h_last, cfg)
    return logits, cache


def lm_decode_step(params, cache, token, pos, *, cfg, sp_cfg, mesh=None,
                   long_context=False, per_slot=False):
    """One decode step.

    pos: scalar — the classic synchronized batch (all rows at the same
    depth, shared cache cursor); or (B,) vector with per_slot=True — the
    continuous-batching mode where every row is an independent request
    slot at its own position (cache writes/masks are slot-indexed).
    """
    b = token.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    else:
        positions = pos.reshape(b, 1)
    with R.activation_sharding(mesh, _serve_dp(mesh, long_context)):
        hidden, new_cache, _ = T.forward(params, token, cfg, sp_cfg,
                                         cache=cache, decode=True,
                                         positions=positions,
                                         per_slot=per_slot)
        logits = T.logits_from_hidden(params, hidden, cfg)
    return logits, new_cache


def encdec_prefill_step(params, batch, *, cfg, sp_cfg, mesh=None):
    with R.activation_sharding(mesh, _serve_dp(mesh, False)):
        enc = E.encode(params, batch["frames"], cfg, sp_cfg)
        b, s = batch["tokens"].shape
        cache = E.init_cache(cfg, b, s)
        hidden, cache = E.decode(params, batch["tokens"], enc, cfg, sp_cfg,
                                 cache=cache)
        logits = E.logits_from_hidden(params, hidden[:, -1:], cfg)
    return logits, cache, enc


def encdec_decode_step(params, cache, enc_out, token, pos, *, cfg, sp_cfg,
                       mesh=None):
    b = token.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    with R.activation_sharding(mesh, _serve_dp(mesh, False)):
        hidden, new_cache = E.decode(params, token, enc_out, cfg, sp_cfg,
                                     cache=cache, decode_step=True,
                                     positions=positions)
        logits = E.logits_from_hidden(params, hidden, cfg)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Builders: resolve shardings + jit
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepBundle:
    step_fn: callable            # jitted
    state_shardings: object
    input_pspecs: dict
    names: list
    specs: object                # logical-axis tree
    mesh: Optional[Mesh] = None  # mesh the bundle was resolved against


def abstract_compute_tree(aparams, sp_cfg, pack=False):
    """ShapeDtypeStruct compute tree (zero allocation) for builders/dry-run."""
    return jax.eval_shape(
        partial(sgd.pregen_tree, sp_cfg=sp_cfg, pack=pack), aparams)


def _train_state_pspecs(p_pspecs, aparams, mesh, sp_cfg, *, compress,
                        pregen, pregen_pack):
    """State pspecs incl. the pre-generated compute tree; asserts that no
    resolved sharding splits an N:M group or a packed run."""
    state_pspecs = {"master": p_pspecs, "momentum": p_pspecs, "step": P()}
    if compress and "pod" in mesh.axis_names:
        state_pspecs["err"] = R.grad_sync_pspecs(mesh)["err"]
    if pregen:
        acompute = abstract_compute_tree(aparams, sp_cfg, pack=pregen_pack)
        c_pspecs = R.pregen_pspecs(acompute, p_pspecs)
        R.assert_nm_unsplit(c_pspecs, acompute, mesh, sp_cfg)
        state_pspecs["compute"] = c_pspecs
    return state_pspecs


def build_lm_train(cfg, mesh: Mesh, sp_cfg: SparsityConfig,
                   opt_cfg: sgd.SGDConfig, *, compress=False,
                   donate=True, seq_parallel=False, pregen=True,
                   pregen_pack=False, use_pallas=False,
                   nm_backend="auto", grad_sync=None) -> StepBundle:
    aparams, specs = T.init(jax.random.PRNGKey(0), cfg, abstract=True)
    rules = R.TRAIN_RULES
    # N:M-aware resolution: a mesh axis that would split an M-group
    # along a grouped weight axis is dropped, and the result is asserted
    p_pspecs = R.nm_params_pspecs(specs, rules, aparams, mesh, sp_cfg)
    R.assert_nm_unsplit(p_pspecs, aparams, mesh, sp_cfg)
    names = sgd._names_of(p_pspecs)
    state_pspecs = _train_state_pspecs(p_pspecs, aparams, mesh, sp_cfg,
                                       compress=compress, pregen=pregen,
                                       pregen_pack=pregen_pack)
    state_sh = jax.tree.map(lambda ps: NamedSharding(mesh, ps), state_pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    dp = R.batch_axes(mesh)
    in_pspecs = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.name.startswith("internvl"):
        in_pspecs["prefix_embeds"] = P(dp, None, None)
    batch_sh = jax.tree.map(lambda ps: NamedSharding(mesh, ps), in_pspecs,
                            is_leaf=lambda x: isinstance(x, P))

    fn = partial(lm_train_step, cfg=cfg, sp_cfg=sp_cfg, opt_cfg=opt_cfg,
                 mesh=mesh, names=names, compress=compress,
                 grad_pspecs=p_pspecs, seq_parallel=seq_parallel,
                 pregen=pregen, pregen_pack=pregen_pack,
                 use_pallas=use_pallas, nm_backend=nm_backend,
                 grad_sync=grad_sync)
    jitted = jax.jit(fn,
                     in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None),
                     donate_argnums=(0,) if donate else ())
    return StepBundle(jitted, state_sh, in_pspecs, names, specs, mesh)


def build_encdec_train(cfg, mesh: Mesh, sp_cfg, opt_cfg,
                       donate=True, pregen=True, pregen_pack=False,
                       use_pallas=False, nm_backend="auto") -> StepBundle:
    aparams, specs = E.init(jax.random.PRNGKey(0), cfg, abstract=True)
    p_pspecs = R.nm_params_pspecs(specs, R.TRAIN_RULES, aparams, mesh,
                                  sp_cfg)
    R.assert_nm_unsplit(p_pspecs, aparams, mesh, sp_cfg)
    names = sgd._names_of(p_pspecs)
    state_pspecs = _train_state_pspecs(p_pspecs, aparams, mesh, sp_cfg,
                                       compress=False, pregen=pregen,
                                       pregen_pack=pregen_pack)
    state_sh = jax.tree.map(lambda ps: NamedSharding(mesh, ps), state_pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    dp = R.batch_axes(mesh)
    in_pspecs = {"frames": P(dp, None, None), "tokens": P(dp, None),
                 "labels": P(dp, None)}
    batch_sh = jax.tree.map(lambda ps: NamedSharding(mesh, ps), in_pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    fn = partial(encdec_train_step, cfg=cfg, sp_cfg=sp_cfg, opt_cfg=opt_cfg,
                 mesh=mesh, names=names, pregen=pregen,
                 pregen_pack=pregen_pack, use_pallas=use_pallas,
                 nm_backend=nm_backend)
    jitted = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None),
                     donate_argnums=(0,) if donate else ())
    return StepBundle(jitted, state_sh, in_pspecs, names, specs, mesh)


def restore_with_pregen(mgr, like_state, step=None, shardings=None, *,
                        sp_cfg=None, pregen_pack=False):
    """Checkpoint restore that upgrades older-dataflow checkpoints.

    Two generations of checkpoint mismatch the current state tree:
      * pre-pregen — no ``compute`` leaf at all;
      * dict-sites-only pregen — a ``compute`` tree whose ``{"w": ...}``
        sites are operand dicts but whose bare-array MoE expert leaves
        are still plain bf16 copies (``pregen_tree(bare_sites=False)``
        reproduces that structure).
    Either way the legacy subtree (master/momentum/step[/err]) restores
    and the compute tree regenerates from the restored master — the
    pre-generated operands are a pure function of master, so both
    upgrades are exact.
    """
    try:
        return mgr.restore(like_state, step=step, shardings=shardings)
    except ValueError as full_err:
        legacy_like = {k: v for k, v in like_state.items() if k != "compute"}
        legacy_sh = None if shardings is None else \
            {k: v for k, v in shardings.items() if k != "compute"}
        attempts = [(legacy_like, legacy_sh)]
        if "compute" in like_state:
            old_compute = jax.eval_shape(
                partial(sgd.pregen_tree, sp_cfg=sp_cfg, pack=pregen_pack,
                        bare_sites=False), legacy_like["master"])
            if (jax.tree_util.tree_structure(old_compute)
                    != jax.tree_util.tree_structure(like_state["compute"])):
                old_sh = None if shardings is None else dict(
                    legacy_sh, compute=_old_compute_shardings(
                        old_compute, shardings["compute"],
                        shardings["master"]))
                attempts.append((dict(legacy_like, compute=old_compute),
                                 old_sh))
        restored = None
        for like, sh in attempts:
            try:
                restored = mgr.restore(like, step=step, shardings=sh)
                break
            except ValueError:
                continue
        if restored is None:
            # no upgrade structure matches either (arch / compress /
            # pack-mode mismatch): surface the original full-structure
            # error, not a misleading legacy-subtree one
            raise full_err from None
        out = {k: v for k, v in restored.items() if k != "compute"}
        out["compute"] = sgd.pregen_tree(out["master"], sp_cfg,
                                         pack=pregen_pack)
        if shardings is not None:
            out = {k: jax.device_put(out[k], shardings[k]) for k in out}
        return out


def _old_compute_shardings(old_compute, new_compute_sh, master_sh):
    """Shardings for a dict-sites-only (pre-MoE) compute structure, so
    the upgrade restore never stages leaves on one device: dict sites
    (PregenOp there and now) match the current compute shardings
    leaf-for-leaf; bare expert leaves (plain bf16 copies there, PregenOp
    operands now) shard like their master weight (same shape)."""
    def walk(old_node, new_sh, m_sh):
        if isinstance(old_node, O.SparseOperand):
            return new_sh  # dict sites kept their operand structure
        if isinstance(old_node, dict):
            return {k: walk(old_node[k],
                            new_sh[k] if isinstance(new_sh, dict) else new_sh,
                            m_sh[k] if isinstance(m_sh, dict) else m_sh)
                    for k in old_node}
        # array leaf: a matching leaf sharding, else the master weight's
        return new_sh \
            if not isinstance(new_sh, (dict, O.SparseOperand)) else m_sh

    return walk(old_compute, new_compute_sh, master_sh)


def build_lm_serve(cfg, mesh: Mesh, sp_cfg: SparsityConfig, input_specs,
                   *, long_context=False, prefill=False,
                   packed=False) -> StepBundle:
    """packed=True: serve from shared-mode pre-gathered N:M weights —
    reduced-K matmuls (M/N x fewer FLOPs AND weight bytes).  The param
    tree (and its shardings) is transformed by bdwp.pack_tree_shared;
    callers pack real weights with the same function."""
    from repro.core import bdwp as B

    aparams, specs = T.init(jax.random.PRNGKey(0), cfg, abstract=True)
    rules = R.SERVE_LONG_RULES if long_context else R.SERVE_BATCH_RULES
    p_pspecs = R.nm_params_pspecs(specs, rules, aparams, mesh, sp_cfg)
    check_tree = aparams
    if packed:
        check_tree, p_pspecs = B.pack_tree_shared(aparams, sp_cfg,
                                                  pspecs=p_pspecs)
    R.assert_nm_unsplit(p_pspecs, check_tree, mesh, sp_cfg)
    param_sh = jax.tree.map(lambda ps: NamedSharding(mesh, ps), p_pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    in_pspecs = R.serve_input_pspecs(input_specs, mesh,
                                     long_context=long_context)
    in_sh = jax.tree.map(lambda ps: NamedSharding(mesh, ps), in_pspecs,
                         is_leaf=lambda x: isinstance(x, P))
    if prefill:
        fn = partial(lm_prefill_step, cfg=cfg, sp_cfg=sp_cfg, mesh=mesh,
                     long_context=long_context)
        jitted = jax.jit(fn, in_shardings=(param_sh, in_sh))
    else:
        fn = partial(lm_decode_step, cfg=cfg, sp_cfg=sp_cfg, mesh=mesh,
                     long_context=long_context)
        jitted = jax.jit(
            fn,
            in_shardings=(param_sh, in_sh["cache"], in_sh["token"],
                          in_sh["pos"]),
            out_shardings=(None, in_sh["cache"]),
            donate_argnums=(1,),
        )
    return StepBundle(jitted, param_sh, in_pspecs, [], specs, mesh)


def build_encdec_serve(cfg, mesh: Mesh, sp_cfg, input_specs, *,
                       prefill=False) -> StepBundle:
    aparams, specs = E.init(jax.random.PRNGKey(0), cfg, abstract=True)
    p_pspecs = R.params_pspecs(specs, R.SERVE_BATCH_RULES, aparams, mesh)
    param_sh = jax.tree.map(lambda ps: NamedSharding(mesh, ps), p_pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    in_pspecs = R.serve_input_pspecs(input_specs, mesh, long_context=False)
    in_sh = jax.tree.map(lambda ps: NamedSharding(mesh, ps), in_pspecs,
                         is_leaf=lambda x: isinstance(x, P))
    if prefill:
        fn = partial(encdec_prefill_step, cfg=cfg, sp_cfg=sp_cfg, mesh=mesh)
        jitted = jax.jit(fn, in_shardings=(param_sh, in_sh))
    else:
        fn = partial(encdec_decode_step, cfg=cfg, sp_cfg=sp_cfg, mesh=mesh)
        jitted = jax.jit(
            fn,
            in_shardings=(param_sh, in_sh["cache"], in_sh["enc_out"],
                          in_sh["token"], in_sh["pos"]),
            out_shardings=(None, in_sh["cache"]),
            donate_argnums=(1,),
        )
    return StepBundle(jitted, param_sh, in_pspecs, [], specs, mesh)
