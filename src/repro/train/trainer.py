"""Training loop: step fn + data + checkpoints + fault tolerance.

The loop a launcher drives.  Composes:
  * StepBundle (jitted train step with resolved shardings),
  * synthetic (or user) data stream placed under input shardings,
  * CheckpointManager (async atomic saves every ``ckpt_every``),
  * StragglerMonitor + Heartbeat,
  * auto-resume (elastic: restores onto whatever mesh is current).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, Optional

import jax

from repro.train.checkpoint import CheckpointManager
from repro.train.fault import Heartbeat, StragglerMonitor


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    heartbeat_path: Optional[str] = None
    straggler_threshold: float = 2.0


def train_steps(bundle, state, data_iter: Iterator, n_steps: int):
    """Bare loop: n_steps through the jitted step, no ckpt/heartbeat.

    The parity tests and the SPMD benchmark drive this — same step fn
    the full ``fit`` loop uses, minus host-side machinery, returning the
    final state and the per-step metrics (still device values; callers
    ``float()`` what they need).
    """
    history = []
    for _ in range(n_steps):
        _, batch = next(data_iter)
        state, metrics = bundle.step_fn(state, batch)
        history.append(metrics)
    jax.block_until_ready(state)
    return state, history


def fit(bundle, state, data_iter: Iterator, tcfg: TrainerConfig,
        log_fn: Callable = print):
    """Runs the loop; returns (final_state, history).

    All bookkeeping is keyed off the optimizer step (``state["step"]``),
    NOT the data iterator's counter: after an auto-resume the iterator
    may restart at 0 while the restored state does not, and keying
    checkpoints by the iterator step made filenames collide/regress and
    misfired the save guard.  A stale iterator is fast-forwarded instead
    (skipped batches are cheap — the synthetic stream is seeded per
    step), so resumed runs see the exact continuation of the stream.
    """
    ckpt = CheckpointManager(tcfg.ckpt_dir) if tcfg.ckpt_dir else None
    hb = Heartbeat(tcfg.heartbeat_path) if tcfg.heartbeat_path else None
    mon = StragglerMonitor(tcfg.straggler_threshold)
    history = []
    cur = int(state["step"])  # authoritative; advances with each update
    last_saved = None         # step of the most recent periodic save
    for it_step, batch in data_iter:
        if it_step < cur:  # stale iterator after a resume: fast-forward
            continue
        if cur >= tcfg.total_steps:
            break
        t0 = time.perf_counter()
        state, metrics = bundle.step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        straggler = mon.record(cur, dt)
        rec = {"step": cur, "loss": float(metrics["loss"]),
               "sec": dt, "straggler": straggler}
        history.append(rec)
        if hb is not None:
            hb.beat(cur, loss=rec["loss"])
        if straggler:
            log_fn(f"[straggler] step {cur}: {dt:.3f}s "
                   f"(mean {mon.mean:.3f}s)")
        if cur % tcfg.log_every == 0:
            log_fn(f"step {cur:5d} loss {rec['loss']:.4f} {dt*1e3:.1f}ms")
        cur += 1  # == int(state["step"]) without a device sync
        if ckpt is not None and cur % tcfg.ckpt_every == 0:
            ckpt.save(cur, state)
            last_saved = cur
    if ckpt is not None:
        # final snapshot — but when the loop's last periodic save already
        # covered this step (total_steps % ckpt_every == 0), saving it
        # AGAIN would race the still-async writer on the same
        # step_XXXX.tmp; just wait for that writer to commit instead
        if last_saved == cur:
            ckpt.wait()
        else:
            ckpt.save(cur, state, blocking=True)
    return state, history
