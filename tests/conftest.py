"""Shared test plumbing.

``require_or_skip`` replaces the bare ``pytest.importorskip`` for
optional dev deps (hypothesis): locally a missing dep still skips the
module so bare envs stay usable, but with ``REQUIRE_HYPOTHESIS=1`` —
exported by the pinned-deps CI jobs, whose requirements-dev.txt installs
hypothesis — the same absence FAILS collection instead of silently
skipping.  A dropped dev pin can no longer turn the property suites
into a green no-op.
"""

import importlib
import os

import pytest


def require_or_skip(module: str):
    if os.environ.get("REQUIRE_HYPOTHESIS"):
        return importlib.import_module(module)  # ImportError -> loud fail
    return pytest.importorskip(module)
