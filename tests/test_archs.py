"""Per-arch smoke tests: every assigned architecture instantiates its
REDUCED config and runs one forward/train step on CPU — output shapes
check out and nothing is NaN.  (The FULL configs are exercised only via
the dry-run: ShapeDtypeStruct, no allocation.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.core.sparsity import SparsityConfig
from repro.data import synthetic as D
from repro.launch.mesh import make_host_mesh
from repro.optim import sgd
from repro.train import step as ST

jax.config.update("jax_platform_name", "cpu")

SP = SparsityConfig(n=2, m=8, method="bdwp")
OPT = sgd.SGDConfig(lr=0.05, total_steps=10)


def _finite(tree):
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_smoke_train_step(arch_id, mesh):
    arch = get_arch(arch_id)
    cfg = arch.smoke
    if arch.family == "encdec":
        bundle = ST.build_encdec_train(cfg, mesh, SP, OPT, donate=False)
    else:
        bundle = ST.build_lm_train(cfg, mesh, SP, OPT, donate=False)
    state = jax.device_put(
        ST.init_train_state(jax.random.PRNGKey(0), cfg, family=arch.family,
                            sp_cfg=SP),
        bundle.state_shardings)
    if arch.family == "encdec":
        stream = D.encdec_stream(cfg.vocab, 2, 32, cfg.d_model, enc_frames=16)
    else:
        prefix = 8 if arch.prefix_len else 0
        stream = D.lm_stream(cfg.vocab, 2, 32, prefix=prefix,
                             d_model=cfg.d_model)
    _, batch = next(iter(stream))
    new_state, metrics = bundle.step_fn(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(new_state["step"]) == 1
    assert _finite(new_state["master"])


@pytest.mark.parametrize("arch_id", ["qwen3-8b", "mamba2-370m",
                                     "hymba-1.5b", "deepseek-v2-lite-16b"])
def test_smoke_decode_step(arch_id, mesh):
    """Prefill + one decode token on the smoke config."""
    from repro.models import transformer_lm as T

    arch = get_arch(arch_id)
    cfg = arch.smoke
    params, _ = T.init(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(lambda w: w.astype(jnp.bfloat16), params)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    logits, cache = ST.lm_prefill_step(params, {"tokens": tokens},
                                       cfg=cfg, sp_cfg=SP)
    assert logits.shape == (b, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits[..., :cfg.vocab]).all())
    # grow cache to s+1 so decode has a slot
    full = T.init_lm_cache(cfg, b, s + 1)

    def seat(dst, src):
        if dst.ndim == 0 or dst.shape == src.shape:
            return src.astype(dst.dtype)
        sl = tuple(slice(0, d) for d in src.shape)
        return dst.at[sl].set(src.astype(dst.dtype))

    cache = jax.tree.map(seat, full, cache)
    tok = jnp.argmax(logits[:, -1, :cfg.vocab], -1)[:, None].astype(jnp.int32)
    logits2, cache2 = ST.lm_decode_step(params, cache, tok,
                                        jnp.asarray(s, jnp.int32),
                                        cfg=cfg, sp_cfg=SP)
    assert logits2.shape == (b, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits2[..., :cfg.vocab]).all())


def test_archs_cover_assignment():
    assert sorted(ARCHS) == sorted([
        "qwen3-8b", "qwen2.5-32b", "glm4-9b", "gemma3-12b",
        "whisper-large-v3", "granite-moe-1b-a400m", "deepseek-v2-lite-16b",
        "mamba2-370m", "hymba-1.5b", "internvl2-26b"])


def test_full_configs_match_assignment():
    a = get_arch("qwen3-8b").full
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv, a.d_ff, a.vocab) == \
        (36, 4096, 32, 8, 12288, 151936) and a.qk_norm
    b = get_arch("qwen2.5-32b").full
    assert (b.n_layers, b.d_model, b.n_heads, b.n_kv, b.d_ff, b.vocab) == \
        (64, 5120, 40, 8, 27648, 152064) and b.qkv_bias
    c = get_arch("deepseek-v2-lite-16b").full
    assert c.kv_lora == 512 and c.moe.top_k == 6 and c.moe.n_shared == 2
    d = get_arch("mamba2-370m").full
    assert d.ssm_state == 128 and not d.has_attn
    e = get_arch("gemma3-12b").full
    assert e.pattern.count("swa") == 5 and e.pattern.count("attn") == 1
    f = get_arch("granite-moe-1b-a400m").full
    assert f.moe.n_experts == 32 and f.moe.top_k == 8
