"""Gradient-semantics tests for BDWP/SR-STE/SDGP/SDWP custom VJPs.

These check Algorithm 1 line-by-line: which operand is pruned, along
which axis, in each of FF / BP / WU — for both the matmul and conv views.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bdwp
from repro.core.sparsity import DENSE, SparsityConfig, sparsify

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


@pytest.fixture(scope="module")
def xwg():
    x = _rand((4, 32), 0)
    w = _rand((32, 16), 1)
    g = _rand((4, 16), 2)
    return x, w, g


def _vjp(fn, x, w, g):
    y, pull = jax.vjp(fn, x, w)
    dx, dw = pull(g)
    return y, dx, dw


CFGS = {
    "dense": SparsityConfig(method="dense"),
    "srste": SparsityConfig(n=2, m=8, method="srste"),
    "sdgp": SparsityConfig(n=2, m=8, method="sdgp"),
    "sdwp": SparsityConfig(n=2, m=8, method="sdwp"),
    "bdwp": SparsityConfig(n=2, m=8, method="bdwp"),
}


class TestLinearSemantics:
    def test_dense_matches_matmul(self, xwg):
        x, w, g = xwg
        y, dx, dw = _vjp(lambda a, b: bdwp.nm_linear(a, b, CFGS["dense"]), x, w, g)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(g @ w.T), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(x.T @ g), rtol=1e-6)

    @pytest.mark.parametrize("method", ["srste", "bdwp"])
    def test_ff_uses_input_axis_pruned_weights(self, xwg, method):
        x, w, g = xwg
        cfg = CFGS[method]
        y = bdwp.nm_linear(x, w, cfg)
        w_ff = sparsify(w, cfg, axis=0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w_ff), rtol=1e-6)

    @pytest.mark.parametrize("method", ["sdgp", "sdwp"])
    def test_ff_dense_for_backward_only_methods(self, xwg, method):
        x, w, g = xwg
        y = bdwp.nm_linear(x, w, CFGS[method])
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-6)

    @pytest.mark.parametrize("method", ["sdwp", "bdwp"])
    def test_bp_uses_output_axis_pruned_weights(self, xwg, method):
        x, w, g = xwg
        cfg = CFGS[method]
        _, dx, _ = _vjp(lambda a, b: bdwp.nm_linear(a, b, cfg), x, w, g)
        w_bp = sparsify(w, cfg, axis=1)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(g @ w_bp.T), rtol=1e-6)

    def test_sdgp_prunes_output_gradients(self, xwg):
        x, w, g = xwg
        cfg = CFGS["sdgp"]
        _, dx, dw = _vjp(lambda a, b: bdwp.nm_linear(a, b, cfg), x, w, g)
        g_sp = sparsify(g, cfg, axis=-1)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(g_sp @ w.T), rtol=1e-6)
        # WU stays dense even for SDGP (Table II: one pass saved only)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(x.T @ g), rtol=1e-6)

    @pytest.mark.parametrize("method", ["srste", "sdwp", "bdwp"])
    def test_wu_always_dense_straight_through(self, xwg, method):
        x, w, g = xwg
        _, _, dw = _vjp(lambda a, b: bdwp.nm_linear(a, b, CFGS[method]), x, w, g)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(x.T @ g), rtol=1e-6)

    def test_batched_inputs(self):
        x = _rand((2, 3, 32), 5)
        w = _rand((32, 16), 6)
        cfg = CFGS["bdwp"]
        y, pull = jax.vjp(lambda a, b: bdwp.nm_linear(a, b, cfg), x, w)
        g = _rand(y.shape, 7)
        dx, dw = pull(g)
        assert dx.shape == x.shape and dw.shape == w.shape
        g2 = g.reshape(-1, 16)
        x2 = x.reshape(-1, 32)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(x2.T @ g2), rtol=1e-5)


class TestConvSemantics:
    def setup_method(self):
        self.x = _rand((2, 8, 8, 16), 0)
        self.w = _rand((3, 3, 16, 8), 1)

    def test_dense_matches_lax_conv(self):
        y = bdwp.nm_conv(self.x, self.w, DENSE)
        ref = jax.lax.conv_general_dilated(
            self.x, self.w, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5)

    def test_ff_prunes_input_channels(self):
        cfg = SparsityConfig(n=2, m=8, method="bdwp")
        y = bdwp.nm_conv(self.x, self.w, cfg)
        w_ff = sparsify(self.w, cfg, axis=2)
        ref = jax.lax.conv_general_dilated(
            self.x, w_ff, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5)

    def test_bp_prunes_output_channels(self):
        cfg = SparsityConfig(n=2, m=8, method="bdwp")
        y, pull = jax.vjp(lambda x, w: bdwp.nm_conv(x, w, cfg), self.x, self.w)
        g = _rand(y.shape, 3)
        dx, dw = pull(g)
        # reference dgrad: vjp of conv with out-channel-pruned weights
        w_bp = sparsify(self.w, cfg, axis=3)
        _, pull_ref = jax.vjp(
            lambda x: jax.lax.conv_general_dilated(
                x, w_bp, (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC")), self.x)
        (dx_ref,) = pull_ref(g)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ref), rtol=1e-5)
        # wgrad dense straight-through
        _, pull_w = jax.vjp(
            lambda w: jax.lax.conv_general_dilated(
                self.x, w, (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC")), self.w)
        (dw_ref,) = pull_w(g)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_ref), rtol=1e-5)

    def test_strided(self):
        cfg = SparsityConfig(n=2, m=8, method="bdwp")
        y = bdwp.nm_conv(self.x, self.w, cfg, 2, "SAME")
        assert y.shape == (2, 4, 4, 8)


class TestEligibility:
    def test_excludes_by_name(self):
        cfg = SparsityConfig(n=2, m=8)
        assert not bdwp.should_prune("tok_embed", (1024, 512), cfg)
        assert not bdwp.should_prune("moe/router/w", (1024, 8), cfg)
        assert not bdwp.should_prune("ln/norm_scale", (1024,), cfg)
        assert bdwp.should_prune("attn/q_proj", (1024, 512), cfg)

    def test_excludes_indivisible(self):
        cfg = SparsityConfig(n=2, m=8)
        assert not bdwp.should_prune("mlp/w1", (1023, 512), cfg)

    def test_dense_cfg_never_prunes(self):
        assert not bdwp.should_prune("mlp/w1", (1024, 512), DENSE)


class TestFlopAccounting:
    def test_bdwp_2_8_saves_half_of_training_macs(self):
        cfg = SparsityConfig(n=2, m=8, method="bdwp")
        acc = bdwp.train_macs_per_matmul(512, 1024, 1024, cfg)
        # FF 0.25 + BP 0.25 + WU 1.0 of dense third each -> 50% total
        assert acc["total"] / acc["dense_total"] == pytest.approx(0.5)

    def test_uni_directional_saves_quarter(self):
        for method in ("srste", "sdgp", "sdwp"):
            cfg = SparsityConfig(n=2, m=8, method=method)
            acc = bdwp.train_macs_per_matmul(512, 1024, 1024, cfg)
            assert acc["total"] / acc["dense_total"] == pytest.approx(0.75)

    def test_dense_identity(self):
        acc = bdwp.train_macs_per_matmul(4, 8, 16, DENSE)
        assert acc["total"] == acc["dense_total"]


class TestTrainingConvergenceSmoke:
    def test_bdwp_descends_on_quadratic(self):
        """A few steps of BDWP training reduce a least-squares loss."""
        key = jax.random.PRNGKey(0)
        w_true = jax.random.normal(key, (32, 8))
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        y = x @ w_true
        cfg = SparsityConfig(n=2, m=8, method="bdwp")

        def loss(w):
            return jnp.mean((bdwp.nm_linear(x, w, cfg) - y) ** 2)

        w = jnp.zeros((32, 8))
        l0 = loss(w)
        for _ in range(50):
            w = w - 0.05 * jax.grad(loss)(w)
        l1 = loss(w)
        assert float(l1) < 0.5 * float(l0)

    def test_all_methods_finite_grads(self):
        x = _rand((8, 32), 0)
        w = _rand((32, 16), 1)
        for cfg in CFGS.values():
            d = jax.grad(lambda w, c=cfg: bdwp.nm_linear(x, w, c).sum())(w)
            assert bool(jnp.isfinite(d).all())
