"""Data pipeline determinism + optimizer (WUVE) semantics + gradient
compression error feedback."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bdwp
from repro.core.sparsity import SparsityConfig, nm_mask
from repro.data import synthetic as D
from repro.optim import sgd
from repro.optim.compress import compress_leaf

jax.config.update("jax_platform_name", "cpu")


class TestDataDeterminism:
    def test_same_seed_same_stream(self):
        a = next(iter(D.lm_stream(512, 2, 16, seed=3)))[1]
        b = next(iter(D.lm_stream(512, 2, 16, seed=3)))[1]
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))

    def test_resume_exactness(self):
        """Restarting at step k reproduces the same batch — checkpointed
        runs see the identical stream."""
        s1 = D.lm_stream(512, 2, 16, seed=1)
        batches = [next(iter([next(s1)]))[1] for _ in range(5)]
        s2 = D.lm_stream(512, 2, 16, seed=1, start=3)
        step, b3 = next(s2)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(b3["tokens"]),
                                      np.asarray(batches[3]["tokens"]))

    def test_labels_are_next_tokens(self):
        _, b = next(iter(D.lm_stream(512, 2, 16, seed=0)))
        assert b["tokens"].shape == b["labels"].shape

    def test_copy_structure_learnable(self):
        cfg = D.TokenTaskConfig(vocab=512, seq=64, batch=4, copy_period=16)
        toks, _ = D.token_batch(cfg, 0)
        np.testing.assert_array_equal(toks[:, 16], toks[:, 0])

    def test_encdec_stream_shapes(self):
        _, b = next(iter(D.encdec_stream(100, 2, 8, 32, enc_frames=16)))
        assert b["frames"].shape == (2, 16, 32)
        assert b["frames"].dtype == jnp.bfloat16


class TestWUVE:
    CFG = sgd.SGDConfig(lr=0.1, momentum=0.9, weight_decay=0.0,
                        warmup_steps=0, total_steps=10**9, min_lr_frac=1.0)

    def test_momentum_semantics(self):
        state = {"master": {"w": jnp.ones((2, 8))},
                 "momentum": {"w": jnp.zeros((2, 8))},
                 "step": jnp.asarray(0, jnp.int32)}
        g = {"w": jnp.full((2, 8), 0.5)}
        sp = SparsityConfig(method="dense")
        s1, compute = sgd.update(state, g, self.CFG, sp)
        np.testing.assert_allclose(np.asarray(s1["momentum"]["w"]), 0.5)
        np.testing.assert_allclose(np.asarray(s1["master"]["w"]),
                                   1.0 - 0.1 * 0.5, rtol=1e-6)
        assert compute["w"].dtype == jnp.bfloat16  # pre-generated copy

    def test_srste_decay_targets_pruned_only(self):
        """SR-STE: lam*(1-mask)*w added to the gradient (Zhou et al.)."""
        sp = SparsityConfig(n=1, m=4, method="bdwp", lam=0.1)
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (8, 8))
        state = {"master": {"proj": w}, "momentum": {"proj": jnp.zeros_like(w)},
                 "step": jnp.asarray(0, jnp.int32)}
        g = {"proj": jnp.zeros_like(w)}
        s1, _ = sgd.update(state, g, self.CFG, sp)
        mask = nm_mask(w, 1, 4, axis=0)
        moved = np.asarray(s1["master"]["proj"] != w)
        # pruned weights decay; kept weights see zero gradient -> unchanged
        np.testing.assert_array_equal(moved, ~np.asarray(mask))


class TestGradCompression:
    def test_error_feedback_conserves_signal(self):
        """sparse + new_err == g + old_err exactly (unbiased over time)."""
        g = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
        err = jax.random.normal(jax.random.PRNGKey(1), (4, 64)) * 0.1
        sparse, new_err = compress_leaf(g, err, 2, 8)
        np.testing.assert_allclose(np.asarray(sparse + new_err),
                                   np.asarray(g + err), rtol=1e-6)

    def test_compression_ratio(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (16, 128))
        sparse, _ = compress_leaf(g, jnp.zeros_like(g), 2, 8)
        assert float((sparse != 0).mean()) <= 2 / 8 + 1e-6

    def test_residual_flushes_over_steps(self):
        """Error feedback conserves mass exactly across steps: everything
        not yet transmitted sits in the residual, nothing is lost."""
        g = jnp.ones((2, 16))
        err = jnp.zeros_like(g)
        sent = jnp.zeros_like(g)
        n_steps = 8
        for _ in range(n_steps):
            s, err = compress_leaf(g, err, 2, 8)
            sent = sent + s
        np.testing.assert_allclose(np.asarray(sent + err),
                                   np.asarray(g * n_steps), rtol=1e-6)
        # and the transmitted mean is close to the true mean (rotation)
        assert abs(float(sent.mean()) / n_steps - 1.0) < 0.3
