"""The docs lint is a tier-1 test, not just a CI step: a PR that
renames a module without updating README/ROADMAP/docs fails locally."""

import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LINT = os.path.join(ROOT, "tools", "docs_lint.py")


def test_docs_reference_only_live_paths():
    proc = subprocess.run([sys.executable, LINT], cwd=ROOT,
                          capture_output=True, text=True)
    assert proc.returncode == 0, (
        f"docs lint failed:\n{proc.stdout}\n{proc.stderr}")


def test_lint_catches_a_dead_reference(tmp_path):
    # the checker itself must not be a rubber stamp: a doc naming a
    # nonexistent module and a broken relative link must both fail
    import importlib.util
    spec = importlib.util.spec_from_file_location("docs_lint", LINT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    bad = tmp_path / "bad.md"
    bad.write_text("see `src/repro/not/a/module.py` and "
                   "[schema](missing_page.md)\n")
    failures = mod.check_doc(str(bad))
    assert len(failures) == 2
    assert any("not on disk" in f for f in failures)
    assert any("does not resolve" in f for f in failures)

    good = tmp_path / "good.md"
    good.write_text("plain prose, a web [link](https://example.com), "
                    "and an artifact glob results/dryrun/*.json\n")
    assert mod.check_doc(str(good)) == []


def _lint_module():
    import importlib.util
    spec = importlib.util.spec_from_file_location("docs_lint", LINT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestBenchFieldCheck:
    def test_documented_fields_exist_in_committed_results(self):
        mod = _lint_module()
        doc = os.path.join(ROOT, "docs", "benchmarks.md")
        assert mod.check_bench_fields(doc) == []

    def test_fiction_field_fails(self, tmp_path):
        mod = _lint_module()
        doc = tmp_path / "schema.md"
        doc.write_text(
            "## `results/BENCH_pregen.json` — `benchmarks/pregen_bench.py`\n"
            "| field | meaning |\n|---|---|\n"
            "| `mask_ops.pregen` | real |\n"
            "| `mask_ops.invented_metric` | fiction |\n")
        failures = mod.check_bench_fields(str(doc))
        assert len(failures) == 1
        assert "invented_metric" in failures[0]

    def test_uncommitted_bench_file_fails(self, tmp_path):
        mod = _lint_module()
        doc = tmp_path / "schema.md"
        doc.write_text("## `results/BENCH_not_a_bench.json` — x\n"
                       "| field | meaning |\n|---|---|\n"
                       "| `anything` | — |\n")
        failures = mod.check_bench_fields(str(doc))
        assert len(failures) == 1
        assert "neither" in failures[0]

    def test_token_grammar_expansion(self):
        mod = _lint_module()
        assert mod._expand_field("a.{x,y}.z", "") == ["a.x.z", "a.y.z"]
        assert mod._expand_field("loads[]", "") == ["loads"]
        assert mod._expand_field(".packed", "mask_ops") == [
            "mask_ops.packed"]
        assert mod._expand_field("projections.<site>.layers", "") == [
            "projections.*.layers"]
