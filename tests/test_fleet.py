"""Serve-fleet tests: cache store, KV-affinity routing, disaggregation.

The fleet extends the engine's load-bearing property one level up: which
replica serves a request — and whether its prefill ran on a dedicated
prefill engine — must be invisible in the token stream.  Routing may
only change WHERE work runs (and how much prefill compute repeats),
never WHAT comes out.
"""

import asyncio
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.sparsity import SparsityConfig
from repro.models import transformer_lm as T
from repro.serve import (AsyncFrontend, CacheStore, FleetConfig, Lane,
                         Router, ServeConfig, ServeEngine, ServeFleet,
                         prefix_chain)
from repro.serve.cache_store import match_depth

jax.config.update("jax_platform_name", "cpu")

ARCH = get_arch("qwen3-8b")
CFG = ARCH.smoke
SP = SparsityConfig(n=2, m=8, method="bdwp")
SERVE = ServeConfig(n_slots=2, max_len=32, prompt_bucket=12)
MAX_NEW = 6


@pytest.fixture(scope="module")
def params():
    p, _ = T.init(jax.random.PRNGKey(0), CFG)
    return jax.tree.map(lambda w: w.astype(jnp.bfloat16), p)


def _prompts(lens, seed=11):
    key = jax.random.PRNGKey(seed)
    return [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                          (n,), 0, CFG.vocab))
            for i, n in enumerate(lens)]


PROMPTS_LENS = (4, 8, 6)


@pytest.fixture(scope="module")
def prompts():
    return _prompts(PROMPTS_LENS)


@pytest.fixture(scope="module")
def solo_refs(params, prompts):
    """Reference streams: each prompt decoded alone on one engine."""
    eng = ServeEngine(params, CFG, SP, SERVE)
    refs = []
    for p in prompts:
        rid = eng.submit(p, max_new_tokens=MAX_NEW)
        refs.append(eng.run()[rid])
        eng.reset()
    return refs


class TestPrefixChain:
    def test_chain_blocks_and_equality(self):
        a = prefix_chain(range(10), block=4)
        assert len(a) == 3  # 4 + 4 + 2
        b = prefix_chain(list(range(10)), block=4)
        assert a == b  # container/int-type agnostic
        c = prefix_chain(range(11), block=4)
        assert a[:2] == c[:2] and a[2] != c[2]

    def test_partial_block_not_confused_with_full(self):
        # [1,2] and [1,2,0,0] share no digest: length is hashed in
        assert prefix_chain([1, 2], 4)[0] != prefix_chain([1, 2, 0, 0], 4)[0]

    def test_match_depth(self):
        a = prefix_chain(range(12), block=4)
        b = prefix_chain(list(range(8)) + [99, 99, 99, 99], block=4)
        assert match_depth(a, b) == 2
        assert match_depth(a, a) == 3
        assert match_depth(a, ()) == 0

    def test_block_validation(self):
        with pytest.raises(ValueError):
            prefix_chain([1], block=0)


class TestCacheStore:
    def _lane(self, key):
        return Lane(key=tuple(key), cache=None, next_token=0, pos=1)

    def test_put_get_pop(self):
        cs = CacheStore(capacity=4)
        lane = self._lane(("a",))
        cs.put(lane)
        assert ("a",) in cs and len(cs) == 1
        assert cs.get(("a",)) is lane       # get keeps the lane (reuse)
        assert cs.get(("a",)) is lane
        assert cs.pop(("a",)) is lane       # pop removes it (handoff)
        assert cs.get(("a",)) is None and len(cs) == 0
        st = cs.stats()
        assert (st["hits"], st["misses"], st["puts"]) == (2, 1, 1)

    def test_lru_eviction_and_recency_refresh(self):
        cs = CacheStore(capacity=2)
        cs.put(self._lane(("a",)))
        cs.put(self._lane(("b",)))
        cs.get(("a",))                 # refresh: "b" is now oldest
        cs.put(self._lane(("c",)))
        assert ("a",) in cs and ("c",) in cs and ("b",) not in cs
        assert cs.stats()["evictions"] == 1

    def test_reput_same_key_no_eviction(self):
        cs = CacheStore(capacity=1)
        cs.put(self._lane(("a",)))
        cs.put(self._lane(("a",)))
        assert cs.stats()["evictions"] == 0 and len(cs) == 1

    def test_match_depth_over_pool(self):
        cs = CacheStore(capacity=4)
        cs.put(self._lane(prefix_chain(range(8), 4)))
        assert cs.match_depth(prefix_chain(range(8), 4)) == 2
        assert cs.match_depth(
            prefix_chain(list(range(4)) + [7, 7, 7, 7], 4)) == 1
        assert cs.match_depth(prefix_chain([5, 5], 4)) == 0

    def test_capacity_validation(self):
        with pytest.raises(ValueError):
            CacheStore(capacity=0)


class TestEnginePrefixReuse:
    def test_repeated_prompt_prefills_once(self, params, prompts, solo_refs):
        """Same prompt 4x through a prefix-pooled engine: one compiled
        prefill total, streams identical to the pool-less engine."""
        scfg = dataclasses.replace(SERVE, prefix_cache=4)
        eng = ServeEngine(params, CFG, SP, scfg)
        rids = [eng.submit(prompts[0], max_new_tokens=MAX_NEW)
                for _ in range(4)]
        out = eng.run()
        assert eng.prefill_steps == 1
        assert eng.prefix_pool.stats()["hits"] == 3
        for r in rids:
            assert out[r] == solo_refs[0]

    def test_distinct_prompts_all_prefill(self, params, prompts, solo_refs):
        scfg = dataclasses.replace(SERVE, prefix_cache=4)
        eng = ServeEngine(params, CFG, SP, scfg)
        rids = [eng.submit(p, max_new_tokens=MAX_NEW) for p in prompts]
        out = eng.run()
        assert eng.prefill_steps == len(prompts)
        assert [out[r] for r in rids] == solo_refs


class TestFleetRouting:
    def _run(self, params, trace, router, **kw):
        fc = FleetConfig(n_replicas=2, router=router, route_seed=3, **kw)
        fl = ServeFleet(params, CFG, SP, SERVE, fc)
        rids = [fl.submit(p, max_new_tokens=m) for p, m in trace]
        out = fl.run()
        return fl, [out[r] for r in rids]

    def test_prefix_beats_random_and_streams_match(self, params, prompts,
                                                   solo_refs):
        """The acceptance property: on a shared-prefix workload the
        prefix-aware router serves with STRICTLY fewer compiled prefill
        steps than random routing — and both produce exactly the solo
        streams for every request."""
        trace = [(prompts[i % 3], MAX_NEW) for i in range(9)]
        fl_p, out_p = self._run(params, trace, "prefix")
        fl_r, out_r = self._run(params, trace, "random")
        for outs in (out_p, out_r):
            for i, toks in enumerate(outs):
                assert toks == solo_refs[i % 3]
        sp, sr = fl_p.stats(), fl_r.stats()
        assert sp["prefill_steps"] < sr["prefill_steps"]
        # the win came from routing onto warm pools, not from luck
        hits = sum(n for d, n in sp["routed_by_depth"].items() if d > 0)
        assert hits > 0

    def test_least_loaded_spreads_work(self, params, prompts):
        trace = [(prompts[0], MAX_NEW)] * 4
        fl, _ = self._run(params, trace, "least_loaded")
        per = [e.decode_steps for e in fl.engines]
        assert all(d > 0 for d in per)  # both replicas actually decoded

    def test_router_unit_prefers_deepest_then_load(self):
        class FakeEngine:
            def __init__(self, depth, running, queued, n_slots=2):
                self._d, self._r, self._q, self._n = (depth, running,
                                                      queued, n_slots)

            def prefix_match_depth(self, chain):
                return self._d

            def utilization(self):
                return {"n_slots": self._n, "running": self._r,
                        "queued": self._q, "free_slots": 0,
                        "load": (self._r + self._q) / self._n}

        chain = ("x",)
        r = Router("prefix")
        # deepest match wins over emptier non-holder
        assert r.choose([FakeEngine(1, 1, 0), FakeEngine(0, 0, 0)],
                        chain) == 0
        # ...until the holder's backlog exceeds least + n_slots + slack
        assert r.choose([FakeEngine(1, 2, 1), FakeEngine(0, 0, 0)],
                        chain) == 1
        # depth tie -> least-loaded
        assert r.choose([FakeEngine(1, 2, 0), FakeEngine(1, 0, 0)],
                        chain) == 1
        assert r.by_depth.get(0, 0) == 1 and r.by_depth.get(1, 0) == 2

    def test_fleet_config_validation(self):
        with pytest.raises(ValueError):
            FleetConfig(n_replicas=0)
        with pytest.raises(ValueError):
            FleetConfig(router="round_robin")
        with pytest.raises(ValueError):
            FleetConfig(disaggregate=True, n_prefill=0)


class TestDisaggregation:
    def test_disagg_bitwise_equals_colocated(self, params, prompts):
        """A disaggregated fleet (1 prefill + 1 decode engine, handoff
        through the CacheStore) must reproduce a single colocated
        engine's streams bitwise on the same trace — including the
        max_new_tokens=1 request that never reaches a decode engine."""
        trace = ([(prompts[i % 3], MAX_NEW) for i in range(4)]
                 + [(prompts[1], 1)])

        eng = ServeEngine(params, CFG, SP, SERVE)
        rc = [eng.submit(p, max_new_tokens=m) for p, m in trace]
        outc = eng.run()

        fl = ServeFleet(params, CFG, SP, SERVE,
                        FleetConfig(n_replicas=1, router="least_loaded",
                                    disaggregate=True, n_prefill=1))
        rd = [fl.submit(p, max_new_tokens=m) for p, m in trace]
        outd = fl.run()
        assert [outd[b] for b in rd] == [outc[a] for a in rc]
        st = fl.stats()
        assert st["store"]["size"] == 0       # every handoff consumed
        assert st["decode_steps"] > 0
        # decode engines never prefilled: disaggregation is real
        assert all(e["prefill_steps"] == 0 for e in st["engines"])
        assert sum(e["prefill_steps"]
                   for e in st["prefill_engines"]) > 0

    def test_disagg_eos_on_first_token(self, params, prompts, solo_refs):
        """EOS hit by the prefill's own sampled token: the request must
        finish on the prefill side with the identical 1-token stream."""
        eos = solo_refs[0][0]
        eng = ServeEngine(params, CFG, SP, SERVE)
        ra = eng.submit(prompts[0], max_new_tokens=MAX_NEW, eos=eos)
        ref = eng.run()[ra]
        assert ref == [eos]

        fl = ServeFleet(params, CFG, SP, SERVE,
                        FleetConfig(n_replicas=1, disaggregate=True))
        rb = fl.submit(prompts[0], max_new_tokens=MAX_NEW, eos=eos)
        out = fl.run()[rb]
        assert out == ref
        assert fl.stats()["decode_steps"] == 0  # never reached decode


class TestLaneExportImport:
    def test_mid_decode_handoff_continues_bitwise(self, params, prompts):
        """Export a RUNNING request's lane after 3 steps, seat it on a
        fresh engine: the concatenated stream equals the uninterrupted
        solo decode."""
        full_new = 8
        e_ref = ServeEngine(params, CFG, SP, SERVE)
        r_ref = e_ref.submit(prompts[1], max_new_tokens=full_new)
        full = e_ref.run()[r_ref]

        e1 = ServeEngine(params, CFG, SP, SERVE)
        r1 = e1.submit(prompts[1], max_new_tokens=full_new)
        for _ in range(3):
            e1.step()
        req = next(r for r in e1._running.values() if r.rid == r1)
        partial = list(req.tokens)
        assert 0 < len(partial) < full_new
        lane = e1.export_lane(r1)
        assert e1.n_running == 0
        assert e1.batcher.kv.n_free == SERVE.n_slots  # slot released
        with pytest.raises(KeyError):
            e1.export_lane(r1)  # detached: not running here anymore

        e2 = ServeEngine(params, CFG, SP, SERVE)
        r2 = e2.submit_lane(lane, max_new_tokens=full_new, tokens=partial)
        assert e2.run()[r2] == full

    def test_submit_lane_validation(self, params, prompts):
        eng = ServeEngine(params, CFG, SP, SERVE)
        lane = eng.prefill_to_lane(prompts[0], max_new_tokens=4)
        with pytest.raises(ValueError):
            eng.submit_lane(lane, max_new_tokens=0)
        with pytest.raises(ValueError):  # pos + remaining exceeds max_len
            eng.submit_lane(lane, max_new_tokens=SERVE.max_len)


class TestAsyncFrontend:
    def test_concurrent_generate_matches_solo(self, params, prompts,
                                              solo_refs):
        async def main():
            fl = ServeFleet(params, CFG, SP, SERVE,
                            FleetConfig(n_replicas=2))
            fr = AsyncFrontend(fl)
            return await asyncio.gather(
                *[fr.generate(p, max_new_tokens=MAX_NEW) for p in prompts])

        outs = asyncio.run(main())
        assert [list(o) for o in outs] == solo_refs

    def test_late_joiner_reuses_driver(self, params, prompts, solo_refs):
        async def main():
            fl = ServeFleet(params, CFG, SP, SERVE,
                            FleetConfig(n_replicas=1))
            fr = AsyncFrontend(fl)
            first = asyncio.create_task(
                fr.generate(prompts[0], max_new_tokens=MAX_NEW))
            await asyncio.sleep(0)  # driver running, queue drained
            second = await fr.generate(prompts[1], max_new_tokens=MAX_NEW)
            return await first, second

        a, b = asyncio.run(main())
        assert list(a) == solo_refs[0] and list(b) == solo_refs[1]


class TestFleetMeshes:
    def test_replica_device_groups_partition(self):
        from repro.launch import spmd
        devs = list(range(8))  # groups don't care about element type
        groups = spmd.replica_device_groups(2, devices=devs)
        assert groups == [[0, 1, 2, 3], [4, 5, 6, 7]]
        with pytest.raises(ValueError):
            spmd.replica_device_groups(3, devices=devs)
        with pytest.raises(ValueError):
            spmd.replica_device_groups(0, devices=devs)

    @pytest.mark.skipif(
        jax.device_count() < 2,
        reason="needs >=2 devices "
               "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    def test_fleet_on_disjoint_meshes(self, params, prompts, solo_refs):
        """2 replicas on disjoint device groups: routing + streams are
        mesh-invariant."""
        from repro.launch import spmd
        meshes = spmd.fleet_meshes(2)
        assert not (set(meshes[0].devices.flat)
                    & set(meshes[1].devices.flat))
        fl = ServeFleet(params, CFG, SP, SERVE,
                        FleetConfig(n_replicas=2, router="prefix"),
                        meshes=meshes)
        trace = [(prompts[i % 3], MAX_NEW) for i in range(6)]
        rids = [fl.submit(p, max_new_tokens=m) for p, m in trace]
        out = fl.run()
        for i, r in enumerate(rids):
            assert out[r] == solo_refs[i % 3]

    def test_mesh_count_mismatch_rejected(self, params):
        from repro.launch import spmd
        with pytest.raises(ValueError):
            ServeFleet(params, CFG, SP, SERVE, FleetConfig(n_replicas=2),
                       meshes=[spmd.single_device_mesh()])
