"""Property suite for the gradient compressor (hypothesis).

The cross-pod sync trusts three exact identities, pinned here:

  * the vectorized jnp fast path (kernels/ops._jnp_grad_compress /
    _jnp_grad_decompress_mean) is BITWISE-identical to the readable
    ref.py oracles — including argmax-vs-top_k tie breaking, the
    compare-swap index ordering, and the scatter-free residual;
  * error feedback telescopes exactly: decode(payload) + new_err
    reconstructs g + err bit-for-bit in f32 (optim/compress leans on
    this to skip decoding the own pod's payload);
  * one transposable mask legally serves W and Wᵀ: N-per-group holds
    along BOTH orientations (Hubara et al., arXiv 2102.08124), which is
    what lets a single stored mask feed FF and BP packed operands.

Plus the refusal properties: bucket plans may never split an M-group,
and the MVUE estimator (arXiv 2203.10991) is exact when a group has
≤ n nonzeros.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import require_or_skip

require_or_skip("hypothesis")  # bare env: skip; CI (REQUIRE_HYPOTHESIS): fail
from hypothesis import given, settings, strategies as st

from repro.core import sparsity as S
from repro.kernels import ops, ref
from repro.optim import compress as C

jax.config.update("jax_platform_name", "cpu")

NM = st.sampled_from([(1, 4), (2, 4), (2, 8), (1, 8), (4, 8), (2, 16)])


def _grads(shape, seed, ties=False):
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, shape, jnp.float32)
    if ties:
        # quantize to a handful of magnitudes: most groups now contain
        # duplicated |g|, exercising the tie-break rule on every call
        g = jnp.round(g * 2) / 2
    return g


class TestFastPathBitwise:
    @settings(max_examples=25, deadline=None)
    @given(nm=NM, seed=st.integers(0, 2**16), rows=st.sampled_from([1, 3]),
           groups=st.integers(1, 24), ties=st.booleans())
    def test_compress_matches_oracle(self, nm, seed, rows, groups, ties):
        n, m = nm
        g = _grads((rows, groups * m), seed, ties)
        err = _grads((rows, groups * m), seed + 1) * 0.1
        v, i, e = ops.grad_compress(g, err, n, m, use_pallas=False)
        rv, ri, re_ = ref.ref_grad_compress(g, err, n, m)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
        np.testing.assert_array_equal(np.asarray(e), np.asarray(re_))

    @settings(max_examples=20, deadline=None)
    @given(nm=NM, seed=st.integers(0, 2**16), pods=st.sampled_from([1, 2, 4]),
           groups=st.integers(1, 16))
    def test_decompress_mean_matches_oracle(self, nm, seed, pods, groups):
        n, m = nm
        g = _grads((pods, groups * m), seed)
        v, i, _ = ops.grad_compress(g, jnp.zeros_like(g), n, m,
                                    use_pallas=False)
        out = ops.grad_decompress_mean(v, i, n, m, use_pallas=False)
        rout = ref.ref_grad_decompress_mean(v, i, n, m)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(rout))

    def test_all_zero_and_all_tied_groups(self):
        # degenerate tie patterns: every lane identical, and all-zero
        g = jnp.concatenate([jnp.zeros((2, 16)), jnp.ones((2, 16))], axis=1)
        err = jnp.zeros_like(g)
        v, i, e = ops.grad_compress(g, err, 2, 8, use_pallas=False)
        rv, ri, re_ = ref.ref_grad_compress(g, err, 2, 8)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
        np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))
        np.testing.assert_array_equal(np.asarray(e), np.asarray(re_))
        # lower index wins every tie: the all-ones groups keep lanes 0, 1
        kept = np.asarray(i)[:, 4:].reshape(2, 2, 2)
        np.testing.assert_array_equal(kept, np.broadcast_to([0, 1], kept.shape))


class TestTelescoping:
    @settings(max_examples=25, deadline=None)
    @given(nm=NM, seed=st.integers(0, 2**16), groups=st.integers(1, 24),
           ties=st.booleans(), steps=st.integers(1, 4))
    def test_decode_plus_residual_is_exact(self, nm, seed, groups, ties, steps):
        """decode(payload) + new_err == g + err bitwise, every step.

        The sync's own-pod decode skip rewrites decode(own) as
        t - new_err; that rewrite is sound iff this holds exactly."""
        n, m = nm
        err = jnp.zeros((1, groups * m), jnp.float32)
        for s in range(steps):
            g = _grads((1, groups * m), seed + s, ties)
            t = g + err
            v, i, err = ops.grad_compress(g, err, n, m, use_pallas=False)
            dec = ops.grad_decompress_mean(v, i, n, m, use_pallas=False)
            np.testing.assert_array_equal(
                np.asarray(dec) + np.asarray(err)[0], np.asarray(t)[0])

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), groups=st.integers(1, 8))
    def test_pallas_interpret_roundtrip_bitwise(self, seed, groups):
        """Packed roundtrip through the Pallas kernels (interpret mode on
        CPU) is bitwise the jnp reference path — payload, index AND
        residual, so either backend may feed the sync."""
        n, m = 2, 8
        g = _grads((1, groups * m), seed, ties=True)
        err = _grads((1, groups * m), seed + 1) * 0.1
        v, i, e = ops.grad_compress(g, err, n, m, use_pallas=True)
        jv, ji, je = ops.grad_compress(g, err, n, m, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(jv))
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ji))
        np.testing.assert_array_equal(np.asarray(e), np.asarray(je))
        d = ops.grad_decompress_mean(v, i, n, m, use_pallas=True)
        jd = ops.grad_decompress_mean(jv, ji, n, m, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(d), np.asarray(jd))


class TestTransposableMask:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**16),
           nm=st.sampled_from([(1, 4), (2, 4), (2, 8)]),
           r=st.sampled_from([8, 16, 32]))
    def test_n_per_group_both_orientations(self, seed, nm, r):
        n, m = nm
        w = _grads((r, r), seed)
        mask = S.nm_mask_transposable(w, n, m)
        mk = np.asarray(mask)
        rows = mk.reshape(r, r // m, m).sum(-1)
        cols = mk.T.reshape(r, r // m, m).sum(-1)
        assert (rows <= n).all(), "row orientation violates N:M"
        assert (cols <= n).all(), "column orientation violates N:M"

    def test_one_mask_serves_w_and_wt(self):
        w = _grads((16, 16), 7)
        mask = S.nm_mask_transposable(w, 2, 8)
        # FF consumes W under mask, BP consumes Wᵀ under maskᵀ: both are
        # valid N:M operands from the SAME stored mask
        for mat, mk in ((w, mask), (w.T, mask.T)):
            v, i = S.nm_pack_from_mask(jnp.where(mk, mat, 0.0), mk, 2, 8,
                                       axis=-1)
            assert v.shape == (16, 16 // 8 * 2)
            groups = np.asarray(mk).reshape(16, 2, 8).sum(-1)
            assert (groups <= 2).all()


class TestBucketIntegrity:
    @settings(max_examples=30, deadline=None)
    @given(m=st.sampled_from([4, 8, 16]), total_groups=st.integers(1, 64),
           bucket_groups=st.integers(1, 16))
    def test_aligned_plans_cover_exactly(self, m, total_groups, bucket_groups):
        total = total_groups * m
        buckets = C.plan_buckets(total, bucket_groups * m, m)
        assert buckets[0][0] == 0 and buckets[-1][1] == total
        for (s0, e0), (s1, e1) in zip(buckets, buckets[1:]):
            assert e0 == s1
        assert all(s % m == 0 and e % m == 0 for s, e in buckets)

    @settings(max_examples=30, deadline=None)
    @given(m=st.sampled_from([4, 8, 16]), off=st.integers(1, 15))
    def test_group_splitting_refused(self, m, off):
        bad = (off if off % m else off + 1)
        with pytest.raises(ValueError):
            C.plan_buckets(16 * m, bad, m)
        with pytest.raises(ValueError):
            C.GradCompressConfig(m=m, bucket_elems=bad)
        with pytest.raises(ValueError):
            C.plan_buckets(16 * m + bad, 4 * m, m)


class TestMvue:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**16), groups=st.integers(1, 12),
           nm=st.sampled_from([(2, 8), (2, 4), (1, 8)]))
    def test_exact_when_group_has_le_n_nonzeros(self, seed, nm, groups):
        """≤ n nonzeros per group: every nonzero gets p=1, no rescaling,
        no sampling noise — the estimate IS the input (arXiv 2203.10991's
        exactness regime).  bf16-representable inputs keep it bitwise."""
        n, m = nm
        key = jax.random.PRNGKey(seed)
        lanes = jax.random.randint(key, (groups, n), 0, m)
        t = np.zeros((groups, m), np.float32)
        vals = np.asarray(
            jax.random.randint(jax.random.PRNGKey(seed + 1),
                               (groups, n), -8, 9), np.float32)
        for gi in range(groups):
            for j in range(n):
                t[gi, int(lanes[gi, j])] = vals[gi, j]  # dups just overwrite
        flat = jnp.asarray(t.reshape(1, groups * m))
        v, i = C.mvue_compress(flat, n, m, jax.random.PRNGKey(seed + 2))
        dec = ops.grad_decompress_mean(
            v.reshape(1, -1), i.reshape(1, -1), n, m, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(dec), t.reshape(-1))

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**16), groups=st.integers(1, 12))
    def test_payload_is_nm_shaped(self, seed, groups):
        n, m = 2, 8
        flat = _grads((1, groups * m), seed)
        v, i = C.mvue_compress(flat, n, m, jax.random.PRNGKey(seed))
        assert v.shape == (1, groups * n) and i.shape == (1, groups * n)
        ii = np.asarray(i).reshape(groups, n)
        assert (ii < m).all()
        assert (np.diff(ii, axis=-1) > 0).all(), "indices ascending per group"
