"""Structural HLO cost-model tests (launch/hlo_cost.py).

The critical property: while-loop bodies are multiplied by their
known_trip_count — XLA's own cost_analysis counts them once, which
would make every scan-over-layers roofline wrong by ~n_layers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost as H

jax.config.update("jax_platform_name", "cpu")


def _scan_matmul_hlo(n_layers=16, b=32, d=64):
    def step(params, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, params)
        return y.sum()

    params = jax.ShapeDtypeStruct((n_layers, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((b, d), jnp.float32)
    return jax.jit(jax.grad(step)).lower(params, x).compile().as_text()


class TestTripExpansion:
    def test_scan_flops_match_hand_count(self):
        n_layers, b, d = 16, 32, 64
        txt = _scan_matmul_hlo(n_layers, b, d)
        got = H.analyze(txt)["flops"]
        # fwd + dx + dw = 3 matmuls/layer, 2*b*d*d flops each
        want = 3 * 2 * b * d * d * n_layers
        assert got == pytest.approx(want, rel=0.10)

    def test_trip_count_parsed(self):
        txt = _scan_matmul_hlo(n_layers=12)
        model = H.HloCostModel(txt)
        trips = [int(m.group(1)) for m in
                 H._TRIP_RE.finditer(txt)]
        assert 12 in trips

    def test_bytes_scale_with_layers(self):
        small = H.analyze(_scan_matmul_hlo(n_layers=4))["bytes"]
        big = H.analyze(_scan_matmul_hlo(n_layers=16))["bytes"]
        assert 2.5 < big / small < 6.0  # ~4x, loop-invariant slack


SYNTHETIC_COLLECTIVE_HLO = """
HloModule test, num_partitions=8

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[128,256]{1,0} all-gather(%p0), replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %ar = f32[128,256]{1,0} all-reduce(%ag), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
}
"""


class TestCollectives:
    def test_ring_accounting(self):
        out = H.analyze(SYNTHETIC_COLLECTIVE_HLO)
        size = 128 * 256 * 4
        coll = out["collectives"]
        assert coll["all-gather"] == int(size * 3 / 4)
        assert coll["all-reduce"] == int(2 * size * 3 / 4)
        assert coll["count"] == 2

    def test_group_size_iota_and_list(self):
        assert H._group_size("replica_groups=[2,4]<=[8]") == 4
        assert H._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
        assert H._group_size("no groups here") == 1

    def test_group_size_malformed_lines_degrade_to_one(self):
        # garbage must degrade (size 1 = free collective), never raise
        assert H._group_size("replica_groups=[not,a,number]<=[8]") == 1
        assert H._group_size("replica_groups={{}}") == 1
        assert H._group_size("replica_groups=") == 1
        assert H._group_size("") == 1


class TestCrossesPod:
    """Pod-crossing attribution over every replica_groups spelling
    (pod_block=4 on 8 devices: pods {0..3} and {4..7})."""

    def test_explicit_groups(self):
        intra = "all-reduce(...), replica_groups={{0,1,2,3},{4,5,6,7}}"
        cross = "all-reduce(...), replica_groups={{0,4},{1,5}}"
        assert not H._crosses_pod(intra, 4)
        assert H._crosses_pod(cross, 4)

    def test_iota_form(self):
        # [2,4]<=[8]: groups {0..3},{4..7} — pod-aligned
        assert not H._crosses_pod("replica_groups=[2,4]<=[8]", 4)
        # [1,8]<=[8]: one world group — spans both pods
        assert H._crosses_pod("replica_groups=[1,8]<=[8]", 4)

    def test_iota_transposed_strides(self):
        # [4,2]<=[2,4]T(1,0): arange(8).reshape(2,4).T.reshape(4,2)
        # -> groups {0,4},{1,5},{2,6},{3,7} — every one crosses
        line = "replica_groups=[4,2]<=[2,4]T(1,0)"
        assert H._crosses_pod(line, 4)
        # same grouping is intra-pod if the whole world is one pod
        assert not H._crosses_pod(line, 8)

    def test_collective_permute_pairs(self):
        assert H._crosses_pod("source_target_pairs={{0,4},{4,0}}", 4)
        assert not H._crosses_pod("source_target_pairs={{0,1},{1,0}}", 4)

    def test_no_grouping_is_conservatively_crossing(self):
        assert H._crosses_pod("all-reduce(%x), to_apply=%add", 4)


NESTED_TUPLE_HLO = """
HloModule t

%helper (a: f32[4,8]) -> f32[4,8] {
  %a = f32[4,8]{1,0} parameter(0)
  ROOT %neg = f32[4,8]{1,0} negate(%a)
}

ENTRY %main (p0: (f32[4,8], (u8[2,8], s32[])), p1: bf16[64,32]) -> f32[4,8] {
  %p0 = (f32[4,8]{1,0}, (u8[2,8]{1,0}, s32[])) parameter(0)
  %p1 = bf16[64,32]{1,0} parameter(1)
  %gte = f32[4,8]{1,0} get-tuple-element(%p0), index=0
  ROOT %r = f32[4,8]{1,0} call(%gte), to_apply=%helper
}
"""


class TestModuleStructure:
    def test_parse_module_nested_tuple_params(self):
        comps = H.parse_module(NESTED_TUPLE_HLO)
        main = comps["main"]
        assert main.is_entry and not comps["helper"].is_entry
        # the nested tuple type survives as one param entry
        assert set(main.params) == {"p0", "p1"}
        assert H._parse_shapes(main.params["p0"]) == [
            ("f32", (4, 8)), ("u8", (2, 8)), ("s32", ())]

    def test_entry_param_shapes_flattens_tuples(self):
        shapes = H.entry_param_shapes(NESTED_TUPLE_HLO)
        assert shapes == [("p0", "f32", (4, 8)), ("p0", "u8", (2, 8)),
                          ("p0", "s32", ()), ("p1", "bf16", (64, 32))]

    def test_entry_fallback_without_keyword(self):
        # older dumps drop ENTRY — fall back to the main-prefixed comp
        txt = NESTED_TUPLE_HLO.replace("ENTRY %main", "%main.17")
        comp = H.entry_computation(H.parse_module(txt))
        assert comp is not None and comp.name.startswith("main")
        assert H.entry_param_shapes("") == []

    def test_count_hlo_ops_all_vs_entry_only(self):
        assert H.count_hlo_ops(NESTED_TUPLE_HLO, ("negate",)) == 1
        assert H.count_hlo_ops(NESTED_TUPLE_HLO, ("negate",),
                               entry_only=True) == 0
        assert H.count_hlo_ops(NESTED_TUPLE_HLO, ("call",),
                               entry_only=True) == 1


class TestDotFlops:
    def test_plain_matmul(self):
        txt = jax.jit(lambda a, b: a @ b).lower(
            jax.ShapeDtypeStruct((32, 48), jnp.float32),
            jax.ShapeDtypeStruct((48, 16), jnp.float32)).compile().as_text()
        got = H.analyze(txt)["flops"]
        assert got == pytest.approx(2 * 32 * 48 * 16, rel=0.01)

    def test_fusion_boundary_bytes(self):
        txt = jax.jit(lambda a: jnp.tanh(a) * 2 + 1).lower(
            jax.ShapeDtypeStruct((1024,), jnp.float32)).compile().as_text()
        got = H.analyze(txt)["bytes"]
        # one fused pass: read + write (allow convert/copy slack)
        assert got <= 4 * 1024 * 4
