"""Per-kernel allclose sweeps vs the ref.py oracles (interpret mode on CPU).

Every Pallas kernel is swept over shapes / dtypes / N:M patterns with
hypothesis; semantics must match the pure-jnp oracle bit-for-bit for
index outputs and to fp tolerance for value outputs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import require_or_skip

require_or_skip("hypothesis")  # bare env: skip; CI (REQUIRE_HYPOTHESIS): fail
from hypothesis import given, settings, strategies as st

from repro.core import sparsity as S
from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")

NM = st.sampled_from([(1, 4), (2, 4), (2, 8), (1, 8), (2, 16), (4, 8)])
DT = st.sampled_from([jnp.float32, jnp.bfloat16])


def _rand(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=dtype)


class TestNmCompactKernel:
    @settings(max_examples=20, deadline=None)
    @given(nm=NM, seed=st.integers(0, 2**16), dtype=DT,
           r=st.sampled_from([8, 32, 64]), gk=st.sampled_from([16, 64, 128]))
    def test_matches_oracle(self, nm, seed, dtype, r, gk):
        n, m = nm
        k = max(gk, m) // m * m
        x = _rand((r, k), seed, dtype)
        v, i = ops.nm_compact(x, n, m)
        rv, ri = ref.ref_nm_compact(x, n, m)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
        np.testing.assert_allclose(
            np.asarray(v, np.float32), np.asarray(rv, np.float32), rtol=1e-6
        )

    def test_3d_input(self):
        x = _rand((2, 8, 32), 0)
        v, i = ops.nm_compact(x, 2, 8)
        assert v.shape == (2, 8, 8) and i.shape == (2, 8, 8)

    def test_multiblock_grid(self):
        x = _rand((512, 1024), 1)
        v, i = ops.nm_compact(x, 2, 8)
        rv, ri = ref.ref_nm_compact(x, 2, 8)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


class TestNmSpmmKernel:
    @settings(max_examples=16, deadline=None)
    @given(nm=st.sampled_from([(2, 8), (2, 4), (1, 4), (2, 16)]),
           seed=st.integers(0, 2**16), dtype=DT,
           b=st.sampled_from([8, 32]), k=st.sampled_from([64, 128]),
           f=st.sampled_from([16, 64]))
    def test_matches_oracle(self, nm, seed, dtype, b, k, f):
        n, m = nm
        act = _rand((b, k), seed, dtype)
        w = _rand((k, f), seed + 1, dtype)
        vals, idx = S.nm_pack(w, n, m, axis=0)
        out = ops.nm_spmm(act, vals, idx, n, m)
        rout = ref.ref_nm_spmm(act, vals, idx, n, m)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(rout),
                                   rtol=tol, atol=tol)

    def test_equals_masked_dense_matmul(self):
        act = _rand((16, 256), 3)
        w = _rand((256, 128), 4)
        vals, idx = S.nm_pack(w, 2, 8, axis=0)
        out = ops.nm_spmm(act, vals, idx, 2, 8)
        dense = act @ S.sparsify(w, S.SparsityConfig(n=2, m=8), axis=0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                                   rtol=1e-4, atol=1e-4)

    def test_accumulation_over_k_grid(self):
        # K spans multiple blocks -> exercises the fp32 accumulator path
        act = _rand((8, 2048), 5)
        w = _rand((2048, 128), 6)
        vals, idx = S.nm_pack(w, 2, 8, axis=0)
        out = ops.nm_spmm(act, vals, idx, 2, 8)
        rout = ref.ref_nm_spmm(act, vals, idx, 2, 8)
        np.testing.assert_allclose(np.asarray(out), np.asarray(rout),
                                   rtol=1e-4, atol=1e-4)


class TestNmSpmmSharedKernel:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16),
           b=st.sampled_from([8, 32]), k=st.sampled_from([64, 256]),
           tile=st.sampled_from([16, 32]))
    def test_matches_oracle(self, seed, b, k, tile):
        act = _rand((b, k), seed)
        w = _rand((k, 2 * tile), seed + 1)
        vals, rows = ops.pack_shared(w, 2, 8, tile=tile)
        out = ops.nm_spmm_shared(act, vals, rows)
        rout = ref.ref_nm_spmm_shared(act, vals, rows)
        np.testing.assert_allclose(np.asarray(out), np.asarray(rout),
                                   rtol=1e-5, atol=1e-5)

    def test_equals_shared_masked_dense(self):
        act = _rand((8, 128), 11)
        w = _rand((128, 64), 12)
        vals, rows = ops.pack_shared(w, 2, 8, tile=32)
        out = ops.nm_spmm_shared(act, vals, rows)
        cfg = S.SparsityConfig(n=2, m=8, granularity="shared", tile=32)
        dense = act @ S.sparsify(w, cfg, axis=0, share_axis=1)
        np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                                   rtol=1e-4, atol=1e-4)

    def test_flop_saving_shape(self):
        # the contraction really is Kc = K*n/m wide
        w = _rand((256, 64), 13)
        vals, rows = ops.pack_shared(w, 2, 8, tile=32)
        assert vals.shape == (2, 64, 32)  # Kc = 256/8*2 = 64
        assert rows.shape == (2, 64)


class TestFusedUpdateKernel:
    @settings(max_examples=12, deadline=None)
    @given(nm=st.sampled_from([(2, 8), (2, 4), (2, 16)]),
           seed=st.integers(0, 2**16),
           r=st.sampled_from([16, 64]), k=st.sampled_from([64, 128]))
    def test_matches_oracle(self, nm, seed, r, k):
        n, m = nm
        w = _rand((r, k), seed)
        g = _rand((r, k), seed + 1)
        v = _rand((r, k), seed + 2) * 0.1
        out = ops.fused_update(w, g, v, 0.05, 0.9, 1e-4, 2e-4, n, m)
        rout = ref.ref_fused_update(w, g, v, lr=0.05, mu=0.9, wd=1e-4,
                                    lam=2e-4, n=n, m=m)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(rout[0]),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(out[1]), np.asarray(rout[1]),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(out[3]), np.asarray(rout[3]))

    def test_momentum_semantics(self):
        # two steps of the kernel == hand-rolled momentum SGD w/ SR-STE
        w = _rand((8, 16), 0)
        g = _rand((8, 16), 1)
        v = jnp.zeros_like(w)
        lr, mu, wd, lam = 0.1, 0.9, 0.0, 0.0
        w1, v1, *_ = ops.fused_update(w, g, v, lr, mu, wd, lam, 2, 8)
        np.testing.assert_allclose(np.asarray(w1), np.asarray(w - lr * g),
                                   rtol=1e-6)
        w2, v2, *_ = ops.fused_update(w1, g, v1, lr, mu, wd, lam, 2, 8)
        np.testing.assert_allclose(np.asarray(v2), np.asarray(mu * g + g),
                                   rtol=1e-6)

    def test_packed_output_matches_nm_pack_of_new_w(self):
        w = _rand((16, 64), 5)
        g = _rand((16, 64), 6)
        v = jnp.zeros_like(w)
        nw, _, pv, pi = ops.fused_update(w, g, v, 0.1, 0.9, 0.0, 0.0, 2, 8)
        ev, ei = S.nm_pack(nw, 2, 8, axis=-1)
        np.testing.assert_array_equal(np.asarray(pi), np.asarray(ei))
        np.testing.assert_allclose(np.asarray(pv, np.float32),
                                   np.asarray(ev, np.float32), rtol=1e-2, atol=1e-2)


class TestPackedBytes:
    def test_element_mode_footprint(self):
        dense = 256 * 128 * 2
        packed = ops.packed_bytes(256, 128, 2, 8)
        assert packed == 256 // 8 * 2 * 128 * 2 + 256 // 8 * 2 * 128
        assert packed < dense / 2  # the paper's >50%-sparsity storage win
