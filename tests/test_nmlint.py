"""nmlint wired into tier-1 (repro/analysis + tools/nmlint.py).

Three guarantees:
  * the repo itself is clean under the AST pass — a PR that reintroduces
    a deprecated-shim call, a raw (vals, idx) unpack, a traced-predicate
    branch, or an idx_bits-less packed constructor fails locally, before
    the blocking CI job even runs;
  * the auditor can still SEE: every rule fires on its seeded violation
    (a silently-blind checker is worse than none);
  * the waiver mechanism is temporary by construction — expiry and glob
    matching behave, and docs/analysis.md + results/NMLINT.json stay in
    sync with the rule registry.

The jaxpr/HLO config-matrix audit itself (--graph --mesh8) runs in the
dedicated blocking CI job — it compiles real models and is too heavy
for tier-1.
"""

import datetime
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.analysis import (  # noqa: E402
    RULES, RULES_BY_ID, SCHEMA_VERSION, Finding, apply_waivers,
    build_report, load_waivers, run_ast_pass, run_selftest,
    scanned_file_count, write_report,
)
from repro.analysis import ast_pass  # noqa: E402


class TestRepoIsClean:
    def test_ast_pass_finds_nothing_unwaived(self):
        waivers, expired = load_waivers(
            os.path.join(ROOT, "tools", "nmlint_waivers.json"))
        findings = apply_waivers(run_ast_pass(), waivers) + expired
        unwaived = [f for f in findings if not f.waived]
        assert unwaived == [], "\n".join(str(f) for f in unwaived)

    def test_scan_covers_the_source_tree(self):
        # the pass must actually be looking at src/repro/ — a broken
        # walk that scans 0 files would be vacuously "clean"
        assert scanned_file_count() >= 50

    def test_selftest_seeds_are_excluded_from_the_scan(self):
        assert "analysis/selftest.py" in ast_pass.SCAN_EXCLUDE


class TestSelftest:
    def test_every_rule_fires_on_its_seed(self):
        ok, fired = run_selftest()
        assert ok, f"silent rules: {[r for r, f in fired.items() if not f]}"
        # one seed per registered rule — registry drift fails here
        assert set(fired) == set(RULES_BY_ID)


class TestWaivers:
    def _write(self, tmp_path, waivers):
        path = tmp_path / "waivers.json"
        path.write_text(json.dumps({"waivers": waivers}))
        return str(path)

    def test_active_waiver_suppresses_by_rule_and_glob(self, tmp_path):
        path = self._write(tmp_path, [
            {"rule": "NM102", "path": "core/*.py", "reason": "migration",
             "expires": "2099-01-01"}])
        active, expired = load_waivers(path)
        assert len(active) == 1 and expired == []
        findings = [Finding("NM102", "core/operand.py", 3, "x"),
                    Finding("NM102", "serve/engine.py", 9, "x"),
                    Finding("NM103", "core/operand.py", 5, "x")]
        apply_waivers(findings, active)
        assert [f.waived for f in findings] == [True, False, False]
        assert findings[0].waiver_reason == "migration"

    def test_expired_waiver_stops_waiving_and_files_nm001(self, tmp_path):
        path = self._write(tmp_path, [
            {"rule": "NM102", "path": "core/*.py", "reason": "old",
             "expires": "2024-01-01"}])
        active, expired = load_waivers(
            path, today=datetime.date(2026, 8, 8))
        assert active == []
        assert len(expired) == 1 and expired[0].rule == "NM001"
        assert "expired" in expired[0].message

    def test_malformed_expiry_is_a_finding_not_a_crash(self, tmp_path):
        path = self._write(tmp_path, [
            {"rule": "NM102", "path": "x.py", "reason": "r",
             "expires": "soon"},
            {"rule": "NM103", "path": "y.py", "reason": "r"}])
        active, expired = load_waivers(path)
        assert active == []
        assert [f.rule for f in expired] == ["NM001", "NM001"]

    def test_committed_waiver_file_has_no_expired_entries(self):
        _, expired = load_waivers(
            os.path.join(ROOT, "tools", "nmlint_waivers.json"))
        assert expired == []


class TestReport:
    def test_schema_and_determinism(self, tmp_path):
        findings = [Finding("NM102", "a.py", 1, "m", waived=True,
                            waiver_reason="r"),
                    Finding("NM103", "b.py", 2, "m")]
        rep = build_report(findings, {"case": {"k": 1}}, ["case"],
                          scanned_files=3)
        assert rep["schema_version"] == SCHEMA_VERSION
        assert set(rep["counts"]["by_rule"]) == set(RULES_BY_ID)
        assert rep["counts"] == {
            "total": 2, "unwaived": 1, "waived": 1,
            "by_rule": {**{r.id: 0 for r in RULES},
                        "NM102": 1, "NM103": 1}}
        out = write_report(rep, str(tmp_path / "r.json"))
        rep2 = build_report(findings, {"case": {"k": 1}}, ["case"],
                           scanned_files=3)
        with open(out) as f:
            assert json.load(f) == rep2  # no timestamps, diffs empty

    def test_committed_report_matches_the_registry(self):
        # results/NMLINT.json is committed; it must carry the current
        # schema, the current rules, and zero unwaived findings
        with open(os.path.join(ROOT, "results", "NMLINT.json")) as f:
            rep = json.load(f)
        assert rep["schema_version"] == SCHEMA_VERSION
        assert set(rep["rules"]) == set(RULES_BY_ID)
        assert rep["counts"]["unwaived"] == 0


class TestAstRules:
    """check_source semantics beyond the selftest seeds: the
    allowlists and non-violating idioms must NOT fire."""

    def test_shim_call_inside_home_is_fine(self):
        src = "def nm_linear(x, w, cfg):\n    return nm_linear_core(x)\n" \
              "def wrap(x, w, cfg):\n    return nm_linear(x, w, cfg)\n"
        assert ast_pass.check_source("core/bdwp.py", src) == []
        assert any(f.rule == "NM101" for f in
                   ast_pass.check_source("models/layers.py", src))

    def test_unpack_allowed_in_sanctioned_producers(self):
        src = "def f(vals, idx):\n    return nm_unpack_n(vals, idx)\n"
        assert ast_pass.check_source("kernels/nm_spmm.py", src) == []
        assert ast_pass.check_source("optim/sgd.py", src) == []
        assert any(f.rule == "NM102" for f in
                   ast_pass.check_source("serve/engine.py", src))

    def test_where_without_vals_idx_in_scope_is_fine(self):
        src = "import jax.numpy as jnp\n" \
              "def mask(w, m):\n    return jnp.where(m, w, 0.0)\n"
        assert ast_pass.check_source("models/layers.py", src) == []

    def test_python_branch_on_concrete_value_is_fine(self):
        src = "def f(x, training):\n" \
              "    if training:\n        return x * 2\n    return x\n"
        assert ast_pass.check_source("train/step.py", src) == []

    def test_packedop_with_explicit_idx_bits_is_fine(self):
        src = "def f(vals, idx, cfg):\n" \
              "    return PackedOp(vals, idx, cfg, idx_bits=4)\n"
        assert ast_pass.check_source("serve/store.py", src) == []

    def test_unparseable_module_is_a_finding(self):
        fs = ast_pass.check_source("models/broken.py", "def f(:\n")
        assert len(fs) == 1 and "unparseable" in fs[0].message


class TestDocsInSync:
    def test_every_rule_documented_in_analysis_md(self):
        with open(os.path.join(ROOT, "docs", "analysis.md")) as f:
            text = f.read()
        for rule in RULES:
            assert rule.id in text, f"{rule.id} missing from docs/analysis.md"
            assert rule.title in text, (
                f"{rule.id} title '{rule.title}' missing from "
                f"docs/analysis.md")


class TestCli:
    def test_list_rules_exits_zero(self):
        proc = subprocess.run(
            [sys.executable, os.path.join(ROOT, "tools", "nmlint.py"),
             "--list-rules"], cwd=ROOT, capture_output=True, text=True)
        assert proc.returncode == 0, proc.stderr
        for rule in RULES:
            assert rule.id in proc.stdout
