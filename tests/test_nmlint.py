"""nmlint wired into tier-1 (repro/analysis + tools/nmlint.py).

Three guarantees:
  * the repo itself is clean under the AST pass — a PR that reintroduces
    a deprecated-shim call, a raw (vals, idx) unpack, a traced-predicate
    branch, or an idx_bits-less packed constructor fails locally, before
    the blocking CI job even runs;
  * the auditor can still SEE: every rule fires on its seeded violation
    (a silently-blind checker is worse than none);
  * the waiver mechanism is temporary by construction — expiry and glob
    matching behave, and docs/analysis.md + results/NMLINT.json stay in
    sync with the rule registry.

The jaxpr/HLO config-matrix audit itself (--graph --mesh8) runs in the
dedicated blocking CI job — it compiles real models and is too heavy
for tier-1.
"""

import datetime
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.analysis import (  # noqa: E402
    RULES, RULES_BY_ID, SCHEMA_VERSION, Finding, apply_waivers,
    build_report, load_waivers, run_ast_pass, run_selftest,
    scanned_file_count, write_report,
)
from repro.analysis import ast_pass  # noqa: E402


class TestRepoIsClean:
    def test_ast_pass_finds_nothing_unwaived(self):
        waivers, expired = load_waivers(
            os.path.join(ROOT, "tools", "nmlint_waivers.json"))
        findings = apply_waivers(run_ast_pass(), waivers) + expired
        unwaived = [f for f in findings if not f.waived]
        assert unwaived == [], "\n".join(str(f) for f in unwaived)

    def test_scan_covers_the_source_tree(self):
        # the pass must actually be looking at src/repro/ — a broken
        # walk that scans 0 files would be vacuously "clean"
        assert scanned_file_count() >= 50

    def test_selftest_seeds_are_excluded_from_the_scan(self):
        assert "analysis/selftest.py" in ast_pass.SCAN_EXCLUDE


class TestSelftest:
    def test_every_rule_fires_on_its_seed(self):
        ok, fired = run_selftest()
        assert ok, f"silent rules: {[r for r, f in fired.items() if not f]}"
        # one seed per registered rule — registry drift fails here
        assert set(fired) == set(RULES_BY_ID)


class TestWaivers:
    def _write(self, tmp_path, waivers):
        path = tmp_path / "waivers.json"
        path.write_text(json.dumps({"waivers": waivers}))
        return str(path)

    def test_active_waiver_suppresses_by_rule_and_glob(self, tmp_path):
        path = self._write(tmp_path, [
            {"rule": "NM102", "path": "core/*.py", "reason": "migration",
             "expires": "2099-01-01"}])
        active, expired = load_waivers(path)
        assert len(active) == 1 and expired == []
        findings = [Finding("NM102", "core/operand.py", 3, "x"),
                    Finding("NM102", "serve/engine.py", 9, "x"),
                    Finding("NM103", "core/operand.py", 5, "x")]
        apply_waivers(findings, active)
        assert [f.waived for f in findings] == [True, False, False]
        assert findings[0].waiver_reason == "migration"

    def test_expired_waiver_stops_waiving_and_files_nm001(self, tmp_path):
        path = self._write(tmp_path, [
            {"rule": "NM102", "path": "core/*.py", "reason": "old",
             "expires": "2024-01-01"}])
        active, expired = load_waivers(
            path, today=datetime.date(2026, 8, 8))
        assert active == []
        assert len(expired) == 1 and expired[0].rule == "NM001"
        assert "expired" in expired[0].message

    def test_malformed_expiry_is_a_finding_not_a_crash(self, tmp_path):
        path = self._write(tmp_path, [
            {"rule": "NM102", "path": "x.py", "reason": "r",
             "expires": "soon"},
            {"rule": "NM103", "path": "y.py", "reason": "r"}])
        active, expired = load_waivers(path)
        assert active == []
        assert [f.rule for f in expired] == ["NM001", "NM001"]

    def test_committed_waiver_file_has_no_expired_entries(self):
        _, expired = load_waivers(
            os.path.join(ROOT, "tools", "nmlint_waivers.json"))
        assert expired == []


class TestReport:
    def test_schema_and_determinism(self, tmp_path):
        findings = [Finding("NM102", "a.py", 1, "m", waived=True,
                            waiver_reason="r"),
                    Finding("NM103", "b.py", 2, "m")]
        rep = build_report(findings, {"case": {"k": 1}}, ["case"],
                          scanned_files=3,
                          families_run=["numerics", "graph"])
        assert rep["schema_version"] == SCHEMA_VERSION
        assert set(rep["counts"]["by_rule"]) == set(RULES_BY_ID)
        assert rep["families_run"] == ["graph", "numerics"]  # sorted
        assert rep["counts"] == {
            "total": 2, "unwaived": 1, "waived": 1,
            "by_rule": {**{r.id: 0 for r in RULES},
                        "NM102": 1, "NM103": 1}}
        out = write_report(rep, str(tmp_path / "r.json"))
        rep2 = build_report(findings, {"case": {"k": 1}}, ["case"],
                           scanned_files=3,
                           families_run=["numerics", "graph"])
        with open(out) as f:
            assert json.load(f) == rep2  # no timestamps, diffs empty

    def test_committed_report_matches_the_registry(self):
        # results/NMLINT.json is committed; it must carry the current
        # schema, the current rules, all three families over the full
        # matrix, and zero unwaived findings
        with open(os.path.join(ROOT, "results", "NMLINT.json")) as f:
            rep = json.load(f)
        assert rep["schema_version"] == SCHEMA_VERSION
        assert set(rep["rules"]) == set(RULES_BY_ID)
        assert rep["counts"]["unwaived"] == 0
        assert rep["families_run"] == ["buffers", "graph", "numerics"]
        assert set(rep["cases_run"]) == {
            "conv", "dense_lm", "gradsync_mesh8", "kernels", "moe",
            "serve_u4"}


class TestAstRules:
    """check_source semantics beyond the selftest seeds: the
    allowlists and non-violating idioms must NOT fire."""

    def test_shim_call_inside_home_is_fine(self):
        src = "def nm_linear(x, w, cfg):\n    return nm_linear_core(x)\n" \
              "def wrap(x, w, cfg):\n    return nm_linear(x, w, cfg)\n"
        assert ast_pass.check_source("core/bdwp.py", src) == []
        assert any(f.rule == "NM101" for f in
                   ast_pass.check_source("models/layers.py", src))

    def test_unpack_allowed_in_sanctioned_producers(self):
        src = "def f(vals, idx):\n    return nm_unpack_n(vals, idx)\n"
        assert ast_pass.check_source("kernels/nm_spmm.py", src) == []
        assert ast_pass.check_source("optim/sgd.py", src) == []
        assert any(f.rule == "NM102" for f in
                   ast_pass.check_source("serve/engine.py", src))

    def test_where_without_vals_idx_in_scope_is_fine(self):
        src = "import jax.numpy as jnp\n" \
              "def mask(w, m):\n    return jnp.where(m, w, 0.0)\n"
        assert ast_pass.check_source("models/layers.py", src) == []

    def test_python_branch_on_concrete_value_is_fine(self):
        src = "def f(x, training):\n" \
              "    if training:\n        return x * 2\n    return x\n"
        assert ast_pass.check_source("train/step.py", src) == []

    def test_packedop_with_explicit_idx_bits_is_fine(self):
        src = "def f(vals, idx, cfg):\n" \
              "    return PackedOp(vals, idx, cfg, idx_bits=4)\n"
        assert ast_pass.check_source("serve/store.py", src) == []

    def test_unparseable_module_is_a_finding(self):
        fs = ast_pass.check_source("models/broken.py", "def f(:\n")
        assert len(fs) == 1 and "unparseable" in fs[0].message


class TestBufferRules:
    """NM4xx semantics beyond the selftest seeds."""

    # -- NM402: the PR 9 batcher crash pattern, reintroduced verbatim --
    PR9_PATTERN = (
        "import jax\n"
        "def build(step, sh):\n"
        "    return jax.jit(step, in_shardings=(sh,),\n"
        "                   donate_argnums=(0,))\n")

    def test_nm402_catches_the_pr9_unpinned_donation(self):
        # regression: donate + in_shardings with out_shardings left for
        # XLA to pick crashed the batcher in PR 9; the default AST pass
        # must refuse it anywhere in the tree
        fs = ast_pass.check_source("serve/batcher.py", self.PR9_PATTERN)
        assert any(f.rule == "NM402" for f in fs)

    def test_nm402_quiet_when_out_shardings_pinned(self):
        src = self.PR9_PATTERN.replace(
            "donate_argnums=(0,))",
            "out_shardings=(sh,), donate_argnums=(0,))")
        assert [f for f in ast_pass.check_source("serve/batcher.py", src)
                if f.rule == "NM402"] == []

    def test_nm402_quiet_on_donation_without_in_shardings(self):
        # solo-path donation (no shardings at all) lets XLA choose
        # consistently — that is the batcher's sanctioned solo idiom
        src = ("import jax\n"
               "def build(step):\n"
               "    return jax.jit(step, donate_argnums=(0,))\n")
        assert [f for f in ast_pass.check_source("serve/batcher.py", src)
                if f.rule == "NM402"] == []

    def test_nm402_sees_through_functools_partial(self):
        src = ("import functools, jax\n"
               "def build(step, sh):\n"
               "    return functools.partial(jax.jit, in_shardings=(sh,),\n"
               "                             donate_argnames=('s',))(step)\n")
        fs = ast_pass.check_source("train/step.py", src)
        assert any(f.rule == "NM402" for f in fs)

    # -- NM401: alias-count accounting ---------------------------------
    def test_nm401_alias_counting_and_clean_donation(self):
        import jax
        import jax.numpy as jnp

        from repro.analysis import (
            check_donation_aliased, count_output_aliases,
        )
        x = jnp.ones((8, 8), jnp.float32)
        jitted = jax.jit(lambda a: a * 2.0, donate_argnums=(0,))
        hlo = jitted.lower(x).compile().as_text()
        assert count_output_aliases(hlo) >= 1
        findings, metrics = check_donation_aliased(hlo, x, "t", "ok")
        assert findings == []
        assert metrics["donation_aliased"] >= metrics["donation_expected"]
        stripped = "\n".join(ln for ln in hlo.splitlines()
                             if "input_output_alias" not in ln)
        findings, _ = check_donation_aliased(stripped, x, "t", "dropped")
        assert [f.rule for f in findings] == ["NM401"]

    # -- NM404: reachability, allowlist, and the real serve package ----
    def test_nm404_fires_two_hops_from_the_async_driver(self):
        from repro.analysis import run_async_sync_pass
        sources = {
            "serve/fleet.py": ("async def _drive(self):\n"
                               "    self._emit()\n"),
            "serve/emit.py": ("import numpy as np\n"
                              "def _emit(self):\n"
                              "    return np.asarray(self.buf)\n"),
        }
        fs = run_async_sync_pass(sources=sources)
        assert any(f.rule == "NM404" for f in fs)

    def test_nm404_allowlists_the_batcher_device_boundary(self):
        # batcher.step/prefill ARE the sanctioned host-device boundary:
        # a sync there must not fire even when the driver reaches it
        from repro.analysis import run_async_sync_pass
        sources = {
            "serve/fleet.py": ("async def _drive(self):\n"
                               "    step(self)\n"),
            "serve/batcher.py": ("def step(self):\n"
                                 "    return self.out.item()\n"),
        }
        assert run_async_sync_pass(sources=sources) == []

    def test_nm404_ignores_syncs_unreachable_from_async_roots(self):
        from repro.analysis import run_async_sync_pass
        sources = {
            "serve/fleet.py": "async def _drive(self):\n    pass\n",
            "serve/debug.py": ("import numpy as np\n"
                               "def dump(self):\n"
                               "    return np.asarray(self.buf)\n"),
        }
        assert run_async_sync_pass(sources=sources) == []

    def test_nm404_real_serve_package_is_clean(self):
        from repro.analysis import run_async_sync_pass
        assert run_async_sync_pass() == []


class TestNumericsRules:
    """NM3xx dtype-provenance semantics: the exemptions that keep the
    real training graphs clean must hold, not just the positive seeds."""

    def test_nm301_quiet_when_selection_reads_the_master(self):
        import jax
        import jax.numpy as jnp

        from repro.analysis import check_master_mask_source, tag_inputs

        def good_select(w):
            _, i = jax.lax.top_k(w, 2)  # scored straight off fp32
            return i

        w = jnp.ones((4, 8), jnp.float32)
        findings, inspected = check_master_mask_source(
            good_select, tag_inputs(w), (2, 8), "t", args=(w,))
        assert findings == [] and inspected >= 1

    def test_nm301_ef_state_rounding_does_not_taint_selection(self):
        # the PR 6 wire path: (g + err) deliberately rounds to u16 and
        # back; err is f32 but NOT master lineage, so a downstream
        # selection off the decoded update must stay clean
        import jax
        import jax.numpy as jnp

        from repro.analysis import check_master_mask_source, tag_inputs

        def wire_then_select(w, err):
            wire = (w + err).astype(jnp.bfloat16).astype(jnp.float32)
            _, i = jax.lax.top_k(wire, 2)
            return i

        w = jnp.ones((4, 8), jnp.float32)
        err = jnp.zeros((4, 8), jnp.float32)
        tags = tag_inputs({"w": w, "err": err})
        findings, _ = check_master_mask_source(
            wire_then_select, tags, (2, 8), "t", args=(w, err))
        # positive control: w lends master lineage, so this DOES fire
        assert any(f.rule == "NM301" for f in findings)

        # err alone must not — EF residual exists to absorb rounding

        def ef_only_select(err):
            wire = err.astype(jnp.bfloat16).astype(jnp.float32)
            _, i = jax.lax.top_k(wire, 2)
            return i

        findings, _ = check_master_mask_source(
            ef_only_select, tag_inputs({"err": err}), (2, 8), "t",
            args=(err,))
        assert findings == []

    def test_nm302_quiet_without_master_lineage_rounding(self):
        # forward-only bf16 rounding (RoPE tables, norm internals) must
        # not smear into the state outputs — the master-lineage gate
        import jax.numpy as jnp

        from repro.analysis import check_no_double_round, tag_inputs

        def update(w, g):
            scale = jnp.float32(0.1).astype(jnp.bfloat16).astype(
                jnp.float32)  # rounded, but not master-derived
            return {"master": {"w": w - scale * g}}

        w = jnp.ones((4, 8), jnp.float32)
        g = jnp.ones((4, 8), jnp.float32)
        assert check_no_double_round(update, tag_inputs(w, g),
                                     ["master/w"], "t",
                                     args=(w, g)) == []

    def test_nm303_quiet_with_f32_accumulation(self):
        import jax
        import jax.numpy as jnp

        from repro.analysis import check_accum_dtype

        def good_mm(a, b):
            return jax.lax.dot(a, b,
                               preferred_element_type=jnp.float32)

        a = jnp.ones((4, 8), jnp.bfloat16)
        b = jnp.ones((8, 4), jnp.bfloat16)
        findings, sites = check_accum_dtype(good_mm, "t", args=(a, b))
        assert findings == [] and sites == 1

    def test_nm304_quiet_for_intra_pod_collectives(self):
        # a widening convert feeding an INTRA-pod all-reduce is the
        # sanctioned f32 reduce inside the pod — only pod-crossing
        # wire traffic must stay narrow
        from repro.analysis import check_wire_narrow
        hlo = """HloModule t

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main (p0: bf16[8,8]) -> f32[8,8] {
  %p0 = bf16[8,8] parameter(0)
  %cvt = f32[8,8] convert(bf16[8,8] %p0)
  ROOT %ar = f32[8,8] all-reduce(f32[8,8] %cvt), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
}
"""
        findings, inspected = check_wire_narrow(hlo, "t", pod_block=4)
        assert findings == [] and inspected == 1


class TestDocsInSync:
    def test_every_rule_documented_in_analysis_md(self):
        with open(os.path.join(ROOT, "docs", "analysis.md")) as f:
            text = f.read()
        for rule in RULES:
            assert rule.id in text, f"{rule.id} missing from docs/analysis.md"
            assert rule.title in text, (
                f"{rule.id} title '{rule.title}' missing from "
                f"docs/analysis.md")


class TestCli:
    def test_list_rules_exits_zero(self):
        proc = subprocess.run(
            [sys.executable, os.path.join(ROOT, "tools", "nmlint.py"),
             "--list-rules"], cwd=ROOT, capture_output=True, text=True)
        assert proc.returncode == 0, proc.stderr
        for rule in RULES:
            assert rule.id in proc.stdout
