"""Unified SparseOperand API tests (core/operand.nm_apply).

What must hold:
  * every operand variant consumed through ``nm_apply`` is BITWISE equal
    (forward AND gradients) to the pre-refactor consumption path it
    replaced — in-op masking (nm_linear/nm_conv), pre-generated FF/BP
    operands (nm_linear_pregen/nm_conv_pregen, incl. stacked MoE expert
    leaves), packed serving (nm_linear_packed), shared-mode serving
    (packed_shared_apply);
  * the packed pre-generated train FORWARD consumes ``(vals, idx)``
    directly through kernels/nm_spmm on the pallas backend — no
    scatter-unpack anywhere in the traced forward (either backend), and
    the lowered forward really invokes the kernel;
  * ``pregen_pack=True`` training is bitwise-identical across
    nm_backend="jnp" / "pallas" and the unpacked state (solo device);
  * the operand pytrees flatten in the dict-era leaf order, so PR-3/4
    checkpoints whose compute trees stored operand *dicts* restore
    leaf-for-leaf (bitwise) into PregenOp-typed state — solo and across
    mesh shapes;
  * the old bdwp entry points still work as thin deprecation shims.
"""

import sys

if "jax" not in sys.modules:  # standalone: force before backend init
    from repro.launch.spmd import force_host_devices
    force_host_devices(8)

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.configs import get_arch
from repro.core import bdwp
from repro.core import operand as O
from repro.core.sparsity import (DENSE, SparsityConfig, nm_mask, nm_pack,
                                 nm_unpack_n, pack_idx_u4, sparsify)
from repro.data import synthetic as D
from repro.kernels import ops
from repro.launch.hlo_cost import count_jaxpr_prims, count_mask_ops
from repro.launch.mesh import make_host_mesh  # noqa: F401


def _solo_mesh():
    """A literal 1-device mesh so the solo parity tests stay solo even
    under a forced multi-device backend (the spmd CI job)."""
    from repro.launch import spmd
    return spmd.single_device_mesh()
from repro.models import layers as L
from repro.models import transformer_lm as T
from repro.optim import sgd
from repro.train import step as ST
from repro.train.checkpoint import CheckpointManager

ARCH = get_arch("qwen3-8b")
CFG = ARCH.smoke
OPT = sgd.SGDConfig(lr=0.05, total_steps=16)
BDWP = SparsityConfig(n=2, m=8, method="bdwp")

mesh8_only = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _eq(a, b, msg=""):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=msg)


def _tree_eq(ta, tb):
    fa = jax.tree_util.tree_flatten_with_path(ta)[0]
    fb = jax.tree.leaves(tb)
    assert len(fa) == len(fb)
    for (path, a), b in zip(fa, fb):
        _eq(a, b, "/".join(str(getattr(k, "key", k)) for k in path))


def _legacy(fn, *args, **kw):
    """Call a deprecated bdwp entry point without warning noise."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*args, **kw)


def _pregen_arrays(key, k=16, f=16, sp=BDWP, stack=()):
    """(x, w, vals, idx, ff_dense, bp) fixture for pregen parity tests."""
    kw, kx = jax.random.split(jax.random.PRNGKey(key))
    w = jax.random.normal(kw, (*stack, k, f), jnp.float32)
    ff_mask = nm_mask(w, sp.n, sp.m, axis=w.ndim - 2)
    bp_mask = nm_mask(w, sp.n, sp.m, axis=w.ndim - 1)
    ff = jnp.where(ff_mask, w, 0.0).astype(jnp.bfloat16)
    bp = jnp.where(bp_mask, w, 0.0).astype(jnp.bfloat16)
    vals, idx = nm_pack(ff, sp.n, sp.m, axis=w.ndim - 2)
    x = jax.random.normal(kx, (*stack, 4, k), jnp.bfloat16)
    return x, w, vals, idx, ff, bp


class TestOperandPytree:
    def test_flatten_roundtrip_preserves_type_and_cfg(self):
        x, w, vals, idx, ff, bp = _pregen_arrays(0)
        for op in (O.DenseOp(w), O.MaskedOp(w, BDWP),
                   O.PregenOp(bp=bp, ff=ff, mask=None, cfg=BDWP),
                   O.PregenOp(bp=bp, vals=vals, idx=idx, cfg=BDWP),
                   O.PackedOp(vals, idx, BDWP), O.SharedOp(vals, idx[:, 0])):
            leaves, tdef = jax.tree_util.tree_flatten(op)
            back = jax.tree_util.tree_unflatten(tdef, leaves)
            assert type(back) is type(op)
            assert back.fields == op.fields
            assert back.cfg == op.cfg
            for fld in op.fields:
                _eq(back[fld], op[fld])

    def test_flatten_order_matches_dict_era(self):
        """PregenOp leaves flatten in the sorted-key order the operand
        DICTS had — the invariant that makes old checkpoints restore
        leaf-for-leaf (dicts flatten in sorted key order)."""
        x, w, vals, idx, ff, bp = _pregen_arrays(1)
        mask = nm_mask(w, 2, 8, axis=0)
        op = O.PregenOp(bp=bp, ff=ff, mask=mask, cfg=BDWP)
        as_dict = {"bp": bp, "ff": ff, "mask": mask}
        for a, b in zip(jax.tree.leaves(op), jax.tree.leaves(as_dict)):
            _eq(a, b)
        op_p = O.PregenOp(bp=bp, vals=vals, idx=idx, mask=mask, cfg=BDWP)
        dict_p = {"bp": bp, "vals": vals, "idx": idx, "mask": mask}
        for a, b in zip(jax.tree.leaves(op_p), jax.tree.leaves(dict_p)):
            _eq(a, b)

    def test_dict_like_accessors(self):
        x, w, vals, idx, ff, bp = _pregen_arrays(2)
        op = O.PregenOp(bp=bp, vals=vals, idx=idx, cfg=BDWP)
        assert "vals" in op and "ff" not in op
        assert set(op) == {"bp", "idx", "vals"}
        _eq(op["bp"], bp)
        assert op.get("mask") is None
        assert op.is_packed
        with pytest.raises(KeyError):
            op["ff"]

    def test_tree_map_and_eval_shape(self):
        x, w, vals, idx, ff, bp = _pregen_arrays(3)
        op = O.PregenOp(bp=bp, ff=ff, cfg=BDWP)
        z = jax.tree.map(jnp.zeros_like, op)
        assert isinstance(z, O.PregenOp) and float(z.bp.sum()) == 0.0
        ab = jax.eval_shape(lambda o: o, op)
        assert isinstance(ab, O.PregenOp)
        assert ab.bp.shape == bp.shape

    def test_packed_op_dense_shape(self):
        x, w, vals, idx, ff, bp = _pregen_arrays(4)
        assert O.PackedOp(vals, idx, BDWP).shape == w.shape

    def test_as_operand_dispatch(self):
        x, w, vals, idx, ff, bp = _pregen_arrays(5)
        op = O.as_operand(w, "blocks/ffn/w_gate/w", BDWP)
        assert isinstance(op, O.MaskedOp) and op.cfg == BDWP
        op = O.as_operand(w, "router/w", BDWP)  # excluded -> dense cfg
        assert isinstance(op, O.MaskedOp) and op.cfg.is_dense
        op = O.as_operand({"bp": bp, "ff": ff}, "p/w", BDWP)
        assert isinstance(op, O.PregenOp) and not op.is_packed
        op = O.as_operand({"vals": vals, "idx": idx}, "p/w", BDWP)
        assert isinstance(op, O.PackedOp)
        op = O.as_operand({"vals": vals, "idx": idx[:, 0]}, "p/w", BDWP)
        assert isinstance(op, O.SharedOp)
        assert O.as_operand(op, "p/w", BDWP) is op


class TestNmApplyParity:
    """nm_apply vs each pre-refactor consumption path — bitwise."""

    @pytest.mark.parametrize("method",
                             ["dense", "srste", "sdgp", "sdwp", "bdwp"])
    def test_masked_linear_all_methods(self, method):
        sp = SparsityConfig(n=2, m=8, method=method)
        x, w, *_ = _pregen_arrays(10, sp=sp)

        def new(x, w):
            return O.nm_apply(O.MaskedOp(w, sp), x).astype(jnp.float32).sum()

        def old(x, w):
            return _legacy(bdwp.nm_linear, x, w, sp).astype(
                jnp.float32).sum()

        _eq(O.nm_apply(O.MaskedOp(w, sp), x), _legacy(bdwp.nm_linear, x, w, sp))
        ga = jax.grad(new, argnums=(0, 1))(x, w)
        gb = jax.grad(old, argnums=(0, 1))(x, w)
        for a, b in zip(ga, gb):
            _eq(a, b)

    def test_pregen_linear(self):
        x, w, vals, idx, ff, bp = _pregen_arrays(11)
        op = O.PregenOp(bp=bp, ff=ff, cfg=BDWP)
        _eq(O.nm_apply(op, x), _legacy(bdwp.nm_linear_pregen, x, ff, bp))

        def new(x, ff, bp):
            return O.nm_apply(O.PregenOp(bp=bp, ff=ff, cfg=BDWP),
                              x).astype(jnp.float32).sum()

        def old(x, ff, bp):
            return _legacy(bdwp.nm_linear_pregen, x, ff, bp).astype(
                jnp.float32).sum()

        ga = jax.grad(new, argnums=(0, 1, 2))(x, ff, bp)
        gb = jax.grad(old, argnums=(0, 1, 2))(x, ff, bp)
        for a, b in zip(ga, gb):
            _eq(a, b)

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_pregen_packed_matches_unpacked(self, backend):
        """Packed (vals, idx) consumption — through the kernel on the
        pallas backend, select-decompressed on jnp — is bitwise the
        unpacked pregen path: same forward, same dx, same dense WU
        gradient on the bp cotangent, zero cotangent on vals."""
        x, w, vals, idx, ff, bp = _pregen_arrays(12)
        op = O.PregenOp(bp=bp, vals=vals, idx=idx, cfg=BDWP)
        y = O.nm_apply(op, x, backend=backend)
        _eq(y, _legacy(bdwp.nm_linear_pregen, x, ff, bp), backend)

        def new(x, vals, bp):
            o = O.PregenOp(bp=bp, vals=vals, idx=idx, cfg=BDWP)
            return O.nm_apply(o, x, backend=backend).astype(
                jnp.float32).sum()

        def old(x, ff, bp):
            return _legacy(bdwp.nm_linear_pregen, x, ff, bp).astype(
                jnp.float32).sum()

        dx_n, dv_n, dbp_n = jax.grad(new, argnums=(0, 1, 2))(x, vals, bp)
        dx_o, dff_o, dbp_o = jax.grad(old, argnums=(0, 1, 2))(x, ff, bp)
        _eq(dx_n, dx_o)
        _eq(dbp_n, dbp_o)  # the dense straight-through WU gradient
        assert float(jnp.abs(dv_n).sum()) == 0.0
        assert float(jnp.abs(dff_o).sum()) == 0.0

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_pregen_packed_stacked_expert_leaf(self, backend):
        """Stacked (E, K, F) MoE leaves ride the same packed consumption
        (the kernel vmaps over the expert axis) — bitwise vs the vmapped
        unpacked path, gradients included."""
        x, w, vals, idx, ff, bp = _pregen_arrays(13, stack=(3,))
        op = O.PregenOp(bp=bp, vals=vals, idx=idx, cfg=BDWP)
        y = O.nm_apply(op, x, backend=backend, stacked=True)
        ref = jax.vmap(O.pregen_linear)(x, ff, bp)
        _eq(y, ref, backend)

        def new(x, vals, bp):
            o = O.PregenOp(bp=bp, vals=vals, idx=idx, cfg=BDWP)
            return O.nm_apply(o, x, backend=backend,
                              stacked=True).astype(jnp.float32).sum()

        def old(x, ff, bp):
            return jax.vmap(O.pregen_linear)(x, ff, bp).astype(
                jnp.float32).sum()

        dx_n, dv_n, dbp_n = jax.grad(new, argnums=(0, 1, 2))(x, vals, bp)
        dx_o, _, dbp_o = jax.grad(old, argnums=(0, 1, 2))(x, ff, bp)
        _eq(dx_n, dx_o)
        _eq(dbp_n, dbp_o)
        assert float(jnp.abs(dv_n).sum()) == 0.0

    def test_masked_stacked_expert_leaf(self):
        sp = SparsityConfig(n=2, m=4, method="bdwp")
        x, w, *_ = _pregen_arrays(14, sp=sp, stack=(3,))
        y = O.nm_apply(O.MaskedOp(w, sp), x, stacked=True)
        ref = jax.vmap(lambda xe, we: _legacy(bdwp.nm_linear, xe, we, sp))(
            x, w)
        _eq(y, ref)

    def test_masked_and_pregen_conv(self):
        sp = SparsityConfig(n=2, m=8, method="bdwp")
        kw, kx = jax.random.split(jax.random.PRNGKey(15))
        w = jax.random.normal(kw, (3, 3, 16, 16), jnp.float32)
        x = jax.random.normal(kx, (2, 8, 8, 16), jnp.bfloat16)
        _eq(O.nm_apply(O.MaskedOp(w, sp), x, stride=2),
            _legacy(bdwp.nm_conv, x, w, sp, 2))
        ff = jnp.where(nm_mask(w, 2, 8, axis=2), w, 0.0).astype(jnp.bfloat16)
        bp = jnp.where(nm_mask(w, 2, 8, axis=3), w, 0.0).astype(jnp.bfloat16)
        op = O.PregenOp(bp=bp, ff=ff, cfg=sp)
        _eq(O.nm_apply(op, x), _legacy(bdwp.nm_conv_pregen, x, ff, bp))
        # packed conv leaves decompress (scatter-free) then convolve
        vals, idx = nm_pack(ff, 2, 8, axis=2)
        op_p = O.PregenOp(bp=bp, vals=vals, idx=idx, cfg=sp)
        for backend in ("jnp", "pallas"):
            _eq(O.nm_apply(op_p, x, backend=backend),
                _legacy(bdwp.nm_conv_pregen, x, ff, bp), backend)

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_packed_serve_operand(self, use_pallas):
        x, w, vals, idx, ff, bp = _pregen_arrays(16)
        op = O.PackedOp(vals, idx, BDWP)
        backend = "pallas" if use_pallas else "jnp"
        _eq(O.nm_apply(op, x, backend=backend),
            _legacy(bdwp.nm_linear_packed, x, vals, idx, BDWP,
                    use_pallas=use_pallas))

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_packed_serve_stacked_leaf(self, backend):
        """Layer-stacked (L, Kc, F) PackedOp leaves (pack_tree_element
        packs stacked dict sites per layer) consume outside the scan
        too: the kernel vmaps over the stack axis, bitwise the per-layer
        2-D consumption."""
        x, w, vals, idx, ff, bp = _pregen_arrays(25, stack=(3,))
        op = O.PackedOp(vals, idx, BDWP)
        y = O.nm_apply(op, x, backend=backend)
        ref = jnp.stack([
            O.nm_apply(O.PackedOp(vals[i], idx[i], BDWP), x[i],
                       backend=backend)
            for i in range(vals.shape[0])])
        _eq(y, ref, backend)

    def test_shared_serve_operand(self):
        x = jax.random.normal(jax.random.PRNGKey(17), (4, 32), jnp.bfloat16)
        w = jax.random.normal(jax.random.PRNGKey(18), (32, 64))
        vals, rows = bdwp.shared_ff_pack(w, BDWP)
        op = O.SharedOp(vals, rows)
        _eq(O.nm_apply(op, x),
            _legacy(bdwp.packed_shared_apply, {"vals": vals, "idx": rows}, x))

    def test_dense_apply_routes_every_leaf_format(self):
        """layers.dense_apply accepts arrays, PregenOp leaves, PackedOp
        leaves and the legacy dict formats — one nm_apply seam."""
        x, w, vals, idx, ff, bp = _pregen_arrays(19)
        b = jnp.ones((w.shape[-1],), jnp.float32)
        name = "blocks/ffn/w_gate/w"
        y_arr = L.dense_apply({"w": w, "b": b}, x, name, BDWP)
        _eq(y_arr, _legacy(bdwp.nm_linear, x, w, BDWP)
            + b.astype(jnp.bfloat16))
        op = O.PregenOp(bp=bp, ff=ff, cfg=BDWP)
        y_op = L.dense_apply({"w": op}, x, name, BDWP)
        y_dict = L.dense_apply({"w": {"bp": bp, "ff": ff}}, x, name, BDWP)
        _eq(y_op, y_dict)
        y_pk = L.dense_apply({"w": O.PackedOp(vals, idx, BDWP)}, x, name,
                             BDWP)
        y_pk_dict = L.dense_apply({"vals": vals, "idx": idx}, x, name, BDWP)
        _eq(y_pk, y_pk_dict)


class TestU4Operand:
    """u4-packed index planes through the one nm_apply seam: the fused
    decode kernel (and its jnp fallback) consuming two offsets per byte
    must be BITWISE the byte-wide path it halves the index traffic of."""

    def _u4(self, key, stack=()):
        x, w, vals, idx, ff, bp = _pregen_arrays(key, stack=stack)
        idx4 = pack_idx_u4(idx, axis=w.ndim - 2)
        return x, w, vals, idx, idx4, ff, bp

    def test_pytree_aux_roundtrip_preserves_idx_bits(self):
        x, w, vals, idx, idx4, ff, bp = self._u4(30)
        for op in (O.PackedOp(vals, idx4, BDWP, idx_bits=4),
                   O.PregenOp(bp=bp, vals=vals, idx=idx4, cfg=BDWP,
                              idx_bits=4)):
            leaves, tdef = jax.tree_util.tree_flatten(op)
            back = jax.tree_util.tree_unflatten(tdef, leaves)
            assert type(back) is type(op) and back.idx_bits == 4
            for fld in op.fields:
                _eq(back[fld], op[fld])
        # distinct aux: a u4 and a u8 operand must never share a jit
        # cache entry (the kernel decodes them differently)
        t4 = jax.tree_util.tree_structure(O.PackedOp(vals, idx4, BDWP, 4))
        t8 = jax.tree_util.tree_structure(O.PackedOp(vals, idx, BDWP, 8))
        assert t4 != t8

    def test_idx_bits_validated(self):
        x, w, vals, idx, idx4, ff, bp = self._u4(31)
        with pytest.raises(ValueError):
            O.PackedOp(vals, idx4, BDWP, idx_bits=6)
        with pytest.raises(ValueError):
            O.PregenOp(bp=bp, vals=vals, idx=idx4, cfg=BDWP, idx_bits=2)

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_packed_serve_u4_bitwise_vs_u8(self, backend):
        """The fused u4 decode — in-kernel nibble expansion on pallas,
        select-decompress on jnp — is bitwise the byte-wide kernel AND
        the unpacked masked matmul oracle."""
        x, w, vals, idx, idx4, ff, bp = self._u4(32)
        y4 = O.nm_apply(O.PackedOp(vals, idx4, BDWP, idx_bits=4), x,
                        backend=backend)
        y8 = O.nm_apply(O.PackedOp(vals, idx, BDWP), x, backend=backend)
        _eq(y4, y8, backend)
        _eq(y4, jnp.matmul(x, ff), backend)

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_packed_serve_u4_stacked_leaf(self, backend):
        """Layer-stacked (L, Kc/2, F) u4 planes vmapping over the stack
        axis — bitwise the per-layer 2-D consumption."""
        x, w, vals, idx, idx4, ff, bp = self._u4(33, stack=(3,))
        op = O.PackedOp(vals, idx4, BDWP, idx_bits=4)
        y = O.nm_apply(op, x, backend=backend)
        ref = jnp.stack([
            O.nm_apply(O.PackedOp(vals[i], idx4[i], BDWP, idx_bits=4),
                       x[i], backend=backend)
            for i in range(vals.shape[0])])
        _eq(y, ref, backend)
        _eq(y, O.nm_apply(O.PackedOp(vals, idx, BDWP), x, backend=backend),
            backend)

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_pregen_train_forward_u4_bitwise(self, backend):
        """The packed pregen TRAIN forward with a u4 plane: forward, dx
        and the dense bp cotangent all bitwise the u8 path; vals and the
        index plane stay gradient-free."""
        x, w, vals, idx, idx4, ff, bp = self._u4(34)

        def loss(x, vals, bp, idx_p, bits):
            o = O.PregenOp(bp=bp, vals=vals, idx=idx_p, cfg=BDWP,
                           idx_bits=bits)
            return O.nm_apply(o, x, backend=backend).astype(
                jnp.float32).sum()

        y4 = O.nm_apply(O.PregenOp(bp=bp, vals=vals, idx=idx4, cfg=BDWP,
                                   idx_bits=4), x, backend=backend)
        y8 = O.nm_apply(O.PregenOp(bp=bp, vals=vals, idx=idx, cfg=BDWP),
                        x, backend=backend)
        _eq(y4, y8, backend)
        g4 = jax.grad(loss, argnums=(0, 1, 2))(x, vals, bp, idx4, 4)
        g8 = jax.grad(loss, argnums=(0, 1, 2))(x, vals, bp, idx, 8)
        for a, b in zip(g4, g8):
            _eq(a, b, backend)
        assert float(jnp.abs(g4[1]).sum()) == 0.0

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_pregen_u4_stacked_moe_leaf(self, backend):
        x, w, vals, idx, idx4, ff, bp = self._u4(35, stack=(3,))
        op4 = O.PregenOp(bp=bp, vals=vals, idx=idx4, cfg=BDWP, idx_bits=4)
        op8 = O.PregenOp(bp=bp, vals=vals, idx=idx, cfg=BDWP)
        _eq(O.nm_apply(op4, x, backend=backend, stacked=True),
            O.nm_apply(op8, x, backend=backend, stacked=True), backend)

    def test_odd_compact_tile_falls_back_bitwise(self):
        """A (K·N/M) compact axis the kernel tiling can't halve (odd
        per-block count) routes to the jnp oracle inside ops.nm_spmm —
        still bitwise the u8 consumption.  Impossible for even n (2:8
        tiles always halve), so force it with 3:6 at K=6 -> Kc=3 and a
        padded final nibble in the u4 plane."""
        sp = SparsityConfig(n=3, m=6, method="bdwp")
        kw, kx = jax.random.split(jax.random.PRNGKey(36))
        w = jax.random.normal(kw, (6, 16), jnp.float32)
        ff = jnp.where(nm_mask(w, sp.n, sp.m, axis=0), w, 0.0).astype(
            jnp.bfloat16)
        vals, idx = nm_pack(ff, sp.n, sp.m, axis=0)
        idx4 = pack_idx_u4(idx, axis=0)
        assert idx4.shape[0] == 2  # ceil(3/2): the plane really padded
        x = jax.random.normal(kx, (4, 6), jnp.bfloat16)
        for backend in ("jnp", "pallas"):
            y4 = O.nm_apply(O.PackedOp(vals, idx4, sp, idx_bits=4), x,
                            backend=backend)
            _eq(y4, O.nm_apply(O.PackedOp(vals, idx, sp), x,
                               backend=backend), backend)


class TestPackedTrainForward:
    """The ROADMAP item: pregen_pack=True training consumes (vals, idx)
    directly through kernels/nm_spmm inside the train-step forward."""

    def _fwd(self, backend, pack=True):
        state = ST.init_train_state(jax.random.PRNGKey(0), CFG, sp_cfg=BDWP,
                                    pregen_pack=pack)
        batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
                 "labels": jnp.zeros((2, 32), jnp.int32)}

        def forward_loss(compute, batch):
            with O.backend_scope(backend):
                hidden, _, aux = T.forward(compute, batch["tokens"], CFG,
                                           BDWP)
                return T.lm_loss(compute, hidden, batch["labels"], CFG) \
                    + 0.01 * aux

        structs = jax.tree.map(
            lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype),
            (state["compute"], batch))
        return forward_loss, structs, state

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_no_scatter_unpack_in_forward(self, backend):
        """Neither backend scatters packed operands back to dense in the
        traced forward (the jnp fallback decompresses with selects; the
        pallas backend never leaves the kernel) — backward included."""
        forward_loss, (cstructs, bstructs), state = self._fwd(backend)
        jaxpr = jax.make_jaxpr(forward_loss)(cstructs, bstructs)
        assert count_jaxpr_prims(jaxpr.jaxpr,
                                 names=("scatter", "scatter-add")) == 0
        # the mask-once selection lives in the OPTIMIZER, not here
        assert count_jaxpr_prims(jaxpr.jaxpr, names=("top_k", "sort")) == 0

        # backward included: packing must add ZERO scatters over the
        # unpacked pregen baseline (the embed-table / loss-gather
        # cotangents legitimately scatter in both)
        def grad_scatters(pack):
            fwd, (cs, bs), st = self._fwd(backend, pack=pack)
            diff, meta = ST.split_compute(st["compute"])
            dstructs = [jax.ShapeDtypeStruct(v.shape, v.dtype) for v in diff]
            gaxpr = jax.make_jaxpr(jax.grad(
                lambda d, b: fwd(ST.merge_compute(d, meta), b)
            ))(dstructs, bs)
            return count_jaxpr_prims(gaxpr.jaxpr,
                                     names=("scatter", "scatter-add"))

        assert grad_scatters(pack=True) == grad_scatters(pack=False)

    def test_pallas_forward_invokes_nm_spmm(self):
        """Every packed FF consumption in the pallas-backend forward is
        a pallas_call (the nm_spmm kernel); the jnp backend has none."""
        fwd_p, (cs, bs), state = self._fwd("pallas")
        n_sites = sum(isinstance(leaf, O.PregenOp) and leaf.is_packed
                      for leaf in jax.tree.leaves(
                          state["compute"],
                          is_leaf=lambda x: isinstance(x, O.PregenOp)))
        assert n_sites > 0
        jp = jax.make_jaxpr(fwd_p)(cs, bs)
        assert count_jaxpr_prims(jp.jaxpr, names=("pallas_call",)) >= n_sites
        fwd_j, (cs, bs), _ = self._fwd("jnp")
        jj = jax.make_jaxpr(fwd_j)(cs, bs)
        assert count_jaxpr_prims(jj.jaxpr, names=("pallas_call",)) == 0

    def _run(self, backend, pack=True, steps=3):
        mesh = _solo_mesh()
        bundle = ST.build_lm_train(CFG, mesh, BDWP, OPT, donate=False,
                                   pregen_pack=pack, nm_backend=backend)
        state = ST.init_train_state(jax.random.PRNGKey(0), CFG, sp_cfg=BDWP,
                                    pregen_pack=pack)
        state = jax.device_put(state, bundle.state_shardings)
        stream = D.lm_stream(CFG.vocab, 2, 32, seed=0)
        losses = []
        for i, (_, batch) in enumerate(stream):
            if i >= steps:
                break
            state, metrics = bundle.step_fn(state, batch)
            losses.append(float(metrics["loss"]))
        return state, losses

    def test_packed_train_bitwise_across_backends_and_vs_unpacked(self):
        """Solo device: pregen_pack training is bitwise identical on the
        jnp and pallas backends, and to the unpacked pregen state — the
        kernel consumption changed WHERE the FF operand decompresses
        (VMEM), not WHAT is computed."""
        s_j, l_j = self._run("jnp")
        s_p, l_p = self._run("pallas")
        s_u, l_u = self._run("jnp", pack=False)
        assert l_j == l_p == l_u
        for a, b in zip(jax.tree.leaves(s_j["master"]),
                        jax.tree.leaves(s_p["master"])):
            _eq(a, b)
        for a, b in zip(jax.tree.leaves(s_j["master"]),
                        jax.tree.leaves(s_u["master"])):
            _eq(a, b)

    def test_mask_once_invariant_survives_pallas_backend(self):
        mesh = _solo_mesh()
        bundle = ST.build_lm_train(CFG, mesh, BDWP, OPT, donate=False,
                                   pregen_pack=True, nm_backend="pallas")
        state = ST.init_train_state(jax.random.PRNGKey(0), CFG, sp_cfg=BDWP,
                                    pregen_pack=True)
        batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
                 "labels": jnp.zeros((2, 32), jnp.int32)}
        structs = jax.tree.map(
            lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), (state, batch))
        n_sites = sum(
            bdwp.pregen_site(n, sgd._logical_shape(n, w.shape)[0], BDWP)
            for n, w in zip(sgd._names_of(state["master"]),
                            jax.tree.leaves(state["master"])))
        assert count_mask_ops(bundle.step_fn, structs[0],
                              structs[1]) == n_sites


class TestDeprecationShims:
    def test_shims_warn_and_compute(self):
        bdwp.reset_deprecation_warnings()  # shims warn only once/process
        x, w, vals, idx, ff, bp = _pregen_arrays(20)
        calls = [
            (lambda: bdwp.nm_linear(x, w, BDWP),
             lambda: O.nm_apply(O.MaskedOp(w, BDWP), x)),
            (lambda: bdwp.nm_linear_pregen(x, ff, bp),
             lambda: O.nm_apply(O.PregenOp(bp=bp, ff=ff, cfg=BDWP), x)),
            (lambda: bdwp.nm_linear_packed(x, vals, idx, BDWP),
             lambda: O.nm_apply(O.PackedOp(vals, idx, BDWP), x,
                                backend="jnp")),
        ]
        for old_fn, new_fn in calls:
            with pytest.warns(DeprecationWarning):
                y_old = old_fn()
            _eq(y_old, new_fn())

    def test_conv_shims_warn_and_compute(self):
        bdwp.reset_deprecation_warnings()
        kw, kx = jax.random.split(jax.random.PRNGKey(21))
        w = jax.random.normal(kw, (3, 3, 16, 16), jnp.float32)
        x = jax.random.normal(kx, (2, 8, 8, 16), jnp.bfloat16)
        with pytest.warns(DeprecationWarning):
            y = bdwp.nm_conv(x, w, BDWP)
        _eq(y, O.nm_apply(O.MaskedOp(w, BDWP), x))
        ff = jnp.where(nm_mask(w, 2, 8, axis=2), w, 0.0).astype(jnp.bfloat16)
        bp = jnp.where(nm_mask(w, 2, 8, axis=3), w, 0.0).astype(jnp.bfloat16)
        with pytest.warns(DeprecationWarning):
            y = bdwp.nm_conv_pregen(x, ff, bp)
        _eq(y, O.nm_apply(O.PregenOp(bp=bp, ff=ff, cfg=BDWP), x))

    def test_shims_warn_once_per_process(self):
        """A per-step training loop through a shim must not spam one
        DeprecationWarning per call — only the first call warns."""
        bdwp.reset_deprecation_warnings()
        x, w, *_ = _pregen_arrays(23)
        with pytest.warns(DeprecationWarning):
            bdwp.nm_linear(x, w, BDWP)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            bdwp.nm_linear(x, w, BDWP)  # silent or this raises

    def test_is_pregen_covers_both_forms(self):
        x, w, vals, idx, ff, bp = _pregen_arrays(22)
        assert bdwp.is_pregen(O.PregenOp(bp=bp, ff=ff, cfg=BDWP))
        assert bdwp.is_pregen({"bp": bp, "ff": ff})
        assert not bdwp.is_pregen({"w": w})
        assert not bdwp.is_pregen(w)

    def test_shared_decompress_is_the_one_implementation(self):
        """The dedicated helper is bitwise nm_unpack_n (scatter formul.)
        and is what the kernel tile decompress delegates to."""
        from repro.kernels import decompress_nm
        from repro.kernels.nm_spmm import _decompress

        x, w, vals, idx, ff, bp = _pregen_arrays(23)
        _eq(decompress_nm(vals, idx, 2, 8, axis=-2),
            nm_unpack_n(vals, idx, 2, 8, axis=-2))
        _eq(_decompress(vals, idx, 2, 8),
            nm_unpack_n(vals, idx, 2, 8, axis=0))
        # stacked leaves decompress along the same axis, batched
        xs, ws, vs, is_, ffs, bps = _pregen_arrays(24, stack=(3,))
        _eq(decompress_nm(vs, is_, 2, 8, axis=-2),
            nm_unpack_n(vs, is_, 2, 8, axis=-2))


def _to_dict_era(compute):
    """Convert PregenOp compute leaves back to the PR-3/4 dict layout."""
    def walk(node):
        if isinstance(node, O.PregenOp):
            return {f: node[f] for f in node.fields}
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(compute)


class TestCheckpointForwardCompat:
    """PR-3/PR-4-era checkpoints stored the compute tree as operand
    DICTS; they must restore bitwise into SparseOperand-typed state."""

    @pytest.mark.parametrize("pack", [False, True])
    def test_dict_leaf_checkpoint_restores_into_operands(self, tmp_path,
                                                         pack):
        state = ST.init_train_state(jax.random.PRNGKey(7), CFG, sp_cfg=BDWP,
                                    pregen_pack=pack)
        old_state = dict(state, compute=_to_dict_era(state["compute"]))
        assert (jax.tree_util.tree_structure(old_state["compute"])
                != jax.tree_util.tree_structure(state["compute"]))
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(0, old_state, blocking=True)

        like = ST.init_train_state(jax.random.PRNGKey(0), CFG, sp_cfg=BDWP,
                                   pregen_pack=pack)
        restored = ST.restore_with_pregen(mgr, like, sp_cfg=BDWP,
                                          pregen_pack=pack)
        _tree_eq(restored, state)
        # ...and the restored compute leaves really are operands
        sites = [leaf for leaf in jax.tree.leaves(
            restored["compute"],
            is_leaf=lambda x: isinstance(x, O.PregenOp))
            if isinstance(leaf, O.PregenOp)]
        assert sites and all(s.is_packed == pack for s in sites)
        # the restored state steps
        mesh = _solo_mesh()
        bundle = ST.build_lm_train(CFG, mesh, BDWP, OPT, donate=False,
                                   pregen_pack=pack)
        restored = jax.device_put(restored, bundle.state_shardings)
        batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
                 "labels": jnp.zeros((2, 32), jnp.int32)}
        _, metrics = bundle.step_fn(restored, batch)
        assert np.isfinite(float(metrics["loss"]))


@mesh8_only
class TestOperandSPMD:
    """The unified API on a forced 8-device mesh: packed consumption
    under GSPMD, and dict-era checkpoint restore across mesh shapes."""

    @pytest.fixture(scope="class")
    def mesh8(self):
        from repro.launch import spmd
        return spmd.make_spmd_mesh("pod,data,model")

    def _run(self, mesh, backend, pack=True, steps=2):
        from jax.sharding import NamedSharding

        bundle = ST.build_lm_train(CFG, mesh, BDWP, OPT, donate=False,
                                   pregen_pack=pack, nm_backend=backend)
        state = ST.init_train_state(jax.random.PRNGKey(0), CFG, sp_cfg=BDWP,
                                    pregen_pack=pack)
        state = jax.device_put(state, bundle.state_shardings)
        sh = {k: NamedSharding(mesh, ps)
              for k, ps in bundle.input_pspecs.items()}
        stream = D.lm_stream(CFG.vocab, 4, 32, shardings=sh, seed=0)
        losses = []
        for i, (_, b) in enumerate(stream):
            if i >= steps:
                break
            state, metrics = bundle.step_fn(state, b)
            losses.append(float(metrics["loss"]))
        return state, losses

    def test_sharded_packed_train_jnp_bitwise_vs_unpacked(self, mesh8):
        """On one mesh the packed and unpacked pregen states must stay
        bitwise equal (pack/decompress is exact under SPMD too)."""
        s_p, l_p = self._run(mesh8, "jnp", pack=True)
        s_u, l_u = self._run(mesh8, "jnp", pack=False)
        assert l_p == l_u
        for a, b in zip(jax.tree.leaves(s_p["master"]),
                        jax.tree.leaves(s_u["master"])):
            _eq(a, b)

    def test_sharded_packed_train_pallas_backend_runs_and_tracks(self, mesh8):
        """The kernel-consuming forward partitions under GSPMD (the
        kernel's fp32 K-block accumulation may legally re-order vs the
        fused dot, so cross-backend equality is tolerance, not bitwise,
        on a sharded mesh)."""
        _, l_p = self._run(mesh8, "pallas")
        _, l_j = self._run(mesh8, "jnp")
        np.testing.assert_allclose(l_p, l_j, rtol=2e-3)

    def test_dict_era_checkpoint_restores_across_meshes(self, tmp_path,
                                                        mesh8):
        """A dict-leaf (PR-3/4) checkpoint saved unsharded restores onto
        the 8-device mesh — elastic resharding straight into operand-
        typed state, bitwise."""
        state = ST.init_train_state(jax.random.PRNGKey(9), CFG, sp_cfg=BDWP,
                                    pregen_pack=True)
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(0, dict(state, compute=_to_dict_era(state["compute"])),
                 blocking=True)
        bundle = ST.build_lm_train(CFG, mesh8, BDWP, OPT, donate=False,
                                   pregen_pack=True)
        like = ST.init_train_state(jax.random.PRNGKey(0), CFG, sp_cfg=BDWP,
                                   pregen_pack=True)
        restored = ST.restore_with_pregen(
            mgr, like, shardings=bundle.state_shardings, sp_cfg=BDWP,
            pregen_pack=True)
        _tree_eq(restored, state)
        batch = {"tokens": jnp.zeros((4, 32), jnp.int32),
                 "labels": jnp.zeros((4, 32), jnp.int32)}
        _, metrics = bundle.step_fn(restored, batch)
        assert np.isfinite(float(metrics["loss"]))
