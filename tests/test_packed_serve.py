"""Shared-mode packed serving tests: pack_tree_shared / packed_shared_apply
(beyond-paper reduced-K serving) + SSD bf16 numerics guard."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bdwp
from repro.core.sparsity import SparsityConfig, nm_mask_shared

jax.config.update("jax_platform_name", "cpu")

SP = SparsityConfig(n=2, m=8, method="bdwp", granularity="shared")


class TestSharedPack:
    def test_pack_selects_shared_top_rows(self):
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (32, 16))
        vals, idx = bdwp.shared_ff_pack(w, SP)
        assert vals.shape == (8, 16) and idx.shape == (8,)
        # selected rows are exactly the shared-mask survivors
        mask = nm_mask_shared(w, 2, 8, axis=0, share_axis=1, tile=16)
        surviving = jnp.nonzero(mask[:, 0])[0]
        np.testing.assert_array_equal(np.sort(np.asarray(idx)),
                                      np.asarray(surviving))

    def test_apply_equals_masked_dense(self):
        key = jax.random.PRNGKey(1)
        w = jax.random.normal(key, (64, 32))
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 64), jnp.bfloat16)
        vals, idx = bdwp.shared_ff_pack(w, SP)
        y_packed = bdwp.packed_shared_apply({"vals": vals, "idx": idx}, x)
        mask = nm_mask_shared(w, 2, 8, axis=0, share_axis=1, tile=32)
        y_dense = jnp.matmul(x, jnp.where(mask, w, 0).astype(x.dtype))
        np.testing.assert_allclose(np.asarray(y_packed, np.float32),
                                   np.asarray(y_dense, np.float32),
                                   rtol=2e-2, atol=1e-2)

    def test_flop_and_byte_reduction(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (128, 64))
        vals, idx = bdwp.shared_ff_pack(w, SP)
        assert vals.size == w.size * 2 // 8
        assert idx.size == 128 * 2 // 8


class TestPackTree:
    def _params(self):
        k = jax.random.PRNGKey(0)
        return {
            "embed": {"embed_table": jax.random.normal(k, (256, 32))},
            "blocks": {"attn": {"q_proj": {"w": jax.random.normal(k, (3, 32, 64))}},
                       "mlp": {"w_in": {"w": jax.random.normal(k, (3, 32, 64)),
                                        "b": jnp.zeros((3, 64))}}},
            "lm_head": {"w": jax.random.normal(k, (32, 256))},
        }

    def test_packs_eligible_only(self):
        from repro.core import operand as O

        packed = bdwp.pack_tree_shared(self._params(), SP)
        assert "embed_table" in packed["embed"]          # excluded by name
        assert not isinstance(packed["lm_head"]["w"],    # excluded (head)
                              O.SparseOperand)
        q = packed["blocks"]["attn"]["q_proj"]["w"]
        assert isinstance(q, O.SharedOp)
        assert q["vals"].shape == (3, 8, 64)             # K 32 -> 8 per layer
        assert q["idx"].shape == (3, 8)
        m = packed["blocks"]["mlp"]["w_in"]
        assert "b" in m                                  # bias carried over

    def test_abstract_tree(self):
        params = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self._params())
        packed = bdwp.pack_tree_shared(params, SP)
        q = packed["blocks"]["attn"]["q_proj"]["w"]
        assert isinstance(q["vals"], jax.ShapeDtypeStruct)
        assert q["vals"].shape == (3, 8, 64)

    def test_pspec_transform(self):
        from jax.sharding import PartitionSpec as P
        params = self._params()
        pspecs = {
            "embed": {"embed_table": P("model", None)},
            "blocks": {"attn": {"q_proj": {"w": P(None, None, "model")}},
                       "mlp": {"w_in": {"w": P(None, None, "model"),
                                        "b": P(None, "model")}}},
            "lm_head": {"w": P(None, "model")},
        }
        _, ps = bdwp.pack_tree_shared(params, SP, pspecs=pspecs)
        q = ps["blocks"]["attn"]["q_proj"]["w"]
        assert q["vals"] == P(None, None, "model")
        assert q["idx"] == P(None, None)


class TestSSDNumerics:
    def test_bf16_intra_chunk_matches_f32_reference(self):
        """The bf16 cast of the SSD attention-like factors must stay
        close to a pure-f32 recurrence (sequential scan oracle)."""
        from repro.models.ssm import _ssd_chunked

        key = jax.random.PRNGKey(0)
        b, s, h, p, n = 2, 64, 4, 8, 16
        x = jax.random.normal(key, (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, s, h)))
        A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)) * 0.3)
        B = jax.random.normal(jax.random.PRNGKey(3), (b, s, n))
        C = jax.random.normal(jax.random.PRNGKey(4), (b, s, n))
        D = jnp.zeros((h,))
        y, h_last = _ssd_chunked(x, dt, A, B, C, D, chunk=16)

        # sequential oracle
        def step(hprev, t):
            da = jnp.exp(dt[:, t] * A[None])  # (B,H)
            upd = jnp.einsum("bh,bn,bhp->bhnp", dt[:, t], B[:, t], x[:, t])
            hnew = hprev * da[..., None, None] + upd
            yt = jnp.einsum("bn,bhnp->bhp", C[:, t], hnew)
            return hnew, yt

        h0 = jnp.zeros((b, h, n, p))
        hT, ys = jax.lax.scan(step, h0, jnp.arange(s))
        y_ref = ys.transpose(1, 0, 2, 3)
        # bf16 factors: absolute error bounded by ~0.5% of output scale
        scale = float(np.abs(np.asarray(y_ref)).max())
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=0.006 * scale)
        np.testing.assert_allclose(np.asarray(h_last), np.asarray(hT),
                                   atol=0.006 * scale)


class TestEndToEndPackedDecode:
    def test_packed_decode_close_to_dense(self):
        """Packed-serving logits track the dense-weight logits on the
        smoke config (shared-mask sparsity changes values, but ranking
        of a trained-sparse model is preserved; here we check the packed
        path equals the shared-masked dense forward exactly)."""
        from repro.configs import get_arch
        from repro.core.sparsity import sparsify
        from repro.train import step as ST

        arch = get_arch("qwen3-8b")
        cfg = arch.smoke
        sp = SparsityConfig(n=2, m=8, method="bdwp", granularity="shared")
        key = jax.random.PRNGKey(0)
        from repro.models import transformer_lm as T
        params, _ = T.init(key, cfg)
        params = jax.tree.map(lambda w: w.astype(jnp.bfloat16), params)
        packed = bdwp.pack_tree_shared(params, sp)

        # masked-dense equivalent: shared-mode sparsify each packed weight
        def mask_like(path, node):
            return node
        def walk(node, path=()):
            if isinstance(node, dict) and "w" in node:
                name = "/".join(str(p) for p in path)
                if bdwp.serve_packable(name, tuple(node["w"].shape[-2:]), sp):
                    ax = node["w"].ndim - 2
                    return dict(node, w=sparsify(node["w"], sp, axis=ax,
                                                 share_axis=node["w"].ndim - 1))
                return node
            if isinstance(node, dict):
                return {k: walk(v, path + (k,)) for k, v in node.items()}
            return node
        masked = walk(params)

        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                    cfg.vocab)
        lp, _ = ST.lm_prefill_step(packed, {"tokens": tokens}, cfg=cfg,
                                   sp_cfg=sp)
        lm, _ = ST.lm_prefill_step(masked, {"tokens": tokens}, cfg=cfg,
                                   sp_cfg=SparsityConfig(method="dense"))
        np.testing.assert_allclose(
            np.asarray(lp[..., :cfg.vocab], np.float32),
            np.asarray(lm[..., :cfg.vocab], np.float32), rtol=0.05,
            atol=0.25)

    def test_packed_params_smaller(self):
        from repro.configs import get_arch
        from repro.models import transformer_lm as T

        arch = get_arch("qwen3-8b")
        params, _ = T.init(jax.random.PRNGKey(0), arch.smoke)
        params = jax.tree.map(lambda w: w.astype(jnp.bfloat16), params)
        sp = SparsityConfig(n=2, m=8, method="bdwp")
        packed = bdwp.pack_tree_shared(params, sp)
        size = lambda t: sum(x.size * x.dtype.itemsize
                             for x in jax.tree.leaves(t))
        assert size(packed) < size(params)
