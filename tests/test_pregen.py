"""Pre-generation dataflow tests (paper Fig. 11c executed for real).

What must hold:
  * mask-once invariant: the traced bdwp train step derives each
    prunable param's N:M masks exactly once (at WU time) — one
    top_k/sort per prunable leaf in the whole step, none in the model;
  * A/B parity: the pregen step tracks the legacy step across all five
    methods, and is BITWISE equal to it whenever the fp32-master masks
    agree with the legacy bf16-scored masks (same masks => same losses);
  * packed (vals, idx) pregen state is bitwise-equal to the unpacked
    form and round-trips through nm_unpack_n;
  * the fused Pallas WU kernel path (interpret mode) is bitwise-equal
    to the jnp path;
  * pre-pregen checkpoints (no "compute" leaf) restore and upgrade;
  * conv FF masks and SR-STE decay both score on fp32 master — a
    bf16-rounding near-tie can no longer make them disagree.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import bdwp
from repro.core import sparsity as S
from repro.core.sparsity import SparsityConfig, nm_mask, nm_pack
from repro.data import synthetic as D
from repro.launch.hlo_cost import count_mask_ops
from repro.launch.mesh import make_host_mesh
from repro.models import transformer_lm as T
from repro.optim import sgd
from repro.train import step as ST
from repro.train.checkpoint import CheckpointManager

jax.config.update("jax_platform_name", "cpu")

ARCH = get_arch("qwen3-8b")
CFG = ARCH.smoke
OPT = sgd.SGDConfig(lr=0.05, total_steps=16)
BDWP = SparsityConfig(n=2, m=8, method="bdwp")

# MoE A/B rig: shared experts, a capacity tight enough to really drop
# tokens, and n_experts != m so the router's top_k over the expert dim
# stays shape-distinguishable from N:M mask selections in the census.
from repro.models import moe as M  # noqa: E402
from repro.models.transformer_lm import LMConfig  # noqa: E402

SP4 = SparsityConfig(n=2, m=4, method="bdwp")
MOE_CFG = LMConfig(
    name="moe-pregen-smoke", vocab=256, d_model=32, n_layers=2,
    n_heads=2, n_kv=1, head_dim=16, d_ff=0,
    moe=M.MoEConfig(n_experts=8, top_k=2, d_expert=16, n_shared=1,
                    capacity_factor=0.6, group_size=16),
    tie_embed=True)
MOE_OPT = sgd.SGDConfig(lr=5e-4, warmup_steps=0, total_steps=100,
                        min_lr_frac=1.0)


def _mask_stable_like(w, key, m):
    """Weights whose N:M masks agree between fp32 and bf16 scoring and
    survive small updates: |values| spaced >=5% apart within every
    M-group along BOTH of the last two axes (group offsets (i%m, j%m)
    map to distinct exponents), bounded magnitude, random signs, and a
    +-0.4% jitter to decorrelate experts/layers."""
    shape = w.shape
    i = jax.lax.broadcasted_iota(jnp.int32, shape, w.ndim - 2)
    j = jax.lax.broadcasted_iota(jnp.int32, shape, w.ndim - 1)
    k = (i % m) + m * (j % m)
    k1, k2 = jax.random.split(key)
    sign = jnp.where(jax.random.bernoulli(k1, shape=shape), 1.0, -1.0)
    jit = 1.0 + 0.004 * jax.random.uniform(k2, shape, minval=-1.0, maxval=1.0)
    return (1.06 ** k.astype(jnp.float32)) * sign * jit * shape[-2] ** -0.5


def _stabilize_masks(master, sp):
    """Replace every pregen-site master leaf with mask-stable values."""
    names = sgd._names_of(master)
    flat, tdef = jax.tree_util.tree_flatten(master)
    out = [
        _mask_stable_like(w, jax.random.PRNGKey(1000 + i), sp.m)
        if bdwp.pregen_site(n, sgd._logical_shape(n, w.shape)[0], sp) else w
        for i, (n, w) in enumerate(zip(names, flat))]
    return jax.tree_util.tree_unflatten(tdef, out)


def _assert_masks_still_stable(master, sp):
    for n, w in zip(sgd._names_of(master), jax.tree.leaves(master)):
        if not bdwp.pregen_site(n, sgd._logical_shape(n, w.shape)[0], sp):
            continue
        for ax in (w.ndim - 2, w.ndim - 1):
            np.testing.assert_array_equal(
                np.asarray(nm_mask(w, sp.n, sp.m, axis=ax)),
                np.asarray(nm_mask(w.astype(jnp.bfloat16), sp.n, sp.m,
                                   axis=ax)),
                err_msg=f"bf16/fp32 masks drifted apart on {n} axis {ax}")


def _structs(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _run(sp_cfg, *, pregen, steps=3, pack=False, use_pallas=False, seed=0,
         cfg=CFG, opt=OPT, stabilize=False):
    mesh = make_host_mesh()
    bundle = ST.build_lm_train(cfg, mesh, sp_cfg, opt, donate=False,
                               pregen=pregen, pregen_pack=pack,
                               use_pallas=use_pallas)
    state = ST.init_train_state(jax.random.PRNGKey(seed), cfg, sp_cfg=sp_cfg,
                                pregen=pregen, pregen_pack=pack)
    if stabilize:
        state["master"] = _stabilize_masks(state["master"], sp_cfg)
        if pregen:
            state["compute"] = sgd.pregen_tree(state["master"], sp_cfg,
                                               pack=pack)
    state = jax.device_put(state, bundle.state_shardings)
    stream = D.lm_stream(cfg.vocab, 2, 32, seed=seed)
    losses = []
    for i, (_, batch) in enumerate(stream):
        if i >= steps:
            break
        state, metrics = bundle.step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    return state, losses


class TestMaskOnce:
    def test_one_topk_per_prunable_param(self):
        """THE invariant: the lowered bdwp train step contains exactly
        one top_k/sort mask derivation per prunable parameter (the fused
        FF+BP selection at WU time), down from 3+ per param when FF, BP
        and SR-STE decay each re-derived it (4x with remat recompute)."""
        mesh = make_host_mesh()
        bundle = ST.build_lm_train(CFG, mesh, BDWP, OPT, donate=False)
        state = ST.init_train_state(jax.random.PRNGKey(0), CFG, sp_cfg=BDWP)
        batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
                 "labels": jnp.zeros((2, 32), jnp.int32)}
        n_sites = sum(
            bdwp.pregen_site(n, sgd._logical_shape(n, w.shape)[0], BDWP)
            for n, w in zip(sgd._names_of(state["master"]),
                            jax.tree.leaves(state["master"])))
        assert n_sites > 0
        count = count_mask_ops(bundle.step_fn, _structs(state),
                               _structs(batch))
        assert count == n_sites, \
            f"{count} top_k/sort ops for {n_sites} prunable params"

    def test_legacy_step_rederives(self):
        """Sanity of the census itself: the legacy dataflow really does
        pay multiple selections per param (FF + remat'd FF + BP + decay)."""
        mesh = make_host_mesh()
        bundle = ST.build_lm_train(CFG, mesh, BDWP, OPT, donate=False,
                                   pregen=False)
        state = ST.init_train_state(jax.random.PRNGKey(0), CFG, sp_cfg=BDWP,
                                    pregen=False)
        batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
                 "labels": jnp.zeros((2, 32), jnp.int32)}
        count = count_mask_ops(bundle.step_fn, _structs(state),
                               _structs(batch))
        assert count >= 3 * 7  # 7 prunable leaves in the smoke config

    def test_fused_pair_equals_two_masks(self):
        w = jax.random.normal(jax.random.PRNGKey(3), (4, 32, 16))
        ff, bp = S.nm_mask_pair(w, 2, 8, 1, 2)
        np.testing.assert_array_equal(np.asarray(ff),
                                      np.asarray(nm_mask(w, 2, 8, axis=1)))
        np.testing.assert_array_equal(np.asarray(bp),
                                      np.asarray(nm_mask(w, 2, 8, axis=2)))

    def test_pack_from_mask_equals_nm_pack(self):
        for seed in range(5):
            x = jax.random.normal(jax.random.PRNGKey(seed), (8, 64))
            mask = nm_mask(x, 2, 8, axis=0)
            v, i = S.nm_pack_from_mask(x, mask, 2, 8, axis=0)
            rv, ri = nm_pack(x, 2, 8, axis=0)
            np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
            np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))


class TestPregenParity:
    @pytest.mark.parametrize("method",
                             ["dense", "srste", "sdgp", "sdwp", "bdwp"])
    def test_tracks_legacy_trajectory(self, method):
        """Pregen vs legacy differ ONLY through the mask-source fix
        (fp32-master vs bf16 scoring flips ~0.1% of near-tie bits), so
        short trajectories must track closely for every method."""
        sp = SparsityConfig(n=2, m=8, method=method)
        _, l_pre = _run(sp, pregen=True)
        _, l_leg = _run(sp, pregen=False)
        np.testing.assert_allclose(l_pre, l_leg, atol=5e-2)

    def test_packed_state_bitwise_equals_unpacked(self):
        s_a, l_a = _run(BDWP, pregen=True, pack=False)
        s_b, l_b = _run(BDWP, pregen=True, pack=True)
        assert l_a == l_b
        for a, b in zip(jax.tree.leaves(s_a["master"]),
                        jax.tree.leaves(s_b["master"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("method,pack", [("srste", False),
                                             ("bdwp", False),
                                             ("bdwp", True)])
    def test_pallas_fused_update_bitwise_equals_jnp(self, method, pack):
        """The fused WUVE+SORE kernel (interpret mode on CPU) wired into
        the train step must match the jnp formulation bitwise: same
        masks, same losses, same master — including the kernel-packed
        state (pack=True stores the kernel's (vals, idx) directly)."""
        sp = SparsityConfig(n=2, m=8, method=method)
        s_j, l_j = _run(sp, pregen=True, steps=2, pack=pack)
        s_p, l_p = _run(sp, pregen=True, steps=2, pack=pack,
                        use_pallas=True)
        assert l_j == l_p
        flat_j = jax.tree_util.tree_flatten_with_path(s_j)[0]
        flat_p = jax.tree.leaves(s_p)
        assert len(flat_j) == len(flat_p)
        for (path, a), b in zip(flat_j, flat_p):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg="/".join(str(getattr(k, "key", k)) for k in path))

    def test_exact_parity_when_masks_stable(self):
        """Same masks => bitwise-equal losses.  With magnitudes spaced
        far beyond bf16 resolution the fp32 and bf16 scorings select the
        same survivors, and the pregen step must reproduce the legacy
        trajectory EXACTLY (fp32-master path)."""
        k, f = 16, 16  # both axes prunable (>= 2*m per group axis)
        # geometrically spaced magnitudes: every |w| gap is ~2%, five
        # bf16 resolution steps — small updates can't create new ties
        vals = 1.02 ** jnp.arange(k * f, dtype=jnp.float32) * 0.05
        vals = vals * jnp.where(jnp.arange(k * f) % 3 == 0, -1.0, 1.0)
        w0 = jax.random.permutation(jax.random.PRNGKey(0), vals).reshape(k, f)
        assert bdwp.pregen_site("proj/w", (k, f),
                                SparsityConfig(n=2, m=8, method="bdwp"))
        sp = SparsityConfig(n=2, m=8, method="bdwp", lam=1e-3)
        opt = sgd.SGDConfig(lr=1e-3, warmup_steps=0, total_steps=100,
                            weight_decay=1e-4, min_lr_frac=1.0)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, k), jnp.bfloat16)
        y = jax.random.normal(jax.random.PRNGKey(2), (4, f), jnp.bfloat16)
        names = ["proj/w"]

        def legacy_step(state):
            def loss_fn(master):
                compute = jax.tree.map(
                    lambda v: v.astype(jnp.bfloat16), master)
                out = bdwp.nm_linear(x, compute["proj"]["w"], sp)
                return jnp.mean((out - y) ** 2)

            loss, grads = jax.value_and_grad(loss_fn)(state["master"])
            new_state, _ = sgd.update(state, grads, opt, sp,
                                      param_names=names)
            return new_state, loss

        def pregen_step(state):
            diff, meta = ST.split_compute(state["compute"])

            def loss_fn(d):
                compute = ST.merge_compute(d, meta)
                pg = compute["proj"]["w"]
                out = bdwp.nm_linear_pregen(
                    x, bdwp.pregen_ff_operand(pg, sp), pg["bp"])
                return jnp.mean((out - y) ** 2)

            loss, gdiff = jax.value_and_grad(loss_fn)(diff)
            grads = sgd.pregen_grads(ST.merge_compute(gdiff, meta))
            core = {k: state[k] for k in ("master", "momentum", "step")}
            new_state, compute = sgd.update(
                core, grads, opt, sp, param_names=names,
                prev_compute=state["compute"], pregen=True, pack=True)
            return dict(new_state, compute=compute), loss

        master = {"proj": {"w": w0}}
        s_leg = sgd.init_state(master)
        s_pre = dict(sgd.init_state(master),
                     compute=sgd.pregen_tree(master, sp, pack=True))
        for step in range(4):
            # precondition: legacy's bf16-scored masks == fp32 masks
            w = s_leg["master"]["proj"]["w"]
            for ax in (0, 1):
                np.testing.assert_array_equal(
                    np.asarray(nm_mask(w, 2, 8, axis=ax)),
                    np.asarray(nm_mask(w.astype(jnp.bfloat16), 2, 8,
                                       axis=ax)))
            s_leg, l_leg = legacy_step(s_leg)
            s_pre, l_pre = pregen_step(s_pre)
            np.testing.assert_array_equal(np.asarray(l_leg),
                                          np.asarray(l_pre))
            np.testing.assert_array_equal(
                np.asarray(s_leg["master"]["proj"]["w"]),
                np.asarray(s_pre["master"]["proj"]["w"]))

    def test_packed_leaf_roundtrips(self):
        state = ST.init_train_state(jax.random.PRNGKey(0), CFG, sp_cfg=BDWP,
                                    pregen_pack=True)
        pg = state["compute"]["blocks"]["ffn"]["w_gate"]["w"]
        assert "vals" in pg and pg["idx"].dtype == jnp.uint8
        master = state["master"]["blocks"]["ffn"]["w_gate"]["w"]
        ff_dense = bdwp.pregen_ff_operand(pg, BDWP)
        expect = jnp.where(nm_mask(master, 2, 8, axis=master.ndim - 2),
                           master, 0.0).astype(jnp.bfloat16)
        np.testing.assert_array_equal(np.asarray(ff_dense),
                                      np.asarray(expect))
        # packed axis really is N/M of the contraction axis
        assert pg["vals"].shape[-2] == master.shape[-2] * 2 // 8

    @pytest.mark.parametrize("method", ["srste", "sdwp", "bdwp"])
    def test_update_decay_uses_stored_mask(self, method):
        """sgd.update(pregen=True) must decay exactly the weights the
        stored (previous-WU) mask pruned — no re-derivation drift."""
        sp = SparsityConfig(n=1, m=4, method=method, lam=0.1)
        w = jax.random.normal(jax.random.PRNGKey(0), (8, 8))
        master = {"proj": {"w": w}}
        state = sgd.init_state(master)
        compute = sgd.pregen_tree(master, sp)
        zero_g = jax.tree.map(jnp.zeros_like, master)
        opt = sgd.SGDConfig(lr=0.1, momentum=0.9, weight_decay=0.0,
                            warmup_steps=0, total_steps=10 ** 9,
                            min_lr_frac=1.0)
        new_state, _ = sgd.update(state, zero_g, opt, sp,
                                  param_names=["proj/w"],
                                  prev_compute=compute, pregen=True)
        moved = np.asarray(new_state["master"]["proj"]["w"] != w)
        stored = np.asarray(compute["proj"]["w"]["mask"])
        np.testing.assert_array_equal(moved, ~stored)


class TestCheckpointCompat:
    def test_pre_pregen_checkpoint_upgrades(self, tmp_path):
        """A checkpoint written before the pregen dataflow (no "compute"
        leaf) restores via restore_with_pregen: the legacy subtree loads
        and the operands regenerate from the restored master, exactly."""
        mesh = make_host_mesh()
        bundle = ST.build_lm_train(CFG, mesh, BDWP, OPT, donate=False)
        legacy = ST.init_train_state(jax.random.PRNGKey(5), CFG,
                                     sp_cfg=BDWP, pregen=False)
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(0, legacy, blocking=True)

        like = ST.init_train_state(jax.random.PRNGKey(0), CFG, sp_cfg=BDWP)
        restored = ST.restore_with_pregen(
            mgr, like, shardings=bundle.state_shardings, sp_cfg=BDWP)
        assert "compute" in restored
        for a, b in zip(jax.tree.leaves(restored["master"]),
                        jax.tree.leaves(legacy["master"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        expect = sgd.pregen_tree(legacy["master"], BDWP)
        for a, b in zip(jax.tree.leaves(restored["compute"]),
                        jax.tree.leaves(expect)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # and the upgraded state steps
        stream = D.lm_stream(CFG.vocab, 2, 32)
        _, batch = next(iter(stream))
        new_state, metrics = bundle.step_fn(restored, batch)
        assert np.isfinite(float(metrics["loss"]))

    def test_full_state_roundtrip_with_compute(self, tmp_path):
        """bf16/uint8/bool compute leaves survive the npy round-trip."""
        state = ST.init_train_state(jax.random.PRNGKey(1), CFG, sp_cfg=BDWP,
                                    pregen_pack=True)
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(2, state, blocking=True)
        out = mgr.restore(jax.tree.map(jnp.zeros_like, state))
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(state)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestMaskSourceConsistency:
    """Satellite bugfix: FF masks and SR-STE decay masks must both score
    on fp32 master.  A near-tie group — two weights closer than bf16
    resolution — is the regression trigger: bf16 scoring rounds them
    equal and keeps the EARLIER index, fp32 keeps the truly larger one."""

    def _near_tie_group(self):
        eps = 2e-4  # far below bf16's ~0.4% relative resolution at 1.0
        g = np.full(8, 1e-4, np.float32)
        g[0], g[1] = 1.0, 1.0 + eps  # fp32 keeps idx 1; bf16 ties -> idx 0
        return jnp.asarray(g)

    def test_near_tie_premise(self):
        g = self._near_tie_group()
        m32 = nm_mask(g, 1, 8, axis=0)
        m16 = nm_mask(g.astype(jnp.bfloat16), 1, 8, axis=0)
        assert bool(m32[1]) and not bool(m32[0])
        assert bool(m16[0]) and not bool(m16[1])  # the legacy disagreement

    def test_conv_ff_mask_scores_on_given_weights(self):
        """nm_conv masks the weights it is GIVEN and casts after masking:
        passing fp32 master (as examples/paper_loss_curves.py now does)
        yields the fp32-mask selection even with bf16 activations."""
        sp = SparsityConfig(n=1, m=8, method="bdwp")
        w = jnp.zeros((1, 1, 8, 8), jnp.float32)
        w = w.at[0, 0, :, 0].set(self._near_tie_group())
        x = jnp.ones((1, 4, 4, 8), jnp.bfloat16)
        y = bdwp.nm_conv(x, w, sp)
        # output channel 0 == conv with only the fp32-kept tap (idx 1)
        w_ref = jnp.zeros_like(w).at[0, 0, 1, 0].set(w[0, 0, 1, 0])
        y_ref = jax.lax.conv_general_dilated(
            x, w_ref.astype(x.dtype), (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        np.testing.assert_array_equal(np.asarray(y[..., 0]),
                                      np.asarray(y_ref[..., 0]))

    def test_pregen_ff_and_decay_share_fp32_mask(self):
        """In the pregen state the FF operand's survivor set IS the
        stored decay mask, both scored on fp32 master — the near-tie
        group can no longer make FF and decay disagree."""
        sp = SparsityConfig(n=1, m=8, method="srste", lam=0.1)
        w = jnp.tile(self._near_tie_group()[:, None], (2, 8))  # (16, 8)
        master = {"proj": {"w": w}}
        compute = sgd.pregen_tree(master, sp)
        pg = compute["proj"]["w"]
        ff_alive = np.asarray(pg["ff"] != 0)
        np.testing.assert_array_equal(ff_alive, np.asarray(pg["mask"]))
        np.testing.assert_array_equal(
            np.asarray(pg["mask"]), np.asarray(nm_mask(w, 1, 8, axis=0)))

    def test_decay_excludes_directly_consumed_weights(self):
        """lm_head never routes through nm_linear, so SR-STE must not
        decay it (it used to — decaying never-pruned weights)."""
        assert not bdwp.decays("lm_head/w", (64, 512), BDWP)
        assert bdwp.decays("blocks/attn/q_proj/w", (64, 64), BDWP)
        assert not bdwp.pregen_site("lm_head/w", (64, 512), BDWP)


class TestMoEPregen:
    """Pre-generation for bare-array MoE expert stacks (ISSUE 4): the
    one-top_k-per-param invariant now holds for every architecture —
    expert stacks (E, K, F) get per-expert masks from one fused
    selection at WU time, the shared-expert path rides the same
    dispatch, and the router (excluded) never becomes a site."""

    def test_bare_leaf_protocol(self):
        # expert stacks and shared-expert mats are sites...
        assert bdwp.pregen_site("blocks/moe/w_gate", (8, 32, 16), SP4)
        assert bdwp.pregen_site("blocks/moe/w_down", (8, 16, 32), SP4)
        assert bdwp.pregen_site("blocks/moe/shared/w_up", (32, 16), SP4)
        # ...the router and other bare arrays are not
        assert not bdwp.pregen_site("blocks/moe/router/w", (32, 8), SP4)
        assert not bdwp.pregen_site("blocks/ssm/conv_w", (4, 64), SP4)
        assert not bdwp.pregen_site("lm_head/w", (64, 512), SP4)
        # SR-STE never decays the router either (it is never pruned)
        assert not bdwp.decays("blocks/moe/router/w", (32, 8), SP4)
        assert bdwp.decays("blocks/moe/w_gate", (8, 32, 16), SP4)
        # dict-site FFN leaves of the same basenames still take "/w"
        assert bdwp.pregen_site("blocks/ffn/w_gate/w", (32, 64), SP4)

    def test_moe_one_topk_per_prunable_param(self):
        """THE invariant, MoE edition: the lowered train step derives
        each prunable param's masks exactly once — stacked expert leaves
        count as ONE derivation for the whole (E, K, F) stack.  The
        census is N:M-shape-filtered so the router's top_k over the
        expert dim (E=8 != m=4 here) is not miscounted as a mask op."""
        mesh = make_host_mesh()
        bundle = ST.build_lm_train(MOE_CFG, mesh, SP4, OPT, donate=False)
        state = ST.init_train_state(jax.random.PRNGKey(0), MOE_CFG,
                                    sp_cfg=SP4)
        batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
                 "labels": jnp.zeros((2, 32), jnp.int32)}
        names = sgd._names_of(state["master"])
        sites = [n for n, w in zip(names, jax.tree.leaves(state["master"]))
                 if bdwp.pregen_site(n, sgd._logical_shape(n, w.shape)[0],
                                     SP4)]
        assert any("moe/w_" in n for n in sites)
        assert any("moe/shared/" in n for n in sites)
        count = count_mask_ops(bundle.step_fn, _structs(state),
                               _structs(batch), nm=(SP4.n, SP4.m))
        assert count == len(sites), \
            f"{count} N:M selections for {len(sites)} prunable params"

    def test_moe_legacy_step_rederives(self):
        """Census sanity: the legacy MoE dataflow pays one selection per
        consumer (FF + remat recompute + BP + decay) per param."""
        mesh = make_host_mesh()
        bundle = ST.build_lm_train(MOE_CFG, mesh, SP4, OPT, donate=False,
                                   pregen=False)
        state = ST.init_train_state(jax.random.PRNGKey(0), MOE_CFG,
                                    sp_cfg=SP4, pregen=False)
        batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
                 "labels": jnp.zeros((2, 32), jnp.int32)}
        count = count_mask_ops(bundle.step_fn, _structs(state),
                               _structs(batch), nm=(SP4.n, SP4.m))
        assert count >= 3 * 10  # 10 prunable leaves in MOE_CFG

    def test_moe_train_bitwise_legacy_vs_pregen(self):
        """Satellite A/B parity: with mask-stable weights (fp32 and bf16
        scoring select the same survivors) the pregen MoE trajectory —
        routing, capacity drops, shared experts, aux loss and all — must
        reproduce the legacy one BITWISE: losses and every master leaf."""
        s_pre, l_pre = _run(SP4, pregen=True, cfg=MOE_CFG, opt=MOE_OPT,
                            stabilize=True)
        s_leg, l_leg = _run(SP4, pregen=False, cfg=MOE_CFG, opt=MOE_OPT,
                            stabilize=True)
        assert l_pre == l_leg
        for (path, a), b in zip(
                jax.tree_util.tree_flatten_with_path(s_pre["master"])[0],
                jax.tree.leaves(s_leg["master"])):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg="/".join(str(getattr(k, "key", k)) for k in path))
        # the precondition held to the end (else the test proves nothing)
        _assert_masks_still_stable(s_pre["master"], SP4)

    def test_moe_train_bitwise_packed_vs_unpacked(self):
        """Packed (vals, idx) MoE pregen state is bitwise-equal to the
        unpacked form: pack->unpack is exact, so the whole trajectory
        matches with no mask-stability precondition needed."""
        s_a, l_a = _run(SP4, pregen=True, pack=False, cfg=MOE_CFG)
        s_b, l_b = _run(SP4, pregen=True, pack=True, cfg=MOE_CFG)
        assert l_a == l_b
        for a, b in zip(jax.tree.leaves(s_a["master"]),
                        jax.tree.leaves(s_b["master"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # the stacked expert FF operand really is stored packed
        pg = s_b["compute"]["blocks"]["moe"]["w_gate"]
        assert "vals" in pg and pg["idx"].dtype == jnp.uint8
        assert pg["vals"].shape[-2] == \
            s_b["master"]["blocks"]["moe"]["w_gate"].shape[-2] * SP4.n // SP4.m

    def test_moe_grads_bitwise_with_shared_experts_and_drops(self):
        """Per-leaf gradient parity through moe_apply itself, with the
        router biased so one expert overflows its capacity (real token
        drops) and a shared expert in the mix: legacy and pregen grads
        must agree bitwise on every leaf (dense straight-through WU
        gradient riding the BP operand's cotangent)."""
        cfg = M.MoEConfig(n_experts=4, top_k=2, d_expert=16, n_shared=1,
                          capacity_factor=0.6, group_size=8)
        d = 32
        params, _ = M.moe_init(jax.random.PRNGKey(0), d, cfg)
        master = _stabilize_masks(params, SP4)
        # bias the router: expert 0 demands far more than its capacity
        master["router"]["w"] = master["router"]["w"].at[:, 0].set(3.0)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, d),
                              jnp.bfloat16)
        # drops really happen: per-group demand for expert 0 exceeds cap
        xt = x.reshape(4, 8, d)
        logits = jnp.matmul(xt, master["router"]["w"].astype(xt.dtype),
                            preferred_element_type=jnp.float32)
        gi = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)[1]
        cap = int(max(cfg.top_k, round(8 * cfg.capacity_factor * cfg.top_k
                                       / cfg.n_experts)))
        demand = (gi[..., None] == jnp.arange(cfg.n_experts)).sum((1, 2))
        assert bool((demand > cap).any())

        def legacy_loss(mtree):
            c = jax.tree.map(lambda v: v.astype(jnp.bfloat16), mtree)
            y, aux = M.moe_apply(c, x, cfg, SP4)
            return jnp.mean(y.astype(jnp.float32) ** 2) + 0.01 * aux

        l_leg, g_leg = jax.value_and_grad(legacy_loss)(master)

        compute = sgd.pregen_tree(master, SP4)
        diff, meta = ST.split_compute(compute)

        def pregen_loss(dv):
            c = ST.merge_compute(dv, meta)
            y, aux = M.moe_apply(c, x, cfg, SP4)
            return jnp.mean(y.astype(jnp.float32) ** 2) + 0.01 * aux

        l_pre, gdiff = jax.value_and_grad(pregen_loss)(diff)
        g_pre = sgd.pregen_grads(ST.merge_compute(gdiff, meta))
        np.testing.assert_array_equal(np.asarray(l_leg), np.asarray(l_pre))
        for (path, a), b in zip(
                jax.tree_util.tree_flatten_with_path(g_leg)[0],
                jax.tree.leaves(g_pre)):
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                err_msg="/".join(str(getattr(k, "key", k)) for k in path))

    def test_moe_update_decay_uses_stored_mask(self):
        """Satellite bugfix pin: SR-STE decay for an expert-stack leaf
        moves exactly the weights the stored fp32-scored mask pruned."""
        sp = SparsityConfig(n=1, m=4, method="srste", lam=0.1)
        w = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 8))
        master = {"moe": {"w_gate": w}}
        state = sgd.init_state(master)
        compute = sgd.pregen_tree(master, sp)
        assert bdwp.is_pregen(compute["moe"]["w_gate"])
        zero_g = jax.tree.map(jnp.zeros_like, master)
        opt = sgd.SGDConfig(lr=0.1, momentum=0.9, weight_decay=0.0,
                            warmup_steps=0, total_steps=10 ** 9,
                            min_lr_frac=1.0)
        new_state, _ = sgd.update(state, zero_g, opt, sp,
                                  param_names=["moe/w_gate"],
                                  prev_compute=compute, pregen=True)
        moved = np.asarray(new_state["master"]["moe"]["w_gate"] != w)
        stored = np.asarray(compute["moe"]["w_gate"]["mask"])
        np.testing.assert_array_equal(moved, ~stored)

    def test_moe_decay_scores_fp32_master_not_bf16(self):
        """Near-tie regression for expert stacks: the stored decay mask
        and the FF operand's survivor set are the SAME fp32-master
        selection — a sub-bf16-resolution tie can't split them, and the
        selection is the fp32 one (truly-larger weight wins), not the
        bf16 tie-break."""
        sp = SparsityConfig(n=1, m=8, method="srste", lam=0.1)
        eps = 2e-4  # far below bf16's ~0.4% relative resolution at 1.0
        w = jnp.full((2, 16, 8), 1e-4, jnp.float32)
        w = w.at[:, 0, :].set(1.0).at[:, 1, :].set(1.0 + eps)
        master = {"moe": {"w_gate": w}}
        pg = sgd.pregen_tree(master, sp)["moe"]["w_gate"]
        assert bdwp.is_pregen(pg)
        ff_alive = np.asarray(pg["ff"] != 0)
        np.testing.assert_array_equal(ff_alive, np.asarray(pg["mask"]))
        np.testing.assert_array_equal(
            np.asarray(pg["mask"]), np.asarray(nm_mask(w, 1, 8, axis=1)))
        assert bool(np.asarray(pg["mask"])[:, 1, :].all())  # fp32 keeps 1+eps
        m16 = nm_mask(w.astype(jnp.bfloat16), 1, 8, axis=1)
        assert not bool(np.asarray(m16)[:, 1, :].any())  # bf16 would not
        assert bool(np.asarray(m16)[:, 0, :].all())  # bf16 ties to idx 0

    def test_pallas_fused_update_on_expert_stack_bitwise(self):
        """use_pallas=True routes stacked (E, K, F) leaves through the
        fused WUVE+SORE kernel too; jitted, it matches the jnp update
        bitwise — master, momentum and the packed compute leaf."""
        from functools import partial

        sp = SparsityConfig(n=2, m=8, method="bdwp")
        w = jax.random.normal(jax.random.PRNGKey(0), (3, 16, 16))
        master = {"moe": {"w_gate": w}}
        grads = {"moe": {"w_gate": 0.1 * jax.random.normal(
            jax.random.PRNGKey(1), w.shape)}}
        prev = sgd.pregen_tree(master, sp, pack=True)
        opt = sgd.SGDConfig(lr=0.1, total_steps=10)

        def upd(state, g, use_pallas):
            return sgd.update(state, g, opt, sp,
                              param_names=["moe/w_gate"], prev_compute=prev,
                              pregen=True, pack=True, use_pallas=use_pallas)

        out_j = jax.jit(partial(upd, use_pallas=False))(
            sgd.init_state(master), grads)
        out_p = jax.jit(partial(upd, use_pallas=True))(
            sgd.init_state(master), grads)
        flat_j = jax.tree_util.tree_flatten_with_path(out_j)[0]
        flat_p = jax.tree.leaves(out_p)
        assert len(flat_j) == len(flat_p)
        for (path, a), b in zip(flat_j, flat_p):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg="/".join(str(getattr(k, "key", k)) for k in path))


class TestMoECheckpointUpgrade:
    def test_dict_sites_only_checkpoint_upgrades(self, tmp_path):
        """A checkpoint from the dict-sites-only pregen era (MoE expert
        leaves still plain bf16 in its compute tree) restores via
        restore_with_pregen: the legacy subtree loads and the full
        compute tree — expert operand dicts included — regenerates from
        the restored master, exactly."""
        mesh = make_host_mesh()
        bundle = ST.build_lm_train(MOE_CFG, mesh, SP4, OPT, donate=False)
        st0 = ST.init_train_state(jax.random.PRNGKey(5), MOE_CFG,
                                  sp_cfg=SP4)
        old = dict({k: st0[k] for k in ("master", "momentum", "step")},
                   compute=sgd.pregen_tree(st0["master"], SP4,
                                           bare_sites=False))
        # the old structure really is different (else this tests nothing)
        assert (jax.tree_util.tree_structure(old["compute"])
                != jax.tree_util.tree_structure(st0["compute"]))
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(0, old, blocking=True)

        like = ST.init_train_state(jax.random.PRNGKey(0), MOE_CFG,
                                   sp_cfg=SP4)
        restored = ST.restore_with_pregen(
            mgr, like, shardings=bundle.state_shardings, sp_cfg=SP4)
        for a, b in zip(jax.tree.leaves(restored["master"]),
                        jax.tree.leaves(st0["master"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        expect = sgd.pregen_tree(st0["master"], SP4)
        for a, b in zip(jax.tree.leaves(restored["compute"]),
                        jax.tree.leaves(expect)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
                 "labels": jnp.zeros((2, 32), jnp.int32)}
        _, metrics = bundle.step_fn(restored, batch)
        assert np.isfinite(float(metrics["loss"]))

    def test_pre_pregen_moe_checkpoint_upgrades(self, tmp_path):
        """The original upgrade path (no compute leaf at all) still
        works for MoE models."""
        mesh = make_host_mesh()
        bundle = ST.build_lm_train(MOE_CFG, mesh, SP4, OPT, donate=False)
        legacy = ST.init_train_state(jax.random.PRNGKey(3), MOE_CFG,
                                     sp_cfg=SP4, pregen=False)
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(0, legacy, blocking=True)
        like = ST.init_train_state(jax.random.PRNGKey(0), MOE_CFG,
                                   sp_cfg=SP4)
        restored = ST.restore_with_pregen(
            mgr, like, shardings=bundle.state_shardings, sp_cfg=SP4)
        assert "compute" in restored
        expect = sgd.pregen_tree(legacy["master"], SP4)
        for a, b in zip(jax.tree.leaves(restored["compute"]),
                        jax.tree.leaves(expect)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestConvPregen:
    def test_resnet9_trains_on_pregen_tree(self):
        """nm_conv_pregen end-to-end: build a pregen tree for ResNet9,
        forward/backward through it, and check the WU gradient is dense
        (straight-through) while FF used the pruned operand."""
        from repro.models import convnets as C

        sp = SparsityConfig(n=2, m=8, method="bdwp")
        params = C.resnet9_init(jax.random.PRNGKey(0), num_classes=10,
                                width=32)
        compute = sgd.pregen_tree(params, sp)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3),
                              jnp.bfloat16)
        diff, meta = ST.split_compute(compute)

        def loss_fn(d):
            logits = C.resnet9_apply(ST.merge_compute(d, meta), x, sp)
            return jnp.mean(logits ** 2)

        loss, gdiff = jax.value_and_grad(loss_fn)(diff)
        assert np.isfinite(float(loss))
        grads = sgd.pregen_grads(ST.merge_compute(gdiff, meta))
        gw = grads["conv1"]["conv"]["w"]
        assert gw.shape == params["conv1"]["conv"]["w"].shape
        assert float((np.asarray(gw, np.float32) != 0).mean()) > 0.9
