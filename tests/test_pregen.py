"""Pre-generation dataflow tests (paper Fig. 11c executed for real).

What must hold:
  * mask-once invariant: the traced bdwp train step derives each
    prunable param's N:M masks exactly once (at WU time) — one
    top_k/sort per prunable leaf in the whole step, none in the model;
  * A/B parity: the pregen step tracks the legacy step across all five
    methods, and is BITWISE equal to it whenever the fp32-master masks
    agree with the legacy bf16-scored masks (same masks => same losses);
  * packed (vals, idx) pregen state is bitwise-equal to the unpacked
    form and round-trips through nm_unpack_n;
  * the fused Pallas WU kernel path (interpret mode) is bitwise-equal
    to the jnp path;
  * pre-pregen checkpoints (no "compute" leaf) restore and upgrade;
  * conv FF masks and SR-STE decay both score on fp32 master — a
    bf16-rounding near-tie can no longer make them disagree.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import bdwp
from repro.core import sparsity as S
from repro.core.sparsity import SparsityConfig, nm_mask, nm_pack
from repro.data import synthetic as D
from repro.launch.hlo_cost import count_mask_ops
from repro.launch.mesh import make_host_mesh
from repro.models import transformer_lm as T
from repro.optim import sgd
from repro.train import step as ST
from repro.train.checkpoint import CheckpointManager

jax.config.update("jax_platform_name", "cpu")

ARCH = get_arch("qwen3-8b")
CFG = ARCH.smoke
OPT = sgd.SGDConfig(lr=0.05, total_steps=16)
BDWP = SparsityConfig(n=2, m=8, method="bdwp")


def _structs(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _run(sp_cfg, *, pregen, steps=3, pack=False, use_pallas=False, seed=0):
    mesh = make_host_mesh()
    bundle = ST.build_lm_train(CFG, mesh, sp_cfg, OPT, donate=False,
                               pregen=pregen, pregen_pack=pack,
                               use_pallas=use_pallas)
    state = ST.init_train_state(jax.random.PRNGKey(seed), CFG, sp_cfg=sp_cfg,
                                pregen=pregen, pregen_pack=pack)
    state = jax.device_put(state, bundle.state_shardings)
    stream = D.lm_stream(CFG.vocab, 2, 32, seed=seed)
    losses = []
    for i, (_, batch) in enumerate(stream):
        if i >= steps:
            break
        state, metrics = bundle.step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    return state, losses


class TestMaskOnce:
    def test_one_topk_per_prunable_param(self):
        """THE invariant: the lowered bdwp train step contains exactly
        one top_k/sort mask derivation per prunable parameter (the fused
        FF+BP selection at WU time), down from 3+ per param when FF, BP
        and SR-STE decay each re-derived it (4x with remat recompute)."""
        mesh = make_host_mesh()
        bundle = ST.build_lm_train(CFG, mesh, BDWP, OPT, donate=False)
        state = ST.init_train_state(jax.random.PRNGKey(0), CFG, sp_cfg=BDWP)
        batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
                 "labels": jnp.zeros((2, 32), jnp.int32)}
        n_sites = sum(
            bdwp.pregen_site(n, sgd._logical_shape(n, w.shape)[0], BDWP)
            for n, w in zip(sgd._names_of(state["master"]),
                            jax.tree.leaves(state["master"])))
        assert n_sites > 0
        count = count_mask_ops(bundle.step_fn, _structs(state),
                               _structs(batch))
        assert count == n_sites, \
            f"{count} top_k/sort ops for {n_sites} prunable params"

    def test_legacy_step_rederives(self):
        """Sanity of the census itself: the legacy dataflow really does
        pay multiple selections per param (FF + remat'd FF + BP + decay)."""
        mesh = make_host_mesh()
        bundle = ST.build_lm_train(CFG, mesh, BDWP, OPT, donate=False,
                                   pregen=False)
        state = ST.init_train_state(jax.random.PRNGKey(0), CFG, sp_cfg=BDWP,
                                    pregen=False)
        batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
                 "labels": jnp.zeros((2, 32), jnp.int32)}
        count = count_mask_ops(bundle.step_fn, _structs(state),
                               _structs(batch))
        assert count >= 3 * 7  # 7 prunable leaves in the smoke config

    def test_fused_pair_equals_two_masks(self):
        w = jax.random.normal(jax.random.PRNGKey(3), (4, 32, 16))
        ff, bp = S.nm_mask_pair(w, 2, 8, 1, 2)
        np.testing.assert_array_equal(np.asarray(ff),
                                      np.asarray(nm_mask(w, 2, 8, axis=1)))
        np.testing.assert_array_equal(np.asarray(bp),
                                      np.asarray(nm_mask(w, 2, 8, axis=2)))

    def test_pack_from_mask_equals_nm_pack(self):
        for seed in range(5):
            x = jax.random.normal(jax.random.PRNGKey(seed), (8, 64))
            mask = nm_mask(x, 2, 8, axis=0)
            v, i = S.nm_pack_from_mask(x, mask, 2, 8, axis=0)
            rv, ri = nm_pack(x, 2, 8, axis=0)
            np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
            np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))


class TestPregenParity:
    @pytest.mark.parametrize("method",
                             ["dense", "srste", "sdgp", "sdwp", "bdwp"])
    def test_tracks_legacy_trajectory(self, method):
        """Pregen vs legacy differ ONLY through the mask-source fix
        (fp32-master vs bf16 scoring flips ~0.1% of near-tie bits), so
        short trajectories must track closely for every method."""
        sp = SparsityConfig(n=2, m=8, method=method)
        _, l_pre = _run(sp, pregen=True)
        _, l_leg = _run(sp, pregen=False)
        np.testing.assert_allclose(l_pre, l_leg, atol=5e-2)

    def test_packed_state_bitwise_equals_unpacked(self):
        s_a, l_a = _run(BDWP, pregen=True, pack=False)
        s_b, l_b = _run(BDWP, pregen=True, pack=True)
        assert l_a == l_b
        for a, b in zip(jax.tree.leaves(s_a["master"]),
                        jax.tree.leaves(s_b["master"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("method,pack", [("srste", False),
                                             ("bdwp", False),
                                             ("bdwp", True)])
    def test_pallas_fused_update_bitwise_equals_jnp(self, method, pack):
        """The fused WUVE+SORE kernel (interpret mode on CPU) wired into
        the train step must match the jnp formulation bitwise: same
        masks, same losses, same master — including the kernel-packed
        state (pack=True stores the kernel's (vals, idx) directly)."""
        sp = SparsityConfig(n=2, m=8, method=method)
        s_j, l_j = _run(sp, pregen=True, steps=2, pack=pack)
        s_p, l_p = _run(sp, pregen=True, steps=2, pack=pack,
                        use_pallas=True)
        assert l_j == l_p
        flat_j = jax.tree_util.tree_flatten_with_path(s_j)[0]
        flat_p = jax.tree.leaves(s_p)
        assert len(flat_j) == len(flat_p)
        for (path, a), b in zip(flat_j, flat_p):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg="/".join(str(getattr(k, "key", k)) for k in path))

    def test_exact_parity_when_masks_stable(self):
        """Same masks => bitwise-equal losses.  With magnitudes spaced
        far beyond bf16 resolution the fp32 and bf16 scorings select the
        same survivors, and the pregen step must reproduce the legacy
        trajectory EXACTLY (fp32-master path)."""
        k, f = 16, 16  # both axes prunable (>= 2*m per group axis)
        # geometrically spaced magnitudes: every |w| gap is ~2%, five
        # bf16 resolution steps — small updates can't create new ties
        vals = 1.02 ** jnp.arange(k * f, dtype=jnp.float32) * 0.05
        vals = vals * jnp.where(jnp.arange(k * f) % 3 == 0, -1.0, 1.0)
        w0 = jax.random.permutation(jax.random.PRNGKey(0), vals).reshape(k, f)
        assert bdwp.pregen_site("proj/w", (k, f),
                                SparsityConfig(n=2, m=8, method="bdwp"))
        sp = SparsityConfig(n=2, m=8, method="bdwp", lam=1e-3)
        opt = sgd.SGDConfig(lr=1e-3, warmup_steps=0, total_steps=100,
                            weight_decay=1e-4, min_lr_frac=1.0)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, k), jnp.bfloat16)
        y = jax.random.normal(jax.random.PRNGKey(2), (4, f), jnp.bfloat16)
        names = ["proj/w"]

        def legacy_step(state):
            def loss_fn(master):
                compute = jax.tree.map(
                    lambda v: v.astype(jnp.bfloat16), master)
                out = bdwp.nm_linear(x, compute["proj"]["w"], sp)
                return jnp.mean((out - y) ** 2)

            loss, grads = jax.value_and_grad(loss_fn)(state["master"])
            new_state, _ = sgd.update(state, grads, opt, sp,
                                      param_names=names)
            return new_state, loss

        def pregen_step(state):
            diff, meta = ST.split_compute(state["compute"])

            def loss_fn(d):
                compute = ST.merge_compute(d, meta)
                pg = compute["proj"]["w"]
                out = bdwp.nm_linear_pregen(
                    x, bdwp.pregen_ff_operand(pg, sp), pg["bp"])
                return jnp.mean((out - y) ** 2)

            loss, gdiff = jax.value_and_grad(loss_fn)(diff)
            grads = sgd.pregen_grads(ST.merge_compute(gdiff, meta))
            core = {k: state[k] for k in ("master", "momentum", "step")}
            new_state, compute = sgd.update(
                core, grads, opt, sp, param_names=names,
                prev_compute=state["compute"], pregen=True, pack=True)
            return dict(new_state, compute=compute), loss

        master = {"proj": {"w": w0}}
        s_leg = sgd.init_state(master)
        s_pre = dict(sgd.init_state(master),
                     compute=sgd.pregen_tree(master, sp, pack=True))
        for step in range(4):
            # precondition: legacy's bf16-scored masks == fp32 masks
            w = s_leg["master"]["proj"]["w"]
            for ax in (0, 1):
                np.testing.assert_array_equal(
                    np.asarray(nm_mask(w, 2, 8, axis=ax)),
                    np.asarray(nm_mask(w.astype(jnp.bfloat16), 2, 8,
                                       axis=ax)))
            s_leg, l_leg = legacy_step(s_leg)
            s_pre, l_pre = pregen_step(s_pre)
            np.testing.assert_array_equal(np.asarray(l_leg),
                                          np.asarray(l_pre))
            np.testing.assert_array_equal(
                np.asarray(s_leg["master"]["proj"]["w"]),
                np.asarray(s_pre["master"]["proj"]["w"]))

    def test_packed_leaf_roundtrips(self):
        state = ST.init_train_state(jax.random.PRNGKey(0), CFG, sp_cfg=BDWP,
                                    pregen_pack=True)
        pg = state["compute"]["blocks"]["ffn"]["w_gate"]["w"]
        assert "vals" in pg and pg["idx"].dtype == jnp.uint8
        master = state["master"]["blocks"]["ffn"]["w_gate"]["w"]
        ff_dense = bdwp.pregen_ff_operand(pg, BDWP)
        expect = jnp.where(nm_mask(master, 2, 8, axis=master.ndim - 2),
                           master, 0.0).astype(jnp.bfloat16)
        np.testing.assert_array_equal(np.asarray(ff_dense),
                                      np.asarray(expect))
        # packed axis really is N/M of the contraction axis
        assert pg["vals"].shape[-2] == master.shape[-2] * 2 // 8

    @pytest.mark.parametrize("method", ["srste", "sdwp", "bdwp"])
    def test_update_decay_uses_stored_mask(self, method):
        """sgd.update(pregen=True) must decay exactly the weights the
        stored (previous-WU) mask pruned — no re-derivation drift."""
        sp = SparsityConfig(n=1, m=4, method=method, lam=0.1)
        w = jax.random.normal(jax.random.PRNGKey(0), (8, 8))
        master = {"proj": {"w": w}}
        state = sgd.init_state(master)
        compute = sgd.pregen_tree(master, sp)
        zero_g = jax.tree.map(jnp.zeros_like, master)
        opt = sgd.SGDConfig(lr=0.1, momentum=0.9, weight_decay=0.0,
                            warmup_steps=0, total_steps=10 ** 9,
                            min_lr_frac=1.0)
        new_state, _ = sgd.update(state, zero_g, opt, sp,
                                  param_names=["proj/w"],
                                  prev_compute=compute, pregen=True)
        moved = np.asarray(new_state["master"]["proj"]["w"] != w)
        stored = np.asarray(compute["proj"]["w"]["mask"])
        np.testing.assert_array_equal(moved, ~stored)


class TestCheckpointCompat:
    def test_pre_pregen_checkpoint_upgrades(self, tmp_path):
        """A checkpoint written before the pregen dataflow (no "compute"
        leaf) restores via restore_with_pregen: the legacy subtree loads
        and the operands regenerate from the restored master, exactly."""
        mesh = make_host_mesh()
        bundle = ST.build_lm_train(CFG, mesh, BDWP, OPT, donate=False)
        legacy = ST.init_train_state(jax.random.PRNGKey(5), CFG,
                                     sp_cfg=BDWP, pregen=False)
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(0, legacy, blocking=True)

        like = ST.init_train_state(jax.random.PRNGKey(0), CFG, sp_cfg=BDWP)
        restored = ST.restore_with_pregen(
            mgr, like, shardings=bundle.state_shardings, sp_cfg=BDWP)
        assert "compute" in restored
        for a, b in zip(jax.tree.leaves(restored["master"]),
                        jax.tree.leaves(legacy["master"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        expect = sgd.pregen_tree(legacy["master"], BDWP)
        for a, b in zip(jax.tree.leaves(restored["compute"]),
                        jax.tree.leaves(expect)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # and the upgraded state steps
        stream = D.lm_stream(CFG.vocab, 2, 32)
        _, batch = next(iter(stream))
        new_state, metrics = bundle.step_fn(restored, batch)
        assert np.isfinite(float(metrics["loss"]))

    def test_full_state_roundtrip_with_compute(self, tmp_path):
        """bf16/uint8/bool compute leaves survive the npy round-trip."""
        state = ST.init_train_state(jax.random.PRNGKey(1), CFG, sp_cfg=BDWP,
                                    pregen_pack=True)
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(2, state, blocking=True)
        out = mgr.restore(jax.tree.map(jnp.zeros_like, state))
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(state)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestMaskSourceConsistency:
    """Satellite bugfix: FF masks and SR-STE decay masks must both score
    on fp32 master.  A near-tie group — two weights closer than bf16
    resolution — is the regression trigger: bf16 scoring rounds them
    equal and keeps the EARLIER index, fp32 keeps the truly larger one."""

    def _near_tie_group(self):
        eps = 2e-4  # far below bf16's ~0.4% relative resolution at 1.0
        g = np.full(8, 1e-4, np.float32)
        g[0], g[1] = 1.0, 1.0 + eps  # fp32 keeps idx 1; bf16 ties -> idx 0
        return jnp.asarray(g)

    def test_near_tie_premise(self):
        g = self._near_tie_group()
        m32 = nm_mask(g, 1, 8, axis=0)
        m16 = nm_mask(g.astype(jnp.bfloat16), 1, 8, axis=0)
        assert bool(m32[1]) and not bool(m32[0])
        assert bool(m16[0]) and not bool(m16[1])  # the legacy disagreement

    def test_conv_ff_mask_scores_on_given_weights(self):
        """nm_conv masks the weights it is GIVEN and casts after masking:
        passing fp32 master (as examples/paper_loss_curves.py now does)
        yields the fp32-mask selection even with bf16 activations."""
        sp = SparsityConfig(n=1, m=8, method="bdwp")
        w = jnp.zeros((1, 1, 8, 8), jnp.float32)
        w = w.at[0, 0, :, 0].set(self._near_tie_group())
        x = jnp.ones((1, 4, 4, 8), jnp.bfloat16)
        y = bdwp.nm_conv(x, w, sp)
        # output channel 0 == conv with only the fp32-kept tap (idx 1)
        w_ref = jnp.zeros_like(w).at[0, 0, 1, 0].set(w[0, 0, 1, 0])
        y_ref = jax.lax.conv_general_dilated(
            x, w_ref.astype(x.dtype), (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        np.testing.assert_array_equal(np.asarray(y[..., 0]),
                                      np.asarray(y_ref[..., 0]))

    def test_pregen_ff_and_decay_share_fp32_mask(self):
        """In the pregen state the FF operand's survivor set IS the
        stored decay mask, both scored on fp32 master — the near-tie
        group can no longer make FF and decay disagree."""
        sp = SparsityConfig(n=1, m=8, method="srste", lam=0.1)
        w = jnp.tile(self._near_tie_group()[:, None], (2, 8))  # (16, 8)
        master = {"proj": {"w": w}}
        compute = sgd.pregen_tree(master, sp)
        pg = compute["proj"]["w"]
        ff_alive = np.asarray(pg["ff"] != 0)
        np.testing.assert_array_equal(ff_alive, np.asarray(pg["mask"]))
        np.testing.assert_array_equal(
            np.asarray(pg["mask"]), np.asarray(nm_mask(w, 1, 8, axis=0)))

    def test_decay_excludes_directly_consumed_weights(self):
        """lm_head never routes through nm_linear, so SR-STE must not
        decay it (it used to — decaying never-pruned weights)."""
        assert not bdwp.decays("lm_head/w", (64, 512), BDWP)
        assert bdwp.decays("blocks/attn/q_proj/w", (64, 64), BDWP)
        assert not bdwp.pregen_site("lm_head/w", (64, 512), BDWP)


class TestConvPregen:
    def test_resnet9_trains_on_pregen_tree(self):
        """nm_conv_pregen end-to-end: build a pregen tree for ResNet9,
        forward/backward through it, and check the WU gradient is dense
        (straight-through) while FF used the pruned operand."""
        from repro.models import convnets as C

        sp = SparsityConfig(n=2, m=8, method="bdwp")
        params = C.resnet9_init(jax.random.PRNGKey(0), num_classes=10,
                                width=32)
        compute = sgd.pregen_tree(params, sp)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3),
                              jnp.bfloat16)
        diff, meta = ST.split_compute(compute)

        def loss_fn(d):
            logits = C.resnet9_apply(ST.merge_compute(d, meta), x, sp)
            return jnp.mean(logits ** 2)

        loss, gdiff = jax.value_and_grad(loss_fn)(diff)
        assert np.isfinite(float(loss))
        grads = sgd.pregen_grads(ST.merge_compute(gdiff, meta))
        gw = grads["conv1"]["conv"]["w"]
        assert gw.shape == params["conv1"]["conv"]["w"].shape
        assert float((np.asarray(gw, np.float32) != 0).mean()) > 0.9
