"""Sharding-rule tests: dedupe, divisibility fallback, activation ctx."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_host_mesh
from repro.sharding import rules as R

jax.config.update("jax_platform_name", "cpu")


class TestSpecToPspec:
    def test_basic_mapping(self):
        ps = R.spec_to_pspec(("embed", "mlp"), R.TRAIN_RULES)
        assert ps == P("data", "model")

    def test_dedupe_moe_stacked(self):
        """(layer, expert, embed, mlp): expert and mlp both -> model;
        first occurrence wins, mlp falls back to replicated."""
        ps = R.spec_to_pspec(("layer", "expert", "embed", "mlp"),
                             R.TRAIN_RULES)
        assert ps == P(None, "model", "data", None)

    def test_divisibility_fallback(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # fake a 16-way axis via rule check: use size-1 mesh -> divides
        ps = R.spec_to_pspec(("embed", "mlp"), R.TRAIN_RULES,
                             shape=(7, 13), mesh=mesh)
        assert ps == P("data", "model")  # size-1 axes always divide

    def test_divisibility_fallback_nondividing(self):
        class FakeMesh:
            shape = {"data": 4, "model": 4}
        ps = R.spec_to_pspec(("embed", "mlp"), R.TRAIN_RULES,
                             shape=(6, 16), mesh=FakeMesh())
        assert ps == P(None, "model")  # 6 % 4 != 0 -> replicated

    def test_params_pspecs_with_params_tree(self):
        class FakeMesh:
            shape = {"data": 4, "model": 4}
        specs = {"a": ("embed", "mlp"), "b": ("embed",)}
        params = {"a": jax.ShapeDtypeStruct((8, 6), jnp.float32),
                  "b": jax.ShapeDtypeStruct((5,), jnp.float32)}
        out = R.params_pspecs(specs, R.TRAIN_RULES, params, FakeMesh())
        assert out["a"] == P("data", None)   # 6 % 4 -> mlp dropped
        assert out["b"] == P(None)           # 5 % 4 -> embed dropped


class TestActivationContext:
    def test_noop_without_context(self):
        x = jnp.ones((4, 8))
        y = R.act(x, R.BATCH, None)
        assert y is x

    def test_constrains_under_context(self):
        mesh = make_host_mesh()
        with R.activation_sharding(mesh, ("data",)):
            @jax.jit
            def f(x):
                return R.act(x, R.BATCH, None) * 2
            y = f(jnp.ones((4, 8)))
        assert bool((y == 2).all())

    def test_nondividing_dim_replicates(self):
        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 4, "model": 4}
        # shape 6 % 4 -> entry must become None: exercise the logic via
        # the internal path (no real device needed since constraint is
        # only applied inside jit; here just check no exception path)
        with R.activation_sharding(None, ("data",)):
            x = jnp.ones((6, 8))
            assert R.act(x, R.BATCH, None) is x

    def test_context_restores(self):
        mesh = make_host_mesh()
        with R.activation_sharding(mesh, ("data",)):
            pass
        x = jnp.ones((4,))
        assert R.act(x, R.BATCH) is x  # context cleared -> no-op


class TestCacheSpecs:
    def test_kv_heads_replicated_when_indivisible(self):
        mesh = make_host_mesh()  # 1 device: everything divides
        specs = {"cache": {"k": jax.ShapeDtypeStruct((4, 2, 64, 8, 16),
                                                     jnp.bfloat16),
                           "pos": jax.ShapeDtypeStruct((), jnp.int32)},
                 "token": jax.ShapeDtypeStruct((2, 1), jnp.int32),
                 "pos": jax.ShapeDtypeStruct((), jnp.int32)}
        out = R.serve_input_pspecs(specs, mesh, long_context=False)
        assert out["cache"]["k"][3] in ("model", None)
        assert out["token"] == P(("data",), None)
