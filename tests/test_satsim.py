"""SAT cycle-model tests: pins satsim to the paper's published numbers."""

import dataclasses

import pytest

from repro.satsim.arch import DEFAULT, SATConfig, SORE, STCE, WUVE, \
    stce_resources
from repro.satsim.model import model_step_time, runtime_throughput
from repro.satsim.workloads import paper_model_layers


class TestPeaks:
    def test_dense_peak_matches_table4(self):
        assert DEFAULT.dense_peak_ops == pytest.approx(409.6e9)

    def test_sparse_peak_matches_table4(self):
        assert DEFAULT.sparse_peak_ops == pytest.approx(1638.4e9)

    def test_sparse_peak_scales_with_m_over_n(self):
        c24 = SATConfig(n=2, m=4)
        assert c24.sparse_peak_ops == pytest.approx(2 * c24.dense_peak_ops)


class TestSTCECycles:
    def test_sparse_faster_than_dense(self):
        s = STCE(DEFAULT)
        d = s.best_cycles(4096, 1024, 1024, sparse=False)[1]
        sp = s.best_cycles(4096, 1024, 1024, sparse=True)[1]
        assert sp < d
        # 2:8 approaches (but never beats) the M/N=4x ideal
        assert 2.0 < d / sp <= 4.0

    def test_interleave_mapping_3x_os(self):
        no_il = dataclasses.replace(DEFAULT, interleave=False)
        base = STCE(DEFAULT).os_cycles(512, 4096, 512, sparse=False)
        stall = STCE(no_il).os_cycles(512, 4096, 512, sparse=False)
        assert stall / base == pytest.approx(3.0, rel=0.05)

    def test_rwg_picks_cheaper_dataflow(self):
        s = STCE(DEFAULT)
        for dims in ((64, 8192, 64), (16384, 256, 256)):
            df, c = s.best_cycles(*dims, sparse=False)
            other = s.os_cycles(*dims, sparse=False) if df == "WS" \
                else s.ws_cycles(*dims, sparse=False)
            assert c <= other


class TestEngines:
    def test_sore_streams_one_elem_per_lane_cycle(self):
        assert SORE(DEFAULT).cycles(32 * 1000) == 1000

    def test_sore_packed_bytes_under_half_at_2_8(self):
        packed = SORE(DEFAULT).packed_bytes(8000)
        dense = 8000 * 2
        assert packed < dense / 2

    def test_wuve_lanes(self):
        assert WUVE(DEFAULT).cycles(3200) == 100


class TestPaperNumbers:
    def test_bdwp_mean_batch_speedup_band(self):
        """Paper Fig. 15: 1.82x mean per-batch speedup (2:8)."""
        speeds = []
        for name in ("resnet9", "vit", "vgg19", "resnet18", "resnet50"):
            layers = paper_model_layers(name)
            speeds.append(model_step_time(layers, "dense")["total_s"]
                          / model_step_time(layers, "bdwp")["total_s"])
        mean = sum(speeds) / len(speeds)
        assert 1.6 < mean < 2.0

    def test_runtime_throughput_band_resnet18(self):
        """Paper Table IV: 280.31 dense / 702.54 sparse GOPS."""
        layers = paper_model_layers("resnet18")
        dense = runtime_throughput(layers, "dense")["gops"]
        sparse = runtime_throughput(layers, "bdwp")["gops"]
        assert 200 < dense < 450
        assert 500 < sparse < 900
        assert sparse > 1.5 * dense

    def test_macs_reduction_bdwp_2_8(self):
        layers = paper_model_layers("resnet18")
        rep = model_step_time(layers, "bdwp")
        red = rep["macs"]["dense"] / rep["macs"]["bdwp"]
        assert 1.8 < red < 2.0  # paper: ~48% fewer ops


class TestResourceModel:
    def test_ff_overhead_grows_with_m(self):
        r24 = stce_resources(SATConfig(array=4, n=2, m=4))
        r28 = stce_resources(SATConfig(array=4, n=2, m=8))
        r216 = stce_resources(SATConfig(array=4, n=2, m=16))
        assert r24["ff"] < r28["ff"] < r216["ff"]

    def test_stce_cheaper_than_iso_throughput_dense(self):
        """Fig. 14's headline: 2:8 STCE beats the 4x16 dense array."""
        stce = stce_resources(SATConfig(array=4, n=2, m=8))
        dense_iso = {k: v * 4 for k, v in
                     stce_resources(SATConfig(array=4), dense=True).items()}
        assert dense_iso["lut"] / stce["lut"] > 2.0
        assert dense_iso["ff"] / stce["ff"] > 1.5
        assert dense_iso["dsp"] / stce["dsp"] == pytest.approx(4.0)
