"""RWG offline-scheduling tests (core/schedule.py)."""

import pytest

from repro.core import schedule as S
from repro.core.sparsity import SparsityConfig

BDWP = SparsityConfig(n=2, m=8, method="bdwp")
DENSE = SparsityConfig(method="dense")


class TestDataflowModel:
    def test_ws_better_for_tall_skinny(self):
        # few rows streaming, big weight: WS amortizes the preload
        df, _ = S.pick_dataflow(b=16384, k=256, f=256)
        assert df == "WS"

    def test_os_better_for_small_batch_long_k(self):
        df, _ = S.pick_dataflow(b=128, k=16384, f=128)
        assert df == "OS"

    def test_utilization_bounded(self):
        for dims in ((64, 64, 64), (4096, 4096, 4096), (1, 8, 8)):
            _, u = S.pick_dataflow(*dims)
            assert 0.0 <= u <= 1.0

    def test_big_square_matmul_high_utilization(self):
        _, u = S.pick_dataflow(8192, 4096, 4096)
        assert u > 0.9


class TestLayerPlan:
    def test_bdwp_stages(self):
        p = S.plan_layer("mlp/w_in", b=1024, k=512, f=512, cfg=BDWP)
        assert p.ff.sparse and p.bp.sparse and not p.wu.sparse
        assert p.ff.pack_site == "pregen"  # Fig. 11c
        assert p.ff.macs == 1024 * 128 * 512    # K shrunk by N/M
        assert p.bp.macs == 1024 * 128 * 512    # F shrunk by N/M
        assert p.wu.macs == 1024 * 512 * 512    # dense

    def test_sdgp_packs_inline(self):
        cfg = SparsityConfig(n=2, m=8, method="sdgp")
        p = S.plan_layer("mlp/w_in", 1024, 512, 512, cfg)
        assert not p.ff.sparse and p.bp.sparse
        assert p.bp.pack_site == "inline"  # grads exist only inside BP

    def test_excluded_layer_stays_dense(self):
        p = S.plan_layer("head0", 1024, 512, 512, BDWP)
        assert not p.ff.sparse and not p.bp.sparse
        assert p.total_macs == 3 * 1024 * 512 * 512

    def test_config_word_roundtrip(self):
        w = S.plan_layer("attn/q_proj", 256, 512, 512, BDWP).config_word()
        assert w["ff"][1] == "sparse" and w["wu"][1] == "dense"
        assert w["ff"][0] in ("WS", "OS")


class TestModelPlan:
    SHAPES = {
        "embed/embed_table": (1024, 64),   # excluded by name
        "blocks/attn/q_proj/w": (4, 64, 64),
        "blocks/mlp/w_in/w": (4, 64, 256),
        "final_norm/norm_scale": (64,),    # rank-1: skipped
    }

    def test_plan_expands_stacked_layers(self):
        plans = S.plan_model(self.SHAPES, tokens=512, cfg=BDWP)
        names = [p.name for p in plans]
        assert sum("q_proj" in n for n in names) == 4
        assert sum("w_in" in n for n in names) == 4
        assert not any("norm" in n for n in names)

    def test_summary_reduction_matches_analytic(self):
        plans = S.plan_model(self.SHAPES, tokens=512, cfg=BDWP)
        summ = S.schedule_summary(plans)
        # embed stays dense (excluded); the 8 block matmuls run FF/BP at
        # N/M=1/4: per-layer factor (0.25+0.25+1)/3 = 0.5
        embed = 512 * 1024 * 64 * 3
        blocks = 4 * (512 * 64 * 64 + 512 * 64 * 256) * 3
        expected = (embed + blocks) / (embed + blocks * 0.5)
        assert summ["reduction"] == pytest.approx(expected, rel=1e-6)
        # and the block-only reduction is exactly 2x
        block_plans = [p for p in plans if "blocks" in p.name]
        bsumm = S.schedule_summary(block_plans)
        assert bsumm["reduction"] == pytest.approx(2.0, rel=1e-6)

    def test_dense_summary_identity(self):
        plans = S.plan_model(self.SHAPES, tokens=512, cfg=DENSE)
        summ = S.schedule_summary(plans)
        assert summ["reduction"] == 1.0
        assert summ["macs_total"] == summ["macs_dense"]
