"""Continuous-batching serve engine tests (CPU, smoke config).

The load-bearing property: batch composition is invisible to a request.
A request decoded alongside arbitrary other traffic — joining
mid-flight, into a reused slot, from packed or masked weights — must
produce exactly the token stream of decoding it alone.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.sparsity import SparsityConfig
from repro.models import transformer_lm as T
from repro.serve import PackedParamStore, ServeConfig, ServeEngine

jax.config.update("jax_platform_name", "cpu")

ARCH = get_arch("qwen3-8b")
CFG = ARCH.smoke
SP = SparsityConfig(n=2, m=8, method="bdwp")
SERVE = ServeConfig(n_slots=2, max_len=32, prompt_bucket=12)


@pytest.fixture(scope="module")
def params():
    p, _ = T.init(jax.random.PRNGKey(0), CFG)
    return jax.tree.map(lambda w: w.astype(jnp.bfloat16), p)


def _prompts(lens, seed=11):
    key = jax.random.PRNGKey(seed)
    return [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                          (n,), 0, CFG.vocab))
            for i, n in enumerate(lens)]


def _solo(params, prompt, max_new, serve_cfg=SERVE):
    eng = ServeEngine(params, CFG, SP, serve_cfg)
    rid = eng.submit(prompt, max_new_tokens=max_new)
    return eng.run()[rid]


class TestContinuousBatching:
    def test_mid_flight_join_matches_solo(self, params):
        """The acceptance workload: 3 mixed-length requests through 2
        slots, the third joining the running batch in the slot freed by
        the first — all streams identical to solo greedy decode."""
        prompts = _prompts((4, 8, 12))
        solo = [_solo(params, p, m) for p, m in
                zip(prompts, (4, 10, 10))]

        eng = ServeEngine(params, CFG, SP, SERVE)
        r0 = eng.submit(prompts[0], max_new_tokens=4)
        r1 = eng.submit(prompts[1], max_new_tokens=10)
        r2 = None
        steps = 0
        while eng.n_running or eng.n_queued or r2 is None:
            ev = eng.step()
            if r2 is None and r0 in ev["finished"]:
                # r1 still mid-flight: the join is continuous batching
                assert eng.n_running == 1
                r2 = eng.submit(prompts[2], max_new_tokens=10)
            steps += 1
            assert steps < 100
        out = eng.harvest()
        assert out[r0] == solo[0]
        assert out[r1] == solo[1]
        assert out[r2] == solo[2]

    def test_slot_reuse_after_eviction(self, params):
        """4 requests through 2 slots: the 3rd/4th decode in evicted
        lanes over stale KV garbage and must reproduce the 1st/2nd."""
        prompts = _prompts((5, 9))
        eng = ServeEngine(params, CFG, SP, SERVE)
        rids = [eng.submit(p, max_new_tokens=6) for p in prompts * 2]
        out = eng.run()
        # slots were actually reused
        assert eng.batcher.kv.n_free == SERVE.n_slots
        assert out[rids[2]] == out[rids[0]]
        assert out[rids[3]] == out[rids[1]]

    def test_queue_admission_order_and_capacity(self, params):
        prompts = _prompts((4, 4, 4))
        eng = ServeEngine(params, CFG, SP, SERVE)
        rids = [eng.submit(p, max_new_tokens=3) for p in prompts]
        ev = eng.step()
        # only n_slots requests admitted; the third waits queued
        assert ev["admitted"] == rids[:2]
        assert eng.n_queued == 1
        out = eng.run()
        assert sorted(out) == sorted(rids)

    def test_eos_stop_condition(self, params):
        prompt = _prompts((6,))[0]
        ref = _solo(params, prompt, 12)
        eos = ref[3]
        eng = ServeEngine(params, CFG, SP, SERVE)
        rid = eng.submit(prompt, max_new_tokens=12, eos=eos)
        out = eng.run()[rid]
        stop = ref.index(eos)
        assert out == ref[:stop + 1]
        assert eng.finished_requests == []  # harvested

    def test_submit_validation(self, params):
        eng = ServeEngine(params, CFG, SP, SERVE)
        with pytest.raises(ValueError):
            eng.submit([1] * (SERVE.prompt_bucket + 1))
        with pytest.raises(ValueError):
            eng.submit([])
        with pytest.raises(ValueError):
            eng.submit([1, 2], max_new_tokens=SERVE.max_len)  # KV overflow
        with pytest.raises(ValueError):
            eng.submit([1, 2], max_new_tokens=0)


class TestPackedServing:
    def test_packed_matches_masked_decode(self, params):
        """Element-packed (vals, idx) decode through kernels/nm_spmm
        produces the same streams as the re-masked dense weights."""
        prompts = _prompts((5, 10))
        packed_cfg = ServeConfig(n_slots=2, max_len=32, prompt_bucket=12,
                                 packed=True)

        def run(scfg):
            eng = ServeEngine(params, CFG, SP, scfg)
            rids = [eng.submit(p, max_new_tokens=8) for p in prompts]
            out = eng.run()
            return eng, [out[r] for r in rids]

        _, masked = run(SERVE)
        eng_p, packed = run(packed_cfg)
        assert packed == masked
        assert eng_p.store is not None and eng_p.store.n_packed > 0

    def test_store_byte_accounting(self, params):
        store = PackedParamStore.pack(params, SP)
        rep = store.report()
        # u4 store (the default at m=8): vals at n/m of dense + one
        # nibble per survivor (bf16 w: vals = dense/4 at 2:8, u4 idx
        # adds a quarter of vals) -> 16/5 saving
        assert rep["n_packed"] > 0 and rep["idx_bits"] == 4
        assert rep["packed_weight_bytes"] < rep["dense_weight_bytes"]
        want = rep["dense_weight_bytes"] * SP.n / SP.m * 1.25
        assert rep["packed_weight_bytes"] == int(want)
        # stored bytes now EQUAL the accounted SORE 4-bit footprint —
        # the format ships, it is no longer just bookkeeping
        assert rep["packed_weight_bytes"] == rep["packed_weight_bytes_4bit_idx"]
        assert rep["measured_packed_weight_bytes"] == rep["packed_weight_bytes"]
        assert rep["measured_over_accounted_4bit"] == pytest.approx(1.0)
        assert rep["hbm_saving"] == pytest.approx(16 / 5, rel=1e-6)
        # a byte-wide store is still available and accounts the same
        # 4-bit figure it no longer stores
        rep8 = PackedParamStore.pack(params, SP, idx_bits=8).report()
        assert rep8["idx_bits"] == 8
        assert rep8["packed_weight_bytes"] == int(
            rep["dense_weight_bytes"] * SP.n / SP.m * 1.5)
        assert rep8["packed_weight_bytes_4bit_idx"] == rep["packed_weight_bytes"]
        # exclusions hold: embeddings / lm_head stay dense
        assert "embed_table" in store.params["embed"]
        assert "w" in store.params["lm_head"]

    def test_dense_trained_weight_stays_dense(self):
        """Eligibility parity: a weight the training path keeps dense
        (bdwp needs BOTH K and F divisible by m) must not be packed —
        packing it would zero values the masked forward keeps."""
        from repro.serve import pack_tree_element
        tree = {"proj": {"w": jax.random.normal(jax.random.PRNGKey(0),
                                                (32, 20))}}  # F=20 % 8 != 0
        packed, st = pack_tree_element(tree, SP)
        assert "w" in packed["proj"]
        assert st["n_packed"] == 0 and st["n_dense"] == 1

    def test_packed_leaf_consumed_by_kernel_path(self, params):
        """dense_apply dispatches element-packed leaves to nm_spmm; the
        interpret-mode Pallas kernel agrees with the oracle route."""
        from repro.core import bdwp
        from repro.core.sparsity import nm_pack, sparsify

        wd = params["blocks"]["ffn"]["w_gate"]["w"][0]  # (K, F) layer 0
        vals, idx = nm_pack(wd, SP.n, SP.m, axis=0)
        x = jax.random.normal(jax.random.PRNGKey(2), (4, wd.shape[0]),
                              jnp.bfloat16)
        y_oracle = bdwp.nm_linear_packed(x, vals, idx, SP, use_pallas=False)
        y_kernel = bdwp.nm_linear_packed(x, vals, idx, SP, use_pallas=True)
        y_masked = jnp.matmul(x, sparsify(wd, SP, axis=0).astype(x.dtype))
        np.testing.assert_allclose(np.asarray(y_oracle, np.float32),
                                   np.asarray(y_kernel, np.float32),
                                   rtol=2e-2, atol=1e-2)
        np.testing.assert_allclose(np.asarray(y_oracle, np.float32),
                                   np.asarray(y_masked, np.float32),
                                   rtol=2e-2, atol=1e-2)


class TestLifecycleEdges:
    def test_reset_refuses_with_work_in_flight(self, params):
        """reset() must never silently drop live requests: refused while
        anything is queued OR running, allowed (and zeroing) after the
        engine drains."""
        prompt = _prompts((4,))[0]
        eng = ServeEngine(params, CFG, SP, SERVE)
        eng.submit(prompt, max_new_tokens=4)
        with pytest.raises(RuntimeError):
            eng.reset()                      # queued
        eng.step()
        with pytest.raises(RuntimeError):
            eng.reset()                      # running mid-decode
        eng.run()
        eng.reset()
        assert (eng.step_count, eng.decode_steps, eng.decoded_tokens,
                eng.prefill_steps) == (0, 0, 0, 0)

    def test_run_raises_when_not_drained(self, params):
        prompt = _prompts((4,))[0]
        eng = ServeEngine(params, CFG, SP, SERVE)
        eng.submit(prompt, max_new_tokens=10)
        with pytest.raises(RuntimeError, match="did not drain"):
            eng.run(max_steps=2)
        eng.run()  # recoverable: keep stepping to completion

    def test_admit_and_finish_same_step(self, params):
        """max_new_tokens=1 requests finish AT admission (the prefill's
        token is the whole stream) and free their slot inside the same
        admission loop — 3 such requests clear 2 slots in one step()."""
        prompts = _prompts((4, 8, 6))
        firsts = [_solo(params, p, 1) for p in prompts]
        eng = ServeEngine(params, CFG, SP, SERVE)
        rids = [eng.submit(p, max_new_tokens=1) for p in prompts]
        ev = eng.step()
        assert ev["admitted"] == rids
        assert ev["finished"] == rids
        assert ev["active"] == 0
        assert eng.batcher.kv.n_free == SERVE.n_slots
        out = eng.harvest()
        for r, f in zip(rids, firsts):
            assert out[r] == f
            assert len(out[r]) == 1

    def test_eos_on_max_new_tokens_boundary(self, params):
        """EOS sampled exactly at the length limit: both stop conditions
        fire on the same token — the reason must report \"eos\" (the
        stream DID terminate naturally), not \"length\"."""
        prompt = _prompts((6,))[0]
        ref = _solo(params, prompt, 12)
        # pick a boundary whose token appears there FIRST, so eos can't
        # fire early (greedy streams repeat tokens; don't hardcode)
        n = max(i + 1 for i in range(1, len(ref))
                if ref[i] not in ref[:i])
        eos = ref[n - 1]
        assert eos not in ref[:n - 1]  # lands first ON the boundary
        def drain(eng):  # run() harvests (pops _done); step by hand
            while eng._queue or eng._running:
                eng.step()

        eng = ServeEngine(params, CFG, SP, SERVE)
        rid = eng.submit(prompt, max_new_tokens=n, eos=eos)
        drain(eng)
        req = next(r for r in eng.finished_requests if r.rid == rid)
        assert req.tokens == ref[:n]
        assert len(req.tokens) == req.max_new_tokens
        assert req.finish_reason == "eos"
        # control: same limit, an eos that never fires -> "length"
        eng2 = ServeEngine(params, CFG, SP, SERVE)
        rid2 = eng2.submit(prompt, max_new_tokens=n, eos=-1)
        drain(eng2)
        req2 = next(r for r in eng2.finished_requests if r.rid == rid2)
        assert req2.finish_reason == "length"


class TestSlotCacheMechanics:
    def test_alloc_free_lowest_first(self, params):
        from repro.serve import SlotKVCache
        kv = SlotKVCache(CFG, 3, 16)
        assert [kv.alloc(), kv.alloc(), kv.alloc()] == [0, 1, 2]
        assert kv.alloc() is None
        kv.free(1)
        kv.free(0)
        assert kv.alloc() == 0  # deterministic lowest-first reuse
        with pytest.raises(ValueError):
            kv.free(1)  # already free

    def test_seat_writes_only_target_slot(self, params):
        """Seating a prefill cache must not disturb other lanes."""
        from repro.serve.batcher import ContinuousBatcher
        b = ContinuousBatcher(params, CFG, SP, n_slots=3, max_len=16,
                              prompt_bucket=8)
        k0 = np.asarray(b.kv.cache["layers"]["k"], np.float32)
        prompt = _prompts((6,))[0]
        slot, _ = b.admit(prompt)
        k1 = np.asarray(b.kv.cache["layers"]["k"], np.float32)
        assert slot == 0
        other = [s for s in range(3) if s != slot]
        np.testing.assert_array_equal(k1[:, other], k0[:, other])
        assert np.abs(k1[:, slot, :6]).sum() > 0  # prompt KV landed
