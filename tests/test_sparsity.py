"""Property + unit tests for the N:M sparsity core (hypothesis-driven)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import require_or_skip

require_or_skip("hypothesis")  # bare env: skip; CI (REQUIRE_HYPOTHESIS): fail
from hypothesis import given, settings, strategies as st

from repro.core import sparsity as S

jax.config.update("jax_platform_name", "cpu")

NM = st.sampled_from([(1, 4), (2, 4), (2, 8), (4, 8), (2, 16), (1, 8), (8, 8)])


def _rand(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=dtype)


class TestMask:
    @settings(max_examples=40, deadline=None)
    @given(
        nm=NM,
        rows=st.integers(1, 9),
        groups=st.integers(1, 6),
        seed=st.integers(0, 2**16),
    )
    def test_exact_n_survivors_per_group(self, nm, rows, groups, seed):
        n, m = nm
        x = _rand((rows, groups * m), seed)
        mask = S.nm_mask(x, n, m, axis=-1)
        nnz = np.asarray(S.group_nonzeros(jnp.where(mask, 1.0, 0.0), m, -1))
        assert (nnz == n).all()

    @settings(max_examples=25, deadline=None)
    @given(nm=NM, seed=st.integers(0, 2**16))
    def test_keeps_largest_magnitudes(self, nm, seed):
        n, m = nm
        x = _rand((4, 4 * m), seed)
        kept = jnp.where(S.nm_mask(x, n, m), jnp.abs(x), jnp.inf)
        dropped = jnp.where(S.nm_mask(x, n, m), -jnp.inf, jnp.abs(x))
        kept_g = kept.reshape(4, 4, m).min(-1)
        drop_g = dropped.reshape(4, 4, m).max(-1)
        assert (np.asarray(kept_g) >= np.asarray(drop_g) - 1e-7).all()

    def test_dense_when_n_equals_m(self):
        x = _rand((3, 16), 0)
        assert bool(S.nm_mask(x, 8, 8).all())

    def test_axis0(self):
        x = _rand((16, 5), 1)
        mask = S.nm_mask(x, 2, 8, axis=0)
        nnz = np.asarray(mask.sum(0))
        assert (nnz == 4).all()  # 16/8 = 2 groups * 2 survivors

    def test_tie_break_prefers_earlier_index(self):
        x = jnp.ones((1, 8))
        mask = S.nm_mask(x, 2, 8)
        assert np.asarray(mask)[0].tolist() == [True, True] + [False] * 6

    def test_all_zero_group(self):
        mask = S.nm_mask(jnp.zeros((2, 8)), 2, 8)
        assert int(mask.sum()) == 4  # deterministic, 2 per group

    def test_indivisible_axis_raises(self):
        with pytest.raises(ValueError):
            S.nm_mask(_rand((2, 10), 0), 2, 8)


class TestPack:
    @settings(max_examples=40, deadline=None)
    @given(
        nm=NM,
        rows=st.integers(1, 8),
        groups=st.integers(1, 5),
        seed=st.integers(0, 2**16),
        dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    )
    def test_pack_unpack_roundtrip_equals_sparsify(self, nm, rows, groups, seed, dtype):
        n, m = nm
        x = _rand((rows, groups * m), seed, dtype)
        v, i = S.nm_pack(x, n, m, axis=-1)
        assert v.shape == (rows, groups * n)
        assert i.dtype == jnp.uint8
        dense = S.nm_unpack_n(v, i, n, m, axis=-1)
        sp = S.sparsify(x, S.SparsityConfig(n=n, m=m), axis=-1)
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(sp))

    @settings(max_examples=20, deadline=None)
    @given(nm=NM, seed=st.integers(0, 2**16))
    def test_indices_ascending_within_group(self, nm, seed):
        n, m = nm
        _, i = S.nm_pack(_rand((3, 4 * m), seed), n, m, axis=-1)
        ig = np.asarray(i).reshape(3, 4, n)
        assert (np.diff(ig.astype(int), axis=-1) > 0).all() or n == 1

    def test_pack_axis0(self):
        x = _rand((16, 6), 2)
        v, i = S.nm_pack(x, 2, 8, axis=0)
        assert v.shape == (4, 6)
        dense = S.nm_unpack_n(v, i, 2, 8, axis=0)
        sp = S.sparsify(x, S.SparsityConfig(n=2, m=8), axis=0)
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(sp))


class TestShared:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**16), tile=st.sampled_from([8, 16, 32]))
    def test_pattern_identical_within_tile(self, seed, tile):
        x = _rand((32, 64), seed)
        mask = S.nm_mask_shared(x, 2, 8, axis=0, share_axis=1, tile=tile)
        m = np.asarray(mask)
        for t0 in range(0, 64, tile):
            ref_col = m[:, t0]
            assert (m[:, t0 : t0 + tile] == ref_col[:, None]).all()

    def test_exact_survivors(self):
        x = _rand((32, 64), 7)
        cfg = S.SparsityConfig(n=2, m=8, granularity="shared", tile=16)
        sp = S.sparsify(x, cfg, axis=0, share_axis=1)
        nnz = np.asarray(S.group_nonzeros(sp, 8, 0))
        assert (nnz <= 2).all()

    def test_non_divisible_tile_padding(self):
        x = _rand((16, 40), 9)
        mask = S.nm_mask_shared(x, 2, 8, axis=0, share_axis=1, tile=16)
        assert mask.shape == x.shape


class TestConfig:
    def test_method_routing(self):
        assert S.SparsityConfig(method="bdwp").prunes_ff_weights()
        assert S.SparsityConfig(method="bdwp").prunes_bp_weights()
        assert not S.SparsityConfig(method="bdwp").prunes_bp_grads()
        assert S.SparsityConfig(method="srste").prunes_ff_weights()
        assert not S.SparsityConfig(method="srste").prunes_bp_weights()
        assert S.SparsityConfig(method="sdwp").prunes_bp_weights()
        assert not S.SparsityConfig(method="sdwp").prunes_ff_weights()
        assert S.SparsityConfig(method="sdgp").prunes_bp_grads()
        assert S.DENSE.is_dense

    def test_validation(self):
        with pytest.raises(ValueError):
            S.SparsityConfig(n=9, m=8)
        with pytest.raises(ValueError):
            S.SparsityConfig(method="nope")

    def test_flops_fraction(self):
        assert S.nm_flops_fraction(S.SparsityConfig(n=2, m=8)) == 0.25
        assert S.nm_flops_fraction(S.DENSE) == 1.0


class TestStackedExpertLeaves:
    """Properties of the N:M core on stacked (E, k, f) MoE expert
    leaves — the bare-array pre-generation sites: per-expert masks from
    one fused selection over the whole stack, exact packing round-trips,
    and FF/decay mask agreement from a shared fp32 source."""

    @settings(max_examples=25, deadline=None)
    @given(nm=NM, e=st.integers(1, 4), kg=st.integers(1, 3),
           fg=st.integers(1, 3), seed=st.integers(0, 2**16))
    def test_pair_equals_vmapped_single_matrix_masks(self, nm, e, kg, fg,
                                                     seed):
        """nm_mask_pair over a stacked leaf == vmapping nm_mask over the
        expert axis, along both grouped axes, bitwise."""
        n, m = nm
        w = _rand((e, kg * m, fg * m), seed)
        ff, bp = S.nm_mask_pair(w, n, m, 1, 2)
        ff_ref = jax.vmap(lambda x: S.nm_mask(x, n, m, axis=0))(w)
        bp_ref = jax.vmap(lambda x: S.nm_mask(x, n, m, axis=1))(w)
        np.testing.assert_array_equal(np.asarray(ff), np.asarray(ff_ref))
        np.testing.assert_array_equal(np.asarray(bp), np.asarray(bp_ref))

    @settings(max_examples=25, deadline=None)
    @given(nm=NM, e=st.integers(1, 4), kg=st.integers(1, 3),
           fg=st.integers(1, 3), seed=st.integers(0, 2**16))
    def test_exactly_n_nonzero_per_group_per_expert(self, nm, e, kg, fg,
                                                    seed):
        n, m = nm
        w = _rand((e, kg * m, fg * m), seed)
        ff, bp = S.nm_mask_pair(w, n, m, 1, 2)
        for mask, axis in ((ff, 1), (bp, 2)):
            nnz = np.asarray(S.group_nonzeros(
                jnp.where(mask, 1.0, 0.0), m, axis))
            assert (nnz == n).all()

    @settings(max_examples=25, deadline=None)
    @given(nm=NM, e=st.integers(1, 4), kg=st.integers(1, 3),
           fg=st.integers(1, 2), seed=st.integers(0, 2**16),
           dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
    def test_stacked_pack_roundtrip(self, nm, e, kg, fg, seed, dtype):
        """nm_pack_from_mask on the stacked contraction axis: packed axis
        shrinks k -> k*n/m, uint8 offsets, and unpacking reproduces the
        masked leaf exactly (pack keeps values verbatim)."""
        n, m = nm
        w = _rand((e, kg * m, fg * m), seed, dtype)
        mask = S.nm_mask(w, n, m, axis=1)
        v, i = S.nm_pack_from_mask(w, mask, n, m, axis=1)
        assert v.shape == (e, kg * n, fg * m) and i.dtype == jnp.uint8
        dense = S.nm_unpack_n(v, i, n, m, axis=1)
        np.testing.assert_array_equal(
            np.asarray(dense), np.asarray(jnp.where(mask, w, 0)))

    @settings(max_examples=25, deadline=None)
    @given(e=st.integers(1, 3), groups=st.integers(1, 3),
           seed=st.integers(0, 2**16),
           eps=st.floats(1e-4, 1e-3), base=st.floats(0.5, 2.0))
    def test_near_tie_ff_and_decay_agree_from_fp32_source(
            self, e, groups, seed, eps, base):
        """A near-tie (two weights closer than bf16 resolution) makes
        bf16-scored and fp32-scored masks disagree — but every selection
        derived from the SAME fp32 leaf (the pre-generation invariant:
        FF operand and SR-STE decay mask) agrees bitwise regardless."""
        m = 8
        w = _rand((e, groups * m, m), seed) * 0.01
        # plant a sub-bf16-resolution tie in one group of every expert:
        # base snaps to the bf16 lattice so base*(1+rel) is guaranteed to
        # round back to it (rel in [1.6e-6, 1.6e-5]: far above fp32
        # resolution, far below bf16's ~0.4%)
        base = float(jnp.bfloat16(base))
        rel = eps / 64.0
        w = w.at[:, 0, 0].set(base).at[:, 1, 0].set(base * (1.0 + rel))
        ff, _ = S.nm_mask_pair(w, 1, m, 1, 2)
        dec = S.nm_mask(w, 1, m, axis=1)
        np.testing.assert_array_equal(np.asarray(ff), np.asarray(dec))
        # premise: the shared-source property is load-bearing — the
        # bf16-scored selection really does flip on the planted tie
        m16 = S.nm_mask(w.astype(jnp.bfloat16), 1, m, axis=1)
        assert bool(np.asarray(ff)[..., 1, 0].all())
        assert not bool(np.asarray(m16)[..., 1, 0].any())


class TestU4Index:
    """u4 index plane (two in-group offsets per byte): bitwise
    roundtrip on arbitrary axes and odd lengths, agreement of the
    nibble-expanding decompress with the byte-wide one, and the SORE
    kernel's native u4 output."""

    @settings(max_examples=40, deadline=None)
    @given(rows=st.integers(1, 6), length=st.integers(1, 33),
           axis=st.integers(0, 1), seed=st.integers(0, 2**16))
    def test_roundtrip_any_offsets(self, rows, length, axis, seed):
        """pack_idx_u4 ∘ unpack_idx_u4 == id for any offsets < 16,
        including odd axis lengths (the pad nibble never leaks)."""
        shape = (rows, length) if axis == 1 else (length, rows)
        rng = np.random.default_rng(seed)
        idx = jnp.asarray(rng.integers(0, 16, shape), jnp.uint8)
        packed = S.pack_idx_u4(idx, axis=axis)
        assert packed.shape[axis] == (length + 1) // 2
        out = S.unpack_idx_u4(packed, length, axis=axis)
        assert out.dtype == jnp.uint8
        np.testing.assert_array_equal(np.asarray(out), np.asarray(idx))

    @settings(max_examples=25, deadline=None)
    @given(nm=NM, e=st.integers(1, 3), kg=st.integers(1, 3),
           fg=st.integers(1, 2), seed=st.integers(0, 2**16))
    def test_stacked_moe_pack_roundtrip(self, nm, e, kg, fg, seed):
        """Real nm_pack offsets of a stacked (E, K, F) MoE expert leaf
        survive the u4 trip along the compact contraction axis — odd
        group counts (kg*n odd) exercise the pad path."""
        n, m = nm
        w = _rand((e, kg * m, fg * m), seed)
        _, idx = S.nm_pack(w, n, m, axis=1)
        kc = kg * n
        packed = S.pack_idx_u4(idx, axis=1)
        assert packed.shape == (e, (kc + 1) // 2, fg * m)
        out = S.unpack_idx_u4(packed, kc, axis=1)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(idx))

    @settings(max_examples=20, deadline=None)
    @given(nm=NM, kg=st.integers(1, 4), fg=st.integers(1, 2),
           seed=st.integers(0, 2**16),
           dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
    def test_decompress_u4_equals_u8(self, nm, kg, fg, seed, dtype):
        """decompress_nm(idx_bits=4) == decompress_nm(idx_bits=8) on the
        same offsets, bitwise, on both compact-axis positions."""
        from repro.kernels.nm_spmm_shared import decompress_nm
        n, m = nm
        w = _rand((kg * m, fg * m), seed, dtype)
        vals, idx = S.nm_pack(w, n, m, axis=0)
        d8 = decompress_nm(vals, idx, n, m, axis=0)
        d4 = decompress_nm(vals, S.pack_idx_u4(idx, axis=0), n, m,
                           axis=0, idx_bits=4)
        np.testing.assert_array_equal(np.asarray(d8), np.asarray(d4))

    def test_values_above_15_rejected_by_roundtrip(self):
        """The format is 4-bit by contract: offsets >= 16 cannot survive
        (documented precondition, m <= 16)."""
        idx = jnp.asarray([[16, 1]], jnp.uint8)
        out = S.unpack_idx_u4(S.pack_idx_u4(idx, axis=1), 2, axis=1)
        assert not (np.asarray(out) == np.asarray(idx)).all()

    def test_unpack_wrong_length_raises(self):
        packed = jnp.zeros((3, 2), jnp.uint8)
        with pytest.raises(ValueError):
            S.unpack_idx_u4(packed, 7, axis=1)  # needs 4 bytes, has 2

    @settings(max_examples=10, deadline=None)
    @given(nm=st.sampled_from([(2, 8), (2, 4), (4, 8), (2, 16)]),
           rg=st.integers(1, 2), kg=st.integers(1, 3),
           seed=st.integers(0, 2**16))
    def test_nm_compact_u4_matches_packed_oracle(self, nm, rg, kg, seed):
        """The SORE kernel's native u4 output (Pallas, interpret mode)
        == pack_idx_u4 of the byte-wide oracle output, bitwise."""
        from repro.kernels import ops
        n, m = nm
        x = _rand((rg * 8, kg * m), seed)
        v8, i8 = ops.nm_compact(x, n, m, use_pallas=False)
        v4, i4 = ops.nm_compact(x, n, m, use_pallas=True, idx_bits=4)
        np.testing.assert_array_equal(np.asarray(v8), np.asarray(v4))
        np.testing.assert_array_equal(
            np.asarray(S.pack_idx_u4(i8, axis=-1)), np.asarray(i4))


class TestSRSTE:
    def test_decay_only_pruned(self):
        x = _rand((4, 16), 3)
        mask = S.nm_mask(x, 2, 8)
        d = S.srste_decay(x, mask, 0.5)
        assert np.allclose(np.asarray(d[mask]), 0.0)
        pruned = ~np.asarray(mask)
        np.testing.assert_allclose(
            np.asarray(d)[pruned], 0.5 * np.asarray(x)[pruned], rtol=1e-6
        )
